"""Make `compile` importable when pytest runs from the python/ directory,
and degrade gracefully when `hypothesis` is absent (the offline image
ships jax + numpy but no hypothesis): the property sweeps then run as
single-example smoke tests instead of breaking collection. Where
hypothesis exists (e.g. CI with network), the full sweeps run unchanged.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import types

    class _Strategy:
        """One representative example standing in for a search strategy."""

        def __init__(self, example):
            self.example = example

    def _integers(lo, hi):
        return _Strategy((lo + hi) // 2)

    def _sampled_from(options):
        return _Strategy(options[0])

    def _floats(lo, hi, **_kwargs):
        return _Strategy((lo + hi) / 2.0)

    def _settings(**_kwargs):
        def decorate(fn):
            return fn

        return decorate

    def _given(**strategies):
        def decorate(fn):
            def single_example():
                fn(**{name: s.example for name, s in strategies.items()})

            single_example.__name__ = fn.__name__
            single_example.__doc__ = fn.__doc__
            return single_example

        return decorate

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.floats = _floats
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
