"""L1 Pallas kernel: radix-4 decimation-in-time Cooley-Tukey FFT stage.

The paper's non-sequential benchmark (Sec. 7) runs 64 independent
4096-point radix-4 FFTs across the cluster; in the k-th stage each core
computes 4 butterflies on inputs at stride N/(4*4k).  Here the same
butterfly network is expressed for the TPU: one Pallas call per stage, the
grid iterating over butterfly groups, with the stride pattern carried by
the reshape between stages rather than by remote-Tile addresses.

Complex values are carried as separate re/im f32 planes — the TPU analog of
the paper's Complex32 (16 b real + imag) SIMD pairs, kept at f32 precision
since the MXU/VPU path here is f32.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def digit_reverse_indices(n: int) -> np.ndarray:
    """Base-4 digit-reversed index permutation (radix-4 DIT input order)."""
    m = 0
    while (1 << (2 * m)) < n:
        m += 1
    assert 4 ** m == n, f"FFT length {n} is not a power of 4"
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for _ in range(m):
        rev = rev * 4 + (idx & 3)
        idx >>= 2
    return rev


def _r4_stage_kernel(yr_ref, yi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    """Combine 4 length-L sub-DFTs into one length-4L DFT.

    Block shapes: y/o (1, 4, L); twiddles w (3, L) with w[p-1] = W_{4L}^{p*j}.
    Output row q is X[j + q*L] = sum_p (-i)^{pq} * w^{p*j} * Y_p[j] — the
    radix-4 butterfly each Snitch core computes with Xpulpimg MACs.
    """
    y0r, y0i = yr_ref[0, 0, :], yi_ref[0, 0, :]
    # Twiddle rotations t_p = w^p * Y_p for p = 1..3.
    t1r = wr_ref[0, :] * yr_ref[0, 1, :] - wi_ref[0, :] * yi_ref[0, 1, :]
    t1i = wr_ref[0, :] * yi_ref[0, 1, :] + wi_ref[0, :] * yr_ref[0, 1, :]
    t2r = wr_ref[1, :] * yr_ref[0, 2, :] - wi_ref[1, :] * yi_ref[0, 2, :]
    t2i = wr_ref[1, :] * yi_ref[0, 2, :] + wi_ref[1, :] * yr_ref[0, 2, :]
    t3r = wr_ref[2, :] * yr_ref[0, 3, :] - wi_ref[2, :] * yi_ref[0, 3, :]
    t3i = wr_ref[2, :] * yi_ref[0, 3, :] + wi_ref[2, :] * yr_ref[0, 3, :]

    # Radix-4 butterfly: multiply row p by (-i)^(p*q), q = output row.
    or_ref[0, 0, :] = y0r + t1r + t2r + t3r
    oi_ref[0, 0, :] = y0i + t1i + t2i + t3i
    or_ref[0, 1, :] = y0r + t1i - t2r - t3i        # -i*t1, -t2, +i*t3
    oi_ref[0, 1, :] = y0i - t1r - t2i + t3r
    or_ref[0, 2, :] = y0r - t1r + t2r - t3r
    oi_ref[0, 2, :] = y0i - t1i + t2i - t3i
    or_ref[0, 3, :] = y0r - t1i - t2r + t3i        # +i*t1, -t2, -i*t3
    oi_ref[0, 3, :] = y0i + t1r - t2i - t3r


def _r4_stage(yr: jnp.ndarray, yi: jnp.ndarray, wr: jnp.ndarray,
              wi: jnp.ndarray):
    """One radix-4 combine over groups: y (G, 4, L) -> (G, 4, L) outputs
    where output row q of group g holds X[j + qL]."""
    g, four, l = yr.shape
    assert four == 4
    out_shape = jax.ShapeDtypeStruct((g, 4, l), yr.dtype)
    return pl.pallas_call(
        _r4_stage_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, 4, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 4, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((3, l), lambda i: (0, 0)),
            pl.BlockSpec((3, l), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 4, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 4, l), lambda i: (i, 0, 0)),
        ],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(yr, yi, wr, wi)


@functools.partial(jax.jit, static_argnames=())
def fft(x_re: jnp.ndarray, x_im: jnp.ndarray):
    """Radix-4 DIT FFT over the last axis of (batch, N); N must be 4^m.

    Returns (re, im). Matches ref.fft (jnp.fft.fft) to f32 tolerance.
    """
    batch, n = x_re.shape
    rev = jnp.asarray(digit_reverse_indices(n))
    yr = jnp.take(x_re, rev, axis=1)
    yi = jnp.take(x_im, rev, axis=1)

    l = 1
    while l < n:
        groups = batch * n // (4 * l)
        yr = yr.reshape(groups, 4, l)
        yi = yi.reshape(groups, 4, l)
        j = np.arange(l)
        ang = -2.0 * np.pi * np.outer(np.arange(1, 4), j) / (4 * l)
        wr = jnp.asarray(np.cos(ang), dtype=x_re.dtype)
        wi = jnp.asarray(np.sin(ang), dtype=x_re.dtype)
        yr, yi = _r4_stage(yr, yi, wr, wi)
        # Row q of each group is the (j + qL) slice of the new length-4L
        # transform: (G, 4, L) already lays X out contiguously as 4L words.
        l *= 4

    return yr.reshape(batch, n), yi.reshape(batch, n)
