"""Pure-jnp correctness oracles for the Pallas kernels.

Each function here is the *specification*: the Pallas kernels in this
package (gemm.py, axpy.py, dotp.py, fft.py) must match these up to float
tolerance. pytest + hypothesis sweep shapes/dtypes against these oracles at
build time; the Rust integration tests additionally compare the cluster
simulator's memory image against the AOT-compiled versions of the same
functions.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B, accumulating in float32 regardless of input dtype."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def axpy(alpha, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """z = alpha * x + y (BLAS-1 AXPY)."""
    return alpha * x + y


def dotp(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Scalar dot product, f32 accumulation."""
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def fft(x_re: jnp.ndarray, x_im: jnp.ndarray):
    """DFT over the last axis; returns (re, im) pair.

    Oracle for the radix-4 Cooley-Tukey implementation: defer to jnp.fft,
    which is an independent code path from our stage-by-stage kernels.
    """
    x = x_re.astype(jnp.complex64) + 1j * x_im.astype(jnp.complex64)
    y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(x_re.dtype), jnp.imag(y).astype(x_re.dtype)


def spmmadd_dense(a_dense: jnp.ndarray, b_dense: jnp.ndarray) -> jnp.ndarray:
    """Semantic result of CSR SpMMadd, on densified operands.

    The cluster simulator performs the addition in CSR form (the paper's
    GraphBLAS workload); the densified sum must equal this elementwise add.
    """
    return a_dense + b_dense
