"""L1 Pallas kernel: tiled GEMM — the TPU re-expression of TeraPool's
blocked MatMul (Sec. 4.1 / Sec. 7 of the paper).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): TeraPool's Snitch
cores hold a 4x4 output block in the 32-entry integer register file and
hide shared-L1 latency behind 8 outstanding loads.  On TPU the analog is a
VMEM-resident (bm, bn) output tile accumulated across a K-grid:

  * register-file output block  -> VMEM accumulator tile (o_ref)
  * 8-entry transaction table   -> Pallas's implicit double buffering of
                                   the (bm, bk) / (bk, bn) input blocks
                                   between grid steps
  * word-interleaved shared L1  -> BlockSpec index_map expressing the
                                   HBM<->VMEM schedule
  * Snitch FMA / zhinx SIMD     -> MXU jnp.dot (f32 or bf16)

The kernel is always lowered with interpret=True: the CPU PJRT client used
by the Rust runtime cannot execute Mosaic custom-calls.  Correctness is
pinned to ref.gemm by python/tests; the block-size/VMEM analysis for a real
TPU lives in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j].

    The K dimension is the innermost ("arbitrary") grid axis so the output
    tile stays resident in VMEM across the whole K loop — the Pallas
    counterpart of keeping the 4x4 block in Snitch's register file for the
    entire inner loop.
    """
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 32, bn: int = 32,
         bk: int = 32) -> jnp.ndarray:
    """C = A @ B via a Pallas grid of (M/bm, N/bn, K/bk) tiles.

    Block sizes must divide the problem; python/tests sweeps this with
    hypothesis. On a real TPU bm=bn=128, bk=256 fills the MXU; defaults here
    are sized for fast interpret-mode runs.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"block sizes ({bm},{bn},{bk}) must divide problem ({m},{n},{k})")

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step (double-buffered inputs + acc).

    Used by DESIGN.md §Perf to check the chosen real-TPU block sizes fit
    the ~16 MiB/core VMEM budget: 2*(bm*bk + bk*bn) input buffers plus the
    resident (bm, bn) accumulator.
    """
    return dtype_bytes * (2 * (bm * bk + bk * bn) + bm * bn)


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU 128x128x128 macro-op occupancy for a tile step.

    A (bm, bk) x (bk, bn) tile issues ceil(bm/128)*ceil(bn/128)*ceil(bk/128)
    MXU passes; utilization is the useful fraction of those passes. This is
    the structural estimate recorded in EXPERIMENTS.md §Perf (interpret-mode
    wallclock is not a TPU proxy).
    """
    import math

    passes = (math.ceil(bm / 128) * math.ceil(bn / 128) * math.ceil(bk / 128))
    useful = (bm * bn * bk) / (128 * 128 * 128)
    return useful / passes
