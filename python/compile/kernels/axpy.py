"""L1 Pallas kernels for the BLAS-1 *local-access* benchmarks of the paper
(Sec. 7): AXPY and DOTP.

In TeraPool these kernels fetch operands from the local-Tile interleaved
region (1-cycle access) and are bound by local interconnect bandwidth; on
TPU the analog is a VPU-elementwise pass over VMEM blocks streamed from
HBM. The grid dimension plays the role of the per-Tile data partitioning:
block i of the Pallas grid corresponds to the slice PE-group i owns in the
word-interleaved L1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def axpy(alpha: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, *,
         block: int = 1024) -> jnp.ndarray:
    """z = alpha*x + y over 1-D arrays; block must divide len(x)."""
    (n,) = x.shape
    assert y.shape == (n,) and n % block == 0
    alpha = jnp.asarray(alpha, x.dtype).reshape((1,))
    return pl.pallas_call(
        _axpy_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # broadcast alpha
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(alpha, x, y)


def _dotp_kernel(x_ref, y_ref, acc_ref):
    """Accumulate partial dot products across the grid; the accumulator
    block is revisited by every grid step (the reduction tree the paper
    implements with atomic fetch&add at the join barrier)."""
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(x * y, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block",))
def dotp(x: jnp.ndarray, y: jnp.ndarray, *, block: int = 1024) -> jnp.ndarray:
    """Scalar dot product with f32 accumulation; block must divide len(x)."""
    (n,) = x.shape
    assert y.shape == (n,) and n % block == 0
    out = pl.pallas_call(
        _dotp_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(x, y)
    return out[0]
