"""L2: JAX entry functions for every benchmark the Rust coordinator runs.

Each function here composes the L1 Pallas kernels (python/compile/kernels)
into the exact problem shapes the cluster simulator executes, and is
AOT-lowered by aot.py to artifacts/<name>.hlo.txt.  The Rust runtime
(rust/src/runtime) loads these artifacts via PJRT and uses them as *golden
references*: the simulated 1024-PE cluster's memory image after a kernel
run must match the artifact's output.

Shapes mirror Sec. 7 of the paper:
  * gemm    — 256x256x256 f32 tiled MatMul (global-access kernel)
  * axpy    — 256 Ki-element f32 AXPY (local-access kernel)
  * dotp    — 256 Ki-element f32 dot product (local-access, join reduction)
  * fft     — 64 independent 4096-point radix-4 FFTs (non-sequential)
  * spmmadd — densified oracle for the CSR SpMMadd GraphBLAS kernel
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import axpy as axpy_k
from .kernels import fft as fft_k
from .kernels import gemm as gemm_k
from .kernels import ref

F32 = jnp.float32


def gemm_entry(a, b):
    return (gemm_k.gemm(a, b, bm=32, bn=32, bk=32),)


def axpy_entry(alpha, x, y):
    return (axpy_k.axpy(alpha, x, y, block=1024),)


def dotp_entry(x, y):
    return (axpy_k.dotp(x, y, block=1024),)


def fft_entry(x_re, x_im):
    return fft_k.fft(x_re, x_im)


def spmmadd_entry(a_dense, b_dense):
    return (ref.spmmadd_dense(a_dense, b_dense),)


GEMM_N = 256
AXPY_N = 256 * 1024
FFT_BATCH, FFT_N = 64, 4096
SPM_N = 512  # densified SpMMadd matrix edge

# name -> (entry fn, example args); single source of truth for aot.py and
# python/tests/test_model.py. Every entry returns a tuple (lowered with
# return_tuple=True; the Rust side unwraps with to_tuple1/to_vec).
ENTRIES = {
    "gemm": (
        gemm_entry,
        (
            jax.ShapeDtypeStruct((GEMM_N, GEMM_N), F32),
            jax.ShapeDtypeStruct((GEMM_N, GEMM_N), F32),
        ),
    ),
    "axpy": (
        axpy_entry,
        (
            jax.ShapeDtypeStruct((), F32),
            jax.ShapeDtypeStruct((AXPY_N,), F32),
            jax.ShapeDtypeStruct((AXPY_N,), F32),
        ),
    ),
    "dotp": (
        dotp_entry,
        (
            jax.ShapeDtypeStruct((AXPY_N,), F32),
            jax.ShapeDtypeStruct((AXPY_N,), F32),
        ),
    ),
    "fft": (
        fft_entry,
        (
            jax.ShapeDtypeStruct((FFT_BATCH, FFT_N), F32),
            jax.ShapeDtypeStruct((FFT_BATCH, FFT_N), F32),
        ),
    ),
    "spmmadd": (
        spmmadd_entry,
        (
            jax.ShapeDtypeStruct((SPM_N, SPM_N), F32),
            jax.ShapeDtypeStruct((SPM_N, SPM_N), F32),
        ),
    ),
}
