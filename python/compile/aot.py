"""AOT lowering: JAX entry functions -> artifacts/<name>.hlo.txt.

HLO **text** (not ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs only here, at build time (``make artifacts``); the Rust binary
is self-contained afterwards.  A manifest with input shapes is emitted next
to the artifacts so the Rust runtime can allocate matching literals.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ENTRIES


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str) -> str:
    fn, example_args = ENTRIES[name]
    lowered = jax.jit(fn).lower(*example_args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory for <name>.hlo.txt artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of entry names to lower")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    names = args.only or list(ENTRIES)
    for name in names:
        text = lower_entry(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, example_args = ENTRIES[name]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in example_args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path}")

    # Line-oriented twin for the Rust runtime (the offline build has no
    # JSON parser crate; see rust/src/runtime.rs::parse_manifest).
    txt_path = os.path.join(args.out_dir, "manifest.txt")
    with open(txt_path, "w") as f:
        f.write("# artifact <name> <file> <sha256> / input <name> <dtype> <dims>\n")
        for name, entry in manifest.items():
            f.write(f"artifact {name} {entry['file']} {entry['sha256']}\n")
            for inp in entry["inputs"]:
                dims = ",".join(str(d) for d in inp["shape"]) or "scalar"
                f.write(f"input {name} {inp['dtype']} {dims}\n")
    print(f"wrote {txt_path}")


if __name__ == "__main__":
    main()
