"""AOT lowering: JAX entry functions -> artifacts/<name>.hlo.txt, plus
build-time golden evaluation -> artifacts/<name>.golden.bin.

HLO **text** (not ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that downstream HLO tooling rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs only here, at build time (``make artifacts``); the Rust binary
is self-contained afterwards.  Two manifest-described products per entry:

* ``<name>.hlo.txt`` — the lowered computation, sha256-fingerprinted for
  provenance;
* ``<name>.golden.bin`` — the entry's *evaluated* output on the canonical
  deterministic inputs (the same closed-form vectors the Rust trace
  builders stage, ``kernels::axpy::input_x`` etc.), flattened f32
  little-endian.  Golden evaluation runs the pure-jnp oracles in
  ``kernels/ref.py`` (the specification the Pallas kernels are pinned to
  by python/tests), so the Rust golden tests compare the cluster
  simulator against an independent code path with no FFI at test time.

spmmadd's canonical inputs are CSR matrices drawn from the Rust-side
SplitMix64 generator rather than a closed form; ``rng.py`` ports the
generator bit-for-bit (cross-language pinned by python/tests/test_rng.py
and rust/src/rng.rs), densifies the same matrices, and the dense-sum
oracle evaluates them into ``spmmadd.golden.bin``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels import ref
from .model import AXPY_N, ENTRIES, FFT_BATCH, FFT_N, GEMM_N, SPM_N
from .rng import spmmadd_dense_inputs


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str) -> str:
    fn, example_args = ENTRIES[name]
    lowered = jax.jit(fn).lower(*example_args)
    return to_hlo_text(lowered)


def _ramp(n: int, mod: int, scale: float, shift: float) -> np.ndarray:
    """The Rust trace builders' closed-form input: (i % mod)*scale - shift."""
    i = np.arange(n, dtype=np.float64)
    return ((i % mod) * scale - shift).astype(np.float32)


def golden_inputs(name: str):
    """Canonical inputs per entry, bit-identical to the Rust generators
    (rust/src/kernels/{axpy,dotp,gemm,fft}.rs input_* functions)."""
    if name == "axpy":
        return (
            np.float32(2.0),
            _ramp(AXPY_N, 97, 0.125, 6.0),
            _ramp(AXPY_N, 31, 0.5, 7.75),
        )
    if name == "dotp":
        return (_ramp(AXPY_N, 13, 0.25, 1.5), _ramp(AXPY_N, 7, 0.5, 1.0))
    if name == "gemm":
        return (
            _ramp(GEMM_N * GEMM_N, 11, 0.25, 1.25).reshape(GEMM_N, GEMM_N),
            _ramp(GEMM_N * GEMM_N, 9, 0.125, 0.5).reshape(GEMM_N, GEMM_N),
        )
    if name == "fft":
        return (
            _ramp(FFT_BATCH * FFT_N, 17, 0.25, 2.0).reshape(FFT_BATCH, FFT_N),
            _ramp(FFT_BATCH * FFT_N, 5, 0.5, 1.0).reshape(FFT_BATCH, FFT_N),
        )
    if name == "spmmadd":
        # Densified canonical CSR pair from the ported SplitMix64
        # generator (rng.py) — bit-identical to Csr::random in
        # rust/src/kernels/spmmadd.rs.
        return spmmadd_dense_inputs(SPM_N)
    return None


# Pure-jnp oracle per entry (the specification layer of kernels/ref.py).
GOLDEN_ORACLES = {
    "axpy": lambda alpha, x, y: (ref.axpy(alpha, x, y),),
    "dotp": lambda x, y: (ref.dotp(x, y).reshape(1),),
    "gemm": lambda a, b: (ref.gemm(a, b),),
    "fft": lambda re, im: ref.fft(re, im),
    "spmmadd": lambda a, b: (ref.spmmadd_dense(a, b),),
}


def evaluate_golden(name: str):
    """Flattened f32 concatenation of the entry's outputs, or None."""
    inputs = golden_inputs(name)
    if inputs is None or name not in GOLDEN_ORACLES:
        return None
    outputs = GOLDEN_ORACLES[name](*(jnp.asarray(a) for a in inputs))
    flat = [np.asarray(o, dtype=np.float32).reshape(-1) for o in outputs]
    return np.concatenate(flat)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory for <name>.hlo.txt artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of entry names to lower")
    ap.add_argument("--skip-goldens", action="store_true",
                    help="emit HLO + manifest only (no golden evaluation)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    names = args.only or list(ENTRIES)
    for name in names:
        text = lower_entry(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, example_args = ENTRIES[name]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in example_args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")

        if not args.skip_goldens:
            golden = evaluate_golden(name)
            if golden is not None:
                gfile = f"{name}.golden.bin"
                gpath = os.path.join(args.out_dir, gfile)
                golden.astype("<f4").tofile(gpath)
                manifest[name]["golden"] = {"file": gfile, "words": int(golden.size)}
                print(f"wrote {gpath} ({golden.size} words)")

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path}")

    # Line-oriented twin for the Rust runtime (the offline build has no
    # JSON parser crate; see rust/src/runtime.rs::parse_manifest).
    txt_path = os.path.join(args.out_dir, "manifest.txt")
    with open(txt_path, "w") as f:
        f.write("# artifact <name> <file> <sha256> / input <name> <dtype> <dims>"
                " / golden <name> <file> <words>\n")
        for name, entry in manifest.items():
            f.write(f"artifact {name} {entry['file']} {entry['sha256']}\n")
            for inp in entry["inputs"]:
                dims = ",".join(str(d) for d in inp["shape"]) or "scalar"
                f.write(f"input {name} {inp['dtype']} {dims}\n")
            if "golden" in entry:
                g = entry["golden"]
                f.write(f"golden {name} {g['file']} {g['words']}\n")
    print(f"wrote {txt_path}")


if __name__ == "__main__":
    main()
