"""SplitMix64 port of rust/src/rng.rs, plus the CSR workload generator of
rust/src/kernels/spmmadd.rs.

The SpMMadd kernel's canonical inputs are sparse CSR matrices drawn from
the Rust-side SplitMix64 generator — not a closed form — which is why the
kernel long had no JAX golden. This module reproduces the generator (and
the exact draw *order* of ``Csr::random``) bit-for-bit, so ``aot.py`` can
densify the same matrices and evaluate ``ref.spmmadd_dense`` into
``artifacts/spmmadd.golden.bin``.

Cross-language contract: ``python/tests/test_rng.py`` and the tests in
``rust/src/rng.rs`` pin the first 64 draws of seed ``0x5EED`` to the same
constants; drift on either side fails both suites.
"""

from __future__ import annotations

import numpy as np

_MASK = (1 << 64) - 1

# The canonical SpMMadd workload (mirrors `terapool validate` and the
# golden tests in rust/tests/golden.rs): 512×512, ~8 nnz/row, seed 0x5EED;
# B's seed is derived exactly as in rust/src/kernels/spmmadd.rs.
SPMMADD_SEED = 0x5EED
SPMMADD_SEED_B_XOR = 0xFFFF_0000
SPMMADD_NNZ_PER_ROW = 8


class SplitMix64:
    """Bit-exact port of ``rust/src/rng.rs::Rng`` (SplitMix64 core)."""

    def __init__(self, seed: int):
        self.state = seed & _MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E37_79B9_7F4A_7C15) & _MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & _MASK
        return (z ^ (z >> 31)) & _MASK

    def gen_range(self, n: int) -> int:
        """Uniform in [0, n) — Lemire multiply-shift, as in Rust."""
        assert n > 0
        return (self.next_u64() * n) >> 64

    def range(self, lo: int, hi: int) -> int:
        """Uniform in [lo, hi)."""
        return lo + self.gen_range(hi - lo)


def csr_random(rows: int, cols: int, nnz_per_row: int, seed: int):
    """Port of ``Csr::random``: identical draw order, sort and dedup.

    Returns ``(row_ptr, col_idx, values)`` as Python lists; ``values``
    are exact multiples of 0.25 (f32-representable).
    """
    rng = SplitMix64(seed)
    row_ptr = [0]
    col_idx: list[int] = []
    values: list[float] = []
    for _ in range(rows):
        k = rng.gen_range(2 * nnz_per_row + 1)
        cols_r = sorted(rng.gen_range(cols) for _ in range(k))
        # dedup (consecutive, post-sort — matches Vec::dedup)
        deduped: list[int] = []
        for c in cols_r:
            if not deduped or deduped[-1] != c:
                deduped.append(c)
        for c in deduped:
            col_idx.append(c)
            values.append(rng.range(-8, 8) * 0.25)
        row_ptr.append(len(col_idx))
    return row_ptr, col_idx, values


def csr_to_dense(rows: int, cols: int, row_ptr, col_idx, values) -> np.ndarray:
    """Port of ``Csr::to_dense`` (float32 accumulation)."""
    d = np.zeros(rows * cols, dtype=np.float32)
    for r in range(rows):
        for i in range(row_ptr[r], row_ptr[r + 1]):
            d[r * cols + col_idx[i]] += np.float32(values[i])
    return d.reshape(rows, cols)


def spmmadd_dense_inputs(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Densified canonical A and B for the spmmadd golden: the same CSR
    matrices ``terapool validate`` and rust/tests/golden.rs rebuild from
    the Rust generator."""
    a = csr_random(n, n, SPMMADD_NNZ_PER_ROW, SPMMADD_SEED)
    b = csr_random(n, n, SPMMADD_NNZ_PER_ROW, SPMMADD_SEED ^ SPMMADD_SEED_B_XOR)
    return csr_to_dense(n, n, *a), csr_to_dense(n, n, *b)
