"""Regression tests for ``tools/report_diff.py``.

The drift check must judge the same symmetric relative drift it prints:
historically the table showed ``|new - old| / max(|old|, |new|)`` while
the verdict tested ``|new - old| <= atol + rtol * |old|``, so a pair
could print a drift within ``--rtol`` yet FAIL (and a zero baseline
failed every nonzero measurement no matter what the table said).
"""

import json
import subprocess
import sys
from pathlib import Path

TOOLS = Path(__file__).resolve().parents[2] / "tools"
sys.path.insert(0, str(TOOLS))

from report_diff import drift  # noqa: E402


def test_drift_check_judges_the_printed_number():
    # rel = 10/110 = 9.09% < rtol: the verdict must agree with the
    # printed number. Pre-fix this failed (10 <= 0.095 * 100 is False).
    rel, ok = drift(100.0, 110.0, rtol=0.095, atol=0.0)
    assert abs(rel - 10.0 / 110.0) < 1e-12
    assert ok


def test_drift_is_symmetric():
    assert drift(100.0, 110.0, 0.1, 0.0) == drift(110.0, 100.0, 0.1, 0.0)
    assert drift(100.0, 120.0, 0.1, 0.0)[1] is False
    assert drift(120.0, 100.0, 0.1, 0.0)[1] is False


def test_zero_baseline_uses_symmetric_denominator_and_atol():
    # A zero baseline yields a finite 100% drift, not a guaranteed FAIL
    # with an infinite/NaN denominator story.
    rel, ok = drift(0.0, 4.0, rtol=0.5, atol=0.0)
    assert rel == 1.0 and not ok
    # --atol is what admits genuinely-near-zero noise on a zero baseline.
    assert drift(0.0, 1e-9, rtol=0.0, atol=1e-6)[1]
    assert drift(0.0, 0.0, 0.0, 0.0) == (0.0, True)


def test_missing_fields():
    assert drift(None, 1.0, 1.0, 1.0) == (float("inf"), False)
    assert drift(None, None, 0.0, 0.0) == (0.0, True)


def _doc(cycles, stall_synch, system=None):
    report = {
        "workload": "axpy-n128",
        "config": "tiny",
        "scale": "fast",
        "fingerprint": "f00d",
        "engine_threads": 1,
        "verdict": {"status": "not_checked", "detail": ""},
        "stats": {"cycles": cycles, "stall_synch": stall_synch},
    }
    if system is not None:
        report["system"] = system
    return {"schema": "terapool-runreport-v1", "reports": [report]}


def test_cli_zero_baseline_within_atol_exits_clean(tmp_path):
    old, new = tmp_path / "old.json", tmp_path / "new.json"
    old.write_text(json.dumps(_doc(1000, 0)))
    new.write_text(json.dumps(_doc(1005, 3)))  # stall_synch: zero baseline
    proc = subprocess.run(
        [
            sys.executable,
            str(TOOLS / "report_diff.py"),
            str(old),
            str(new),
            "--rtol",
            "0.01",
            "--atol",
            "5",
        ],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_real_drift_still_fails(tmp_path):
    old, new = tmp_path / "old.json", tmp_path / "new.json"
    old.write_text(json.dumps(_doc(1000, 0)))
    new.write_text(json.dumps(_doc(1500, 0)))
    proc = subprocess.run(
        [
            sys.executable,
            str(TOOLS / "report_diff.py"),
            str(old),
            str(new),
            "--rtol",
            "0.10",
        ],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr


def _run_diff(old_path, new_path, *extra):
    return subprocess.run(
        [sys.executable, str(TOOLS / "report_diff.py"), str(old_path), str(new_path), *extra],
        capture_output=True,
        text=True,
    )


OVERLAP = {"slices": 4, "exposed_bus_cycles": 100, "hidden_bus_cycles": 300}


def test_overlap_counters_are_exact_when_present_in_both(tmp_path):
    # The system.* counters are determinism-pinned: any difference is an
    # EXACT-DRIFT failure even when --rtol would forgive it.
    old, new = tmp_path / "old.json", tmp_path / "new.json"
    drifted = dict(OVERLAP, hidden_bus_cycles=299)
    old.write_text(json.dumps(_doc(1000, 0, system=OVERLAP)))
    new.write_text(json.dumps(_doc(1000, 0, system=drifted)))
    proc = _run_diff(old, new, "--rtol", "0.5")
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "system.hidden_bus_cycles" in proc.stdout
    assert "EXACT-DRIFT" in proc.stdout


def test_overlap_counters_are_skipped_when_absent_on_either_side(tmp_path):
    # Old baselines predate the overlap fields; a new report that carries
    # them must still diff cleanly against such a baseline (and vice
    # versa) — absence is schema age, not drift.
    old, new = tmp_path / "old.json", tmp_path / "new.json"
    old.write_text(json.dumps(_doc(1000, 0)))
    new.write_text(json.dumps(_doc(1000, 0, system=OVERLAP)))
    proc = _run_diff(old, new)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    proc = _run_diff(new, old)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_overlap_counters_matching_in_both_pass(tmp_path):
    old, new = tmp_path / "old.json", tmp_path / "new.json"
    old.write_text(json.dumps(_doc(1000, 0, system=OVERLAP)))
    new.write_text(json.dumps(_doc(1000, 0, system=OVERLAP)))
    proc = _run_diff(old, new)
    assert proc.returncode == 0, proc.stdout + proc.stderr
