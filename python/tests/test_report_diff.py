"""Regression tests for ``tools/report_diff.py``.

The drift check must judge the same symmetric relative drift it prints:
historically the table showed ``|new - old| / max(|old|, |new|)`` while
the verdict tested ``|new - old| <= atol + rtol * |old|``, so a pair
could print a drift within ``--rtol`` yet FAIL (and a zero baseline
failed every nonzero measurement no matter what the table said).
"""

import json
import subprocess
import sys
from pathlib import Path

TOOLS = Path(__file__).resolve().parents[2] / "tools"
sys.path.insert(0, str(TOOLS))

from report_diff import drift  # noqa: E402


def test_drift_check_judges_the_printed_number():
    # rel = 10/110 = 9.09% < rtol: the verdict must agree with the
    # printed number. Pre-fix this failed (10 <= 0.095 * 100 is False).
    rel, ok = drift(100.0, 110.0, rtol=0.095, atol=0.0)
    assert abs(rel - 10.0 / 110.0) < 1e-12
    assert ok


def test_drift_is_symmetric():
    assert drift(100.0, 110.0, 0.1, 0.0) == drift(110.0, 100.0, 0.1, 0.0)
    assert drift(100.0, 120.0, 0.1, 0.0)[1] is False
    assert drift(120.0, 100.0, 0.1, 0.0)[1] is False


def test_zero_baseline_uses_symmetric_denominator_and_atol():
    # A zero baseline yields a finite 100% drift, not a guaranteed FAIL
    # with an infinite/NaN denominator story.
    rel, ok = drift(0.0, 4.0, rtol=0.5, atol=0.0)
    assert rel == 1.0 and not ok
    # --atol is what admits genuinely-near-zero noise on a zero baseline.
    assert drift(0.0, 1e-9, rtol=0.0, atol=1e-6)[1]
    assert drift(0.0, 0.0, 0.0, 0.0) == (0.0, True)


def test_missing_fields():
    assert drift(None, 1.0, 1.0, 1.0) == (float("inf"), False)
    assert drift(None, None, 0.0, 0.0) == (0.0, True)


def _doc(cycles, stall_synch):
    return {
        "schema": "terapool-runreport-v1",
        "reports": [
            {
                "workload": "axpy-n128",
                "config": "tiny",
                "scale": "fast",
                "fingerprint": "f00d",
                "engine_threads": 1,
                "verdict": {"status": "not_checked", "detail": ""},
                "stats": {"cycles": cycles, "stall_synch": stall_synch},
            }
        ],
    }


def test_cli_zero_baseline_within_atol_exits_clean(tmp_path):
    old, new = tmp_path / "old.json", tmp_path / "new.json"
    old.write_text(json.dumps(_doc(1000, 0)))
    new.write_text(json.dumps(_doc(1005, 3)))  # stall_synch: zero baseline
    proc = subprocess.run(
        [
            sys.executable,
            str(TOOLS / "report_diff.py"),
            str(old),
            str(new),
            "--rtol",
            "0.01",
            "--atol",
            "5",
        ],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_real_drift_still_fails(tmp_path):
    old, new = tmp_path / "old.json", tmp_path / "new.json"
    old.write_text(json.dumps(_doc(1000, 0)))
    new.write_text(json.dumps(_doc(1500, 0)))
    proc = subprocess.run(
        [
            sys.executable,
            str(TOOLS / "report_diff.py"),
            str(old),
            str(new),
            "--rtol",
            "0.10",
        ],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
