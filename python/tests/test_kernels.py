"""Kernel-vs-oracle correctness: the CORE build-time signal.

hypothesis sweeps shapes/dtypes/block sizes of every Pallas kernel against
the pure-jnp oracles in compile.kernels.ref.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import axpy as axpy_k
from compile.kernels import fft as fft_k
from compile.kernels import gemm as gemm_k
from compile.kernels import ref

RNG = np.random.default_rng(0xBEEF)


def rand(shape, dtype=np.float32, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------- GEMM ---

@settings(max_examples=24, deadline=None)
@given(
    mi=st.integers(1, 4), ni=st.integers(1, 4), ki=st.integers(1, 4),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
)
def test_gemm_matches_ref(mi, ni, ki, bm, bn, bk):
    m, n, k = mi * bm, ni * bn, ki * bk
    a, b = rand((m, k)), rand((k, n))
    got = gemm_k.gemm(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk)
    want = ref.gemm(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gemm_bf16():
    a = rand((64, 64)).astype(jnp.bfloat16)
    b = rand((64, 64)).astype(jnp.bfloat16)
    got = gemm_k.gemm(jnp.asarray(a), jnp.asarray(b), bm=32, bn=32, bk=32)
    want = ref.gemm(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2)


def test_gemm_rejects_nondividing_blocks():
    a, b = jnp.zeros((33, 32)), jnp.zeros((32, 32))
    with pytest.raises(AssertionError):
        gemm_k.gemm(a, b, bm=32, bn=32, bk=32)


def test_gemm_identity():
    n = 32
    a = rand((n, n))
    eye = np.eye(n, dtype=np.float32)
    got = gemm_k.gemm(jnp.asarray(a), jnp.asarray(eye), bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(got), a, rtol=1e-6, atol=1e-6)


def test_gemm_vmem_model_monotone():
    assert gemm_k.vmem_bytes(128, 128, 256) > gemm_k.vmem_bytes(64, 64, 128)
    # Real-TPU default tile fits the 16 MiB VMEM budget.
    assert gemm_k.vmem_bytes(128, 128, 256) < 16 * 2**20
    assert 0.0 < gemm_k.mxu_utilization_estimate(128, 128, 256) <= 1.0
    assert gemm_k.mxu_utilization_estimate(128, 128, 128) == 1.0


# ------------------------------------------------------------ AXPY/DOTP ---

@settings(max_examples=16, deadline=None)
@given(blocks=st.integers(1, 8), block=st.sampled_from([64, 256, 1024]),
       alpha=st.floats(-4, 4, allow_nan=False, width=32))
def test_axpy_matches_ref(blocks, block, alpha):
    n = blocks * block
    x, y = rand(n), rand(n)
    got = axpy_k.axpy(jnp.float32(alpha), jnp.asarray(x), jnp.asarray(y),
                      block=block)
    want = ref.axpy(jnp.float32(alpha), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=16, deadline=None)
@given(blocks=st.integers(1, 8), block=st.sampled_from([64, 256, 1024]))
def test_dotp_matches_ref(blocks, block):
    n = blocks * block
    x, y = rand(n), rand(n)
    got = axpy_k.dotp(jnp.asarray(x), jnp.asarray(y), block=block)
    want = ref.dotp(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-4)


def test_dotp_zero():
    x = jnp.zeros((1024,), jnp.float32)
    assert float(axpy_k.dotp(x, x, block=256)) == 0.0


# ------------------------------------------------------------------ FFT ---

@settings(max_examples=10, deadline=None)
@given(batch=st.integers(1, 4), m=st.integers(1, 5))
def test_fft_matches_ref(batch, m):
    n = 4 ** m
    xr, xi = rand((batch, n)), rand((batch, n))
    gr, gi = fft_k.fft(jnp.asarray(xr), jnp.asarray(xi))
    wr, wi = ref.fft(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                               rtol=1e-3, atol=1e-3 * np.sqrt(n))
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi),
                               rtol=1e-3, atol=1e-3 * np.sqrt(n))


def test_fft_paper_shape():
    """The paper's workload: 4096-point FFTs (shrunk batch for test time)."""
    xr, xi = rand((2, 4096)), rand((2, 4096))
    gr, gi = fft_k.fft(jnp.asarray(xr), jnp.asarray(xi))
    wr, wi = ref.fft(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                               rtol=1e-3, atol=0.2)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi),
                               rtol=1e-3, atol=0.2)


def test_fft_impulse():
    """FFT of a unit impulse is all-ones (exact)."""
    n = 64
    xr = np.zeros((1, n), np.float32)
    xr[0, 0] = 1.0
    xi = np.zeros((1, n), np.float32)
    gr, gi = fft_k.fft(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_allclose(np.asarray(gr), np.ones((1, n)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gi), np.zeros((1, n)), atol=1e-5)


def test_fft_rejects_non_power_of_4():
    with pytest.raises(AssertionError):
        fft_k.digit_reverse_indices(8)


def test_digit_reverse_is_involution():
    for n in (4, 16, 64, 256, 4096):
        rev = fft_k.digit_reverse_indices(n)
        assert (rev[rev] == np.arange(n)).all()
