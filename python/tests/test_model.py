"""L2 model + AOT pipeline tests: every entry traces, lowers to HLO text,
and the text contains a parseable ENTRY module (the exact interchange the
Rust runtime consumes)."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("name", list(model.ENTRIES))
def test_entry_traces_and_shapes(name):
    fn, example_args = model.ENTRIES[name]
    out = jax.eval_shape(lambda *a: fn(*a), *example_args)
    assert isinstance(out, tuple) and len(out) >= 1
    for o in out:
        assert o.dtype == jnp.float32


@pytest.mark.parametrize("name", ["axpy", "dotp", "spmmadd"])
def test_lower_small_entries_to_hlo_text(name):
    text = aot.lower_entry(name)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text


def test_gemm_entry_numerics_small_proxy():
    """gemm_entry semantics on a shrunk shape (full 256^3 is covered by the
    artifact-level Rust integration test)."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    (got,) = model.gemm_entry(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)


def test_fft_entry_numerics_small_proxy():
    rng = np.random.default_rng(8)
    xr = rng.standard_normal((4, 64)).astype(np.float32)
    xi = rng.standard_normal((4, 64)).astype(np.float32)
    gr, gi = model.fft_entry(jnp.asarray(xr), jnp.asarray(xi))
    wr, wi = ref.fft(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi), atol=1e-3)


def test_manifest_roundtrip(tmp_path):
    """aot.main writes artifact + manifest consistent with ENTRIES."""
    import sys
    from unittest import mock

    argv = ["aot", "--out-dir", str(tmp_path), "--only", "axpy"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert "axpy" in manifest
    entry = manifest["axpy"]
    assert (tmp_path / entry["file"]).exists()
    assert entry["inputs"][1]["shape"] == [model.AXPY_N]
    text = (tmp_path / entry["file"]).read_text()
    assert text.startswith("HloModule")
