"""Cross-language pin of the SplitMix64 generator and the CSR workload
generator (python/compile/rng.py vs rust/src/rng.rs +
rust/src/kernels/spmmadd.rs).

The 64 constants below are identical to
`rust/src/rng.rs::tests::first_64_draws_pinned_cross_language`; the CSR
invariant tests mirror what `Csr::random` guarantees structurally. If the
port drifts from the Rust generator in any way, the spmmadd golden would
silently describe a *different* matrix pair — these pins make that loud
on both sides.
"""

import numpy as np

from compile.rng import (
    SPMMADD_NNZ_PER_ROW,
    SPMMADD_SEED,
    SPMMADD_SEED_B_XOR,
    SplitMix64,
    csr_random,
    csr_to_dense,
    spmmadd_dense_inputs,
)

# Keep in sync with rust/src/rng.rs (same seed, same order).
PINNED_SEED = 0x5EED
PINNED_DRAWS = [
    0x09F1FD9D03F0A9B4, 0x553274161BBF8475, 0x5D5BCA4696B343B3, 0x70D29B6C7D22528D,
    0x0BF2B716F9915475, 0x5EB7F92B95387CCA, 0x296CD0F2C21D7F90, 0x1289A69805C125B1,
    0xDAA27FB8DACB9E73, 0x3ED08D59CB3F4727, 0x58A5F17B6C15C659, 0x651AC042FA7B481A,
    0x22AF6AEAA88E8DCC, 0x2D2BAE64640ABFB9, 0xAD0E83A710231B07, 0x9D30FF2169D91F12,
    0xF5FF07C9523504DD, 0x1273C823BA66EEC0, 0x47E1DBE249CB520B, 0xBBEA42BD69484ADC,
    0xC33E61BC6EF9E4C4, 0x752CD583231B5114, 0xE53DC6E1988622E5, 0x928EB721ED361BA3,
    0x10BF7972F379031E, 0x974041D15AD75C38, 0xFF9B273F42286387, 0x2601349FEF087EB0,
    0x5753F8EF429A4A7E, 0x2663E5E9DCBCBABA, 0xA8BB872E52C6235C, 0xE1774D56B0DC91AC,
    0x8634930F702B6452, 0x1674658F30892DDD, 0x2F957488E4FD469E, 0x656ED1CB9A126362,
    0x5325662609163089, 0x3BA278A39643A1BC, 0x0EFA3DDA544646D9, 0x4CC8C74C1FB520CC,
    0x626C1EF331F85C18, 0x01457B862CC7B3C9, 0x3825403DF6F9AD71, 0x272C78C413C9D42D,
    0x4DDE6838B289C9CE, 0x1467A1289E64EB89, 0x00EB8B8A36B5B98D, 0xF2443B542BF81344,
    0x278641CAD03AD4BE, 0x5A71CD3D503FAEEE, 0x2C58DAA06446969A, 0x79559FF0F9D26976,
    0x4A127FE7AAC0FFFD, 0xBCA4883827803ECC, 0xB60627C1559D3728, 0x0D1D73CE3F48B12D,
    0x78E74B9EB7B50E87, 0xEB26C664BA822E65, 0xEF794A8DCA9DCB0A, 0x89119CBF1EE9784B,
    0x180B37DFF135DE45, 0xBE1B67D3E6055F33, 0x6FBE6FBA62CE02C8, 0x1FBF7B87B4F36BC8,
]


def test_first_64_draws_match_rust_pin():
    rng = SplitMix64(PINNED_SEED)
    draws = [rng.next_u64() for _ in range(64)]
    assert draws == PINNED_DRAWS


def test_gen_range_bounds_and_determinism():
    a, b = SplitMix64(7), SplitMix64(7)
    for _ in range(1000):
        x, y = a.gen_range(13), b.gen_range(13)
        assert x == y and 0 <= x < 13
    assert SplitMix64(9).range(-8, 8) in range(-8, 8)


def test_csr_structure_matches_rust_invariants():
    row_ptr, col_idx, values = csr_random(64, 64, 4, 1)
    assert row_ptr[0] == 0 and row_ptr[-1] == len(col_idx) == len(values)
    for r in range(64):
        cols_r = col_idx[row_ptr[r] : row_ptr[r + 1]]
        # sorted + deduped, within range, ≤ 2*nnz_per_row entries
        assert cols_r == sorted(set(cols_r))
        assert all(0 <= c < 64 for c in cols_r)
        assert len(cols_r) <= 8
    # values are exact multiples of 0.25 in [-2, 2) (f32-representable)
    assert all(v * 4 == int(v * 4) and -2.0 <= v < 2.0 for v in values)


def test_densified_inputs_are_deterministic_and_sparse():
    a1, b1 = spmmadd_dense_inputs(64)
    a2, b2 = spmmadd_dense_inputs(64)
    assert np.array_equal(a1, a2) and np.array_equal(b1, b2)
    assert a1.dtype == np.float32 and a1.shape == (64, 64)
    assert not np.array_equal(a1, b1), "A and B use different seeds"
    # ~nnz_per_row entries per row on average, far below dense
    assert 0 < np.count_nonzero(a1) < 64 * 64 // 2


def test_dense_roundtrip_small_case():
    row_ptr, col_idx, values = [0, 2, 3], [1, 3, 0], [0.25, -0.5, 1.75]
    d = csr_to_dense(2, 4, row_ptr, col_idx, values)
    want = np.array([[0, 0.25, 0, -0.5], [1.75, 0, 0, 0]], dtype=np.float32)
    assert np.array_equal(d, want)


def test_canonical_seed_constants():
    # The golden pipeline and the Rust tests agree on the workload.
    assert (SPMMADD_SEED, SPMMADD_NNZ_PER_ROW) == (0x5EED, 8)
    assert SPMMADD_SEED ^ SPMMADD_SEED_B_XOR == 0x5EED ^ 0xFFFF_0000
