//! Spectral-processing scenario (the paper's SDR motivation): run the
//! 64×4096-point radix-4 FFT batch on the simulated cluster, validate
//! against the build-time JAX-evaluated golden, and report per-stage
//! behaviour.
//!
//! ```bash
//! make artifacts && cargo run --release --example fft_spectral [--fast]
//! ```

use terapool::config::ClusterConfig;
use terapool::ensure;
use terapool::errors::Result;
use terapool::kernels::fft::{build, im_plane_offset, FftParams};
use terapool::runtime::{max_abs_diff, Runtime};

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let cfg = ClusterConfig::terapool(9);
    let p = if fast {
        FftParams { batch: 16, n: 1024 }
    } else {
        FftParams { batch: 64, n: 4096 } // the artifact's shape
    };
    println!(
        "fft: {} transforms × {} points on {} PEs (radix-4 DIF, {} stages)",
        p.batch,
        p.n,
        cfg.num_pes(),
        (p.n as f64).log(4.0) as usize
    );

    let setup = build(&cfg, &p);
    let im_off = im_plane_offset(&cfg, &p);
    let (mut cl, io) = setup.into_cluster(cfg.clone());
    let stats = cl.run(2_000_000_000);
    let got_re = io.read_output(&cl)?;
    let got_im = cl.l1.read_slice(io.output_base + im_off, p.batch * p.n);

    println!(
        "perf: {} cycles — IPC/PE {:.2}, AMAT {:.2}, {:.1} GFLOP/s; \
         NUMA mix local/SG/G/RG = {:.0}%/{:.0}%/{:.0}%/{:.0}%",
        stats.cycles,
        stats.ipc(),
        stats.amat,
        stats.gflops(),
        100.0 * stats.reqs_per_class[0] as f64 / stats.loads.max(1) as f64,
        100.0 * stats.reqs_per_class[1] as f64 / stats.loads.max(1) as f64,
        100.0 * stats.reqs_per_class[2] as f64 / stats.loads.max(1) as f64,
        100.0 * stats.reqs_per_class[3] as f64 / stats.loads.max(1) as f64,
    );

    if !fast {
        // Golden comparison against the JAX-evaluated artifact (64×4096,
        // stored re-plane then im-plane).
        let rt = Runtime::with_default_dir()?;
        println!("golden: loading fft.golden.bin…");
        let golden = rt.golden_f32("fft")?;
        let plane = p.batch * p.n;
        let dre = max_abs_diff(&got_re, &golden[..plane]);
        let dim = max_abs_diff(&got_im, &golden[plane..]);
        println!("numerics: max |Δre| = {dre:.2e}, max |Δim| = {dim:.2e}");
        // 4096-point f32 FFT: values reach O(10³); allow 4096·ε-ish.
        ensure!(dre < 0.25 && dim < 0.25, "spectral mismatch vs the JAX golden");
        println!("fft_spectral OK — cluster spectrum matches the JAX golden");
    } else {
        println!("fft_spectral OK (fast mode: golden check skipped — artifact is 64×4096)");
    }
    Ok(())
}
