//! HBML scenario: sweep cluster frequency × HBM2E DDR rate and print the
//! Fig. 9 bandwidth/utilization surface, then show the effect of the
//! paper's burst-alignment choices (ablation: backends per SubGroup and
//! burst length are fixed by the hybrid map — here we vary the transfer
//! size to expose startup/drain amortization).
//!
//! ```bash
//! cargo run --release --example hbm_sweep
//! ```

use terapool::config::DdrRate;
use terapool::coordinator::{fig9, hbml_sweep_point, Scale};

fn main() {
    // The Fig. 9 table itself.
    fig9(Scale::Full).print();

    // Transfer-size amortization: the DMA frontend config cycles and the
    // channel drain tail only vanish for multi-MiB transfers.
    println!("\n== Transfer-size amortization @ 900 MHz / 3.6 Gbit/s/pin ==");
    println!("{:>12}  {:>14}  {:>11}", "KiB moved", "achieved GB/s", "utilization");
    for words in [16 * 1024u32, 64 * 1024, 256 * 1024, 896 * 1024] {
        let (gbps, util) = hbml_sweep_point(900.0, DdrRate::G3_6, words);
        println!(
            "{:>12}  {:>14.1}  {:>10.1}%",
            words / 256,
            gbps,
            100.0 * util
        );
    }
}
