//! End-to-end driver (DESIGN.md §Golden contract): the full three-layer
//! stack on a real workload.
//!
//! 1. loads the **JAX-evaluated golden** `gemm.golden.bin` (built once by
//!    `make artifacts`; Python is not involved at run time);
//! 2. runs the same 256×256×256 f32 GEMM on the **simulated 1024-PE
//!    TeraPool cluster** — 4×4 register-blocked traces, shared-L1
//!    interconnect, fork-join barriers — on the deterministic
//!    tile-parallel engine;
//! 3. runs the **double-buffered HBM2E variant** (tiles streamed through
//!    the iDMA) to show compute/transfer overlap;
//! 4. compares the cluster's final memory image against the JAX output
//!    (assert_allclose) and reports cycles, IPC, GFLOP/s and GFLOP/s/W.
//!
//! ```bash
//! make artifacts && cargo run --release --example gemm_e2e
//! ```

use terapool::config::ClusterConfig;
use terapool::dma::hbm_image_clear;
use terapool::errors::Result;
use terapool::kernels::double_buffer::{self, DbKernel, DbParams};
use terapool::kernels::gemm::{build, GemmParams};
use terapool::physical::energy::EnergyModel;
use terapool::runtime::{assert_allclose, Runtime};

fn main() -> Result<()> {
    let cfg = ClusterConfig::terapool(9);
    let em = EnergyModel::for_cluster(&cfg);
    let threads = terapool::parallel::default_threads();

    // --- golden: JAX oracle evaluated at build time -------------------
    let rt = Runtime::with_default_dir()?;
    let shape = rt.entry("gemm")?.inputs[0].shape.clone();
    let p = GemmParams { m: shape[0], n: shape[1], k: shape[0] };
    println!("golden: loading gemm.golden.bin ({}x{}x{})…", p.m, p.n, p.k);
    let golden = rt.golden_f32("gemm")?;

    // --- cluster: trace-driven 1024-PE simulation ---------------------
    println!(
        "cluster: running 4x4-blocked GEMM on {} PEs ({threads} host threads)…",
        cfg.num_pes()
    );
    let setup = build(&cfg, &p);
    let flops = setup.flops;
    let (mut cl, io) = setup.into_cluster(cfg.clone());
    let stats = cl.run_parallel(2_000_000_000, threads);

    assert_allclose(&io.read_output(&cl)?, &golden, 2e-2, "gemm vs JAX golden");
    println!("numerics: cluster L1 image matches the JAX golden ✓");

    let us = stats.cycles as f64 / cfg.freq_mhz;
    println!(
        "perf: {} cycles ({:.0} µs @ {} MHz) — IPC/PE {:.2}, {:.0} GFLOP/s \
         ({:.1}% of peak), {:.0} GFLOP/s/W, AMAT {:.2}",
        stats.cycles,
        us,
        cfg.freq_mhz,
        stats.ipc(),
        stats.gflops(),
        100.0 * stats.gflops() / cfg.peak_gflops_f32(),
        em.gflops_per_watt(&stats),
        stats.amat,
    );
    let _ = flops;

    // --- HBM2E double-buffered variant ---------------------------------
    println!("hbml: double-buffered GEMM panels through 16×HBM2E…");
    hbm_image_clear();
    let db = double_buffer::run(
        &cfg,
        &DbParams { kernel: DbKernel::Gemm, chunk: 32 * 4096, rounds: 6 },
    );
    println!(
        "hbml: {} cycles, compute fraction {:.0}% (transfers hidden), {:.1} MB moved",
        db.cycles,
        100.0 * db.compute_fraction,
        db.bytes_transferred as f64 / 1e6
    );

    println!("\ngemm_e2e OK — all three layers compose");
    Ok(())
}
