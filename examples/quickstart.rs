//! Quickstart: build a TeraPool cluster, run an AXPY across all 1024 PEs,
//! and check the result against the host reference.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use terapool::cluster::Cluster;
use terapool::config::ClusterConfig;
use terapool::isa::Program;
use terapool::kernels::axpy::{build, reference, AxpyParams};

fn main() {
    // 1. Pick an operating point: TeraPool-1-3-5-9 runs at 850 MHz, the
    //    paper's energy-optimal configuration.
    let cfg = ClusterConfig::terapool(9);
    println!(
        "cluster: {} — {} PEs, {} banks, {:.1} MiB L1, {} MHz",
        cfg.name,
        cfg.num_pes(),
        cfg.num_banks(),
        cfg.l1_bytes() as f64 / (1024.0 * 1024.0),
        cfg.freq_mhz
    );

    // 2. Build a kernel: AXPY over 256 Ki elements, local-access layout.
    let params = AxpyParams { n: 256 * 1024, alpha: 2.0 };
    let setup = build(&cfg, &params);
    let want = reference(&params);

    // 3. Stage the data into the simulated L1 and run to completion.
    let (mut cluster, io) = setup.into_cluster(cfg);
    let stats = cluster.run(100_000_000);

    // 4. Inspect the result and the performance counters.
    let got = io.read_output(&cluster);
    assert_eq!(got, want, "cluster result must match the host reference");
    println!(
        "axpy OK: {} elements in {} cycles — IPC/PE {:.2}, {:.1} GFLOP/s, AMAT {:.2} cyc",
        params.n,
        stats.cycles,
        stats.ipc(),
        stats.gflops(),
        stats.amat,
    );

    // 5. Programs are plain instruction traces — write your own:
    let cfg = ClusterConfig::tiny();
    let progs: Vec<Program> = (0..cfg.num_pes())
        .map(|i| {
            let mut p = Program::new();
            p.ld_imm(1, i as f32);
            p.fmac(2, 1, 1); // r2 += i*i
            p.halt();
            p
        })
        .collect();
    let mut tiny = Cluster::new(cfg, progs);
    tiny.run(1000);
    println!(
        "custom trace OK: PE 5 computed 5² = {}",
        tiny.pes[5].reg(2)
    );
}
