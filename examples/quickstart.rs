//! Quickstart: the Workload/Session API in four steps — run a registered
//! kernel, pin a custom problem size, batch a sweep across host threads,
//! and drop down to raw instruction traces.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use terapool::cluster::Cluster;
use terapool::config::{ClusterConfig, Scale};
use terapool::errors::Result;
use terapool::isa::Program;
use terapool::kernels::axpy::{Axpy, AxpyParams};
use terapool::report::Verdict;
use terapool::session::{Job, Session};

fn main() -> Result<()> {
    // 1. Pick an operating point and build a Session — the single run
    //    path. TeraPool-1-3-5-9 runs at 850 MHz, the paper's
    //    energy-optimal configuration. `check(true)` compares every run
    //    against its host reference and records the verdict.
    let cfg = ClusterConfig::terapool(9);
    println!(
        "cluster: {} — {} PEs, {} banks, {:.1} MiB L1, {} MHz",
        cfg.name,
        cfg.num_pes(),
        cfg.num_banks(),
        cfg.l1_bytes() as f64 / (1024.0 * 1024.0),
        cfg.freq_mhz
    );
    let session = Session::new(cfg.clone()).scale(Scale::Fast).check(true);

    // 2. Run a kernel by registry name. The report carries the config
    //    fingerprint, full RunStats and the validation verdict — and is
    //    JSON-serializable (`terapool <exp> --json out.json`).
    let r = session.run_named("axpy")?;
    println!(
        "{}: {} in {} cycles — IPC/PE {:.2}, {:.1} GFLOP/s, AMAT {:.2} cyc [{}]",
        r.kind,
        r.workload,
        r.stats.cycles,
        r.stats.ipc(),
        r.stats.gflops(),
        r.stats.amat,
        r.verdict.status(),
    );
    assert!(matches!(r.verdict, Verdict::Passed { .. }));

    // 3. Pin explicit parameters, or fan a batch of workload×config
    //    jobs out across host threads — results are bit-identical to
    //    running them sequentially, in job order. Config knobs ride on
    //    the per-job ClusterConfig: `with_burst(true)` turns on TCDM
    //    burst access (multi-word loads/stores, one port grant per run
    //    of consecutive banks — `--burst` on the CLI).
    let batch = Session::new(cfg.clone()).scale(Scale::Fast).threads(4);
    let jobs = vec![
        Job::new(cfg.clone(), Box::new(Axpy::with(AxpyParams { n: cfg.num_banks() * 8, alpha: 0.5 }))),
        Job::new(cfg.clone().with_burst(true), Box::new(Axpy::default())),
        Job::new(ClusterConfig::mempool(), Box::new(Axpy::default())),
        Job::new(ClusterConfig::occamy(), Box::new(Axpy::default())),
    ];
    for r in batch.run_batch(&jobs) {
        let r = r?;
        println!(
            "batch: {:24} on {:16} IPC {:.2} ({} cycles)",
            r.workload, r.config, r.stats.ipc(), r.stats.cycles
        );
    }

    // 4. Programs are plain instruction traces — write your own and
    //    drive the cluster directly when the Workload API is too coarse:
    let cfg = ClusterConfig::tiny();
    let progs: Vec<Program> = (0..cfg.num_pes())
        .map(|i| {
            let mut p = Program::new();
            p.ld_imm(1, i as f32);
            p.fmac(2, 1, 1); // r2 += i*i
            p.halt();
            p
        })
        .collect();
    let mut tiny = Cluster::new(cfg, progs);
    tiny.run(1000);
    println!("custom trace OK: PE 5 computed 5² = {}", tiny.pes[5].reg(2));
    Ok(())
}
