#!/usr/bin/env python3
"""Sweep frontier drift gate (ISSUE 9 CI leg): hold every Pareto-frontier
point of a ``terapool-sweepreport-v1`` document to its stated rtol by
re-deriving the estimated-vs-measured comparison from the embedded
``RunReport`` pairs with ``report_diff``'s field semantics (exact
census-backed counters, tolerant timing fields) — and cross-check the
document's own in-process drift verdicts against that independent
rederivation, so a bug in either implementation fails loudly.

The gate also enforces the sweep-service shape contract:

* the grid explored at least ``--min-points`` points;
* only frontier points carry cycle-accurate measurements (the refine
  phase must not have re-run dominated points);
* every estimated report carries ``EstimateInfo`` provenance.

Usage:
    python3 tools/sweep_gate.py fig_sweep.json
    python3 tools/sweep_gate.py fig_sweep.json --min-points 24

Exit codes: 0 all frontier points within rtol, 1 drift/shape violation,
2 usage/IO error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
from report_diff import EXACT_FIELDS, TOLERANT_FIELDS, drift, lookup  # noqa: E402

SCHEMA = "terapool-sweepreport-v1"


def point_failures(point: dict, rtol: float) -> list[str]:
    """Re-derive the drift comparison for one measured frontier point."""
    est, meas = point["estimated"], point["measured"]
    rows = []
    for field in EXACT_FIELDS:
        rel, ok = drift(lookup(meas, field), lookup(est, field), 0.0, 0.0)
        if not ok:
            rows.append(f"{field}: {lookup(meas, field)} -> {lookup(est, field)} EXACT-DRIFT")
    for field in TOLERANT_FIELDS:
        rel, ok = drift(lookup(meas, field), lookup(est, field), rtol, 0.0)
        if not ok:
            rows.append(f"{field}: {lookup(meas, field)} -> {lookup(est, field)} "
                        f"({rel:.2%} rel, rtol {rtol})")
    if est.get("fingerprint") != meas.get("fingerprint"):
        rows.append(f"fingerprint: {meas.get('fingerprint')} -> {est.get('fingerprint')}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="terapool-sweepreport-v1 document (fig_sweep.json)")
    ap.add_argument("--min-points", type=int, default=24,
                    help="minimum explored grid size (default: %(default)s)")
    args = ap.parse_args()

    try:
        doc = json.loads(Path(args.report).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"sweep-gate: {e}")
        return 2
    if doc.get("schema") != SCHEMA:
        print(f"sweep-gate: schema {doc.get('schema')!r}, want {SCHEMA!r}")
        return 2

    rtol = float(doc["rtol"])
    points = doc["points"]
    explored = [p for p in points if p.get("estimated")]
    failed = [p for p in points if p.get("error")]
    frontier = [p for p in points if p.get("frontier")]
    measured = [p for p in points if p.get("measured")]
    print(f"sweep-gate: {doc['name']}: {len(points)} points "
          f"({len(explored)} explored, {len(failed)} failed, "
          f"{len(frontier)} on the frontier, {len(measured)} measured), rtol {rtol}")

    failures = 0
    if len(points) < args.min_points:
        print(f"sweep-gate: FAIL: grid has {len(points)} points, want >= {args.min_points}")
        failures += 1
    for p in points:
        if p.get("measured") and not p.get("frontier"):
            print(f"sweep-gate: FAIL: {p['key']}: dominated point was re-run cycle-accurately")
            failures += 1
        if p.get("estimated") and not lookup(p["estimated"], "estimate"):
            print(f"sweep-gate: FAIL: {p['key']}: estimated report lacks EstimateInfo")
            failures += 1

    for p in frontier:
        if not p.get("measured"):
            # A frontier point may legitimately lack a measurement only
            # when its re-run failed — and then the error is on record.
            if not p.get("error"):
                print(f"sweep-gate: FAIL: {p['key']}: frontier point never measured")
                failures += 1
            else:
                print(f"sweep-gate: note: {p['key']}: re-run failed "
                      f"({p['error']['kind']}): {p['error']['message']}")
            continue
        rows = point_failures(p, rtol)
        verdict = p.get("drift") or {}
        if rows:
            failures += 1
            print(f"sweep-gate: FAIL: {p['key']}: {len(rows)} drifting field(s)")
            for row in rows:
                print(f"    {row}")
        if bool(verdict.get("pass")) != (not rows):
            failures += 1
            print(f"sweep-gate: FAIL: {p['key']}: in-process verdict "
                  f"(pass={verdict.get('pass')}) disagrees with the rederivation "
                  f"({len(rows)} failure(s))")

    if failures:
        print(f"\nsweep-gate: FAIL — {failures} violation(s)")
        return 1
    print(f"\nsweep-gate: OK — {len(frontier)} frontier point(s) within rtol {rtol}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
