#!/usr/bin/env python3
"""Diff two ``terapool-runreport-v1`` documents field by field (ROADMAP
"RunReport diff tool"): pair up reports, compare every numeric stat with
per-field tolerances, and print a drift table — the paper-vs-measured
tracking loop for `--json` dumps across PRs, configs or machines.

Reports are paired on ``(workload, config, scale)`` by default; pass
``--key`` to override (comma-separated field names, e.g.
``--key kind,config``). Counters that determinism pins exactly
(instructions, loads, stores, atomics, flops, num_pes, reqs_per_class)
default to zero tolerance; timing-derived fields (cycles, stalls, AMAT,
ipc, gflops) default to ``--rtol`` (relative). A missing counterpart is
reported and — unless ``--ignore-unmatched`` — fails the diff.

Usage:
    python3 tools/report_diff.py old.json new.json
    python3 tools/report_diff.py a.json b.json --rtol 0.02
    python3 tools/report_diff.py a.json b.json --key kind --ignore-unmatched

Exit codes: 0 no drift beyond tolerance, 1 drift/unmatched, 2 usage/IO.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

SCHEMA = "terapool-runreport-v1"

# Fields pinned bit-exactly by the deterministic engines: any difference
# is a real behavioral change, not noise.
EXACT_FIELDS = [
    "stats.instructions",
    "stats.flops",
    "stats.num_pes",
    "stats.loads",
    "stats.stores",
    "stats.atomics",
    "stats.reqs_per_class[0]",
    "stats.reqs_per_class[1]",
    "stats.reqs_per_class[2]",
    "stats.reqs_per_class[3]",
    "stats.burst_reqs_per_class[0]",
    "stats.burst_reqs_per_class[1]",
    "stats.burst_reqs_per_class[2]",
    "stats.burst_reqs_per_class[3]",
    "stats.burst_words_per_class[0]",
    "stats.burst_words_per_class[1]",
    "stats.burst_words_per_class[2]",
    "stats.burst_words_per_class[3]",
]

# Optional exact fields: present only on reports that carry the
# matching sub-document (e.g. ``system.*`` overlap counters from
# `terapool system`). Compared bit-exactly when BOTH sides have them,
# silently skipped when either side predates the field — old baselines
# must keep diffing cleanly against new reports.
OPTIONAL_EXACT_FIELDS = [
    "system.slices",
    "system.exposed_bus_cycles",
    "system.hidden_bus_cycles",
    "system.bus_words",
    "system.bus_busy_cycles",
]

# Timing-derived fields: tolerate --rtol relative drift (config changes,
# model recalibrations, paper-vs-measured comparisons).
TOLERANT_FIELDS = [
    "stats.cycles",
    "stats.stall_raw",
    "stats.stall_lsu",
    "stats.stall_ctrl",
    "stats.stall_synch",
    "stats.amat",
    "stats.amat_per_class[0]",
    "stats.amat_per_class[1]",
    "stats.amat_per_class[2]",
    "stats.amat_per_class[3]",
    "stats.ipc",
    "stats.gflops",
    "dma_bytes",
]


def load_reports(path: Path) -> list[dict]:
    doc = json.loads(path.read_text())
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"{path}: schema {doc.get('schema')!r}, want {SCHEMA!r}")
    return doc["reports"]


def lookup(report: dict, field: str):
    """Resolve a dotted/indexed path like ``stats.amat_per_class[2]``."""
    cur = report
    for part in field.split("."):
        idx = None
        if part.endswith("]"):
            part, bracket = part[:-1].split("[")
            idx = int(bracket)
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
        if idx is not None:
            if not isinstance(cur, list) or idx >= len(cur):
                return None
            cur = cur[idx]
    return cur


def key_of(report: dict, key_fields: list[str]) -> tuple:
    return tuple(str(lookup(report, f)) for f in key_fields)


def drift(old, new, rtol: float, atol: float) -> tuple[float, bool]:
    """(relative drift, within_tolerance) for a field pair.

    Both the reported drift and the pass/fail check use the symmetric
    denominator ``max(|old|, |new|)``: the check must judge exactly the
    number it prints, and a zero (or near-zero) baseline must not turn
    every nonzero measurement into an automatic failure while the table
    claims a finite drift (that combination previously made the printed
    drift and the verdict disagree).
    """
    if old is None and new is None:
        return 0.0, True
    if old is None or new is None:
        return float("inf"), False
    old, new = float(old), float(new)
    if old == new:
        return 0.0, True
    denom = max(abs(old), abs(new))
    rel = abs(new - old) / denom if denom > 0 else float("inf")
    return rel, abs(new - old) <= atol + rtol * denom


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline terapool-runreport-v1 document")
    ap.add_argument("new", help="fresh terapool-runreport-v1 document")
    ap.add_argument("--key", default="workload,config,scale",
                    help="comma-separated pairing fields (default: %(default)s)")
    ap.add_argument("--rtol", type=float, default=0.0,
                    help="relative tolerance for timing-derived fields (default: exact)")
    ap.add_argument("--atol", type=float, default=0.0,
                    help="absolute tolerance added on top of --rtol (default: %(default)s)")
    ap.add_argument("--ignore-unmatched", action="store_true",
                    help="unpaired reports are notes, not failures")
    args = ap.parse_args()

    try:
        old_reports = load_reports(Path(args.old))
        new_reports = load_reports(Path(args.new))
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        print(f"report-diff: {e}")
        return 2

    key_fields = [f.strip() for f in args.key.split(",") if f.strip()]
    old_by_key: dict[tuple, dict] = {}
    for r in old_reports:
        k = key_of(r, key_fields)
        if k in old_by_key:
            print(f"report-diff: note: duplicate key {k} in {args.old}; keeping the last")
        old_by_key[k] = r
    new_by_key: dict[tuple, dict] = {}
    for r in new_reports:
        k = key_of(r, key_fields)
        if k in new_by_key:
            print(f"report-diff: note: duplicate key {k} in {args.new}; keeping the last")
        new_by_key[k] = r

    failures = 0
    compared = 0
    for k in sorted(old_by_key):
        if k not in new_by_key:
            print(f"report-diff: {'note' if args.ignore_unmatched else 'FAIL'}: "
                  f"{k} only in {args.old}")
            failures += 0 if args.ignore_unmatched else 1
            continue
        old_r, new_r = old_by_key[k], new_by_key[k]
        compared += 1
        rows = []
        for field in EXACT_FIELDS:
            rel, ok = drift(lookup(old_r, field), lookup(new_r, field), 0.0, 0.0)
            if not ok:
                rows.append((field, rel, "EXACT-DRIFT"))
        for field in OPTIONAL_EXACT_FIELDS:
            a, b = lookup(old_r, field), lookup(new_r, field)
            if a is None or b is None:
                continue  # field absent on one side: older schema, not drift
            rel, ok = drift(a, b, 0.0, 0.0)
            if not ok:
                rows.append((field, rel, "EXACT-DRIFT"))
        for field in TOLERANT_FIELDS:
            rel, ok = drift(lookup(old_r, field), lookup(new_r, field), args.rtol, args.atol)
            if not ok:
                rows.append((field, rel, "DRIFT"))
        # Identity fields that should rarely change silently.
        for field in ("fingerprint", "engine_threads", "verdict.status"):
            a, b = lookup(old_r, field), lookup(new_r, field)
            if a != b:
                rows.append((field, float("nan"), f"{a!r} -> {b!r}"))
        label = " / ".join(k)
        if rows:
            failures += 1
            print(f"  {label}: {len(rows)} drifting field(s)")
            for field, rel, status in rows:
                a, b = lookup(old_r, field), lookup(new_r, field)
                extra = "" if rel != rel else f"  ({rel:+.2%} rel)".replace("+", "")
                print(f"    {field:<28} {a} -> {b}{extra}  {status}")
        else:
            print(f"  {label}: ok")
    for k in sorted(set(new_by_key) - set(old_by_key)):
        print(f"report-diff: {'note' if args.ignore_unmatched else 'FAIL'}: "
              f"{k} only in {args.new} (new coverage)")
        failures += 0 if args.ignore_unmatched else 1

    if compared == 0:
        print("report-diff: no comparable reports — check --key")
        return 1
    if failures:
        print(f"\nreport-diff: FAIL — {failures} report pair(s) drifted "
              f"(rtol {args.rtol}, atol {args.atol})")
        return 1
    print(f"\nreport-diff: OK — {compared} report pair(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
