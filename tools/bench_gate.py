#!/usr/bin/env python3
"""Bench regression gate (ROADMAP open item): compare a freshly written
``BENCH_simspeed.json`` against the committed baseline and fail when the
simulator got more than ``--max-regress`` slower on any matched row.

Rows are matched on ``(bench, engine)`` and compared on
``mcycles_per_s`` (simulated PE-Mcycles per host second — higher is
better). The gate is *advisory* in CI (hosted-runner numbers are noisy;
the step uses continue-on-error), but locally ``make bench-check`` makes
a perf regression impossible to miss.

Usage:
    python3 tools/bench_gate.py                       # HEAD vs ./BENCH_simspeed.json
    python3 tools/bench_gate.py --baseline old.json --fresh new.json
    python3 tools/bench_gate.py --max-regress 0.10    # stricter gate

Exit codes: 0 ok / nothing to compare, 1 regression, 2 usage/IO error.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

SCHEMA = "terapool-simspeed-v1"


def load_rows(text: str, origin: str) -> dict[tuple[str, str], dict]:
    doc = json.loads(text)
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"{origin}: schema {doc.get('schema')!r}, want {SCHEMA!r}")
    rows = {}
    for row in doc["rows"]:
        rows[(row["bench"], row["engine"])] = row
    return rows


def baseline_from_git(path: str) -> str | None:
    """The committed version of `path` at HEAD, or None when absent."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"],
            capture_output=True,
            text=True,
            cwd=Path(__file__).resolve().parent.parent,
        )
    except OSError:
        return None
    return out.stdout if out.returncode == 0 else None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="BENCH_simspeed.json",
                    help="freshly generated bench file (default: %(default)s)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: HEAD's committed copy of --fresh)")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="tolerated fractional sim-speed drop (default: %(default)s)")
    args = ap.parse_args()

    fresh_path = Path(args.fresh)
    if not fresh_path.exists():
        print(f"bench-gate: {fresh_path} missing — run `cargo bench --bench simspeed` first")
        return 2
    fresh = load_rows(fresh_path.read_text(), str(fresh_path))

    if args.baseline is not None:
        base_path = Path(args.baseline)
        if not base_path.exists():
            print(f"bench-gate: baseline {base_path} missing")
            return 2
        base_text = base_path.read_text()
        origin = str(base_path)
    else:
        base_text = baseline_from_git(args.fresh)
        origin = f"git:HEAD:{args.fresh}"
        if base_text is None:
            print(f"bench-gate: no committed {args.fresh} at HEAD yet — "
                  "nothing to compare (commit one to arm the gate)")
            return 0
    base = load_rows(base_text, origin)

    regressions = []
    compared = 0
    for key, brow in sorted(base.items()):
        frow = fresh.get(key)
        if frow is None:
            print(f"bench-gate: note: row {key} in baseline only (renamed/removed?)")
            continue
        compared += 1
        old, new = brow["mcycles_per_s"], frow["mcycles_per_s"]
        drop = 0.0 if old <= 0 else (old - new) / old
        status = "REGRESSED" if drop > args.max_regress else "ok"
        print(f"  {key[0]:>10} / {key[1]:<12} {old:10.2f} -> {new:10.2f} Mcyc/s "
              f"({-drop:+7.1%})  {status}")
        if drop > args.max_regress:
            regressions.append((key, old, new, drop))
    for key in sorted(set(fresh) - set(base)):
        print(f"bench-gate: note: new row {key} (no baseline yet)")

    if not compared:
        print("bench-gate: no comparable rows — treating as pass")
        return 0
    if regressions:
        print(f"\nbench-gate: FAIL — {len(regressions)} row(s) regressed more than "
              f"{args.max_regress:.0%}:")
        for key, old, new, drop in regressions:
            print(f"  {key[0]} / {key[1]}: {old:.2f} -> {new:.2f} Mcyc/s ({drop:.1%} slower)")
        return 1
    print(f"\nbench-gate: OK — {compared} row(s) within {args.max_regress:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
