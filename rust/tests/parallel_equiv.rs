//! Differential suite: the deterministic fully sharded engine
//! (`Cluster::run_parallel`) vs the serial reference engine
//! (`Cluster::run`).
//!
//! The acceptance bar of the engine (DESIGN.md §Fully sharded engine):
//! for every Table-6 cluster configuration and kernel — the full Sec. 7
//! set: axpy, dotp, gemm, fft, spmmadd — the parallel engine must
//! produce the **identical** final memory image, cycle count and
//! `RunStats` (instructions, per-cause stalls, AMAT, per-class request
//! histogram — everything `RunStats: PartialEq` compares) at 1, 2, 4, 8
//! and 16 host threads. No tolerances anywhere: determinism means bit
//! equality. DMA coverage: a raw start/wait trace, the Fig. 14b
//! double-buffer pipeline, and a DMA-saturated many-round pipeline that
//! maximizes pressure on the engine's sharded pre-phase (distributed
//! barriers, per-worker DMA waiters, partitioned burst movement).

use terapool::cluster::{Cluster, RunStats};
use terapool::config::{ClusterConfig, Scale};
use terapool::dma::{hbm_image_clear, hbm_image_fetch, hbm_image_stage, DmaDescriptor};
use terapool::isa::{Op, Program};
use terapool::kernels::{axpy, dotp, double_buffer, fft, gemm, spmmadd, Workload};
use terapool::memory::L1Memory;
use terapool::session::Session;

const THREADS: [usize; 5] = [1, 2, 4, 8, 16];

/// Every ClusterConfig the paper's Table 6 sweeps, plus all three
/// TeraPool spill-register operating points.
fn table6_configs() -> Vec<ClusterConfig> {
    vec![
        ClusterConfig::tiny(),
        ClusterConfig::mempool(),
        ClusterConfig::occamy(),
        ClusterConfig::terapool(7),
        ClusterConfig::terapool(9),
        ClusterConfig::terapool(11),
    ]
}

fn run_engine(
    cfg: &ClusterConfig,
    w: &dyn Workload,
    threads: Option<usize>,
) -> (RunStats, Vec<f32>) {
    let setup = w.build(cfg, Scale::Fast);
    let (mut cl, io) = setup.into_cluster(cfg.clone());
    let stats = match threads {
        None => cl.run(50_000_000),
        Some(t) => cl.run_parallel(50_000_000, t),
    };
    let out = io.read_output(&cl).expect("engine run finished");
    (stats, out)
}

fn assert_engines_agree(cfg: &ClusterConfig, w: &dyn Workload) {
    let (serial_stats, serial_out) = run_engine(cfg, w, None);
    for &threads in &THREADS {
        let (par_stats, par_out) = run_engine(cfg, w, Some(threads));
        assert_eq!(
            serial_stats,
            par_stats,
            "{} / {}: stats diverge at {threads} threads",
            cfg.name,
            w.kind()
        );
        assert_eq!(
            serial_out,
            par_out,
            "{} / {}: memory image diverges at {threads} threads",
            cfg.name,
            w.kind()
        );
    }
}

// Cluster-size-scaled kernel problems, small enough that the full
// matrix (6 configs × 5 kernels × 5 engine runs) stays fast in debug.

#[test]
fn axpy_identical_on_all_table6_configs() {
    for cfg in table6_configs() {
        let w = axpy::Axpy::with(axpy::AxpyParams { n: cfg.num_banks() * 4, alpha: 2.0 });
        assert_engines_agree(&cfg, &w);
    }
}

#[test]
fn dotp_identical_on_all_table6_configs() {
    for cfg in table6_configs() {
        let w = dotp::Dotp::with(dotp::DotpParams { n: cfg.num_banks() * 4 });
        assert_engines_agree(&cfg, &w);
    }
}

#[test]
fn gemm_identical_on_all_table6_configs() {
    for cfg in table6_configs() {
        let w = gemm::Gemm::with(gemm::GemmParams { m: 32, n: 32, k: 32 });
        assert_engines_agree(&cfg, &w);
    }
}

#[test]
fn fft_identical_on_all_table6_configs() {
    // Barrier-heavy, all-hierarchy strides (radix-4, 3 stages).
    for cfg in table6_configs() {
        let w = fft::Fft::with(fft::FftParams { batch: 2, n: 64 });
        assert_engines_agree(&cfg, &w);
    }
}

#[test]
fn spmmadd_identical_on_all_table6_configs() {
    // Irregular, branch-heavy CSR merges with data-dependent loads.
    for cfg in table6_configs() {
        let w = spmmadd::Spmmadd::with(spmmadd::SpmmaddParams {
            rows: cfg.num_pes().min(512),
            cols: 256,
            nnz_per_row: 4,
            seed: 0xD1FF,
        });
        assert_engines_agree(&cfg, &w);
    }
}

/// Burst-on differentials: with `cfg.burst` the kernels issue multi-word
/// requests whose beats claim consecutive bank ports as one unit, and
/// the split/merge of those requests across shard boundaries is exactly
/// where a non-deterministic engine would diverge first. Serial vs
/// 1/8/16 threads on every Table-6 config, for the three burst-emitting
/// kernels, bit-identical stats (including the burst split counters)
/// and memory image.
#[test]
fn burst_runs_identical_on_all_table6_configs() {
    for cfg in table6_configs() {
        let cfg = cfg.with_burst(true);
        let kernels: Vec<Box<dyn Workload>> = vec![
            Box::new(axpy::Axpy::with(axpy::AxpyParams {
                n: cfg.num_banks() * 4,
                alpha: 2.0,
            })),
            Box::new(dotp::Dotp::with(dotp::DotpParams { n: cfg.num_banks() * 4 })),
            Box::new(spmmadd::Spmmadd::with(spmmadd::SpmmaddParams {
                rows: cfg.num_pes().min(512),
                cols: 256,
                nnz_per_row: 4,
                seed: 0xD1FF,
            })),
        ];
        for w in &kernels {
            let (serial_stats, serial_out) = run_engine(&cfg, &**w, None);
            assert!(
                serial_stats.burst_reqs_per_class.iter().sum::<u64>() > 0,
                "{} / {}: burst mode produced no burst traffic",
                cfg.name,
                w.kind()
            );
            for &threads in &[1usize, 8, 16] {
                let (par_stats, par_out) = run_engine(&cfg, &**w, Some(threads));
                assert_eq!(
                    serial_stats,
                    par_stats,
                    "{} / {}: burst stats diverge at {threads} threads",
                    cfg.name,
                    w.kind()
                );
                assert_eq!(
                    serial_out,
                    par_out,
                    "{} / {}: burst image diverges at {threads} threads",
                    cfg.name,
                    w.kind()
                );
            }
        }
    }
}

/// The Fig. 14b double-buffer pipeline: DMA start/wait chains overlapping
/// compute across rounds — the richest interleaving of the coordinator's
/// DMA control path with the sharded memory step. `DbResult` carries the
/// cycle count, stall-derived compute fraction, transferred bytes and
/// IPC; all four must be bit-identical across engines and thread counts.
#[test]
fn double_buffer_trace_identical_across_engines() {
    let cfg = ClusterConfig::tiny();
    let p = double_buffer::DbParams {
        kernel: double_buffer::DbKernel::Axpy,
        chunk: cfg.num_banks() * 4,
        rounds: 3,
    };
    hbm_image_clear();
    let serial = double_buffer::run(&cfg, &p);
    for &threads in &THREADS {
        hbm_image_clear();
        let par = double_buffer::run_threads(&cfg, &p, threads);
        assert_eq!(serial, par, "double-buffer diverges at {threads} threads");
    }
}

/// DMA-saturated pipeline: many short rounds keep three descriptors per
/// round in flight with every PE cycling through `DmaWait`s — the
/// heaviest sustained traffic on the paths the sharded engine
/// distributes: worker-local DMA waiter parking/waking, the `DmaStart`
/// summary-tree stream, per-cycle retirement broadcasts, and burst word
/// movement partitioned across the workers' Tile ranges (both
/// directions: inbound input staging and outbound result write-back).
#[test]
fn dma_saturated_double_buffer_identical_across_engines() {
    for cfg in [ClusterConfig::tiny(), ClusterConfig::mempool()] {
        let chunk = cfg.num_banks() * 4;
        let rounds = 6usize;
        let p = double_buffer::DbParams {
            kernel: double_buffer::DbKernel::Axpy,
            chunk,
            rounds,
        };
        // The outbound write-backs must reach the main-memory image
        // identically: stage() puts round r's z at z_base + r*chunk*4
        // (AXPY writes the full chunk back each round).
        let ch_b = (chunk * 4) as u64;
        let z_base = 2 * ch_b * rounds as u64;
        let fetch_z = || -> Vec<f32> {
            (0..rounds)
                .flat_map(|r| hbm_image_fetch(z_base + r as u64 * ch_b, chunk))
                .collect()
        };
        hbm_image_clear();
        let serial = double_buffer::run(&cfg, &p);
        let z_serial = fetch_z();
        assert!(serial.bytes_transferred > 0);
        assert!(
            z_serial.iter().any(|&v| v != 0.0),
            "{}: serial write-backs never reached the image",
            cfg.name
        );
        for &threads in &THREADS {
            hbm_image_clear();
            let par = double_buffer::run_threads(&cfg, &p, threads);
            assert_eq!(
                serial, par,
                "{}: DMA-saturated pipeline diverges at {threads} threads",
                cfg.name
            );
            assert_eq!(
                z_serial,
                fetch_z(),
                "{}: outbound image contents diverge at {threads} threads",
                cfg.name
            );
        }
    }
}

/// Synthetic stress trace: control bubbles, bank-hammering atomics and
/// two barrier phases with a straggler PE — the shared-state paths
/// (barrier counters, wake broadcast, atomic serialization) where a
/// non-deterministic engine would diverge first.
#[test]
fn stress_trace_identical_across_engines() {
    for cfg in [ClusterConfig::tiny(), ClusterConfig::mempool()] {
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let hot = base; // every PE's atomic hits this word
        let out = base + cfg.num_banks() as u32;
        let npes = cfg.num_pes();
        let build = |cfg: &ClusterConfig| -> Vec<Program> {
            (0..cfg.num_pes())
                .map(|i| {
                    let mut p = Program::new();
                    p.ld_imm(1, 1.0);
                    if i == 0 {
                        // Straggler: every other PE piles up at barrier 0.
                        for _ in 0..100 {
                            p.alu();
                            p.branch();
                        }
                    }
                    p.atom_add(1, hot);
                    p.barrier(0);
                    p.ld(2, hot);
                    p.st(2, out + i as u32);
                    p.barrier(1);
                    p.ld(3, out + ((i as u32 + 1) % cfg.num_pes() as u32));
                    p.add(4, 3, 2);
                    p.halt();
                    p
                })
                .collect()
        };
        let mut serial = Cluster::new(cfg.clone(), build(&cfg));
        let s_stats = serial.run(1_000_000);
        // The atomic sum must be visible to every PE after barrier 0.
        assert_eq!(serial.l1.read(hot), npes as f32, "{}", cfg.name);
        for &threads in &THREADS {
            let mut par = Cluster::new(cfg.clone(), build(&cfg));
            let p_stats = par.run_parallel(1_000_000, threads);
            assert_eq!(s_stats, p_stats, "{}: stats @ {threads} threads", cfg.name);
            assert_eq!(
                serial.l1.read_slice(out, npes),
                par.l1.read_slice(out, npes),
                "{}: image @ {threads} threads",
                cfg.name
            );
        }
    }
}

/// DMA start/wait traces must behave identically too: the coordinator
/// owns DMA progress in both engines, but the wake paths differ
/// mechanically (in-cycle vs next-cycle-top wake) and must stay
/// observationally identical.
#[test]
fn dma_trace_identical_across_engines() {
    let cfg = ClusterConfig::tiny();
    let base = L1Memory::new(&cfg).map.interleaved_base();
    let words = 256usize;
    let data: Vec<f32> = (0..words).map(|i| i as f32 + 0.25).collect();
    let build = |cfg: &ClusterConfig| -> Vec<Program> {
        (0..cfg.num_pes())
            .map(|i| {
                let mut p = Program::new();
                if i == 0 {
                    p.push(Op::DmaStart { id: 0 });
                }
                p.push(Op::DmaWait { id: 0 });
                p.ld(1, base + i as u32);
                p.push(Op::DmaWait { id: 0 }); // already-retired wait path
                p.st(1, base + words as u32 + i as u32);
                p.halt();
                p
            })
            .collect()
    };
    let run = |threads: Option<usize>| -> (RunStats, Vec<f32>) {
        hbm_image_clear();
        hbm_image_stage(0, &data);
        let mut cl = Cluster::new(cfg.clone(), build(&cfg)).with_dma();
        cl.dma.as_mut().unwrap().register(DmaDescriptor {
            l1_word: base,
            mem_byte: 0,
            words: words as u32,
            to_l1: true,
        });
        let stats = match threads {
            None => cl.run(1_000_000),
            Some(t) => cl.run_parallel(1_000_000, t),
        };
        let image = cl.l1.read_slice(base + words as u32, cfg.num_pes());
        (stats, image)
    };
    let (s_stats, s_image) = run(None);
    assert_eq!(s_image[0], 0.25, "DMA staged data must land in L1");
    for &threads in &THREADS {
        let (p_stats, p_image) = run(Some(threads));
        assert_eq!(s_stats, p_stats, "stats @ {threads} threads");
        assert_eq!(s_image, p_image, "image @ {threads} threads");
    }
}

/// Idle-heavy differential: a sparse barrier-ping trace whose phases are
/// dominated by fully quiescent drain gaps — one straggler grinds
/// through long Alu/Branch chains while every other PE parks at the
/// barrier, and a cluster-wide `DmaWait` parks *all* PEs behind a
/// streaming transfer. Exactly the spans the engines' idle-cycle
/// fast-forward jumps. The skip must be unobservable: `RunStats` and the
/// memory image bit-identical between `fast_forward` on and off, on the
/// serial engine and at 1/8/16 worker threads.
#[test]
fn idle_heavy_fast_forward_is_bit_identical() {
    for cfg in [ClusterConfig::tiny(), ClusterConfig::mempool()] {
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let hot = base;
        let out = base + cfg.num_banks() as u32;
        // DMA L1 targets must sit on a 256-word SubGroup-run boundary
        // past the scratch words above.
        let used = cfg.num_banks() + cfg.num_pes();
        let dma_l1 = base + (used as u32).div_ceil(256) * 256;
        let words = 256usize;
        let data: Vec<f32> = (0..words).map(|i| i as f32 * 0.5).collect();
        let build = |cfg: &ClusterConfig| -> Vec<Program> {
            (0..cfg.num_pes())
                .map(|i| {
                    let mut p = Program::new();
                    p.ld_imm(1, 1.0);
                    // Three barrier-ping phases, each with a long drain
                    // gap: everyone else arrives immediately and sits
                    // parked while PE 0 grinds.
                    for phase in 0..3u16 {
                        if i == 0 {
                            for _ in 0..200 {
                                p.alu();
                                p.branch();
                            }
                        }
                        p.atom_add(1, hot);
                        p.barrier(phase);
                    }
                    // Cluster-wide DMA park: every PE waits on the same
                    // streaming transfer — zero busy PEs until the HBML
                    // event lands.
                    if i == 0 {
                        p.push(Op::DmaStart { id: 0 });
                    }
                    p.push(Op::DmaWait { id: 0 });
                    p.ld(2, dma_l1 + (i % words) as u32);
                    p.st(2, out + i as u32);
                    p.halt();
                    p
                })
                .collect()
        };
        let run = |fast_forward: bool, threads: Option<usize>| -> (RunStats, Vec<f32>) {
            hbm_image_clear();
            hbm_image_stage(0, &data);
            let mut cl = Cluster::new(cfg.clone(), build(&cfg)).with_dma();
            cl.fast_forward = fast_forward;
            cl.dma.as_mut().unwrap().register(DmaDescriptor {
                l1_word: dma_l1,
                mem_byte: 0,
                words: words as u32,
                to_l1: true,
            });
            let stats = match threads {
                None => cl.run(5_000_000),
                Some(t) => cl.run_parallel(5_000_000, t),
            };
            let image = cl.l1.read_slice(out, cfg.num_pes());
            (stats, image)
        };
        let (ref_stats, ref_image) = run(false, None);
        // The trace must actually be idle-heavy, or this test guards
        // nothing: parked PEs dominate the straggler phases.
        assert!(
            ref_stats.stall_synch > ref_stats.cycles,
            "{}: trace not idle-heavy (synch {} vs cycles {})",
            cfg.name,
            ref_stats.stall_synch,
            ref_stats.cycles
        );
        for (ff, threads) in [
            (true, None),
            (true, Some(1)),
            (false, Some(1)),
            (true, Some(8)),
            (false, Some(8)),
            (true, Some(16)),
            (false, Some(16)),
        ] {
            let (stats, image) = run(ff, threads);
            assert_eq!(
                ref_stats, stats,
                "{}: stats diverge (fast_forward={ff}, threads={threads:?})",
                cfg.name
            );
            assert_eq!(
                ref_image, image,
                "{}: image diverges (fast_forward={ff}, threads={threads:?})",
                cfg.name
            );
        }
    }
}

/// Thread counts beyond the Tile count (and absurd ones) clamp instead
/// of misbehaving — occamy has a single Tile, so this exercises the
/// one-worker edge of the sharding.
#[test]
fn thread_clamping_preserves_results() {
    let cfg = ClusterConfig::occamy();
    let w = axpy::Axpy::with(axpy::AxpyParams { n: cfg.num_banks() * 4, alpha: 2.0 });
    let (serial_stats, serial_out) = run_engine(&cfg, &w, None);
    for threads in [1usize, 3, 64, 1024] {
        let (p_stats, p_out) = run_engine(&cfg, &w, Some(threads));
        assert_eq!(serial_stats, p_stats, "{threads} threads");
        assert_eq!(serial_out, p_out, "{threads} threads");
    }
}

/// The coordinator must also agree with itself: re-running the parallel
/// engine at the same thread count is reproducible (no hidden
/// scheduling dependence).
#[test]
fn parallel_engine_is_reproducible() {
    let cfg = ClusterConfig::tiny();
    let w = gemm::Gemm::with(gemm::GemmParams { m: 32, n: 32, k: 32 });
    let (a_stats, a_out) = run_engine(&cfg, &w, Some(4));
    let (b_stats, b_out) = run_engine(&cfg, &w, Some(4));
    assert_eq!(a_stats, b_stats);
    assert_eq!(a_out, b_out);
}

/// The Session run path must route through the same engines (guards the
/// plumbing behind the CLI's --threads flag): a single run with a
/// thread budget > 1 executes on the tile-parallel engine and must
/// report identical stats to a serial session.
#[test]
fn session_threading_matches_serial() {
    let cfg = ClusterConfig::tiny();
    let serial = Session::new(cfg.clone())
        .scale(Scale::Fast)
        .run_named("axpy")
        .expect("serial session run");
    let parallel = Session::new(cfg)
        .scale(Scale::Fast)
        .threads(4)
        .run_named("axpy")
        .expect("parallel session run");
    assert_eq!(serial.stats, parallel.stats);
    assert_eq!(serial.fingerprint, parallel.fingerprint);
}
