//! Differential suite for the system engine (the scale-out analogue of
//! `parallel_equiv.rs`): stepping the clusters of a system run
//! **cluster-parallel on host threads** must be bit-identical to the
//! serial in-order stepping — same aggregate `RunStats`, same
//! `SystemInfo` breakdown (per-cluster, per-link, bus, timeline split),
//! same merged memory-node image, same verdict. The system phases
//! (staging, broadcast, merge) are simulated on the coordinating thread
//! in fixed order, and the compute chunks share no state, so the only
//! way this can fail is a real determinism bug.

use terapool::config::ClusterConfig;
use terapool::kernels::{fft::FftParams, gemm::GemmParams};
use terapool::report::Verdict;
use terapool::system::{run_system, run_system_phases, run_system_sliced, SystemKernel, SystemRun};
use terapool::topology::Topology;

const BUDGET: u64 = 10_000_000;

fn run_at(threads: usize, kernel: &SystemKernel, topo: &Topology) -> SystemRun {
    run_system(topo, kernel, threads, BUDGET, true, true).expect("system run finishes")
}

#[test]
fn system_stepping_is_bit_identical_across_host_threads() {
    let cases: &[(SystemKernel, usize)] = &[
        (SystemKernel::Gemm(GemmParams { m: 32, n: 16, k: 16 }), 4),
        (SystemKernel::Fft(FftParams { batch: 8, n: 64 }), 4),
        (SystemKernel::Gemm(GemmParams { m: 16, n: 16, k: 16 }), 2),
    ];
    for (kernel, parts) in cases {
        let topo = Topology::split(&ClusterConfig::tiny(), *parts).expect("tiny splits");
        let serial = run_at(1, kernel, &topo);
        assert!(
            matches!(serial.verdict, Verdict::Passed { .. }),
            "{}: {:?}",
            serial.name,
            serial.verdict
        );
        for threads in [2usize, 4] {
            let parallel = run_at(threads, kernel, &topo);
            assert_eq!(serial.name, parallel.name);
            assert_eq!(
                serial.stats, parallel.stats,
                "{}: aggregate stats diverge at {threads} host threads",
                serial.name
            );
            assert_eq!(
                serial.info, parallel.info,
                "{}: system breakdown diverges at {threads} host threads",
                serial.name
            );
            assert_eq!(
                serial.output, parallel.output,
                "{}: memory-node image diverges at {threads} host threads",
                serial.name
            );
            assert_eq!(serial.verdict, parallel.verdict);
        }
    }
}

/// Fast-forward must stay bit-identical inside system runs too (each
/// cluster chunk skips its own idle spans; the system timeline is
/// arithmetic on top).
#[test]
fn system_fast_forward_is_bit_identical() {
    let topo = Topology::split(&ClusterConfig::tiny(), 2).expect("tiny splits");
    let kernel = SystemKernel::Gemm(GemmParams { m: 16, n: 16, k: 16 });
    let skipped = run_system(&topo, &kernel, 2, BUDGET, true, true).unwrap();
    let stepped = run_system(&topo, &kernel, 2, BUDGET, false, true).unwrap();
    assert_eq!(skipped.stats, stepped.stats);
    assert_eq!(skipped.info, stepped.info);
    assert_eq!(skipped.output, stepped.output);
}

/// The pipelined engine reorders *timing* (staging and merge stream on
/// the shared bus while earlier slices compute) but must never reorder
/// *data*: the merged memory-node image has to stay byte-identical to
/// the phase-serial reference at every slice count and host-thread
/// count. Functional state is staged per slice straight from the host
/// arrays, so any divergence here is a slicing bug (wrong tile bounds,
/// wrong K-phase, wrong merge stride), not a scheduling artifact.
#[test]
fn pipelined_image_matches_the_phase_serial_reference() {
    let cases: &[(SystemKernel, usize, &[usize])] = &[
        (SystemKernel::Gemm(GemmParams { m: 32, n: 16, k: 16 }), 4, &[2, 4]),
        (SystemKernel::Gemm(GemmParams { m: 16, n: 16, k: 16 }), 2, &[2, 4]),
        (SystemKernel::Fft(FftParams { batch: 8, n: 64 }), 4, &[2]),
        (SystemKernel::Fft(FftParams { batch: 8, n: 64 }), 2, &[2, 4]),
    ];
    for (kernel, parts, slice_counts) in cases {
        let topo = Topology::split(&ClusterConfig::tiny(), *parts).expect("tiny splits");
        let reference =
            run_system_phases(&topo, kernel, 1, BUDGET, true, true).expect("reference runs");
        for &slices in *slice_counts {
            for threads in [1usize, 2, 4] {
                let sliced = run_system_sliced(&topo, kernel, threads, BUDGET, true, true, slices)
                    .expect("sliced run finishes");
                assert_eq!(
                    reference.output, sliced.output,
                    "{}: merged image diverges at S={slices}, {threads} host threads",
                    reference.name
                );
                assert_eq!(reference.verdict, sliced.verdict);
                assert_eq!(sliced.info.slices, slices as u64, "{}", sliced.name);
                assert_eq!(
                    sliced.info.exposed_bus_cycles + sliced.info.hidden_bus_cycles,
                    sliced.info.bus_busy_cycles,
                    "{}: bus-cycle split must partition busy cycles",
                    sliced.name
                );
            }
        }
    }
}

/// `--slices 1` is not "approximately" the old engine — it must
/// reproduce the phase-serial timeline exactly: same cycle count, same
/// `SystemInfo` breakdown, same image, at every host-thread count.
#[test]
fn single_slice_run_is_exactly_the_phase_serial_engine() {
    let cases: &[(SystemKernel, usize)] = &[
        (SystemKernel::Gemm(GemmParams { m: 32, n: 16, k: 16 }), 4),
        (SystemKernel::Fft(FftParams { batch: 8, n: 64 }), 4),
        (SystemKernel::Gemm(GemmParams { m: 16, n: 16, k: 16 }), 2),
    ];
    for (kernel, parts) in cases {
        let topo = Topology::split(&ClusterConfig::tiny(), *parts).expect("tiny splits");
        let phases = run_system_phases(&topo, kernel, 1, BUDGET, true, true).unwrap();
        for threads in [1usize, 2, 4] {
            let sliced = run_system_sliced(&topo, kernel, threads, BUDGET, true, true, 1).unwrap();
            assert_eq!(phases.name, sliced.name);
            assert_eq!(phases.stats, sliced.stats, "{}", phases.name);
            assert_eq!(phases.info, sliced.info, "{}", phases.name);
            assert_eq!(phases.output, sliced.output, "{}", phases.name);
        }
    }
}

/// The point of the pipeline: on the shipped quad mesh the 4-way sliced
/// GEMM must finish in fewer cycles than the serial reference while
/// producing the same bytes — overlap buys time, never correctness.
#[test]
fn quad_mesh_gemm_pipelining_saves_cycles_and_keeps_the_image() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples");
    let topo = Topology::load(&dir.join("quad.topo")).expect("quad.topo parses");
    let kernel = SystemKernel::Gemm(GemmParams { m: 32, n: 32, k: 32 });
    let serial = run_system_sliced(&topo, &kernel, 4, BUDGET, true, true, 1).unwrap();
    let sliced = run_system_sliced(&topo, &kernel, 4, BUDGET, true, true, 4).unwrap();
    assert_eq!(serial.output, sliced.output, "image must survive 4-way slicing");
    assert!(
        sliced.stats.cycles < serial.stats.cycles,
        "S=4 must beat S=1: {} vs {}",
        sliced.stats.cycles,
        serial.stats.cycles
    );
    assert_eq!(
        sliced.info.exposed_bus_cycles + sliced.info.hidden_bus_cycles,
        sliced.info.bus_busy_cycles
    );
    assert!(
        sliced.info.hidden_bus_cycles > 0,
        "4-way slicing on the quad mesh must hide some bus traffic"
    );
}

/// The example topology files shipped for the CLI must parse and carry
/// the advertised shape (quad: 4×256 PEs on a 2x2 mesh; dual: 2×512
/// over one p2p link — both 1024 total).
#[test]
fn example_topology_files_parse() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples");
    let quad = Topology::load(&dir.join("quad.topo")).expect("quad.topo parses");
    assert_eq!(quad.clusters.len(), 4);
    assert_eq!(quad.mesh, Some((2, 2)));
    assert_eq!(quad.total_pes(), 1024);
    let dual = Topology::load(&dir.join("dual.topo")).expect("dual.topo parses");
    assert_eq!(dual.clusters.len(), 2);
    assert_eq!(dual.links.len(), 1);
    assert_eq!(dual.total_pes(), 1024);
    assert_eq!(dual.memory.name, "hbm");
}
