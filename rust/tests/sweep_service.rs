//! Serving-layer robustness suite for the design-space sweep service
//! (the ISSUE-9 acceptance tests):
//!
//! * **per-point failure isolation** — an unresolvable point mid-sweep
//!   is recorded as a typed `PointError` while its siblings' embedded
//!   `RunReport`s stay *bit-identical* to solo session runs;
//! * **kill/resume determinism** — a sweep killed after its first
//!   checkpoint and resumed from the on-disk document renders a
//!   `SweepReport` byte-identical to an uninterrupted run;
//! * **no re-estimation on resume** — a value planted in the checkpoint
//!   survives into the final report verbatim, proving completed points
//!   are reused rather than silently recomputed;
//! * **cross-spec checkpoints are refused** via the spec fingerprint.

use terapool::config::{ClusterConfig, Scale};
use terapool::kernels;
use terapool::session::Session;
use terapool::sweep::{run_sweep, SweepReport, SweepSpec, DEFAULT_RTOL};

/// A 3-point grid on the tiny preset with an unresolvable workload
/// planted mid-list. `SweepSpec::parse` would reject it (validate runs
/// workload lookup), so robustness tests construct the spec directly —
/// exactly the state a registry mismatch between checkpoint-time and
/// resume-time would produce.
fn spec_with_bogus_point() -> SweepSpec {
    SweepSpec {
        name: "iso".into(),
        scale: Scale::Fast,
        rtol: DEFAULT_RTOL,
        presets: vec!["tiny".into()],
        groups: vec![None],
        banking: vec![None],
        burst: vec![false],
        workloads: vec!["axpy".into(), "bogus".into(), "dotp".into()],
    }
}

#[test]
fn failing_point_is_isolated_and_siblings_match_solo_runs() {
    let rep = run_sweep(&spec_with_bogus_point(), 1, None, |_| Ok(())).unwrap();
    assert_eq!(rep.points.len(), 3);

    let bad = &rep.points[1];
    assert_eq!(bad.workload, "bogus");
    let e = bad.error.as_ref().expect("the planted point must fail");
    assert_eq!(e.kind, "unknown-workload");
    assert!(bad.estimated.is_none() && bad.measured.is_none() && !bad.frontier);

    // Siblings are bit-identical to solo runs through an equivalent
    // estimating session (same config, scale, thread budget) — the
    // failure never leaks into their reports. The sweep labels each
    // point's config with its grid label, so the solo side does too
    // (the label is fingerprinted).
    let mut cfg = ClusterConfig::tiny();
    cfg.name = "tiny".into();
    let solo = Session::new(cfg.clone()).scale(Scale::Fast).threads(1).estimating(true);
    for (i, kind) in [(0usize, "axpy"), (2, "dotp")] {
        let want = solo.run_on(&cfg, &*kernels::lookup(kind).unwrap()).unwrap();
        let got = rep.points[i].estimated.as_ref().expect("sibling estimate survives");
        assert_eq!(
            got.to_json().render(),
            want.to_json().render(),
            "{kind}: sweep-embedded report drifted from the solo run"
        );
    }
    // The failure is recorded, not fatal — and it never joins the
    // frontier, so it is never re-run either.
    assert!(rep.points.iter().any(|p| p.frontier), "healthy points still form a frontier");
}

fn clean_spec() -> SweepSpec {
    SweepSpec {
        name: "resume".into(),
        scale: Scale::Fast,
        rtol: DEFAULT_RTOL,
        presets: vec!["tiny".into()],
        groups: vec![None],
        banking: vec![None],
        burst: vec![false],
        workloads: vec!["axpy".into(), "dotp".into()],
    }
}

#[test]
fn killed_then_resumed_sweep_is_byte_identical() {
    let spec = clean_spec();
    let full = run_sweep(&spec, 1, None, |_| Ok(())).unwrap();

    // Kill the sweep right after its first checkpoint lands: the
    // callback persists the snapshot, then fails the run — the same
    // observable state as a SIGKILL between batches.
    let mut checkpoint = String::new();
    let killed = run_sweep(&spec, 1, None, |snap| {
        if checkpoint.is_empty() {
            checkpoint = snap.render();
            Ok(())
        } else {
            Err(terapool::err!("injected kill"))
        }
    });
    assert!(killed.is_err(), "the injected kill must abort the sweep");
    assert!(!checkpoint.is_empty(), "one checkpoint must have landed first");

    // Resume from the persisted bytes (parse → run): the final document
    // renders byte-identically to the uninterrupted sweep.
    let prior = SweepReport::parse(&checkpoint).unwrap();
    let done = prior.points.iter().filter(|p| p.estimated.is_some()).count();
    assert!(done >= 1 && done < prior.points.len(), "the kill left a partial document");
    let resumed = run_sweep(&spec, 1, Some(&prior), |_| Ok(())).unwrap();
    assert_eq!(resumed.render(), full.render(), "resume must not change a single byte");
}

#[test]
fn resume_reuses_checkpointed_estimates_verbatim() {
    let spec = clean_spec();
    let full = run_sweep(&spec, 1, None, |_| Ok(())).unwrap();

    // Plant a tracer: bump the first point's estimated cycle count in
    // the checkpoint. If resume re-estimated completed points the
    // engine would deterministically revert it; reuse preserves it.
    let mut prior = full.clone();
    for p in &mut prior.points {
        p.measured = None; // pretend the kill hit before the refine phase
    }
    let est = prior.points[0].estimated.as_mut().unwrap();
    est.stats.cycles += 1;
    let planted = est.stats.cycles;

    let resumed = run_sweep(&spec, 1, Some(&prior), |_| Ok(())).unwrap();
    let got = resumed.points[0].estimated.as_ref().unwrap().stats.cycles;
    assert_eq!(got, planted, "resume re-estimated a checkpointed point");
    assert_ne!(got, full.points[0].estimated.as_ref().unwrap().stats.cycles);
}

#[test]
fn checkpoint_roundtrips_through_disk_bytes() {
    let spec = clean_spec();
    let rep = run_sweep(&spec, 2, None, |snap| {
        // Every checkpoint must parse back to an equal document — the
        // on-disk form is the report schema itself.
        let back = SweepReport::parse(&snap.render()).unwrap();
        assert_eq!(back.render(), snap.render());
        Ok(())
    })
    .unwrap();
    assert_eq!(rep.spec_fingerprint, spec.fingerprint());
    assert!(rep.frontier_drift_failures() == 0);
}
