//! Integration suite for the Workload/Session API (the ISSUE-4
//! acceptance tests):
//!
//! * **registry completeness** — every kernel the simulator ships is
//!   listed; unknown names are a typed error, never a panic;
//! * **JSON report round-trip** — emit → parse → field equality for the
//!   `terapool-runreport-v1` document `--json` writes;
//! * **batch-vs-sequential bit-identity** — a mixed workload×config
//!   batch (including a DMA-carrying double-buffered job) produces
//!   byte-identical `RunReport`s at 1/2/4/8 host threads;
//! * **typed timeouts** — a run that hits `max_cycles` surfaces
//!   `ErrorKind::MaxCyclesExceeded` instead of comparing garbage;
//! * **failure isolation** — one job timing out mid-batch must not
//!   poison its siblings: they report bit-identically to solo runs.

use terapool::config::{ClusterConfig, Scale};
use terapool::errors::ErrorKind;
use terapool::kernels::{self, axpy, dotp, double_buffer, gemm};
use terapool::report::{reports_from_json, reports_to_json, Verdict};
use terapool::session::{Job, Session};

// ------------------------------------------------------------------
// Registry
// ------------------------------------------------------------------

#[test]
fn registry_lists_every_kernel() {
    let names = kernels::names();
    for want in ["axpy", "dotp", "gemm", "fft", "spmmadd", "db-axpy", "db-dotp", "db-gemm"] {
        assert!(names.contains(&want), "{want} missing from registry {names:?}");
    }
    // The Fig. 14a sweep is resolved through the registry too.
    for k in terapool::coordinator::FIG14A_KERNELS {
        assert!(names.contains(&k), "{k} missing from registry");
    }
    // Every entry resolves to itself.
    for name in &names {
        assert_eq!(kernels::lookup(name).unwrap().kind(), *name);
        assert!(!kernels::lookup(name).unwrap().describe().is_empty());
    }
}

#[test]
fn unknown_workload_is_a_typed_error_not_a_panic() {
    let e = kernels::lookup("axpyy").unwrap_err();
    assert_eq!(e.kind(), ErrorKind::UnknownWorkload);
    assert!(e.to_string().contains("axpyy"), "{e}");
    assert!(e.to_string().contains("axpy"), "error should list known names: {e}");

    let s = Session::new(ClusterConfig::tiny()).scale(Scale::Fast);
    assert_eq!(s.run_named("gemmm").unwrap_err().kind(), ErrorKind::UnknownWorkload);
}

// ------------------------------------------------------------------
// JSON round-trip
// ------------------------------------------------------------------

#[test]
fn run_report_round_trips_through_json() {
    let cfg = ClusterConfig::tiny();
    let s = Session::new(cfg.clone()).scale(Scale::Fast).check(true);
    let jobs = vec![
        Job::new(cfg.clone(), kernels::lookup("axpy").unwrap()),
        Job::new(cfg.clone(), kernels::lookup("dotp").unwrap()),
        // A DMA-carrying report: exercises the dma_bytes field.
        Job::new(
            cfg.clone(),
            Box::new(double_buffer::Db::with(
                double_buffer::DbKernel::Axpy,
                cfg.num_banks() * 4,
                3,
            )),
        ),
    ];
    let reports: Vec<_> = s
        .run_batch(&jobs)
        .into_iter()
        .map(|r| r.expect("batch job runs"))
        .collect();
    assert!(reports[2].dma_bytes.is_some(), "db job must report HBML traffic");
    assert!(matches!(reports[0].verdict, Verdict::Passed { .. }), "{:?}", reports[0].verdict);

    let text = reports_to_json(&reports);
    let parsed = reports_from_json(&text).expect("document parses");
    assert_eq!(parsed, reports, "emit → parse must preserve every field");

    // And the session accumulated the same reports for --json.
    assert_eq!(s.reports(), reports);
}

#[test]
fn malformed_report_documents_are_rejected() {
    assert!(reports_from_json("{}").is_err());
    assert!(reports_from_json("{\"schema\": \"other\", \"reports\": []}").is_err());
    assert!(reports_from_json("not json").is_err());
}

// ------------------------------------------------------------------
// Batch vs sequential bit-identity
// ------------------------------------------------------------------

/// A mixed batch over two Table-6 configs: local-access, global-access,
/// reduction, and DMA-carrying double-buffered jobs.
fn mixed_jobs() -> Vec<Job> {
    let a = ClusterConfig::tiny();
    let b = ClusterConfig::mempool();
    vec![
        Job::new(a.clone(), Box::new(axpy::Axpy::with(axpy::AxpyParams { n: a.num_banks() * 4, alpha: 2.0 }))),
        Job::new(b.clone(), Box::new(axpy::Axpy::with(axpy::AxpyParams { n: b.num_banks() * 4, alpha: 2.0 }))),
        Job::new(a.clone(), Box::new(gemm::Gemm::with(gemm::GemmParams { m: 16, n: 16, k: 16 }))),
        Job::new(b.clone(), Box::new(dotp::Dotp::with(dotp::DotpParams { n: b.num_banks() * 4 }))),
        Job::new(
            a.clone(),
            Box::new(double_buffer::Db::with(double_buffer::DbKernel::Axpy, a.num_banks() * 4, 3)),
        ),
        Job::new(
            b.clone(),
            Box::new(double_buffer::Db::with(double_buffer::DbKernel::Gemm, b.num_banks() * 4, 3)),
        ),
    ]
}

#[test]
fn batch_is_bit_identical_to_sequential_at_any_thread_count() {
    let run_at = |threads: usize| {
        let s = Session::new(ClusterConfig::tiny()).scale(Scale::Fast).threads(threads).check(true);
        s.run_batch(&mixed_jobs())
            .into_iter()
            .map(|r| r.expect("batch job runs"))
            .collect::<Vec<_>>()
    };
    let sequential = run_at(1);
    assert_eq!(sequential.len(), 6);
    for &threads in &[2usize, 4, 8] {
        let batched = run_at(threads);
        // RunReport: PartialEq covers identity, fingerprint, the full
        // RunStats, dma_bytes and the verdict — bit equality, no
        // tolerances.
        assert_eq!(sequential, batched, "batch diverges at {threads} host threads");
    }
}

#[test]
fn batch_reports_arrive_in_job_order() {
    let s = Session::new(ClusterConfig::tiny()).scale(Scale::Fast).threads(4);
    let jobs = mixed_jobs();
    let want_kinds: Vec<&str> = jobs.iter().map(|j| j.workload.kind()).collect();
    let got: Vec<String> = s
        .run_batch(&jobs)
        .into_iter()
        .map(|r| r.expect("batch job runs").kind)
        .collect();
    assert_eq!(got, want_kinds);
}

// ------------------------------------------------------------------
// Per-job config deltas
// ------------------------------------------------------------------

/// `Job::tweak` sweeps single knobs off a shared base config: the
/// tweaked job must be bit-identical to a clone-and-edit job, the
/// report fingerprint must follow the *effective* config, and deltas
/// must compose in registration order.
#[test]
fn job_tweaks_match_clone_and_edit_and_refingerprint() {
    let base = ClusterConfig::tiny();
    let mut edited = base.clone();
    edited.tx_table_entries = 2;
    let w = || -> Box<dyn kernels::Workload> {
        Box::new(axpy::Axpy::with(axpy::AxpyParams { n: base.num_banks() * 4, alpha: 2.0 }))
    };

    let s = Session::new(base.clone()).scale(Scale::Fast).check(true);
    let jobs = vec![
        Job::new(base.clone(), w()),
        Job::new(base.clone(), w()).tweak(|c| c.tx_table_entries = 2),
        Job::new(edited.clone(), w()),
        // Deltas compose in registration order: the second overrides.
        Job::new(base.clone(), w())
            .tweak(|c| c.tx_table_entries = 7)
            .tweak(|c| c.tx_table_entries = 2),
    ];
    assert_eq!(jobs[1].effective_cfg().tx_table_entries, 2);
    assert_eq!(jobs[3].effective_cfg().tx_table_entries, 2);

    let rs: Vec<_> = s.run_batch(&jobs).into_iter().map(|r| r.expect("job runs")).collect();
    assert_eq!(rs[0].fingerprint, base.fingerprint());
    assert_eq!(rs[1].fingerprint, edited.fingerprint(), "fingerprint must follow the delta");
    assert_ne!(rs[0].fingerprint, rs[1].fingerprint, "a 2-entry tx table is a different config");
    assert_eq!(rs[1], rs[2], "tweak must equal clone-and-edit bit for bit");
    assert_eq!(rs[1].stats, rs[3].stats, "composed deltas must land on the same config");
    // Shrinking the transaction table must actually change timing
    // (more LSU stalls → different cycle count), proving the delta
    // reached the simulated cluster.
    assert_ne!(rs[0].stats.cycles, rs[1].stats.cycles);
}

// ------------------------------------------------------------------
// Typed timeouts
// ------------------------------------------------------------------

#[test]
fn max_cycles_is_surfaced_not_compared() {
    let cfg = ClusterConfig::tiny();
    let s = Session::new(cfg.clone()).scale(Scale::Fast).max_cycles(50).check(true);
    // Single run: typed error.
    let e = s.run_named("gemm").unwrap_err();
    assert_eq!(e.kind(), ErrorKind::MaxCyclesExceeded);
    // Batch: the timed-out job errs, healthy jobs still report.
    let jobs = vec![
        Job::new(cfg.clone(), kernels::lookup("gemm").unwrap()),
        Job::new(cfg.clone(), kernels::lookup("axpy").unwrap()),
    ];
    let quick = Session::new(cfg).scale(Scale::Fast).max_cycles(50);
    let rs = quick.run_batch(&jobs);
    assert_eq!(rs[0].as_ref().unwrap_err().kind(), ErrorKind::MaxCyclesExceeded);
    // (axpy at 50 cycles also cannot finish — both must be typed, and
    // nothing may land in the report log.)
    assert_eq!(rs[1].as_ref().unwrap_err().kind(), ErrorKind::MaxCyclesExceeded);
    assert!(quick.reports().is_empty());
}

/// One job hitting `max_cycles` mid-batch must not poison its
/// siblings: they finish, verify, and report **bit-identically** to
/// running them alone, and only the successes land in the session's
/// report log (in job order). The budget is probed at runtime so the
/// test pins behaviour, not magic cycle counts.
#[test]
fn batch_failure_is_isolated_to_the_failing_job() {
    let cfg = ClusterConfig::tiny();
    let fast = || {
        Job::new(
            cfg.clone(),
            Box::new(axpy::Axpy::with(axpy::AxpyParams { n: cfg.num_banks() * 4, alpha: 2.0 })),
        )
    };
    let slow = || {
        Job::new(cfg.clone(), Box::new(gemm::Gemm::with(gemm::GemmParams { m: 16, n: 16, k: 64 })))
    };

    // Probe both run lengths under a generous budget, then pick one
    // strictly between them so exactly the gemm job times out.
    let probe = Session::new(cfg.clone()).scale(Scale::Fast).check(true);
    let solo: Vec<_> = probe
        .run_batch(&[fast(), slow()])
        .into_iter()
        .map(|r| r.expect("probe job runs"))
        .collect();
    let (fast_cycles, slow_cycles) = (solo[0].stats.cycles, solo[1].stats.cycles);
    assert!(slow_cycles > fast_cycles + 2, "probe separation: {fast_cycles} vs {slow_cycles}");
    let budget = fast_cycles + (slow_cycles - fast_cycles) / 2;

    let s = Session::new(cfg.clone()).scale(Scale::Fast).threads(2).max_cycles(budget).check(true);
    let rs = s.run_batch(&[fast(), slow(), fast()]);
    assert_eq!(rs.len(), 3);
    // The slow job surfaces a typed timeout...
    assert_eq!(rs[1].as_ref().unwrap_err().kind(), ErrorKind::MaxCyclesExceeded);
    // ...while both siblings match their solo runs bit for bit
    // (`max_cycles` is recorded in the report, so compare the
    // simulation-derived fields, not the whole document).
    for i in [0usize, 2] {
        let r = rs[i].as_ref().expect("sibling jobs must still run");
        assert_eq!(r.stats, solo[0].stats, "sibling {i} diverged from its solo run");
        assert_eq!(r.verdict, solo[0].verdict);
        assert_eq!(r.fingerprint, solo[0].fingerprint);
    }
    // Only the successes land in the report log, in job order.
    let logged: Vec<String> = s.reports().iter().map(|r| r.kind.clone()).collect();
    assert_eq!(logged, ["axpy", "axpy"]);
}
