//! Property tests over randomized configurations — the offline stand-in
//! for `proptest` (same policy as `rng` replacing rand): a deterministic
//! `forall` driver that reports the failing case and seed so a failure
//! reproduces exactly.
//!
//! Covered contracts:
//!
//! * **hybrid address map is a bijection** (memory.rs, Sec. 5.4): for
//!   randomized bank/tile/region shapes, `map` is injective over the
//!   full L1 range, onto the bank×row space, and `unmap` inverts it;
//! * **AMAT monotonicity** (amat.rs, Sec. 3.1): latency never decreases
//!   with radix-induced hop count — per-level zero-load latencies grow
//!   strictly with hierarchy distance, contention models are monotone in
//!   injection rate and port sharing, and measured burst latencies are
//!   bounded below by their zero-load floor.

use terapool::amat::{
    expected_latency_n_to_1, expected_latency_n_to_k, HierSpec,
};
use terapool::config::{ClusterConfig, Hierarchy};
use terapool::memory::AddressMap;
use terapool::rng::Rng;

/// Run `prop` over `cases` generated inputs; panic with the case index,
/// seed and input debug on the first violation.
fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    generate: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::seed_from_u64(seed);
    for case in 0..cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x})\n  \
                 input: {input:?}\n  violation: {msg}"
            );
        }
    }
}

fn pick<T: Copy>(rng: &mut Rng, options: &[T]) -> T {
    options[rng.gen_range(options.len())]
}

/// Random but valid cluster shape: hierarchy, banking factor, bank depth
/// and sequential-region size all vary; the seed keeps it reproducible.
fn random_cfg(rng: &mut Rng) -> ClusterConfig {
    let mut cfg = ClusterConfig::tiny();
    cfg.hierarchy = Hierarchy {
        pes_per_tile: pick(rng, &[2, 4, 8]),
        tiles_per_subgroup: pick(rng, &[1, 2, 4]),
        subgroups_per_group: pick(rng, &[1, 2, 4]),
        groups: pick(rng, &[1, 2, 4]),
    };
    cfg.banking_factor = pick(rng, &[2, 4]);
    cfg.words_per_bank = pick(rng, &[64, 128, 256]);
    // Sequential region: whole bank rows per Tile, leaving most rows to
    // the interleaved region (the AddressMap constructor's invariants).
    let rows = 1 + rng.gen_range(8);
    cfg.seq_words_per_tile = rows * cfg.banks_per_tile();
    cfg.name = format!(
        "prop-{}c-{}t-{}sg-{}g-bf{}-wpb{}-seq{}",
        cfg.hierarchy.pes_per_tile,
        cfg.hierarchy.tiles_per_subgroup,
        cfg.hierarchy.subgroups_per_group,
        cfg.hierarchy.groups,
        cfg.banking_factor,
        cfg.words_per_bank,
        cfg.seq_words_per_tile,
    );
    cfg
}

#[test]
fn address_map_is_a_bijection_for_random_shapes() {
    forall(
        "hybrid map bijection",
        24,
        0xB17_5EED,
        |rng| random_cfg(rng),
        |cfg| {
            let m = AddressMap::new(cfg);
            let words = cfg.l1_words();
            let mut seen = vec![false; words];
            for w in 0..words as u32 {
                let at = m.map(w);
                if at.bank as usize >= cfg.num_banks() || at.row as usize >= cfg.words_per_bank
                {
                    return Err(format!("{}: word {w} maps out of range {at:?}", cfg.name));
                }
                let flat = at.bank as usize * cfg.words_per_bank + at.row as usize;
                if seen[flat] {
                    return Err(format!("{}: collision at word {w} -> {at:?}", cfg.name));
                }
                seen[flat] = true;
                let back = m.unmap(at);
                if back != w {
                    return Err(format!(
                        "{}: round-trip broke: {w} -> {at:?} -> {back}",
                        cfg.name
                    ));
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err(format!("{}: map is not onto", cfg.name));
            }
            Ok(())
        },
    );
}

/// Realistic hierarchy shapes for the AMAT model (the γ>1, δ=1 corner is
/// not a paper configuration and the 3-level bookkeeping excludes it).
fn random_spec(rng: &mut Rng) -> HierSpec {
    let alpha = pick(rng, &[2, 4, 8, 16]);
    let beta = pick(rng, &[2, 4, 8]);
    let (gamma, delta) = pick(rng, &[(1, 1), (1, 2), (1, 4), (2, 2), (2, 4), (4, 4)]);
    HierSpec::new(alpha, beta, gamma, delta)
}

#[test]
fn level_latency_grows_with_hop_count() {
    forall(
        "zero-load latency strictly increases per hierarchy level",
        32,
        0xA3A7,
        |rng| random_spec(rng),
        |spec| {
            for level in 0..3 {
                let (lo, hi) = (spec.level_latency(level), spec.level_latency(level + 1));
                if hi <= lo {
                    return Err(format!(
                        "{}: level {} latency {hi} <= level {} latency {lo}",
                        spec.name(),
                        level + 1,
                        level
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn arbiter_contention_is_monotone_in_injection_rate_and_fanin() {
    forall(
        "E[n->1] monotone in p and n",
        200,
        0xC0DE,
        |rng| {
            let n = 2 + rng.gen_range(63);
            let p_lo = rng.f64() * 0.98 + 0.01;
            let p_hi = p_lo + rng.f64() * (1.0 - p_lo);
            (n, p_lo, p_hi)
        },
        |&(n, p_lo, p_hi)| {
            let (e_lo, e_hi) = (
                expected_latency_n_to_1(n, p_lo),
                expected_latency_n_to_1(n, p_hi),
            );
            if e_hi + 1e-9 < e_lo {
                return Err(format!("p: E({n},{p_hi:.4})={e_hi} < E({n},{p_lo:.4})={e_lo}"));
            }
            let e_more = expected_latency_n_to_1(n + 8, p_lo);
            if e_more + 1e-9 < e_lo {
                return Err(format!("n: E({},{p_lo:.4})={e_more} < E({n},..)={e_lo}", n + 8));
            }
            Ok(())
        },
    );
}

#[test]
fn wider_arbiters_never_increase_expected_latency() {
    forall(
        "E[n->k] non-increasing in k",
        200,
        0xFA57,
        |rng| {
            let n = 2 + rng.gen_range(31);
            let k = 1 << rng.gen_range(5); // 1..16
            let p = rng.f64() * 0.99 + 0.01;
            (n, k, p)
        },
        |&(n, k, p)| {
            let narrow = expected_latency_n_to_k(n, k, p);
            let wide = expected_latency_n_to_k(n, k * 2, p);
            if wide > narrow + 1e-9 {
                return Err(format!(
                    "E({n}->{},{p:.4})={wide} > E({n}->{k},{p:.4})={narrow}",
                    k * 2
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn analytic_amat_never_beats_zero_load() {
    forall(
        "AMAT >= zero-load latency",
        32,
        0x1234_5678,
        |rng| random_spec(rng),
        |spec| {
            let (amat, zl) = (spec.analytic_amat(), spec.zero_load_latency());
            if amat + 1e-9 < zl {
                return Err(format!(
                    "{}: analytic AMAT {amat:.4} < zero-load {zl:.4}",
                    spec.name()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn burst_latencies_are_floored_by_their_hop_count() {
    forall(
        "per-level burst mean >= per-level zero-load",
        12,
        0xB0B5,
        |rng| (random_spec(rng), rng.next_u64()),
        |(spec, seed)| {
            let r = terapool::amat::burst_amat(spec, *seed);
            if r.amat < 1.0 - 1e-9 {
                return Err(format!("{}: AMAT {} < 1", spec.name(), r.amat));
            }
            for level in 0..spec.levels() {
                let mean = r.amat_per_level[level];
                if mean == 0.0 {
                    continue; // no request drew this level in the burst
                }
                let floor = spec.level_latency(level) as f64;
                if mean + 1e-9 < floor {
                    return Err(format!(
                        "{}: level {level} mean {mean:.3} < zero-load {floor}",
                        spec.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Fig. 8b's qualitative shape on the paper's own four-level rows:
/// measured per-level latency is ordered by hop count.
#[test]
fn burst_per_level_latency_ordered_on_table4_four_level_rows() {
    for spec in [
        HierSpec::new(4, 16, 4, 4),
        HierSpec::new(8, 8, 4, 4),
        HierSpec::new(16, 4, 4, 4),
    ] {
        let r = terapool::amat::amat(&spec, 4);
        for level in 0..3 {
            assert!(
                r.amat_per_level[level] <= r.amat_per_level[level + 1] + 1e-9,
                "{}: level {} mean {:.3} > level {} mean {:.3}",
                spec.name(),
                level,
                r.amat_per_level[level],
                level + 1,
                r.amat_per_level[level + 1]
            );
        }
    }
}
