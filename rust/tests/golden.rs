//! Integration: the cluster simulator's functional results vs (a) the
//! pure-Rust `reference()` oracles — always available, no toolchain
//! needed — and (b) the **build-time JAX-evaluated goldens**
//! (`artifacts/<name>.golden.bin`, produced by `make artifacts`), the
//! cross-layer correctness contract of the whole stack.
//!
//! Artifact handling: `require_artifacts!` opens the golden runtime or
//! *skips* the test with an actionable message naming `make artifacts`.
//! Set `TERAPOOL_REQUIRE_ARTIFACTS=1` (as CI does after generating them)
//! to turn that skip into a hard failure, so golden coverage can never
//! silently evaporate where the Python toolchain exists.

use terapool::config::ClusterConfig;
use terapool::kernels::{axpy, dotp, fft, gemm, spmmadd};
use terapool::runtime::{assert_allclose, max_abs_diff, Runtime};

/// Open the golden [`Runtime`] or skip the calling test (see module
/// docs). Fails instead of skipping when TERAPOOL_REQUIRE_ARTIFACTS is
/// set.
macro_rules! require_artifacts {
    () => {
        match Runtime::with_default_dir() {
            Ok(rt) => rt,
            Err(e) => {
                assert!(
                    std::env::var_os("TERAPOOL_REQUIRE_ARTIFACTS").is_none(),
                    "golden artifacts required but unavailable: {e}\n\
                     generate them with `make artifacts` \
                     (python/compile/aot.py needs jax + numpy)"
                );
                eprintln!(
                    "SKIP {}: {e}\n     run `make artifacts` to enable the golden layer",
                    module_path!()
                );
                return;
            }
        }
    };
}

/// Small cluster for fast functional runs; numerics are identical to the
/// 1024-PE machine (same traces, same arithmetic).
fn cfg() -> ClusterConfig {
    ClusterConfig::tiny()
}

/// Host threads for the full-size golden runs (debug-mode wall clock is
/// the constraint; determinism is engine-independent).
fn threads() -> usize {
    terapool::parallel::default_threads()
}

// ------------------------------------------------------------------
// Non-PJRT fallbacks: simulator vs pure-Rust references. These run
// everywhere, Python toolchain or not.
// ------------------------------------------------------------------

#[test]
fn axpy_cluster_matches_host_reference() {
    let cfg = cfg();
    let p = axpy::AxpyParams { n: cfg.num_banks() * 8, alpha: 2.0 };
    let (mut cl, io) = axpy::build(&cfg, &p).into_cluster(cfg.clone());
    cl.run(10_000_000);
    assert_allclose(&io.read_output(&cl).unwrap(), &axpy::reference(&p), 1e-6, "axpy vs host ref");
}

#[test]
fn dotp_cluster_matches_host_reference() {
    let cfg = cfg();
    let p = dotp::DotpParams { n: cfg.num_banks() * 8 };
    let (mut cl, io) = dotp::build(&cfg, &p).into_cluster(cfg.clone());
    cl.run(10_000_000);
    let (got, want) = (io.read_output(&cl).unwrap()[0], dotp::reference(&p));
    let tol = want.abs().max(1.0) * 2e-4; // reduction-order differences
    assert!((got - want).abs() < tol, "dotp {got} vs host ref {want}");
}

#[test]
fn gemm_cluster_matches_host_reference() {
    let p = gemm::GemmParams { m: 64, n: 64, k: 64 };
    let setup = gemm::build(&cfg(), &p);
    let want = gemm::reference(&p);
    let (mut cl, io) = setup.into_cluster(cfg());
    cl.run(500_000_000);
    assert_allclose(&io.read_output(&cl).unwrap(), &want, 1e-2, "gemm 64^3 vs host ref");
}

#[test]
fn fft_cluster_matches_host_reference() {
    let p = fft::FftParams { batch: 4, n: 256 };
    let setup = fft::build(&cfg(), &p);
    let im_off = fft::im_plane_offset(&cfg(), &p);
    let (want_re, want_im) = fft::reference(&p);
    let (mut cl, io) = setup.into_cluster(cfg());
    cl.run(500_000_000);
    let got_re = io.read_output(&cl).unwrap();
    let got_im = cl.l1.read_slice(io.output_base + im_off, p.batch * p.n);
    assert!(max_abs_diff(&got_re, &want_re) < 5e-2);
    assert!(max_abs_diff(&got_im, &want_im) < 5e-2);
}

#[test]
fn spmmadd_cluster_matches_dense_add_oracle() {
    let p = spmmadd::SpmmaddParams { rows: 256, cols: 256, nnz_per_row: 6, seed: 42 };
    let (setup, layout) = spmmadd::build_with_layout(&cfg(), &p);
    let (mut cl, _) = setup.into_cluster(cfg());
    cl.run(500_000_000);
    // Densify the simulated CSR output and compare to A_dense + B_dense.
    let vals = cl.l1.read_slice(layout.c_val_base, layout.c_ref.nnz());
    let cols = cl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
    let mut dense = vec![0.0f32; p.rows * p.cols];
    for r in 0..p.rows {
        for i in layout.c_ref.row_ptr[r] as usize..layout.c_ref.row_ptr[r + 1] as usize {
            dense[r * p.cols + cols[i] as usize] += vals[i];
        }
    }
    let mut want = layout.a.to_dense();
    for (w, b) in want.iter_mut().zip(layout.b.to_dense()) {
        *w += b;
    }
    assert_allclose(&dense, &want, 1e-5, "spmmadd densified vs dense add");
}

// ------------------------------------------------------------------
// Golden layer: vs the JAX-evaluated artifacts.
// ------------------------------------------------------------------

#[test]
fn manifest_lists_all_kernels_with_shapes() {
    let rt = require_artifacts!();
    for k in ["gemm", "axpy", "dotp", "fft", "spmmadd"] {
        assert!(rt.names().contains(&k), "missing {k}");
    }
    let gemm = rt.entry("gemm").unwrap();
    assert_eq!(gemm.inputs.len(), 2);
    assert_eq!(gemm.inputs[0].shape, vec![256, 256]);
    assert!(!gemm.sha256.is_empty());
    // Every entry carries an evaluated golden (spmmadd's CSR inputs come
    // from the SplitMix64 generator ported to python/compile/rng.py).
    for k in ["gemm", "axpy", "dotp", "fft", "spmmadd"] {
        assert!(rt.entry(k).unwrap().golden.is_some(), "{k} has no golden");
    }
}

/// The Rust host references and the JAX oracles are independent code
/// paths computing the same specification; pinning them to each other
/// transitively extends every sim-vs-reference test above into a
/// sim-vs-JAX test, without re-running the big problems on the
/// simulator in debug mode.
#[test]
fn host_references_match_jax_goldens() {
    let rt = require_artifacts!();

    let n = rt.entry("axpy").unwrap().inputs[1].shape[0];
    let golden = rt.golden_f32("axpy").unwrap();
    assert_allclose(
        &axpy::reference(&axpy::AxpyParams { n, alpha: 2.0 }),
        &golden,
        1e-6,
        "axpy host ref vs JAX golden",
    );

    let n = rt.entry("dotp").unwrap().inputs[0].shape[0];
    let golden = rt.golden_f32("dotp").unwrap();
    let want = dotp::reference(&dotp::DotpParams { n });
    let tol = want.abs().max(1.0) * 2e-4;
    assert!(
        (golden[0] - want).abs() < tol,
        "dotp: JAX golden {} vs host ref {want}",
        golden[0]
    );

    let shape = rt.entry("gemm").unwrap().inputs[0].shape.clone();
    let p = gemm::GemmParams { m: shape[0], n: shape[1], k: shape[0] };
    let golden = rt.golden_f32("gemm").unwrap();
    assert_allclose(&gemm::reference(&p), &golden, 1e-2, "gemm host ref vs JAX golden");
}

/// The spmmadd golden was evaluated on CSR inputs regenerated by the
/// *Python* port of the SplitMix64 generator; rebuilding the same
/// matrices from the *Rust* generator and densifying must reproduce it
/// exactly (all values are multiples of 0.25 with at most two addends
/// per cell — no rounding anywhere). This is the cross-language closure
/// of the CSR workload: rng port ↔ CSR generator ↔ dense-sum oracle.
#[test]
fn spmmadd_golden_matches_rust_csr_dense_sum() {
    let rt = require_artifacts!();
    let shape = rt.entry("spmmadd").unwrap().inputs[0].shape.clone();
    let (rows, cols) = (shape[0], shape[1]);
    let golden = rt.golden_f32("spmmadd").unwrap();
    assert_eq!(golden.len(), rows * cols, "dense sum shape");
    let want = spmmadd::canonical_dense_sum(rows, cols);
    assert_eq!(golden, want, "spmmadd golden vs Rust-generated CSR dense sum");
}

/// End-to-end at golden scale: the cluster executes the canonical
/// 512×512 SpMMadd (CSR in, CSR out), the densified result must match
/// the JAX-evaluated golden. mempool's 1 MiB L1 holds the working set;
/// tiny's 128 KiB does not.
#[test]
fn spmmadd_cluster_matches_jax_golden_end_to_end() {
    let rt = require_artifacts!();
    let shape = rt.entry("spmmadd").unwrap().inputs[0].shape.clone();
    let (rows, cols) = (shape[0], shape[1]);
    let golden = rt.golden_f32("spmmadd").unwrap();
    let cfg = ClusterConfig::mempool();
    let p = spmmadd::SpmmaddParams {
        rows,
        cols,
        nnz_per_row: spmmadd::CANONICAL_NNZ_PER_ROW,
        seed: spmmadd::CANONICAL_SEED,
    };
    let (setup, layout) = spmmadd::build_with_layout(&cfg, &p);
    let (mut cl, _) = setup.into_cluster(cfg);
    cl.run_parallel(500_000_000, threads());
    let vals = cl.l1.read_slice(layout.c_val_base, layout.c_ref.nnz());
    let cols_got = cl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
    let mut dense = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for i in layout.c_ref.row_ptr[r] as usize..layout.c_ref.row_ptr[r + 1] as usize {
            dense[r * cols + cols_got[i] as usize] += vals[i];
        }
    }
    assert_allclose(&dense, &golden, 1e-6, "spmmadd cluster vs JAX golden");
}

/// FFT golden layout is re || im, checked against a single-row naive DFT
/// (the full 64×4096² host DFT is too slow for debug test runs).
#[test]
fn fft_golden_matches_naive_dft_on_first_row() {
    let rt = require_artifacts!();
    let shape = rt.entry("fft").unwrap().inputs[0].shape.clone();
    let (batch, n) = (shape[0], shape[1]);
    let golden = rt.golden_f32("fft").unwrap();
    assert_eq!(golden.len(), 2 * batch * n, "re plane then im plane");

    let p = fft::FftParams { batch, n };
    let re = fft::input_re(&p);
    let im = fft::input_im(&p);
    for k in (0..n).step_by(509) {
        let (mut sr, mut si) = (0.0f64, 0.0f64);
        for t in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (k * t % n) as f64 / n as f64;
            let (c, s) = (ang.cos(), ang.sin());
            let (xr, xi) = (re[t] as f64, im[t] as f64);
            sr += xr * c - xi * s;
            si += xr * s + xi * c;
        }
        assert!(
            (golden[k] as f64 - sr).abs() < 1e-1 * sr.abs().max(100.0),
            "fft golden re[{k}] = {} vs naive {sr}",
            golden[k]
        );
        assert!(
            (golden[batch * n + k] as f64 - si).abs() < 1e-1 * si.abs().max(100.0),
            "fft golden im[{k}] = {} vs naive {si}",
            golden[batch * n + k]
        );
    }
}

/// One full end-to-end run at artifact scale: the 1024-PE cluster's AXPY
/// memory image vs the JAX golden, on the tile-parallel engine (which
/// also exercises run_parallel on the full machine).
#[test]
fn axpy_cluster_matches_jax_golden_end_to_end() {
    let rt = require_artifacts!();
    let n = rt.entry("axpy").unwrap().inputs[1].shape[0];
    let full = ClusterConfig::terapool(9);
    let p = axpy::AxpyParams { n, alpha: 2.0 };
    let (mut cl, io) = axpy::build(&full, &p).into_cluster(full);
    cl.run_parallel(500_000_000, threads());
    let golden = rt.golden_f32("axpy").unwrap();
    assert_allclose(&io.read_output(&cl).unwrap(), &golden, 1e-5, "axpy cluster vs JAX golden");
}

#[test]
fn dotp_cluster_matches_jax_golden_end_to_end() {
    let rt = require_artifacts!();
    let n = rt.entry("dotp").unwrap().inputs[0].shape[0];
    let full = ClusterConfig::terapool(9);
    let p = dotp::DotpParams { n };
    let (mut cl, io) = dotp::build(&full, &p).into_cluster(full);
    cl.run_parallel(500_000_000, threads());
    let golden = rt.golden_f32("dotp").unwrap();
    let (got, want) = (io.read_output(&cl).unwrap()[0], golden[0]);
    let tol = want.abs().max(1.0) * 2e-4;
    assert!((got - want).abs() < tol, "dotp {got} vs JAX golden {want}");
}
