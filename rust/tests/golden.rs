//! Integration: the cluster simulator's functional results vs the
//! AOT-compiled JAX/Pallas artifacts executed through PJRT — the
//! cross-layer correctness contract of the whole stack.
//!
//! Requires `make artifacts` (skipped gracefully if absent would hide
//! regressions, so these tests *fail* without artifacts).

use terapool::config::ClusterConfig;
use terapool::kernels::{axpy, dotp, fft, gemm, spmmadd};
use terapool::runtime::{assert_allclose, max_abs_diff, Runtime};

/// Small cluster for fast functional runs; numerics are identical to the
/// 1024-PE machine (same traces, same arithmetic).
fn cfg() -> ClusterConfig {
    ClusterConfig::tiny()
}

#[test]
fn axpy_cluster_matches_xla_artifact() {
    let mut rt = Runtime::with_default_dir().expect("run `make artifacts` first");
    let n = rt.entry("axpy").unwrap().inputs[1].shape[0];
    // The artifact-shaped problem (3 × 256 Ki words) needs the full
    // 4 MiB machine.
    let full = ClusterConfig::terapool(9);
    let p = axpy::AxpyParams { n, alpha: 2.0 };
    let setup = axpy::build(&full, &p);
    let (mut cl, io) = setup.into_cluster(full);
    cl.run(500_000_000);
    let golden = rt
        .execute_f32("axpy", &[vec![p.alpha], axpy::input_x(n), axpy::input_y(n)])
        .unwrap();
    assert_allclose(&io.read_output(&cl), &golden[0], 1e-5, "axpy");
}

#[test]
fn dotp_cluster_matches_xla_artifact() {
    let mut rt = Runtime::with_default_dir().expect("run `make artifacts` first");
    let n = rt.entry("dotp").unwrap().inputs[0].shape[0];
    let full = ClusterConfig::terapool(9);
    let p = dotp::DotpParams { n };
    let setup = dotp::build(&full, &p);
    let (mut cl, io) = setup.into_cluster(full);
    cl.run(500_000_000);
    let golden = rt
        .execute_f32("dotp", &[dotp::input_x(n), dotp::input_y(n)])
        .unwrap();
    let (got, want) = (io.read_output(&cl)[0], golden[0][0]);
    let tol = want.abs().max(1.0) * 2e-4; // reduction-order differences
    assert!((got - want).abs() < tol, "dotp {got} vs XLA {want}");
}

#[test]
fn gemm_cluster_matches_xla_artifact_subsampled() {
    // Full 256³ on the tiny cluster takes a while in debug; run a 64³
    // sub-problem against a host reference AND spot-check the artifact
    // semantics at its native shape via the runtime test-suite.
    let p = gemm::GemmParams { m: 64, n: 64, k: 64 };
    let setup = gemm::build(&cfg(), &p);
    let want = gemm::reference(&p);
    let (mut cl, io) = setup.into_cluster(cfg());
    cl.run(500_000_000);
    assert_allclose(&io.read_output(&cl), &want, 1e-2, "gemm 64^3 vs host ref");
}

#[test]
fn fft_cluster_matches_xla_artifact_small() {
    // The artifact is 64×4096; the same trace generator at 4×256 is
    // checked against jnp.fft's independent path via the naive host DFT
    // (fft::reference), which python/tests pins to the Pallas kernel.
    let p = fft::FftParams { batch: 4, n: 256 };
    let setup = fft::build(&cfg(), &p);
    let im_off = fft::im_plane_offset(&cfg(), &p);
    let (want_re, want_im) = fft::reference(&p);
    let (mut cl, io) = setup.into_cluster(cfg());
    cl.run(500_000_000);
    let got_re = io.read_output(&cl);
    let got_im = cl.l1.read_slice(io.output_base + im_off, p.batch * p.n);
    assert!(max_abs_diff(&got_re, &want_re) < 5e-2);
    assert!(max_abs_diff(&got_im, &want_im) < 5e-2);
}

#[test]
fn spmmadd_cluster_matches_xla_artifact() {
    let mut rt = Runtime::with_default_dir().expect("run `make artifacts` first");
    let shape = rt.entry("spmmadd").unwrap().inputs[0].shape.clone();
    let p = spmmadd::SpmmaddParams {
        rows: shape[0],
        cols: shape[1],
        nnz_per_row: 6,
        seed: 42,
    };
    let (setup, layout) = spmmadd::build_with_layout(&cfg(), &p);
    let (mut cl, _) = setup.into_cluster(cfg());
    cl.run(500_000_000);
    // Densify the simulated CSR output and compare to the dense-add
    // artifact.
    let vals = cl.l1.read_slice(layout.c_val_base, layout.c_ref.nnz());
    let cols = cl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
    let mut dense = vec![0.0f32; p.rows * p.cols];
    for r in 0..p.rows {
        for i in layout.c_ref.row_ptr[r] as usize..layout.c_ref.row_ptr[r + 1] as usize {
            dense[r * p.cols + cols[i] as usize] += vals[i];
        }
    }
    let golden = rt
        .execute_f32("spmmadd", &[layout.a.to_dense(), layout.b.to_dense()])
        .unwrap();
    assert_allclose(&dense, &golden[0], 1e-5, "spmmadd densified");
}

#[test]
fn gemm_artifact_native_shape_matches_cluster_inputs() {
    // Execute the native 256×256 artifact once and spot-check elements
    // against the host reference — proves the artifact itself encodes the
    // same semantics the cluster traces compute.
    let mut rt = Runtime::with_default_dir().expect("run `make artifacts` first");
    let shape = rt.entry("gemm").unwrap().inputs[0].shape.clone();
    let p = gemm::GemmParams { m: shape[0], n: shape[1], k: shape[0] };
    let golden = rt
        .execute_f32("gemm", &[gemm::input_a(&p), gemm::input_b(&p)])
        .unwrap();
    let want = gemm::reference(&p);
    assert_allclose(&golden[0], &want, 1e-2, "gemm artifact vs host ref");
}
