//! Integration: cross-module behaviours that no unit test covers —
//! kernels × interconnect × barriers × DMA × stats on multi-cluster
//! configurations, plus failure injection.

use terapool::cluster::Cluster;
use terapool::config::{ClusterConfig, Scale};
use terapool::dma::{hbm_image_clear, hbm_image_stage, DmaDescriptor};
use terapool::isa::{Op, Program};
use terapool::kernels::axpy;
use terapool::session::Session;

#[test]
fn axpy_runs_on_all_three_table6_clusters() {
    for cfg in [
        ClusterConfig::tiny(),
        ClusterConfig::mempool(),
        ClusterConfig::occamy(),
    ] {
        let n = cfg.num_banks() * 8;
        let p = axpy::AxpyParams { n, alpha: 3.0 };
        let want = axpy::reference(&p);
        let (mut cl, io) = axpy::build(&cfg, &p).into_cluster(cfg.clone());
        let stats = cl.run(100_000_000);
        assert_eq!(io.read_output(&cl).unwrap(), want, "{}", cfg.name);
        assert!(stats.ipc() > 0.5, "{}: ipc {}", cfg.name, stats.ipc());
    }
}

#[test]
fn kernel_suite_runs_on_full_terapool_fast_scale() {
    let session = Session::new(ClusterConfig::terapool(9)).scale(Scale::Fast);
    for k in ["axpy", "dotp"] {
        let r = session.run_named(k).expect("registered kernel runs");
        assert!(r.stats.ipc() > 0.2, "{}: ipc {}", r.workload, r.stats.ipc());
        assert!(r.stats.instructions > 0);
    }
}

#[test]
fn parallel_engine_reproduces_serial_on_full_terapool_fast_scale() {
    let cfg = ClusterConfig::terapool(9);
    let serial = Session::new(cfg.clone()).scale(Scale::Fast);
    let threads = terapool::parallel::default_threads();
    let parallel = Session::new(cfg).scale(Scale::Fast).threads(threads);
    let s = serial.run_named("axpy").expect("serial run");
    let p = parallel.run_named("axpy").expect("parallel run");
    assert_eq!(s.stats, p.stats, "1024-PE axpy diverges at {threads} threads");
}

#[test]
fn spill_register_tradeoff_latency_vs_frequency() {
    // More spill registers (11-cycle remote) cost cycles but buy MHz —
    // wall-clock for a remote-heavy workload must stay within ~20 %.
    let session = Session::new(ClusterConfig::terapool(9)).scale(Scale::Fast);
    let mut res = Vec::new();
    for rg in [7u32, 11] {
        let cfg = ClusterConfig::terapool(rg);
        let s = session.run_on(&cfg, &axpy::Axpy::default()).expect("axpy run").stats;
        res.push((s.cycles, cfg.freq_mhz, s.cycles as f64 / cfg.freq_mhz));
    }
    let (c7, _, us7) = res[0];
    let (c11, _, us11) = res[1];
    assert!(c11 >= c7, "higher latency ⇒ not fewer cycles");
    assert!(us11 < us7 * 1.25, "frequency gain bounds the runtime loss");
}

#[test]
fn dma_failure_injection_unknown_descriptor_panics() {
    let cfg = ClusterConfig::tiny();
    let progs: Vec<Program> = (0..cfg.num_pes())
        .map(|i| {
            let mut p = Program::new();
            if i == 0 {
                p.push(Op::DmaStart { id: 7 }); // never registered
            }
            p.halt();
            p
        })
        .collect();
    let mut cl = Cluster::new(cfg, progs).with_dma();
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cl.run(10_000);
    }));
    assert!(r.is_err(), "starting an unregistered descriptor must panic");
}

#[test]
fn cluster_without_dma_rejects_dma_traces() {
    let cfg = ClusterConfig::tiny();
    let progs: Vec<Program> = (0..cfg.num_pes())
        .map(|i| {
            let mut p = Program::new();
            if i == 0 {
                p.push(Op::DmaStart { id: 0 });
            }
            p.halt();
            p
        })
        .collect();
    let mut cl = Cluster::new(cfg, progs);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cl.run(10_000);
    }));
    assert!(r.is_err());
}

#[test]
fn deadlock_detection_reports_unfinished_cluster() {
    // A barrier that not every PE reaches must trip the run() guard.
    let cfg = ClusterConfig::tiny();
    let progs: Vec<Program> = (0..cfg.num_pes())
        .map(|i| {
            let mut p = Program::new();
            if i != 0 {
                p.barrier(0); // PE 0 skips the barrier
            }
            p.halt();
            p
        })
        .collect();
    let mut cl = Cluster::new(cfg, progs);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cl.run(5_000);
    }));
    assert!(r.is_err(), "half-arrived barrier must be flagged as deadlock");
}

#[test]
fn dma_roundtrip_preserves_data_through_hbm_image() {
    hbm_image_clear();
    let cfg = ClusterConfig::tiny();
    let mut l1 = terapool::memory::L1Memory::new(&cfg);
    let mut dma = terapool::dma::DmaSubsystem::new(&cfg);
    let base = l1.map.interleaved_base();
    let data: Vec<f32> = (0..2048).map(|i| (i as f32).sin()).collect();
    hbm_image_stage(0, &data);
    let din = dma.register(DmaDescriptor { l1_word: base, mem_byte: 0, words: 2048, to_l1: true });
    let dout = dma.register(DmaDescriptor {
        l1_word: base,
        mem_byte: 0x100000,
        words: 2048,
        to_l1: false,
    });
    dma.start(din, 0);
    let mut now = 0;
    while !dma.is_done(din) {
        dma.step(now, &mut l1);
        now += 1;
    }
    dma.start(dout, now);
    while !dma.is_done(dout) {
        dma.step(now, &mut l1);
        now += 1;
    }
    assert_eq!(terapool::dma::hbm_image_fetch(0x100000, 2048), data);
}

#[test]
fn stats_fractions_are_consistent() {
    let s = Session::new(ClusterConfig::terapool(9))
        .scale(Scale::Fast)
        .run_named("axpy")
        .expect("axpy run")
        .stats;
    let total = s.fraction(s.instructions)
        + s.fraction(s.stall_lsu)
        + s.fraction(s.stall_raw)
        + s.fraction(s.stall_ctrl)
        + s.fraction(s.stall_synch);
    assert!(total <= 1.0 + 1e-9, "fractions sum {total}");
    assert!(total > 0.5, "fractions sum {total} suspiciously low");
}
