//! Bench (§Perf): raw simulator speed — simulated PE-cycles per host
//! second on the 1024-PE cluster. The EXPERIMENTS.md §Perf target is
//! ≥ 20 M PE-cycles/s so Fig. 14a regenerates in seconds.
//!
//! `cargo bench --bench simspeed`

#[path = "util.rs"]
mod util;

use terapool::cluster::Cluster;
use terapool::config::ClusterConfig;
use terapool::isa::Program;
use terapool::kernels::axpy::{build, AxpyParams};

fn main() {
    // Pure-compute traces: issue-loop ceiling (no memory traffic).
    let cfg = ClusterConfig::terapool(9);
    let r = util::bench("1024 PEs × 2k compute instrs", 5, || {
        let progs: Vec<Program> = (0..cfg.num_pes())
            .map(|_| {
                let mut p = Program::new();
                p.ld_imm(1, 1.0);
                p.ld_imm(2, 1.5);
                for _ in 0..2000 {
                    p.fmac(3, 1, 2);
                }
                p.halt();
                p
            })
            .collect();
        let mut cl = Cluster::new(cfg.clone(), progs);
        cl.run(1_000_000).cycles
    });
    util::report_rate("PE-cycles", 1024.0 * 2002.0 / 1e6, "M", r.median_ms);

    // Local-access memory traffic: AXPY (1 request per ~2 instrs).
    let r = util::bench("axpy 256Ki on 1024 PEs", 3, || {
        let p = AxpyParams { n: 256 * 1024, alpha: 2.0 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
        cl.run(100_000_000).cycles
    });
    let (mut cl, _) = build(&cfg, &AxpyParams { n: 256 * 1024, alpha: 2.0 })
        .into_cluster(cfg.clone());
    let cycles = cl.run(100_000_000).cycles;
    util::report_rate("PE-cycles", (cycles * 1024) as f64 / 1e6, "M", r.median_ms);
}
