//! Bench (§Perf): raw simulator speed — simulated PE-cycles per host
//! second on the 1024-PE cluster, serial engine vs the deterministic
//! fully sharded engine. The EXPERIMENTS.md §Perf targets: ≥ 20 M
//! PE-cycles/s serial on the compute trace so Fig. 14a regenerates in
//! seconds, ≥ 3× over serial at 8 threads on the compute trace, and
//! ≥ 2.5× over serial at 8 threads on the memory-bound AXPY row (hosts
//! with ≥ 8 cores). The AXPY rows are the acceptance bar for the sharded
//! pre-phase (owner-computes response delivery scales with the workers);
//! the double-buffer rows pressure what remains serial of the DMA path
//! (channel arbitration) against the worker-partitioned word movement.
//!
//! Besides the human-readable report, every run rewrites
//! `BENCH_simspeed.json` at the repository root (one row per
//! engine/thread-count configuration) so the perf trajectory is tracked
//! across PRs; CI uploads it as an advisory artifact and
//! `tools/bench_gate.py` compares it against the committed baseline.
//!
//! `cargo bench --bench simspeed`

#[path = "util.rs"]
mod util;

use terapool::cluster::Cluster;
use terapool::config::ClusterConfig;
use terapool::dma::hbm_image_clear;
use terapool::isa::Program;
use terapool::kernels::axpy::{build, AxpyParams};
use terapool::kernels::double_buffer::{self, DbKernel, DbParams};
use terapool::session::Session;

/// One benchmark configuration's outcome, destined for the JSON report.
struct Row {
    bench: &'static str,
    engine: String,
    threads: usize,
    median_ms: f64,
    mean_ms: f64,
    min_ms: f64,
    /// Simulated PE-cycles of one run, in millions.
    pe_mcycles: f64,
    /// Throughput: simulated PE-cycles per host second, in millions.
    mcycles_per_s: f64,
    /// Wall-clock speedup vs this bench's serial row (1.0 for serial).
    speedup_vs_serial: f64,
}

impl Row {
    fn new(bench: &'static str, threads: usize, r: &util::BenchResult, pe_mcycles: f64, serial_ms: f64) -> Self {
        Row {
            bench,
            engine: if threads <= 1 { "serial".into() } else { format!("sharded-{threads}") },
            threads,
            median_ms: r.median_ms,
            mean_ms: r.mean_ms,
            min_ms: r.min_ms,
            pe_mcycles,
            mcycles_per_s: pe_mcycles / (r.median_ms / 1e3),
            speedup_vs_serial: serial_ms / r.median_ms,
        }
    }
}

/// Hand-rolled JSON (the offline build has no serde): enough structure
/// for CI trend tooling — `{schema, host, rows: [...]}`.
fn write_json(rows: &[Row], host_cores: usize) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_simspeed.json");
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"terapool-simspeed-v1\",\n");
    s.push_str(&format!("  \"host_cores\": {host_cores},\n"));
    s.push_str("  \"unit\": \"simulated PE-Mcycles per host second\",\n");
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"bench\": \"{}\", \"engine\": \"{}\", \"threads\": {}, \
             \"median_ms\": {:.3}, \"mean_ms\": {:.3}, \"min_ms\": {:.3}, \
             \"pe_mcycles\": {:.3}, \"mcycles_per_s\": {:.2}, \
             \"speedup_vs_serial\": {:.3}}}{}\n",
            r.bench,
            r.engine,
            r.threads,
            r.median_ms,
            r.mean_ms,
            r.min_ms,
            r.pe_mcycles,
            r.mcycles_per_s,
            r.speedup_vs_serial,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nWARNING: could not write {path}: {e}"),
    }
}

fn compute_programs(cfg: &ClusterConfig) -> Vec<Program> {
    (0..cfg.num_pes())
        .map(|_| {
            let mut p = Program::new();
            p.ld_imm(1, 1.0);
            p.ld_imm(2, 1.5);
            for _ in 0..2000 {
                p.fmac(3, 1, 2);
            }
            p.halt();
            p
        })
        .collect()
}

fn main() {
    let cfg = ClusterConfig::terapool(9);
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut rows: Vec<Row> = Vec::new();

    // Pure-compute traces: issue-loop ceiling (no memory traffic). This
    // is the 1024-PE compute-trace benchmark of the acceptance criteria.
    let pe_mcycles = 1024.0 * 2002.0 / 1e6;
    let serial = util::bench("compute 1024 PEs × 2k instrs (serial)", 5, || {
        let mut cl = Cluster::new(cfg.clone(), compute_programs(&cfg));
        cl.run(1_000_000).cycles
    });
    util::report_rate("PE-cycles", pe_mcycles, "M", serial.median_ms);
    rows.push(Row::new("compute", 1, &serial, pe_mcycles, serial.median_ms));

    for threads in [2usize, 4, 8, 16] {
        let r = util::bench(
            &format!("compute 1024 PEs × 2k instrs ({threads} threads)"),
            5,
            || {
                let mut cl = Cluster::new(cfg.clone(), compute_programs(&cfg));
                cl.run_parallel(1_000_000, threads).cycles
            },
        );
        util::report_rate("PE-cycles", pe_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
        rows.push(Row::new("compute", threads, &r, pe_mcycles, serial.median_ms));
    }

    // Memory-bound traffic: AXPY (1 request per ~2 instrs). Bank
    // arbitration is sharded per destination Tile and — with the fully
    // sharded pre-phase — response delivery, barrier bookkeeping and the
    // transfer merge scale with the workers too; these rows are the
    // acceptance bar for the sharded pre-phase (not slower at any thread
    // count, faster at ≥ 8 threads on an ≥ 8-core host).
    let p = AxpyParams { n: 256 * 1024, alpha: 2.0 };
    let mut cycles = 0u64;
    let serial = util::bench("axpy 256Ki on 1024 PEs (serial)", 3, || {
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
        cycles = cl.run(100_000_000).cycles;
        cycles
    });
    let axpy_mcycles = (cycles * 1024) as f64 / 1e6;
    util::report_rate("PE-cycles", axpy_mcycles, "M", serial.median_ms);
    rows.push(Row::new("axpy-1024", 1, &serial, axpy_mcycles, serial.median_ms));

    for threads in [2usize, 4, 8, 16] {
        let r = util::bench(&format!("axpy 256Ki on 1024 PEs ({threads} threads)"), 3, || {
            let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
            cl.run_parallel(100_000_000, threads).cycles
        });
        util::report_rate("PE-cycles", axpy_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
        rows.push(Row::new("axpy-1024", threads, &r, axpy_mcycles, serial.median_ms));
    }

    // Double-buffered AXPY through the HBML: the longest pre-phase in
    // the engine (DMA control + channel arbitration + burst movement +
    // distributed barriers every round). The sharded engine partitions
    // the burst word movement and the waiter bookkeeping across the
    // workers; only channel arbitration stays serial.
    let dbp = DbParams { kernel: DbKernel::Axpy, chunk: cfg.num_banks() * 4, rounds: 3 };
    let mut db_cycles = 0u64;
    let serial = util::bench("db-axpy 16Ki×3 rounds on 1024 PEs (serial)", 3, || {
        hbm_image_clear();
        db_cycles = double_buffer::run(&cfg, &dbp).cycles;
        db_cycles
    });
    let db_mcycles = (db_cycles * 1024) as f64 / 1e6;
    util::report_rate("PE-cycles", db_mcycles, "M", serial.median_ms);
    rows.push(Row::new("db-axpy-1024", 1, &serial, db_mcycles, serial.median_ms));

    for threads in [2usize, 4, 8, 16] {
        let r = util::bench(
            &format!("db-axpy 16Ki×3 rounds on 1024 PEs ({threads} threads)"),
            3,
            || {
                hbm_image_clear();
                double_buffer::run_threads(&cfg, &dbp, threads).cycles
            },
        );
        util::report_rate("PE-cycles", db_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
        rows.push(Row::new("db-axpy-1024", threads, &r, db_mcycles, serial.median_ms));
    }

    // Idle-heavy barrier-ping trace: 200 bulk-synchronous phases with
    // nothing but the arrival atomic per phase, on a config whose
    // barrier wake-up broadcast is long — almost every simulated cycle
    // is fully quiescent (all PEs parked, one scheduled release event).
    // These are exactly the spans the engines' event-driven idle-cycle
    // fast-forward jumps in O(1); EXPERIMENTS.md §Perf sets ≥ 5× over
    // the unskipped engine on this trace.
    let mut idle_cfg = ClusterConfig::terapool(9);
    idle_cfg.barrier_wakeup = 128;
    let idle_programs = |cfg: &ClusterConfig| -> Vec<Program> {
        (0..cfg.num_pes())
            .map(|_| {
                let mut p = Program::new();
                for phase in 0..200u16 {
                    p.barrier(phase);
                }
                p.halt();
                p
            })
            .collect()
    };
    let mut idle_cycles = 0u64;
    let noskip = util::bench("idle-heavy 200 barriers on 1024 PEs (skip off)", 3, || {
        let mut cl = Cluster::new(idle_cfg.clone(), idle_programs(&idle_cfg));
        cl.fast_forward = false;
        idle_cycles = cl.run(10_000_000).cycles;
        idle_cycles
    });
    let idle_mcycles = (idle_cycles * 1024) as f64 / 1e6;
    util::report_rate("PE-cycles", idle_mcycles, "M", noskip.median_ms);
    rows.push(Row {
        engine: "serial-noskip".into(),
        ..Row::new("idle-heavy", 1, &noskip, idle_mcycles, noskip.median_ms)
    });

    let skip = util::bench("idle-heavy 200 barriers on 1024 PEs (skip on)", 3, || {
        let mut cl = Cluster::new(idle_cfg.clone(), idle_programs(&idle_cfg));
        let cycles = cl.run(10_000_000).cycles;
        assert_eq!(cycles, idle_cycles, "fast-forward must not change the cycle count");
        cycles
    });
    util::report_rate("PE-cycles", idle_mcycles, "M", skip.median_ms);
    println!(
        "  ↳ idle-skip speedup vs unskipped serial: {:.2}x (target ≥ 5x)",
        noskip.median_ms / skip.median_ms
    );
    rows.push(Row {
        engine: "serial".into(),
        ..Row::new("idle-heavy", 1, &skip, idle_mcycles, noskip.median_ms)
    });

    for (threads, ff, engine) in [(8usize, false, "sharded-8-noskip"), (8, true, "sharded-8")] {
        let r = util::bench(
            &format!(
                "idle-heavy 200 barriers on 1024 PEs ({threads} threads, skip {})",
                if ff { "on" } else { "off" }
            ),
            3,
            || {
                let mut cl = Cluster::new(idle_cfg.clone(), idle_programs(&idle_cfg));
                cl.fast_forward = ff;
                let cycles = cl.run_parallel(10_000_000, threads).cycles;
                assert_eq!(cycles, idle_cycles, "engines must agree on the idle-heavy trace");
                cycles
            },
        );
        util::report_rate("PE-cycles", idle_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs unskipped serial: {:.2}x",
            noskip.median_ms / r.median_ms
        );
        rows.push(Row {
            engine: engine.into(),
            ..Row::new("idle-heavy", threads, &r, idle_mcycles, noskip.median_ms)
        });
    }

    // Estimate-vs-exact: the calibrated analytic fast path against the
    // cycle-accurate engine on full-scale AXPY. The row's speedup column
    // is the wall-clock ratio; the printed accuracy is the |Δcycles|
    // relative error the estimate gate holds to ≤ 10% in CI.
    let exact_session = Session::new(cfg.clone());
    let mut exact_cycles = 0u64;
    let exact = util::bench("axpy full-scale (cycle-accurate)", 3, || {
        let r = exact_session.run_named("axpy").expect("exact axpy run");
        exact_cycles = r.stats.cycles;
        exact_cycles
    });
    let est_session = Session::new(cfg.clone()).estimating(true);
    let mut est_cycles = 0u64;
    let est = util::bench("axpy full-scale (estimate)", 3, || {
        let r = est_session.run_named("axpy").expect("estimate axpy run");
        est_cycles = r.stats.cycles;
        est_cycles
    });
    let err = (est_cycles as f64 - exact_cycles as f64).abs() / exact_cycles as f64;
    println!(
        "  ↳ estimate vs exact: {:.2}x wall-clock, cycles {est_cycles} vs {exact_cycles} \
         ({:.1}% error, gate ≤ 10%)",
        exact.median_ms / est.median_ms,
        err * 100.0
    );
    rows.push(Row {
        engine: "estimate".into(),
        ..Row::new(
            "estimate-axpy",
            1,
            &est,
            (est_cycles * 1024) as f64 / 1e6,
            exact.median_ms,
        )
    });

    write_json(&rows, host_cores);
}
