//! Bench (§Perf): raw simulator speed — simulated PE-cycles per host
//! second on the 1024-PE cluster, serial engine vs the deterministic
//! tile-parallel engine. The EXPERIMENTS.md §Perf target is ≥ 20 M
//! PE-cycles/s so Fig. 14a regenerates in seconds; the parallel-engine
//! acceptance bar is ≥ 3× over serial on the compute-trace benchmark at
//! 8 threads (on a host with ≥ 8 cores).
//!
//! `cargo bench --bench simspeed`

#[path = "util.rs"]
mod util;

use terapool::cluster::Cluster;
use terapool::config::ClusterConfig;
use terapool::isa::Program;
use terapool::kernels::axpy::{build, AxpyParams};

fn compute_programs(cfg: &ClusterConfig) -> Vec<Program> {
    (0..cfg.num_pes())
        .map(|_| {
            let mut p = Program::new();
            p.ld_imm(1, 1.0);
            p.ld_imm(2, 1.5);
            for _ in 0..2000 {
                p.fmac(3, 1, 2);
            }
            p.halt();
            p
        })
        .collect()
}

fn main() {
    let cfg = ClusterConfig::terapool(9);
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pe_mcycles = 1024.0 * 2002.0 / 1e6;

    // Pure-compute traces: issue-loop ceiling (no memory traffic). This
    // is the 1024-PE compute-trace benchmark of the acceptance criteria.
    let serial = util::bench("compute 1024 PEs × 2k instrs (serial)", 5, || {
        let mut cl = Cluster::new(cfg.clone(), compute_programs(&cfg));
        cl.run(1_000_000).cycles
    });
    util::report_rate("PE-cycles", pe_mcycles, "M", serial.median_ms);

    for threads in [2usize, 4, 8] {
        let r = util::bench(
            &format!("compute 1024 PEs × 2k instrs ({threads} threads)"),
            5,
            || {
                let mut cl = Cluster::new(cfg.clone(), compute_programs(&cfg));
                cl.run_parallel(1_000_000, threads).cycles
            },
        );
        util::report_rate("PE-cycles", pe_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
    }

    // Local-access memory traffic: AXPY (1 request per ~2 instrs) —
    // phase 2 (bank arbitration) stays serial, so this bounds the
    // Amdahl fraction of real kernels. Cycle count is captured from the
    // timed runs (deterministic workload — every rep reports the same).
    let p = AxpyParams { n: 256 * 1024, alpha: 2.0 };
    let mut cycles = 0u64;
    let serial = util::bench("axpy 256Ki on 1024 PEs (serial)", 3, || {
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
        cycles = cl.run(100_000_000).cycles;
        cycles
    });
    util::report_rate("PE-cycles", (cycles * 1024) as f64 / 1e6, "M", serial.median_ms);

    let threads = terapool::parallel::default_threads().max(2);
    let r = util::bench(&format!("axpy 256Ki on 1024 PEs ({threads} threads)"), 3, || {
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
        cl.run_parallel(100_000_000, threads).cycles
    });
    util::report_rate("PE-cycles", (cycles * 1024) as f64 / 1e6, "M", r.median_ms);
    println!(
        "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
        serial.median_ms / r.median_ms
    );
}
