//! Bench (§Perf): raw simulator speed — simulated PE-cycles per host
//! second on the 1024-PE cluster, serial engine vs the deterministic
//! fully sharded engine. The EXPERIMENTS.md §Perf targets: ≥ 20 M
//! PE-cycles/s serial on the compute trace so Fig. 14a regenerates in
//! seconds, ≥ 3× over serial at 8 threads on the compute trace, and
//! ≥ 2.5× over serial at 8 threads on the memory-bound AXPY row (hosts
//! with ≥ 8 cores). The AXPY rows are the acceptance bar for the sharded
//! pre-phase (owner-computes response delivery scales with the workers);
//! the double-buffer rows pressure what remains serial of the DMA path
//! (channel arbitration) against the worker-partitioned word movement.
//!
//! Besides the human-readable report, every run rewrites
//! `BENCH_simspeed.json` at the repository root (one row per
//! engine/thread-count configuration) so the perf trajectory is tracked
//! across PRs; CI uploads it as an advisory artifact and
//! `tools/bench_gate.py` compares it against the committed baseline.
//!
//! `cargo bench --bench simspeed`

#[path = "util.rs"]
mod util;

use terapool::cluster::Cluster;
use terapool::config::ClusterConfig;
use terapool::dma::hbm_image_clear;
use terapool::isa::Program;
use terapool::kernels::axpy::{build, AxpyParams};
use terapool::kernels::double_buffer::{self, DbKernel, DbParams};

/// One benchmark configuration's outcome, destined for the JSON report.
struct Row {
    bench: &'static str,
    engine: String,
    threads: usize,
    median_ms: f64,
    mean_ms: f64,
    min_ms: f64,
    /// Simulated PE-cycles of one run, in millions.
    pe_mcycles: f64,
    /// Throughput: simulated PE-cycles per host second, in millions.
    mcycles_per_s: f64,
    /// Wall-clock speedup vs this bench's serial row (1.0 for serial).
    speedup_vs_serial: f64,
}

impl Row {
    fn new(bench: &'static str, threads: usize, r: &util::BenchResult, pe_mcycles: f64, serial_ms: f64) -> Self {
        Row {
            bench,
            engine: if threads <= 1 { "serial".into() } else { format!("sharded-{threads}") },
            threads,
            median_ms: r.median_ms,
            mean_ms: r.mean_ms,
            min_ms: r.min_ms,
            pe_mcycles,
            mcycles_per_s: pe_mcycles / (r.median_ms / 1e3),
            speedup_vs_serial: serial_ms / r.median_ms,
        }
    }
}

/// Hand-rolled JSON (the offline build has no serde): enough structure
/// for CI trend tooling — `{schema, host, rows: [...]}`.
fn write_json(rows: &[Row], host_cores: usize) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_simspeed.json");
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"terapool-simspeed-v1\",\n");
    s.push_str(&format!("  \"host_cores\": {host_cores},\n"));
    s.push_str("  \"unit\": \"simulated PE-Mcycles per host second\",\n");
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"bench\": \"{}\", \"engine\": \"{}\", \"threads\": {}, \
             \"median_ms\": {:.3}, \"mean_ms\": {:.3}, \"min_ms\": {:.3}, \
             \"pe_mcycles\": {:.3}, \"mcycles_per_s\": {:.2}, \
             \"speedup_vs_serial\": {:.3}}}{}\n",
            r.bench,
            r.engine,
            r.threads,
            r.median_ms,
            r.mean_ms,
            r.min_ms,
            r.pe_mcycles,
            r.mcycles_per_s,
            r.speedup_vs_serial,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nWARNING: could not write {path}: {e}"),
    }
}

fn compute_programs(cfg: &ClusterConfig) -> Vec<Program> {
    (0..cfg.num_pes())
        .map(|_| {
            let mut p = Program::new();
            p.ld_imm(1, 1.0);
            p.ld_imm(2, 1.5);
            for _ in 0..2000 {
                p.fmac(3, 1, 2);
            }
            p.halt();
            p
        })
        .collect()
}

fn main() {
    let cfg = ClusterConfig::terapool(9);
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut rows: Vec<Row> = Vec::new();

    // Pure-compute traces: issue-loop ceiling (no memory traffic). This
    // is the 1024-PE compute-trace benchmark of the acceptance criteria.
    let pe_mcycles = 1024.0 * 2002.0 / 1e6;
    let serial = util::bench("compute 1024 PEs × 2k instrs (serial)", 5, || {
        let mut cl = Cluster::new(cfg.clone(), compute_programs(&cfg));
        cl.run(1_000_000).cycles
    });
    util::report_rate("PE-cycles", pe_mcycles, "M", serial.median_ms);
    rows.push(Row::new("compute", 1, &serial, pe_mcycles, serial.median_ms));

    for threads in [2usize, 4, 8, 16] {
        let r = util::bench(
            &format!("compute 1024 PEs × 2k instrs ({threads} threads)"),
            5,
            || {
                let mut cl = Cluster::new(cfg.clone(), compute_programs(&cfg));
                cl.run_parallel(1_000_000, threads).cycles
            },
        );
        util::report_rate("PE-cycles", pe_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
        rows.push(Row::new("compute", threads, &r, pe_mcycles, serial.median_ms));
    }

    // Memory-bound traffic: AXPY (1 request per ~2 instrs). Bank
    // arbitration is sharded per destination Tile and — with the fully
    // sharded pre-phase — response delivery, barrier bookkeeping and the
    // transfer merge scale with the workers too; these rows are the
    // acceptance bar for the sharded pre-phase (not slower at any thread
    // count, faster at ≥ 8 threads on an ≥ 8-core host).
    let p = AxpyParams { n: 256 * 1024, alpha: 2.0 };
    let mut cycles = 0u64;
    let serial = util::bench("axpy 256Ki on 1024 PEs (serial)", 3, || {
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
        cycles = cl.run(100_000_000).cycles;
        cycles
    });
    let axpy_mcycles = (cycles * 1024) as f64 / 1e6;
    util::report_rate("PE-cycles", axpy_mcycles, "M", serial.median_ms);
    rows.push(Row::new("axpy-1024", 1, &serial, axpy_mcycles, serial.median_ms));

    for threads in [2usize, 4, 8, 16] {
        let r = util::bench(&format!("axpy 256Ki on 1024 PEs ({threads} threads)"), 3, || {
            let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
            cl.run_parallel(100_000_000, threads).cycles
        });
        util::report_rate("PE-cycles", axpy_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
        rows.push(Row::new("axpy-1024", threads, &r, axpy_mcycles, serial.median_ms));
    }

    // Double-buffered AXPY through the HBML: the longest pre-phase in
    // the engine (DMA control + channel arbitration + burst movement +
    // distributed barriers every round). The sharded engine partitions
    // the burst word movement and the waiter bookkeeping across the
    // workers; only channel arbitration stays serial.
    let dbp = DbParams { kernel: DbKernel::Axpy, chunk: cfg.num_banks() * 4, rounds: 3 };
    let mut db_cycles = 0u64;
    let serial = util::bench("db-axpy 16Ki×3 rounds on 1024 PEs (serial)", 3, || {
        hbm_image_clear();
        db_cycles = double_buffer::run(&cfg, &dbp).cycles;
        db_cycles
    });
    let db_mcycles = (db_cycles * 1024) as f64 / 1e6;
    util::report_rate("PE-cycles", db_mcycles, "M", serial.median_ms);
    rows.push(Row::new("db-axpy-1024", 1, &serial, db_mcycles, serial.median_ms));

    for threads in [2usize, 4, 8, 16] {
        let r = util::bench(
            &format!("db-axpy 16Ki×3 rounds on 1024 PEs ({threads} threads)"),
            3,
            || {
                hbm_image_clear();
                double_buffer::run_threads(&cfg, &dbp, threads).cycles
            },
        );
        util::report_rate("PE-cycles", db_mcycles, "M", r.median_ms);
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
        rows.push(Row::new("db-axpy-1024", threads, &r, db_mcycles, serial.median_ms));
    }

    write_json(&rows, host_cores);
}
