//! Bench: Fig. 9 regeneration — HBML bandwidth across frequency × DDR
//! rate, plus raw HBM2E channel-model throughput.
//!
//! `cargo bench --bench hbml`

#[path = "util.rs"]
mod util;

use terapool::config::DdrRate;
use terapool::coordinator::{fig9, hbml_sweep_point, Scale};
use terapool::hbm::{Hbm, HbmConfig};

fn main() {
    fig9(Scale::Fast).print();

    let r = util::bench("fig9 point 900MHz/3.6 (256 KiW in+out)", 5, || {
        hbml_sweep_point(900.0, DdrRate::G3_6, 256 * 1024)
    });
    util::report_rate("simulated transfer", 2.0 * 256.0 * 1024.0 * 4.0 / 1e6, "MB", r.median_ms);

    util::bench("raw hbm model: 16k bursts", 10, || {
        let mut h = Hbm::new(HbmConfig::new(DdrRate::G3_6, 900.0));
        for i in 0..16_384u64 {
            h.submit(i, i * 1024, 1024, i);
        }
        let mut done = 0u64;
        let mut now = 0;
        while done < 16_384 {
            h.take_completed(now, |_| done += 1);
            now += 64;
        }
        now
    });
}
