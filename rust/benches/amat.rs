//! Bench: Table 4 / Fig. 8b regeneration — the closed-form AMAT model
//! (Eqs. 4-6) and the burst simulation over all 13 hierarchy candidates.
//!
//! `cargo bench --bench amat`

#[path = "util.rs"]
mod util;

use terapool::amat::{amat, HierSpec};
use terapool::coordinator::{fig8, table4, Scale};

fn main() {
    // The regenerated artifacts themselves:
    table4(Scale::Fast).print();
    fig8(Scale::Fast).print();

    // Timing: closed form vs burst simulation.
    util::bench("table4 closed-form (13 rows)", 10, || {
        HierSpec::table4_rows()
            .iter()
            .map(|s| s.analytic_amat())
            .sum::<f64>()
    });
    util::bench("burst sim terapool (1024 reqs)", 20, || {
        amat(&HierSpec::terapool(), 1).amat
    });
    util::bench("burst sim flat 1024C", 20, || {
        amat(&HierSpec::new(1024, 1, 1, 1), 1).amat
    });
}
