//! Bench: Table 6 regeneration — Byte/FLOP vs IPC across the TeraPool /
//! MemPool / Occamy cluster scales, plus the Sec. 2 balance analysis and
//! the tile-parallel engine's thread-scaling curve on the 1024-PE GEMM
//! sweep (the workload Fig. 14a / Table 6 regeneration is bound by).
//!
//! `cargo bench --bench scaling`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::{scaling_analysis, table6, Scale};
use terapool::kernels::gemm::{build, GemmParams};
use terapool::session::Session;

fn main() {
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let session = Session::new(ClusterConfig::terapool(9))
        .scale(Scale::Fast)
        .threads(terapool::parallel::default_threads());
    table6(&session).print();
    scaling_analysis().print();

    for cfg in [
        ClusterConfig::terapool(9),
        ClusterConfig::mempool(),
        ClusterConfig::occamy(),
    ] {
        // Size the problem to the cluster's L1 (Occamy holds 128 KiB).
        let edge = match cfg.num_pes() {
            n if n >= 1024 => 128,
            n if n >= 256 => 96,
            _ => 32,
        };
        let p = GemmParams { m: edge, n: edge, k: edge };
        util::bench(
            &format!("gemm {edge}^3 on {} ({} PEs, serial)", cfg.name, cfg.num_pes()),
            3,
            || {
                let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
                cl.run(2_000_000_000).cycles
            },
        );
    }

    // Thread-scaling curve of the parallel engine on the 1024-PE GEMM.
    let cfg = ClusterConfig::terapool(9);
    let p = GemmParams { m: 128, n: 128, k: 128 };
    let serial = util::bench("gemm 128^3 terapool (serial)", 3, || {
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
        cl.run(2_000_000_000).cycles
    });
    for threads in [2usize, 4, 8] {
        let r = util::bench(&format!("gemm 128^3 terapool ({threads} threads)"), 3, || {
            let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
            cl.run_parallel(2_000_000_000, threads).cycles
        });
        println!(
            "  ↳ speedup vs serial: {:.2}x ({threads} threads, {host_cores} host cores)",
            serial.median_ms / r.median_ms
        );
    }
}
