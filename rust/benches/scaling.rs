//! Bench: Table 6 regeneration — Byte/FLOP vs IPC across the TeraPool /
//! MemPool / Occamy cluster scales, plus the Sec. 2 balance analysis.
//!
//! `cargo bench --bench scaling`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::{scaling_analysis, table6, Scale};
use terapool::kernels::gemm::{build, GemmParams};

fn main() {
    table6(Scale::Fast).print();
    scaling_analysis().print();

    for cfg in [
        ClusterConfig::terapool(9),
        ClusterConfig::mempool(),
        ClusterConfig::occamy(),
    ] {
        // Size the problem to the cluster's L1 (Occamy holds 128 KiB).
        let edge = match cfg.num_pes() {
            n if n >= 1024 => 128,
            n if n >= 256 => 96,
            _ => 32,
        };
        let p = GemmParams { m: edge, n: edge, k: edge };
        util::bench(
            &format!("gemm {edge}^3 on {} ({} PEs)", cfg.name, cfg.num_pes()),
            3,
            || {
                let (mut cl, _) = build(&cfg, &p).into_cluster(cfg.clone());
                cl.run(2_000_000_000).cycles
            },
        );
    }
}
