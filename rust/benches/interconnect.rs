//! Bench (ablation): spill-register configurations (1-3-5-7/9/11),
//! transaction-table depth, and sequential-region sizing — the design
//! choices DESIGN.md calls out, measured on GEMM/AXPY through the
//! Session run path.
//!
//! `cargo bench --bench interconnect`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::Scale;
use terapool::kernels::gemm::Gemm;
use terapool::report::{f1, f2, int, pct, Table};
use terapool::session::Session;

fn main() {
    let session = Session::new(ClusterConfig::terapool(9)).scale(Scale::Fast);
    let gemm = Gemm::default();

    // Ablation 1: spill registers — latency vs frequency (Sec. 6.2).
    let mut t = Table::new(
        "Ablation — spill-register configs (GEMM, fast scale)",
        &["Config", "MHz", "IPC", "Cycles", "Runtime µs", "GFLOP/s"],
    );
    for rg in [7u32, 9, 11] {
        let cfg = ClusterConfig::terapool(rg);
        let s = session.run_on(&cfg, &gemm).expect("gemm run").stats;
        t.row(vec![
            cfg.name.clone(),
            f1(cfg.freq_mhz),
            f2(s.ipc()),
            int(s.cycles),
            f1(s.cycles as f64 / cfg.freq_mhz),
            f1(s.gflops()),
        ]);
    }
    t.print();

    // Ablation 2: transaction-table depth (Sec. 4.1 break-even at 8).
    let mut t = Table::new(
        "Ablation — LSU transaction-table depth (GEMM, fast scale)",
        &["Entries", "IPC", "LSU stall %", "Cycles"],
    );
    for entries in [1usize, 2, 4, 8, 16] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.tx_table_entries = entries;
        let s = session.run_on(&cfg, &gemm).expect("gemm run").stats;
        t.row(vec![
            int(entries as u64),
            f2(s.ipc()),
            pct(s.fraction(s.stall_lsu)),
            int(s.cycles),
        ]);
    }
    t.print();

    // Timing of the arbitration engine itself.
    util::bench("gemm fast on terapool-9", 3, || {
        session.run_named("gemm").expect("gemm run").stats.cycles
    });
}
