//! Bench (ablation): spill-register configurations (1-3-5-7/9/11),
//! transaction-table depth, and sequential-region sizing — the design
//! choices DESIGN.md calls out, measured on GEMM/AXPY.
//!
//! `cargo bench --bench interconnect`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::{run_kernel, Scale};
use terapool::report::{f1, f2, int, pct, Table};

fn main() {
    // Ablation 1: spill registers — latency vs frequency (Sec. 6.2).
    let mut t = Table::new(
        "Ablation — spill-register configs (GEMM, fast scale)",
        &["Config", "MHz", "IPC", "Cycles", "Runtime µs", "GFLOP/s"],
    );
    for rg in [7u32, 9, 11] {
        let cfg = ClusterConfig::terapool(rg);
        let (s, _) = run_kernel(&cfg, "gemm", Scale::Fast);
        t.row(vec![
            cfg.name.clone(),
            f1(cfg.freq_mhz),
            f2(s.ipc()),
            int(s.cycles),
            f1(s.cycles as f64 / cfg.freq_mhz),
            f1(s.gflops()),
        ]);
    }
    t.print();

    // Ablation 2: transaction-table depth (Sec. 4.1 break-even at 8).
    let mut t = Table::new(
        "Ablation — LSU transaction-table depth (GEMM, fast scale)",
        &["Entries", "IPC", "LSU stall %", "Cycles"],
    );
    for entries in [1usize, 2, 4, 8, 16] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.tx_table_entries = entries;
        let (s, _) = run_kernel(&cfg, "gemm", Scale::Fast);
        t.row(vec![
            int(entries as u64),
            f2(s.ipc()),
            pct(s.fraction(s.stall_lsu)),
            int(s.cycles),
        ]);
    }
    t.print();

    // Timing of the arbitration engine itself.
    let cfg = ClusterConfig::terapool(9);
    util::bench("gemm fast on terapool-9", 3, || {
        run_kernel(&cfg, "gemm", Scale::Fast).0.cycles
    });
}
