//! Minimal bench harness (criterion is unavailable in the offline
//! build): warm-up + timed iterations, median/mean/min reporting.
//! Included by every bench target via `#[path] mod util;`.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:40} {:4} iters  mean {:>10.3} ms  median {:>10.3} ms  min {:>10.3} ms",
            self.name, self.iters, self.mean_ms, self.median_ms, self.min_ms
        );
    }
}

/// Time `f` for `iters` iterations (after one warm-up) and report.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    std::hint::black_box(f()); // warm-up
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: samples.iter().sum::<f64>() / samples.len() as f64,
        median_ms: samples[samples.len() / 2],
        min_ms: samples[0],
    };
    r.print();
    r
}

/// Report a throughput metric alongside a timed run.
#[allow(dead_code)]
pub fn report_rate(what: &str, amount: f64, unit: &str, ms: f64) {
    println!("  ↳ {what}: {:.2} {unit}/s", amount / (ms / 1e3));
}
