//! Bench: Fig. 14a / Fig. 14b regeneration — the five benchmark kernels
//! on the full 1024-PE cluster (reduced problem sizes so a bench run
//! stays in seconds), plus the double-buffered HBM variants.
//!
//! `cargo bench --bench kernels_e2e`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::{
    fig14a_threads, fig14b_threads, run_kernel, run_kernel_threads, Scale, FIG14A_KERNELS,
};

fn main() {
    // Regenerate Fig. 14a on the tile-parallel engine (identical numbers,
    // less wall clock), then time the kernels per engine.
    let threads = terapool::parallel::default_threads();
    fig14a_threads(Scale::Fast, threads).print();
    fig14b_threads(Scale::Fast, threads).print();

    let cfg = ClusterConfig::terapool(9);
    for k in FIG14A_KERNELS {
        // Capture the stats from inside the timed runs instead of paying
        // for an extra full simulation afterwards.
        let mut last = None;
        let r = util::bench(&format!("kernel {k} (fast scale, serial)"), 3, || {
            let (stats, _) = run_kernel(&cfg, k, Scale::Fast);
            let cycles = stats.cycles;
            last = Some(stats);
            cycles
        });
        let rp = util::bench(&format!("kernel {k} (fast scale, {threads} threads)"), 3, || {
            run_kernel_threads(&cfg, k, Scale::Fast, threads).0.cycles
        });
        println!("  ↳ parallel speedup: {:.2}x", r.median_ms / rp.median_ms);
        let stats = last.expect("bench ran at least once");
        util::report_rate(
            "simulated PE-cycles",
            (stats.cycles * stats.num_pes as u64) as f64 / 1e6,
            "M",
            r.median_ms,
        );
    }
}
