//! Bench: Fig. 14a / Fig. 14b regeneration — the five benchmark kernels
//! on the full 1024-PE cluster (reduced problem sizes so a bench run
//! stays in seconds), plus the double-buffered HBM variants.
//!
//! `cargo bench --bench kernels_e2e`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::{fig14a, fig14b, run_kernel, Scale, FIG14A_KERNELS};

fn main() {
    fig14a(Scale::Fast).print();
    fig14b(Scale::Fast).print();

    let cfg = ClusterConfig::terapool(9);
    for k in FIG14A_KERNELS {
        let r = util::bench(&format!("kernel {k} (fast scale)"), 3, || {
            run_kernel(&cfg, k, Scale::Fast).0.cycles
        });
        let (stats, _) = run_kernel(&cfg, k, Scale::Fast);
        util::report_rate(
            "simulated PE-cycles",
            (stats.cycles * stats.num_pes as u64) as f64 / 1e6,
            "M",
            r.median_ms,
        );
    }
}
