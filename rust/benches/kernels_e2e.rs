//! Bench: Fig. 14a / Fig. 14b regeneration — the five benchmark kernels
//! on the full 1024-PE cluster (reduced problem sizes so a bench run
//! stays in seconds), plus the double-buffered HBM variants. Everything
//! goes through the Session run path.
//!
//! `cargo bench --bench kernels_e2e`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::{fig14a, fig14b, Scale, FIG14A_KERNELS};
use terapool::kernels;
use terapool::session::Session;

fn main() {
    // Regenerate Fig. 14a/b with the host-thread budget: the kernel
    // batch fans out across jobs (identical numbers, less wall clock).
    let threads = terapool::parallel::default_threads();
    let batch = Session::new(ClusterConfig::terapool(9)).scale(Scale::Fast).threads(threads);
    fig14a(&batch).print();
    fig14b(&batch).print();

    let cfg = ClusterConfig::terapool(9);
    let serial = Session::new(cfg.clone()).scale(Scale::Fast);
    let parallel = Session::new(cfg).scale(Scale::Fast).threads(threads);
    for k in FIG14A_KERNELS {
        let w = kernels::lookup(k).expect("registered kernel");
        // Capture the stats from inside the timed runs instead of paying
        // for an extra full simulation afterwards.
        let mut last = None;
        let r = util::bench(&format!("kernel {k} (fast scale, serial)"), 3, || {
            let rep = serial.run(&*w).expect("serial run");
            let cycles = rep.stats.cycles;
            last = Some(rep);
            cycles
        });
        let rp = util::bench(&format!("kernel {k} (fast scale, {threads} threads)"), 3, || {
            parallel.run(&*w).expect("parallel run").stats.cycles
        });
        println!("  ↳ parallel speedup: {:.2}x", r.median_ms / rp.median_ms);
        let rep = last.expect("bench ran at least once");
        util::report_rate(
            "simulated PE-cycles",
            (rep.stats.cycles * rep.stats.num_pes as u64) as f64 / 1e6,
            "M",
            r.median_ms,
        );
    }
}
