//! Bench: Table 3 / Fig. 3 (congestion), Fig. 11 (EDA), Fig. 12 (area),
//! Fig. 13 (energy/EDP) regeneration.
//!
//! `cargo bench --bench physical`

#[path = "util.rs"]
mod util;

use terapool::config::ClusterConfig;
use terapool::coordinator::{fig11, fig12, fig13, table3, table5};
use terapool::physical::{area, congestion, energy};

fn main() {
    table3().print();
    fig11().print();
    fig12().print();
    fig13().print();
    table5().print();

    util::bench("congestion sweep 256..4096", 100, || {
        (256..=4096usize)
            .step_by(64)
            .map(|c| congestion::predict(c).congestion)
            .sum::<f64>()
    });
    util::bench("area breakdown", 1000, || {
        area::breakdown(&ClusterConfig::terapool(9)).total()
    });
    util::bench("energy model full Fig13 grid", 1000, || {
        let mut acc = 0.0;
        for rg in [7, 9, 11] {
            let m = energy::EnergyModel::for_config(rg);
            for i in energy::FIG13_INSTRS {
                acc += m.pj(i) + m.edp(i);
            }
        }
        acc
    });
}
