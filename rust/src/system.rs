//! The system layer: a [`Topology`] of TeraPool clusters stepped as one
//! scale-out machine (ROADMAP item 1). One kernel is chunked
//! data-parallel across the clusters; the system scheduler pays for
//! every word that crosses a chip boundary:
//!
//! 1. **Staging** — each cluster's private inputs stream in from the
//!    off-chip memory node over the *shared* main-memory bus
//!    (round-robin arbitration, one grant of `memory.width` words per
//!    cycle, plus the access latency once per stream).
//! 2. **Halo broadcast** — operands shared by every cluster (the GEMM B
//!    matrix, the FFT twiddle table) are staged once, on cluster 0, and
//!    forwarded to the others over the inter-cluster links
//!    (store-and-forward per hop: occupy `⌈words/width⌉` cycles, then
//!    the hop latency; links are FIFO, transfers are processed in fixed
//!    ascending-destination order over [`Topology::route`]'s
//!    deterministic BFS routes).
//! 3. **Start barrier** — compute starts globally at `T0 = max` over
//!    every cluster's readiness: the synchronization cost the
//!    scale-out analysis quantifies.
//! 4. **Compute** — every cluster runs its chunk to completion on the
//!    serial reference engine. Chunks exchange *no* mid-kernel traffic
//!    (all inter-cluster movement is confined to phases 1–2 and 5), so
//!    run-to-completion and cycle-lockstep interleavings commute, and
//!    stepping the clusters **cluster-parallel on host threads**
//!    ([`crate::parallel::scatter`]) is bit-identical to the serial
//!    order — `rust/tests/system_equiv.rs` pins this at 1/2/4 threads.
//! 5. **Merge** — each cluster's output band streams back to the memory
//!    node over the shared bus (same arbiter), becoming eligible when
//!    that cluster finishes. The merged image lives in the memory node
//!    (a host-side buffer), *not* some designated cluster's L1: a split
//!    cluster's L1 cannot hold the full-problem output, and the memory
//!    node is what a host would read.
//!
//! Everything here is deterministic by construction: fixed phase order,
//! fixed arbitration order (ascending round-robin), fixed routes, and
//! compute phases that share no state across clusters.

use std::sync::Mutex;

use crate::cluster::{Cluster, RunStats};
use crate::config::Scale;
use crate::errors::{Error, Result};
use crate::kernels::{allclose_verdict, chunk_range, fft, gemm, Staged};
use crate::parallel::scatter;
use crate::report::{SystemClusterInfo, SystemInfo, SystemLinkInfo, Verdict};
use crate::topology::Topology;

/// A kernel the system layer knows how to chunk across clusters. The
/// single-cluster [`crate::kernels::Workload`] registry stays the source
/// of truth for the *math*; this enum only names the kernels whose
/// builders expose band staging (`build_band`).
#[derive(Debug, Clone, Copy)]
pub enum SystemKernel {
    Gemm(gemm::GemmParams),
    Fft(fft::FftParams),
}

/// Resolve a registry kind to a chunked system kernel at `scale`'s
/// default problem size. Kinds without a band builder are a typed
/// `UnknownWorkload` error.
pub fn resolve_kernel(kind: &str, scale: Scale) -> Result<SystemKernel> {
    match kind {
        "gemm" => {
            let e = scale.pick(256, 128);
            Ok(SystemKernel::Gemm(gemm::GemmParams { m: e, n: e, k: e }))
        }
        "fft" => Ok(SystemKernel::Fft(fft::FftParams {
            batch: scale.pick(64, 16),
            n: scale.pick(4096, 1024),
        })),
        other => Err(Error::unknown_workload(other, &["gemm", "fft"])),
    }
}

/// A finished system run: what [`crate::session::Session::system`]
/// reports, plus the merged memory-node image for differential tests.
#[derive(Debug, Clone)]
pub struct SystemRun {
    /// `<kernel>@<topology>`, e.g. `gemm-256x256x256@quad`.
    pub name: String,
    /// Aggregate stats: `cycles` is the full system timeline
    /// (staging + compute + merge), counters are sums over clusters,
    /// AMAT is the request-count-weighted average.
    pub stats: RunStats,
    pub info: SystemInfo,
    pub verdict: Verdict,
    /// The memory node's final image — the merged system output.
    pub output: Vec<f32>,
}

/// One shared-operand broadcast from cluster 0 to `dst`.
struct Bcast {
    dst: usize,
    /// Words the links carry: the *unique* operand words (each cluster
    /// re-replicates locally where the kernel wants replicas).
    words: u64,
    deliver: Deliver,
}

/// How a broadcast's payload lands functionally in the destination L1.
enum Deliver {
    /// Copy `words` f32 verbatim from cluster 0's `src_base`.
    Copy { src_base: u32, dst_base: u32, words: usize },
    /// Gather the `n` canonical table entries out of cluster 0's
    /// copy-interleaved layout and re-interleave for the destination's
    /// replica count (replica counts scale with cluster size, so the
    /// two ends of a link may disagree).
    Replicate { src_base: u32, src_copies: usize, dst_base: u32, dst_copies: usize, n: usize },
}

/// The staged chunking plan: per-cluster builds, broadcast and merge
/// descriptors, and the memory-node image size.
struct Plan {
    /// Kernel instance name (without the topology suffix).
    name: String,
    staged: Vec<Staged>,
    bcasts: Vec<Bcast>,
    /// Per cluster: (L1 base, words, offset into the memory image).
    merges: Vec<Vec<(u32, usize, usize)>>,
    out_len: usize,
}

/// Refuse chunkings that would leave a cluster with an empty band — a
/// typed `Unsupported`, mirroring the estimate-census refusal: the
/// combination is declaratively out of scope, never silently reshaped.
fn ensure_chunks(total: usize, parts: usize, what: &str) -> Result<()> {
    for c in 0..parts {
        if chunk_range(total, c, parts).is_empty() {
            return Err(Error::unsupported(format!(
                "{what}: {total} bands cannot cover {parts} clusters (cluster {c}'s \
                 band would be empty); use fewer clusters or a bigger problem"
            )));
        }
    }
    Ok(())
}

fn stage(topo: &Topology, kernel: &SystemKernel) -> Result<Plan> {
    let parts = topo.clusters.len();
    Ok(match kernel {
        SystemKernel::Gemm(p) => {
            let name = format!("gemm-{}x{}x{}", p.m, p.n, p.k);
            ensure_chunks(p.m / 4, parts, &name)?;
            let mut staged = Vec::with_capacity(parts);
            let mut bands = Vec::with_capacity(parts);
            for c in 0..parts {
                let (s, b) = gemm::build_band(&topo.clusters[c].cfg, p, c, parts, c == 0);
                staged.push(s);
                bands.push(b);
            }
            let bcasts = (1..parts)
                .map(|d| Bcast {
                    dst: d,
                    words: (p.k * p.n) as u64,
                    deliver: Deliver::Copy {
                        src_base: bands[0].b_base,
                        dst_base: bands[d].b_base,
                        words: p.k * p.n,
                    },
                })
                .collect();
            let merges = bands
                .iter()
                .map(|b| vec![(b.c_base, b.rows * p.n, b.row0 * p.n)])
                .collect();
            Plan { name, staged, bcasts, merges, out_len: p.m * p.n }
        }
        SystemKernel::Fft(p) => {
            let name = format!("fft-{}x{}", p.batch, p.n);
            ensure_chunks(p.batch, parts, &name)?;
            let mut staged = Vec::with_capacity(parts);
            let mut bands = Vec::with_capacity(parts);
            for c in 0..parts {
                let (s, b) = fft::build_band(&topo.clusters[c].cfg, p, c, parts, c == 0);
                staged.push(s);
                bands.push(b);
            }
            let mut bcasts = Vec::new();
            for d in 1..parts {
                let (src, dst) = (&bands[0], &bands[d]);
                for (sb, db) in [
                    (src.tw_re_base, dst.tw_re_base),
                    (src.tw_im_base, dst.tw_im_base),
                ] {
                    bcasts.push(Bcast {
                        dst: d,
                        words: p.n as u64,
                        deliver: Deliver::Replicate {
                            src_base: sb,
                            src_copies: src.tw_words / p.n,
                            dst_base: db,
                            dst_copies: dst.tw_words / p.n,
                            n: p.n,
                        },
                    });
                }
            }
            // Memory image: the re planes of all frames, then the im
            // planes (a single cluster instead lays im directly after
            // its own re plane — the system image is the host-facing
            // canonical layout).
            let merges = bands
                .iter()
                .map(|b| {
                    vec![
                        (b.re_base, b.frames * p.n, b.f0 * p.n),
                        (b.im_base, b.frames * p.n, (p.batch + b.f0) * p.n),
                    ]
                })
                .collect();
            Plan { name, staged, bcasts, merges, out_len: 2 * p.batch * p.n }
        }
    })
}

/// Outcome of one shared-bus episode (staging or merge).
struct BusOutcome {
    /// Per-source cycle its last word has landed (grant + access
    /// latency); sources with no words keep their `avail` time.
    finish: Vec<u64>,
    /// Cycles the bus spent granting.
    busy: u64,
    /// Words moved in this episode.
    words: u64,
}

/// The shared main-memory bus: source `c` becomes eligible at
/// `avail[c]` with `words[c]` words to move; each cycle the bus grants
/// up to `width` words to **one** eligible source, round-robin starting
/// after the previous grantee. Deterministic: ties break on ascending
/// index from the rotating pointer.
fn bus_sim(avail: &[u64], words: &[u64], width: usize, latency: u64) -> BusOutcome {
    let n = avail.len();
    let mut rem = words.to_vec();
    let mut finish = avail.to_vec();
    let width = width.max(1) as u64;
    let (mut busy, mut t, mut rr) = (0u64, 0u64, 0usize);
    while rem.iter().any(|&r| r > 0) {
        if !(0..n).any(|c| rem[c] > 0 && avail[c] <= t) {
            // Idle until the earliest pending source is available.
            t = (0..n).filter(|&c| rem[c] > 0).map(|c| avail[c]).min().unwrap();
            continue;
        }
        let pick = (0..n)
            .map(|i| (rr + i) % n)
            .find(|&c| rem[c] > 0 && avail[c] <= t)
            .unwrap();
        rem[pick] = rem[pick].saturating_sub(width);
        busy += 1;
        if rem[pick] == 0 {
            finish[pick] = t + 1 + latency;
        }
        rr = (pick + 1) % n;
        t += 1;
    }
    BusOutcome { finish, busy, words: words.iter().sum() }
}

/// Run `kernel` chunked across the clusters of `topo`. See the module
/// docs for the five phases; `host_threads > 1` steps the compute phase
/// cluster-parallel (bit-identical). `max_cycles` bounds each cluster's
/// compute chunk (typed `MaxCyclesExceeded`, prefixed with the cluster
/// name). `checking` compares the merged memory image against the
/// kernel's host reference.
pub fn run_system(
    topo: &Topology,
    kernel: &SystemKernel,
    host_threads: usize,
    max_cycles: u64,
    fast_forward: bool,
    checking: bool,
) -> Result<SystemRun> {
    let parts = topo.clusters.len();
    let plan = stage(topo, kernel)?;

    // Phase 1 — staging: every cluster's functionally-staged words
    // stream from the memory node over the shared bus.
    let stage_words: Vec<u64> = plan
        .staged
        .iter()
        .map(|s| s.inputs.iter().map(|(_, d)| d.len() as u64).sum())
        .collect();
    let stage_avail = vec![0u64; parts];
    let stage_bus = bus_sim(&stage_avail, &stage_words, topo.memory.width, topo.memory.latency);

    let mut clusters: Vec<Cluster> = Vec::with_capacity(parts);
    for (c, staged) in plan.staged.into_iter().enumerate() {
        assert!(staged.dma.is_none(), "system runs are L1-resident (no HBML plan)");
        let (mut cl, _io) = staged.into_cluster(topo.clusters[c].cfg.clone());
        cl.fast_forward = fast_forward;
        clusters.push(cl);
    }

    // Phase 2 — halo broadcasts over the links, in fixed (ascending
    // destination, plane) order; a transfer leaves cluster 0 once its
    // staging finished, holds each route hop for ⌈words/width⌉ cycles
    // (FIFO per link), then pays the hop latency.
    let mut link_words = vec![0u64; topo.links.len()];
    let mut link_busy = vec![0u64; topo.links.len()];
    let mut link_free = vec![0u64; topo.links.len()];
    let mut arrival = vec![0u64; parts];
    for b in &plan.bcasts {
        let mut ready = stage_bus.finish[0];
        for li in topo.route(0, b.dst)? {
            let l = &topo.links[li];
            let occ = b.words.div_ceil(l.width as u64).max(1);
            let start = ready.max(link_free[li]);
            link_free[li] = start + occ;
            ready = start + occ + l.latency;
            link_words[li] += b.words;
            link_busy[li] += occ;
        }
        arrival[b.dst] = arrival[b.dst].max(ready);
        // Functional delivery (the timing above is the cost model; the
        // bytes move here).
        match b.deliver {
            Deliver::Copy { src_base, dst_base, words } => {
                let data = clusters[0].l1.read_slice(src_base, words);
                clusters[b.dst].l1.write_slice(dst_base, &data);
            }
            Deliver::Replicate { src_base, src_copies, dst_base, dst_copies, n } => {
                let src = clusters[0].l1.read_slice(src_base, src_copies * n);
                let mut out = vec![0.0f32; dst_copies * n];
                for e in 0..n {
                    let v = src[e * src_copies];
                    for c in 0..dst_copies {
                        out[e * dst_copies + c] = v;
                    }
                }
                clusters[b.dst].l1.write_slice(dst_base, &out);
            }
        }
    }

    // Phase 3 — the system start barrier.
    let t0 = (0..parts)
        .map(|c| stage_bus.finish[c].max(arrival[c]))
        .max()
        .unwrap_or(0);

    // Phase 4 — compute, cluster-parallel across host threads. With
    // `host_threads <= 1` `scatter` degenerates to an in-order loop on
    // this thread — the serial reference order of the differential
    // suite. Chunks share no state, so the results cannot depend on the
    // interleaving.
    let cells: Vec<Mutex<Cluster>> = clusters.into_iter().map(Mutex::new).collect();
    let results: Vec<Result<RunStats>> = scatter(parts, host_threads, |i| {
        let mut cl = cells[i].lock().unwrap();
        cl.try_run_threads(max_cycles, 1)
            .map_err(|e| e.prefixed(&topo.clusters[i].name))
    });
    let mut per: Vec<RunStats> = Vec::with_capacity(parts);
    for r in results {
        per.push(r?);
    }
    let clusters: Vec<Cluster> = cells
        .into_iter()
        .map(|m| m.into_inner().unwrap())
        .collect();
    let compute_cycles = per.iter().map(|s| s.cycles).max().unwrap_or(0);
    let compute_done: Vec<u64> = per.iter().map(|s| t0 + s.cycles).collect();

    // Phase 5 — merge each cluster's output band(s) into the memory
    // node over the shared bus; a cluster's band is eligible once that
    // cluster finished.
    let merge_words: Vec<u64> = plan
        .merges
        .iter()
        .map(|ms| ms.iter().map(|&(_, w, _)| w as u64).sum())
        .collect();
    let merge_bus = bus_sim(&compute_done, &merge_words, topo.memory.width, topo.memory.latency);
    let t_end = merge_bus
        .finish
        .iter()
        .zip(&compute_done)
        .map(|(&f, &d)| f.max(d))
        .max()
        .unwrap_or(t0);

    let mut output = vec![0.0f32; plan.out_len];
    for (c, ms) in plan.merges.iter().enumerate() {
        for &(base, words, off) in ms {
            let data = clusters[c].l1.read_slice(base, words);
            output[off..off + words].copy_from_slice(&data);
        }
    }

    // Aggregate stats over the system timeline.
    let mut agg = per[0].clone();
    agg.cycles = t_end;
    agg.num_pes = topo.total_pes();
    let (mut w_total, mut w_class) = (0.0f64, [0.0f64; 4]);
    let mut reqs_total = 0u64;
    for (i, s) in per.iter().enumerate() {
        if i > 0 {
            agg.instructions += s.instructions;
            agg.flops += s.flops;
            agg.stall_raw += s.stall_raw;
            agg.stall_lsu += s.stall_lsu;
            agg.stall_ctrl += s.stall_ctrl;
            agg.stall_synch += s.stall_synch;
            agg.loads += s.loads;
            agg.stores += s.stores;
            agg.atomics += s.atomics;
            for k in 0..4 {
                agg.reqs_per_class[k] += s.reqs_per_class[k];
                agg.burst_reqs_per_class[k] += s.burst_reqs_per_class[k];
                agg.burst_words_per_class[k] += s.burst_words_per_class[k];
            }
        }
        for k in 0..4 {
            w_class[k] += s.amat_per_class[k] * s.reqs_per_class[k] as f64;
            w_total += s.amat_per_class[k] * s.reqs_per_class[k] as f64;
            reqs_total += s.reqs_per_class[k];
        }
    }
    agg.amat = if reqs_total > 0 { w_total / reqs_total as f64 } else { 0.0 };
    for k in 0..4 {
        agg.amat_per_class[k] = if agg.reqs_per_class[k] > 0 {
            w_class[k] / agg.reqs_per_class[k] as f64
        } else {
            0.0
        };
    }

    let info = SystemInfo {
        topology: topo.name.clone(),
        clusters: (0..parts)
            .map(|c| SystemClusterInfo {
                name: topo.clusters[c].name.clone(),
                num_pes: per[c].num_pes,
                cycles: per[c].cycles,
                instructions: per[c].instructions,
                flops: per[c].flops,
            })
            .collect(),
        links: (0..topo.links.len())
            .map(|i| SystemLinkInfo {
                name: topo.link_name(i),
                words: link_words[i],
                busy_cycles: link_busy[i],
            })
            .collect(),
        bus_words: stage_bus.words + merge_bus.words,
        bus_busy_cycles: stage_bus.busy + merge_bus.busy,
        stage_cycles: t0,
        compute_cycles,
        merge_cycles: t_end.saturating_sub(t0 + compute_cycles),
        link_words: link_words.iter().sum(),
    };

    let verdict = if !checking {
        Verdict::NotChecked
    } else {
        match kernel {
            SystemKernel::Gemm(p) => {
                allclose_verdict(&output, &gemm::reference(p), 2e-2, "system gemm vs host reference")
            }
            SystemKernel::Fft(p) => {
                if p.batch * p.n * p.n > (1 << 29) {
                    // The O(n²) host DFT is intractable at this size.
                    Verdict::NotChecked
                } else {
                    let (re, im) = fft::reference(p);
                    let bn = p.batch * p.n;
                    match allclose_verdict(&output[..bn], &re, 5e-2, "system fft re-plane vs host DFT") {
                        Verdict::Passed { .. } => allclose_verdict(
                            &output[bn..],
                            &im,
                            5e-2,
                            "system fft re+im planes vs host DFT",
                        ),
                        failed => failed,
                    }
                }
            }
        }
    };

    Ok(SystemRun {
        name: format!("{}@{}", plan.name, topo.name),
        stats: agg,
        info,
        verdict,
        output,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::errors::ErrorKind;

    const BUDGET: u64 = 10_000_000;

    #[test]
    fn dual_cluster_gemm_matches_the_host_reference() {
        let topo = Topology::split(&ClusterConfig::tiny(), 2).unwrap();
        let k = SystemKernel::Gemm(gemm::GemmParams { m: 16, n: 16, k: 16 });
        let run = run_system(&topo, &k, 1, BUDGET, true, true).unwrap();
        assert!(matches!(run.verdict, Verdict::Passed { .. }), "{:?}", run.verdict);
        assert_eq!(run.output.len(), 16 * 16);
        // Two clusters, one p2p link carrying one B broadcast.
        assert_eq!(run.info.clusters.len(), 2);
        assert_eq!(run.info.links.len(), 1);
        assert_eq!(run.info.link_words, 16 * 16);
        assert!(run.info.stage_cycles > 0);
        assert!(run.info.merge_cycles > 0);
        // The timeline decomposes exactly.
        assert_eq!(
            run.stats.cycles,
            run.info.stage_cycles + run.info.compute_cycles + run.info.merge_cycles
        );
        // Bus traffic = staged inputs + merged outputs: two A bands
        // (128 words each) + B (256) + two C bands (128 each).
        assert_eq!(run.info.bus_words, 128 + 256 + 128 + 128 + 128);
    }

    #[test]
    fn quad_cluster_fft_matches_the_host_reference() {
        let topo = Topology::split(&ClusterConfig::tiny(), 4).unwrap();
        let k = SystemKernel::Fft(fft::FftParams { batch: 4, n: 64 });
        let run = run_system(&topo, &k, 1, BUDGET, true, true).unwrap();
        assert!(matches!(run.verdict, Verdict::Passed { .. }), "{:?}", run.verdict);
        assert_eq!(run.output.len(), 2 * 4 * 64);
        // Twiddle broadcasts: two canonical 64-word planes to each of
        // the three non-root clusters (multi-hop routes re-count words
        // per link crossed, so the sum is at least the unique payload).
        assert!(run.info.link_words >= 3 * 2 * 64, "{}", run.info.link_words);
    }

    #[test]
    fn single_cluster_system_matches_the_standalone_engine() {
        let cfg = ClusterConfig::tiny();
        let p = gemm::GemmParams { m: 16, n: 16, k: 16 };
        let topo = Topology::split(&cfg, 1).unwrap();
        let run = run_system(&topo, &SystemKernel::Gemm(p), 1, BUDGET, true, false).unwrap();
        let (mut cl, _io) = gemm::build(&cfg, &p).into_cluster(cfg.clone());
        cl.fast_forward = true;
        let stats = cl.try_run(BUDGET).unwrap();
        // The compute chunk is byte-identical to a standalone run; only
        // the system timeline adds staging/merge around it.
        assert_eq!(run.info.clusters[0].cycles, stats.cycles);
        assert_eq!(run.info.clusters[0].instructions, stats.instructions);
        assert_eq!(run.info.link_words, 0);
    }

    #[test]
    fn overchunked_problems_are_refused_typed() {
        // 8 block-rows of gemm m=32 cannot cover a tiny 8-way split at
        // m=8 (2 block-rows < 8 clusters).
        let topo = Topology::split(&ClusterConfig::tiny(), 8).unwrap();
        let k = SystemKernel::Gemm(gemm::GemmParams { m: 8, n: 16, k: 16 });
        let e = run_system(&topo, &k, 1, BUDGET, true, false).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Unsupported);
        let k = SystemKernel::Fft(fft::FftParams { batch: 4, n: 64 });
        let e = run_system(&topo, &k, 1, BUDGET, true, false).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Unsupported);
    }

    #[test]
    fn resolve_kernel_is_typed() {
        assert!(matches!(
            resolve_kernel("gemm", Scale::Fast),
            Ok(SystemKernel::Gemm(p)) if p.m == 128
        ));
        assert!(matches!(
            resolve_kernel("fft", Scale::Full),
            Ok(SystemKernel::Fft(p)) if p.batch == 64 && p.n == 4096
        ));
        assert_eq!(
            resolve_kernel("axpy", Scale::Fast).unwrap_err().kind(),
            ErrorKind::UnknownWorkload
        );
    }
}
