//! The system layer: a [`Topology`] of TeraPool clusters stepped as one
//! scale-out machine (ROADMAP item 1). One kernel is chunked
//! data-parallel across the clusters; the system scheduler pays for
//! every word that crosses a chip boundary. Two engines share the cost
//! model:
//!
//! **Phase-serial reference** ([`run_system_phases`]) — the five-phase
//! timeline the scale-out layer started with:
//!
//! 1. **Staging** — each cluster's private inputs stream in from the
//!    off-chip memory node over the *shared* main-memory bus
//!    (round-robin arbitration, one grant of `memory.width` words per
//!    cycle, plus the access latency once per stream).
//! 2. **Halo broadcast** — operands shared by every cluster (the GEMM B
//!    matrix, the FFT twiddle table) are staged once, on cluster 0, and
//!    forwarded to the others over the inter-cluster links
//!    (store-and-forward per hop: occupy `⌈words/width⌉` cycles, then
//!    the hop latency; links are FIFO, transfers are processed in fixed
//!    ascending-destination order over [`Topology::route`]'s
//!    deterministic BFS routes).
//! 3. **Start barrier** — compute starts globally at `T0 = max` over
//!    every cluster's readiness.
//! 4. **Compute** — every cluster runs its chunk to completion on the
//!    serial reference engine. Chunks exchange *no* mid-kernel traffic,
//!    so run-to-completion and cycle-lockstep interleavings commute, and
//!    stepping the clusters **cluster-parallel on host threads**
//!    ([`crate::parallel::scatter`]) is bit-identical to the serial
//!    order — `rust/tests/system_equiv.rs` pins this at 1/2/4 threads.
//! 5. **Merge** — each cluster's output band streams back to the memory
//!    node over the shared bus (same arbiter), becoming eligible when
//!    that cluster finishes.
//!
//! **Pipelined engine** ([`run_system_sliced`], the default behind
//! [`run_system`]) — the comm/compute-overlap optimization the paper's
//! full-bandwidth main-memory link exists to enable. Each cluster's band
//! is sub-sliced into `S` slices (GEMM: a 2-D `sr×sc` tile grid per
//! [`gemm::slice_grid`]; FFT: frame sub-bands); slice `t+1`'s bus
//! staging and halo delivery are double-buffered behind slice `t`'s
//! compute, and a slice's merge streams back the moment its compute
//! retires — no global barrier at `S > 1`. The five phase-episodes
//! collapse into **one** availability-ordered streaming bus arbiter over
//! all `2·parts·S` transfers (stage transfers first, then merge
//! transfers, unit-major) with a single persistent round-robin pointer
//! and the same ascending tie-breaks. At `S = 1` the schedule provably
//! degenerates to the phase-serial timeline (same grants, same `T0`,
//! same cycle counts — the module tests and `system_equiv.rs` pin this
//! bit-for-bit), so `--slices 1` *is* the reference.
//!
//! Determinism at any `S` and any `host_threads`: functional state is
//! fully staged per (cluster, slice) unit before compute (the links and
//! bus carry timing and traffic accounting, never unique bytes), every
//! unit's program depends only on its tile coordinates, and the GEMM
//! K-loop phase is keyed on the *global* block index — so the merged
//! memory-node image is byte-identical across engines, slicings, and
//! host-thread counts.

use std::sync::Mutex;

use crate::cluster::{Cluster, RunStats};
use crate::config::Scale;
use crate::err;
use crate::errors::{Error, Result};
use crate::kernels::{allclose_verdict, chunk_range, fft, gemm, Staged};
use crate::parallel::scatter;
use crate::report::{SystemClusterInfo, SystemInfo, SystemLinkInfo, Verdict};
use crate::topology::Topology;

/// A kernel the system layer knows how to chunk across clusters. The
/// single-cluster [`crate::kernels::Workload`] registry stays the source
/// of truth for the *math*; this enum only names the kernels whose
/// builders expose band staging (`build_band`) and slice staging
/// (`build_tile` / `build_band_slice`).
#[derive(Debug, Clone, Copy)]
pub enum SystemKernel {
    Gemm(gemm::GemmParams),
    Fft(fft::FftParams),
}

/// Resolve a registry kind to a chunked system kernel at `scale`'s
/// default problem size. Kinds without a band builder are a typed
/// `UnknownWorkload` error.
pub fn resolve_kernel(kind: &str, scale: Scale) -> Result<SystemKernel> {
    match kind {
        "gemm" => {
            let e = scale.pick(256, 128);
            Ok(SystemKernel::Gemm(gemm::GemmParams { m: e, n: e, k: e }))
        }
        "fft" => Ok(SystemKernel::Fft(fft::FftParams {
            batch: scale.pick(64, 16),
            n: scale.pick(4096, 1024),
        })),
        other => Err(Error::unknown_workload(other, &["gemm", "fft"])),
    }
}

/// A finished system run: what [`crate::session::Session::system`]
/// reports, plus the merged memory-node image for differential tests.
#[derive(Debug, Clone)]
pub struct SystemRun {
    /// `<kernel>@<topology>`, e.g. `gemm-256x256x256@quad`; pipelined
    /// runs append `~s<S>`.
    pub name: String,
    /// Aggregate stats: `cycles` is the full system timeline
    /// (staging + compute + merge), counters are sums over clusters,
    /// AMAT is the request-count-weighted average.
    pub stats: RunStats,
    pub info: SystemInfo,
    pub verdict: Verdict,
    /// The memory node's final image — the merged system output.
    pub output: Vec<f32>,
}

/// One shared-operand broadcast from cluster 0 to `dst` (phase engine).
struct Bcast {
    dst: usize,
    /// Words the links carry: the *unique* operand words (each cluster
    /// re-replicates locally where the kernel wants replicas).
    words: u64,
    deliver: Deliver,
}

/// How a broadcast's payload lands functionally in the destination L1.
enum Deliver {
    /// Copy `words` f32 verbatim from cluster 0's `src_base`.
    Copy { src_base: u32, dst_base: u32, words: usize },
    /// Gather the `n` canonical table entries out of cluster 0's
    /// copy-interleaved layout and re-interleave for the destination's
    /// replica count (replica counts scale with cluster size, so the
    /// two ends of a link may disagree).
    Replicate { src_base: u32, src_copies: usize, dst_base: u32, dst_copies: usize, n: usize },
}

/// The staged chunking plan of the phase-serial engine: per-cluster
/// builds, broadcast and merge descriptors, and the memory-node image
/// size.
struct Plan {
    /// Kernel instance name (without the topology suffix).
    name: String,
    staged: Vec<Staged>,
    bcasts: Vec<Bcast>,
    /// Per cluster: (L1 base, words, offset into the memory image).
    merges: Vec<Vec<(u32, usize, usize)>>,
    out_len: usize,
}

/// Refuse chunkings that would leave a cluster with an empty band — a
/// typed `Unsupported`, mirroring the estimate-census refusal: the
/// combination is declaratively out of scope, never silently reshaped.
fn ensure_chunks(total: usize, parts: usize, what: &str) -> Result<()> {
    for c in 0..parts {
        if chunk_range(total, c, parts).is_empty() {
            return Err(Error::unsupported(format!(
                "{what}: {total} bands cannot cover {parts} clusters (cluster {c}'s \
                 band would be empty); use fewer clusters or a bigger problem"
            )));
        }
    }
    Ok(())
}

/// [`ensure_chunks`]' sibling for the pipelined engine's sub-slicing:
/// refuse slice counts that would leave a (cluster, slice) unit empty.
fn ensure_slices(total: usize, slices: usize, what: &str) -> Result<()> {
    for t in 0..slices {
        if chunk_range(total, t, slices).is_empty() {
            return Err(Error::unsupported(format!(
                "{what}: {total} units cannot cover {slices} slices (slice {t} would \
                 be empty); lower --slices or use a bigger problem"
            )));
        }
    }
    Ok(())
}

fn stage(topo: &Topology, kernel: &SystemKernel) -> Result<Plan> {
    let parts = topo.clusters.len();
    Ok(match kernel {
        SystemKernel::Gemm(p) => {
            let name = format!("gemm-{}x{}x{}", p.m, p.n, p.k);
            ensure_chunks(p.m / 4, parts, &name)?;
            let mut staged = Vec::with_capacity(parts);
            let mut bands = Vec::with_capacity(parts);
            for c in 0..parts {
                let (s, b) = gemm::build_band(&topo.clusters[c].cfg, p, c, parts, c == 0);
                staged.push(s);
                bands.push(b);
            }
            let bcasts = (1..parts)
                .map(|d| Bcast {
                    dst: d,
                    words: (p.k * p.n) as u64,
                    deliver: Deliver::Copy {
                        src_base: bands[0].b_base,
                        dst_base: bands[d].b_base,
                        words: p.k * p.n,
                    },
                })
                .collect();
            let merges = bands
                .iter()
                .map(|b| vec![(b.c_base, b.rows * p.n, b.row0 * p.n)])
                .collect();
            Plan { name, staged, bcasts, merges, out_len: p.m * p.n }
        }
        SystemKernel::Fft(p) => {
            let name = format!("fft-{}x{}", p.batch, p.n);
            ensure_chunks(p.batch, parts, &name)?;
            let mut staged = Vec::with_capacity(parts);
            let mut bands = Vec::with_capacity(parts);
            for c in 0..parts {
                let (s, b) = fft::build_band(&topo.clusters[c].cfg, p, c, parts, c == 0);
                staged.push(s);
                bands.push(b);
            }
            let mut bcasts = Vec::new();
            for d in 1..parts {
                let (src, dst) = (&bands[0], &bands[d]);
                for (sb, db) in [
                    (src.tw_re_base, dst.tw_re_base),
                    (src.tw_im_base, dst.tw_im_base),
                ] {
                    bcasts.push(Bcast {
                        dst: d,
                        words: p.n as u64,
                        deliver: Deliver::Replicate {
                            src_base: sb,
                            src_copies: src.tw_words / p.n,
                            dst_base: db,
                            dst_copies: dst.tw_words / p.n,
                            n: p.n,
                        },
                    });
                }
            }
            // Memory image: the re planes of all frames, then the im
            // planes (a single cluster instead lays im directly after
            // its own re plane — the system image is the host-facing
            // canonical layout).
            let merges = bands
                .iter()
                .map(|b| {
                    vec![
                        (b.re_base, b.frames * p.n, b.f0 * p.n),
                        (b.im_base, b.frames * p.n, (p.batch + b.f0) * p.n),
                    ]
                })
                .collect();
            Plan { name, staged, bcasts, merges, out_len: 2 * p.batch * p.n }
        }
    })
}

/// Outcome of one shared-bus episode (staging or merge).
struct BusOutcome {
    /// Per-source cycle its last word has landed (grant + access
    /// latency); sources with no words keep their `avail` time.
    finish: Vec<u64>,
    /// Cycles the bus spent granting.
    busy: u64,
    /// Words moved in this episode.
    words: u64,
    /// The cycle of every grant, in grant order — what the overlap
    /// accounting classifies as exposed or hidden.
    grants: Vec<u64>,
}

/// The shared main-memory bus: source `c` becomes eligible at
/// `avail[c]` with `words[c]` words to move; each cycle the bus grants
/// up to `width` words to **one** eligible source, round-robin starting
/// after the previous grantee. Deterministic: ties break on ascending
/// index from the rotating pointer.
fn bus_sim(avail: &[u64], words: &[u64], width: usize, latency: u64) -> BusOutcome {
    let n = avail.len();
    let mut rem = words.to_vec();
    let mut finish = avail.to_vec();
    let width = width.max(1) as u64;
    let mut grants = Vec::new();
    let (mut busy, mut t, mut rr) = (0u64, 0u64, 0usize);
    while rem.iter().any(|&r| r > 0) {
        if !(0..n).any(|c| rem[c] > 0 && avail[c] <= t) {
            // Idle until the earliest pending source is available.
            t = (0..n).filter(|&c| rem[c] > 0).map(|c| avail[c]).min().unwrap();
            continue;
        }
        let pick = (0..n)
            .map(|i| (rr + i) % n)
            .find(|&c| rem[c] > 0 && avail[c] <= t)
            .unwrap();
        rem[pick] = rem[pick].saturating_sub(width);
        busy += 1;
        grants.push(t);
        if rem[pick] == 0 {
            finish[pick] = t + 1 + latency;
        }
        rr = (pick + 1) % n;
        t += 1;
    }
    BusOutcome { finish, busy, words: words.iter().sum(), grants }
}

/// Classify bus grant cycles against the union of compute windows:
/// a grant inside any `[start, end)` window is **hidden** behind
/// compute, everything else is **exposed** wall-clock the timeline pays
/// for. `exposed + hidden == grants.len()` by construction.
fn split_hidden(grants: &[u64], windows: &[(u64, u64)]) -> (u64, u64) {
    let mut iv: Vec<(u64, u64)> = windows.iter().copied().filter(|w| w.1 > w.0).collect();
    iv.sort_unstable();
    let mut merged: Vec<(u64, u64)> = Vec::with_capacity(iv.len());
    for w in iv {
        match merged.last_mut() {
            Some(m) if w.0 <= m.1 => m.1 = m.1.max(w.1),
            _ => merged.push(w),
        }
    }
    let (mut exposed, mut hidden) = (0u64, 0u64);
    for &g in grants {
        let idx = merged.partition_point(|&(s, _)| s <= g);
        if idx > 0 && g < merged[idx - 1].1 {
            hidden += 1;
        } else {
            exposed += 1;
        }
    }
    (exposed, hidden)
}

/// Aggregate per-unit compute stats over the system timeline: counters
/// sum, `cycles` is the full timeline, AMAT is the request-weighted
/// average over every unit.
fn aggregate_stats(per: &[RunStats], t_end: u64, num_pes: usize) -> RunStats {
    let mut agg = per[0].clone();
    agg.cycles = t_end;
    agg.num_pes = num_pes;
    let (mut w_total, mut w_class) = (0.0f64, [0.0f64; 4]);
    let mut reqs_total = 0u64;
    for (i, s) in per.iter().enumerate() {
        if i > 0 {
            agg.instructions += s.instructions;
            agg.flops += s.flops;
            agg.stall_raw += s.stall_raw;
            agg.stall_lsu += s.stall_lsu;
            agg.stall_ctrl += s.stall_ctrl;
            agg.stall_synch += s.stall_synch;
            agg.loads += s.loads;
            agg.stores += s.stores;
            agg.atomics += s.atomics;
            for k in 0..4 {
                agg.reqs_per_class[k] += s.reqs_per_class[k];
                agg.burst_reqs_per_class[k] += s.burst_reqs_per_class[k];
                agg.burst_words_per_class[k] += s.burst_words_per_class[k];
            }
        }
        for k in 0..4 {
            w_class[k] += s.amat_per_class[k] * s.reqs_per_class[k] as f64;
            w_total += s.amat_per_class[k] * s.reqs_per_class[k] as f64;
            reqs_total += s.reqs_per_class[k];
        }
    }
    agg.amat = if reqs_total > 0 { w_total / reqs_total as f64 } else { 0.0 };
    for k in 0..4 {
        agg.amat_per_class[k] = if agg.reqs_per_class[k] > 0 {
            w_class[k] / agg.reqs_per_class[k] as f64
        } else {
            0.0
        };
    }
    agg
}

/// Check the merged memory image against the kernel's host reference.
fn system_verdict(kernel: &SystemKernel, output: &[f32], checking: bool) -> Verdict {
    if !checking {
        return Verdict::NotChecked;
    }
    match kernel {
        SystemKernel::Gemm(p) => {
            allclose_verdict(output, &gemm::reference(p), 2e-2, "system gemm vs host reference")
        }
        SystemKernel::Fft(p) => {
            if p.batch * p.n * p.n > (1 << 29) {
                // The O(n²) host DFT is intractable at this size.
                Verdict::NotChecked
            } else {
                let (re, im) = fft::reference(p);
                let bn = p.batch * p.n;
                match allclose_verdict(&output[..bn], &re, 5e-2, "system fft re-plane vs host DFT") {
                    Verdict::Passed { .. } => allclose_verdict(
                        &output[bn..],
                        &im,
                        5e-2,
                        "system fft re+im planes vs host DFT",
                    ),
                    failed => failed,
                }
            }
        }
    }
}

/// Run `kernel` chunked across the clusters of `topo` on the pipelined
/// engine at `S = 1` — the phase-serial timeline, reproduced bit-for-bit
/// (`run_system_phases` stays available as the independent differential
/// reference). `host_threads > 1` steps compute cluster-parallel
/// (bit-identical). `max_cycles` bounds each unit's compute chunk (typed
/// `MaxCyclesExceeded`, prefixed with the cluster name). `checking`
/// compares the merged memory image against the kernel's host reference.
pub fn run_system(
    topo: &Topology,
    kernel: &SystemKernel,
    host_threads: usize,
    max_cycles: u64,
    fast_forward: bool,
    checking: bool,
) -> Result<SystemRun> {
    run_system_sliced(topo, kernel, host_threads, max_cycles, fast_forward, checking, 1)
}

/// The phase-serial reference engine — the five-phase timeline of the
/// module docs, kept verbatim as the differential oracle the pipelined
/// engine is pinned against (`rust/tests/system_equiv.rs` and the module
/// tests compare images, cycles, and full `SystemInfo`).
pub fn run_system_phases(
    topo: &Topology,
    kernel: &SystemKernel,
    host_threads: usize,
    max_cycles: u64,
    fast_forward: bool,
    checking: bool,
) -> Result<SystemRun> {
    let parts = topo.clusters.len();
    let plan = stage(topo, kernel)?;

    // Phase 1 — staging: every cluster's functionally-staged words
    // stream from the memory node over the shared bus.
    let stage_words: Vec<u64> = plan
        .staged
        .iter()
        .map(|s| s.inputs.iter().map(|(_, d)| d.len() as u64).sum())
        .collect();
    let stage_avail = vec![0u64; parts];
    let stage_bus = bus_sim(&stage_avail, &stage_words, topo.memory.width, topo.memory.latency);

    let mut clusters: Vec<Cluster> = Vec::with_capacity(parts);
    for (c, staged) in plan.staged.into_iter().enumerate() {
        assert!(staged.dma.is_none(), "system runs are L1-resident (no HBML plan)");
        let (mut cl, _io) = staged.into_cluster(topo.clusters[c].cfg.clone());
        cl.fast_forward = fast_forward;
        clusters.push(cl);
    }

    // Phase 2 — halo broadcasts over the links, in fixed (ascending
    // destination, plane) order; a transfer leaves cluster 0 once its
    // staging finished, holds each route hop for ⌈words/width⌉ cycles
    // (FIFO per link), then pays the hop latency.
    let mut link_words = vec![0u64; topo.links.len()];
    let mut link_busy = vec![0u64; topo.links.len()];
    let mut link_free = vec![0u64; topo.links.len()];
    let mut arrival = vec![0u64; parts];
    for b in &plan.bcasts {
        let mut ready = stage_bus.finish[0];
        for li in topo.route(0, b.dst)? {
            let l = &topo.links[li];
            let occ = b.words.div_ceil(l.width as u64).max(1);
            let start = ready.max(link_free[li]);
            link_free[li] = start + occ;
            ready = start + occ + l.latency;
            link_words[li] += b.words;
            link_busy[li] += occ;
        }
        arrival[b.dst] = arrival[b.dst].max(ready);
        // Functional delivery (the timing above is the cost model; the
        // bytes move here).
        match b.deliver {
            Deliver::Copy { src_base, dst_base, words } => {
                let data = clusters[0].l1.read_slice(src_base, words);
                clusters[b.dst].l1.write_slice(dst_base, &data);
            }
            Deliver::Replicate { src_base, src_copies, dst_base, dst_copies, n } => {
                let src = clusters[0].l1.read_slice(src_base, src_copies * n);
                let mut out = vec![0.0f32; dst_copies * n];
                for e in 0..n {
                    let v = src[e * src_copies];
                    for c in 0..dst_copies {
                        out[e * dst_copies + c] = v;
                    }
                }
                clusters[b.dst].l1.write_slice(dst_base, &out);
            }
        }
    }

    // Phase 3 — the system start barrier.
    let t0 = (0..parts)
        .map(|c| stage_bus.finish[c].max(arrival[c]))
        .max()
        .unwrap_or(0);

    // Phase 4 — compute, cluster-parallel across host threads. With
    // `host_threads <= 1` `scatter` degenerates to an in-order loop on
    // this thread — the serial reference order of the differential
    // suite. Chunks share no state, so the results cannot depend on the
    // interleaving.
    let cells: Vec<Mutex<Cluster>> = clusters.into_iter().map(Mutex::new).collect();
    let results: Vec<Result<RunStats>> = scatter(parts, host_threads, |i| {
        let mut cl = cells[i].lock().unwrap();
        cl.try_run_threads(max_cycles, 1)
            .map_err(|e| e.prefixed(&topo.clusters[i].name))
    });
    let mut per: Vec<RunStats> = Vec::with_capacity(parts);
    for r in results {
        per.push(r?);
    }
    let clusters: Vec<Cluster> = cells
        .into_iter()
        .map(|m| m.into_inner().unwrap())
        .collect();
    let compute_cycles = per.iter().map(|s| s.cycles).max().unwrap_or(0);
    let compute_done: Vec<u64> = per.iter().map(|s| t0 + s.cycles).collect();

    // Phase 5 — merge each cluster's output band(s) into the memory
    // node over the shared bus; a cluster's band is eligible once that
    // cluster finished.
    let merge_words: Vec<u64> = plan
        .merges
        .iter()
        .map(|ms| ms.iter().map(|&(_, w, _)| w as u64).sum())
        .collect();
    let merge_bus = bus_sim(&compute_done, &merge_words, topo.memory.width, topo.memory.latency);
    let t_end = merge_bus
        .finish
        .iter()
        .zip(&compute_done)
        .map(|(&f, &d)| f.max(d))
        .max()
        .unwrap_or(t0);

    let mut output = vec![0.0f32; plan.out_len];
    for (c, ms) in plan.merges.iter().enumerate() {
        for &(base, words, off) in ms {
            let data = clusters[c].l1.read_slice(base, words);
            output[off..off + words].copy_from_slice(&data);
        }
    }

    let agg = aggregate_stats(&per, t_end, topo.total_pes());

    // Overlap accounting for the phase timeline: compute windows are
    // one per cluster, `[t0, compute_done)`; stage grants all precede
    // `t0`, merge grants can hide behind still-running clusters.
    let windows: Vec<(u64, u64)> = (0..parts).map(|c| (t0, compute_done[c])).collect();
    let mut grants = stage_bus.grants.clone();
    grants.extend_from_slice(&merge_bus.grants);
    let (exposed, hidden) = split_hidden(&grants, &windows);

    let info = SystemInfo {
        topology: topo.name.clone(),
        clusters: (0..parts)
            .map(|c| SystemClusterInfo {
                name: topo.clusters[c].name.clone(),
                num_pes: per[c].num_pes,
                cycles: per[c].cycles,
                instructions: per[c].instructions,
                flops: per[c].flops,
                slice_windows: vec![(t0, compute_done[c])],
            })
            .collect(),
        links: (0..topo.links.len())
            .map(|i| SystemLinkInfo {
                name: topo.link_name(i),
                words: link_words[i],
                busy_cycles: link_busy[i],
            })
            .collect(),
        bus_words: stage_bus.words + merge_bus.words,
        bus_busy_cycles: stage_bus.busy + merge_bus.busy,
        stage_cycles: t0,
        compute_cycles,
        merge_cycles: t_end.saturating_sub(t0 + compute_cycles),
        link_words: link_words.iter().sum(),
        slices: 1,
        exposed_bus_cycles: exposed,
        hidden_bus_cycles: hidden,
    };

    let verdict = system_verdict(kernel, &output, checking);

    Ok(SystemRun {
        name: format!("{}@{}", plan.name, topo.name),
        stats: agg,
        info,
        verdict,
        output,
    })
}

// ---------------------------------------------------------------------
// The pipelined engine.
// ---------------------------------------------------------------------

/// One strided copy from a unit's L1 into the memory-node image:
/// `rows` runs of `row_words`, L1 rows `l1_pitch` apart, image rows
/// `image_pitch` apart (GEMM C tiles are strided at the full-problem
/// pitch `n`; FFT planes are one contiguous run).
struct MergeSeg {
    l1_base: u32,
    image_off: usize,
    rows: usize,
    row_words: usize,
    l1_pitch: usize,
    image_pitch: usize,
}

/// Scheduling metadata of one (cluster, slice) unit. `stage_words` is
/// the unit's shared-bus charge (operands charged elsewhere — a reused A
/// row-slice, a broadcast B panel — charge 0 here); `extra_deps` are
/// unit indices whose *stage finish* gates this unit's compute (the unit
/// that streamed its A rows, the cluster-0 unit that streamed its B
/// panel); `halo` is the broadcast slot whose arrival gates compute on
/// non-root clusters.
struct SliceMeta {
    stage_words: u64,
    extra_deps: Vec<usize>,
    halo: Option<usize>,
    segs: Vec<MergeSeg>,
}

/// One link broadcast of the pipelined plan: fires (in fixed global
/// order) once unit `ready_dep`'s staging finishes, lands in arrival
/// slot `slot`.
struct SlicedBcast {
    dst: usize,
    words: u64,
    ready_dep: usize,
    slot: usize,
}

/// The sliced chunking plan: one `Staged` build per (cluster, slice)
/// unit, unit-major (`unit = cluster * slices + slice`), with every
/// operand staged functionally (links/bus carry only timing).
struct SlicedPlan {
    name: String,
    slices: usize,
    staged: Vec<Staged>,
    units: Vec<SliceMeta>,
    bcasts: Vec<SlicedBcast>,
    n_slots: usize,
    out_len: usize,
}

fn stage_sliced(topo: &Topology, kernel: &SystemKernel, slices: usize) -> Result<SlicedPlan> {
    let parts = topo.clusters.len();
    let s = slices;
    Ok(match kernel {
        SystemKernel::Gemm(p) => {
            // 2-D tile grid: row-slices of the cluster's band × column
            // panels of the whole problem. Column slicing is what lets
            // the *shared* B staging pipeline too — panel j streams
            // while panel j-1's tiles compute.
            let (sr, sc) = gemm::slice_grid(s);
            let name = format!("gemm-{}x{}x{}", p.m, p.n, p.k);
            ensure_chunks(p.m / 4, parts, &name)?;
            ensure_slices(p.n / 4, sc, &format!("{name} column panels"))?;
            let mut staged = Vec::with_capacity(parts * s);
            let mut units = Vec::with_capacity(parts * s);
            let mut panel_cols = vec![0usize; sc];
            for c in 0..parts {
                let band = chunk_range(p.m / 4, c, parts);
                ensure_slices(band.end - band.start, sr, &format!("{name} cluster {c} row band"))?;
                for i in 0..sr {
                    for j in 0..sc {
                        let (st, tile) =
                            gemm::build_tile(&topo.clusters[c].cfg, p, c, parts, i, sr, j, sc, true);
                        panel_cols[j] = tile.cols;
                        // Bus charges: the A row-slice streams once, at
                        // the row's first tile; the B panel streams
                        // once, at cluster 0's first row (other
                        // clusters receive it over the links).
                        let a_words = if j == 0 { (tile.rows * p.k) as u64 } else { 0 };
                        let b_words = if c == 0 && i == 0 { (p.k * tile.cols) as u64 } else { 0 };
                        let stage_words = a_words + b_words;
                        let mut extra_deps = vec![c * s + i * sc];
                        if c == 0 {
                            extra_deps.push(j);
                        }
                        let halo = if c > 0 { Some((c - 1) * sc + j) } else { None };
                        let segs = vec![MergeSeg {
                            l1_base: tile.c_base,
                            image_off: tile.row0 * p.n + tile.col0,
                            rows: tile.rows,
                            row_words: tile.cols,
                            l1_pitch: tile.cols,
                            image_pitch: p.n,
                        }];
                        staged.push(st);
                        units.push(SliceMeta { stage_words, extra_deps, halo, segs });
                    }
                }
            }
            let mut bcasts = Vec::new();
            for j in 0..sc {
                for d in 1..parts {
                    bcasts.push(SlicedBcast {
                        dst: d,
                        words: (p.k * panel_cols[j]) as u64,
                        ready_dep: j,
                        slot: (d - 1) * sc + j,
                    });
                }
            }
            SlicedPlan {
                name,
                slices: s,
                staged,
                units,
                bcasts,
                n_slots: parts.saturating_sub(1) * sc,
                out_len: p.m * p.n,
            }
        }
        SystemKernel::Fft(p) => {
            // 1-D frame slicing: frames are independent transforms, so
            // any frame partition computes bit-identical planes.
            let name = format!("fft-{}x{}", p.batch, p.n);
            ensure_chunks(p.batch, parts, &name)?;
            let mut staged = Vec::with_capacity(parts * s);
            let mut units = Vec::with_capacity(parts * s);
            for c in 0..parts {
                let band = chunk_range(p.batch, c, parts);
                ensure_slices(band.end - band.start, s, &format!("{name} cluster {c} frame band"))?;
                for t in 0..s {
                    let (st, b) = fft::build_band_slice(&topo.clusters[c].cfg, p, c, parts, t, s, true);
                    // The twiddle table streams once, with cluster 0's
                    // first slice; everyone else gets it over the links
                    // (the arrival gates all of that cluster's slices).
                    let tw_charge = if c == 0 && t == 0 { (2 * b.tw_words) as u64 } else { 0 };
                    let stage_words = (2 * b.frames * p.n) as u64 + tw_charge;
                    let halo = if c > 0 { Some(c - 1) } else { None };
                    let segs = vec![
                        MergeSeg {
                            l1_base: b.re_base,
                            image_off: b.f0 * p.n,
                            rows: 1,
                            row_words: b.frames * p.n,
                            l1_pitch: 0,
                            image_pitch: 0,
                        },
                        MergeSeg {
                            l1_base: b.im_base,
                            image_off: (p.batch + b.f0) * p.n,
                            rows: 1,
                            row_words: b.frames * p.n,
                            l1_pitch: 0,
                            image_pitch: 0,
                        },
                    ];
                    staged.push(st);
                    units.push(SliceMeta { stage_words, extra_deps: Vec::new(), halo, segs });
                }
            }
            let mut bcasts = Vec::new();
            for d in 1..parts {
                for _plane in 0..2 {
                    bcasts.push(SlicedBcast { dst: d, words: p.n as u64, ready_dep: 0, slot: d - 1 });
                }
            }
            SlicedPlan {
                name,
                slices: s,
                staged,
                units,
                bcasts,
                n_slots: parts.saturating_sub(1),
                out_len: 2 * p.batch * p.n,
            }
        }
    })
}

/// The streaming co-simulation of the pipelined timeline. Transfer ids
/// `0..n` are the units' stage transfers, `n..2n` their merge transfers
/// (both unit-major); one persistent round-robin pointer arbitrates the
/// shared bus over all of them, and every grant completion triggers a
/// fixpoint [`Pipeline::resolve`] pass that advances broadcasts, compute
/// schedules, and newly-known availability times. At `S = 1` the
/// schedule degenerates to the phase-serial episodes exactly: merge
/// transfers only become available after the global barrier, past every
/// stage grant, so the single pointer scans them in the same ascending
/// order a fresh episode would.
struct Pipeline {
    /// Unit count (`parts * slices`).
    n: usize,
    s: usize,
    width: u64,
    latency: u64,
    /// Remaining words per transfer id (stage ids then merge ids).
    rem: Vec<u64>,
    /// Availability per transfer id; `None` = not yet known.
    avail: Vec<Option<u64>>,
    /// Cycle the transfer's last word lands (grant + access latency);
    /// zero-word transfers finish at their availability.
    finish: Vec<Option<u64>>,
    /// Per-unit compute cycle counts (from the functional runs).
    cycles: Vec<u64>,
    compute_start: Vec<Option<u64>>,
    compute_end: Vec<Option<u64>>,
    /// Next unscheduled slice per cluster (`S > 1` scheduling).
    next_slice: Vec<usize>,
    /// Per-slot broadcast arrival (set once every bcast of the slot
    /// fired).
    arrivals: Vec<Option<u64>>,
    slot_hi: Vec<u64>,
    slot_pending: Vec<usize>,
    /// Next broadcast to fire — broadcasts fire in fixed global order.
    next_bcast: usize,
    /// BFS route per broadcast, resolved up front.
    routes: Vec<Vec<usize>>,
    link_words: Vec<u64>,
    link_busy: Vec<u64>,
    link_free: Vec<u64>,
    /// The global start barrier (`S = 1` only).
    t0: Option<u64>,
    grants: Vec<u64>,
    busy: u64,
}

impl Pipeline {
    fn new(plan: &SlicedPlan, topo: &Topology, cycles: Vec<u64>) -> Result<Pipeline> {
        let n = plan.units.len();
        let s = plan.slices;
        let mut rem = Vec::with_capacity(2 * n);
        for m in &plan.units {
            rem.push(m.stage_words);
        }
        for m in &plan.units {
            rem.push(m.segs.iter().map(|g| (g.rows * g.row_words) as u64).sum());
        }
        let mut routes = Vec::with_capacity(plan.bcasts.len());
        for b in &plan.bcasts {
            routes.push(topo.route(0, b.dst)?);
        }
        let mut slot_pending = vec![0usize; plan.n_slots];
        for b in &plan.bcasts {
            slot_pending[b.slot] += 1;
        }
        let mut p = Pipeline {
            n,
            s,
            width: topo.memory.width.max(1) as u64,
            latency: topo.memory.latency,
            rem,
            avail: vec![None; 2 * n],
            finish: vec![None; 2 * n],
            cycles,
            compute_start: vec![None; n],
            compute_end: vec![None; n],
            next_slice: vec![0; n / s],
            arrivals: vec![None; plan.n_slots],
            slot_hi: vec![0; plan.n_slots],
            slot_pending,
            next_bcast: 0,
            routes,
            link_words: vec![0; topo.links.len()],
            link_busy: vec![0; topo.links.len()],
            link_free: vec![0; topo.links.len()],
            t0: None,
            grants: Vec::new(),
            busy: 0,
        };
        // Every cluster's first slice can start staging at cycle 0; the
        // rest become available as the double-buffer frees up.
        for c in 0..(n / s) {
            p.set_avail(c * s, 0);
        }
        Ok(p)
    }

    /// Record a transfer's availability (first writer wins); zero-word
    /// transfers finish the moment they become available, like the
    /// episode arbiter's no-words sources.
    fn set_avail(&mut self, x: usize, at: u64) {
        if self.avail[x].is_some() {
            return;
        }
        self.avail[x] = Some(at);
        if self.rem[x] == 0 {
            self.finish[x] = Some(at);
        }
    }

    fn eligible(&self, x: usize, t: u64) -> bool {
        self.rem[x] > 0 && matches!(self.avail[x], Some(a) if a <= t)
    }

    /// Earliest cycle unit `u`'s compute inputs are all resident:
    /// its own stage finish, its dependency units' stage finishes, and
    /// (non-root clusters) its halo broadcast arrival. `None` while any
    /// of them is still unknown.
    fn unit_ready(&self, plan: &SlicedPlan, u: usize) -> Option<u64> {
        let mut r = self.finish[u]?;
        for &d in &plan.units[u].extra_deps {
            r = r.max(self.finish[d]?);
        }
        if let Some(slot) = plan.units[u].halo {
            r = r.max(self.arrivals[slot]?);
        }
        Some(r)
    }

    /// Fixpoint propagation: fire broadcasts whose source staging
    /// finished (fixed global order, FIFO links), schedule computes
    /// whose inputs are resident, and release the availability of merge
    /// transfers (at compute end) and next-slice stage transfers (at
    /// compute start — the double-buffer handoff). Loops until nothing
    /// new becomes known.
    fn resolve(&mut self, plan: &SlicedPlan, topo: &Topology) {
        loop {
            let mut progressed = false;

            // Broadcasts, in fixed global order.
            while self.next_bcast < plan.bcasts.len() {
                let b = &plan.bcasts[self.next_bcast];
                let Some(dep) = self.finish[b.ready_dep] else { break };
                let (slot, words) = (b.slot, b.words);
                let mut ready = dep;
                // Each broadcast fires exactly once; taking its route
                // frees the borrow on `self` for the link bookkeeping.
                let route = std::mem::take(&mut self.routes[self.next_bcast]);
                for &li in &route {
                    let l = &topo.links[li];
                    let occ = words.div_ceil(l.width as u64).max(1);
                    let start = ready.max(self.link_free[li]);
                    self.link_free[li] = start + occ;
                    ready = start + occ + l.latency;
                    self.link_words[li] += words;
                    self.link_busy[li] += occ;
                }
                self.slot_hi[slot] = self.slot_hi[slot].max(ready);
                self.slot_pending[slot] -= 1;
                if self.slot_pending[slot] == 0 {
                    self.arrivals[slot] = Some(self.slot_hi[slot]);
                }
                self.next_bcast += 1;
                progressed = true;
            }

            if self.s == 1 {
                // Exact phase-serial degeneracy: a global start barrier
                // at the max over every unit's readiness.
                if self.t0.is_none() {
                    let mut all = Some(0u64);
                    for u in 0..self.n {
                        match self.unit_ready(plan, u) {
                            Some(r) => all = all.map(|m| m.max(r)),
                            None => {
                                all = None;
                                break;
                            }
                        }
                    }
                    if let Some(t0) = all {
                        self.t0 = Some(t0);
                        for u in 0..self.n {
                            let end = t0 + self.cycles[u];
                            self.compute_start[u] = Some(t0);
                            self.compute_end[u] = Some(end);
                            self.set_avail(self.n + u, end);
                        }
                        progressed = true;
                    }
                }
            } else {
                // Pipelined: each cluster runs its slices back-to-back;
                // a slice starts at max(inputs resident, previous slice
                // done) — no cross-cluster barrier.
                for c in 0..(self.n / self.s) {
                    while self.next_slice[c] < self.s {
                        let t = self.next_slice[c];
                        let u = c * self.s + t;
                        let Some(mut start) = self.unit_ready(plan, u) else { break };
                        if t > 0 {
                            start = start.max(self.compute_end[u - 1].unwrap());
                        }
                        let end = start + self.cycles[u];
                        self.compute_start[u] = Some(start);
                        self.compute_end[u] = Some(end);
                        self.set_avail(self.n + u, end);
                        if t + 1 < self.s {
                            self.set_avail(u + 1, start);
                        }
                        self.next_slice[c] = t + 1;
                        progressed = true;
                    }
                }
            }

            if !progressed {
                return;
            }
        }
    }

    /// Drive the shared bus over the whole timeline: one grant of
    /// `width` words per cycle to the first eligible transfer scanning
    /// round-robin from the persistent pointer; idle-jump to the
    /// earliest known availability when nothing is eligible. Every
    /// completed transfer re-resolves the schedule, which can make more
    /// transfers available.
    fn solve(&mut self, plan: &SlicedPlan, topo: &Topology) -> Result<()> {
        let n2 = 2 * self.n;
        self.resolve(plan, topo);
        let (mut t, mut rr) = (0u64, 0usize);
        while (0..n2).any(|x| self.rem[x] > 0) {
            if !(0..n2).any(|x| self.eligible(x, t)) {
                let next = (0..n2)
                    .filter(|&x| self.rem[x] > 0)
                    .filter_map(|x| self.avail[x])
                    .min();
                let Some(next) = next else {
                    // Provably unreachable: every cluster's slice-0
                    // stage is available at cycle 0 and the dependency
                    // DAG is grounded there — kept as a typed guard so
                    // a scheduling bug cannot become a hang.
                    return Err(err!(
                        "system pipeline solver stalled: no pending transfer has a \
                         known availability (internal scheduling bug)"
                    ));
                };
                debug_assert!(next > t);
                t = next;
                continue;
            }
            let pick = (0..n2)
                .map(|i| (rr + i) % n2)
                .find(|&x| self.eligible(x, t))
                .unwrap();
            self.rem[pick] = self.rem[pick].saturating_sub(self.width);
            self.busy += 1;
            self.grants.push(t);
            rr = (pick + 1) % n2;
            if self.rem[pick] == 0 {
                self.finish[pick] = Some(t + 1 + self.latency);
                self.resolve(plan, topo);
            }
            t += 1;
        }
        self.resolve(plan, topo);
        Ok(())
    }
}

/// Run `kernel` chunked across the clusters of `topo` on the pipelined
/// engine with `slices` sub-slices per cluster band. `slices = 1`
/// reproduces the phase-serial timeline bit-for-bit; `slices > 1`
/// double-buffers staging and streams merges behind compute. The merged
/// memory image is byte-identical at any `slices` and any
/// `host_threads`.
pub fn run_system_sliced(
    topo: &Topology,
    kernel: &SystemKernel,
    host_threads: usize,
    max_cycles: u64,
    fast_forward: bool,
    checking: bool,
    slices: usize,
) -> Result<SystemRun> {
    let s = slices.max(1);
    let parts = topo.clusters.len();
    let mut plan = stage_sliced(topo, kernel, s)?;
    let n = parts * s;

    // Functional compute first: every unit is fully staged (the plan's
    // timing metadata is solved afterwards), so the units are
    // independent and scatter across host threads bit-identically.
    let staged_list = std::mem::take(&mut plan.staged);
    let mut cells: Vec<Mutex<Cluster>> = Vec::with_capacity(n);
    for (u, staged) in staged_list.into_iter().enumerate() {
        assert!(staged.dma.is_none(), "system runs are L1-resident (no HBML plan)");
        let (mut cl, _io) = staged.into_cluster(topo.clusters[u / s].cfg.clone());
        cl.fast_forward = fast_forward;
        cells.push(Mutex::new(cl));
    }
    let results: Vec<Result<RunStats>> = scatter(n, host_threads, |u| {
        let mut cl = cells[u].lock().unwrap();
        cl.try_run_threads(max_cycles, 1)
            .map_err(|e| e.prefixed(&topo.clusters[u / s].name))
    });
    let mut per: Vec<RunStats> = Vec::with_capacity(n);
    for r in results {
        per.push(r?);
    }
    let clusters: Vec<Cluster> = cells
        .into_iter()
        .map(|m| m.into_inner().unwrap())
        .collect();

    // Timeline co-simulation.
    let cycles: Vec<u64> = per.iter().map(|st| st.cycles).collect();
    let mut pipe = Pipeline::new(&plan, topo, cycles)?;
    pipe.solve(&plan, topo)?;
    for u in 0..n {
        if pipe.compute_end[u].is_none() || pipe.finish[n + u].is_none() {
            return Err(err!(
                "system pipeline left unit {u} unscheduled (internal scheduling bug)"
            ));
        }
    }
    let t_end = (0..n)
        .map(|u| pipe.finish[n + u].unwrap().max(pipe.compute_end[u].unwrap()))
        .max()
        .unwrap_or(0);
    let windows: Vec<(u64, u64)> = (0..n)
        .map(|u| (pipe.compute_start[u].unwrap(), pipe.compute_end[u].unwrap()))
        .collect();
    let (exposed, hidden) = split_hidden(&pipe.grants, &windows);
    let first_start = windows.iter().map(|w| w.0).min().unwrap_or(0);
    let last_end = windows.iter().map(|w| w.1).max().unwrap_or(0);

    // Merge the units' outputs into the memory-node image.
    let mut output = vec![0.0f32; plan.out_len];
    for (u, meta) in plan.units.iter().enumerate() {
        for seg in &meta.segs {
            for r in 0..seg.rows {
                let data = clusters[u]
                    .l1
                    .read_slice(seg.l1_base + (r * seg.l1_pitch) as u32, seg.row_words);
                let off = seg.image_off + r * seg.image_pitch;
                output[off..off + seg.row_words].copy_from_slice(&data);
            }
        }
    }

    let bus_words: u64 = plan
        .units
        .iter()
        .map(|m| {
            m.stage_words
                + m.segs.iter().map(|g| (g.rows * g.row_words) as u64).sum::<u64>()
        })
        .sum();

    let agg = aggregate_stats(&per, t_end, topo.total_pes());

    let info = SystemInfo {
        topology: topo.name.clone(),
        clusters: (0..parts)
            .map(|c| SystemClusterInfo {
                name: topo.clusters[c].name.clone(),
                num_pes: per[c * s].num_pes,
                cycles: (c * s..(c + 1) * s).map(|u| per[u].cycles).sum(),
                instructions: (c * s..(c + 1) * s).map(|u| per[u].instructions).sum(),
                flops: (c * s..(c + 1) * s).map(|u| per[u].flops).sum(),
                slice_windows: windows[c * s..(c + 1) * s].to_vec(),
            })
            .collect(),
        links: (0..topo.links.len())
            .map(|i| SystemLinkInfo {
                name: topo.link_name(i),
                words: pipe.link_words[i],
                busy_cycles: pipe.link_busy[i],
            })
            .collect(),
        bus_words,
        bus_busy_cycles: pipe.busy,
        stage_cycles: first_start,
        compute_cycles: last_end.saturating_sub(first_start),
        merge_cycles: t_end.saturating_sub(last_end),
        link_words: pipe.link_words.iter().sum(),
        slices: s as u64,
        exposed_bus_cycles: exposed,
        hidden_bus_cycles: hidden,
    };

    let verdict = system_verdict(kernel, &output, checking);

    let name = if s == 1 {
        format!("{}@{}", plan.name, topo.name)
    } else {
        format!("{}@{}~s{}", plan.name, topo.name, s)
    };
    Ok(SystemRun { name, stats: agg, info, verdict, output })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::errors::ErrorKind;

    const BUDGET: u64 = 10_000_000;

    #[test]
    fn dual_cluster_gemm_matches_the_host_reference() {
        let topo = Topology::split(&ClusterConfig::tiny(), 2).unwrap();
        let k = SystemKernel::Gemm(gemm::GemmParams { m: 16, n: 16, k: 16 });
        let run = run_system(&topo, &k, 1, BUDGET, true, true).unwrap();
        assert!(matches!(run.verdict, Verdict::Passed { .. }), "{:?}", run.verdict);
        assert_eq!(run.output.len(), 16 * 16);
        // Two clusters, one p2p link carrying one B broadcast.
        assert_eq!(run.info.clusters.len(), 2);
        assert_eq!(run.info.links.len(), 1);
        assert_eq!(run.info.link_words, 16 * 16);
        assert!(run.info.stage_cycles > 0);
        assert!(run.info.merge_cycles > 0);
        // The timeline decomposes exactly.
        assert_eq!(
            run.stats.cycles,
            run.info.stage_cycles + run.info.compute_cycles + run.info.merge_cycles
        );
        // Bus traffic = staged inputs + merged outputs: two A bands
        // (128 words each) + B (256) + two C bands (128 each).
        assert_eq!(run.info.bus_words, 128 + 256 + 128 + 128 + 128);
        // Every bus grant is classified.
        assert_eq!(
            run.info.exposed_bus_cycles + run.info.hidden_bus_cycles,
            run.info.bus_busy_cycles
        );
    }

    #[test]
    fn quad_cluster_fft_matches_the_host_reference() {
        let topo = Topology::split(&ClusterConfig::tiny(), 4).unwrap();
        let k = SystemKernel::Fft(fft::FftParams { batch: 4, n: 64 });
        let run = run_system(&topo, &k, 1, BUDGET, true, true).unwrap();
        assert!(matches!(run.verdict, Verdict::Passed { .. }), "{:?}", run.verdict);
        assert_eq!(run.output.len(), 2 * 4 * 64);
        // Twiddle broadcasts: two canonical 64-word planes to each of
        // the three non-root clusters (multi-hop routes re-count words
        // per link crossed, so the sum is at least the unique payload).
        assert!(run.info.link_words >= 3 * 2 * 64, "{}", run.info.link_words);
    }

    #[test]
    fn single_cluster_system_matches_the_standalone_engine() {
        let cfg = ClusterConfig::tiny();
        let p = gemm::GemmParams { m: 16, n: 16, k: 16 };
        let topo = Topology::split(&cfg, 1).unwrap();
        let run = run_system(&topo, &SystemKernel::Gemm(p), 1, BUDGET, true, false).unwrap();
        let (mut cl, _io) = gemm::build(&cfg, &p).into_cluster(cfg.clone());
        cl.fast_forward = true;
        let stats = cl.try_run(BUDGET).unwrap();
        // The compute chunk is byte-identical to a standalone run; only
        // the system timeline adds staging/merge around it.
        assert_eq!(run.info.clusters[0].cycles, stats.cycles);
        assert_eq!(run.info.clusters[0].instructions, stats.instructions);
        assert_eq!(run.info.link_words, 0);
    }

    #[test]
    fn sliced_s1_matches_the_phase_serial_engine_exactly() {
        // The tentpole invariant: at S = 1 the pipelined engine IS the
        // phase-serial timeline — same name, cycles, full SystemInfo,
        // and memory image. (system_equiv.rs extends this across
        // kernels, cluster counts, and host threads.)
        let topo = Topology::split(&ClusterConfig::tiny(), 2).unwrap();
        let k = SystemKernel::Gemm(gemm::GemmParams { m: 16, n: 16, k: 16 });
        let phases = run_system_phases(&topo, &k, 1, BUDGET, true, true).unwrap();
        let piped = run_system_sliced(&topo, &k, 1, BUDGET, true, true, 1).unwrap();
        assert_eq!(phases.name, piped.name);
        assert_eq!(phases.stats.cycles, piped.stats.cycles);
        assert_eq!(phases.info, piped.info);
        assert_eq!(phases.output, piped.output);
    }

    #[test]
    fn sliced_gemm_pipelines_and_matches_the_serial_image() {
        let topo = Topology::split(&ClusterConfig::tiny(), 2).unwrap();
        let k = SystemKernel::Gemm(gemm::GemmParams { m: 16, n: 16, k: 16 });
        let serial = run_system_phases(&topo, &k, 1, BUDGET, true, false).unwrap();
        let piped = run_system_sliced(&topo, &k, 1, BUDGET, true, false, 2).unwrap();
        assert_eq!(piped.info.slices, 2);
        assert!(piped.name.ends_with("~s2"), "{}", piped.name);
        // The memory image is byte-identical at any slicing.
        assert_eq!(serial.output, piped.output);
        // Total staged+merged traffic is slicing-invariant.
        assert_eq!(serial.info.bus_words, piped.info.bus_words);
        // Every bus grant is classified, and with slicing some of the
        // traffic hides behind compute.
        assert_eq!(
            piped.info.exposed_bus_cycles + piped.info.hidden_bus_cycles,
            piped.info.bus_busy_cycles
        );
        assert!(piped.info.clusters.iter().all(|c| c.slice_windows.len() == 2));
    }

    #[test]
    fn sliced_fft_matches_the_serial_image() {
        let topo = Topology::split(&ClusterConfig::tiny(), 2).unwrap();
        let k = SystemKernel::Fft(fft::FftParams { batch: 4, n: 64 });
        let serial = run_system_phases(&topo, &k, 1, BUDGET, true, false).unwrap();
        let piped = run_system_sliced(&topo, &k, 1, BUDGET, true, false, 2).unwrap();
        assert_eq!(serial.output, piped.output);
        assert_eq!(serial.info.bus_words, piped.info.bus_words);
    }

    #[test]
    fn empty_slices_are_refused_typed() {
        let topo = Topology::split(&ClusterConfig::tiny(), 2).unwrap();
        // gemm 16³ at 9 slices wants a 3×3 grid — neither 4 column
        // panels over div_ceil chunks nor a 2-block-row band can cover
        // 3 slices.
        let k = SystemKernel::Gemm(gemm::GemmParams { m: 16, n: 16, k: 16 });
        let e = run_system_sliced(&topo, &k, 1, BUDGET, true, false, 9).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Unsupported);
        // fft batch 4 on 2 clusters: a 2-frame band cannot cover 3
        // slices.
        let k = SystemKernel::Fft(fft::FftParams { batch: 4, n: 64 });
        let e = run_system_sliced(&topo, &k, 1, BUDGET, true, false, 3).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Unsupported);
    }

    #[test]
    fn overchunked_problems_are_refused_typed() {
        // 8 block-rows of gemm m=32 cannot cover a tiny 8-way split at
        // m=8 (2 block-rows < 8 clusters).
        let topo = Topology::split(&ClusterConfig::tiny(), 8).unwrap();
        let k = SystemKernel::Gemm(gemm::GemmParams { m: 8, n: 16, k: 16 });
        let e = run_system(&topo, &k, 1, BUDGET, true, false).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Unsupported);
        let k = SystemKernel::Fft(fft::FftParams { batch: 4, n: 64 });
        let e = run_system(&topo, &k, 1, BUDGET, true, false).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Unsupported);
    }

    #[test]
    fn resolve_kernel_is_typed() {
        assert!(matches!(
            resolve_kernel("gemm", Scale::Fast),
            Ok(SystemKernel::Gemm(p)) if p.m == 128
        ));
        assert!(matches!(
            resolve_kernel("fft", Scale::Full),
            Ok(SystemKernel::Fft(p)) if p.batch == 64 && p.n == 4096
        ));
        assert_eq!(
            resolve_kernel("axpy", Scale::Fast).unwrap_err().kind(),
            ErrorKind::UnknownWorkload
        );
    }
}
