//! Cycle-level HBM2E main-memory timing model — the DRAMsys5.0 substitute
//! (Sec. 5.3).
//!
//! Two stacks × 8 channels of Micron MT54A16G808A00AC-36-class HBM2E: 16
//! independent 128-pin channels at 2.8/3.2/3.6 Gbit/s/pin DDR
//! (44.8/51.2/57.6 GB/s per channel, 716.8/819.2/921.6 GB/s total). Each
//! channel models:
//!
//! * a serialized data bus (bursts occupy the bus back-to-back),
//! * 16 banks with open-row tracking: a row miss pays tRP+tRCD, hidden by
//!   bank interleaving for streaming patterns,
//! * periodic refresh: every tREFI the channel stalls for tRFC(sb) —
//!   same-bank staggered refresh, the ~2-3 % tax visible in Fig. 9,
//! * a fixed command/read pipeline latency (the "hundred-cycle" latency
//!   the paper quotes for HBM2E at cluster frequencies).
//!
//! All times are kept in *cluster cycles*: the DRAM's fixed-ns parameters
//! shrink in cycles as the cluster slows down, exactly the effect that
//! makes TeraPool frequency-bound at 500 MHz and HBM-bound at 900 MHz.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::DdrRate;

/// Timing parameters (nanoseconds). Defaults follow HBM2E datasheet-class
/// values; see EXPERIMENTS.md Fig. 9 for the calibration notes.
#[derive(Debug, Clone, Copy)]
pub struct HbmTiming {
    /// Command + read pipeline latency (tRCD+CL+data return), ns.
    pub t_access_ns: f64,
    /// Row-miss penalty (tRP + tRCD), ns.
    pub t_rowmiss_ns: f64,
    /// Refresh interval, ns.
    pub t_refi_ns: f64,
    /// Refresh stall (same-bank staggered), ns.
    pub t_rfc_ns: f64,
}

impl Default for HbmTiming {
    fn default() -> Self {
        HbmTiming {
            t_access_ns: 60.0,
            t_rowmiss_ns: 32.0,
            t_refi_ns: 3900.0,
            t_rfc_ns: 100.0,
        }
    }
}

/// Static geometry of the 16-channel subsystem.
#[derive(Debug, Clone, Copy)]
pub struct HbmConfig {
    pub channels: usize,
    pub banks_per_channel: usize,
    /// Bytes per row (open-page granularity).
    pub row_bytes: u64,
    /// Channel interleave granularity — 1 KiB = one 256-word AXI burst,
    /// matching the paper's hybrid mapping (Sec. 5.4).
    pub interleave_bytes: u64,
    pub ddr: DdrRate,
    /// Cluster frequency used to convert ns ↔ cycles.
    pub freq_mhz: f64,
    pub timing: HbmTiming,
}

impl HbmConfig {
    pub fn new(ddr: DdrRate, freq_mhz: f64) -> Self {
        HbmConfig {
            channels: 16,
            banks_per_channel: 16,
            row_bytes: 2048,
            interleave_bytes: 1024,
            ddr,
            freq_mhz,
            timing: HbmTiming::default(),
        }
    }

    /// Cluster cycles per nanosecond.
    #[inline]
    pub fn cycles_per_ns(&self) -> f64 {
        self.freq_mhz / 1000.0
    }

    /// Data-bus occupancy (cluster cycles) of a burst of `bytes`.
    pub fn data_cycles(&self, bytes: u64) -> f64 {
        // Channel bandwidth: 128 pins × rate Gb/s / 8 = 16×rate B/ns.
        let bytes_per_ns = 16.0 * self.ddr.gbps();
        bytes as f64 / bytes_per_ns * self.cycles_per_ns()
    }

    /// Channel of a main-memory byte address (1 KiB interleave).
    pub fn channel_of(&self, addr: u64) -> usize {
        ((addr / self.interleave_bytes) % self.channels as u64) as usize
    }
}

#[derive(Debug, Clone, Copy)]
struct BankState {
    open_row: u64,
}

#[derive(Debug)]
struct Channel {
    /// Cycle (fractional) at which the data bus frees.
    bus_free: f64,
    banks: Vec<BankState>,
    last_bank: usize,
    refresh_next: f64,
    /// Stats.
    bytes: u64,
    row_misses: u64,
    refreshes: u64,
}

/// A burst completion: (cluster cycle, user id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub at: u64,
    pub id: u64,
}

/// The HBM2E subsystem: submit bursts, poll completions.
pub struct Hbm {
    pub cfg: HbmConfig,
    channels: Vec<Channel>,
    completions: BinaryHeap<Reverse<(u64, u64)>>,
}

impl Hbm {
    pub fn new(cfg: HbmConfig) -> Self {
        let ch = (0..cfg.channels)
            .map(|i| Channel {
                bus_free: 0.0,
                banks: vec![BankState { open_row: u64::MAX }; cfg.banks_per_channel],
                last_bank: usize::MAX,
                // Stagger refresh across channels to avoid artificial
                // lock-step stalls.
                refresh_next: cfg.timing.t_refi_ns * cfg.cycles_per_ns() * (1.0 + i as f64 / cfg.channels as f64),
                bytes: 0,
                row_misses: 0,
                refreshes: 0,
            })
            .collect();
        Hbm { cfg, channels: ch, completions: BinaryHeap::new() }
    }

    /// Submit a burst (read or write — timing symmetric at this
    /// granularity) of `bytes` at main-memory byte address `addr`.
    /// Completion is reported via [`Hbm::take_completed`] with `id`.
    pub fn submit(&mut self, now: u64, addr: u64, bytes: u64, id: u64) {
        let cpn = self.cfg.cycles_per_ns();
        let t = &self.cfg.timing;
        let chan_idx = self.cfg.channel_of(addr);
        let ch = &mut self.channels[chan_idx];

        let mut start = (now as f64).max(ch.bus_free);
        // Refresh windows that elapsed before this burst begins.
        while start >= ch.refresh_next {
            ch.refresh_next += t.t_refi_ns * cpn;
            start += t.t_rfc_ns * cpn;
            ch.refreshes += 1;
        }

        // Bank/row resolution: within a channel, consecutive interleave
        // blocks stripe across banks, so streaming traffic activates banks
        // round-robin and row misses overlap with data transfer.
        let in_channel = addr / (self.cfg.interleave_bytes * self.cfg.channels as u64);
        let bank_idx = (in_channel % self.cfg.banks_per_channel as u64) as usize;
        let row = in_channel / self.cfg.banks_per_channel as u64 * self.cfg.interleave_bytes
            / self.cfg.row_bytes;
        let miss = ch.banks[bank_idx].open_row != row;
        if miss {
            ch.banks[bank_idx].open_row = row;
            ch.row_misses += 1;
        }
        // A row activate only stalls the data bus when bank interleaving
        // cannot hide it, i.e. on a same-bank back-to-back miss; streaming
        // traffic striped over banks overlaps activates with other banks'
        // data beats (the effect that lets Fig. 9 reach 97 %).
        let miss_cycles = if miss && ch.last_bank == bank_idx {
            t.t_rowmiss_ns * cpn
        } else {
            0.0
        };
        ch.last_bank = bank_idx;

        let data = self.cfg.data_cycles(bytes);
        let done_bus = start + data + miss_cycles;
        ch.bus_free = done_bus;
        ch.bytes += bytes;

        let complete = done_bus + t.t_access_ns * cpn;
        self.completions.push(Reverse((complete.ceil() as u64, id)));
    }

    /// Pop all bursts completed by cycle `now`.
    pub fn take_completed(&mut self, now: u64, mut sink: impl FnMut(u64)) {
        while let Some(&Reverse((at, id))) = self.completions.peek() {
            if at > now {
                break;
            }
            self.completions.pop();
            sink(id);
        }
    }

    pub fn pending(&self) -> usize {
        self.completions.len()
    }

    /// Earliest scheduled burst completion, if any — the engines'
    /// idle-skip wake query. Completion stamps are resolved fully at
    /// [`Hbm::submit`] time (bus occupancy, refresh windows and row
    /// misses are all folded into the absolute cycle pushed on the
    /// heap), so a peek is exact: no per-cycle HBM state advances
    /// between `submit` and the completion popping out.
    pub fn next_completion_at(&self) -> Option<u64> {
        self.completions.peek().map(|&Reverse((at, _))| at)
    }

    /// Total bytes transferred so far.
    pub fn total_bytes(&self) -> u64 {
        self.channels.iter().map(|c| c.bytes).sum()
    }

    pub fn total_row_misses(&self) -> u64 {
        self.channels.iter().map(|c| c.row_misses).sum()
    }

    pub fn total_refreshes(&self) -> u64 {
        self.channels.iter().map(|c| c.refreshes).sum()
    }

    /// Achieved bandwidth in GB/s over `cycles` cluster cycles.
    pub fn achieved_gbps(&self, cycles: u64) -> f64 {
        if cycles == 0 {
            return 0.0;
        }
        let seconds = cycles as f64 / (self.cfg.freq_mhz * 1e6);
        self.total_bytes() as f64 / seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hbm(freq: f64, ddr: DdrRate) -> Hbm {
        Hbm::new(HbmConfig::new(ddr, freq))
    }

    #[test]
    fn single_burst_latency_is_hundreds_of_cycles_at_900mhz() {
        let mut h = hbm(900.0, DdrRate::G3_6);
        h.submit(0, 0, 1024, 1);
        let mut done = Vec::new();
        for now in 0..1000 {
            h.take_completed(now, |id| done.push((now, id)));
            if !done.is_empty() {
                break;
            }
        }
        let (at, id) = done[0];
        assert_eq!(id, 1);
        // ~60 ns access + ~18 cycles data at 0.9 cycles/ns ≈ 70–90 cycles.
        assert!((60..150).contains(&at), "latency {at}");
    }

    #[test]
    fn channel_bandwidth_saturates_near_peak() {
        // Stream 4 MiB across all 16 channels; utilization should be
        // > 90 % of the DDR peak (only refresh + row-miss tax).
        let mut h = hbm(900.0, DdrRate::G3_6);
        let total: u64 = 4 * 1024 * 1024;
        let mut id = 0;
        for addr in (0..total).step_by(1024) {
            h.submit(0, addr, 1024, id);
            id += 1;
        }
        let mut last = 0;
        for now in 0..200_000 {
            let mut got = false;
            h.take_completed(now, |_| got = true);
            if got {
                last = now;
            }
            if h.pending() == 0 {
                break;
            }
        }
        let achieved = h.achieved_gbps(last);
        let peak = DdrRate::G3_6.peak_gbps_total();
        assert!(
            achieved > 0.90 * peak && achieved <= peak * 1.001,
            "achieved {achieved:.1} GB/s vs peak {peak:.1}"
        );
    }

    #[test]
    fn refresh_happens() {
        let mut h = hbm(900.0, DdrRate::G2_8);
        // Enough traffic to span several tREFI windows on channel 0.
        let mut clock = 0u64;
        for i in 0..2000u64 {
            h.submit(clock, i * 1024 * 16, 1024, i); // all to channel 0
            clock += 25;
        }
        assert!(h.total_refreshes() > 5, "refreshes: {}", h.total_refreshes());
    }

    #[test]
    fn channel_interleave_is_1kib() {
        let cfg = HbmConfig::new(DdrRate::G3_6, 900.0);
        assert_eq!(cfg.channel_of(0), 0);
        assert_eq!(cfg.channel_of(1023), 0);
        assert_eq!(cfg.channel_of(1024), 1);
        assert_eq!(cfg.channel_of(15 * 1024), 15);
        assert_eq!(cfg.channel_of(16 * 1024), 0);
    }

    #[test]
    fn slower_cluster_sees_fewer_cycles_per_burst() {
        let fast = HbmConfig::new(DdrRate::G3_6, 900.0);
        let slow = HbmConfig::new(DdrRate::G3_6, 500.0);
        assert!(fast.data_cycles(1024) > slow.data_cycles(1024));
    }
}
