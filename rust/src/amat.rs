//! The paper's analytical AMAT model of hierarchical crossbar
//! interconnects (Sec. 3.1, Eqs. (3)–(6)) plus the input-queue burst
//! simulation its Python scripts perform (footnote 3) — together they
//! regenerate **Table 4** and **Fig. 8b**.
//!
//! Three pieces:
//!
//! 1. closed-form arbitration contention: `E_{L:n×1}` and the recursive
//!    `E_{L:n×k}` over a Binomial(n, p) request process (Eqs. (4)–(5)),
//!    with stage-to-stage injection-rate propagation (Eq. (6));
//! 2. an abstract **burst simulator**: all PEs issue one uniformly random
//!    bank request in the same cycle and the multi-stage crossbar with
//!    input queues drains it — the AMAT definition the paper evaluates;
//! 3. physical-complexity bookkeeping (total/critical interconnect
//!    complexity, combinational delay) for every hierarchy candidate.

use crate::rng::Rng;


// -------------------------------------------------------------------
// Closed-form contention model, Eqs. (4)–(6)
// -------------------------------------------------------------------

/// Eq. (4): expected arbitration latency of an n→1 arbiter with
/// per-input injection rate `p`: `Σ_{x=1..n} (x-1)·P_req(x)`. The paper's
/// convention charges every request in an x-way collision the full drain
/// time x−1.
pub fn expected_latency_n_to_1(n: usize, p: f64) -> f64 {
    if p <= 0.0 {
        return 0.0;
    }
    // Iterative PMF evaluation (P(x+1) = P(x)·(n-x)/(x+1)·q/(1-q)),
    // truncated once the tail is negligible — needed because the flat
    // 1024×4096 row evaluates this thousands of times.
    let q = p.min(1.0);
    if (q - 1.0).abs() < 1e-12 {
        return (n - 1) as f64; // everyone always collides
    }
    let mut pmf = (1.0 - q).powi(n as i32); // P(0)
    let mut e = 0.0;
    let ratio = q / (1.0 - q);
    let mut cum = pmf;
    for x in 0..n {
        pmf *= (n - x) as f64 / (x + 1) as f64 * ratio;
        e += x as f64 * pmf; // (x+1)-1 = x
        cum += pmf;
        if cum > 1.0 - 1e-13 && x as f64 > q * n as f64 {
            break;
        }
    }
    e
}

/// Eq. (5): recursive expected latency of an n→k arbiter. Each output
/// sees Binomial(n, p/k); if no request targets the watch-point output
/// the residual n→(k-1) arbiter is observed. Evaluated iteratively with
/// geometric truncation (the product of P₀ factors vanishes quickly).
pub fn expected_latency_n_to_k(n: usize, k: usize, p: f64) -> f64 {
    let mut e = 0.0;
    let mut weight = 1.0;
    let mut kk = k;
    while kk >= 1 {
        let q = (p / kk as f64).min(1.0);
        let e1 = expected_latency_n_to_1(n, q);
        e += weight * e1;
        if kk == 1 {
            break;
        }
        let p0 = (1.0 - q).powi(n as i32);
        weight *= p0;
        if weight < 1e-12 {
            break;
        }
        kk -= 1;
    }
    e
}

/// Eq. (6): injection rate seen by the next stage = probability the
/// previous stage's output forwards a request.
pub fn next_stage_injection(n: usize, k: usize, p: f64) -> f64 {
    1.0 - (1.0 - (p / k as f64).min(1.0)).powi(n as i32)
}

/// One input-queue adjustment iteration (the paper's footnote-3 dynamic
/// injection-rate correction): requests delayed by contention re-inject,
/// inflating the effective rate until the port saturates.
pub fn queue_adjusted_rate(n: usize, p: f64) -> f64 {
    let e = expected_latency_n_to_1(n, p);
    (p * (1.0 + e)).min(1.0)
}

// -------------------------------------------------------------------
// Hierarchy candidates (Table 4 rows)
// -------------------------------------------------------------------

/// A hierarchy candidate αC-βT-γSG-δG connecting `pes()` PEs to
/// `banking × pes()` banks. γ=δ=1 collapse levels:
/// flat = (1024, 1, 1, 1); two-level αC-βT = (α, β, 1, 1);
/// three-level αC-βT-δG = (α, β, 1, δ); four-level = (α, β, γ, δ).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierSpec {
    pub alpha: usize,
    pub beta: usize,
    pub gamma: usize,
    pub delta: usize,
    /// Banks per PE (4 throughout the paper).
    pub banking: usize,
}

impl HierSpec {
    pub const fn new(alpha: usize, beta: usize, gamma: usize, delta: usize) -> Self {
        HierSpec { alpha, beta, gamma, delta, banking: 4 }
    }
    pub fn pes(&self) -> usize {
        self.alpha * self.beta * self.gamma * self.delta
    }
    pub fn tiles(&self) -> usize {
        self.beta * self.gamma * self.delta
    }
    pub fn banks(&self) -> usize {
        self.pes() * self.banking
    }
    pub fn banks_per_tile(&self) -> usize {
        self.alpha * self.banking
    }
    /// Hierarchy depth: 1 = flat crossbar … 4 = Tile/SubGroup/Group.
    pub fn levels(&self) -> usize {
        1 + (self.beta > 1) as usize + (self.gamma > 1) as usize + (self.delta > 1) as usize
    }
    /// Remote ports per Tile: 1 toward the sibling Tiles of the lowest
    /// grouping + (γ-1) + (δ-1) toward remote SubGroups/Groups.
    pub fn ports(&self) -> usize {
        if self.levels() == 1 {
            return 0;
        }
        1 + (self.gamma - 1) + (self.delta - 1)
    }
    pub fn name(&self) -> String {
        match self.levels() {
            1 => format!("{}C", self.alpha),
            2 => format!("{}C-{}T", self.alpha, self.beta),
            3 => format!("{}C-{}T-{}G", self.alpha, self.beta, self.delta),
            _ => format!("{}C-{}T-{}SG-{}G", self.alpha, self.beta, self.gamma, self.delta),
        }
    }

    /// The Table-4 candidate list (all 1024-PE / 4096-bank designs).
    pub fn table4_rows() -> Vec<HierSpec> {
        vec![
            HierSpec::new(1024, 1, 1, 1),
            HierSpec::new(4, 256, 1, 1),
            HierSpec::new(8, 128, 1, 1),
            HierSpec::new(16, 64, 1, 1),
            HierSpec::new(4, 16, 1, 16),
            HierSpec::new(4, 32, 1, 8),
            HierSpec::new(8, 16, 1, 8),
            HierSpec::new(8, 32, 1, 4),
            HierSpec::new(16, 8, 1, 8),
            HierSpec::new(16, 16, 1, 4),
            HierSpec::new(4, 16, 4, 4),
            HierSpec::new(8, 8, 4, 4),
            HierSpec::new(16, 4, 4, 4),
        ]
    }

    /// TeraPool's chosen configuration.
    pub fn terapool() -> HierSpec {
        HierSpec::new(8, 8, 4, 4)
    }

    // ------------------------------------------------ NUMA distances --

    /// Round-trip zero-load latency per level: same Tile 1, then +2 per
    /// hierarchy boundary crossed (the Table-4 evaluation uses the
    /// lowest-latency TeraPool_1-3-5-7 spill profile).
    pub fn level_latency(&self, level: usize) -> u32 {
        1 + 2 * level as u32
    }

    /// Probability that a uniformly random bank lives at hierarchy
    /// distance `level` (0 = local Tile).
    pub fn level_prob(&self, level: usize) -> f64 {
        let t = self.tiles() as f64;
        match (self.levels(), level) {
            (1, 0) => 1.0,
            (1, _) => 0.0,
            (2, 0) => 1.0 / t,
            (2, 1) => (self.beta - 1) as f64 / t,
            (2, _) => 0.0,
            (3, 0) => 1.0 / t,
            (3, 1) => (self.beta - 1) as f64 / t,
            (3, 2) => (self.tiles() - self.beta) as f64 / t,
            (3, _) => 0.0,
            (_, 0) => 1.0 / t,
            (_, 1) => (self.beta - 1) as f64 / t,
            (_, 2) => (self.beta * (self.gamma - 1)) as f64 / t,
            (_, 3) => (self.beta * self.gamma * (self.delta - 1)) as f64 / t,
            _ => 0.0,
        }
    }

    /// Zero-load latency: probability-weighted NUMA round trips (the
    /// "ZeroLd" column of Table 4).
    pub fn zero_load_latency(&self) -> f64 {
        (0..4)
            .map(|l| self.level_prob(l) * self.level_latency(l) as f64)
            .sum()
    }

    // ---------------------------------------------- complexity model --

    /// Per-Tile crossbar complexity (leaf nodes): `(α + P [+1 AXI]) ×
    /// banks + α × P` — inputs are the Tile's cores, remote slave ports
    /// and (at ≥3 levels) the AXI/DMA port; outputs its banks; plus the
    /// core→master-port leaves.
    fn tile_complexity(&self) -> usize {
        if self.levels() == 1 {
            return self.pes() * self.banks();
        }
        let p = self.ports();
        // ≥3 levels add the AXI/DMA slave port; at 4 levels the paper's
        // bookkeeping also counts the cores' leaf toward the AXI master.
        let axi = if self.levels() >= 3 { 1 } else { 0 };
        let leaf_ports = p + if self.levels() >= 4 { 1 } else { 0 };
        (self.alpha + p + axi) * self.banks_per_tile() + self.alpha * leaf_ports
    }

    /// Inter-Tile crossbars above the Tile level: (size n×k, count).
    fn level_xbars(&self) -> Vec<(usize, usize, usize)> {
        match self.levels() {
            1 => vec![],
            // one β×β crossbar between all tiles
            2 => vec![(self.beta, self.beta, 1)],
            // ordered remote-Group pairs of β×β (the intra-Group crossbar
            // is absorbed in the Tiles' slave ports, as in the paper's
            // bookkeeping)
            3 => vec![(self.beta, self.beta, self.delta * (self.delta - 1))],
            _ => {
                let tpg = self.beta * self.gamma;
                vec![
                    // inter-SubGroup ordered pairs per Group
                    (self.beta, self.beta, self.delta * self.gamma * (self.gamma - 1)),
                    // remote-Group ordered pairs, tiles-per-group wide
                    (tpg, tpg, self.delta * (self.delta - 1)),
                ]
            }
        }
    }

    /// Total interconnect complexity (the "Total Complex." column).
    pub fn total_complexity(&self) -> usize {
        let mut c = self.tiles() * self.tile_complexity();
        for (n, k, cnt) in self.level_xbars() {
            c += n * k * cnt;
        }
        if self.levels() == 1 {
            c = self.pes() * self.banks();
        }
        c
    }

    /// The most complex single implementation block (the "Critical
    /// Complex." column): max over the Tile block and the level crossbars.
    pub fn critical_block(&self) -> (usize, usize) {
        if self.levels() == 1 {
            return (self.pes(), self.banks());
        }
        let axi = if self.levels() >= 4 { 1 } else { 0 };
        let mut best = (
            self.alpha + self.ports() + axi,
            self.banks_per_tile(),
        );
        for (n, k, _) in self.level_xbars() {
            if n * k > best.0 * best.1 {
                best = (n, k);
            }
        }
        best
    }

    pub fn critical_complexity(&self) -> usize {
        let (n, k) = self.critical_block();
        n * k
    }

    /// Combinational delay of the critical block: `log2 n + log2 k`
    /// routing-tree plus arbitration-switch levels.
    pub fn critical_comb_delay(&self) -> f64 {
        let (n, k) = self.critical_block();
        (n as f64).log2() + (k as f64).log2()
    }
}

// -------------------------------------------------------------------
// Closed-form AMAT (the Table-4 "AMAT" column): per NUMA class, chain
// the master-port arbiter (with one queue-adjustment iteration), the
// level crossbar, and the bank stage via Eqs. (4)-(6), then weight by
// the class probabilities of Eq. (3).
// -------------------------------------------------------------------

impl HierSpec {
    /// Crossbar (inputs, outputs) a request of NUMA level ℓ traverses
    /// above the Tile, and the number of same-level ports per Tile.
    fn level_route(&self, level: usize) -> Option<((usize, usize), usize)> {
        match (self.levels(), level) {
            (_, 0) => None,
            (2, _) => Some(((self.beta, self.beta), 1)),
            (3, 1) => Some(((self.beta, self.beta), 1)),
            (3, _) => Some(((self.beta, self.beta), self.delta - 1)),
            (_, 1) => Some(((self.beta, self.beta), 1)),
            (_, 2) => Some(((self.beta, self.beta), self.gamma - 1)),
            _ => {
                let tpg = self.beta * self.gamma;
                Some(((tpg, tpg), self.delta - 1))
            }
        }
    }

    /// Expected contention (cycles beyond zero-load) for a level-ℓ
    /// request under all-PEs-inject-every-cycle traffic (p = 1).
    pub fn level_contention(&self, level: usize) -> f64 {
        self.level_contention_at(level, self.level_prob(level))
    }

    /// Expected contention for a level-ℓ request when each PE injects a
    /// level-ℓ request with per-cycle probability `p_level` — the
    /// generalization of [`HierSpec::level_contention`] (which fixes
    /// `p_level = level_prob(level)`, the all-PEs-inject-every-cycle
    /// burst). `Session::estimate` feeds measured per-class injection
    /// rates from a workload census through this to predict contention
    /// off the saturation point.
    pub fn level_contention_at(&self, level: usize, p_level: f64) -> f64 {
        if p_level <= 0.0 {
            return 0.0;
        }
        match self.level_route(level) {
            None => {
                // Local: the Tile crossbar / flat cluster crossbar.
                expected_latency_n_to_k(self.alpha, self.banks_per_tile(), p_level)
            }
            Some(((nx, kx), ports)) => {
                // Master port: α cores share `ports` same-level ports.
                let p_port = p_level / ports as f64;
                let p_adj = queue_adjusted_rate(self.alpha, p_port);
                let e_master = expected_latency_n_to_1(self.alpha, p_adj);
                // Level crossbar, injection per Eq. (6).
                let p_x = next_stage_injection(self.alpha, 1, p_adj);
                let e_xbar = expected_latency_n_to_k(nx, kx, p_x);
                // Bank stage at the destination Tile.
                let p_b = next_stage_injection(nx, kx, p_x);
                let e_bank =
                    expected_latency_n_to_k(nx, self.banks_per_tile(), p_b / nx as f64);
                e_master + e_xbar + e_bank
            }
        }
    }

    /// Closed-form AMAT (Eq. (3)): zero-load plus probability-weighted
    /// per-level contention.
    pub fn analytic_amat(&self) -> f64 {
        self.zero_load_latency()
            + (0..4)
                .map(|l| self.level_prob(l) * self.level_contention(l))
                .sum::<f64>()
    }

    /// Table-4 "Throughput" column: sustained injection under continuous
    /// random traffic = 1 / (1 + mean contention).
    pub fn analytic_throughput(&self) -> f64 {
        1.0 / (self.analytic_amat() - self.zero_load_latency() + 1.0)
    }
}

// -------------------------------------------------------------------
// Burst simulation: AMAT with input queues (the paper's footnote-3
// Python-script methodology) — the event-level cross-check of the
// closed-form model above, and the source of Fig. 8b's per-level means.
// -------------------------------------------------------------------

/// Result of a burst simulation.
#[derive(Debug, Clone, Copy)]
pub struct BurstResult {
    /// Mean request latency (the "AMAT" column of Table 4).
    pub amat: f64,
    /// Mean latency per NUMA level (Fig. 8b "random access" series).
    pub amat_per_level: [f64; 4],
    /// Max latency observed.
    pub max: u64,
}

/// All PEs issue one uniformly random bank request in the same cycle;
/// the hierarchical crossbar with per-node input queues drains the burst.
/// FIFO-per-node, one grant per node per cycle, spill-register delays per
/// crossed boundary — the same arbitration discipline as the full cluster
/// simulator (`crate::interconnect`), evaluated standalone.
pub fn burst_amat(spec: &HierSpec, seed: u64) -> BurstResult {
    #[derive(Clone, Copy)]
    struct R {
        level: usize, // 0..4 NUMA distance
        master: u32,  // master node or NO
        slave: u32,
        bank: u32,
        done_at: u64,
    }
    const NO: u32 = u32::MAX;

    let mut rng = Rng::seed_from_u64(seed);
    let tiles = spec.tiles();
    let ports = spec.ports().max(1);
    let banks = spec.banks();
    let bpt = spec.banks_per_tile();
    let tpsg = spec.beta; // tiles per lowest grouping
    let tpg = spec.beta * spec.gamma;

    // Build one request per PE.
    let npes = spec.pes();
    let mut reqs: Vec<R> = Vec::with_capacity(npes);
    for pe in 0..npes {
        let src_tile = pe / spec.alpha;
        let bank = rng.gen_range(banks);
        let dst_tile = bank / bpt;
        let (level, port_m, port_s) = if spec.levels() == 1 || src_tile == dst_tile {
            (0, 0, 0)
        } else if spec.levels() == 2 {
            (1, 0, 0)
        } else if src_tile / tpg != dst_tile / tpg {
            // remote Group: master port indexed by destination group,
            // slave port (at the target tile) by source group.
            let (sg, dg) = (src_tile / tpg, dst_tile / tpg);
            let rel_m = if dg < sg { dg } else { dg - 1 };
            let rel_s = if sg < dg { sg } else { sg - 1 };
            let base = spec.gamma - 1 + 1;
            (3.min(spec.levels() - 1), base + rel_m, base + rel_s)
        } else if spec.levels() >= 4 && (src_tile % tpg) / tpsg != (dst_tile % tpg) / tpsg {
            // other SubGroup, same Group
            let (ss, ds) = ((src_tile % tpg) / tpsg, (dst_tile % tpg) / tpsg);
            let rel_m = if ds < ss { ds } else { ds - 1 };
            let rel_s = if ss < ds { ss } else { ss - 1 };
            (2, 1 + rel_m, 1 + rel_s)
        } else {
            (1, 0, 0)
        };
        let (port_m, port_s) = (port_m.min(ports - 1), port_s.min(ports - 1));
        let (master, slave) = if level == 0 {
            (NO, NO)
        } else {
            (
                (src_tile * ports + port_m) as u32,
                (dst_tile * ports + port_s) as u32,
            )
        };
        reqs.push(R { level, master, slave, bank: bank as u32, done_at: 0 });
    }

    // FIFO queues.
    use std::collections::VecDeque;
    let mut master_q: Vec<VecDeque<u32>> = vec![VecDeque::new(); tiles * ports];
    let mut slave_q: Vec<VecDeque<u32>> = vec![VecDeque::new(); tiles * ports];
    let mut bank_q: Vec<VecDeque<u32>> = vec![VecDeque::new(); banks];
    let mut arrivals: Vec<Vec<(u32, u32)>> = vec![Vec::new(); 64]; // wheel

    for (i, r) in reqs.iter().enumerate() {
        if r.master == NO {
            bank_q[r.bank as usize].push_back(i as u32);
        } else {
            master_q[r.master as usize].push_back(i as u32);
        }
    }

    let mut remaining = npes;
    let mut now = 0u64;
    while remaining > 0 {
        for (node, rid) in std::mem::take(&mut arrivals[(now as usize) % 64]) {
            slave_q[node as usize].push_back(rid);
        }
        for q in master_q.iter_mut() {
            if let Some(rid) = q.pop_front() {
                let r = reqs[rid as usize];
                let l = spec.level_latency(r.level);
                let hop = ((l - 1) / 2) as u64;
                arrivals[((now + hop) as usize) % 64].push((r.slave, rid));
            }
        }
        for q in slave_q.iter_mut() {
            if let Some(rid) = q.pop_front() {
                bank_q[reqs[rid as usize].bank as usize].push_back(rid);
            }
        }
        for q in bank_q.iter_mut() {
            if let Some(rid) = q.pop_front() {
                let r = &mut reqs[rid as usize];
                let l = spec.level_latency(r.level) as u64;
                let hop = (l - 1) / 2;
                r.done_at = now + (l - hop).max(1);
                remaining -= 1;
            }
        }
        now += 1;
        assert!(now < 1_000_000, "burst sim runaway");
    }

    let mut sum = 0u64;
    let mut max = 0u64;
    let mut lsum = [0u64; 4];
    let mut lcnt = [0u64; 4];
    for r in &reqs {
        sum += r.done_at;
        max = max.max(r.done_at);
        lsum[r.level] += r.done_at;
        lcnt[r.level] += 1;
    }
    let mut amat_per_level = [0.0; 4];
    for l in 0..4 {
        if lcnt[l] > 0 {
            amat_per_level[l] = lsum[l] as f64 / lcnt[l] as f64;
        }
    }
    BurstResult {
        amat: sum as f64 / npes as f64,
        amat_per_level,
        max,
    }
}

/// Averaged burst AMAT over several seeds (the number the Table-4 rows
/// report).
pub fn amat(spec: &HierSpec, seeds: usize) -> BurstResult {
    let mut acc = BurstResult { amat: 0.0, amat_per_level: [0.0; 4], max: 0 };
    for s in 0..seeds {
        let r = burst_amat(spec, 0x7e4a_9001 + s as u64);
        acc.amat += r.amat;
        for l in 0..4 {
            acc.amat_per_level[l] += r.amat_per_level[l];
        }
        acc.max = acc.max.max(r.max);
    }
    acc.amat /= seeds as f64;
    for l in 0..4 {
        acc.amat_per_level[l] /= seeds as f64;
    }
    acc
}

/// Table-4 "Throughput" column: sustained injection under continuous
/// random traffic ≈ 1 / (1 + mean contention) = 1 / (AMAT − ZeroLoad + 1).
pub fn throughput(spec: &HierSpec, seeds: usize) -> f64 {
    let a = amat(spec, seeds).amat;
    1.0 / (a - spec.zero_load_latency() + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_n_to_1_hand_check() {
        // n=2, p=1: both always request: E = (2-1)·P(2) = 1.
        assert!((expected_latency_n_to_1(2, 1.0) - 1.0).abs() < 1e-12);
        // n=2, p=0.5: E = 1·P(X=2) = 0.25.
        assert!((expected_latency_n_to_1(2, 0.5) - 0.25).abs() < 1e-12);
        // p=0 → no contention.
        assert_eq!(expected_latency_n_to_1(8, 0.0), 0.0);
    }

    #[test]
    fn closed_form_n_to_k_decreases_with_k() {
        let e1 = expected_latency_n_to_k(16, 1, 0.5);
        let e4 = expected_latency_n_to_k(16, 4, 0.5);
        let e16 = expected_latency_n_to_k(16, 16, 0.5);
        assert!(e1 > e4 && e4 > e16, "{e1} {e4} {e16}");
    }

    #[test]
    fn injection_propagation_bounded() {
        let p2 = next_stage_injection(8, 4, 0.9);
        assert!(p2 > 0.0 && p2 < 1.0);
    }

    #[test]
    fn zero_load_matches_table4() {
        // Paper Table 4, ZeroLd column.
        let cases = [
            (HierSpec::new(1024, 1, 1, 1), 1.000),
            (HierSpec::new(4, 256, 1, 1), 2.992),
            (HierSpec::new(8, 128, 1, 1), 2.984),
            (HierSpec::new(16, 64, 1, 1), 2.969),
            (HierSpec::new(4, 16, 1, 16), 4.867),
            (HierSpec::new(4, 32, 1, 8), 4.742),
            (HierSpec::new(8, 16, 1, 8), 4.734),
            (HierSpec::new(8, 32, 1, 4), 4.484),
            (HierSpec::new(16, 8, 1, 8), 4.719),
            (HierSpec::new(16, 16, 1, 4), 4.469),
            (HierSpec::new(4, 16, 4, 4), 6.367),
            (HierSpec::new(8, 8, 4, 4), 6.359),
            (HierSpec::new(16, 4, 4, 4), 6.344),
        ];
        for (spec, want) in cases {
            let got = spec.zero_load_latency();
            assert!(
                (got - want).abs() < 0.005,
                "{}: got {got:.3}, want {want:.3}",
                spec.name()
            );
        }
    }

    #[test]
    fn complexity_matches_table4_exactly_for_2level() {
        // Rows where the paper's bookkeeping is unambiguous.
        let cases = [
            (HierSpec::new(1024, 1, 1, 1), 4194304, 4194304),
            (HierSpec::new(4, 256, 1, 1), 87040, 65536),
            (HierSpec::new(8, 128, 1, 1), 54272, 16384),
            (HierSpec::new(16, 64, 1, 1), 74752, 4096),
        ];
        for (spec, total, critical) in cases {
            assert_eq!(spec.total_complexity(), total, "{} total", spec.name());
            assert_eq!(spec.critical_complexity(), critical, "{} critical", spec.name());
        }
    }

    #[test]
    fn complexity_terapool_matches_table4() {
        let tp = HierSpec::terapool();
        assert_eq!(tp.total_complexity(), 89088);
        assert_eq!(tp.critical_complexity(), 1024); // 32×32 remote-Group xbar
        assert!((tp.critical_comb_delay() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn critical_complexity_more_rows() {
        for (spec, want) in [
            (HierSpec::new(4, 16, 1, 16), 320),
            (HierSpec::new(4, 32, 1, 8), 1024),
            (HierSpec::new(8, 16, 1, 8), 512),
            (HierSpec::new(8, 32, 1, 4), 1024),
            (HierSpec::new(16, 8, 1, 8), 1536),
            (HierSpec::new(16, 16, 1, 4), 1280),
            (HierSpec::new(4, 16, 4, 4), 4096),
        ] {
            assert_eq!(spec.critical_complexity(), want, "{}", spec.name());
        }
    }

    #[test]
    fn comb_delay_matches_table4() {
        for (spec, want) in [
            (HierSpec::new(1024, 1, 1, 1), 22.0),
            (HierSpec::new(4, 256, 1, 1), 16.0),
            (HierSpec::new(8, 128, 1, 1), 14.0),
            (HierSpec::new(16, 64, 1, 1), 12.0),
            (HierSpec::new(4, 16, 1, 16), 8.3),
            (HierSpec::new(8, 16, 1, 8), 9.0),
            (HierSpec::new(16, 16, 1, 4), 10.3),
            (HierSpec::new(4, 16, 4, 4), 12.0),
        ] {
            let got = spec.critical_comb_delay();
            assert!((got - want).abs() < 0.05, "{}: {got} vs {want}", spec.name());
        }
    }

    #[test]
    fn burst_amat_flat_matches_paper() {
        // 1024C: AMAT 1.130 — only bank conflicts.
        let r = amat(&HierSpec::new(1024, 1, 1, 1), 8);
        assert!((r.amat - 1.13).abs() < 0.03, "flat AMAT {}", r.amat);
    }

    #[test]
    fn analytic_amat_matches_table4() {
        // Paper Table 4, AMAT column — the closed-form Eqs. (4)-(6) with
        // one input-queue adjustment. Tolerance 10 % (the paper's own
        // scripts embed small bookkeeping differences).
        let cases = [
            (HierSpec::new(1024, 1, 1, 1), 1.130),
            (HierSpec::new(4, 256, 1, 1), 6.081),
            (HierSpec::new(8, 128, 1, 1), 10.075),
            (HierSpec::new(16, 64, 1, 1), 18.077),
            (HierSpec::new(4, 16, 1, 16), 5.318),
            (HierSpec::new(4, 32, 1, 8), 5.443),
            (HierSpec::new(8, 16, 1, 8), 5.794),
            (HierSpec::new(8, 8, 4, 4), 9.198),
        ];
        for (spec, want) in cases {
            let got = spec.analytic_amat();
            assert!(
                (got - want).abs() / want < 0.10,
                "{}: got {got:.3}, want {want:.3}",
                spec.name()
            );
        }
    }

    #[test]
    fn analytic_amat_saturated_rows_are_pessimistic_but_ordered() {
        // For the rows whose remote ports are oversubscribed ≥ 4×
        // (8C-32T-4G, 16C-16T-4G, 16C-4T-4SG-4G) our single-iteration
        // queue adjustment saturates harder than the paper's scripts and
        // overshoots AMAT (documented in EXPERIMENTS.md). The ordering
        // relative to the feasible designs is preserved, which is what
        // the Table-4 decision uses.
        let tp = HierSpec::terapool().analytic_amat();
        for (spec, want) in [
            (HierSpec::new(8, 32, 1, 4), 6.676),
            (HierSpec::new(16, 16, 1, 4), 8.612),
            (HierSpec::new(16, 4, 4, 4), 11.049),
        ] {
            let got = spec.analytic_amat();
            assert!(got >= want * 0.9, "{}: got {got:.3}", spec.name());
            assert!(got <= want * 2.5, "{}: got {got:.3}", spec.name());
        }
        // 16C-4T-4SG-4G stays worse than TeraPool, as in the paper.
        assert!(HierSpec::new(16, 4, 4, 4).analytic_amat() > tp);
    }

    #[test]
    fn analytic_throughput_matches_table4() {
        for (spec, want) in [
            (HierSpec::new(1024, 1, 1, 1), 0.885),
            (HierSpec::new(4, 256, 1, 1), 0.245),
            (HierSpec::new(8, 128, 1, 1), 0.124),
            (HierSpec::new(16, 64, 1, 1), 0.062),
            (HierSpec::new(8, 8, 4, 4), 0.230),
        ] {
            let got = spec.analytic_throughput();
            assert!(
                (got - want).abs() / want < 0.15,
                "{}: got {got:.3}, want {want:.3}",
                spec.name()
            );
        }
    }

    #[test]
    fn burst_sim_cross_checks_closed_form() {
        // The event-level burst simulation and the closed-form model must
        // agree on ordering and rough magnitude (the burst model resolves
        // staggered arrivals the closed form cannot, so allow 40 %).
        for spec in [
            HierSpec::new(4, 256, 1, 1),
            HierSpec::new(16, 64, 1, 1),
            HierSpec::terapool(),
        ] {
            let sim = amat(&spec, 4).amat;
            let ana = spec.analytic_amat();
            let ratio = sim / ana;
            assert!(
                (0.6..1.4).contains(&ratio),
                "{}: sim {sim:.2} vs analytic {ana:.2}",
                spec.name()
            );
        }
    }

    #[test]
    fn burst_amat_ordering_matches_table4() {
        // The design decision: among 1024-PE candidates the flat design
        // has the best AMAT, two-level the worst, TeraPool in between —
        // and within four-level rows AMAT grows with α.
        let flat = amat(&HierSpec::new(1024, 1, 1, 1), 4).amat;
        let two = amat(&HierSpec::new(8, 128, 1, 1), 4).amat;
        let tp = amat(&HierSpec::terapool(), 4).amat;
        let tp16 = amat(&HierSpec::new(16, 4, 4, 4), 4).amat;
        assert!(flat < tp && tp < two, "{flat} {tp} {two}");
        assert!(tp < tp16, "{tp} {tp16}");
    }

    #[test]
    fn throughput_flat_matches() {
        let t = throughput(&HierSpec::new(1024, 1, 1, 1), 4);
        assert!((t - 0.885).abs() < 0.03, "throughput {t}");
    }

    #[test]
    fn closed_form_drain_convention() {
        // p = 1, n inputs: everyone waits the full drain n-1.
        assert_eq!(expected_latency_n_to_1(16, 1.0), 15.0);
        assert_eq!(expected_latency_n_to_1(4, 1.0), 3.0);
        // Flat 1024×4096 at p = 1: the paper's 1.13 AMAT ⇒ 0.13 contention.
        let e = expected_latency_n_to_k(1024, 4096, 1.0);
        assert!((e - 0.13).abs() < 0.01, "flat contention {e}");
    }

    #[test]
    fn level_contention_at_generalizes_burst_rate() {
        let tp = HierSpec::terapool();
        for l in 0..4 {
            // At the burst rate the generalization is the original.
            let a = tp.level_contention(l);
            let b = tp.level_contention_at(l, tp.level_prob(l));
            assert!((a - b).abs() < 1e-12, "level {l}: {a} vs {b}");
            // Lighter traffic never contends more, and zero not at all.
            let light = tp.level_contention_at(l, tp.level_prob(l) * 0.1);
            assert!(light <= a + 1e-12, "level {l}: {light} > {a}");
            assert_eq!(tp.level_contention_at(l, 0.0), 0.0);
        }
    }

    #[test]
    fn queue_adjustment_saturates() {
        // Saturated port (offered 2.0 over 8 inputs at 0.25) inflates the
        // effective rate; an unloaded port stays put.
        let hot = queue_adjusted_rate(8, 0.25);
        assert!(hot > 0.4 && hot <= 1.0, "{hot}");
        let cold = queue_adjusted_rate(8, 0.01);
        assert!((cold - 0.01).abs() < 0.005, "{cold}");
    }
}
