//! # TeraPool reproduction library
//!
//! A from-scratch reproduction of *TeraPool: A Physical Design Aware, 1024
//! RISC-V Cores Shared-L1-Memory Scaled-up Cluster Design with High
//! Bandwidth Main Memory Link* (Zhang et al., IEEE TC,
//! 10.1109/TC.2025.3603692) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * a **cycle-level functional + timing simulator** of the TeraPool
//!   cluster: Snitch-like PEs ([`pe`]), the hierarchical Tile → SubGroup →
//!   Group crossbar interconnect sharded into per-Tile memory domains
//!   ([`interconnect`]), the banked shared-L1 SPM with the paper's hybrid
//!   address map, stored as per-Tile slices ([`memory`]), and the cluster
//!   composition with fork-join barriers ([`cluster`]) — runnable on a
//!   serial reference engine or the deterministic fully sharded engine
//!   ([`parallel`], `Cluster::run_parallel`), which distributes PE
//!   stepping, per-Tile bank arbitration, response/wake delivery,
//!   barrier/DMA bookkeeping and the cross-shard transfer merge across
//!   host threads by the paper's Tile → SubGroup → Group hierarchy
//!   (O(threads) coordinator) while staying bit-identical to the serial
//!   engine;
//! * the paper's **analytical AMAT model** of hierarchical crossbars,
//!   Eqs. (3)–(6) ([`amat`]) — regenerates Table 4 and Fig. 8b;
//! * the **High Bandwidth Memory Link**: a cycle-level HBM2E channel model
//!   standing in for DRAMsys5.0 ([`hbm`]), the tree-like AXI4 interconnect
//!   ([`axi`]) and the modular frontend/midend/backend iDMA ([`dma`]) —
//!   regenerates Fig. 9 and Fig. 14b;
//! * **benchmark kernels** as per-PE instruction trace builders: AXPY,
//!   DOTP, tiled GEMM, radix-4 FFT, CSR SpMMadd ([`kernels`]) —
//!   regenerates Fig. 14a and Table 6;
//! * the **Workload/Session API** ([`kernels::Workload`] + the static
//!   registry, [`session::Session`]): the single run path — every kernel
//!   is a registry entry, every run returns a structured
//!   [`report::RunReport`] (config fingerprint, stats, per-class
//!   interconnect numbers, validation verdict, JSON-serializable), and
//!   batches of workload×config jobs fan out across host threads with
//!   bit-identical-to-sequential results;
//! * the **scale-out system layer** ([`topology`], [`system`],
//!   [`session::Session::system`]): a declarative multi-cluster topology
//!   (text format under `examples/`, programmatic [`Topology::split`]),
//!   point-to-point / 2-D-mesh inter-cluster links and one off-chip
//!   memory node on a shared bus; kernels are chunked data-parallel
//!   across the clusters (band staging, halo broadcasts, deterministic
//!   merge) and the compute phase steps cluster-parallel on host
//!   threads, bit-identical to serial system stepping — regenerates the
//!   scale-up-vs-scale-out comparison (`fig-scaleout`);
//! * the **design-space sweep service** ([`sweep`]): a declarative config
//!   grid (`examples/*.sweep`) explored with the calibrated estimator via
//!   batched fan-out, Pareto-refined over (estimated cycles, area proxy),
//!   with only frontier points re-run cycle-accurately — per-point failure
//!   isolation, resumable checkpoints and an in-process estimate-drift
//!   verdict per frontier point (`terapool sweep-space`, `fig-sweep`);
//! * **physical-design models** calibrated on the paper's GF12 data:
//!   routing congestion, GE area, per-instruction energy + EDP, EDA effort
//!   ([`physical`]) — regenerates Table 3/Fig. 3 and Figs. 11–13;
//! * the **golden runtime** ([`runtime`]) that loads the JAX/Pallas AOT
//!   artifact manifest and the build-time-evaluated golden outputs
//!   (`artifacts/*.golden.bin`) used as references for the simulator's
//!   functional results.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! Rust binary is self-contained afterwards and depends on **no external
//! crates** (the offline build has no registry — [`errors`] stands in for
//! anyhow, [`rng`] for rand, [`parallel`] for rayon, `benches/util.rs`
//! for criterion, `tests/properties.rs` for proptest). See DESIGN.md for
//! the module ↔ experiment map and EXPERIMENTS.md for paper-vs-measured
//! results.

pub mod amat;
pub mod axi;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dma;
pub mod errors;
pub mod estimate;
pub mod hbm;
pub mod interconnect;
pub mod isa;
pub mod kernels;
pub mod memory;
pub mod parallel;
pub mod pe;
pub mod physical;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod session;
pub mod stats;
pub mod sweep;
pub mod system;
pub mod topology;

pub use config::{ClusterConfig, Scale};
pub use kernels::Workload;
pub use report::RunReport;
pub use session::{Job, Session};
pub use topology::Topology;
