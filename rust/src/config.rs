//! Cluster configuration: the αC-βT-γSG-δG hierarchy of the paper
//! (Sec. 3.2, Table 4), NUMA latency profiles (Sec. 4.2), the hybrid L1
//! memory map (Sec. 5.4) and operating points (Sec. 6.2).
//!
//! All experiment presets live here: the three TeraPool operating points
//! (`terapool_7/9/11`), the Table-6 baselines (`mempool`, `occamy`) and
//! every Table-4 hierarchy candidate.

/// Hierarchy shape αC-βT-γSG-δG: `pes_per_tile` cores per Tile, grouped
/// into SubGroups, Groups, and the full cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hierarchy {
    /// α — PEs per Tile.
    pub pes_per_tile: usize,
    /// β — Tiles per SubGroup.
    pub tiles_per_subgroup: usize,
    /// γ — SubGroups per Group (1 collapses the SubGroup level).
    pub subgroups_per_group: usize,
    /// δ — Groups per cluster (1 collapses the Group level).
    pub groups: usize,
}

impl Hierarchy {
    pub const fn num_pes(&self) -> usize {
        self.pes_per_tile * self.tiles_per_subgroup * self.subgroups_per_group * self.groups
    }
    pub const fn num_tiles(&self) -> usize {
        self.tiles_per_subgroup * self.subgroups_per_group * self.groups
    }
    pub const fn num_subgroups(&self) -> usize {
        self.subgroups_per_group * self.groups
    }
    pub const fn tiles_per_group(&self) -> usize {
        self.tiles_per_subgroup * self.subgroups_per_group
    }
}

/// Round-trip zero-load L1 access latency (cycles) per NUMA distance, as
/// seen by a load: issue cycle → data-ready cycle (Fig. 8b).
///
/// TeraPool ships three hardware-parameterizable remote-Group latencies
/// (7/9/11 cycles) trading frequency for latency (Sec. 6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyCfg {
    /// Same-Tile access (fully combinational local crossbar).
    pub local: u32,
    /// Different Tile, same SubGroup.
    pub subgroup: u32,
    /// Different SubGroup, same Group.
    pub group: u32,
    /// Remote Group (7, 9 or 11 in TeraPool).
    pub remote_group: u32,
}

/// Main-memory DDR rate of the HBM2E parts (Sec. 5.3): Micron
/// MT54A16G808A00AC-36 supports 2.8 / 3.2 / 3.6 Gbit/s/pin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DdrRate {
    G2_8,
    G3_2,
    G3_6,
}

impl DdrRate {
    /// Gbit/s/pin.
    pub fn gbps(&self) -> f64 {
        match self {
            DdrRate::G2_8 => 2.8,
            DdrRate::G3_2 => 3.2,
            DdrRate::G3_6 => 3.6,
        }
    }
    /// Peak bandwidth of the 16-channel (2-stack × 8) HBM2E subsystem in
    /// GB/s: 16 channels × 128 pins × rate / 8.
    pub fn peak_gbps_total(&self) -> f64 {
        16.0 * 128.0 * self.gbps() / 8.0
    }
}

/// Full cluster configuration. `Default` is TeraPool(1-3-5-9) @ 850 MHz —
/// the paper's energy-optimal operating point (Sec. 6.3).
/// Experiment scale: `Full` regenerates paper-sized workloads (minutes),
/// `Fast` shrinks problem sizes for smoke runs and CI. Lives next to
/// [`ClusterConfig`] because workload builders resolve their default
/// problem sizes from the (config, scale) pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Full,
    Fast,
}

impl Scale {
    pub fn pick<T>(&self, full: T, fast: T) -> T {
        match self {
            Scale::Full => full,
            Scale::Fast => fast,
        }
    }

    /// Stable lowercase tag (used by `RunReport` serialization).
    pub fn tag(&self) -> &'static str {
        match self {
            Scale::Full => "full",
            Scale::Fast => "fast",
        }
    }
}

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub name: String,
    pub hierarchy: Hierarchy,
    pub latency: LatencyCfg,
    /// Banking factor: L1 banks per PE (4 in TeraPool → 4096 banks).
    pub banking_factor: usize,
    /// Words (32-bit) per SPM bank (256 → 1 KiB banks, 4 MiB total).
    pub words_per_bank: usize,
    /// Words of the per-Tile *sequential region* (Sec. 5.4; 512 KiB
    /// cluster-wide by default → 1024 words/Tile in TeraPool).
    pub seq_words_per_tile: usize,
    /// LSU transaction-table entries (8 in TeraPool, Sec. 4.1).
    pub tx_table_entries: usize,
    /// Operating frequency (MHz), typical corner TT/0.80 V/25 °C.
    pub freq_mhz: f64,
    /// HBM2E DDR rate for the HBML experiments.
    pub ddr: DdrRate,
    /// Barrier wake-up broadcast latency (cycles) after the last arrival —
    /// models the WFI wake propagation through the hierarchy.
    pub barrier_wakeup: u32,
    /// TCDM burst access (the sequel paper "TCDM Burst Access: Breaking
    /// the Bandwidth Barrier in Shared-L1 RVV Clusters Beyond 1000
    /// FPUs"): kernel trace builders emit multi-word `LdBurst`/`StBurst`
    /// ops where their access patterns allow it, moving up to
    /// `MAX_BURST_WORDS` words per port grant. Off by default — the
    /// baseline paper's one-word-per-request interconnect.
    pub burst: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self::terapool(9)
    }
}

impl ClusterConfig {
    /// TeraPool 8C-8T-4SG-4G with the given remote-Group round-trip
    /// latency (7, 9 or 11) and the matching implementation frequency
    /// (730 / 850 / 910 MHz, Sec. 6.2).
    pub fn terapool(remote_group_latency: u32) -> Self {
        let freq = match remote_group_latency {
            7 => 730.0,
            9 => 850.0,
            11 => 910.0,
            l => panic!("TeraPool ships 7/9/11-cycle remote-Group configs, got {l}"),
        };
        ClusterConfig {
            name: format!("terapool-1-3-5-{remote_group_latency}"),
            hierarchy: Hierarchy {
                pes_per_tile: 8,
                tiles_per_subgroup: 8,
                subgroups_per_group: 4,
                groups: 4,
            },
            latency: LatencyCfg {
                local: 1,
                subgroup: 3,
                group: 5,
                remote_group: remote_group_latency,
            },
            banking_factor: 4,
            words_per_bank: 256,
            seq_words_per_tile: 1024,
            tx_table_entries: 8,
            freq_mhz: freq,
            ddr: DdrRate::G3_6,
            barrier_wakeup: 10,
            burst: false,
        }
    }

    /// MemPool baseline (Table 6): 256 cores, 4C tiles, 16 tiles/group,
    /// 4 groups, 1 MiB L1, latencies 1-3-5. The SubGroup level collapses.
    pub fn mempool() -> Self {
        ClusterConfig {
            name: "mempool".into(),
            hierarchy: Hierarchy {
                pes_per_tile: 4,
                tiles_per_subgroup: 16,
                subgroups_per_group: 1,
                groups: 4,
            },
            latency: LatencyCfg {
                local: 1,
                subgroup: 3, // same-group in MemPool terms
                group: 3,    // unused (γ=1)
                remote_group: 5,
            },
            banking_factor: 4,
            words_per_bank: 256,
            seq_words_per_tile: 1024,
            tx_table_entries: 8,
            freq_mhz: 500.0,
            ddr: DdrRate::G3_6,
            barrier_wakeup: 8,
            burst: false,
        }
    }

    /// Occamy-style single compute cluster (Table 6): 8 PEs sharing
    /// 128 KiB through a 1-cycle crossbar.
    pub fn occamy() -> Self {
        ClusterConfig {
            name: "occamy".into(),
            hierarchy: Hierarchy {
                pes_per_tile: 8,
                tiles_per_subgroup: 1,
                subgroups_per_group: 1,
                groups: 1,
            },
            latency: LatencyCfg {
                local: 1,
                subgroup: 1,
                group: 1,
                remote_group: 1,
            },
            banking_factor: 4,
            words_per_bank: 1024, // 32 banks × 4 KiB = 128 KiB
            seq_words_per_tile: 1024,
            tx_table_entries: 8,
            freq_mhz: 1000.0,
            ddr: DdrRate::G3_6,
            barrier_wakeup: 4,
            burst: false,
        }
    }

    /// A scaled-down TeraPool for fast unit tests: 4C-2T-2SG-2G = 32 PEs,
    /// 128 banks, same latency profile as the full machine.
    pub fn tiny() -> Self {
        ClusterConfig {
            name: "tiny-4c-2t-2sg-2g".into(),
            hierarchy: Hierarchy {
                pes_per_tile: 4,
                tiles_per_subgroup: 2,
                subgroups_per_group: 2,
                groups: 2,
            },
            latency: LatencyCfg {
                local: 1,
                subgroup: 3,
                group: 5,
                remote_group: 9,
            },
            banking_factor: 4,
            words_per_bank: 256,
            seq_words_per_tile: 64,
            tx_table_entries: 8,
            freq_mhz: 850.0,
            ddr: DdrRate::G3_6,
            barrier_wakeup: 10,
            burst: false,
        }
    }

    /// Builder-style toggle for the TCDM burst knob (tests, CLI, sweeps).
    pub fn with_burst(mut self, on: bool) -> Self {
        self.burst = on;
        self
    }

    // ------------------------------------------------------ derived ----

    pub fn num_pes(&self) -> usize {
        self.hierarchy.num_pes()
    }
    pub fn num_tiles(&self) -> usize {
        self.hierarchy.num_tiles()
    }
    pub fn num_banks(&self) -> usize {
        self.num_pes() * self.banking_factor
    }
    pub fn banks_per_tile(&self) -> usize {
        self.hierarchy.pes_per_tile * self.banking_factor
    }
    pub fn banks_per_subgroup(&self) -> usize {
        self.banks_per_tile() * self.hierarchy.tiles_per_subgroup
    }
    /// Total L1 words (32-bit).
    pub fn l1_words(&self) -> usize {
        self.num_banks() * self.words_per_bank
    }
    pub fn l1_bytes(&self) -> usize {
        self.l1_words() * 4
    }
    /// Words of the sequential region across all Tiles.
    pub fn seq_words_total(&self) -> usize {
        self.seq_words_per_tile * self.num_tiles()
    }
    /// Rows per bank reserved for the sequential region.
    pub fn seq_rows_per_bank(&self) -> usize {
        self.seq_words_per_tile.div_ceil(self.banks_per_tile())
    }
    /// Peak FP32 performance (GFLOP/s): 1 FMA = 2 FLOP per PE per cycle.
    pub fn peak_gflops_f32(&self) -> f64 {
        self.num_pes() as f64 * 2.0 * self.freq_mhz / 1000.0
    }
    /// Peak FP16 (zhinx SIMD ×2) performance (GFLOP/s).
    pub fn peak_gflops_f16(&self) -> f64 {
        2.0 * self.peak_gflops_f32()
    }

    /// Zero-load round-trip latency for a (source tile, dest tile) pair.
    pub fn numa_latency(&self, src_tile: usize, dst_tile: usize) -> u32 {
        let h = &self.hierarchy;
        let tpg = h.tiles_per_group();
        let (sg_g, dg_g) = (src_tile / tpg, dst_tile / tpg);
        if sg_g != dg_g {
            return self.latency.remote_group;
        }
        let (s_sg, d_sg) = (
            (src_tile % tpg) / h.tiles_per_subgroup,
            (dst_tile % tpg) / h.tiles_per_subgroup,
        );
        if s_sg != d_sg {
            self.latency.group
        } else if src_tile != dst_tile {
            self.latency.subgroup
        } else {
            self.latency.local
        }
    }

    /// Tile index of a PE.
    pub fn tile_of_pe(&self, pe: usize) -> usize {
        pe / self.hierarchy.pes_per_tile
    }
    /// Tile index of a bank.
    pub fn tile_of_bank(&self, bank: usize) -> usize {
        bank / self.banks_per_tile()
    }

    /// Stable fingerprint of every timing-relevant knob (FNV-1a over the
    /// canonical `Debug` rendering, hex). Two configs with the same
    /// fingerprint produce bit-identical simulations; `RunReport` carries
    /// it so results can be matched to the exact configuration that
    /// produced them.
    pub fn fingerprint(&self) -> String {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x100_0000_01b3;
        let mut h = FNV_OFFSET;
        for b in format!("{self:?}").bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        format!("{h:016x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terapool_shape_matches_paper() {
        let c = ClusterConfig::terapool(9);
        assert_eq!(c.num_pes(), 1024);
        assert_eq!(c.num_tiles(), 128);
        assert_eq!(c.num_banks(), 4096);
        assert_eq!(c.l1_bytes(), 4 * 1024 * 1024); // 4 MiB
        assert_eq!(c.freq_mhz, 850.0);
    }

    #[test]
    fn terapool_operating_points() {
        assert_eq!(ClusterConfig::terapool(7).freq_mhz, 730.0);
        assert_eq!(ClusterConfig::terapool(11).freq_mhz, 910.0);
        // Peak at 910 MHz: 1024 PEs × 2 FLOP = 1.86 SP-TFLOP/s (paper: 1.89
        // counting the redundant-precision paths; FP16 doubles it).
        let c = ClusterConfig::terapool(11);
        assert!((c.peak_gflops_f32() - 1863.68).abs() < 1.0);
        assert!((c.peak_gflops_f16() - 2.0 * 1863.68).abs() < 2.0);
    }

    #[test]
    fn mempool_occamy_shapes() {
        assert_eq!(ClusterConfig::mempool().num_pes(), 256);
        assert_eq!(ClusterConfig::mempool().l1_bytes(), 1024 * 1024);
        assert_eq!(ClusterConfig::occamy().num_pes(), 8);
        assert_eq!(ClusterConfig::occamy().l1_bytes(), 128 * 1024);
    }

    #[test]
    fn numa_latency_classes() {
        let c = ClusterConfig::terapool(9);
        assert_eq!(c.numa_latency(0, 0), 1); // same tile
        assert_eq!(c.numa_latency(0, 1), 3); // same subgroup
        assert_eq!(c.numa_latency(0, 8), 5); // same group, other SG
        assert_eq!(c.numa_latency(0, 32), 9); // remote group
        assert_eq!(c.numa_latency(33, 32), 3); // same subgroup in group 1
        assert_eq!(c.numa_latency(33, 33), 1);
        assert_eq!(c.numa_latency(127, 0), 9);
    }

    #[test]
    fn hbm_peak_rates() {
        assert!((DdrRate::G2_8.peak_gbps_total() - 716.8).abs() < 0.1);
        assert!((DdrRate::G3_2.peak_gbps_total() - 819.2).abs() < 0.1);
        assert!((DdrRate::G3_6.peak_gbps_total() - 921.6).abs() < 0.1);
    }

    #[test]
    fn tiny_is_consistent() {
        let c = ClusterConfig::tiny();
        assert_eq!(c.num_pes(), 32);
        assert_eq!(c.num_banks(), 128);
        assert!(c.seq_words_total() < c.l1_words());
    }

    #[test]
    fn fingerprint_is_stable_and_knob_sensitive() {
        let a = ClusterConfig::terapool(9);
        assert_eq!(a.fingerprint(), ClusterConfig::terapool(9).fingerprint());
        assert_eq!(a.fingerprint().len(), 16);
        // Any timing-relevant knob must move the fingerprint.
        let mut b = ClusterConfig::terapool(9);
        b.tx_table_entries = 4;
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), ClusterConfig::terapool(11).fingerprint());
        // The burst knob is timing-relevant and must move it too.
        assert_ne!(
            a.fingerprint(),
            ClusterConfig::terapool(9).with_burst(true).fingerprint()
        );
    }

    #[test]
    fn scale_picks_and_tags() {
        assert_eq!(Scale::Full.pick(1, 2), 1);
        assert_eq!(Scale::Fast.pick(1, 2), 2);
        assert_eq!(Scale::Full.tag(), "full");
        assert_eq!(Scale::Fast.tag(), "fast");
    }
}
