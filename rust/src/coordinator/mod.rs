//! The benchmark coordinator: one driver per paper table/figure, shared
//! by the CLI (`terapool <experiment>`) and the criterion benches.
//!
//! Every function returns a [`crate::report::Table`] with the same rows
//! the paper reports; EXPERIMENTS.md records paper-vs-measured. The
//! cluster-simulator experiments (Fig. 14a/b, Table 6, headline) take a
//! [`crate::session::Session`] — the single run path — so scale, engine
//! threads and report collection are configured once by the caller.

pub mod experiments;

pub use experiments::*;

/// Re-export: `Scale` moved to [`crate::config`] so workload builders can
/// resolve their default problem sizes without depending on the
/// coordinator layer.
pub use crate::config::Scale;

/// Experiment index: name ↔ one-line description, the source of truth for
/// the CLI dispatch and `terapool --list`.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("table3", "routing quality vs crossbar complexity (GF12)"),
    ("table4", "hierarchical interconnect analysis (AMAT, complexity)"),
    ("fig8", "L1 access latency per hierarchy level"),
    ("fig9", "HBML bandwidth vs cluster frequency x DDR rate"),
    ("fig11", "EDA implementation-time breakdown"),
    ("fig12", "hierarchical area breakdown"),
    ("fig13", "instruction energy + EDP per operating point"),
    ("fig14a", "kernel IPC / stall fractions (batched workload sweep)"),
    ("fig14b", "double-buffered kernels with HBM2E transfers"),
    ("table5", "state-of-the-art cluster comparison"),
    ("table6", "main-memory Byte/FLOP vs IPC across cluster scales"),
    ("scaling", "Sec. 2 Kung balance under scale-up"),
    ("headline", "headline numbers vs paper"),
    ("all", "every experiment above, in order"),
    ("fig-scaleout", "scale-up vs scale-out: 1 vs 2/4 clusters at equal PEs"),
    ("system", "chunked GEMM + FFT across a --topology system (checked)"),
    ("validate", "kernels vs host references + AOT goldens"),
    ("ablate-txtable", "LSU transaction-table depth ablation"),
    ("ablate-addrmap", "sequential-region size ablation"),
    ("ablate-spill", "spill-register latency vs frequency ablation"),
    ("fig-sweep", "estimate-guided design-space sweep: Pareto frontier + drift"),
];
