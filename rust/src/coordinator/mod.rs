//! The benchmark coordinator: one driver per paper table/figure, shared
//! by the CLI (`terapool <experiment>`) and the criterion benches.
//!
//! Every function returns a [`crate::report::Table`] with the same rows
//! the paper reports; EXPERIMENTS.md records paper-vs-measured.

pub mod experiments;

pub use experiments::*;

/// Experiment scale: `Full` regenerates paper-sized workloads (minutes),
/// `Fast` shrinks problem sizes for smoke runs and CI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Full,
    Fast,
}

impl Scale {
    pub fn pick<T>(&self, full: T, fast: T) -> T {
        match self {
            Scale::Full => full,
            Scale::Fast => fast,
        }
    }
}
