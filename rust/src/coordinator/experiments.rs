//! Experiment drivers — one per paper table/figure (see DESIGN.md's
//! experiment index).
//!
//! Cluster-simulator experiments (Fig. 14a/b, Table 6, headline) take a
//! [`Session`] and submit their kernels as one **batch** of
//! workload×config jobs — the session's host-thread budget makes the
//! sweep embarrassingly parallel while every simulated number stays
//! bit-identical to a sequential run. There are no `*_threads` variants:
//! the engine/batch choice lives in the session, not in duplicated
//! drivers.

use crate::amat::{self, HierSpec};
use crate::config::{ClusterConfig, DdrRate};
use crate::dma::{hbm_image_clear, DmaDescriptor, DmaSubsystem};
use crate::kernels::{self, axpy::Axpy, gemm::Gemm, gemm::GemmParams};
use crate::memory::L1Memory;
use crate::physical::{area, congestion, eda, energy, scaling, soa};
use crate::report::{f1, f2, f3, int, pct, Table};
use crate::session::{Job, Session};
use crate::topology::Topology;

use super::Scale;

// ------------------------------------------------------------------
// Table 3 / Fig. 3 — routing quality vs crossbar complexity
// ------------------------------------------------------------------

pub fn table3() -> Table {
    let mut t = Table::new(
        "Table 3 — Routing quality of logarithmic-staged crossbars (GF12, 13M)",
        &["Complexity", "H%", "V%", "Overall%", "Area kGE", "CritPath ns", "Routable"],
    );
    for c in [256, 512, 1024, 1280, 1536, 2048, 3072, 4096] {
        let q = congestion::predict(c);
        t.row(vec![
            int(c as u64),
            f2(q.congestion_h),
            f2(q.congestion_v),
            f2(q.congestion),
            f1(q.area_kge),
            f2(q.critical_path_ns),
            if congestion::is_routable(c) { "yes" } else { "NO" }.into(),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Table 4 — hierarchical interconnect design analysis
// ------------------------------------------------------------------

pub fn table4(scale: Scale) -> Table {
    let seeds = scale.pick(8, 2);
    let mut t = Table::new(
        "Table 4 — Hierarchical interconnect analysis (1024 PEs, 4096 banks)",
        &[
            "Hierarchy", "ZeroLd", "AMAT", "AMAT(sim)", "Thrpt", "TotalCplx",
            "CritCplx", "CombDelay", "Routable",
        ],
    );
    for spec in HierSpec::table4_rows() {
        let zl = spec.zero_load_latency();
        let a = spec.analytic_amat(); // closed-form Eqs. (4)-(6)
        let sim = amat::amat(&spec, seeds).amat; // event-level cross-check
        t.row(vec![
            spec.name(),
            f3(zl),
            f3(a),
            f3(sim),
            f3(spec.analytic_throughput()),
            int(spec.total_complexity() as u64),
            int(spec.critical_complexity() as u64),
            f1(spec.critical_comb_delay()),
            if congestion::is_routable(spec.critical_complexity()) { "yes" } else { "NO" }.into(),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Fig. 8b — access latency per hierarchy level
// ------------------------------------------------------------------

pub fn fig8(scale: Scale) -> Table {
    let seeds = scale.pick(8, 2);
    let spec = HierSpec::terapool();
    let r = amat::amat(&spec, seeds);
    let mut t = Table::new(
        "Fig. 8b — TeraPool L1 access latency by hierarchy level (1-3-5-7)",
        &["Level", "Zero-load (cyc)", "Random-traffic avg (cyc)"],
    );
    for (i, name) in ["local Tile", "SubGroup", "Group", "remote Group"].iter().enumerate() {
        t.row(vec![
            name.to_string(),
            int(spec.level_latency(i) as u64),
            f2(r.amat_per_level[i]),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Fig. 9 — HBML bandwidth vs cluster frequency × DDR rate
// ------------------------------------------------------------------

/// Transfer the full interleaved L1 in and out through the HBML; report
/// achieved GB/s and utilization.
pub fn hbml_sweep_point(freq_mhz: f64, ddr: DdrRate, words: u32) -> (f64, f64) {
    hbm_image_clear();
    let mut cfg = ClusterConfig::terapool(9);
    cfg.freq_mhz = freq_mhz;
    cfg.ddr = ddr;
    let mut l1 = L1Memory::new(&cfg);
    let mut dma = DmaSubsystem::new(&cfg);
    let base = l1.map.interleaved_base();
    let inbound = dma.register(DmaDescriptor { l1_word: base, mem_byte: 0, words, to_l1: true });
    let outbound = dma.register(DmaDescriptor {
        l1_word: base,
        mem_byte: words as u64 * 4,
        words,
        to_l1: false,
    });
    dma.start(inbound, 0);
    let mut now = 0u64;
    while !dma.is_done(inbound) {
        dma.step(now, &mut l1);
        now += 1;
        assert!(now < 100_000_000, "HBML inbound runaway");
    }
    dma.start(outbound, now);
    while !dma.is_done(outbound) {
        dma.step(now, &mut l1);
        now += 1;
        assert!(now < 100_000_000, "HBML outbound runaway");
    }
    let gbps = dma.hbm.achieved_gbps(now);
    (gbps, gbps / ddr.peak_gbps_total())
}

pub fn fig9(scale: Scale) -> Table {
    // Full 3.5 MiB interleaved region in+out (paper: 4 MiB L1).
    let words = scale.pick(896 * 1024, 64 * 1024) as u32;
    let mut t = Table::new(
        "Fig. 9 — HBML transfer bandwidth (L1 read+write via 16×HBM2E)",
        &["Cluster MHz", "DDR Gbit/s/pin", "Peak GB/s", "Achieved GB/s", "Utilization"],
    );
    for freq in [500.0, 700.0, 800.0, 900.0] {
        for ddr in [DdrRate::G2_8, DdrRate::G3_2, DdrRate::G3_6] {
            let (gbps, util) = hbml_sweep_point(freq, ddr, words);
            t.row(vec![
                f1(freq),
                f1(ddr.gbps()),
                f1(ddr.peak_gbps_total()),
                f1(gbps),
                pct(util),
            ]);
        }
    }
    t
}

// ------------------------------------------------------------------
// Fig. 11 — EDA implementation-time breakdown
// ------------------------------------------------------------------

pub fn fig11() -> Table {
    let mut t = Table::new(
        "Fig. 11 — Relative EDA implementation time for a TeraPool Group",
        &["Config", "Synth", "Place", "CTS", "Route", "TimingOpt", "Total", "Timing %"],
    );
    for cfg in eda::FIG11_CONFIGS {
        let b = eda::breakdown(cfg);
        t.row(vec![
            cfg.name(),
            f2(b.synthesis),
            f2(b.placement),
            f2(b.cts),
            f2(b.routing),
            f2(b.timing_opt),
            f2(b.total()),
            pct(b.timing_fraction()),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Fig. 12 — area breakdown
// ------------------------------------------------------------------

pub fn fig12() -> Table {
    let b = area::breakdown(&ClusterConfig::terapool(9));
    let total = b.total();
    let mut t = Table::new(
        "Fig. 12 — TeraPool hierarchical area breakdown",
        &["Component", "MGE", "% of cluster"],
    );
    let row = |t: &mut Table, name: &str, ge: f64| {
        t.row(vec![name.into(), f2(ge / 1e6), pct(ge / total)]);
    };
    row(&mut t, "SPM banks", b.spm);
    row(&mut t, "Snitch cores", b.cores);
    row(&mut t, "IPUs (Xpulpimg)", b.ipus);
    row(&mut t, "FP subsystems", b.fpss);
    row(&mut t, "DIVSQRT units", b.divsqrt);
    row(&mut t, "Instruction caches", b.icache);
    row(&mut t, "Hierarchical interconnect", b.interconnect);
    row(&mut t, "HBML (AXI + iDMA)", b.hbml);
    row(&mut t, "TOTAL", total);
    t
}

// ------------------------------------------------------------------
// Fig. 13 — instruction energy breakdown + EDP
// ------------------------------------------------------------------

pub fn fig13() -> Table {
    let mut t = Table::new(
        "Fig. 13 — Instruction energy (pJ/instr/core) and EDP (pJ·ns)",
        &[
            "Instruction", "7cyc/730MHz pJ", "9cyc/850MHz pJ", "11cyc/910MHz pJ",
            "EDP@730", "EDP@850", "EDP@910", "EDP optimum",
        ],
    );
    let models = [
        energy::EnergyModel::for_config(7),
        energy::EnergyModel::for_config(9),
        energy::EnergyModel::for_config(11),
    ];
    for i in energy::FIG13_INSTRS {
        let pj: Vec<f64> = models.iter().map(|m| m.pj(i)).collect();
        let edp: Vec<f64> = models.iter().map(|m| m.edp(i)).collect();
        let best = (0..3).min_by(|&a, &b| edp[a].total_cmp(&edp[b])).unwrap();
        t.row(vec![
            i.name().into(),
            f2(pj[0]),
            f2(pj[1]),
            f2(pj[2]),
            f2(edp[0]),
            f2(edp[1]),
            f2(edp[2]),
            ["730 MHz", "850 MHz", "910 MHz"][best].into(),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Fig. 14a — kernel IPC and stall fractions
// ------------------------------------------------------------------

/// The Fig. 14a kernel sweep, in reporting order. Resolved through the
/// workload registry ([`kernels::lookup`]) — this list is data, not
/// dispatch.
pub const FIG14A_KERNELS: [&str; 5] = ["axpy", "dotp", "gemm", "fft", "spmmadd"];

/// Registry jobs for a kernel-name list, all on the same config.
fn jobs_for(cfg: &ClusterConfig, names: &[&str]) -> Vec<Job> {
    names
        .iter()
        .map(|k| Job::new(cfg.clone(), kernels::lookup(k).expect("registered kernel")))
        .collect()
}

pub fn fig14a(s: &Session) -> Table {
    let cfg = ClusterConfig::terapool(9); // the energy-optimal 850 MHz point
    let em = energy::EnergyModel::for_cluster(&cfg);
    let mut t = Table::new(
        "Fig. 14a — Kernel IPC / stall fractions on TeraPool-1-3-5-9 @ 850 MHz",
        &[
            "Kernel", "IPC", "Instr%", "LSU%", "RAW%", "Ctrl%", "WFI%",
            "AMAT", "GFLOP/s", "GFLOP/s/W",
        ],
    );
    for r in s.run_batch(&jobs_for(&cfg, &FIG14A_KERNELS)) {
        let r = r.expect("fig14a kernel run");
        let s = &r.stats;
        t.row(vec![
            r.workload.clone(),
            f2(s.ipc()),
            pct(s.fraction(s.instructions)),
            pct(s.fraction(s.stall_lsu)),
            pct(s.fraction(s.stall_raw)),
            pct(s.fraction(s.stall_ctrl)),
            pct(s.fraction(s.stall_synch)),
            f2(s.amat),
            f1(s.gflops()),
            f1(em.gflops_per_watt(s)),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Fig. 14b — double-buffered kernels with HBM2E
// ------------------------------------------------------------------

pub fn fig14b(s: &Session) -> Table {
    let cfg = ClusterConfig::terapool(9);
    let mut t = Table::new(
        "Fig. 14b — Double-buffered kernels with HBM2E transfers",
        &["Kernel", "Cycles", "Compute %", "Transfer-hidden %", "MB moved", "IPC"],
    );
    for r in s.run_batch(&jobs_for(&cfg, &["db-gemm", "db-dotp", "db-axpy"])) {
        let r = r.expect("fig14b kernel run");
        let st = &r.stats;
        // Compute fraction: cycles not stalled on synchronization (DMA
        // wait + barrier) — the Fig. 14b split.
        let compute = 1.0 - st.stall_synch as f64 / (st.cycles as f64 * st.num_pes as f64);
        t.row(vec![
            r.kind.trim_start_matches("db-").into(),
            int(st.cycles),
            pct(compute),
            pct(compute), // hidden fraction == compute share
            f1(r.dma_bytes.expect("db workloads attach the HBML") as f64 / 1e6),
            f2(st.ipc()),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Table 5 — SoA comparison
// ------------------------------------------------------------------

pub fn table5() -> Table {
    let mut t = Table::new(
        "Table 5 — State-of-the-art cluster-based many-core designs",
        &[
            "Design", "Scaling", "PE", "Exec", "PEs/cluster", "Total PEs",
            "L1 MiB", "L1 B/cyc", "L2 B/cyc", "L1 latency", "Peak op/cyc", "OSS",
        ],
    );
    let mut rows = vec![soa::terapool_row(&ClusterConfig::terapool(9))];
    rows.extend(soa::literature_rows());
    for r in rows {
        t.row(vec![
            r.name.into(),
            r.scaling.into(),
            r.pe.into(),
            r.execution.into(),
            int(r.pes_per_cluster as u64),
            int(r.total_pes as u64),
            f2(r.shared_l1_mib),
            f1(r.l1_bw),
            r.l2_bw.map(f1).unwrap_or_else(|| "N.A.".into()),
            r.l1_latency.into(),
            f1(r.peak_ops),
            if r.open_source { "yes" } else { "no" }.into(),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Table 6 — data-transfer cost vs compute IPC across cluster scales
// ------------------------------------------------------------------

pub fn table6(s: &Session) -> Table {
    let scale = s.current_scale();
    let mut t = Table::new(
        "Table 6 — Main-memory Byte/FLOP vs IPC (AXPY f32 / MatMul f32)",
        &[
            "Cluster", "Max tiling MiB", "AXPY B/F", "AXPY IPC", "GEMM B/F", "GEMM IPC",
        ],
    );
    let configs = [
        ClusterConfig::terapool(9),
        ClusterConfig::mempool(),
        ClusterConfig::occamy(),
    ];
    // One batch: (AXPY, GEMM) per cluster, workloads scaled to cluster
    // size so every PE has comparable work (AXPY's registry default is
    // already 64/16 bank sweeps; GEMM's edge tracks sqrt(num_pes)).
    let mut jobs = Vec::new();
    for cfg in &configs {
        let gemm_edge = scale
            .pick(8, 4)
            .max((cfg.num_pes() as f64).sqrt() as usize / 4 * 4)
            .max(8)
            * 4;
        jobs.push(Job::new(cfg.clone(), Box::new(Axpy::default())));
        jobs.push(Job::new(
            cfg.clone(),
            Box::new(Gemm::with(GemmParams { m: gemm_edge, n: gemm_edge, k: gemm_edge })),
        ));
    }
    let results = s.run_batch(&jobs);
    for (cfg, pair) in configs.iter().zip(results.chunks(2)) {
        let sa = &pair[0].as_ref().expect("table6 axpy run").stats;
        let sg = &pair[1].as_ref().expect("table6 gemm run").stats;
        let l1 = cfg.l1_bytes();
        let tile = scaling::max_tile_edge(l1);
        t.row(vec![
            cfg.name.clone(),
            f2(l1 as f64 / (1024.0 * 1024.0)),
            f2(scaling::axpy_bytes_per_flop()),
            f2(sa.ipc()),
            f3(scaling::gemm_bytes_per_flop(tile)),
            f2(sg.ipc()),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Sec. 2 — scale-up balance analysis
// ------------------------------------------------------------------

pub fn scaling_analysis() -> Table {
    let mut t = Table::new(
        "Sec. 2 — Kung balance under cluster scale-up (Eqs. 1-2)",
        &["Scale S", "W (KiWords)", "AI (op/word)", "Transfer cyc", "Compute cyc", "Balanced"],
    );
    let base = scaling::BalanceInput {
        l: 500.0,
        w: 3.0 * 256.0 * 256.0,
        bw: 64.0,
        ai: scaling::matmul_ai(3.0 * 256.0 * 256.0),
        n_pes: 64.0,
        u: 0.8,
    };
    for s in [1.0, 2.0, 4.0, 8.0, 16.0] {
        let b = scaling::scale(&base, s);
        t.row(vec![
            f1(s),
            f1(b.w / 1024.0),
            f1(b.ai),
            f1(scaling::transfer_cycles(&b)),
            f1(scaling::compute_cycles(&b)),
            if scaling::is_balanced(&b) { "yes" } else { "no" }.into(),
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Headline numbers
// ------------------------------------------------------------------

pub fn headline(sess: &Session) -> Table {
    let scale = sess.current_scale();
    let mut t = Table::new("Headline — TeraPool reproduction vs paper", &["Metric", "Paper", "Measured"]);
    let c11 = ClusterConfig::terapool(11);
    t.row(vec![
        "Peak SP TFLOP/s @ 910 MHz".into(),
        "1.89".into(),
        f2(c11.peak_gflops_f32() / 1000.0 * 2048.0 / 2048.0),
    ]);
    t.row(vec![
        "Peak HP TFLOP/s".into(),
        "~3.7".into(),
        f2(c11.peak_gflops_f16() / 1000.0),
    ]);
    // GEMM/AXPY sustained, one batch on the energy-optimal config.
    let cfg = ClusterConfig::terapool(9);
    let em = energy::EnergyModel::for_cluster(&cfg);
    let results = sess.run_batch(&jobs_for(&cfg, &["gemm", "axpy"]));
    let s = &results[0].as_ref().expect("headline gemm run").stats;
    t.row(vec!["GEMM IPC".into(), "0.70".into(), f2(s.ipc())]);
    t.row(vec![
        "GEMM sustained GFLOP/s".into(),
        "~740 (0.74 TFLOP/s)".into(),
        f1(s.gflops()),
    ]);
    t.row(vec![
        "GEMM GFLOP/s/W (f32)".into(),
        "100-200 (up to 200 w/ f16)".into(),
        f1(em.gflops_per_watt(s)),
    ]);
    let sa = &results[1].as_ref().expect("headline axpy run").stats;
    t.row(vec!["AXPY IPC".into(), "0.85".into(), f2(sa.ipc())]);
    // HBML.
    let (gbps, util) = hbml_sweep_point(900.0, DdrRate::G3_6, scale.pick(896 * 1024, 64 * 1024));
    t.row(vec!["HBML @900 MHz GB/s".into(), "896 (97%)".into(), format!("{} ({})", f1(gbps), pct(util))]);
    t
}

// ------------------------------------------------------------------
// Scale-out — scale-up vs scale-out at equal total PE count
// ------------------------------------------------------------------

/// One big TeraPool cluster vs 2/4 smaller clusters at the same total
/// PE count ([`Topology::split`]), every variant through the system
/// engine so the staging/merge overhead accounting is uniform: measured
/// total cycles, the compute/overhead split, inter-cluster link
/// traffic, shared-bus traffic, and aggregate GFLOP/s. Every variant
/// runs twice — overlap off (`slices = 1`, the phase-serial timeline)
/// and overlap on (`slices = 4`, the pipelined engine) — and the table
/// quantifies how much staging+merge bus time the pipeline hides
/// (`Hidden %`, target ≥60% on the 4-way GEMM). Variants whose bands
/// cannot cover 4 slices report the overlap columns as `-`.
pub fn fig_scaleout(s: &Session) -> Table {
    let base = ClusterConfig::terapool(9);
    let mut t = Table::new(
        "Scale-out — one big cluster vs 2/4 smaller at equal total PE count",
        &[
            "System", "Clusters", "PEs", "Cycles", "Compute", "Overhead %",
            "Cycles S=4", "Hidden %", "Link words", "Bus words", "GFLOP/s",
        ],
    );
    for parts in [1usize, 2, 4] {
        let topo = Topology::split(&base, parts).expect("terapool splits 1/2/4-way");
        for kind in ["gemm", "fft"] {
            let r = s.system_sliced(&topo, kind, 1).expect("scale-out system run");
            let info = r.system.as_ref().expect("system runs carry the system section");
            let st = &r.stats;
            let overhead = (info.stage_cycles + info.merge_cycles) as f64 / st.cycles as f64;
            // The overlap-on twin: same problem, 4 slices per cluster.
            // An Unsupported refusal (band too small to slice) leaves
            // the overlap columns empty rather than failing the figure.
            let (c4, hid) = match s.system_sliced(&topo, kind, 4) {
                Ok(r4) => {
                    let i4 = r4.system.as_ref().expect("system runs carry the system section");
                    let frac = if i4.bus_busy_cycles > 0 {
                        i4.hidden_bus_cycles as f64 / i4.bus_busy_cycles as f64
                    } else {
                        0.0
                    };
                    (int(r4.stats.cycles), pct(frac))
                }
                Err(_) => ("-".into(), "-".into()),
            };
            t.row(vec![
                r.workload.clone(),
                int(info.clusters.len() as u64),
                int(st.num_pes as u64),
                int(st.cycles),
                int(info.compute_cycles),
                pct(overhead),
                c4,
                hid,
                int(info.link_words),
                int(info.bus_words),
                f1(st.gflops()),
            ]);
        }
    }
    t
}

// ------------------------------------------------------------------
// Design-space sweep — estimate-guided Pareto refinement
// ------------------------------------------------------------------

/// The `examples/terapool.sweep` grid, built programmatically (the
/// coordinator cannot assume a checkout layout): the three characterized
/// operating points × banking factor {paper, halved} × burst {off, on}
/// × {axpy, dotp} = 24 points, explored with the estimator at the
/// session's scale, Pareto-refined over (estimated cycles, area GE),
/// frontier re-measured cycle-accurately and held to the 10% drift
/// bound. Runs unchecked (no checkpoint file) — the resumable path is
/// the `sweep-space` CLI entry.
pub fn fig_sweep(s: &Session) -> crate::errors::Result<Table> {
    let spec = crate::sweep::SweepSpec {
        name: "fig-sweep".into(),
        scale: s.current_scale(),
        rtol: crate::sweep::DEFAULT_RTOL,
        presets: vec!["terapool7".into(), "terapool9".into(), "terapool11".into()],
        groups: vec![None],
        banking: vec![None, Some(2)],
        burst: vec![false, true],
        freq: vec![None],
        workloads: vec!["axpy".into(), "dotp".into()],
    };
    spec.validate()?;
    let report = crate::sweep::run_sweep(&spec, s.host_threads(), None, |_| Ok(()))?;
    Ok(report.table())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_and_fig11_and_fig12_and_fig13_render() {
        for t in [table3(), fig11(), fig12(), fig13(), table5(), scaling_analysis()] {
            let s = t.render();
            assert!(s.len() > 100, "{s}");
        }
    }

    #[test]
    fn fig9_fast_shows_frequency_bound_vs_hbm_bound() {
        let words = 128 * 1024u32;
        let (slow, _) = hbml_sweep_point(500.0, DdrRate::G3_6, words);
        let (fast, util_fast) = hbml_sweep_point(900.0, DdrRate::G3_6, words);
        assert!(fast > slow, "900 MHz must beat 500 MHz: {fast} vs {slow}");
        assert!(util_fast > 0.85, "near-peak at 900 MHz: {util_fast}");
        // At 500 MHz the cluster side (16×64 B/cyc) caps well below peak.
        assert!(slow < 0.75 * DdrRate::G3_6.peak_gbps_total());
    }
}
