//! Tree-like hierarchical AXI4 interconnect model (Sec. 5.1, Fig. 7).
//!
//! The HBML's system-level fabric: each Tile shares one 512-bit AXI4
//! master among its cores; within a SubGroup the 8 Tile masters arbitrate
//! in a tree to a single 512-bit SubGroup master; the 16 SubGroup masters
//! reach the DMA backends / L2 / CSRs through address demultiplexers.
//!
//! For the HBML experiments the traffic sources are the 16 DMA backends
//! (one per SubGroup, Sec. 5.4), so the model exposes per-port rate
//! limiting (one beat per cycle per 512-bit port → 64 B/cycle) plus the
//! tree traversal latency. Its unit is the *transaction slot*: `try_issue`
//! answers whether a port can accept another burst this cycle.

/// One 512-bit AXI4 master port with bounded outstanding transactions.
#[derive(Debug, Clone)]
pub struct AxiPort {
    /// Port width in bytes per cycle (512 bit = 64 B).
    pub bytes_per_cycle: u64,
    /// Max outstanding bursts (AXI ID space / write-response depth).
    pub max_outstanding: u32,
    outstanding: u32,
    /// Cycle until which the address/data channel is busy issuing the
    /// current burst's beats.
    busy_until: u64,
    /// Stats.
    pub bursts: u64,
    pub bytes: u64,
    pub stall_cycles: u64,
}

impl AxiPort {
    pub fn new(bytes_per_cycle: u64, max_outstanding: u32) -> Self {
        AxiPort {
            bytes_per_cycle,
            max_outstanding,
            outstanding: 0,
            busy_until: 0,
            bursts: 0,
            bytes: 0,
            stall_cycles: 0,
        }
    }

    /// Beats needed to move `bytes` through this port.
    pub fn beats(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.bytes_per_cycle)
    }

    /// Can a new burst be issued at `now`?
    pub fn can_issue(&self, now: u64) -> bool {
        self.outstanding < self.max_outstanding && now >= self.busy_until
    }

    /// Issue a burst of `bytes`; returns the cycle its beats finish
    /// crossing the port (data-channel occupancy).
    pub fn issue(&mut self, now: u64, bytes: u64) -> u64 {
        debug_assert!(self.can_issue(now));
        self.outstanding += 1;
        self.busy_until = now + self.beats(bytes);
        self.bursts += 1;
        self.bytes += bytes;
        self.busy_until
    }

    pub fn note_stall(&mut self) {
        self.stall_cycles += 1;
    }

    /// A burst's response (B/R channel) returned.
    pub fn retire(&mut self) {
        debug_assert!(self.outstanding > 0);
        self.outstanding -= 1;
    }

    pub fn outstanding(&self) -> u32 {
        self.outstanding
    }
}

/// Fixed traversal latencies through the AXI tree (cycles).
#[derive(Debug, Clone, Copy)]
pub struct AxiTreeLatency {
    /// Tile master → SubGroup master (tree arbitration stage).
    pub tile_to_subgroup: u32,
    /// SubGroup master → system demux → memory controller.
    pub subgroup_to_mc: u32,
}

impl Default for AxiTreeLatency {
    fn default() -> Self {
        AxiTreeLatency { tile_to_subgroup: 2, subgroup_to_mc: 4 }
    }
}

impl AxiTreeLatency {
    /// End-to-end request latency from a SubGroup DMA backend to the
    /// memory controller.
    pub fn backend_to_mc(&self) -> u32 {
        self.subgroup_to_mc
    }
    /// From a core's Tile port (I$ refills, CSR accesses).
    pub fn tile_to_mc(&self) -> u32 {
        self.tile_to_subgroup + self.subgroup_to_mc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_moves_64_bytes_per_cycle() {
        let mut p = AxiPort::new(64, 8);
        assert_eq!(p.beats(1024), 16);
        let done = p.issue(0, 1024);
        assert_eq!(done, 16);
        assert!(!p.can_issue(5), "data channel busy");
        assert!(p.can_issue(16));
    }

    #[test]
    fn outstanding_limit_blocks() {
        let mut p = AxiPort::new(64, 2);
        let t1 = p.issue(0, 64);
        let t2 = p.issue(t1, 64);
        assert!(!p.can_issue(t2), "2 outstanding, limit 2");
        p.retire();
        assert!(p.can_issue(t2));
    }

    #[test]
    fn stats_accumulate() {
        let mut p = AxiPort::new(64, 8);
        let t = p.issue(0, 1024);
        p.issue(t, 1024);
        assert_eq!(p.bursts, 2);
        assert_eq!(p.bytes, 2048);
    }

    #[test]
    fn tree_latency_compose() {
        let l = AxiTreeLatency::default();
        assert!(l.tile_to_mc() > l.backend_to_mc());
    }
}
