//! Small statistics helpers shared by the simulator and the models.

/// Online mean/min/max accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn add(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }
}

/// Fixed-bin latency histogram (bin per cycle, saturating last bin).
#[derive(Debug, Clone)]
pub struct Histogram {
    bins: Vec<u64>,
}

impl Histogram {
    pub fn new(max: usize) -> Self {
        Histogram { bins: vec![0; max + 1] }
    }
    pub fn add(&mut self, v: usize) {
        let i = v.min(self.bins.len() - 1);
        self.bins[i] += 1;
    }
    pub fn count(&self) -> u64 {
        self.bins.iter().sum()
    }
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let s: u64 = self.bins.iter().enumerate().map(|(i, &c)| i as u64 * c).sum();
        s as f64 / n as f64
    }
    pub fn percentile(&self, p: f64) -> usize {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = (p * n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                return i;
            }
        }
        self.bins.len() - 1
    }
    pub fn bins(&self) -> &[u64] {
        &self.bins
    }
}

/// Tiny id → count accumulator over a sorted vec, for per-cycle event
/// tallies with few distinct ids (e.g. barrier-arrival counts in the
/// sharded engine's per-worker cycle summaries). Integer adds merged in
/// any order produce the same totals, so [`IdCounts::absorb`] is safe at
/// every level of a reduction tree.
#[derive(Debug, Clone, Default)]
pub struct IdCounts {
    entries: Vec<(u16, u32)>,
}

impl IdCounts {
    pub fn add(&mut self, id: u16, n: u32) {
        match self.entries.binary_search_by_key(&id, |e| e.0) {
            Ok(i) => self.entries[i].1 += n,
            Err(i) => self.entries.insert(i, (id, n)),
        }
    }
    /// Fold another accumulator into this one (order-insensitive).
    pub fn absorb(&mut self, other: &IdCounts) {
        for &(id, n) in &other.entries {
            self.add(id, n);
        }
    }
    pub fn clear(&mut self) {
        self.entries.clear();
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    /// (id, count) pairs in ascending id order.
    pub fn iter(&self) -> impl Iterator<Item = (u16, u32)> + '_ {
        self.entries.iter().copied()
    }
}

/// Binomial(n, p) probability mass function P(X = k) — the arbitration
/// contention primitive of the paper's AMAT model (Sec. 3.1).
pub fn binomial_pmf(n: usize, p: f64, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    // Multiplicative evaluation, numerically stable for the n ≤ 4096
    // range used here.
    let mut c = 1.0f64;
    for i in 0..k {
        c *= (n - i) as f64 / (i + 1) as f64;
    }
    c * p.powi(k as i32) * (1.0 - p).powi((n - k) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_tracks_extremes() {
        let mut s = Summary::default();
        for x in [2.0, 8.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 8.0);
        assert!((s.mean() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_mean_and_percentile() {
        let mut h = Histogram::new(16);
        for v in [1, 1, 3, 5] {
            h.add(v);
        }
        assert!((h.mean() - 2.5).abs() < 1e-12);
        assert_eq!(h.percentile(0.5), 1);
        assert_eq!(h.percentile(1.0), 5);
    }

    #[test]
    fn id_counts_accumulate_and_merge_order_insensitively() {
        let mut a = IdCounts::default();
        a.add(3, 1);
        a.add(1, 2);
        a.add(3, 1);
        let mut b = IdCounts::default();
        b.add(1, 5);
        b.add(7, 1);
        let mut ab = a.clone();
        ab.absorb(&b);
        let mut ba = b.clone();
        ba.absorb(&a);
        let got: Vec<_> = ab.iter().collect();
        assert_eq!(got, vec![(1, 7), (3, 2), (7, 1)]);
        assert_eq!(got, ba.iter().collect::<Vec<_>>(), "merge order must not matter");
        ab.clear();
        assert!(ab.is_empty());
    }

    #[test]
    fn binomial_sums_to_one() {
        for &(n, p) in &[(8usize, 0.3), (32, 0.9), (1024, 0.01)] {
            let s: f64 = (0..=n).map(|k| binomial_pmf(n, p, k)).sum();
            assert!((s - 1.0).abs() < 1e-9, "n={n} p={p} sum={s}");
        }
    }

    #[test]
    fn binomial_matches_hand_values() {
        // Binomial(2, 0.5): [0.25, 0.5, 0.25]
        assert!((binomial_pmf(2, 0.5, 0) - 0.25).abs() < 1e-12);
        assert!((binomial_pmf(2, 0.5, 1) - 0.5).abs() < 1e-12);
        assert!((binomial_pmf(2, 0.5, 2) - 0.25).abs() < 1e-12);
    }
}
