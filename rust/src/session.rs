//! The `Session` API — the **single run path** of the simulator.
//!
//! A [`Session`] is built once from a [`ClusterConfig`] plus knobs
//! (`Session::new(cfg).scale(..).threads(..).dma(..)`) and then runs
//! [`Workload`]s: one at a time ([`Session::run`]), by registry name
//! ([`Session::run_named`]), or as a **batch** of independent
//! workload×config jobs ([`Session::run_batch`]) fanned out across host
//! threads. Every run produces a structured [`RunReport`] (config
//! fingerprint, `RunStats`, per-class interconnect numbers, validation
//! verdict), and the session accumulates all of them so the CLI's
//! `--json` flag can dump one document per invocation.
//!
//! ## Thread budget
//!
//! `threads(n)` is the session's host-thread budget, spent where it
//! helps most:
//!
//! * a **single** run gives all `n` threads to the deterministic
//!   tile-parallel engine (PR 3) — same numbers, less wall clock;
//! * a **batch** schedules whole jobs across the `n` threads, each
//!   job simulated on the serial reference engine — job-level
//!   parallelism dominates cycle-level parallelism when there is more
//!   than one job.
//!
//! Either way the simulated results are bit-identical to a sequential
//! one-thread run: the engines are deterministic, jobs are independent
//! (the HBM functional image is thread-local and re-staged per job), and
//! batch results are returned in job order. `rust/tests/session_api.rs`
//! enforces this.
//!
//! ## Timeouts are typed
//!
//! A run that hits `max_cycles` before the cluster is done returns an
//! [`ErrorKind::MaxCyclesExceeded`](crate::errors::ErrorKind) error —
//! the output image is never read, reported, or compared.

use std::sync::Mutex;

use crate::config::{ClusterConfig, Scale};
use crate::errors::Result;
use crate::kernels::{self, Workload};
use crate::report::{EstimateInfo, RunReport, Verdict};
use crate::topology::Topology;

/// A config delta applied to a copy of a [`Job`]'s base config at run
/// time.
type ConfigTweak = Box<dyn Fn(&mut ClusterConfig) + Send + Sync>;

/// One batch entry: a workload, the base config to run it on, and an
/// optional chain of config *deltas* ([`Job::tweak`]).
pub struct Job {
    pub cfg: ClusterConfig,
    pub workload: Box<dyn Workload>,
    tweaks: Vec<ConfigTweak>,
}

impl Job {
    pub fn new(cfg: ClusterConfig, workload: Box<dyn Workload>) -> Self {
        Job { cfg, workload, tweaks: Vec::new() }
    }

    /// Register a config delta applied (in registration order) to a copy
    /// of the base config when the job runs. Sweeps over single knobs —
    /// `tx_table_entries`, the sequential-region size, NUMA latencies —
    /// share one base config instead of clone-and-edit at every call
    /// site:
    ///
    /// ```ignore
    /// let jobs: Vec<Job> = [2, 4, 8, 16]
    ///     .map(|tx| Job::new(base.clone(), kernels::lookup("axpy")?)
    ///         .tweak(move |c| c.tx_table_entries = tx))
    ///     .into();
    /// ```
    ///
    /// The `RunReport` fingerprint is computed from the tweaked config,
    /// so swept reports stay distinguishable.
    pub fn tweak(mut self, f: impl Fn(&mut ClusterConfig) + Send + Sync + 'static) -> Self {
        self.tweaks.push(Box::new(f));
        self
    }

    /// The exact config this job will simulate: the base config with
    /// every registered delta applied.
    pub fn effective_cfg(&self) -> ClusterConfig {
        let mut cfg = self.cfg.clone();
        for t in &self.tweaks {
            t(&mut cfg);
        }
        cfg
    }
}

/// See the module docs. Construct with [`Session::new`], configure with
/// the chained builder methods, then `run` / `run_named` / `run_batch`.
pub struct Session {
    cfg: ClusterConfig,
    scale: Scale,
    threads: usize,
    max_cycles: u64,
    force_dma: bool,
    checking: bool,
    fast_forward: bool,
    estimating: bool,
    slices: usize,
    reports: Mutex<Vec<RunReport>>,
}

impl Session {
    /// A session over `cfg` with the defaults harness code wants:
    /// full scale, one host thread, 2 G max cycles, no forced HBML, no
    /// reference checking, idle-cycle fast-forward on.
    pub fn new(cfg: ClusterConfig) -> Self {
        Session {
            cfg,
            scale: Scale::Full,
            threads: 1,
            max_cycles: 2_000_000_000,
            force_dma: false,
            checking: false,
            fast_forward: true,
            estimating: false,
            slices: 1,
            reports: Mutex::new(Vec::new()),
        }
    }

    /// Problem-size scale workloads resolve their defaults from.
    pub fn scale(mut self, s: Scale) -> Self {
        self.scale = s;
        self
    }

    /// Host-thread budget (see the module docs; clamped to ≥ 1).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Attach the HBML (DMA + HBM2E) subsystem even for workloads whose
    /// staging doesn't carry a `DmaPlan`.
    pub fn dma(mut self, on: bool) -> Self {
        self.force_dma = on;
        self
    }

    /// Run each workload's host-reference check and record the verdict.
    pub fn check(mut self, on: bool) -> Self {
        self.checking = on;
        self
    }

    /// Simulated-cycle budget per run.
    pub fn max_cycles(mut self, c: u64) -> Self {
        self.max_cycles = c.max(1);
        self
    }

    /// Engine idle-cycle fast-forward (on by default; the results are
    /// bit-identical either way — `rust/tests/parallel_equiv.rs`).
    /// `--no-skip` exists so the differential suite and the simspeed
    /// bench can measure the unskipped engine.
    pub fn fast_forward(mut self, on: bool) -> Self {
        self.fast_forward = on;
        self
    }

    /// Route runs through the calibrated analytic fast path
    /// ([`crate::estimate`]) instead of the cycle-accurate engine at the
    /// target scale: exact instruction/traffic census, model-predicted
    /// timing, ratio-calibrated against one cycle-accurate run at
    /// [`Scale::Fast`]. Reports carry [`EstimateInfo`] provenance.
    pub fn estimating(mut self, on: bool) -> Self {
        self.estimating = on;
        self
    }

    /// Band slices per cluster for system runs (clamped to ≥ 1). `1`
    /// keeps the phase-serial timeline; `> 1` pipelines shared-bus
    /// staging and merge behind cluster compute
    /// ([`crate::system::run_system_sliced`]). The merged memory image
    /// is byte-identical at any value.
    pub fn slices(mut self, s: usize) -> Self {
        self.slices = s.max(1);
        self
    }

    pub fn current_scale(&self) -> Scale {
        self.scale
    }

    pub fn host_threads(&self) -> usize {
        self.threads
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Run one workload on the session config, with the full thread
    /// budget on the tile-parallel engine.
    pub fn run(&self, w: &dyn Workload) -> Result<RunReport> {
        let cfg = self.cfg.clone();
        self.run_on(&cfg, w)
    }

    /// Run one workload on an explicit config (ablations sweep config
    /// knobs without rebuilding the session).
    pub fn run_on(&self, cfg: &ClusterConfig, w: &dyn Workload) -> Result<RunReport> {
        let r = if self.estimating {
            self.estimate_inner(cfg, w)
        } else {
            self.run_inner(cfg, w, self.threads)
        };
        if let Ok(rep) = &r {
            self.reports.lock().unwrap().push(rep.clone());
        }
        r
    }

    /// Run a workload by registry name — unknown names are a typed
    /// `UnknownWorkload` error, not a panic.
    pub fn run_named(&self, name: &str) -> Result<RunReport> {
        self.run(&*kernels::lookup(name)?)
    }

    /// Run a batch of independent jobs across the host-thread budget.
    /// Results come back in job order and are bit-identical to running
    /// the same jobs sequentially (each job simulates on the serial
    /// reference engine; see the module docs).
    pub fn run_batch(&self, jobs: &[Job]) -> Vec<Result<RunReport>> {
        let results = crate::parallel::scatter(jobs.len(), self.threads, |i| {
            let cfg = jobs[i].effective_cfg();
            if self.estimating {
                self.estimate_inner(&cfg, &*jobs[i].workload)
            } else {
                self.run_inner(&cfg, &*jobs[i].workload, 1)
            }
        });
        let mut acc = self.reports.lock().unwrap();
        for r in results.iter().flatten() {
            acc.push(r.clone());
        }
        results
    }

    /// Everything this session has run so far, in completion order
    /// (single runs) / job order (batches).
    pub fn reports(&self) -> Vec<RunReport> {
        self.reports.lock().unwrap().clone()
    }

    /// Drain the accumulated reports (the CLI aggregates multiple
    /// sessions into one `--json` document).
    pub fn take_reports(&self) -> Vec<RunReport> {
        std::mem::take(&mut *self.reports.lock().unwrap())
    }

    /// The run path every public entry above funnels into: build, stage,
    /// simulate, (optionally) check, report.
    fn run_inner(
        &self,
        cfg: &ClusterConfig,
        w: &dyn Workload,
        engine_threads: usize,
    ) -> Result<RunReport> {
        let staged = w.build(cfg, self.scale);
        let (mut cl, io) = staged.into_cluster(cfg.clone());
        if self.force_dma && cl.dma.is_none() {
            cl = cl.with_dma();
        }
        cl.fast_forward = self.fast_forward;
        let stats = cl
            .try_run_threads(self.max_cycles, engine_threads)
            .map_err(|e| e.prefixed(&io.name))?;
        let verdict = if self.checking {
            w.check(cfg, self.scale, &cl, &io)
        } else {
            Verdict::NotChecked
        };
        Ok(RunReport {
            workload: io.name.clone(),
            kind: w.kind().to_string(),
            config: cfg.name.clone(),
            fingerprint: cfg.fingerprint(),
            scale: self.scale.tag().to_string(),
            engine_threads,
            max_cycles: self.max_cycles,
            stats,
            dma_bytes: cl.dma.as_ref().map(|d| d.total_bytes()),
            verdict,
            estimate: None,
            system: None,
        })
    }

    /// The analytic fast path (see [`crate::estimate`]): census + model
    /// the target-scale build, calibrate against one cycle-accurate run
    /// of the same workload at [`Scale::Fast`], and report the blended
    /// stats with provenance. No cluster is ever built at the target
    /// scale — for a TeraPool-sized config this is the difference
    /// between seconds and hours.
    fn estimate_inner(&self, cfg: &ClusterConfig, w: &dyn Workload) -> Result<RunReport> {
        let target_staged = w.build(cfg, self.scale);
        let name = target_staged.name.clone();
        let has_dma = target_staged.dma.is_some() || self.force_dma;
        let target_model = crate::estimate::model_run(cfg, &target_staged);
        drop(target_staged);

        // Calibration anchor: the same workload at fast scale, measured
        // cycle-accurately, plus the model of that exact build.
        let fast_staged = w.build(cfg, Scale::Fast);
        let fast_model = crate::estimate::model_run(cfg, &fast_staged);
        let (mut cl, io) = fast_staged.into_cluster(cfg.clone());
        if self.force_dma && cl.dma.is_none() {
            cl = cl.with_dma();
        }
        cl.fast_forward = self.fast_forward;
        let fast_actual = cl
            .try_run_threads(self.max_cycles, self.threads)
            .map_err(|e| e.prefixed(&io.name))?;

        let stats =
            crate::estimate::calibrated_stats(cfg, &target_model, &fast_actual, &fast_model);
        let residual = (fast_model.cycles - fast_actual.cycles as f64).abs()
            / (fast_actual.cycles as f64).max(1.0);
        Ok(RunReport {
            workload: name,
            kind: w.kind().to_string(),
            config: cfg.name.clone(),
            fingerprint: cfg.fingerprint(),
            scale: self.scale.tag().to_string(),
            engine_threads: self.threads,
            max_cycles: self.max_cycles,
            stats,
            dma_bytes: if has_dma { Some(target_model.census.dma_bytes) } else { None },
            verdict: Verdict::NotChecked,
            estimate: Some(EstimateInfo {
                calibration_scale: Scale::Fast.tag().to_string(),
                calibration_cycles: fast_actual.cycles,
                model_residual: residual,
                stated_rtol: 0.10,
            }),
            system: None,
        })
    }

    /// Run one chunked workload kind (`"gemm"` or `"fft"`) data-parallel
    /// across the clusters of `topo`: stage every cluster's band, pay the
    /// shared-bus staging + inter-cluster halo broadcasts, run all
    /// clusters to completion (serially in lockstep, or cluster-parallel
    /// across this session's host threads — bit-identical by
    /// construction, pinned by `tests/system_equiv.rs`), then merge each
    /// band into the off-chip memory node over the arbitrated bus. The
    /// report's `system` section carries the per-cluster, per-link and
    /// bus breakdowns.
    ///
    /// The analytic estimate census is defined over a single cluster's
    /// interconnect; a multi-cluster run is refused with a typed
    /// [`ErrorKind::Unsupported`](crate::errors::ErrorKind) instead of
    /// silently estimating cluster 0.
    pub fn system(&self, topo: &Topology, kind: &str) -> Result<RunReport> {
        self.system_sliced(topo, kind, self.slices)
    }

    /// [`Session::system`] with an explicit slice count, overriding the
    /// session's [`Session::slices`] knob — what `fig-scaleout` uses to
    /// run the overlap-on/off pair without rebuilding the session.
    pub fn system_sliced(&self, topo: &Topology, kind: &str, slices: usize) -> Result<RunReport> {
        if self.estimating {
            return Err(crate::errors::Error::unsupported(format!(
                "the analytic estimate census does not extend to multi-cluster system \
                 runs ({} clusters in {:?}); re-run without --estimate",
                topo.clusters.len(),
                topo.name
            )));
        }
        let kernel = crate::system::resolve_kernel(kind, self.scale)?;
        let run = crate::system::run_system_sliced(
            topo,
            &kernel,
            self.threads,
            self.max_cycles,
            self.fast_forward,
            self.checking,
            slices.max(1),
        )
        .map_err(|e| e.prefixed(&topo.name))?;
        let report = RunReport {
            workload: run.name.clone(),
            kind: kind.to_string(),
            config: topo.name.clone(),
            fingerprint: topo.fingerprint(),
            scale: self.scale.tag().to_string(),
            engine_threads: self.threads,
            max_cycles: self.max_cycles,
            stats: run.stats.clone(),
            // The shared-bus traffic is the system's main-memory
            // movement — the scale-out analogue of the HBML byte count.
            dma_bytes: Some(run.info.bus_words * 4),
            verdict: run.verdict.clone(),
            estimate: None,
            system: Some(run.info.clone()),
        };
        self.reports.lock().unwrap().push(report.clone());
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errors::ErrorKind;
    use crate::kernels::axpy::{Axpy, AxpyParams};

    #[test]
    fn single_run_produces_a_checked_report() {
        let cfg = ClusterConfig::tiny();
        let s = Session::new(cfg.clone()).scale(Scale::Fast).check(true);
        let r = s
            .run(&Axpy::with(AxpyParams { n: cfg.num_banks() * 4, alpha: 2.0 }))
            .unwrap();
        assert_eq!(r.kind, "axpy");
        assert_eq!(r.config, cfg.name);
        assert_eq!(r.fingerprint, cfg.fingerprint());
        assert!(matches!(r.verdict, Verdict::Passed { .. }), "{:?}", r.verdict);
        assert!(r.stats.cycles > 0);
        assert_eq!(s.reports().len(), 1);
    }

    #[test]
    fn timeout_is_a_typed_error_and_unreported() {
        let cfg = ClusterConfig::tiny();
        let s = Session::new(cfg).scale(Scale::Fast).max_cycles(10);
        let e = s.run_named("axpy").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::MaxCyclesExceeded);
        assert!(s.reports().is_empty(), "failed runs must not be reported");
    }

    #[test]
    fn estimate_reports_provenance_and_exact_census() {
        let cfg = ClusterConfig::tiny();
        // Target scale == calibration scale: the ratio calibration
        // collapses and the estimate must equal the measurement.
        let est = Session::new(cfg.clone()).scale(Scale::Fast).estimating(true);
        let exact = Session::new(cfg).scale(Scale::Fast);
        let re = est.run_named("axpy").unwrap();
        let rx = exact.run_named("axpy").unwrap();
        assert_eq!(re.stats, rx.stats);
        let info = re.estimate.as_ref().expect("estimate runs carry provenance");
        assert_eq!(info.calibration_scale, "fast");
        assert_eq!(info.calibration_cycles, rx.stats.cycles);
        assert!(info.model_residual >= 0.0);
        assert_eq!(info.stated_rtol, 0.10);
        assert!(rx.estimate.is_none(), "cycle-accurate runs carry none");
    }

    #[test]
    fn system_runs_are_refused_on_the_estimate_path() {
        let cfg = ClusterConfig::tiny();
        let topo = Topology::split(&cfg, 1).unwrap();
        let s = Session::new(cfg).scale(Scale::Fast).estimating(true);
        let e = s.system(&topo, "gemm").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Unsupported);
        assert!(s.reports().is_empty());
    }

    #[test]
    fn unknown_name_is_typed() {
        let s = Session::new(ClusterConfig::tiny());
        assert_eq!(
            s.run_named("nope").unwrap_err().kind(),
            ErrorKind::UnknownWorkload
        );
    }
}
