//! TeraPool reproduction CLI — regenerate any paper table/figure.
//!
//! ```text
//! terapool table4                 # hierarchical interconnect analysis
//! terapool fig14a --fast          # kernel IPC/stalls at reduced scale
//! terapool fig14a --threads 8     # same numbers, batched across 8 host threads
//! terapool all --fast             # everything (reduced scale)
//! terapool validate               # kernels vs references + AOT goldens
//! terapool validate --json r.json # ... and dump structured RunReports
//! terapool --list                 # registered workloads + experiments
//! ```
//!
//! Argument parsing is hand-rolled (no clap in the offline build), and
//! error plumbing uses the crate's own [`terapool::errors`] (no anyhow).
//!
//! Every cluster-simulator experiment goes through one [`Session`] — the
//! single run path. `--threads N` sets the session's host-thread budget
//! (kernel batches fan out across jobs; single runs use the
//! deterministic tile-parallel engine). Simulated results are
//! bit-identical at any thread count; only host wall clock changes.
//! `--json <path>` writes every `RunReport` the invocation produced.

use terapool::config::ClusterConfig;
use terapool::coordinator::{self, Scale};
use terapool::errors::Result;
use terapool::kernels::{self, fft, gemm, spmmadd};
use terapool::report::{reports_to_json, RunReport, Verdict};
use terapool::runtime::{assert_allclose, Runtime};
use terapool::session::{Job, Session};
use terapool::{bail, ensure};

const USAGE: &str = "usage: terapool <experiment> [--fast] [--threads N] [--json PATH]
       terapool sweep [--fast] [--estimate] [--json PATH]
       terapool sweep-space [--spec PATH] [--resume PATH] [--fast] [--json PATH]
       terapool system [--topology PATH] [--slices N] [--fast] [--threads N]
       terapool --list
experiments:
  table3 table4 fig8 fig9 fig11 fig12 fig13 fig14a fig14b
  table5 table6 scaling headline fig-scaleout fig-sweep system all validate
  sweep sweep-space ablate-txtable ablate-addrmap ablate-spill
options:
  --fast        reduced problem sizes (smoke runs, CI)
  --threads N   host-thread budget for the Session run path: kernel
                batches fan out across jobs, single runs use the
                tile-parallel engine (default 1; simulated results are
                identical at any N)
  --json PATH   write every RunReport of this invocation (config
                fingerprint, stats, per-class interconnect numbers,
                validation verdict) as terapool-runreport-v1 JSON
  --no-skip     disable engine idle-cycle fast-forward (results are
                bit-identical either way; this exists for differential
                and speedup measurements)
  --estimate    route runs through the calibrated analytic fast path
                (Session::estimating): exact census, model timing,
                one fast-scale cycle-accurate calibration run per job.
                Compare vs a cycle-accurate sweep with
                tools/report_diff.py --rtol 0.10
  --burst       enable TCDM burst access (ClusterConfig::burst): kernels
                that support it issue multi-word loads/stores moving up
                to MAX_BURST_WORDS consecutive-bank words per port grant
  --spec PATH   sweep grid for `terapool sweep-space` (declarative
                preset x groups/banking x burst x workload axes; default
                examples/terapool.sweep). Every point is explored with
                the calibrated estimator, only the Pareto frontier over
                (estimated cycles, area GE) re-runs cycle-accurately,
                and each frontier point's estimate is held to the spec
                rtol against its measurement
  --resume PATH checkpoint file for `terapool sweep-space`: read if it
                exists (completed points are reused, never re-estimated),
                rewritten after every batch — an interrupted sweep
                resumed this way renders a byte-identical SweepReport
  --topology P  system topology file for `terapool system` (declarative
                clusters + inter-cluster links + memory node; default
                examples/quad.topo). The multi-cluster run chunks GEMM
                and FFT data-parallel across the clusters, checks the
                merged memory image against the host references, and
                reports per-cluster / per-link / bus breakdowns
  --slices N    band slices per cluster for `terapool system` (default 1
                = the phase-serial timeline). N > 1 pipelines shared-bus
                staging and merge behind cluster compute, double-buffering
                slice k+1 while slice k runs; the merged memory image is
                byte-identical at any N, only the timeline changes
  --list        enumerate registered workloads and experiments";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let scale = if fast { Scale::Fast } else { Scale::Full };
    let threads = parse_value(&args, "--threads")?
        .map(|v| match v.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(terapool::err!("--threads wants a positive integer, got {v}")),
        })
        .transpose()?
        .unwrap_or(1);
    let json_path = parse_value(&args, "--json")?;
    let no_skip = args.iter().any(|a| a == "--no-skip");
    let estimate = args.iter().any(|a| a == "--estimate");
    let burst = args.iter().any(|a| a == "--burst");
    let topology = parse_value(&args, "--topology")?;
    let slices = parse_value(&args, "--slices")?
        .map(|v| match v.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(terapool::err!("--slices wants a positive integer, got {v}")),
        })
        .transpose()?
        .unwrap_or(1);
    let spec = parse_value(&args, "--spec")?;
    let resume = parse_value(&args, "--resume")?;

    if args.iter().any(|a| a == "--list") {
        print_list();
        return Ok(());
    }

    let cmd = args
        .iter()
        .enumerate()
        .filter(|(i, a)| !a.starts_with("--") && !is_option_value(&args, *i))
        .map(|(_, a)| a.clone())
        .next();
    let Some(cmd) = cmd else { bail!("{USAGE}") };

    // The sweep service runs before the shared Session is built: its
    // --json artifact is one combined SweepReport (which embeds every
    // RunReport with provenance), not the flat RunReport list.
    if cmd == "sweep-space" {
        return sweep_space(
            spec.as_deref(),
            resume.as_deref(),
            json_path.as_deref(),
            fast,
            threads,
        );
    }

    // The single Session every cluster-simulator experiment runs
    // through; its accumulated RunReports become the --json document.
    let session = Session::new(ClusterConfig::terapool(9).with_burst(burst))
        .scale(scale)
        .threads(threads)
        .fast_forward(!no_skip)
        .estimating(estimate);
    let mut reports: Vec<RunReport> = Vec::new();

    // Dispatch, but write the --json document even when the command
    // fails: a failing `validate` is exactly when CI needs the report
    // (the Failed verdicts are in it).
    let outcome = dispatch(
        &cmd,
        scale,
        threads,
        burst,
        no_skip,
        topology.as_deref(),
        slices,
        &session,
        &mut reports,
    );
    reports.extend(session.take_reports());
    if let Some(path) = json_path {
        std::fs::write(&path, reports_to_json(&reports))?;
        println!("\nwrote {} RunReport(s) to {path}", reports.len());
    }
    outcome
}

#[allow(clippy::too_many_arguments)]
fn dispatch(
    cmd: &str,
    scale: Scale,
    threads: usize,
    burst: bool,
    no_skip: bool,
    topology: Option<&str>,
    slices: usize,
    session: &Session,
    reports: &mut Vec<RunReport>,
) -> Result<()> {
    match cmd {
        "table3" => coordinator::table3().print(),
        "table4" => coordinator::table4(scale).print(),
        "fig8" => coordinator::fig8(scale).print(),
        "fig9" => coordinator::fig9(scale).print(),
        "fig11" => coordinator::fig11().print(),
        "fig12" => coordinator::fig12().print(),
        "fig13" => coordinator::fig13().print(),
        "fig14a" => coordinator::fig14a(session).print(),
        "fig14b" => coordinator::fig14b(session).print(),
        "table5" => coordinator::table5().print(),
        "table6" => coordinator::table6(session).print(),
        "scaling" => coordinator::scaling_analysis().print(),
        "headline" => coordinator::headline(session).print(),
        "all" => {
            coordinator::table3().print();
            coordinator::table4(scale).print();
            coordinator::fig8(scale).print();
            coordinator::fig9(scale).print();
            coordinator::fig11().print();
            coordinator::fig12().print();
            coordinator::fig13().print();
            coordinator::fig14a(session).print();
            coordinator::fig14b(session).print();
            coordinator::table5().print();
            coordinator::table6(session).print();
            coordinator::scaling_analysis().print();
            coordinator::headline(session).print();
        }
        "fig-scaleout" => coordinator::fig_scaleout(session).print(),
        "fig-sweep" => coordinator::fig_sweep(session)?.print(),
        "system" => system_cmd(scale, threads, no_skip, topology, slices, reports)?,
        "validate" => validate(scale, threads, reports)?,
        "sweep" => sweep(session, burst)?,
        "ablate-txtable" => ablate_txtable(session),
        "ablate-addrmap" => ablate_addrmap(session),
        "ablate-spill" => ablate_spill(session),
        other => bail!("unknown experiment {other}\n{USAGE}"),
    }
    Ok(())
}

/// Extract the value of `--flag V` or `--flag=V` (None when absent).
fn parse_value(args: &[String], flag: &str) -> Result<Option<String>> {
    for (i, a) in args.iter().enumerate() {
        if a == flag {
            let Some(v) = args.get(i + 1) else {
                bail!("{flag} requires a value\n{USAGE}");
            };
            return Ok(Some(v.clone()));
        }
        if let Some(v) = a.strip_prefix(&format!("{flag}=")) {
            return Ok(Some(v.to_string()));
        }
    }
    Ok(None)
}

/// Is `args[i]` the value operand of a preceding value-taking option?
fn is_option_value(args: &[String], i: usize) -> bool {
    i > 0
        && (args[i - 1] == "--threads"
            || args[i - 1] == "--json"
            || args[i - 1] == "--topology"
            || args[i - 1] == "--slices"
            || args[i - 1] == "--spec"
            || args[i - 1] == "--resume")
}

/// `--list`: everything the registry and the experiment index know.
fn print_list() {
    println!("registered workloads (run via `validate`, figs, or the Session API):");
    for w in kernels::registry() {
        println!("  {:10} {}", w.kind(), w.describe());
    }
    println!("\nexperiments:");
    for (name, what) in coordinator::EXPERIMENTS {
        println!("  {name:16} {what}");
    }
}

/// `terapool system`: load (or default) the topology, run chunked GEMM
/// and FFT data-parallel across its clusters with host-reference
/// checking on, print the per-cluster / per-link / bus breakdowns, and
/// fail on any `Failed` verdict. Reports land in `reports` before any
/// failure propagates so `--json` carries them.
fn system_cmd(
    scale: Scale,
    threads: usize,
    no_skip: bool,
    topology: Option<&str>,
    slices: usize,
    reports: &mut Vec<RunReport>,
) -> Result<()> {
    let path = std::path::PathBuf::from(topology.unwrap_or("examples/quad.topo"));
    let topo = terapool::topology::Topology::load(&path)?;
    println!("system: {}", topo.describe());
    // The session's own ClusterConfig is irrelevant here — system runs
    // simulate the topology's cluster configs.
    let s = Session::new(ClusterConfig::terapool(9))
        .scale(scale)
        .threads(threads)
        .fast_forward(!no_skip)
        .slices(slices)
        .check(true);
    let mut failures = 0usize;
    for kind in ["gemm", "fft"] {
        let r = s.system(&topo, kind)?;
        print_system_report(&r);
        if r.verdict.is_failure() {
            failures += 1;
        }
    }
    reports.extend(s.take_reports());
    ensure!(failures == 0, "system: {failures} kernel(s) failed their host reference");
    Ok(())
}

fn print_system_report(r: &RunReport) {
    let info = r.system.as_ref().expect("system runs carry the system section");
    println!(
        "\n{}: {} cycles (stage {} + compute {} + merge {}), {} [{}]",
        r.workload,
        r.stats.cycles,
        info.stage_cycles,
        info.compute_cycles,
        info.merge_cycles,
        r.verdict.status(),
        r.verdict.detail(),
    );
    println!(
        "  aggregate: {} PEs, {:.1} GFLOP/s, bus {} words / {} busy cycles",
        r.stats.num_pes,
        r.stats.gflops(),
        info.bus_words,
        info.bus_busy_cycles
    );
    let hidden_pct = if info.bus_busy_cycles > 0 {
        100.0 * info.hidden_bus_cycles as f64 / info.bus_busy_cycles as f64
    } else {
        0.0
    };
    println!(
        "  overlap: {} slices/cluster, bus cycles {} exposed / {} hidden ({hidden_pct:.0}% hidden)",
        info.slices, info.exposed_bus_cycles, info.hidden_bus_cycles
    );
    for c in &info.clusters {
        println!(
            "  cluster {:>4}: {:>5} PEs  {:>9} cycles  {:>11} instr",
            c.name, c.num_pes, c.cycles, c.instructions
        );
    }
    for l in &info.links {
        println!(
            "  link {:>10}: {:>7} words  {:>6} busy cycles",
            l.name, l.words, l.busy_cycles
        );
    }
}

/// Functional validation, two layers:
///
/// 1. **pure-Rust references** (always available): every registered
///    kernel runs through a checking [`Session`] and must come back
///    `Verdict::Passed`. A run that hits the cycle budget surfaces as a
///    typed `MaxCyclesExceeded` error — reported as a failure, never
///    compared as garbage output.
/// 2. **AOT goldens** (when `make artifacts` has run): the same host
///    references vs the JAX-evaluated `artifacts/<name>.golden.bin`.
///
/// Reports accumulate into `reports` *before* any failure propagates, so
/// `--json` always carries the verdicts (including `Failed` ones).
fn validate(scale: Scale, threads: usize, reports: &mut Vec<RunReport>) -> Result<()> {
    let cfg = ClusterConfig::terapool(9);

    // ---- layer 1: host references ---------------------------------
    let session = Session::new(cfg.clone()).scale(scale).threads(threads).check(true);
    // Validation problem sizes: registry defaults where the reference
    // is cheap, pinned smaller shapes where it is quadratic/cubic.
    let jobs = vec![
        Job::new(cfg.clone(), kernels::lookup("axpy")?),
        Job::new(cfg.clone(), kernels::lookup("dotp")?),
        Job::new(
            cfg.clone(),
            Box::new(gemm::Gemm::with({
                let e = scale.pick(256, 64);
                gemm::GemmParams { m: e, n: e, k: e }
            })),
        ),
        Job::new(cfg.clone(), Box::new(fft::Fft::with(fft::FftParams { batch: 4, n: 256 }))),
        Job::new(
            cfg.clone(),
            Box::new(spmmadd::Spmmadd::with(spmmadd::SpmmaddParams {
                rows: 512,
                cols: 512,
                nnz_per_row: spmmadd::CANONICAL_NNZ_PER_ROW,
                seed: spmmadd::CANONICAL_SEED,
            })),
        ),
    ];
    let mut failures = 0usize;
    for (job, r) in jobs.iter().zip(session.run_batch(&jobs)) {
        let kind = job.workload.kind();
        match r {
            Err(e) => {
                failures += 1;
                println!("{kind:8} FAILED: {e}");
            }
            Ok(rep) => match &rep.verdict {
                Verdict::Passed { detail } => println!(
                    "{kind:8} OK: {detail} (IPC {:.2}, {} cycles)",
                    rep.stats.ipc(),
                    rep.stats.cycles
                ),
                Verdict::Failed { reason } => {
                    failures += 1;
                    println!("{kind:8} FAILED: {reason}");
                }
                Verdict::NotChecked => {
                    failures += 1;
                    println!("{kind:8} FAILED: workload ships no host-reference check");
                }
            },
        }
    }
    // Hand the verdict-bearing reports to the caller before any bail:
    // --json must carry the failures, not vanish with them.
    reports.extend(session.take_reports());
    ensure!(failures == 0, "validate: {failures} kernel(s) failed their host reference");

    // ---- layer 2: AOT goldens -------------------------------------
    // The simulator was already validated against the host references
    // above; pinning those same references to the JAX-evaluated goldens
    // closes the loop sim ↔ reference ↔ JAX without re-simulating the
    // full-scale problems (the cluster↔golden end-to-end runs live in
    // rust/tests/golden.rs).
    match Runtime::with_default_dir() {
        Err(e) => println!(
            "\ngoldens  SKIPPED: {e}\n         run `make artifacts` to enable the JAX-evaluated layer"
        ),
        Ok(rt) => {
            let n = rt.entry("axpy")?.inputs[1].shape[0];
            let p = kernels::axpy::AxpyParams { n, alpha: 2.0 };
            let golden = rt.golden_f32("axpy")?;
            assert_allclose(&kernels::axpy::reference(&p), &golden, 1e-6, "axpy ref vs golden");
            println!("axpy     OK: host reference matches the JAX golden ({n} elements)");

            let n = rt.entry("dotp")?.inputs[0].shape[0];
            let golden = rt.golden_f32("dotp")?;
            let want = kernels::dotp::reference(&kernels::dotp::DotpParams { n });
            let tol = want.abs().max(1.0) * 2e-4;
            ensure!(
                (golden[0] - want).abs() < tol,
                "dotp ref vs golden: {want} vs {}",
                golden[0]
            );
            println!("dotp     OK: host reference matches the JAX golden");

            let shape = rt.entry("gemm")?.inputs[0].shape.clone();
            let gp = gemm::GemmParams { m: shape[0], n: shape[1], k: shape[0] };
            let golden = rt.golden_f32("gemm")?;
            assert_allclose(&gemm::reference(&gp), &golden, 1e-2, "gemm ref vs golden");
            println!("gemm     OK: {}x{} host reference matches the JAX golden", gp.m, gp.n);

            // spmmadd's golden was evaluated on CSR inputs regenerated by
            // the Python SplitMix64 port; the Rust generator must land on
            // the identical dense sum (exact — quarters, two addends).
            let shape = rt.entry("spmmadd")?.inputs[0].shape.clone();
            let (rows, cols) = (shape[0], shape[1]);
            let golden = rt.golden_f32("spmmadd")?;
            let want = spmmadd::canonical_dense_sum(rows, cols);
            ensure!(golden == want, "spmmadd golden diverges from the Rust CSR generator");
            println!("spmmadd  OK: {rows}x{cols} CSR dense sum matches the JAX golden");
        }
    }

    println!("\nvalidate: all cluster-simulator results match their references");
    Ok(())
}

/// `terapool sweep-space`: the estimate-guided design-space sweep
/// service ([`terapool::sweep`]). `--spec` picks the grid, `--resume`
/// makes the run checkpointed and resumable, `--json` writes the final
/// combined `SweepReport`, `--fast` forces the spec's scale down. Fails
/// *after* writing every artifact if any frontier point's estimate
/// drifts beyond the spec rtol — same reports-before-bail contract as
/// `system` and `validate`.
fn sweep_space(
    spec: Option<&str>,
    resume: Option<&str>,
    json_path: Option<&str>,
    fast: bool,
    threads: usize,
) -> Result<()> {
    use terapool::sweep::{run_sweep, SweepReport, SweepSpec};
    let path = std::path::PathBuf::from(spec.unwrap_or("examples/terapool.sweep"));
    let mut spec = SweepSpec::load(&path)?;
    if fast {
        spec.scale = Scale::Fast;
    }
    let prior = match resume {
        Some(p) if std::path::Path::new(p).exists() => {
            let rep = SweepReport::parse(&std::fs::read_to_string(p)?)?;
            let done = rep
                .points
                .iter()
                .filter(|r| r.estimated.is_some() || r.error.is_some())
                .count();
            println!("resuming from {p}: {done}/{} points already explored", rep.points.len());
            Some(rep)
        }
        _ => None,
    };
    let report = run_sweep(&spec, threads, prior.as_ref(), |snap| {
        if let Some(p) = resume {
            std::fs::write(p, snap.render())?;
        }
        Ok(())
    })?;
    report.table().print();
    if let Some(p) = resume {
        std::fs::write(p, report.render())?;
    }
    if let Some(p) = json_path {
        std::fs::write(p, report.render())?;
        println!("\nwrote SweepReport ({} points) to {p}", report.points.len());
    }
    let drift = report.frontier_drift_failures();
    ensure!(
        drift == 0,
        "sweep-space: {drift} frontier point(s) exceed the rtol {} drift bound",
        report.rtol
    );
    Ok(())
}

/// Table-6 config × kernel sweep through the session's run path. One
/// command serves both sides of the estimate-accuracy CI gate: run it
/// plain for the cycle-accurate reference, run it with `--estimate` for
/// the analytic fast path, and hold the two documents together with
/// `tools/report_diff.py --rtol 0.10` (census-backed fields are
/// compared exactly; cycles/stalls/AMAT to the stated bound). The
/// kernel list includes a double-buffered workload so the gate also
/// pins the estimator's fluid DMA-timeline model.
fn sweep(s: &Session, burst: bool) -> Result<()> {
    use terapool::report::{f2, int, Table};
    let configs = [
        ClusterConfig::tiny().with_burst(burst),
        ClusterConfig::mempool().with_burst(burst),
        ClusterConfig::occamy().with_burst(burst),
        ClusterConfig::terapool(9).with_burst(burst),
    ];
    let mut t = Table::new(
        "Sweep — Table-6 configs × kernels (Session run path)",
        &["Config", "Kernel", "Cycles", "IPC", "AMAT", "Path"],
    );
    for cfg in &configs {
        for kernel in ["axpy", "dotp", "db-axpy"] {
            let r = s.run_on(cfg, &*kernels::lookup(kernel)?)?;
            let path = match &r.estimate {
                Some(e) => format!("estimate (residual {:.3})", e.model_residual),
                None => "cycle-accurate".into(),
            };
            t.row(vec![
                cfg.name.clone(),
                kernel.into(),
                int(r.stats.cycles),
                f2(r.stats.ipc()),
                f2(r.stats.amat),
                path,
            ]);
        }
    }
    t.print();
    Ok(())
}

fn ablate_txtable(s: &Session) {
    use terapool::report::{f2, int, Table};
    let mut t = Table::new(
        "Ablation — LSU transaction-table depth (GEMM)",
        &["Entries", "IPC", "LSU stall %", "Cycles"],
    );
    for entries in [1usize, 2, 4, 8, 16] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.tx_table_entries = entries;
        let r = s.run_on(&cfg, &gemm::Gemm::default()).expect("ablation gemm run");
        let st = &r.stats;
        t.row(vec![
            int(entries as u64),
            f2(st.ipc()),
            terapool::report::pct(st.fraction(st.stall_lsu)),
            int(st.cycles),
        ]);
    }
    t.print();
}

fn ablate_addrmap(s: &Session) {
    use terapool::report::{f2, Table};
    let mut t = Table::new(
        "Ablation — sequential-region size (AXPY AMAT, barrier traffic local vs remote)",
        &["Seq words/Tile", "IPC", "AMAT", "Local req %"],
    );
    for seq in [256usize, 1024, 4096] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.seq_words_per_tile = seq;
        let r = s.run_on(&cfg, &kernels::axpy::Axpy::default()).expect("ablation axpy run");
        let st = &r.stats;
        let total: u64 = st.reqs_per_class.iter().sum();
        t.row(vec![
            terapool::report::int(seq as u64),
            f2(st.ipc()),
            f2(st.amat),
            terapool::report::pct(st.reqs_per_class[0] as f64 / total as f64),
        ]);
    }
    t.print();
}

fn ablate_spill(s: &Session) {
    use terapool::report::{f1, f2, Table};
    let mut t = Table::new(
        "Ablation — spill-register configs: latency vs frequency (GEMM)",
        &["Config", "MHz", "IPC", "Cycles", "Runtime µs", "GFLOP/s"],
    );
    for rg in [7u32, 9, 11] {
        let cfg = ClusterConfig::terapool(rg);
        let r = s.run_on(&cfg, &gemm::Gemm::default()).expect("ablation gemm run");
        let st = &r.stats;
        let us = st.cycles as f64 / cfg.freq_mhz;
        t.row(vec![
            cfg.name.clone(),
            f1(cfg.freq_mhz),
            f2(st.ipc()),
            terapool::report::int(st.cycles),
            f1(us),
            f1(st.gflops()),
        ]);
    }
    t.print();
}
