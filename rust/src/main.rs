//! TeraPool reproduction CLI — regenerate any paper table/figure.
//!
//! ```text
//! terapool table4            # hierarchical interconnect analysis
//! terapool fig14a --fast     # kernel IPC/stalls at reduced scale
//! terapool all --fast        # everything (reduced scale)
//! terapool validate          # run kernels + compare vs AOT goldens
//! ```
//!
//! Argument parsing is hand-rolled (no clap in the offline build).

use anyhow::{bail, Result};

use terapool::config::ClusterConfig;
use terapool::coordinator::{self, Scale};
use terapool::kernels;
use terapool::runtime::{assert_allclose, Runtime};

const USAGE: &str = "usage: terapool <experiment> [--fast]
experiments:
  table3 table4 fig8 fig9 fig11 fig12 fig13 fig14a fig14b
  table5 table6 scaling headline all validate
  ablate-txtable ablate-addrmap ablate-spill";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let scale = if fast { Scale::Fast } else { Scale::Full };
    let cmd = args.iter().find(|a| !a.starts_with("--")).cloned();
    let Some(cmd) = cmd else { bail!("{USAGE}") };
    match cmd.as_str() {
        "table3" => coordinator::table3().print(),
        "table4" => coordinator::table4(scale).print(),
        "fig8" => coordinator::fig8(scale).print(),
        "fig9" => coordinator::fig9(scale).print(),
        "fig11" => coordinator::fig11().print(),
        "fig12" => coordinator::fig12().print(),
        "fig13" => coordinator::fig13().print(),
        "fig14a" => coordinator::fig14a(scale).print(),
        "fig14b" => coordinator::fig14b(scale).print(),
        "table5" => coordinator::table5().print(),
        "table6" => coordinator::table6(scale).print(),
        "scaling" => coordinator::scaling_analysis().print(),
        "headline" => coordinator::headline(scale).print(),
        "all" => {
            coordinator::table3().print();
            coordinator::table4(scale).print();
            coordinator::fig8(scale).print();
            coordinator::fig9(scale).print();
            coordinator::fig11().print();
            coordinator::fig12().print();
            coordinator::fig13().print();
            coordinator::fig14a(scale).print();
            coordinator::fig14b(scale).print();
            coordinator::table5().print();
            coordinator::table6(scale).print();
            coordinator::scaling_analysis().print();
            coordinator::headline(scale).print();
        }
        "validate" => validate(scale)?,
        "ablate-txtable" => ablate_txtable(scale),
        "ablate-addrmap" => ablate_addrmap(scale),
        "ablate-spill" => ablate_spill(scale),
        other => bail!("unknown experiment {other}\n{USAGE}"),
    }
    Ok(())
}

/// Functional validation: run AXPY/DOTP/GEMM on the simulated cluster and
/// compare the final L1 image against the PJRT-executed JAX artifacts.
fn validate(scale: Scale) -> Result<()> {
    let mut rt = Runtime::with_default_dir()?;
    let cfg = ClusterConfig::terapool(9);

    // AXPY at artifact size.
    let n = rt.entry("axpy")?.inputs[1].shape[0];
    let p = kernels::axpy::AxpyParams { n, alpha: 2.0 };
    let setup = kernels::axpy::build(&cfg, &p);
    let x = kernels::axpy::input_x(n);
    let y = kernels::axpy::input_y(n);
    let (mut cl, io) = setup.into_cluster(cfg.clone());
    let stats = cl.run(2_000_000_000);
    let golden = rt.execute_f32("axpy", &[vec![p.alpha], x, y])?;
    assert_allclose(&io.read_output(&cl), &golden[0], 1e-5, "axpy vs artifact");
    println!(
        "axpy     OK: {} elements match XLA golden (IPC {:.2}, {} cycles)",
        n, stats.ipc(), stats.cycles
    );

    // DOTP.
    let n = rt.entry("dotp")?.inputs[0].shape[0];
    let p = kernels::dotp::DotpParams { n };
    let setup = kernels::dotp::build(&cfg, &p);
    let x = kernels::dotp::input_x(n);
    let y = kernels::dotp::input_y(n);
    let (mut cl, io) = setup.into_cluster(cfg.clone());
    cl.run(2_000_000_000);
    let golden = rt.execute_f32("dotp", &[x, y])?;
    let got = io.read_output(&cl)[0];
    let want = golden[0][0];
    let tol = want.abs().max(1.0) * 1e-4;
    anyhow::ensure!(
        (got - want).abs() < tol,
        "dotp mismatch: {got} vs {want}"
    );
    println!("dotp     OK: {got:.3} matches XLA golden {want:.3}");

    // GEMM (full 256^3 when not --fast).
    if scale == Scale::Full {
        let shape = rt.entry("gemm")?.inputs[0].shape.clone();
        let p = kernels::gemm::GemmParams { m: shape[0], n: shape[1], k: shape[0] };
        let setup = kernels::gemm::build(&cfg, &p);
        let a = kernels::gemm::input_a(&p);
        let b = kernels::gemm::input_b(&p);
        let (mut cl, io) = setup.into_cluster(cfg.clone());
        let stats = cl.run(2_000_000_000);
        let golden = rt.execute_f32("gemm", &[a, b])?;
        assert_allclose(&io.read_output(&cl), &golden[0], 2e-2, "gemm vs artifact");
        println!(
            "gemm     OK: {}x{} result matches XLA golden (IPC {:.2})",
            p.m, p.n, stats.ipc()
        );
    }

    // SpMMadd: densified CSR result vs the dense-add artifact.
    let shape = rt.entry("spmmadd")?.inputs[0].shape.clone();
    let sp = kernels::spmmadd::SpmmaddParams {
        rows: shape[0],
        cols: shape[1],
        nnz_per_row: 8,
        seed: 0x5EED,
    };
    let (setup, layout) = kernels::spmmadd::build_with_layout(&cfg, &sp);
    let (mut cl, _io) = setup.into_cluster(cfg.clone());
    cl.run(2_000_000_000);
    // Densify the simulated CSR output.
    let vals = cl.l1.read_slice(layout.c_val_base, layout.c_ref.nnz());
    let cols = cl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
    let mut dense = vec![0.0f32; sp.rows * sp.cols];
    for r in 0..sp.rows {
        for i in layout.c_ref.row_ptr[r] as usize..layout.c_ref.row_ptr[r + 1] as usize {
            dense[r * sp.cols + cols[i] as usize] += vals[i];
        }
    }
    let golden = rt.execute_f32("spmmadd", &[layout.a.to_dense(), layout.b.to_dense()])?;
    assert_allclose(&dense, &golden[0], 1e-5, "spmmadd vs artifact");
    println!("spmmadd  OK: densified CSR sum matches XLA golden");

    println!("\nvalidate: all cluster-simulator results match the AOT XLA goldens");
    Ok(())
}

fn ablate_txtable(scale: Scale) {
    use terapool::report::{f2, int, Table};
    let mut t = Table::new(
        "Ablation — LSU transaction-table depth (GEMM)",
        &["Entries", "IPC", "LSU stall %", "Cycles"],
    );
    for entries in [1usize, 2, 4, 8, 16] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.tx_table_entries = entries;
        let (s, _) = coordinator::run_kernel(&cfg, "gemm", scale);
        t.row(vec![
            int(entries as u64),
            f2(s.ipc()),
            terapool::report::pct(s.fraction(s.stall_lsu)),
            int(s.cycles),
        ]);
    }
    t.print();
}

fn ablate_addrmap(scale: Scale) {
    use terapool::report::{f2, Table};
    let mut t = Table::new(
        "Ablation — sequential-region size (AXPY AMAT, barrier traffic local vs remote)",
        &["Seq words/Tile", "IPC", "AMAT", "Local req %"],
    );
    for seq in [256usize, 1024, 4096] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.seq_words_per_tile = seq;
        let (s, _) = coordinator::run_kernel(&cfg, "axpy", scale);
        let total: u64 = s.reqs_per_class.iter().sum();
        t.row(vec![
            terapool::report::int(seq as u64),
            f2(s.ipc()),
            f2(s.amat),
            terapool::report::pct(s.reqs_per_class[0] as f64 / total as f64),
        ]);
    }
    t.print();
}

fn ablate_spill(scale: Scale) {
    use terapool::report::{f1, f2, Table};
    let mut t = Table::new(
        "Ablation — spill-register configs: latency vs frequency (GEMM)",
        &["Config", "MHz", "IPC", "Cycles", "Runtime µs", "GFLOP/s"],
    );
    for rg in [7u32, 9, 11] {
        let cfg = ClusterConfig::terapool(rg);
        let (s, _) = coordinator::run_kernel(&cfg, "gemm", scale);
        let us = s.cycles as f64 / cfg.freq_mhz;
        t.row(vec![
            cfg.name.clone(),
            f1(cfg.freq_mhz),
            f2(s.ipc()),
            terapool::report::int(s.cycles),
            f1(us),
            f1(s.gflops()),
        ]);
    }
    t.print();
}
