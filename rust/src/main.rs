//! TeraPool reproduction CLI — regenerate any paper table/figure.
//!
//! ```text
//! terapool table4                 # hierarchical interconnect analysis
//! terapool fig14a --fast          # kernel IPC/stalls at reduced scale
//! terapool fig14a --threads 8     # same numbers, tile-parallel engine
//! terapool all --fast             # everything (reduced scale)
//! terapool validate               # kernels vs references + AOT goldens
//! ```
//!
//! Argument parsing is hand-rolled (no clap in the offline build), and
//! error plumbing uses the crate's own [`terapool::errors`] (no anyhow).
//!
//! `--threads N` selects the deterministic tile-parallel engine for every
//! cluster-simulator experiment. Simulated results are bit-identical to
//! the serial engine (N ≤ 1); only host wall clock changes.

use terapool::config::ClusterConfig;
use terapool::coordinator::{self, Scale};
use terapool::errors::Result;
use terapool::kernels;
use terapool::runtime::{assert_allclose, max_abs_diff, Runtime};
use terapool::{bail, ensure};

const USAGE: &str = "usage: terapool <experiment> [--fast] [--threads N]
experiments:
  table3 table4 fig8 fig9 fig11 fig12 fig13 fig14a fig14b
  table5 table6 scaling headline all validate
  ablate-txtable ablate-addrmap ablate-spill
options:
  --fast        reduced problem sizes (smoke runs, CI)
  --threads N   tile-parallel engine with N host threads (default 1 =
                serial reference engine; results are identical)";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let scale = if fast { Scale::Fast } else { Scale::Full };
    let threads = parse_threads(&args)?;
    let cmd = args
        .iter()
        .enumerate()
        .filter(|(i, a)| !a.starts_with("--") && !is_threads_value(&args, *i))
        .map(|(_, a)| a.clone())
        .next();
    let Some(cmd) = cmd else { bail!("{USAGE}") };
    match cmd.as_str() {
        "table3" => coordinator::table3().print(),
        "table4" => coordinator::table4(scale).print(),
        "fig8" => coordinator::fig8(scale).print(),
        "fig9" => coordinator::fig9(scale).print(),
        "fig11" => coordinator::fig11().print(),
        "fig12" => coordinator::fig12().print(),
        "fig13" => coordinator::fig13().print(),
        "fig14a" => coordinator::fig14a_threads(scale, threads).print(),
        "fig14b" => coordinator::fig14b_threads(scale, threads).print(),
        "table5" => coordinator::table5().print(),
        "table6" => coordinator::table6_threads(scale, threads).print(),
        "scaling" => coordinator::scaling_analysis().print(),
        "headline" => coordinator::headline_threads(scale, threads).print(),
        "all" => {
            coordinator::table3().print();
            coordinator::table4(scale).print();
            coordinator::fig8(scale).print();
            coordinator::fig9(scale).print();
            coordinator::fig11().print();
            coordinator::fig12().print();
            coordinator::fig13().print();
            coordinator::fig14a_threads(scale, threads).print();
            coordinator::fig14b_threads(scale, threads).print();
            coordinator::table5().print();
            coordinator::table6_threads(scale, threads).print();
            coordinator::scaling_analysis().print();
            coordinator::headline_threads(scale, threads).print();
        }
        "validate" => validate(scale, threads)?,
        "ablate-txtable" => ablate_txtable(scale, threads),
        "ablate-addrmap" => ablate_addrmap(scale, threads),
        "ablate-spill" => ablate_spill(scale, threads),
        other => bail!("unknown experiment {other}\n{USAGE}"),
    }
    Ok(())
}

/// Extract `--threads N` (defaults to 1: the serial reference engine).
fn parse_threads(args: &[String]) -> Result<usize> {
    for (i, a) in args.iter().enumerate() {
        if a == "--threads" {
            let Some(v) = args.get(i + 1) else {
                bail!("--threads requires a value\n{USAGE}");
            };
            return match v.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(n),
                _ => bail!("--threads wants a positive integer, got {v}"),
            };
        }
        if let Some(v) = a.strip_prefix("--threads=") {
            return match v.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(n),
                _ => bail!("--threads wants a positive integer, got {v}"),
            };
        }
    }
    Ok(1)
}

/// Is `args[i]` the value operand of a preceding `--threads`?
fn is_threads_value(args: &[String], i: usize) -> bool {
    i > 0 && args[i - 1] == "--threads"
}

/// Run a kernel setup on the selected engine.
fn run_setup(
    setup: kernels::KernelSetup,
    cfg: &ClusterConfig,
    threads: usize,
) -> (terapool::cluster::Cluster, kernels::KernelIo, terapool::cluster::RunStats) {
    let (mut cl, io) = setup.into_cluster(cfg.clone());
    let stats = cl.run_threads(2_000_000_000, threads);
    (cl, io, stats)
}

/// Functional validation, two layers:
///
/// 1. **pure-Rust references** (always available): every kernel's final
///    L1 image vs its host `reference()` implementation;
/// 2. **AOT goldens** (when `make artifacts` has run): the same results
///    vs the JAX-evaluated `artifacts/<name>.golden.bin` files.
fn validate(scale: Scale, threads: usize) -> Result<()> {
    let cfg = ClusterConfig::terapool(9);

    // ---- layer 1: host references ---------------------------------
    let n = scale.pick(256 * 1024, cfg.num_banks() * 16);
    let p = kernels::axpy::AxpyParams { n, alpha: 2.0 };
    let (cl, io, stats) = run_setup(kernels::axpy::build(&cfg, &p), &cfg, threads);
    assert_allclose(
        &io.read_output(&cl),
        &kernels::axpy::reference(&p),
        1e-5,
        "axpy vs host reference",
    );
    println!(
        "axpy     OK: {} elements match the host reference (IPC {:.2}, {} cycles)",
        n,
        stats.ipc(),
        stats.cycles
    );

    let p = kernels::dotp::DotpParams { n };
    let (cl, io, _) = run_setup(kernels::dotp::build(&cfg, &p), &cfg, threads);
    let got = io.read_output(&cl)[0];
    let want = kernels::dotp::reference(&p);
    let tol = want.abs().max(1.0) * 2e-4;
    ensure!((got - want).abs() < tol, "dotp mismatch: {got} vs reference {want}");
    println!("dotp     OK: {got:.3} matches host reference {want:.3}");

    let edge = scale.pick(256, 64);
    let gp = kernels::gemm::GemmParams { m: edge, n: edge, k: edge };
    let (cl, io, stats) = run_setup(kernels::gemm::build(&cfg, &gp), &cfg, threads);
    assert_allclose(
        &io.read_output(&cl),
        &kernels::gemm::reference(&gp),
        2e-2,
        "gemm vs host reference",
    );
    println!(
        "gemm     OK: {}x{} result matches the host reference (IPC {:.2})",
        gp.m,
        gp.n,
        stats.ipc()
    );

    let fp = kernels::fft::FftParams { batch: 4, n: 256 };
    let (cl, io, _) = run_setup(kernels::fft::build(&cfg, &fp), &cfg, threads);
    let im_off = kernels::fft::im_plane_offset(&cfg, &fp);
    let (want_re, want_im) = kernels::fft::reference(&fp);
    let got_re = io.read_output(&cl);
    let got_im = cl.l1.read_slice(io.output_base + im_off, fp.batch * fp.n);
    ensure!(max_abs_diff(&got_re, &want_re) < 5e-2, "fft re-plane mismatch");
    ensure!(max_abs_diff(&got_im, &want_im) < 5e-2, "fft im-plane mismatch");
    println!("fft      OK: {}x{} transform matches the host DFT", fp.batch, fp.n);

    let sp = kernels::spmmadd::SpmmaddParams {
        rows: 512,
        cols: 512,
        nnz_per_row: kernels::spmmadd::CANONICAL_NNZ_PER_ROW,
        seed: kernels::spmmadd::CANONICAL_SEED,
    };
    let (setup, layout) = kernels::spmmadd::build_with_layout(&cfg, &sp);
    let (mut cl, _io) = setup.into_cluster(cfg.clone());
    cl.run_threads(2_000_000_000, threads);
    let vals = cl.l1.read_slice(layout.c_val_base, layout.c_ref.nnz());
    let cols = cl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
    let mut dense = vec![0.0f32; sp.rows * sp.cols];
    for r in 0..sp.rows {
        for i in layout.c_ref.row_ptr[r] as usize..layout.c_ref.row_ptr[r + 1] as usize {
            dense[r * sp.cols + cols[i] as usize] += vals[i];
        }
    }
    let mut want = layout.a.to_dense();
    for (w, b) in want.iter_mut().zip(layout.b.to_dense()) {
        *w += b;
    }
    assert_allclose(&dense, &want, 1e-5, "spmmadd densified vs dense add");
    println!("spmmadd  OK: densified CSR sum matches the dense reference");

    // ---- layer 2: AOT goldens -------------------------------------
    // The simulator was already validated against the host references
    // above; pinning those same references to the JAX-evaluated goldens
    // closes the loop sim ↔ reference ↔ JAX without re-simulating the
    // full-scale problems (the cluster↔golden end-to-end runs live in
    // rust/tests/golden.rs).
    match Runtime::with_default_dir() {
        Err(e) => println!(
            "\ngoldens  SKIPPED: {e}\n         run `make artifacts` to enable the JAX-evaluated layer"
        ),
        Ok(rt) => {
            let n = rt.entry("axpy")?.inputs[1].shape[0];
            let p = kernels::axpy::AxpyParams { n, alpha: 2.0 };
            let golden = rt.golden_f32("axpy")?;
            assert_allclose(&kernels::axpy::reference(&p), &golden, 1e-6, "axpy ref vs golden");
            println!("axpy     OK: host reference matches the JAX golden ({n} elements)");

            let n = rt.entry("dotp")?.inputs[0].shape[0];
            let golden = rt.golden_f32("dotp")?;
            let want = kernels::dotp::reference(&kernels::dotp::DotpParams { n });
            let tol = want.abs().max(1.0) * 2e-4;
            ensure!(
                (golden[0] - want).abs() < tol,
                "dotp ref vs golden: {want} vs {}",
                golden[0]
            );
            println!("dotp     OK: host reference matches the JAX golden");

            let shape = rt.entry("gemm")?.inputs[0].shape.clone();
            let gp = kernels::gemm::GemmParams { m: shape[0], n: shape[1], k: shape[0] };
            let golden = rt.golden_f32("gemm")?;
            assert_allclose(&kernels::gemm::reference(&gp), &golden, 1e-2, "gemm ref vs golden");
            println!("gemm     OK: {}x{} host reference matches the JAX golden", gp.m, gp.n);

            // spmmadd's golden was evaluated on CSR inputs regenerated by
            // the Python SplitMix64 port; the Rust generator must land on
            // the identical dense sum (exact — quarters, two addends).
            let shape = rt.entry("spmmadd")?.inputs[0].shape.clone();
            let (rows, cols) = (shape[0], shape[1]);
            let golden = rt.golden_f32("spmmadd")?;
            let want = kernels::spmmadd::canonical_dense_sum(rows, cols);
            ensure!(golden == want, "spmmadd golden diverges from the Rust CSR generator");
            println!("spmmadd  OK: {rows}x{cols} CSR dense sum matches the JAX golden");
        }
    }

    println!("\nvalidate: all cluster-simulator results match their references");
    Ok(())
}

fn ablate_txtable(scale: Scale, threads: usize) {
    use terapool::report::{f2, int, Table};
    let mut t = Table::new(
        "Ablation — LSU transaction-table depth (GEMM)",
        &["Entries", "IPC", "LSU stall %", "Cycles"],
    );
    for entries in [1usize, 2, 4, 8, 16] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.tx_table_entries = entries;
        let (s, _) = coordinator::run_kernel_threads(&cfg, "gemm", scale, threads);
        t.row(vec![
            int(entries as u64),
            f2(s.ipc()),
            terapool::report::pct(s.fraction(s.stall_lsu)),
            int(s.cycles),
        ]);
    }
    t.print();
}

fn ablate_addrmap(scale: Scale, threads: usize) {
    use terapool::report::{f2, Table};
    let mut t = Table::new(
        "Ablation — sequential-region size (AXPY AMAT, barrier traffic local vs remote)",
        &["Seq words/Tile", "IPC", "AMAT", "Local req %"],
    );
    for seq in [256usize, 1024, 4096] {
        let mut cfg = ClusterConfig::terapool(9);
        cfg.seq_words_per_tile = seq;
        let (s, _) = coordinator::run_kernel_threads(&cfg, "axpy", scale, threads);
        let total: u64 = s.reqs_per_class.iter().sum();
        t.row(vec![
            terapool::report::int(seq as u64),
            f2(s.ipc()),
            f2(s.amat),
            terapool::report::pct(s.reqs_per_class[0] as f64 / total as f64),
        ]);
    }
    t.print();
}

fn ablate_spill(scale: Scale, threads: usize) {
    use terapool::report::{f1, f2, Table};
    let mut t = Table::new(
        "Ablation — spill-register configs: latency vs frequency (GEMM)",
        &["Config", "MHz", "IPC", "Cycles", "Runtime µs", "GFLOP/s"],
    );
    for rg in [7u32, 9, 11] {
        let cfg = ClusterConfig::terapool(rg);
        let (s, _) = coordinator::run_kernel_threads(&cfg, "gemm", scale, threads);
        let us = s.cycles as f64 / cfg.freq_mhz;
        t.row(vec![
            cfg.name.clone(),
            f1(cfg.freq_mhz),
            f2(s.ipc()),
            terapool::report::int(s.cycles),
            f1(us),
            f1(s.gflops()),
        ]);
    }
    t.print();
}
