//! Shared-L1 SPM: banked storage plus the paper's **hybrid address
//! mapping scheme** (Sec. 5.4, Fig. 8a).
//!
//! The word-addressed L1 space is split into:
//!
//! * a **sequential region** (first `seq_words_per_tile × num_tiles`
//!   words): Tile-private ranges for stacks/private data; requests stay in
//!   the issuing PE's Tile. Within a Tile the words interleave over the
//!   Tile's banks.
//! * an **interleaved region** (the rest): word-level interleaving across
//!   *all* banks, distributing data evenly and minimizing conflicts.
//!
//! The map is pure address scrambling (the paper: "wire crossings and a
//! multiplexer"), so it is a bijection — property-tested below.
//!
//! ## Storage sharding
//!
//! The backing storage is split into **per-Tile slices** ([`TileStore`]),
//! mirroring the physical design: a bank belongs to exactly one Tile, so
//! the sharded memory engine's per-Tile domains mutate disjoint slices
//! with no shared mutable state. Each slice sits behind an uncontended
//! mutex: the hot paths (the serial engine, and each parallel worker
//! inside its own phase) either go through `Mutex::get_mut` or lock a
//! slice once per cycle; the host-side word accessors used for staging,
//! result readback and the DMA's functional data movement lock per
//! access (cold paths).

use std::sync::Mutex;

use crate::config::ClusterConfig;

/// Physical location of a word: bank index and row within the bank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BankAddr {
    pub bank: u32,
    pub row: u32,
}

/// Address map resolved from a [`ClusterConfig`].
#[derive(Debug, Clone)]
pub struct AddressMap {
    num_banks: usize,
    banks_per_tile: usize,
    seq_words_per_tile: usize,
    seq_rows_per_bank: usize,
    seq_words_total: usize,
    words_per_bank: usize,
    /// log2(num_banks) when it is a power of two (§Perf: the interleaved
    /// mapping is on the per-request hot path; all paper configurations
    /// have power-of-two bank counts, so the div/mod reduce to shifts).
    nb_shift: Option<u32>,
}

impl AddressMap {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let nb = cfg.num_banks();
        let m = AddressMap {
            num_banks: nb,
            banks_per_tile: cfg.banks_per_tile(),
            seq_words_per_tile: cfg.seq_words_per_tile,
            seq_rows_per_bank: cfg.seq_rows_per_bank(),
            seq_words_total: cfg.seq_words_total(),
            words_per_bank: cfg.words_per_bank,
            nb_shift: if nb.is_power_of_two() { Some(nb.trailing_zeros()) } else { None },
        };
        assert!(
            m.seq_rows_per_bank < m.words_per_bank,
            "sequential region must leave interleaved rows"
        );
        assert_eq!(
            m.seq_words_per_tile % m.banks_per_tile,
            0,
            "seq region must fill whole bank rows per tile"
        );
        m
    }

    /// Total words in L1.
    pub fn l1_words(&self) -> usize {
        self.num_banks * self.words_per_bank
    }

    /// First word of the interleaved region.
    pub fn interleaved_base(&self) -> u32 {
        self.seq_words_total as u32
    }

    /// First sequential-region word of a Tile (its private range).
    pub fn seq_base_of_tile(&self, tile: usize) -> u32 {
        (tile * self.seq_words_per_tile) as u32
    }

    /// Map a word address to its bank and row.
    pub fn map(&self, word: u32) -> BankAddr {
        let w = word as usize;
        if w < self.seq_words_total {
            // Sequential region: per-Tile private, interleaved over the
            // Tile's own banks only.
            let tile = w / self.seq_words_per_tile;
            let off = w % self.seq_words_per_tile;
            let bank = tile * self.banks_per_tile + off % self.banks_per_tile;
            let row = off / self.banks_per_tile;
            BankAddr { bank: bank as u32, row: row as u32 }
        } else {
            // Interleaved region: word-level across all banks, rows above
            // the reserved sequential rows.
            let off = w - self.seq_words_total;
            let (bank, quot) = match self.nb_shift {
                Some(sh) => (off & (self.num_banks - 1), off >> sh),
                None => (off % self.num_banks, off / self.num_banks),
            };
            let row = self.seq_rows_per_bank + quot;
            assert!(
                row < self.words_per_bank,
                "word address {word} beyond L1 capacity"
            );
            BankAddr { bank: bank as u32, row: row as u32 }
        }
    }

    /// Inverse of [`AddressMap::map`]: the word address stored at a bank
    /// location. Together with `map` this witnesses that the hybrid
    /// scheme is a **bijection** between the word-address space and the
    /// bank×row space (the paper's "wire crossings and a multiplexer"
    /// claim, Sec. 5.4) — property-tested over randomized bank/tile
    /// counts in rust/tests/properties.rs.
    pub fn unmap(&self, at: BankAddr) -> u32 {
        let bank = at.bank as usize;
        let row = at.row as usize;
        debug_assert!(bank < self.num_banks && row < self.words_per_bank);
        if row < self.seq_rows_per_bank {
            // Sequential region: the Tile owning the bank, row-major
            // within the Tile's private range.
            let tile = bank / self.banks_per_tile;
            let off = row * self.banks_per_tile + bank % self.banks_per_tile;
            (tile * self.seq_words_per_tile + off) as u32
        } else {
            let off = (row - self.seq_rows_per_bank) * self.num_banks + bank;
            (self.seq_words_total + off) as u32
        }
    }

    /// SubGroup that owns an interleaved-region word (for the iDMA midend
    /// split, Sec. 5.4: 256 banks per SubGroup, one word per bank-row →
    /// contiguous 256-word runs alternate SubGroups).
    pub fn subgroup_of_interleaved(&self, word: u32, banks_per_subgroup: usize) -> usize {
        let off = word as usize - self.seq_words_total;
        (off % self.num_banks) / banks_per_subgroup
    }

    /// Split an `n`-word burst at `word` into its **beat runs**: maximal
    /// sub-ranges whose words map to consecutive banks of one Tile at a
    /// single row — exactly the window one bank-arbitration grant can
    /// cover. `sink(base, len)` receives each run's base bank location
    /// and beat count in address order; the run lengths sum to `n`, and
    /// run `k`'s base equals `map(word + sum of earlier lengths)`.
    ///
    /// Splits happen at a bank-row wrap, at a Tile boundary (a request is
    /// arbitrated entirely inside its destination Tile's domain), and at
    /// the interleaved region's bank-space wrap. This is the *single*
    /// definition of burst beat grouping: `cluster::route_action` builds
    /// one interconnect request per run, and the estimate path's traffic
    /// census replays the same split, so engine and census counters agree
    /// bit for bit.
    pub fn map_burst(&self, word: u32, n: u8, mut sink: impl FnMut(BankAddr, u8)) {
        debug_assert!(n >= 1);
        let mut run_base = self.map(word);
        let mut run_len: u8 = 1;
        let mut prev = run_base;
        for k in 1..n as u32 {
            let at = self.map(word + k);
            let same_tile = at.bank as usize / self.banks_per_tile
                == run_base.bank as usize / self.banks_per_tile;
            if at.row == prev.row && at.bank == prev.bank + 1 && same_tile {
                run_len += 1;
            } else {
                sink(run_base, run_len);
                run_base = at;
                run_len = 1;
            }
            prev = at;
        }
        sink(run_base, run_len);
    }
}

/// One Tile's slice of the banked L1: `banks_per_tile` banks, bank-major.
/// Functional state only — timing (ports, conflicts) is owned by the
/// Tile's memory domain in [`crate::interconnect`].
#[derive(Debug)]
pub struct TileStore {
    words: Vec<f32>,
    words_per_bank: usize,
}

impl TileStore {
    #[inline]
    pub fn read(&self, local_bank: usize, row: usize) -> f32 {
        self.words[local_bank * self.words_per_bank + row]
    }
    #[inline]
    pub fn write(&mut self, local_bank: usize, row: usize, v: f32) {
        self.words[local_bank * self.words_per_bank + row] = v;
    }
    /// Atomic fetch-and-add at the bank (returns the *new* value).
    #[inline]
    pub fn amo_add(&mut self, local_bank: usize, row: usize, v: f32) -> f32 {
        let slot = &mut self.words[local_bank * self.words_per_bank + row];
        *slot += v;
        *slot
    }
}

/// The banked L1 storage, sharded per Tile (see the module docs).
#[derive(Debug)]
pub struct L1Memory {
    pub map: AddressMap,
    banks_per_tile: usize,
    tiles: Vec<Mutex<TileStore>>,
}

impl L1Memory {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let map = AddressMap::new(cfg);
        L1Memory {
            map,
            banks_per_tile: cfg.banks_per_tile(),
            tiles: (0..cfg.num_tiles())
                .map(|_| {
                    Mutex::new(TileStore {
                        words: vec![0.0; cfg.banks_per_tile() * cfg.words_per_bank],
                        words_per_bank: cfg.words_per_bank,
                    })
                })
                .collect(),
        }
    }

    /// (tile, bank-within-tile) of a global bank index.
    #[inline]
    fn locate(&self, at: BankAddr) -> (usize, usize) {
        let bank = at.bank as usize;
        (bank / self.banks_per_tile, bank % self.banks_per_tile)
    }

    /// A Tile's slice cell, for the parallel engine's workers (each locks
    /// its owned Tiles once per cycle; never contended — phases strictly
    /// alternate).
    pub fn tile_store(&self, tile: usize) -> &Mutex<TileStore> {
        &self.tiles[tile]
    }

    /// A Tile's slice with exclusive access (serial engine; no locking).
    pub fn tile_store_mut(&mut self, tile: usize) -> &mut TileStore {
        self.tiles[tile].get_mut().unwrap()
    }

    pub fn read_bank(&self, at: BankAddr) -> f32 {
        let (t, b) = self.locate(at);
        self.tiles[t].lock().unwrap().read(b, at.row as usize)
    }
    pub fn write_bank(&mut self, at: BankAddr, v: f32) {
        let (t, b) = self.locate(at);
        self.tiles[t].get_mut().unwrap().write(b, at.row as usize, v);
    }
    /// Atomic fetch-and-add at the bank (returns the *new* value).
    pub fn amo_add_bank(&mut self, at: BankAddr, v: f32) -> f32 {
        let (t, b) = self.locate(at);
        self.tiles[t].get_mut().unwrap().amo_add(b, at.row as usize, v)
    }

    /// Word-addressed accessors (host/DMA side).
    pub fn read(&self, word: u32) -> f32 {
        self.read_bank(self.map.map(word))
    }
    pub fn write(&mut self, word: u32, v: f32) {
        self.write_bank(self.map.map(word), v)
    }
    /// Word write through a shared reference (the DMA's functional data
    /// movement runs in the coordinator's serial pre-phase while the
    /// worker threads hold `&L1Memory`; the per-Tile locks are free then).
    pub fn write_shared(&self, word: u32, v: f32) {
        let at = self.map.map(word);
        let (t, b) = self.locate(at);
        self.tiles[t].lock().unwrap().write(b, at.row as usize, v);
    }

    /// Bulk write of consecutive words through a shared reference,
    /// locking each destination Tile once per contiguous run instead of
    /// once per word. Consecutive interleaved words sweep consecutive
    /// banks, so runs are `banks_per_tile` long — a 256-word DMA burst
    /// takes ~8 locks instead of 256.
    pub fn write_run_shared(&self, base: u32, data: &[f32]) {
        let mut i = 0;
        while i < data.len() {
            let at = self.map.map(base + i as u32);
            let (t, b) = self.locate(at);
            let mut store = self.tiles[t].lock().unwrap();
            store.write(b, at.row as usize, data[i]);
            i += 1;
            while i < data.len() {
                let at = self.map.map(base + i as u32);
                let (t2, b2) = self.locate(at);
                if t2 != t {
                    break;
                }
                store.write(b2, at.row as usize, data[i]);
                i += 1;
            }
        }
    }

    /// Bulk read of consecutive words through a shared reference into a
    /// caller-recycled buffer (cleared first); Tile-run locking as in
    /// [`L1Memory::write_run_shared`].
    pub fn read_run_shared(&self, base: u32, n: usize, out: &mut Vec<f32>) {
        out.clear();
        let mut i = 0;
        while i < n {
            let at = self.map.map(base + i as u32);
            let (t, b) = self.locate(at);
            let store = self.tiles[t].lock().unwrap();
            out.push(store.read(b, at.row as usize));
            i += 1;
            while i < n {
                let at = self.map.map(base + i as u32);
                let (t2, b2) = self.locate(at);
                if t2 != t {
                    break;
                }
                out.push(store.read(b2, at.row as usize));
                i += 1;
            }
        }
    }

    /// Words to skip past the remainder of a *foreign* Tile's bank run
    /// starting at `word` (which maps to `at`): in the interleaved region
    /// consecutive words sweep consecutive banks, so the rest of the
    /// current Tile's bank window — including the wrap back to bank 0,
    /// which is itself a Tile boundary — can be stepped over in one jump.
    /// In the sequential region (not a DMA target, kept correct anyway)
    /// advance a single word.
    #[inline]
    fn foreign_run_skip(&self, word: u32, at: BankAddr) -> usize {
        if (word as usize) < self.map.seq_words_total {
            1
        } else {
            self.banks_per_tile - (at.bank as usize % self.banks_per_tile)
        }
    }

    /// Range-restricted variant of [`L1Memory::write_run_shared`] for the
    /// sharded engine's workers: writes only the words of the run that
    /// land in Tiles `[tile_lo, tile_hi)`. Every worker applies an
    /// inbound DMA burst's sub-runs to the slices it owns — no two
    /// workers ever touch the same slice, so the per-Tile locks stay
    /// uncontended and the union over all workers' ranges equals the
    /// serial engine's whole-run write. Foreign Tiles' runs are skipped
    /// in one jump each, so a worker's pass costs O(own words +
    /// number of foreign runs), not O(burst length).
    pub fn write_run_range(&self, base: u32, data: &[f32], tile_lo: usize, tile_hi: usize) {
        let mut i = 0;
        while i < data.len() {
            let at = self.map.map(base + i as u32);
            let (t, b) = self.locate(at);
            if t < tile_lo || t >= tile_hi {
                i += self.foreign_run_skip(base + i as u32, at);
                continue;
            }
            let mut store = self.tiles[t].lock().unwrap();
            store.write(b, at.row as usize, data[i]);
            i += 1;
            while i < data.len() {
                let at = self.map.map(base + i as u32);
                let (t2, b2) = self.locate(at);
                if t2 != t {
                    break;
                }
                store.write(b2, at.row as usize, data[i]);
                i += 1;
            }
        }
    }

    /// Bulk host-side copy-in/out, used by test harnesses and the DMA
    /// backends' functional data movement.
    pub fn write_slice(&mut self, base: u32, data: &[f32]) {
        for (i, &v) in data.iter().enumerate() {
            self.write(base + i as u32, v);
        }
    }
    pub fn read_slice(&self, base: u32, n: usize) -> Vec<f32> {
        (0..n).map(|i| self.read(base + i as u32)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn map() -> AddressMap {
        AddressMap::new(&ClusterConfig::terapool(9))
    }

    #[test]
    fn sequential_region_stays_in_tile() {
        let cfg = ClusterConfig::terapool(9);
        let m = map();
        for tile in [0usize, 1, 63, 127] {
            let base = m.seq_base_of_tile(tile);
            for off in 0..cfg.seq_words_per_tile as u32 {
                let at = m.map(base + off);
                assert_eq!(cfg.tile_of_bank(at.bank as usize), tile);
                assert!((at.row as usize) < cfg.seq_rows_per_bank());
            }
        }
    }

    #[test]
    fn interleaved_region_spreads_across_all_banks() {
        let m = map();
        let base = m.interleaved_base();
        // 4096 consecutive words hit 4096 distinct banks.
        let mut seen = vec![false; 4096];
        for i in 0..4096 {
            let at = m.map(base + i);
            assert!(!seen[at.bank as usize]);
            seen[at.bank as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn subgroup_split_matches_paper() {
        // 256 banks per SubGroup → contiguous 256-word runs per SubGroup,
        // cycling through all 16 SubGroups every 4096 words (Sec. 5.4).
        let cfg = ClusterConfig::terapool(9);
        let m = map();
        let base = m.interleaved_base();
        let bps = cfg.banks_per_subgroup();
        assert_eq!(bps, 256);
        for run in 0..16u32 {
            for w in 0..256u32 {
                assert_eq!(
                    m.subgroup_of_interleaved(base + run * 256 + w, bps),
                    run as usize
                );
            }
        }
    }

    #[test]
    fn l1_read_write_roundtrip() {
        let cfg = ClusterConfig::tiny();
        let mut l1 = L1Memory::new(&cfg);
        let base = l1.map.interleaved_base();
        let data: Vec<f32> = (0..500).map(|i| i as f32 * 0.5).collect();
        l1.write_slice(base, &data);
        assert_eq!(l1.read_slice(base, 500), data);
    }

    #[test]
    fn amo_add_accumulates() {
        let cfg = ClusterConfig::tiny();
        let mut l1 = L1Memory::new(&cfg);
        let at = l1.map.map(l1.map.interleaved_base());
        assert_eq!(l1.amo_add_bank(at, 2.5), 2.5);
        assert_eq!(l1.amo_add_bank(at, 1.5), 4.0);
        assert_eq!(l1.read_bank(at), 4.0);
    }

    #[test]
    fn shared_writes_land_in_tile_slices() {
        let cfg = ClusterConfig::tiny();
        let l1 = L1Memory::new(&cfg);
        // Every 128th interleaved word lands in the same bank of tile 0.
        let base = l1.map.interleaved_base();
        l1.write_shared(base, 3.25);
        l1.write_shared(base + cfg.num_banks() as u32, 4.5);
        assert_eq!(l1.read(base), 3.25);
        assert_eq!(l1.read(base + cfg.num_banks() as u32), 4.5);
        // The bank-level view agrees with the word-level view.
        let at = l1.map.map(base);
        assert_eq!(l1.read_bank(at), 3.25);
        let (t, b) = l1.locate(at);
        assert_eq!(t, 0, "first interleaved word lives in tile 0");
        assert_eq!(
            l1.tile_store(t).lock().unwrap().read(b, at.row as usize),
            3.25
        );
    }

    /// The range-restricted run writer must tile the whole-run writer:
    /// applying a run through every worker's disjoint Tile range (with
    /// foreign runs skipped in single jumps) reproduces
    /// `write_run_shared` exactly — at offsets that start mid-Tile-run
    /// and lengths that wrap the bank space multiple times.
    #[test]
    fn run_range_partitions_reproduce_whole_run() {
        let cfg = ClusterConfig::tiny();
        let num_tiles = cfg.num_tiles();
        let nb = cfg.num_banks() as u32;
        let interleaved = L1Memory::new(&cfg).map.interleaved_base();
        // Misaligned starts: mid-Tile-run (+5) and near the bank wrap
        // (+nb-3), with lengths spanning several wraps.
        for (off, len) in [(5u32, 300usize), (nb - 3, 2 * nb as usize + 17), (0, 64)] {
            let base = interleaved + 7 * nb + off;
            let data: Vec<f32> = (0..len).map(|i| i as f32 * 0.25 + 1.0).collect();

            let whole = L1Memory::new(&cfg);
            whole.write_run_shared(base, &data);

            for workers in [1usize, 2, 3] {
                let split = L1Memory::new(&cfg);
                let tpw = num_tiles.div_ceil(workers);
                for w in 0..workers {
                    let (lo, hi) =
                        ((w * tpw).min(num_tiles), ((w + 1) * tpw).min(num_tiles));
                    split.write_run_range(base, &data, lo, hi);
                }
                assert_eq!(
                    split.read_slice(base, data.len()),
                    whole.read_slice(base, data.len()),
                    "{workers}-way split write diverges (off {off}, len {len})"
                );
            }
        }
    }

    /// Property: the hybrid map is a bijection over the full address
    /// space (randomized pairs + in-range checks; offline stand-in for
    /// proptest, see rust/src/rng.rs).
    #[test]
    fn map_is_injective_property() {
        let cfg = ClusterConfig::terapool(9);
        let m = map();
        let mut rng = crate::rng::Rng::seed_from_u64(0xB17);
        for _ in 0..20_000 {
            let a = rng.gen_range(1 << 20) as u32;
            let b = rng.gen_range(1 << 20) as u32;
            let (ma, mb) = (m.map(a), m.map(b));
            assert!((ma.bank as usize) < cfg.num_banks());
            assert!((ma.row as usize) < cfg.words_per_bank);
            if a != b {
                assert_ne!(ma, mb, "collision: {a} and {b} -> {ma:?}");
            }
        }
    }

    #[test]
    fn unmap_inverts_map_on_both_regions() {
        for cfg in [ClusterConfig::tiny(), ClusterConfig::terapool(9)] {
            let m = AddressMap::new(&cfg);
            let probes = [
                0u32,
                1,
                m.interleaved_base() - 1,
                m.interleaved_base(),
                m.interleaved_base() + 4097,
                cfg.l1_words() as u32 - 1,
            ];
            for w in probes {
                assert_eq!(m.unmap(m.map(w)), w, "{}: word {w}", cfg.name);
            }
        }
    }

    /// Burst runs partition the word range, stay within one Tile, and
    /// cover consecutive banks at one row — over both regions and at
    /// every boundary a burst can straddle.
    #[test]
    fn map_burst_runs_partition_and_stay_in_tile() {
        for cfg in [ClusterConfig::tiny(), ClusterConfig::terapool(9)] {
            let m = AddressMap::new(&cfg);
            let bpt = cfg.banks_per_tile();
            let nb = cfg.num_banks() as u32;
            let probes = [
                m.interleaved_base(),                    // aligned interleaved
                m.interleaved_base() + bpt as u32 - 2,   // straddles a Tile boundary
                m.interleaved_base() + nb - 2,           // straddles the bank-space wrap
                0,                                       // sequential region
                cfg.seq_words_per_tile as u32 - 2,       // seq Tile boundary
            ];
            for base in probes {
                for n in 1..=4u8 {
                    let mut covered = Vec::new();
                    m.map_burst(base, n, |run, len| {
                        let tile = run.bank as usize / bpt;
                        for k in 0..len as u32 {
                            let at = BankAddr { bank: run.bank + k, row: run.row };
                            assert_eq!(at.bank as usize / bpt, tile, "run leaves its Tile");
                            covered.push(at);
                        }
                    });
                    let want: Vec<BankAddr> =
                        (0..n as u32).map(|k| m.map(base + k)).collect();
                    assert_eq!(covered, want, "{}: base {base} n {n}", cfg.name);
                }
            }
        }
    }

    /// Exhaustive bijection over a tiny config's whole space.
    #[test]
    fn map_is_bijective_exhaustive_tiny() {
        let cfg = ClusterConfig::tiny();
        let m = AddressMap::new(&cfg);
        let mut seen = vec![false; cfg.l1_words()];
        for w in 0..cfg.l1_words() as u32 {
            let at = m.map(w);
            let flat = at.bank as usize * cfg.words_per_bank + at.row as usize;
            assert!(!seen[flat], "word {w} collides");
            seen[flat] = true;
        }
        assert!(seen.iter().all(|&s| s), "map must be onto");
    }
}
