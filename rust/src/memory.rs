//! Shared-L1 SPM: banked storage plus the paper's **hybrid address
//! mapping scheme** (Sec. 5.4, Fig. 8a).
//!
//! The word-addressed L1 space is split into:
//!
//! * a **sequential region** (first `seq_words_per_tile × num_tiles`
//!   words): Tile-private ranges for stacks/private data; requests stay in
//!   the issuing PE's Tile. Within a Tile the words interleave over the
//!   Tile's banks.
//! * an **interleaved region** (the rest): word-level interleaving across
//!   *all* banks, distributing data evenly and minimizing conflicts.
//!
//! The map is pure address scrambling (the paper: "wire crossings and a
//! multiplexer"), so it is a bijection — property-tested below.

use crate::config::ClusterConfig;

/// Physical location of a word: bank index and row within the bank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BankAddr {
    pub bank: u32,
    pub row: u32,
}

/// Address map resolved from a [`ClusterConfig`].
#[derive(Debug, Clone)]
pub struct AddressMap {
    num_banks: usize,
    banks_per_tile: usize,
    seq_words_per_tile: usize,
    seq_rows_per_bank: usize,
    seq_words_total: usize,
    words_per_bank: usize,
    /// log2(num_banks) when it is a power of two (§Perf: the interleaved
    /// mapping is on the per-request hot path; all paper configurations
    /// have power-of-two bank counts, so the div/mod reduce to shifts).
    nb_shift: Option<u32>,
}

impl AddressMap {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let nb = cfg.num_banks();
        let m = AddressMap {
            num_banks: nb,
            banks_per_tile: cfg.banks_per_tile(),
            seq_words_per_tile: cfg.seq_words_per_tile,
            seq_rows_per_bank: cfg.seq_rows_per_bank(),
            seq_words_total: cfg.seq_words_total(),
            words_per_bank: cfg.words_per_bank,
            nb_shift: if nb.is_power_of_two() { Some(nb.trailing_zeros()) } else { None },
        };
        assert!(
            m.seq_rows_per_bank < m.words_per_bank,
            "sequential region must leave interleaved rows"
        );
        assert_eq!(
            m.seq_words_per_tile % m.banks_per_tile,
            0,
            "seq region must fill whole bank rows per tile"
        );
        m
    }

    /// Total words in L1.
    pub fn l1_words(&self) -> usize {
        self.num_banks * self.words_per_bank
    }

    /// First word of the interleaved region.
    pub fn interleaved_base(&self) -> u32 {
        self.seq_words_total as u32
    }

    /// First sequential-region word of a Tile (its private range).
    pub fn seq_base_of_tile(&self, tile: usize) -> u32 {
        (tile * self.seq_words_per_tile) as u32
    }

    /// Map a word address to its bank and row.
    pub fn map(&self, word: u32) -> BankAddr {
        let w = word as usize;
        if w < self.seq_words_total {
            // Sequential region: per-Tile private, interleaved over the
            // Tile's own banks only.
            let tile = w / self.seq_words_per_tile;
            let off = w % self.seq_words_per_tile;
            let bank = tile * self.banks_per_tile + off % self.banks_per_tile;
            let row = off / self.banks_per_tile;
            BankAddr { bank: bank as u32, row: row as u32 }
        } else {
            // Interleaved region: word-level across all banks, rows above
            // the reserved sequential rows.
            let off = w - self.seq_words_total;
            let (bank, quot) = match self.nb_shift {
                Some(sh) => (off & (self.num_banks - 1), off >> sh),
                None => (off % self.num_banks, off / self.num_banks),
            };
            let row = self.seq_rows_per_bank + quot;
            assert!(
                row < self.words_per_bank,
                "word address {word} beyond L1 capacity"
            );
            BankAddr { bank: bank as u32, row: row as u32 }
        }
    }

    /// Inverse of [`AddressMap::map`]: the word address stored at a bank
    /// location. Together with `map` this witnesses that the hybrid
    /// scheme is a **bijection** between the word-address space and the
    /// bank×row space (the paper's "wire crossings and a multiplexer"
    /// claim, Sec. 5.4) — property-tested over randomized bank/tile
    /// counts in rust/tests/properties.rs.
    pub fn unmap(&self, at: BankAddr) -> u32 {
        let bank = at.bank as usize;
        let row = at.row as usize;
        debug_assert!(bank < self.num_banks && row < self.words_per_bank);
        if row < self.seq_rows_per_bank {
            // Sequential region: the Tile owning the bank, row-major
            // within the Tile's private range.
            let tile = bank / self.banks_per_tile;
            let off = row * self.banks_per_tile + bank % self.banks_per_tile;
            (tile * self.seq_words_per_tile + off) as u32
        } else {
            let off = (row - self.seq_rows_per_bank) * self.num_banks + bank;
            (self.seq_words_total + off) as u32
        }
    }

    /// SubGroup that owns an interleaved-region word (for the iDMA midend
    /// split, Sec. 5.4: 256 banks per SubGroup, one word per bank-row →
    /// contiguous 256-word runs alternate SubGroups).
    pub fn subgroup_of_interleaved(&self, word: u32, banks_per_subgroup: usize) -> usize {
        let off = word as usize - self.seq_words_total;
        (off % self.num_banks) / banks_per_subgroup
    }
}

/// The banked L1 storage: `num_banks` arrays of f32 words. Functional
/// state only — timing (ports, conflicts) is owned by the interconnect.
#[derive(Debug, Clone)]
pub struct L1Memory {
    pub map: AddressMap,
    banks: Vec<Vec<f32>>,
}

impl L1Memory {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let map = AddressMap::new(cfg);
        L1Memory {
            banks: vec![vec![0.0; cfg.words_per_bank]; cfg.num_banks()],
            map,
        }
    }

    pub fn read_bank(&self, at: BankAddr) -> f32 {
        self.banks[at.bank as usize][at.row as usize]
    }
    pub fn write_bank(&mut self, at: BankAddr, v: f32) {
        self.banks[at.bank as usize][at.row as usize] = v;
    }
    /// Atomic fetch-and-add at the bank (returns the *new* value).
    pub fn amo_add_bank(&mut self, at: BankAddr, v: f32) -> f32 {
        let slot = &mut self.banks[at.bank as usize][at.row as usize];
        *slot += v;
        *slot
    }

    /// Word-addressed accessors (host/DMA side).
    pub fn read(&self, word: u32) -> f32 {
        self.read_bank(self.map.map(word))
    }
    pub fn write(&mut self, word: u32, v: f32) {
        self.write_bank(self.map.map(word), v)
    }

    /// Bulk host-side copy-in/out, used by test harnesses and the DMA
    /// backends' functional data movement.
    pub fn write_slice(&mut self, base: u32, data: &[f32]) {
        for (i, &v) in data.iter().enumerate() {
            self.write(base + i as u32, v);
        }
    }
    pub fn read_slice(&self, base: u32, n: usize) -> Vec<f32> {
        (0..n).map(|i| self.read(base + i as u32)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn map() -> AddressMap {
        AddressMap::new(&ClusterConfig::terapool(9))
    }

    #[test]
    fn sequential_region_stays_in_tile() {
        let cfg = ClusterConfig::terapool(9);
        let m = map();
        for tile in [0usize, 1, 63, 127] {
            let base = m.seq_base_of_tile(tile);
            for off in 0..cfg.seq_words_per_tile as u32 {
                let at = m.map(base + off);
                assert_eq!(cfg.tile_of_bank(at.bank as usize), tile);
                assert!((at.row as usize) < cfg.seq_rows_per_bank());
            }
        }
    }

    #[test]
    fn interleaved_region_spreads_across_all_banks() {
        let m = map();
        let base = m.interleaved_base();
        // 4096 consecutive words hit 4096 distinct banks.
        let mut seen = vec![false; 4096];
        for i in 0..4096 {
            let at = m.map(base + i);
            assert!(!seen[at.bank as usize]);
            seen[at.bank as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn subgroup_split_matches_paper() {
        // 256 banks per SubGroup → contiguous 256-word runs per SubGroup,
        // cycling through all 16 SubGroups every 4096 words (Sec. 5.4).
        let cfg = ClusterConfig::terapool(9);
        let m = map();
        let base = m.interleaved_base();
        let bps = cfg.banks_per_subgroup();
        assert_eq!(bps, 256);
        for run in 0..16u32 {
            for w in 0..256u32 {
                assert_eq!(
                    m.subgroup_of_interleaved(base + run * 256 + w, bps),
                    run as usize
                );
            }
        }
    }

    #[test]
    fn l1_read_write_roundtrip() {
        let cfg = ClusterConfig::tiny();
        let mut l1 = L1Memory::new(&cfg);
        let base = l1.map.interleaved_base();
        let data: Vec<f32> = (0..500).map(|i| i as f32 * 0.5).collect();
        l1.write_slice(base, &data);
        assert_eq!(l1.read_slice(base, 500), data);
    }

    #[test]
    fn amo_add_accumulates() {
        let cfg = ClusterConfig::tiny();
        let mut l1 = L1Memory::new(&cfg);
        let at = l1.map.map(l1.map.interleaved_base());
        assert_eq!(l1.amo_add_bank(at, 2.5), 2.5);
        assert_eq!(l1.amo_add_bank(at, 1.5), 4.0);
        assert_eq!(l1.read_bank(at), 4.0);
    }

    /// Property: the hybrid map is a bijection over the full address
    /// space (randomized pairs + in-range checks; offline stand-in for
    /// proptest, see rust/src/rng.rs).
    #[test]
    fn map_is_injective_property() {
        let cfg = ClusterConfig::terapool(9);
        let m = map();
        let mut rng = crate::rng::Rng::seed_from_u64(0xB17);
        for _ in 0..20_000 {
            let a = rng.gen_range(1 << 20) as u32;
            let b = rng.gen_range(1 << 20) as u32;
            let (ma, mb) = (m.map(a), m.map(b));
            assert!((ma.bank as usize) < cfg.num_banks());
            assert!((ma.row as usize) < cfg.words_per_bank);
            if a != b {
                assert_ne!(ma, mb, "collision: {a} and {b} -> {ma:?}");
            }
        }
    }

    #[test]
    fn unmap_inverts_map_on_both_regions() {
        for cfg in [ClusterConfig::tiny(), ClusterConfig::terapool(9)] {
            let m = AddressMap::new(&cfg);
            let probes = [
                0u32,
                1,
                m.interleaved_base() - 1,
                m.interleaved_base(),
                m.interleaved_base() + 4097,
                cfg.l1_words() as u32 - 1,
            ];
            for w in probes {
                assert_eq!(m.unmap(m.map(w)), w, "{}: word {w}", cfg.name);
            }
        }
    }

    /// Exhaustive bijection over a tiny config's whole space.
    #[test]
    fn map_is_bijective_exhaustive_tiny() {
        let cfg = ClusterConfig::tiny();
        let m = AddressMap::new(&cfg);
        let mut seen = vec![false; cfg.l1_words()];
        for w in 0..cfg.l1_words() as u32 {
            let at = m.map(w);
            let flat = at.bank as usize * cfg.words_per_bank + at.row as usize;
            assert!(!seen[flat], "word {w} collides");
            seen[flat] = true;
        }
        assert!(seen.iter().all(|&s| s), "map must be onto");
    }
}
