//! Scale-up analysis of Sec. 2: arithmetic-intensity growth under cluster
//! scaling (Eq. 1) and Kung's balance condition (Eq. 2), plus the
//! tiling-driven main-memory traffic model behind **Table 6**.

/// Arithmetic intensity of an m×m MatMul tile: AI = m³ / 3m² = m/3, with
/// W = 3m² words resident (Eq. 1's example).
pub fn matmul_ai(w_words: f64) -> f64 {
    (w_words / 3.0).sqrt() / 3.0f64.sqrt()
}

/// Eq. (1): scaling the cluster by S scales W linearly and AI by √S.
pub fn scaled_ai(w_words: f64, s: f64) -> f64 {
    matmul_ai(s * w_words)
}

/// Eq. (2): Kung's balance — the cluster is *not* main-memory bound when
/// `L + W/BW < (AI·W) / (N_pes·U)` (left: transfer time, right: compute
/// time per tile).
#[derive(Debug, Clone, Copy)]
pub struct BalanceInput {
    /// Main-memory latency (cycles).
    pub l: f64,
    /// Problem tile size in L1 (words).
    pub w: f64,
    /// Cluster↔main-memory bandwidth (words/cycle).
    pub bw: f64,
    /// Arithmetic intensity (ops/word).
    pub ai: f64,
    pub n_pes: f64,
    /// Per-PE utilization.
    pub u: f64,
}

pub fn transfer_cycles(b: &BalanceInput) -> f64 {
    b.l + b.w / b.bw
}

pub fn compute_cycles(b: &BalanceInput) -> f64 {
    b.ai * b.w / (b.n_pes * b.u)
}

pub fn is_balanced(b: &BalanceInput) -> bool {
    transfer_cycles(b) < compute_cycles(b)
}

/// Scale a balance point by S: W and BW and N_pes scale linearly, AI by
/// √S, L and U constant (the Sec. 2.1 argument).
pub fn scale(b: &BalanceInput, s: f64) -> BalanceInput {
    BalanceInput {
        l: b.l,
        w: b.w * s,
        bw: b.bw * s,
        ai: b.ai * s.sqrt(),
        n_pes: b.n_pes * s,
        u: b.u,
    }
}

// -------------------------------------------------------------------
// Table 6: main-memory Byte/FLOP of tiled GEMM vs cluster L1 capacity
// -------------------------------------------------------------------

/// Largest square double-buffered GEMM tile edge fitting an L1 of
/// `l1_bytes`: 3 operands × 2 buffers × m² × 4 B ≤ capacity.
pub fn max_tile_edge(l1_bytes: usize) -> usize {
    ((l1_bytes as f64 / (3.0 * 2.0 * 4.0)).sqrt()) as usize
}

/// Main-memory Byte/FLOP of output-stationary tiled GEMM with tile edge
/// m: each output tile loads an m×K panel of A and K×m of B →
/// 2·4·m·K bytes for 2·m²·K FLOP = 4/m.
pub fn gemm_bytes_per_flop(tile_edge: usize) -> f64 {
    4.0 / tile_edge as f64
}

/// AXPY moves 3 words (2 in, 1 out) per 2 FLOP regardless of tiling.
pub fn axpy_bytes_per_flop() -> f64 {
    3.0 * 4.0 / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ai_grows_with_sqrt_s() {
        let w = 3.0 * 512.0 * 512.0;
        let base = matmul_ai(w);
        for s in [2.0, 4.0, 16.0] {
            let got = scaled_ai(w, s);
            assert!((got / base - s.sqrt()).abs() < 1e-9, "s={s}");
        }
    }

    #[test]
    fn scaling_preserves_transfer_and_grows_compute_margin() {
        // The Sec. 2.1 claim: as S grows the inequality holds for larger L
        // and smaller BW.
        let b = BalanceInput {
            l: 500.0,
            w: 3.0 * 128.0 * 128.0,
            bw: 256.0,
            ai: matmul_ai(3.0 * 128.0 * 128.0),
            n_pes: 64.0,
            u: 0.8,
        };
        let b4 = scale(&b, 4.0);
        let b16 = scale(&b, 16.0);
        // W/BW ratio unchanged; compute side grows by √S.
        assert!((b.w / b.bw - b16.w / b16.bw).abs() < 1e-9);
        let margin = |x: &BalanceInput| compute_cycles(x) - transfer_cycles(x);
        assert!(margin(&b4) > margin(&b));
        assert!(margin(&b16) > margin(&b4));
    }

    #[test]
    fn table6_byte_per_flop_ordering() {
        // TeraPool (4 MiB) ≪ MemPool (1 MiB) ≪ Occamy (128 KiB).
        let tp = gemm_bytes_per_flop(max_tile_edge(4 * 1024 * 1024));
        let mp = gemm_bytes_per_flop(max_tile_edge(1024 * 1024));
        let oc = gemm_bytes_per_flop(max_tile_edge(128 * 1024));
        assert!(tp < mp && mp < oc);
        // Paper Table 6: 0.009 / 0.016 / 0.062 — same decade & ordering,
        // ratios ≈ 1 : 2 : ~6–7.
        assert!((tp - 0.009).abs() < 0.003, "terapool {tp}");
        assert!((mp - 0.016).abs() < 0.006, "mempool {mp}");
        assert!((oc - 0.062).abs() < 0.02, "occamy {oc}");
    }

    #[test]
    fn axpy_byte_per_flop_constant() {
        assert_eq!(axpy_bytes_per_flop(), 6.0);
    }

    #[test]
    fn bigger_cluster_tolerates_higher_latency() {
        // Find the max L each scale tolerates; it must grow with S.
        let base = BalanceInput {
            l: 0.0,
            w: 3.0 * 256.0 * 256.0,
            bw: 512.0,
            ai: matmul_ai(3.0 * 256.0 * 256.0),
            n_pes: 256.0,
            u: 0.8,
        };
        let max_l = |b: &BalanceInput| compute_cycles(b) - b.w / b.bw;
        let l1 = max_l(&base);
        let l4 = max_l(&scale(&base, 4.0));
        assert!(l4 > 2.0 * l1, "L tolerance should grow ~√S·: {l1} {l4}");
    }
}
