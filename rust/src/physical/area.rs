//! Gate-equivalent area model — regenerates **Fig. 12** (hierarchical
//! area breakdown) and the Sec. 6.1 floorplan figures of merit.
//!
//! Unit costs are calibrated so the full TeraPool cluster reproduces the
//! paper's breakdown: SPM banks largest, Snitch core-complexes split
//! 7.3 % cores / 9.1 % IPUs / 22 % FP-SSs of cluster area, shared
//! instruction caches next, hierarchical interconnect only 8.5 % and
//! HBML 9.2 %.

use crate::amat::HierSpec;
use crate::config::ClusterConfig;

/// Calibrated unit areas (GE).
pub mod units {
    /// SRAM bit (high-density macro, incl. periphery amortized).
    pub const SPM_GE_PER_BIT: f64 = 0.52;
    /// Snitch integer pipeline (single-stage RV32IMA).
    pub const CORE_GE: f64 = 3_560.0;
    /// Integer processing unit with the Xpulpimg extension.
    pub const IPU_GE: f64 = 4_440.0;
    /// FP subsystem (zfinx/zhinx/smallfloat, SIMD f16).
    pub const FPSS_GE: f64 = 10_730.0;
    /// Shared FP divide/sqrt unit (2 per Tile).
    pub const DIVSQRT_GE: f64 = 8_000.0;
    /// Shared 4 KiB 2-way L1 I$ per Tile + per-core L0 (32 entries).
    pub const ICACHE_TILE_GE: f64 = 26_000.0;
    pub const L0_ICACHE_GE: f64 = 1_200.0;
    /// Hierarchical interconnect, per crossbar leaf node (routing +
    /// arbitration + spill registers amortized).
    pub const XBAR_GE_PER_LEAF: f64 = 47.0;
    /// HBML: per-Tile AXI plumbing + per-SubGroup DMA backend + frontend.
    pub const AXI_TILE_GE: f64 = 24_000.0;
    pub const DMA_BACKEND_GE: f64 = 65_000.0;
    pub const DMA_FRONTEND_GE: f64 = 30_000.0;
}

/// Area breakdown in GE.
#[derive(Debug, Clone, Copy)]
pub struct AreaBreakdown {
    pub spm: f64,
    pub cores: f64,
    pub ipus: f64,
    pub fpss: f64,
    pub divsqrt: f64,
    pub icache: f64,
    pub interconnect: f64,
    pub hbml: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.spm
            + self.cores
            + self.ipus
            + self.fpss
            + self.divsqrt
            + self.icache
            + self.interconnect
            + self.hbml
    }
    /// Core-complex total (cores + IPUs + FP-SSs), as Fig. 12 groups it.
    pub fn cc(&self) -> f64 {
        self.cores + self.ipus + self.fpss
    }
}

/// Compute the breakdown for a cluster configuration.
pub fn breakdown(cfg: &ClusterConfig) -> AreaBreakdown {
    use units::*;
    let pes = cfg.num_pes() as f64;
    let tiles = cfg.num_tiles() as f64;
    let sgs = cfg.hierarchy.num_subgroups() as f64;
    let spec = HierSpec {
        alpha: cfg.hierarchy.pes_per_tile,
        beta: cfg.hierarchy.tiles_per_subgroup,
        gamma: cfg.hierarchy.subgroups_per_group,
        delta: cfg.hierarchy.groups,
        banking: cfg.banking_factor,
    };
    AreaBreakdown {
        spm: cfg.l1_bytes() as f64 * 8.0 * SPM_GE_PER_BIT,
        cores: pes * CORE_GE,
        ipus: pes * IPU_GE,
        fpss: pes * FPSS_GE,
        divsqrt: tiles * 2.0 * DIVSQRT_GE,
        icache: tiles * ICACHE_TILE_GE + pes * L0_ICACHE_GE,
        interconnect: spec.total_complexity() as f64 * XBAR_GE_PER_LEAF,
        hbml: tiles * AXI_TILE_GE + sgs * DMA_BACKEND_GE + DMA_FRONTEND_GE,
    }
}

/// Floorplan figures of merit (Sec. 6.1).
#[derive(Debug, Clone, Copy)]
pub struct Floorplan {
    /// Die area (mm²).
    pub die_mm2: f64,
    /// mm² per core including top-level routing channels.
    pub mm2_per_core: f64,
    /// mm² per core inside a SubGroup block.
    pub mm2_per_core_block: f64,
    /// Fraction of the die spent on routing channels.
    pub channel_fraction: f64,
}

/// The paper's GF12 floorplan numbers for TeraPool.
pub fn terapool_floorplan() -> Floorplan {
    Floorplan {
        die_mm2: 81.8,
        mm2_per_core: 0.079,
        mm2_per_core_block: 0.047,
        channel_fraction: 1.0 - 0.047 / 0.079, // ≈ 40 % (Sec. 9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_fractions_reproduce() {
        let b = breakdown(&ClusterConfig::terapool(9));
        let t = b.total();
        let frac = |x: f64| 100.0 * x / t;
        // Paper Fig. 12 anchor percentages (± small tolerance).
        assert!((frac(b.cores) - 7.3).abs() < 1.0, "cores {}", frac(b.cores));
        assert!((frac(b.ipus) - 9.1).abs() < 1.0, "ipus {}", frac(b.ipus));
        assert!((frac(b.fpss) - 22.0).abs() < 2.0, "fpss {}", frac(b.fpss));
        assert!((frac(b.interconnect) - 8.5).abs() < 1.5, "icn {}", frac(b.interconnect));
        assert!((frac(b.hbml) - 9.2).abs() < 2.0, "hbml {}", frac(b.hbml));
        // SPM is the single largest component.
        assert!(b.spm > b.fpss && b.spm > b.icache && b.spm > b.interconnect);
    }

    #[test]
    fn interconnect_and_hbml_are_minor() {
        // The headline claim: scale-up does NOT drown in interconnect.
        let b = breakdown(&ClusterConfig::terapool(9));
        assert!(b.interconnect / b.total() < 0.10);
        assert!(b.hbml / b.total() < 0.11);
    }

    #[test]
    fn smaller_cluster_has_smaller_area() {
        let tp = breakdown(&ClusterConfig::terapool(9)).total();
        let mp = breakdown(&ClusterConfig::mempool()).total();
        assert!(mp < tp / 2.0);
    }

    #[test]
    fn floorplan_channel_overhead_matches_sec9() {
        let f = terapool_floorplan();
        assert!((f.channel_fraction - 0.40).abs() < 0.02);
        assert!((f.mm2_per_core * 1024.0 - f.die_mm2).abs() < 1.0);
    }
}
