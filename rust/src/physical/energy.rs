//! Per-instruction energy model — regenerates **Fig. 13** (instruction
//! energy breakdown across the 7/9/11-cycle configurations, with EDP
//! markers) and powers the GFLOP/s/W headline when integrated over
//! simulated kernel activity.
//!
//! Anchors from the paper (TT/0.80 V/25 °C): interconnect 2.5–6.8 pJ and
//! SPM 1.06 pJ dominate loads (up to 51 %); a local-Tile `ld` grows
//! +10 / +20 / +58 % toward SubGroup/Group/remote-Group; `fmadd.s` costs
//! 12.19 pJ with compute units at 72.3 % share; rising frequency adds
//! low-Vt optimization-cell energy (≈ +16 % from 730 to 910 MHz).

use crate::cluster::RunStats;
use crate::config::ClusterConfig;
use crate::interconnect::NumaClass;

/// Energy components of one instruction (pJ).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyParts {
    pub core: f64,
    pub compute_unit: f64,
    pub interconnect: f64,
    pub spm: f64,
    /// Low-Vt optimization cells added by physical design.
    pub opt_cells: f64,
}

impl EnergyParts {
    pub fn total(&self) -> f64 {
        self.core + self.compute_unit + self.interconnect + self.spm + self.opt_cells
    }
}

/// Instruction kinds shown in Fig. 13.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    LdLocal,
    LdSubGroup,
    LdGroup,
    LdRemoteGroup,
    IntMac,
    FaddH,
    FmulH,
    FmaddH,
    FaddS,
    FmulS,
    FmaddS,
    DivSqrt,
}

pub const FIG13_INSTRS: [Instr; 12] = [
    Instr::LdLocal,
    Instr::LdSubGroup,
    Instr::LdGroup,
    Instr::LdRemoteGroup,
    Instr::IntMac,
    Instr::FaddH,
    Instr::FmulH,
    Instr::FmaddH,
    Instr::FaddS,
    Instr::FmulS,
    Instr::FmaddS,
    Instr::DivSqrt,
];

impl Instr {
    pub fn name(&self) -> &'static str {
        match self {
            Instr::LdLocal => "ld (local Tile)",
            Instr::LdSubGroup => "ld (SubGroup)",
            Instr::LdGroup => "ld (Group)",
            Instr::LdRemoteGroup => "ld (remote Group)",
            Instr::IntMac => "mac (int32)",
            Instr::FaddH => "fadd.h",
            Instr::FmulH => "fmul.h",
            Instr::FmaddH => "fmadd.h",
            Instr::FaddS => "fadd.s",
            Instr::FmulS => "fmul.s",
            Instr::FmaddS => "fmadd.s",
            Instr::DivSqrt => "div/sqrt",
        }
    }
}

/// Energy model for one operating point.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Remote-group latency config (7/9/11) — selects the frequency.
    pub rg_latency: u32,
    pub freq_mhz: f64,
    /// Multiplier on the optimization-cell component (grows with the
    /// frequency push: 730 → 910 MHz adds ≈ 16 % total).
    opt_scale: f64,
}

impl EnergyModel {
    pub fn for_config(rg_latency: u32) -> Self {
        let (freq, opt) = match rg_latency {
            7 => (730.0, 0.55),
            9 => (850.0, 1.0),
            11 => (910.0, 1.9),
            l => panic!("no operating point for remote-group latency {l}"),
        };
        EnergyModel { rg_latency, freq_mhz: freq, opt_scale: opt }
    }

    pub fn for_cluster(cfg: &ClusterConfig) -> Self {
        Self::for_config(cfg.latency.remote_group)
    }

    /// Per-instruction energy breakdown (pJ/instruction/core).
    pub fn parts(&self, i: Instr) -> EnergyParts {
        // Baseline (850 MHz) components; opt cells scale with frequency.
        let base = match i {
            // Loads: core front end + interconnect distance + SPM bank.
            Instr::LdLocal => EnergyParts { core: 3.3, compute_unit: 0.0, interconnect: 2.5, spm: 1.06, opt_cells: 0.9 },
            Instr::LdSubGroup => EnergyParts { core: 3.3, compute_unit: 0.0, interconnect: 3.3, spm: 1.06, opt_cells: 1.0 },
            Instr::LdGroup => EnergyParts { core: 3.3, compute_unit: 0.0, interconnect: 4.1, spm: 1.06, opt_cells: 1.1 },
            Instr::LdRemoteGroup => EnergyParts { core: 3.3, compute_unit: 0.0, interconnect: 6.8, spm: 1.06, opt_cells: 1.4 },
            // Integer MAC (Xpulpimg).
            Instr::IntMac => EnergyParts { core: 2.4, compute_unit: 6.6, interconnect: 0.0, spm: 0.05, opt_cells: 0.9 },
            // Half precision (zhinx SIMD ×2 ops/instr).
            Instr::FaddH => EnergyParts { core: 2.1, compute_unit: 3.1, interconnect: 0.0, spm: 0.05, opt_cells: 0.6 },
            Instr::FmulH => EnergyParts { core: 2.1, compute_unit: 3.8, interconnect: 0.0, spm: 0.05, opt_cells: 0.7 },
            Instr::FmaddH => EnergyParts { core: 2.1, compute_unit: 4.9, interconnect: 0.0, spm: 0.05, opt_cells: 0.8 },
            // Single precision.
            Instr::FaddS => EnergyParts { core: 2.4, compute_unit: 7.9, interconnect: 0.0, spm: 0.05, opt_cells: 1.0 },
            Instr::FmulS => EnergyParts { core: 2.4, compute_unit: 8.0, interconnect: 0.0, spm: 0.05, opt_cells: 1.0 },
            Instr::FmaddS => EnergyParts { core: 2.4, compute_unit: 8.6, interconnect: 0.0, spm: 0.05, opt_cells: 1.1 },
            Instr::DivSqrt => EnergyParts { core: 2.4, compute_unit: 11.5, interconnect: 0.0, spm: 0.05, opt_cells: 1.2 },
        };
        EnergyParts { opt_cells: base.opt_cells * self.opt_scale, ..base }
    }

    /// Total pJ for an instruction.
    pub fn pj(&self, i: Instr) -> f64 {
        self.parts(i).total()
    }

    /// Energy-delay product (pJ·ns) at this operating point.
    pub fn edp(&self, i: Instr) -> f64 {
        self.pj(i) * 1000.0 / self.freq_mhz
    }

    /// Load energy by NUMA class.
    pub fn ld_pj(&self, class: NumaClass) -> f64 {
        self.pj(match class {
            NumaClass::Local => Instr::LdLocal,
            NumaClass::SubGroup => Instr::LdSubGroup,
            NumaClass::Group => Instr::LdGroup,
            NumaClass::RemoteGroup => Instr::LdRemoteGroup,
        })
    }

    /// Integrate a kernel run into Joules: per-instruction energies plus
    /// the idle/clock baseline of stalled cycles.
    pub fn run_energy_j(&self, s: &RunStats) -> f64 {
        // Memory ops weighted by the observed NUMA mix.
        let total_reqs: u64 = s.reqs_per_class.iter().sum();
        let mem_pj: f64 = if total_reqs == 0 {
            0.0
        } else {
            let classes = [
                NumaClass::Local,
                NumaClass::SubGroup,
                NumaClass::Group,
                NumaClass::RemoteGroup,
            ];
            let mean: f64 = classes
                .iter()
                .enumerate()
                .map(|(i, &c)| self.ld_pj(c) * s.reqs_per_class[i] as f64)
                .sum::<f64>()
                / total_reqs as f64;
            mean * (s.loads + s.stores + s.atomics) as f64
        };
        let compute_instr =
            s.instructions - s.loads - s.stores - s.atomics;
        let compute_pj = compute_instr as f64 * self.pj(Instr::FmaddS) * 0.75;
        // Idle/stall cycles still burn clock-tree + leakage (the 14.5 %
        // "not accessed" share the paper quotes for the interconnect).
        let stall_cycles =
            (s.cycles * s.num_pes as u64).saturating_sub(s.instructions) as f64;
        let idle_pj = stall_cycles * 1.8;
        (mem_pj + compute_pj + idle_pj) * 1e-12
    }

    /// GFLOP/s/W for a kernel run: total FLOP divided by total Joules
    /// (equivalently GFLOP/s over Watts).
    pub fn gflops_per_watt(&self, s: &RunStats) -> f64 {
        let joules = self.run_energy_j(s);
        if joules == 0.0 {
            return 0.0;
        }
        s.flops as f64 / 1e9 / joules
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmadd_s_matches_paper() {
        let m = EnergyModel::for_config(9);
        assert!((m.pj(Instr::FmaddS) - 12.19).abs() < 0.15, "{}", m.pj(Instr::FmaddS));
    }

    #[test]
    fn ld_distance_scaling_matches_paper() {
        // +10 / +20 / +58 % vs local-Tile (Sec. 6.3).
        let m = EnergyModel::for_config(9);
        let local = m.pj(Instr::LdLocal);
        assert!((m.pj(Instr::LdSubGroup) / local - 1.10).abs() < 0.03);
        assert!((m.pj(Instr::LdGroup) / local - 1.20).abs() < 0.04);
        assert!((m.pj(Instr::LdRemoteGroup) / local - 1.58).abs() < 0.06);
    }

    #[test]
    fn ranges_match_fig13() {
        let m = EnergyModel::for_config(9);
        // Integer 6.4–13.5 pJ, fp16 5.2–7.9 pJ, fp32 11.3–12.2 pJ.
        assert!((6.4..=13.5).contains(&m.pj(Instr::IntMac)));
        for i in [Instr::FaddH, Instr::FmulH, Instr::FmaddH] {
            assert!((5.2..=7.9).contains(&m.pj(i)), "{:?} = {}", i, m.pj(i));
        }
        for i in [Instr::FaddS, Instr::FmulS, Instr::FmaddS] {
            assert!((11.0..=12.3).contains(&m.pj(i)), "{:?} = {}", i, m.pj(i));
        }
    }

    #[test]
    fn frequency_push_adds_energy() {
        // 730 → 910 MHz adds ≈ 16 % on average (Sec. 6.3).
        let lo = EnergyModel::for_config(7);
        let hi = EnergyModel::for_config(11);
        let ratio = hi.pj(Instr::LdRemoteGroup) / lo.pj(Instr::LdRemoteGroup);
        assert!((1.05..1.25).contains(&ratio), "ratio {ratio}");
        // Remote-group load rises ~1.6 pJ.
        let delta = hi.pj(Instr::LdRemoteGroup) - lo.pj(Instr::LdRemoteGroup);
        assert!((1.0..2.2).contains(&delta), "delta {delta}");
    }

    #[test]
    fn edp_optimum_is_the_850mhz_config() {
        // Fig. 13's red markers: the 9-cycle/850 MHz point minimizes EDP
        // for most operations.
        for i in [Instr::FmaddS, Instr::FmulS, Instr::IntMac, Instr::LdRemoteGroup] {
            let e7 = EnergyModel::for_config(7).edp(i);
            let e9 = EnergyModel::for_config(9).edp(i);
            let e11 = EnergyModel::for_config(11).edp(i);
            assert!(e9 <= e7 && e9 <= e11, "{:?}: {e7} {e9} {e11}", i);
        }
    }

    #[test]
    fn per_op_energy_stays_in_paper_envelope() {
        // "5–15 pJ/operation/core" (Sec. 6.3).
        let m = EnergyModel::for_config(9);
        for i in FIG13_INSTRS {
            let pj = m.pj(i);
            assert!((5.0..=15.5).contains(&pj), "{:?} = {pj}", i);
        }
    }
}
