//! State-of-the-art comparison — regenerates **Table 5**.
//!
//! Literature rows are constants from the paper's own survey; the
//! TeraPool row is *computed* from this reproduction's configuration so
//! any change to the model shows up here.

use crate::config::ClusterConfig;

/// One Table-5 row.
#[derive(Debug, Clone)]
pub struct SoaRow {
    pub name: &'static str,
    pub scaling: &'static str,
    pub pe: &'static str,
    pub execution: &'static str,
    pub pes_per_cluster: usize,
    pub total_pes: usize,
    pub shared_l1_mib: f64,
    /// L1 / L2 interconnect bandwidth (Byte/cycle/cluster).
    pub l1_bw: f64,
    pub l2_bw: Option<f64>,
    pub l1_latency: &'static str,
    /// Peak 32-bit (FL)OP/cycle/cluster (MAC = 2).
    pub peak_ops: f64,
    pub open_source: bool,
}

/// The computed TeraPool row.
pub fn terapool_row(cfg: &ClusterConfig) -> SoaRow {
    let pes = cfg.num_pes();
    SoaRow {
        name: "TeraPool (this work)",
        scaling: "Scaling-up (NUMA) Crossbar",
        pe: "32bit RISC-V",
        execution: "SPMD",
        pes_per_cluster: pes,
        total_pes: pes,
        shared_l1_mib: cfg.l1_bytes() as f64 / (1024.0 * 1024.0),
        // Full PE-side bandwidth: every PE can retire one 32-bit access
        // per cycle → 4 B × 1024 = 4 KiB/cycle; L2 side: 16 × 512-bit AXI.
        l1_bw: 4.0 * pes as f64,
        l2_bw: Some(16.0 * 64.0),
        l1_latency: "1-5 (9 remote)",
        peak_ops: 2.0 * pes as f64,
        open_source: true,
    }
}

/// Literature rows (Table 5 constants).
pub fn literature_rows() -> Vec<SoaRow> {
    vec![
        SoaRow { name: "Kalray MPPA3-80", scaling: "Scaling-out 2D-mesh NoC", pe: "64bit VLIW", execution: "SPMD/LWI", pes_per_cluster: 16, total_pes: 64, shared_l1_mib: 3.8, l1_bw: 23.0, l2_bw: Some(32.0), l1_latency: "N.A.", peak_ops: 64.0, open_source: false },
        SoaRow { name: "Ramon RC64", scaling: "Scaling-up Crossbar", pe: "32bit VLIW", execution: "MIMD", pes_per_cluster: 64, total_pes: 64, shared_l1_mib: 3.8, l1_bw: 128.0, l2_bw: None, l1_latency: "N.A.", peak_ops: 64.0, open_source: false },
        SoaRow { name: "TensTorrent Wormhole", scaling: "Scaling-out 2D-mesh NoC", pe: "32bit RISC-V", execution: "SIMD", pes_per_cluster: 5, total_pes: 400, shared_l1_mib: 1.43, l1_bw: 20.0, l2_bw: None, l1_latency: ">4", peak_ops: 20.0, open_source: false },
        SoaRow { name: "Esperanto ET-SoC-1", scaling: "Scaling-out 2D-mesh NoC", pe: "64bit RVV", execution: "SIMD", pes_per_cluster: 32, total_pes: 1088, shared_l1_mib: 3.8, l1_bw: 256.0, l2_bw: Some(32.0), l1_latency: "N.A.", peak_ops: 64.0, open_source: false },
        SoaRow { name: "NVIDIA H100 (SM)", scaling: "Scaling-out data-driven NoC", pe: "64/32bit PTX", execution: "SIMT", pes_per_cluster: 128, total_pes: 18432, shared_l1_mib: 0.244, l1_bw: 128.0, l2_bw: None, l1_latency: "~1736 (avg)", peak_ops: 128.0, open_source: false },
        SoaRow { name: "HammerBlade (Cell)", scaling: "Scaling-out 2D-ruche NoC", pe: "32bit RISC-V", execution: "SPMD", pes_per_cluster: 128, total_pes: 2048, shared_l1_mib: 0.5, l1_bw: 512.0, l2_bw: None, l1_latency: "2×hops (≤52)", peak_ops: 256.0, open_source: true },
        SoaRow { name: "Occamy", scaling: "Scaling-out Crossbar", pe: "64bit RISC-V", execution: "SPMD", pes_per_cluster: 8, total_pes: 432, shared_l1_mib: 0.125, l1_bw: 32.0, l2_bw: Some(32.0), l1_latency: "1", peak_ops: 32.0, open_source: true },
        SoaRow { name: "MemPool", scaling: "Scaling-up (NUMA) Crossbar", pe: "32bit RISC-V", execution: "SPMD", pes_per_cluster: 256, total_pes: 256, shared_l1_mib: 1.0, l1_bw: 1024.0, l2_bw: Some(256.0), l1_latency: "1-5", peak_ops: 512.0, open_source: true },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terapool_leads_every_scaleup_metric() {
        let tp = terapool_row(&ClusterConfig::terapool(9));
        for row in literature_rows() {
            assert!(tp.pes_per_cluster >= 4 * row.pes_per_cluster,
                "4x PE-count claim vs {}", row.name);
            assert!(tp.l1_bw >= row.l1_bw, "L1 BW vs {}", row.name);
        }
    }

    #[test]
    fn terapool_row_matches_paper_cells() {
        let tp = terapool_row(&ClusterConfig::terapool(9));
        assert_eq!(tp.pes_per_cluster, 1024);
        assert_eq!(tp.shared_l1_mib, 4.0);
        assert_eq!(tp.l1_bw, 4096.0); // 4 KiB/cycle
        assert_eq!(tp.l2_bw, Some(1024.0)); // 16×512 bit
        assert_eq!(tp.peak_ops, 2048.0);
    }

    #[test]
    fn mempool_ratios_match_sec8() {
        // TeraPool scales MemPool by 4× in PEs, L1 size and bandwidth.
        let tp = terapool_row(&ClusterConfig::terapool(9));
        let rows = literature_rows();
        let mp = rows.iter().find(|r| r.name == "MemPool").unwrap();
        assert_eq!(tp.pes_per_cluster, 4 * mp.pes_per_cluster);
        assert_eq!(tp.shared_l1_mib, 4.0 * mp.shared_l1_mib);
        assert_eq!(tp.l1_bw, 4.0 * mp.l1_bw);
    }
}
