//! Routing-congestion model of logarithmic-staged crossbars in GF12 with
//! a 13-metal stack — regenerates **Table 3** and **Fig. 3**.
//!
//! Mechanism: a crossbar with complexity `c = n×k` leaf nodes needs wire
//! length ∝ c·√area while the BEOL supplies tracks ∝ area; block area
//! stops scaling once the placeable region saturates (~1536 leaves under
//! the paper's floorplan), beyond which demand outruns supply and overflow
//! explodes — the 25→308 % wall between 2048 and 4096. The quantitative
//! anchor points are the paper's own PnR measurements (Table 3), with
//! log-log interpolation between anchors and the mechanistic power laws
//! (area ×1.8 / doubling, delay ×<1.3 / doubling) extrapolating beyond.

/// One Table-3 row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoutingQuality {
    pub complexity: usize,
    /// Average routing-track overflow, horizontal layers (%).
    pub congestion_h: f64,
    /// Vertical layers (%).
    pub congestion_v: f64,
    /// Overall (%).
    pub congestion: f64,
    /// Logic area (kGE).
    pub area_kge: f64,
    /// Critical path (ns) at TT/0.80 V/25 °C.
    pub critical_path_ns: f64,
}

/// The paper's PnR calibration anchors (Table 3, GF12nm 13M).
pub const CALIBRATION: [RoutingQuality; 8] = [
    RoutingQuality { complexity: 256, congestion_h: 0.13, congestion_v: 0.07, congestion: 0.10, area_kge: 109.0, critical_path_ns: 0.59 },
    RoutingQuality { complexity: 512, congestion_h: 0.26, congestion_v: 0.11, congestion: 0.19, area_kge: 196.0, critical_path_ns: 0.73 },
    RoutingQuality { complexity: 1024, congestion_h: 0.56, congestion_v: 0.12, congestion: 0.34, area_kge: 361.0, critical_path_ns: 0.91 },
    RoutingQuality { complexity: 1280, congestion_h: 1.72, congestion_v: 0.47, congestion: 1.09, area_kge: 503.0, critical_path_ns: 1.06 },
    RoutingQuality { complexity: 1536, congestion_h: 3.25, congestion_v: 0.82, congestion: 2.04, area_kge: 669.0, critical_path_ns: 1.08 },
    RoutingQuality { complexity: 2048, congestion_h: 34.46, congestion_v: 15.09, congestion: 24.77, area_kge: 923.0, critical_path_ns: 1.13 },
    RoutingQuality { complexity: 3072, congestion_h: 172.30, congestion_v: 294.31, congestion: 233.31, area_kge: 1274.0, critical_path_ns: 1.27 },
    RoutingQuality { complexity: 4096, congestion_h: 247.10, congestion_v: 368.90, congestion: 308.00, area_kge: 1485.0, critical_path_ns: 1.47 },
];

fn loglog(x: f64, x0: f64, y0: f64, x1: f64, y1: f64) -> f64 {
    let t = (x.ln() - x0.ln()) / (x1.ln() - x0.ln());
    (y0.ln() + t * (y1.ln() - y0.ln())).exp()
}

/// Predict routing quality at an arbitrary complexity.
pub fn predict(complexity: usize) -> RoutingQuality {
    let c = complexity as f64;
    let cal = &CALIBRATION;
    // Locate the bracketing anchors (extrapolate with end slopes).
    let (lo, hi) = {
        let mut lo = 0;
        while lo + 2 < cal.len() && cal[lo + 1].complexity as f64 <= c {
            lo += 1;
        }
        (lo, lo + 1)
    };
    let (a, b) = (&cal[lo], &cal[hi]);
    let f = |ya: f64, yb: f64| loglog(c, a.complexity as f64, ya, b.complexity as f64, yb);
    RoutingQuality {
        complexity,
        congestion_h: f(a.congestion_h, b.congestion_h),
        congestion_v: f(a.congestion_v, b.congestion_v),
        congestion: f(a.congestion, b.congestion),
        area_kge: f(a.area_kge, b.area_kge),
        critical_path_ns: f(a.critical_path_ns, b.critical_path_ns),
    }
}

/// The paper's routability verdict: designs stay implementable while the
/// most complex crossbar keeps overall overflow in the low single digits;
/// beyond complexity 2048 BEOL overflow (25–308 %) makes routing
/// infeasible.
pub fn is_routable(complexity: usize) -> bool {
    predict(complexity).congestion < 5.0
}

/// Max achievable frequency (MHz) for a block whose critical path is the
/// crossbar of the given complexity (TT/0.80 V/25 °C).
pub fn max_freq_mhz(complexity: usize) -> f64 {
    1000.0 / predict(complexity).critical_path_ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_reproduce_exactly() {
        for want in CALIBRATION {
            let got = predict(want.complexity);
            assert!((got.congestion - want.congestion).abs() < 1e-9);
            assert!((got.area_kge - want.area_kge).abs() < 1e-6);
            assert!((got.critical_path_ns - want.critical_path_ns).abs() < 1e-9);
        }
    }

    #[test]
    fn doubling_laws_hold_in_routable_region() {
        // Paper: each complexity doubling ⇒ area ×~1.8 (×2.6 at the 2048
        // congestion knee in the paper's own Table 3), delay ×<1.3.
        for c in [256usize, 512, 1024] {
            let a = predict(c);
            let b = predict(2 * c);
            let area_ratio = b.area_kge / a.area_kge;
            let delay_ratio = b.critical_path_ns / a.critical_path_ns;
            assert!((1.5..2.6).contains(&area_ratio), "area ratio {area_ratio}");
            assert!(delay_ratio < 1.31, "delay ratio {delay_ratio}");
        }
    }

    #[test]
    fn routability_wall_at_2048() {
        assert!(is_routable(256));
        assert!(is_routable(1024));
        assert!(is_routable(1536));
        assert!(!is_routable(2048));
        assert!(!is_routable(4096));
    }

    #[test]
    fn terapool_critical_block_is_routable_flat_is_not() {
        use crate::amat::HierSpec;
        assert!(is_routable(HierSpec::terapool().critical_complexity()));
        assert!(!is_routable(HierSpec::new(1024, 1, 1, 1).critical_complexity()));
        // And the two-level designs are also infeasible (Table 4).
        assert!(!is_routable(HierSpec::new(4, 256, 1, 1).critical_complexity()));
        assert!(!is_routable(HierSpec::new(8, 128, 1, 1).critical_complexity()));
        assert!(!is_routable(HierSpec::new(16, 64, 1, 1).critical_complexity()));
    }

    #[test]
    fn interpolation_is_monotone() {
        let mut prev = 0.0;
        for c in (256..=4096).step_by(128) {
            let q = predict(c);
            assert!(q.congestion >= prev, "congestion not monotone at {c}");
            prev = q.congestion;
        }
    }
}
