//! EDA implementation-effort model — regenerates **Fig. 11** (relative
//! implementation time for a TeraPool Group across design configurations).
//!
//! The paper's observation (Sec. 6.1): implementing a Group of the
//! 16C-8T-8G candidate costs ≈ 3.5× the EDA runtime of TeraPool_1-3-5-9,
//! with timing optimization accounting for > 80 % of the effort and the
//! routing stage 5.5× slower — and the design still fails 500 MHz
//! closure. The mechanism: the 16C-8T-8G Group must be implemented flat
//! (eight 16×16 interconnects + eight large Tiles in a single PnR run),
//! so every timing-optimization iteration re-legalizes and re-routes
//! detoured paths through a congested block, while TeraPool's bottom-up
//! SubGroup blocks leave the Group level only the channel-routed 32×32
//! crossbars. Stage weights are calibrated to the paper's reported
//! ratios; the congestion/complexity inputs come from the Table-3 model.

use super::congestion;
use crate::amat::HierSpec;

/// Relative runtimes of the PnR flow stages (TeraPool_1-3-5-9 ≡ 1.0
/// total).
#[derive(Debug, Clone, Copy)]
pub struct EdaBreakdown {
    pub synthesis: f64,
    pub placement: f64,
    pub cts: f64,
    pub routing: f64,
    pub timing_opt: f64,
}

impl EdaBreakdown {
    pub fn total(&self) -> f64 {
        self.synthesis + self.placement + self.cts + self.routing + self.timing_opt
    }
    pub fn timing_fraction(&self) -> f64 {
        self.timing_opt / self.total()
    }
}

/// A named design configuration of the Fig. 11 comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupConfig {
    /// TeraPool with 7/9/11-cycle remote-Group latency.
    TeraPool(u32),
    /// The non-implementable 16C-8T-8G candidate (flattened Group).
    C16T8G8,
}

pub const FIG11_CONFIGS: [GroupConfig; 4] = [
    GroupConfig::TeraPool(7),
    GroupConfig::TeraPool(9),
    GroupConfig::TeraPool(11),
    GroupConfig::C16T8G8,
];

impl GroupConfig {
    pub fn name(&self) -> String {
        match self {
            GroupConfig::TeraPool(l) => format!("TeraPool 1-3-5-{l}"),
            GroupConfig::C16T8G8 => "16C-8T-8G".into(),
        }
    }

    /// Complexity the Group-level PnR run actually routes: TeraPool's
    /// bottom-up flow leaves only the 32×32 remote crossbars; 16C-8T-8G
    /// flattens the Tiles into the Group.
    pub fn group_routed_complexity(&self) -> usize {
        match self {
            GroupConfig::TeraPool(_) => HierSpec::terapool().critical_complexity(),
            GroupConfig::C16T8G8 => {
                let spec = HierSpec::new(16, 8, 1, 8);
                // 8 Tiles flattened + the 8 per-group crossbars.
                8 * spec.critical_complexity() + 8 * 64
            }
        }
    }

    /// Extra timing-optimization iterations demanded by the frequency
    /// push (TeraPool 730→910 MHz) or by failing closure (16C-8T-8G).
    fn timing_iterations(&self) -> f64 {
        match self {
            GroupConfig::TeraPool(7) => 0.85,
            GroupConfig::TeraPool(9) => 1.0,
            GroupConfig::TeraPool(11) => 1.35,
            GroupConfig::TeraPool(_) => 1.0,
            // Never converges; the paper stops after ~4.5× the iterations
            // with metal shorts remaining.
            GroupConfig::C16T8G8 => 4.45,
        }
    }
}

/// Relative EDA effort, normalized so TeraPool(9) totals 1.0.
pub fn breakdown(cfg: GroupConfig) -> EdaBreakdown {
    let raw = raw_breakdown(cfg);
    let norm = raw_breakdown(GroupConfig::TeraPool(9)).total();
    EdaBreakdown {
        synthesis: raw.synthesis / norm,
        placement: raw.placement / norm,
        cts: raw.cts / norm,
        routing: raw.routing / norm,
        timing_opt: raw.timing_opt / norm,
    }
}

fn raw_breakdown(cfg: GroupConfig) -> EdaBreakdown {
    let c = cfg.group_routed_complexity();
    let q = congestion::predict(c);
    // Both Groups hold the same 256-PE netlist, so synthesis/placement/
    // CTS effort is comparable; routing and timing optimization are where
    // the flat 16C-8T-8G block diverges.
    let route_factor = 1.0 + (q.congestion / 25.0).min(4.5);
    let iters = cfg.timing_iterations();
    EdaBreakdown {
        synthesis: 0.09,
        placement: 0.15,
        cts: 0.05,
        routing: 0.07 * route_factor,
        timing_opt: 0.64 * iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terapool9_is_unity() {
        assert!((breakdown(GroupConfig::TeraPool(9)).total() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn c16t8g8_costs_about_3_5x() {
        let ratio = breakdown(GroupConfig::C16T8G8).total();
        assert!((3.0..4.2).contains(&ratio), "total ratio {ratio}");
    }

    #[test]
    fn timing_opt_dominates_the_bad_config() {
        let bad = breakdown(GroupConfig::C16T8G8);
        assert!(bad.timing_fraction() > 0.80, "{}", bad.timing_fraction());
    }

    #[test]
    fn routing_stage_much_slower_on_bad_config() {
        let bad = breakdown(GroupConfig::C16T8G8);
        let good = breakdown(GroupConfig::TeraPool(9));
        let ratio = bad.routing / good.routing;
        assert!((4.0..7.0).contains(&ratio), "routing ratio {ratio}");
    }

    #[test]
    fn terapool_variants_ordered_by_frequency_push() {
        let t7 = breakdown(GroupConfig::TeraPool(7)).total();
        let t9 = breakdown(GroupConfig::TeraPool(9)).total();
        let t11 = breakdown(GroupConfig::TeraPool(11)).total();
        assert!(t7 < t9 && t9 < t11, "{t7} {t9} {t11}");
        assert!(t11 < 1.5, "frequency push stays affordable: {t11}");
    }
}
