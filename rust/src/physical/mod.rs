//! Physical-design models calibrated on the paper's GF12 LP+ FinFET
//! measurements (Secs. 3.2 and 6).
//!
//! We obviously cannot run Fusion Compiler in this reproduction; these
//! models capture the *decision surfaces* the paper derives from physical
//! design — which crossbar complexities route, what each hierarchy level
//! costs in area and energy, where the frequency/latency trade-off lands —
//! so that every downstream experiment (Table 3/4, Figs. 3, 11, 12, 13,
//! and the GFLOP/s/W headline) regenerates from the same inputs the
//! architecture decisions used.

pub mod area;
pub mod congestion;
pub mod eda;
pub mod energy;
pub mod scaling;
pub mod soa;
