//! Benchmark kernels as per-PE instruction-trace builders (Sec. 7), plus
//! the **Workload API**: every kernel registers a [`Workload`]
//! implementation in the static [`registry`], and the `Session` run path
//! (`crate::session`) is the only consumer — no stringly-typed dispatch
//! outside [`lookup`].
//!
//! Each builder lays the working set out in the shared L1 (hybrid map,
//! interleaved region), emits one trace per PE with the same instruction
//! mix the paper's hand-tuned RV32 kernels issue, and describes where the
//! inputs/outputs live so the harness can stage data and compare the final
//! memory image against the AOT-compiled JAX golden artifacts.
//!
//! * [`axpy`]/[`dotp`] — *local-access* BLAS-1 kernels: chunk-of-4
//!   interleaved assignment keeps every access in the PE's own Tile;
//! * [`gemm`] — *global-access* 4×4-register-blocked MatMul: operand
//!   fetches sweep all 4096 banks;
//! * [`fft`] — radix-4 DIF Cooley-Tukey, 64 independent 4096-point
//!   transforms, stage strides exercising every hierarchy level;
//! * [`spmmadd`] — CSR sparse matrix-matrix addition (GraphBLAS):
//!   irregular, branch-heavy, data-dependent accesses;
//! * [`double_buffer`] — the Fig. 14b double-buffered variants
//!   (`db-axpy`/`db-dotp`/`db-gemm`) streaming through the HBML.

pub mod axpy;
pub mod dotp;
pub mod double_buffer;
pub mod fft;
pub mod gemm;
pub mod spmmadd;

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, Scale};
use crate::dma::DmaDescriptor;
use crate::errors::{Error, Result};
use crate::isa::Program;
use crate::report::Verdict;

/// HBML staging plan of a double-buffered workload: descriptors to
/// register with the iDMA frontend plus the functional main-memory image
/// regions to stage before the run. Applied by [`Staged::into_cluster`]
/// on the thread that will run the cluster (the HBM image is
/// thread-local), which is what makes batched DMA jobs safe.
pub struct DmaPlan {
    pub descriptors: Vec<DmaDescriptor>,
    /// (byte address, contents) regions staged into the HBM image.
    pub image: Vec<(u64, Vec<f32>)>,
}

/// A fully-staged workload: traces + data placement (+ optional HBML
/// plan). Produced by [`Workload::build`] and by the per-kernel `build`
/// functions for harness code that wants the raw pieces.
pub struct Staged {
    pub name: String,
    /// One program per PE.
    pub programs: Vec<Program>,
    /// (base word, contents) pairs to stage into L1 before the run.
    pub inputs: Vec<(u32, Vec<f32>)>,
    /// Output location (base word, length) in L1 after the run.
    pub output_base: u32,
    pub output_len: usize,
    /// Useful FLOP of the kernel (for GFLOP/s; MAC = 2).
    pub flops: u64,
    /// HBML transfers (double-buffered workloads); None for L1-resident
    /// kernels.
    pub dma: Option<DmaPlan>,
}

impl Staged {
    /// Build a cluster, stage the L1 inputs (and the HBML plan, when
    /// present: attach the DMA subsystem, reset + stage the thread-local
    /// HBM image, register the descriptors), and return it ready to run.
    pub fn into_cluster(self, cfg: ClusterConfig) -> (Cluster, StagedIo) {
        let mut cl = Cluster::new(cfg, self.programs);
        for (base, data) in &self.inputs {
            cl.l1.write_slice(*base, data);
        }
        if let Some(plan) = &self.dma {
            cl = cl.with_dma();
            crate::dma::hbm_image_clear();
            for (addr, data) in &plan.image {
                crate::dma::hbm_image_stage(*addr, data);
            }
            let dma = cl.dma.as_mut().unwrap();
            for d in &plan.descriptors {
                dma.register(*d);
            }
        }
        (
            cl,
            StagedIo {
                name: self.name,
                output_base: self.output_base,
                output_len: self.output_len,
                flops: self.flops,
            },
        )
    }
}

/// What remains of a [`Staged`] workload after the cluster took
/// ownership: where to find the output and how much useful work it
/// represents.
pub struct StagedIo {
    pub name: String,
    pub output_base: u32,
    pub output_len: usize,
    pub flops: u64,
}

impl StagedIo {
    /// Read the output region — **only valid after the run finished**.
    /// Returns a typed `MaxCyclesExceeded` error when the cluster is not
    /// done (the image would be garbage mid-run); the old silent read is
    /// available as [`StagedIo::read_output_unchecked`] for engine
    /// differential tests that deliberately inspect partial state.
    pub fn read_output(&self, cl: &Cluster) -> Result<Vec<f32>> {
        if !cl.done() {
            return Err(Error::with_kind(
                crate::errors::ErrorKind::MaxCyclesExceeded,
                format!(
                    "read_output: {}: cluster not done at cycle {} — the output \
                     image is not final",
                    self.name, cl.cycle
                ),
            ));
        }
        Ok(self.read_output_unchecked(cl))
    }

    /// Read the output region without the done() guard.
    pub fn read_output_unchecked(&self, cl: &Cluster) -> Vec<f32> {
        cl.l1.read_slice(self.output_base, self.output_len)
    }
}

// ---------------------------------------------------------------------
// The Workload trait + static registry.
// ---------------------------------------------------------------------

/// A runnable workload: the unit the `Session` API schedules. One
/// registration here replaces a bespoke `run_<kernel>` entry point:
/// implementors provide the registry key, the staging (problem sizes
/// resolved from config × scale when not pinned explicitly), and the
/// host-reference check.
pub trait Workload: Send + Sync {
    /// Registry key, e.g. `"axpy"` — stable, lowercase, unique.
    fn kind(&self) -> &'static str;

    /// One-line description for `terapool --list`.
    fn describe(&self) -> &'static str;

    /// Stage programs + data. Implementations resolve their default
    /// problem size from `(cfg, scale)` unless constructed with pinned
    /// parameters.
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged;

    /// Verdict of the finished run against the kernel's host reference.
    /// Only called once the cluster is `done()`. The default says the
    /// workload ships no reference.
    fn check(&self, cfg: &ClusterConfig, scale: Scale, cl: &Cluster, io: &StagedIo) -> Verdict {
        let _ = (cfg, scale, cl, io);
        Verdict::NotChecked
    }
}

/// The static workload registry: every kernel the simulator ships, in
/// the canonical reporting order (Fig. 14a compute kernels first, then
/// the Fig. 14b double-buffered variants). This is the single place a
/// kernel name maps to code.
pub fn registry() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(axpy::Axpy::default()),
        Box::new(dotp::Dotp::default()),
        Box::new(gemm::Gemm::default()),
        Box::new(fft::Fft::default()),
        Box::new(spmmadd::Spmmadd::default()),
        Box::new(double_buffer::Db::new(double_buffer::DbKernel::Gemm)),
        Box::new(double_buffer::Db::new(double_buffer::DbKernel::Dotp)),
        Box::new(double_buffer::Db::new(double_buffer::DbKernel::Axpy)),
    ]
}

/// Registry keys, in registry order.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|w| w.kind()).collect()
}

/// Resolve a registry key to its workload — a typed
/// [`crate::errors::ErrorKind::UnknownWorkload`] error (never a panic)
/// when the name is not registered.
pub fn lookup(name: &str) -> Result<Box<dyn Workload>> {
    registry()
        .into_iter()
        .find(|w| w.kind() == name)
        .ok_or_else(|| Error::unknown_workload(name, &names()))
}

/// Shared helper for element-wise reference checks: max |got - want|
/// against a tolerance, rendered into a [`Verdict`]. Non-finite
/// differences (NaN/inf anywhere in the output) fail outright —
/// `f32::max` would silently skip NaN.
pub fn allclose_verdict(got: &[f32], want: &[f32], tol: f32, what: &str) -> Verdict {
    if got.len() != want.len() {
        return Verdict::Failed {
            reason: format!("{what}: length {} vs reference {}", got.len(), want.len()),
        };
    }
    let mut max_d = 0.0f32;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let d = (g - w).abs();
        if !d.is_finite() {
            return Verdict::Failed {
                reason: format!("{what}: non-finite at [{i}]: got {g}, want {w}"),
            };
        }
        max_d = max_d.max(d);
    }
    if max_d <= tol {
        Verdict::Passed {
            detail: format!("{what}: {} elements, max |d| {max_d:.2e} ≤ {tol:.0e}", got.len()),
        }
    } else {
        Verdict::Failed { reason: format!("{what}: max |d| {max_d:.3e} > {tol:.0e}") }
    }
}

/// Allocation cursor over the interleaved region. Keeps kernel layouts
/// aligned to full bank sweeps so local-access assignments stay local.
pub struct Alloc {
    next: u32,
    limit: u32,
    num_banks: u32,
}

impl Alloc {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let map = crate::memory::AddressMap::new(cfg);
        Alloc {
            next: map.interleaved_base(),
            limit: cfg.l1_words() as u32,
            num_banks: cfg.num_banks() as u32,
        }
    }
    /// Allocate `words`, rounded up to a multiple of the bank count (one
    /// full interleave sweep), so that word i of every array maps to bank
    /// `i mod num_banks`.
    pub fn alloc(&mut self, words: u32) -> u32 {
        let base = self.next;
        let rounded = words.div_ceil(self.num_banks) * self.num_banks;
        self.next += rounded;
        assert!(
            self.next <= self.limit,
            "kernel working set exceeds L1 interleaved region \
             ({} > {} words)",
            self.next,
            self.limit
        );
        base
    }
}

/// Round-robin work split: item range `[0, n)` for PE `pe` of `npes`.
pub fn chunk_range(n: usize, pe: usize, npes: usize) -> std::ops::Range<usize> {
    let per = n.div_ceil(npes);
    let start = (pe * per).min(n);
    let end = ((pe + 1) * per).min(n);
    start..end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errors::ErrorKind;

    #[test]
    fn alloc_rounds_to_bank_sweeps() {
        let cfg = ClusterConfig::tiny(); // 128 banks
        let mut a = Alloc::new(&cfg);
        let b0 = a.alloc(100);
        let b1 = a.alloc(1);
        assert_eq!((b1 - b0) % 128, 0);
        // word i of each array lands in bank i mod 128
        let map = crate::memory::AddressMap::new(&cfg);
        assert_eq!(map.map(b0).bank, map.map(b1).bank);
    }

    #[test]
    #[should_panic(expected = "exceeds L1")]
    fn alloc_checks_capacity() {
        let cfg = ClusterConfig::tiny();
        let mut a = Alloc::new(&cfg);
        a.alloc(10_000_000);
    }

    #[test]
    fn chunk_range_covers_everything() {
        let n = 1000;
        let npes = 32;
        let mut seen = vec![false; n];
        for pe in 0..npes {
            for i in chunk_range(n, pe, npes) {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn registry_keys_are_unique_and_lookup_is_typed() {
        let names = names();
        for (i, a) in names.iter().enumerate() {
            assert!(!names[i + 1..].contains(a), "duplicate registry key {a}");
        }
        assert_eq!(lookup("axpy").unwrap().kind(), "axpy");
        let e = lookup("definitely-not-a-kernel").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::UnknownWorkload);
    }

    #[test]
    fn read_output_is_gated_on_done() {
        let cfg = ClusterConfig::tiny();
        let staged = axpy::build(&cfg, &axpy::AxpyParams { n: cfg.num_banks(), alpha: 1.0 });
        let (mut cl, io) = staged.into_cluster(cfg);
        // Before (and mid-) run: typed refusal, not garbage.
        let e = io.read_output(&cl).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::MaxCyclesExceeded);
        cl.run(1_000_000);
        assert!(io.read_output(&cl).is_ok());
    }
}
