//! Benchmark kernels as per-PE instruction-trace builders (Sec. 7).
//!
//! Each builder lays the working set out in the shared L1 (hybrid map,
//! interleaved region), emits one trace per PE with the same instruction
//! mix the paper's hand-tuned RV32 kernels issue, and describes where the
//! inputs/outputs live so the harness can stage data and compare the final
//! memory image against the AOT-compiled JAX golden artifacts.
//!
//! * [`axpy`]/[`dotp`] — *local-access* BLAS-1 kernels: chunk-of-4
//!   interleaved assignment keeps every access in the PE's own Tile;
//! * [`gemm`] — *global-access* 4×4-register-blocked MatMul: operand
//!   fetches sweep all 4096 banks;
//! * [`fft`] — radix-4 DIF Cooley-Tukey, 64 independent 4096-point
//!   transforms, stage strides exercising every hierarchy level;
//! * [`spmmadd`] — CSR sparse matrix-matrix addition (GraphBLAS):
//!   irregular, branch-heavy, data-dependent accesses.

pub mod axpy;
pub mod dotp;
pub mod double_buffer;
pub mod fft;
pub mod gemm;
pub mod spmmadd;

use crate::config::ClusterConfig;
use crate::isa::Program;

/// A fully-staged kernel: traces + data placement.
pub struct KernelSetup {
    pub name: String,
    /// One program per PE.
    pub programs: Vec<Program>,
    /// (base word, contents) pairs to stage into L1 before the run.
    pub inputs: Vec<(u32, Vec<f32>)>,
    /// Output location (base word, length) in L1 after the run.
    pub output_base: u32,
    pub output_len: usize,
    /// Useful FLOP of the kernel (for GFLOP/s; MAC = 2).
    pub flops: u64,
}

impl KernelSetup {
    /// Build a cluster, stage the inputs, and return it ready to run.
    pub fn into_cluster(self, cfg: ClusterConfig) -> (crate::cluster::Cluster, KernelIo) {
        let mut cl = crate::cluster::Cluster::new(cfg, self.programs);
        for (base, data) in &self.inputs {
            cl.l1.write_slice(*base, data);
        }
        (
            cl,
            KernelIo {
                name: self.name,
                output_base: self.output_base,
                output_len: self.output_len,
                flops: self.flops,
            },
        )
    }
}

/// What remains of a [`KernelSetup`] after the cluster took ownership.
pub struct KernelIo {
    pub name: String,
    pub output_base: u32,
    pub output_len: usize,
    pub flops: u64,
}

impl KernelIo {
    pub fn read_output(&self, cl: &crate::cluster::Cluster) -> Vec<f32> {
        cl.l1.read_slice(self.output_base, self.output_len)
    }
}

/// Allocation cursor over the interleaved region. Keeps kernel layouts
/// aligned to full bank sweeps so local-access assignments stay local.
pub struct Alloc {
    next: u32,
    limit: u32,
    num_banks: u32,
}

impl Alloc {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let map = crate::memory::AddressMap::new(cfg);
        Alloc {
            next: map.interleaved_base(),
            limit: cfg.l1_words() as u32,
            num_banks: cfg.num_banks() as u32,
        }
    }
    /// Allocate `words`, rounded up to a multiple of the bank count (one
    /// full interleave sweep), so that word i of every array maps to bank
    /// `i mod num_banks`.
    pub fn alloc(&mut self, words: u32) -> u32 {
        let base = self.next;
        let rounded = words.div_ceil(self.num_banks) * self.num_banks;
        self.next += rounded;
        assert!(
            self.next <= self.limit,
            "kernel working set exceeds L1 interleaved region \
             ({} > {} words)",
            self.next,
            self.limit
        );
        base
    }
}

/// Round-robin work split: item range `[0, n)` for PE `pe` of `npes`.
pub fn chunk_range(n: usize, pe: usize, npes: usize) -> std::ops::Range<usize> {
    let per = n.div_ceil(npes);
    let start = (pe * per).min(n);
    let end = ((pe + 1) * per).min(n);
    start..end
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_rounds_to_bank_sweeps() {
        let cfg = ClusterConfig::tiny(); // 128 banks
        let mut a = Alloc::new(&cfg);
        let b0 = a.alloc(100);
        let b1 = a.alloc(1);
        assert_eq!((b1 - b0) % 128, 0);
        // word i of each array lands in bank i mod 128
        let map = crate::memory::AddressMap::new(&cfg);
        assert_eq!(map.map(b0).bank, map.map(b1).bank);
    }

    #[test]
    #[should_panic(expected = "exceeds L1")]
    fn alloc_checks_capacity() {
        let cfg = ClusterConfig::tiny();
        let mut a = Alloc::new(&cfg);
        a.alloc(10_000_000);
    }

    #[test]
    fn chunk_range_covers_everything() {
        let n = 1000;
        let npes = 32;
        let mut seen = vec![false; n];
        for pe in 0..npes {
            for i in chunk_range(n, pe, npes) {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
