//! DOTP — the second *local-access* kernel (Sec. 7): `s = Σ x_i · y_i`.
//!
//! Same chunk-of-4 local data placement as AXPY; the **join** is the
//! paper's atomic fetch&add reduction: each PE folds its partial sums into
//! 4 accumulator registers, reduces them, atomically adds the partial into
//! a per-Tile slot (Tile-local bank, 8 PEs serialize), and after a barrier
//! the Tile leaders atomically add their Tile sums into the global slot.
//! This two-level software tree is why DOTP shows more AMAT +
//! synchronization overhead than AXPY in Fig. 14a (IPC 0.83 vs 0.85).

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, Scale};
use crate::isa::Program;
use crate::report::Verdict;

use super::{Alloc, Staged, StagedIo, Workload};

const R_X: u8 = 2; // r2..r5
const R_Y: u8 = 6; // r6..r9
const R_ACC: u8 = 10; // r10..r13
const R_T: u8 = 14;

#[derive(Debug, Clone)]
pub struct DotpParams {
    pub n: usize,
}

impl Default for DotpParams {
    fn default() -> Self {
        DotpParams { n: 256 * 1024 }
    }
}

pub fn input_x(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 13) as f32) * 0.25 - 1.5).collect()
}
pub fn input_y(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 7) as f32) * 0.5 - 1.0).collect()
}

/// [`Workload`] registration: DOTP with pinned or scale-resolved size.
#[derive(Default)]
pub struct Dotp(pub Option<DotpParams>);

impl Dotp {
    pub fn with(p: DotpParams) -> Self {
        Dotp(Some(p))
    }
    fn resolve(&self, cfg: &ClusterConfig, scale: Scale) -> DotpParams {
        self.0
            .clone()
            .unwrap_or(DotpParams { n: cfg.num_banks() * scale.pick(64, 16) })
    }
}

impl Workload for Dotp {
    fn kind(&self) -> &'static str {
        "dotp"
    }
    fn describe(&self) -> &'static str {
        "local-access BLAS-1 s = sum(x*y), two-level atomic reduction (Fig. 14a)"
    }
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged {
        build(cfg, &self.resolve(cfg, scale))
    }
    fn check(
        &self,
        cfg: &ClusterConfig,
        scale: Scale,
        cl: &Cluster,
        io: &StagedIo,
    ) -> Verdict {
        let p = self.resolve(cfg, scale);
        let got = match io.read_output(cl) {
            Ok(v) => v[0],
            Err(e) => return Verdict::Failed { reason: e.to_string() },
        };
        let want = reference(&p);
        // Relative tolerance: the cluster reduces in a different
        // association order than the host fold.
        let tol = want.abs().max(1.0) * 2e-4;
        if (got - want).abs() < tol {
            Verdict::Passed { detail: format!("dotp {got:.3} matches host reference {want:.3}") }
        } else {
            Verdict::Failed { reason: format!("dotp {got} vs host reference {want} (tol {tol})") }
        }
    }
}

pub fn build(cfg: &ClusterConfig, p: &DotpParams) -> Staged {
    let nb = cfg.num_banks();
    let bf = cfg.banking_factor;
    let npes = cfg.num_pes();
    let ppt = cfg.hierarchy.pes_per_tile;
    assert_eq!(p.n % nb, 0, "n must be a multiple of the bank count");

    let mut alloc = Alloc::new(cfg);
    let xb = alloc.alloc(p.n as u32);
    let yb = alloc.alloc(p.n as u32);
    // One partial-sum slot per Tile + the global slot; the global slot is
    // the kernel output.
    let tile_slots = alloc.alloc(cfg.num_tiles() as u32);
    let out = alloc.alloc(1);

    let sweeps = p.n / nb;
    let burst = cfg.burst && bf > 1 && bf <= crate::isa::MAX_BURST_WORDS;
    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let tile = pe / ppt;
        let mut t = Program::new();
        for j in 0..bf as u8 {
            t.ld_imm(R_ACC + j, 0.0);
        }
        for k in 0..sweeps {
            let i0 = (k * nb + bf * pe) as u32;
            if burst {
                // One grant per bf-element group (see axpy.rs).
                t.ld_burst(R_X, xb + i0, bf as u8);
                t.ld_burst(R_Y, yb + i0, bf as u8);
            } else {
                for j in 0..bf as u32 {
                    t.ld(R_X + j as u8, xb + i0 + j);
                }
                for j in 0..bf as u32 {
                    t.ld(R_Y + j as u8, yb + i0 + j);
                }
            }
            for j in 0..bf as u8 {
                t.fmac(R_ACC + j, R_X + j, R_Y + j);
            }
            t.alu();
            t.branch();
        }
        // Fold the 4 accumulators.
        t.add(R_T, R_ACC, R_ACC + 1);
        t.add(R_T + 1, R_ACC + 2, R_ACC + 3);
        t.add(R_T, R_T, R_T + 1);
        // Level 1: per-Tile atomic reduction (local bank).
        t.atom_add(R_T, tile_slots + tile as u32);
        t.barrier(0);
        // Level 2: Tile leaders fold Tile sums into the global slot.
        if pe % ppt == 0 {
            t.ld(R_T, tile_slots + tile as u32);
            t.atom_add(R_T, out);
        }
        t.barrier(1);
        t.halt();
        programs.push(t);
    }

    Staged {
        name: format!("dotp-n{}", p.n),
        programs,
        inputs: vec![(xb, input_x(p.n)), (yb, input_y(p.n))],
        output_base: out,
        output_len: 1,
        flops: 2 * p.n as u64,
        dma: None,
    }
}

pub fn reference(p: &DotpParams) -> f32 {
    input_x(p.n)
        .iter()
        .zip(input_y(p.n))
        .map(|(&x, y)| x * y)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dotp_reduces_correctly() {
        let cfg = ClusterConfig::tiny();
        let p = DotpParams { n: cfg.num_banks() * 8 };
        let want = reference(&p);
        let (mut cl, io) = build(&cfg, &p).into_cluster(cfg);
        cl.run(1_000_000);
        let got = io.read_output(&cl).unwrap()[0];
        assert!(
            (got - want).abs() < 1e-2 * want.abs().max(1.0),
            "got {got}, want {want}"
        );
    }

    #[test]
    fn dotp_has_more_synch_than_axpy() {
        let cfg = ClusterConfig::tiny();
        let n = cfg.num_banks() * 16;
        let (mut ca, _) = super::super::axpy::build(
            &cfg,
            &super::super::axpy::AxpyParams { n, alpha: 2.0 },
        )
        .into_cluster(cfg.clone());
        let sa = ca.run(1_000_000);
        let (mut cd, _) = build(&cfg, &DotpParams { n }).into_cluster(cfg);
        let sd = cd.run(1_000_000);
        let fa = sa.fraction(sa.stall_synch);
        let fd = sd.fraction(sd.stall_synch);
        assert!(fd > fa, "dotp synch {fd} vs axpy {fa}");
    }
}
