//! GEMM — the *global-access* kernel (Sec. 7): `C = A · B`, 4×4 register
//! blocking.
//!
//! Mirrors the paper's tiled Snitch implementation: each PE owns a set of
//! 4×4 output blocks ("the maximum supported by 32 ISA registers"); per
//! K-step it issues 8 non-blocking loads (4 of A, 4 of B — at most 8 input
//! transactions, the transaction-table break-even of Sec. 4.1) followed by
//! 16 FMAs. Operand fetches sweep all banks through the shared
//! interconnect, which is what drags IPC from ~0.85 to ~0.70 in Fig. 14a
//! and makes the measured AMAT line up with the Sec. 3 random-traffic
//! model.

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, Scale};
use crate::isa::Program;
use crate::report::Verdict;

use super::{allclose_verdict, chunk_range, Alloc, Staged, StagedIo, Workload};

const BM: usize = 4;
const BN: usize = 4;
// Register map: r1..r4 A operands, r5..r8 B operands, r12..r27 the 4×4
// accumulator block.
const R_A: u8 = 1;
const R_B: u8 = 5;
const R_ACC: u8 = 12;

#[derive(Debug, Clone, Copy)]
pub struct GemmParams {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Default for GemmParams {
    fn default() -> Self {
        GemmParams { m: 256, n: 256, k: 256 }
    }
}

/// Deterministic inputs (reproduced on the JAX side by the harness).
pub fn input_a(p: &GemmParams) -> Vec<f32> {
    (0..p.m * p.k).map(|i| ((i % 11) as f32) * 0.25 - 1.25).collect()
}
pub fn input_b(p: &GemmParams) -> Vec<f32> {
    (0..p.k * p.n).map(|i| ((i % 9) as f32) * 0.125 - 0.5).collect()
}

/// [`Workload`] registration: GEMM with pinned or scale-resolved edge
/// (256³ full / 128³ fast — the Fig. 14a sizes).
#[derive(Default)]
pub struct Gemm(pub Option<GemmParams>);

impl Gemm {
    pub fn with(p: GemmParams) -> Self {
        Gemm(Some(p))
    }
    fn resolve(&self, _cfg: &ClusterConfig, scale: Scale) -> GemmParams {
        self.0.unwrap_or({
            let e = scale.pick(256, 128);
            GemmParams { m: e, n: e, k: e }
        })
    }
}

impl Workload for Gemm {
    fn kind(&self) -> &'static str {
        "gemm"
    }
    fn describe(&self) -> &'static str {
        "global-access 4x4-register-blocked MatMul (Fig. 14a, Table 6)"
    }
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged {
        build(cfg, &self.resolve(cfg, scale))
    }
    fn check(
        &self,
        cfg: &ClusterConfig,
        scale: Scale,
        cl: &Cluster,
        io: &StagedIo,
    ) -> Verdict {
        let p = self.resolve(cfg, scale);
        match io.read_output(cl) {
            // 2e-2: K-loop phase staggering changes accumulation order.
            Ok(got) => allclose_verdict(&got, &reference(&p), 2e-2, "gemm vs host reference"),
            Err(e) => Verdict::Failed { reason: e.to_string() },
        }
    }
}

pub fn build(cfg: &ClusterConfig, p: &GemmParams) -> Staged {
    build_band(cfg, p, 0, 1, true).0
}

/// Placement of one cluster's block-row band inside the full problem —
/// what the system layer needs to wire the band into the scale-out
/// schedule (where the shared B lives for the halo broadcast, which C
/// rows to merge into the main-memory image).
#[derive(Debug, Clone, Copy)]
pub struct GemmBand {
    /// First C row owned by this band.
    pub row0: usize,
    /// C rows in this band.
    pub rows: usize,
    pub a_base: u32,
    pub b_base: u32,
    pub c_base: u32,
}

/// Factor a slice count into the squarest `(row-slices, col-slices)`
/// grid: the largest divisor ≤ √S times its cofactor (4 → 2×2, 2 → 1×2,
/// 6 → 2×3). A 2-D grid is what lets the pipelined system engine hide
/// the *shared* B staging too — B streams in one column panel at a
/// time, whereas a 1-D row slicing would need the whole of B before the
/// first slice can start.
pub fn slice_grid(slices: usize) -> (usize, usize) {
    let s = slices.max(1);
    let mut sr = 1;
    let mut d = 1;
    while d * d <= s {
        if s % d == 0 {
            sr = d;
        }
        d += 1;
    }
    (sr, s / sr)
}

/// Placement of one (cluster, slice) tile inside the full problem: C
/// rows `[row0, row0+rows)` × columns `[col0, col0+cols)`. The tile's
/// A/B/C arrays are compact — A is `rows×k`, B the `k×cols` column
/// panel at pitch `cols`, C the `rows×cols` tile (strided in the merged
/// memory image at pitch `n`).
#[derive(Debug, Clone, Copy)]
pub struct GemmTile {
    pub row0: usize,
    pub rows: usize,
    pub col0: usize,
    pub cols: usize,
    pub a_base: u32,
    pub b_base: u32,
    pub c_base: u32,
}

/// [`build`] restricted to block-row band `part` of `parts`: the cluster
/// computes C rows `[row0, row0 + rows)` from its own A band and a full
/// copy of B. The A band and (when `stage_b`) B are staged locally;
/// non-root clusters of a phase-serial system run pass `stage_b = false`
/// and receive B over the inter-cluster links instead (same bytes —
/// staging is the functional delivery, the links carry the
/// timing/traffic). Layout is compact (band-sized A and C), so split
/// clusters with proportionally smaller L1s still fit the full-scale
/// problem.
pub fn build_band(
    cfg: &ClusterConfig,
    p: &GemmParams,
    part: usize,
    parts: usize,
    stage_b: bool,
) -> (Staged, GemmBand) {
    let (s, t) = build_tile(cfg, p, part, parts, 0, 1, 0, 1, stage_b);
    (s, GemmBand { row0: t.row0, rows: t.rows, a_base: t.a_base, b_base: t.b_base, c_base: t.c_base })
}

/// [`build_band`] restricted further to slice `(si, sj)` of an `sr×sc`
/// grid over the band: row-slice `si` of the band's block-rows ×
/// col-slice `sj` of the problem's block-columns. The full band is the
/// 1×1 grid (that is exactly what [`build_band`] delegates to). Each
/// tile is an independent `Staged` instance — the pipelined system
/// engine runs a cluster's tiles back-to-back, staging tile `t+1` while
/// tile `t` computes.
#[allow(clippy::too_many_arguments)]
pub fn build_tile(
    cfg: &ClusterConfig,
    p: &GemmParams,
    part: usize,
    parts: usize,
    si: usize,
    sr: usize,
    sj: usize,
    sc: usize,
    stage_b: bool,
) -> (Staged, GemmTile) {
    assert!(p.m % BM == 0 && p.n % BN == 0, "4x4 blocking requires 4|M, 4|N");
    let blocks_m_total = p.m / BM;
    let blocks_n_total = p.n / BN;
    let band = chunk_range(blocks_m_total, part, parts);
    let rb = chunk_range(band.end - band.start, si, sr);
    let cb_range = chunk_range(blocks_n_total, sj, sc);
    let blocks_m = rb.end - rb.start;
    let blocks_n = cb_range.end - cb_range.start;
    assert!(
        blocks_m > 0 && blocks_n > 0,
        "tile ({si},{sj})/{sr}x{sc} of band {part}/{parts} is empty"
    );
    let (row0, rows) = ((band.start + rb.start) * BM, blocks_m * BM);
    let (col0, cols) = (cb_range.start * BN, blocks_n * BN);
    let npes = cfg.num_pes();

    let mut alloc = Alloc::new(cfg);
    let ab = alloc.alloc((rows * p.k) as u32);
    let bb = alloc.alloc((p.k * cols) as u32);
    let cb = alloc.alloc((rows * cols) as u32);

    let nblocks = blocks_m * blocks_n;

    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let mut t = Program::new();
        for blk in chunk_range(nblocks, pe, npes) {
            let (bi, bj) = (blk / blocks_n, blk % blocks_n);
            // Stagger the K-loop starting phase per 4×4 block. Without
            // this, PEs sharing a block-column fetch the *same* four B
            // words in lockstep, hammering four banks per step (the
            // classic broadcast hotspot; the paper's hand-tuned kernels
            // use the same cyclic offset trick). The phase is keyed on
            // the block's *global* index — not the PE id — so each C
            // element's FP accumulation order is a function of the
            // block alone, invariant to how clusters/slices/PEs divide
            // the blocks: the merged system image stays byte-identical
            // at any slicing and any cluster count.
            let gblk = (row0 / BM + bi) * blocks_n_total + (col0 / BN + bj);
            let phase = (gblk * 17) % p.k;
            // Zero the accumulator block.
            for r in 0..(BM * BN) as u8 {
                t.ld_imm(R_ACC + r, 0.0);
            }
            for kk0 in 0..p.k {
                let kk = (kk0 + phase) % p.k;
                for u in 0..BM {
                    // Tile-local row: the A/C arrays hold only this
                    // tile's rows.
                    let row = bi * BM + u;
                    t.ld(R_A + u as u8, ab + (row * p.k + kk) as u32);
                }
                for v in 0..BN {
                    // Tile-local column: B is the k×cols panel.
                    let col = bj * BN + v;
                    t.ld(R_B + v as u8, bb + (kk * cols + col) as u32);
                }
                for u in 0..BM {
                    for v in 0..BN {
                        t.fmac(R_ACC + (u * BN + v) as u8, R_A + u as u8, R_B + v as u8);
                    }
                }
                t.alu(); // k-pointer bump
                t.branch();
            }
            for u in 0..BM {
                for v in 0..BN {
                    let row = bi * BM + u;
                    let col = bj * BN + v;
                    t.st(R_ACC + (u * BN + v) as u8, cb + (row * cols + col) as u32);
                }
            }
        }
        t.barrier(0);
        t.halt();
        programs.push(t);
    }

    let a_band = input_a(p)[row0 * p.k..(row0 + rows) * p.k].to_vec();
    let mut inputs = vec![(ab, a_band)];
    if stage_b {
        let bfull = input_b(p);
        let mut panel = Vec::with_capacity(p.k * cols);
        for kk in 0..p.k {
            panel.extend_from_slice(&bfull[kk * p.n + col0..kk * p.n + col0 + cols]);
        }
        inputs.push((bb, panel));
    }
    let shape = format!("gemm-{}x{}x{}", p.m, p.n, p.k);
    let name = match (parts, sr * sc) {
        (1, 1) => shape,
        (_, 1) => format!("{shape}[{part}/{parts}]"),
        _ => format!("{shape}[{part}/{parts}]~{si}.{sj}/{sr}x{sc}"),
    };
    let staged = Staged {
        name,
        programs,
        inputs,
        output_base: cb,
        output_len: rows * cols,
        flops: 2 * (rows * cols * p.k) as u64,
        dma: None,
    };
    (staged, GemmTile { row0, rows, col0, cols, a_base: ab, b_base: bb, c_base: cb })
}

/// Host-side reference.
pub fn reference(p: &GemmParams) -> Vec<f32> {
    let a = input_a(p);
    let b = input_b(p);
    let mut c = vec![0.0f32; p.m * p.n];
    for i in 0..p.m {
        for kk in 0..p.k {
            let av = a[i * p.k + kk];
            for j in 0..p.n {
                c[i * p.n + j] += av * b[kk * p.n + j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_computes_correctly_on_tiny_cluster() {
        let cfg = ClusterConfig::tiny();
        let p = GemmParams { m: 16, n: 16, k: 24 };
        let want = reference(&p);
        let (mut cl, io) = build(&cfg, &p).into_cluster(cfg);
        cl.run(10_000_000);
        let got = io.read_output(&cl).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-3, "C[{i}] = {g}, want {w}");
        }
    }

    #[test]
    fn gemm_tile_slices_match_the_host_reference_subblock() {
        // Every tile of a 2×2 slice grid over band 1 of 2 must reproduce
        // exactly its C sub-block of the host reference — the per-slice
        // functional check the pipelined system engine relies on.
        let cfg = ClusterConfig::tiny();
        let p = GemmParams { m: 16, n: 16, k: 24 };
        let want = reference(&p);
        for si in 0..2 {
            for sj in 0..2 {
                let (staged, tile) = build_tile(&cfg, &p, 1, 2, si, 2, sj, 2, true);
                let (mut cl, io) = staged.into_cluster(cfg.clone());
                cl.run(10_000_000);
                let got = io.read_output(&cl).unwrap();
                assert_eq!(got.len(), tile.rows * tile.cols);
                for r in 0..tile.rows {
                    for c in 0..tile.cols {
                        let g = got[r * tile.cols + c];
                        let w = want[(tile.row0 + r) * p.n + tile.col0 + c];
                        assert!(
                            (g - w).abs() < 1e-3,
                            "tile ({si},{sj}) C[{r},{c}] = {g}, want {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn slice_grid_is_the_squarest_factorization() {
        assert_eq!(slice_grid(1), (1, 1));
        assert_eq!(slice_grid(2), (1, 2));
        assert_eq!(slice_grid(3), (1, 3));
        assert_eq!(slice_grid(4), (2, 2));
        assert_eq!(slice_grid(6), (2, 3));
        assert_eq!(slice_grid(8), (2, 4));
        assert_eq!(slice_grid(9), (3, 3));
    }

    #[test]
    fn gemm_traffic_is_global() {
        let cfg = ClusterConfig::tiny();
        let p = GemmParams { m: 16, n: 16, k: 16 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(10_000_000);
        // Loads must hit every hierarchy level, incl. remote Groups.
        assert!(stats.reqs_per_class[3] > 0, "no remote-group traffic?");
        assert!(stats.reqs_per_class[1] > 0);
    }

    #[test]
    fn gemm_respects_tx_table_window() {
        // Exactly 8 loads between FMA batches — the inner loop never
        // overflows the 8-entry table (the paper's break-even analysis).
        // Only the trailing stores/barrier may briefly fill it.
        let cfg = ClusterConfig::tiny();
        let p = GemmParams { m: 8, n: 8, k: 8 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(1_000_000);
        assert!(
            stats.fraction(stats.stall_lsu) < 0.01,
            "LSU-full stalls: {}",
            stats.fraction(stats.stall_lsu)
        );
    }
}
