//! GEMM — the *global-access* kernel (Sec. 7): `C = A · B`, 4×4 register
//! blocking.
//!
//! Mirrors the paper's tiled Snitch implementation: each PE owns a set of
//! 4×4 output blocks ("the maximum supported by 32 ISA registers"); per
//! K-step it issues 8 non-blocking loads (4 of A, 4 of B — at most 8 input
//! transactions, the transaction-table break-even of Sec. 4.1) followed by
//! 16 FMAs. Operand fetches sweep all banks through the shared
//! interconnect, which is what drags IPC from ~0.85 to ~0.70 in Fig. 14a
//! and makes the measured AMAT line up with the Sec. 3 random-traffic
//! model.

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, Scale};
use crate::isa::Program;
use crate::report::Verdict;

use super::{allclose_verdict, chunk_range, Alloc, Staged, StagedIo, Workload};

const BM: usize = 4;
const BN: usize = 4;
// Register map: r1..r4 A operands, r5..r8 B operands, r12..r27 the 4×4
// accumulator block.
const R_A: u8 = 1;
const R_B: u8 = 5;
const R_ACC: u8 = 12;

#[derive(Debug, Clone, Copy)]
pub struct GemmParams {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Default for GemmParams {
    fn default() -> Self {
        GemmParams { m: 256, n: 256, k: 256 }
    }
}

/// Deterministic inputs (reproduced on the JAX side by the harness).
pub fn input_a(p: &GemmParams) -> Vec<f32> {
    (0..p.m * p.k).map(|i| ((i % 11) as f32) * 0.25 - 1.25).collect()
}
pub fn input_b(p: &GemmParams) -> Vec<f32> {
    (0..p.k * p.n).map(|i| ((i % 9) as f32) * 0.125 - 0.5).collect()
}

/// [`Workload`] registration: GEMM with pinned or scale-resolved edge
/// (256³ full / 128³ fast — the Fig. 14a sizes).
#[derive(Default)]
pub struct Gemm(pub Option<GemmParams>);

impl Gemm {
    pub fn with(p: GemmParams) -> Self {
        Gemm(Some(p))
    }
    fn resolve(&self, _cfg: &ClusterConfig, scale: Scale) -> GemmParams {
        self.0.unwrap_or({
            let e = scale.pick(256, 128);
            GemmParams { m: e, n: e, k: e }
        })
    }
}

impl Workload for Gemm {
    fn kind(&self) -> &'static str {
        "gemm"
    }
    fn describe(&self) -> &'static str {
        "global-access 4x4-register-blocked MatMul (Fig. 14a, Table 6)"
    }
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged {
        build(cfg, &self.resolve(cfg, scale))
    }
    fn check(
        &self,
        cfg: &ClusterConfig,
        scale: Scale,
        cl: &Cluster,
        io: &StagedIo,
    ) -> Verdict {
        let p = self.resolve(cfg, scale);
        match io.read_output(cl) {
            // 2e-2: K-loop phase staggering changes accumulation order.
            Ok(got) => allclose_verdict(&got, &reference(&p), 2e-2, "gemm vs host reference"),
            Err(e) => Verdict::Failed { reason: e.to_string() },
        }
    }
}

pub fn build(cfg: &ClusterConfig, p: &GemmParams) -> Staged {
    build_band(cfg, p, 0, 1, true).0
}

/// Placement of one cluster's block-row band inside the full problem —
/// what the system layer needs to wire the band into the scale-out
/// schedule (where the shared B lives for the halo broadcast, which C
/// rows to merge into the main-memory image).
#[derive(Debug, Clone, Copy)]
pub struct GemmBand {
    /// First C row owned by this band.
    pub row0: usize,
    /// C rows in this band.
    pub rows: usize,
    pub a_base: u32,
    pub b_base: u32,
    pub c_base: u32,
}

/// [`build`] restricted to block-row band `part` of `parts`: the cluster
/// computes C rows `[row0, row0 + rows)` from its own A band and a full
/// copy of B. The A band and (when `stage_b`) B are staged locally;
/// non-root clusters of a system run pass `stage_b = false` and receive
/// B over the inter-cluster links instead (same bytes — staging is the
/// functional delivery, the links carry the timing/traffic). Layout is
/// compact (band-sized A and C), so split clusters with proportionally
/// smaller L1s still fit the full-scale problem.
pub fn build_band(
    cfg: &ClusterConfig,
    p: &GemmParams,
    part: usize,
    parts: usize,
    stage_b: bool,
) -> (Staged, GemmBand) {
    assert!(p.m % BM == 0 && p.n % BN == 0, "4x4 blocking requires 4|M, 4|N");
    let blocks_m_total = p.m / BM;
    let band = chunk_range(blocks_m_total, part, parts);
    let blocks_m = band.end - band.start;
    assert!(blocks_m > 0, "band {part}/{parts} of {blocks_m_total} block-rows is empty");
    let (row0, rows) = (band.start * BM, blocks_m * BM);
    let npes = cfg.num_pes();

    let mut alloc = Alloc::new(cfg);
    let ab = alloc.alloc((rows * p.k) as u32);
    let bb = alloc.alloc((p.k * p.n) as u32);
    let cb = alloc.alloc((rows * p.n) as u32);

    let blocks_n = p.n / BN;
    let nblocks = blocks_m * blocks_n;

    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let mut t = Program::new();
        // Stagger each PE's K-loop starting phase. Without this, the PEs
        // sharing a block-column fetch the *same* four B words in
        // lockstep, hammering four banks per step (the classic broadcast
        // hotspot; the paper's hand-tuned kernels use the same cyclic
        // offset trick). FP accumulation order changes, not the result
        // set (tolerances in the golden comparison absorb it).
        let phase = (pe * 17) % p.k;
        for blk in chunk_range(nblocks, pe, npes) {
            let (bi, bj) = (blk / blocks_n, blk % blocks_n);
            // Zero the accumulator block.
            for r in 0..(BM * BN) as u8 {
                t.ld_imm(R_ACC + r, 0.0);
            }
            for kk0 in 0..p.k {
                let kk = (kk0 + phase) % p.k;
                for u in 0..BM {
                    // Band-local row: the A/C arrays hold only this
                    // band's rows.
                    let row = bi * BM + u;
                    t.ld(R_A + u as u8, ab + (row * p.k + kk) as u32);
                }
                for v in 0..BN {
                    let col = bj * BN + v;
                    t.ld(R_B + v as u8, bb + (kk * p.n + col) as u32);
                }
                for u in 0..BM {
                    for v in 0..BN {
                        t.fmac(R_ACC + (u * BN + v) as u8, R_A + u as u8, R_B + v as u8);
                    }
                }
                t.alu(); // k-pointer bump
                t.branch();
            }
            for u in 0..BM {
                for v in 0..BN {
                    let row = bi * BM + u;
                    let col = bj * BN + v;
                    t.st(R_ACC + (u * BN + v) as u8, cb + (row * p.n + col) as u32);
                }
            }
        }
        t.barrier(0);
        t.halt();
        programs.push(t);
    }

    let a_band = input_a(p)[row0 * p.k..(row0 + rows) * p.k].to_vec();
    let mut inputs = vec![(ab, a_band)];
    if stage_b {
        inputs.push((bb, input_b(p)));
    }
    let name = if parts == 1 {
        format!("gemm-{}x{}x{}", p.m, p.n, p.k)
    } else {
        format!("gemm-{}x{}x{}[{part}/{parts}]", p.m, p.n, p.k)
    };
    let staged = Staged {
        name,
        programs,
        inputs,
        output_base: cb,
        output_len: rows * p.n,
        flops: 2 * (rows * p.n * p.k) as u64,
        dma: None,
    };
    (staged, GemmBand { row0, rows, a_base: ab, b_base: bb, c_base: cb })
}

/// Host-side reference.
pub fn reference(p: &GemmParams) -> Vec<f32> {
    let a = input_a(p);
    let b = input_b(p);
    let mut c = vec![0.0f32; p.m * p.n];
    for i in 0..p.m {
        for kk in 0..p.k {
            let av = a[i * p.k + kk];
            for j in 0..p.n {
                c[i * p.n + j] += av * b[kk * p.n + j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_computes_correctly_on_tiny_cluster() {
        let cfg = ClusterConfig::tiny();
        let p = GemmParams { m: 16, n: 16, k: 24 };
        let want = reference(&p);
        let (mut cl, io) = build(&cfg, &p).into_cluster(cfg);
        cl.run(10_000_000);
        let got = io.read_output(&cl).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-3, "C[{i}] = {g}, want {w}");
        }
    }

    #[test]
    fn gemm_traffic_is_global() {
        let cfg = ClusterConfig::tiny();
        let p = GemmParams { m: 16, n: 16, k: 16 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(10_000_000);
        // Loads must hit every hierarchy level, incl. remote Groups.
        assert!(stats.reqs_per_class[3] > 0, "no remote-group traffic?");
        assert!(stats.reqs_per_class[1] > 0);
    }

    #[test]
    fn gemm_respects_tx_table_window() {
        // Exactly 8 loads between FMA batches — the inner loop never
        // overflows the 8-entry table (the paper's break-even analysis).
        // Only the trailing stores/barrier may briefly fill it.
        let cfg = ClusterConfig::tiny();
        let p = GemmParams { m: 8, n: 8, k: 8 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(1_000_000);
        assert!(
            stats.fraction(stats.stall_lsu) < 0.01,
            "LSU-full stalls: {}",
            stats.fraction(stats.stall_lsu)
        );
    }
}
