//! FFT — the *non-sequential access* kernel (Sec. 7): radix-4
//! decimation-in-frequency Cooley-Tukey, 64 independent 4096-point
//! transforms run in parallel, each stage computed between barriers.
//!
//! In the k-th stage each butterfly takes 4 inputs at stride N/4^(k+1):
//! early stages reach across SubGroups/Groups, late stages are Tile-local
//! — exactly the AMAT range (1.36–9.18 cycles across stages) the paper
//! reports. Complex values are stored as separate re/im f32 planes (the
//! f32 stand-in for the paper's Complex32 16-bit pairs). The DIF network
//! leaves results digit-reversed; a final in-place swap pass (base-4 digit
//! reversal is an involution) restores natural order, so the L1 image is
//! directly comparable against the `fft.hlo.txt` golden artifact.

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, Scale};
use crate::isa::Program;
use crate::report::Verdict;

use super::{chunk_range, Alloc, Staged, StagedIo, Workload};

#[derive(Debug, Clone, Copy)]
pub struct FftParams {
    /// Number of independent transforms.
    pub batch: usize,
    /// Transform length; must be a power of 4.
    pub n: usize,
}

impl Default for FftParams {
    fn default() -> Self {
        FftParams { batch: 64, n: 4096 }
    }
}

/// Base-4 digit reversal of `k` over `m` digits.
pub fn digit_reverse(mut k: usize, m: usize) -> usize {
    let mut r = 0;
    for _ in 0..m {
        r = (r << 2) | (k & 3);
        k >>= 2;
    }
    r
}

/// Deterministic pseudo-inputs.
pub fn input_re(p: &FftParams) -> Vec<f32> {
    (0..p.batch * p.n)
        .map(|i| ((i % 17) as f32) * 0.25 - 2.0)
        .collect()
}
pub fn input_im(p: &FftParams) -> Vec<f32> {
    (0..p.batch * p.n)
        .map(|i| ((i % 5) as f32) * 0.5 - 1.0)
        .collect()
}

// Register map (re/im pairs):
// x0..x3 → r1..r8, t0..t3 → r9..r16, w1..w3 → r17..r22, tmp → r23..r26.
const RX: u8 = 1;
const RT: u8 = 9;
const RW: u8 = 17;
const RY: u8 = 23;

/// Twiddle-table replicas (breaks the shared-table bank hotspot).
pub const TW_COPIES: usize = 16;

/// [`Workload`] registration: batched radix-4 FFT with pinned or
/// scale-resolved shape (64×4096 full / 16×1024 fast).
#[derive(Default)]
pub struct Fft(pub Option<FftParams>);

impl Fft {
    pub fn with(p: FftParams) -> Self {
        Fft(Some(p))
    }
    fn resolve(&self, _cfg: &ClusterConfig, scale: Scale) -> FftParams {
        self.0.unwrap_or(FftParams {
            batch: scale.pick(64, 16),
            n: scale.pick(4096, 1024),
        })
    }
}

impl Workload for Fft {
    fn kind(&self) -> &'static str {
        "fft"
    }
    fn describe(&self) -> &'static str {
        "batched radix-4 DIF Cooley-Tukey, all-hierarchy strides (Fig. 14a)"
    }
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged {
        build(cfg, &self.resolve(cfg, scale))
    }
    fn check(
        &self,
        cfg: &ClusterConfig,
        scale: Scale,
        cl: &Cluster,
        io: &StagedIo,
    ) -> Verdict {
        let p = self.resolve(cfg, scale);
        // The host reference is a naive O(n²) DFT — refuse shapes where
        // it would take longer than the simulation itself.
        if p.batch * p.n * p.n > 1usize << 29 {
            return Verdict::NotChecked;
        }
        let got_re = match io.read_output(cl) {
            Ok(v) => v,
            Err(e) => return Verdict::Failed { reason: e.to_string() },
        };
        let got_im = cl.l1.read_slice(io.output_base + im_plane_offset(cfg, &p), p.batch * p.n);
        let (want_re, want_im) = reference(&p);
        match super::allclose_verdict(&got_re, &want_re, 5e-2, "fft re-plane vs host DFT") {
            Verdict::Passed { .. } => {
                super::allclose_verdict(&got_im, &want_im, 5e-2, "fft re+im planes vs host DFT")
            }
            failed => failed,
        }
    }
}

pub fn build(cfg: &ClusterConfig, p: &FftParams) -> Staged {
    build_band(cfg, p, 0, 1, true).0
}

/// Placement of one cluster's frame band inside the full batch — what
/// the system layer needs for the twiddle halo broadcast and the
/// re/im-plane merge into the main-memory image.
#[derive(Debug, Clone, Copy)]
pub struct FftBand {
    /// First transform frame owned by this band.
    pub f0: usize,
    /// Frames in this band.
    pub frames: usize,
    pub re_base: u32,
    pub im_base: u32,
    pub tw_re_base: u32,
    pub tw_im_base: u32,
    /// Words per twiddle plane (`copies * n`).
    pub tw_words: usize,
}

/// [`build`] restricted to frame band `part` of `parts`: the cluster
/// transforms frames `[f0, f0 + frames)` out of the full batch, with
/// band-sized re/im planes. The twiddle table is staged locally only
/// when `stage_tw` (cluster 0 of a system run); the other clusters
/// receive it over the inter-cluster links. For `parts > 1` the replica
/// count scales with the cluster's PE count (`npes/64`, clamped to
/// [1, TW_COPIES]) instead of the flat TW_COPIES — a split cluster has
/// proportionally fewer PEs hammering the table *and* proportionally
/// less L1 to hold replicas in; `parts == 1` keeps the legacy flat
/// count so single-cluster runs stay bit-identical.
pub fn build_band(
    cfg: &ClusterConfig,
    p: &FftParams,
    part: usize,
    parts: usize,
    stage_tw: bool,
) -> (Staged, FftBand) {
    build_band_slice(cfg, p, part, parts, 0, 1, stage_tw)
}

/// [`build_band`] restricted further to frame slice `slice` of `slices`
/// within the band — the full band is the 1-slice case (exactly what
/// [`build_band`] delegates to). Frames are independent transforms, so
/// any frame partition computes bit-identical planes; the pipelined
/// system engine runs a cluster's slices back-to-back, staging slice
/// `t+1`'s frames while slice `t` computes. The twiddle replica count
/// stays a function of `(cfg, parts)` alone, so every slice instance of
/// a cluster lays its table out identically.
pub fn build_band_slice(
    cfg: &ClusterConfig,
    p: &FftParams,
    part: usize,
    parts: usize,
    slice: usize,
    slices: usize,
    stage_tw: bool,
) -> (Staged, FftBand) {
    let n = p.n;
    let mut m = 0;
    while 1usize << (2 * m) < n {
        m += 1;
    }
    assert_eq!(1usize << (2 * m), n, "FFT length must be a power of 4");
    let band = chunk_range(p.batch, part, parts);
    let sub = chunk_range(band.end - band.start, slice, slices);
    let (f0, lb) = (band.start + sub.start, sub.end - sub.start);
    assert!(
        lb > 0,
        "slice {slice}/{slices} of band {part}/{parts} of {} frames is empty",
        p.batch
    );
    let npes = cfg.num_pes();

    // Replicate the twiddle table: PEs index copy `pe % tw_copies`,
    // rotating the hot entries across banks (real deployments hold the
    // per-stage twiddles in registers or Tile-private memory; a shared
    // single-copy table would serialize every butterfly on bank 0).
    let tw_copies = if parts == 1 {
        TW_COPIES.min(npes).max(1)
    } else {
        TW_COPIES.min(npes.div_ceil(64)).max(1)
    };
    let mut alloc = Alloc::new(cfg);
    let xr = alloc.alloc((lb * n) as u32);
    let xi = alloc.alloc((lb * n) as u32);
    let twr = alloc.alloc((tw_copies * n) as u32);
    let twi = alloc.alloc((tw_copies * n) as u32);

    // Twiddle table W_N^k = e^{-2πik/N}, stored *copy-interleaved*
    // (entry e of copy c at word e·copies + c) so the replicas of a hot
    // entry land in `tw_copies` distinct banks.
    let tw1: Vec<f32> = (0..n)
        .map(|k| (-2.0 * std::f64::consts::PI * k as f64 / n as f64).cos() as f32)
        .collect();
    let tw2: Vec<f32> = (0..n)
        .map(|k| (-2.0 * std::f64::consts::PI * k as f64 / n as f64).sin() as f32)
        .collect();
    let mut tw_re = vec![0.0f32; tw_copies * n];
    let mut tw_im = vec![0.0f32; tw_copies * n];
    for e in 0..n {
        for c in 0..tw_copies {
            tw_re[e * tw_copies + c] = tw1[e];
            tw_im[e * tw_copies + c] = tw2[e];
        }
    }

    let bpf = n / 4; // butterflies per transform per stage
    let total_bf = lb * bpf;

    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let mut t = Program::new();
        let mut next_barrier: u16 = 0;

        for s in 0..m {
            let ns = n >> (2 * s); // current sub-transform size
            let q = ns / 4;
            let blocks = 1usize << (2 * s); // sub-transforms this stage
            let stride4s = blocks;
            // j-major butterfly order: butterflies sharing a twiddle
            // triple (same j, different block) run back-to-back, so the
            // 6 twiddle loads amortize over `blocks` butterflies — the
            // register-reuse structure of the paper's "4 butterflies per
            // core" inner loop. Tracks the last loaded j per PE.
            let mut last_j = usize::MAX;
            for g in chunk_range(total_bf, pe, npes) {
                let (f, bidx) = (g / bpf, g % bpf);
                let (j, b) = (bidx / blocks, bidx % blocks);
                let base = (f * n + b * ns + j) as u32;
                let idx = |quarter: usize| base + (quarter * q) as u32;

                // Loads: 4 complex points (+ 3 complex twiddles when j
                // changed).
                for quarter in 0..4u8 {
                    t.ld(RX + 2 * quarter, xr + idx(quarter as usize));
                    t.ld(RX + 2 * quarter + 1, xi + idx(quarter as usize));
                }
                // j = 0 ⇒ all three twiddles are W^0 = 1: materialize
                // immediates instead of loading (the standard special
                // case; also removes the tw[0] hotspot of late stages).
                let copy = (pe % tw_copies) as u32;
                if j != last_j {
                    last_j = j;
                    for r in 1..4u8 {
                        let e = (j * r as usize * stride4s) as u32;
                        if j == 0 {
                            t.ld_imm(RW + 2 * (r - 1), 1.0);
                            t.ld_imm(RW + 2 * (r - 1) + 1, 0.0);
                        } else {
                            let w = e * tw_copies as u32 + copy;
                            t.ld(RW + 2 * (r - 1), twr + w);
                            t.ld(RW + 2 * (r - 1) + 1, twi + w);
                        }
                    }
                }
                // t0 = x0+x2, t1 = x1+x3, t2 = x0-x2, t3 = x1-x3.
                t.add(RT, RX, RX + 4);
                t.add(RT + 1, RX + 1, RX + 5);
                t.add(RT + 2, RX + 2, RX + 6);
                t.add(RT + 3, RX + 3, RX + 7);
                t.sub(RT + 4, RX, RX + 4);
                t.sub(RT + 5, RX + 1, RX + 5);
                t.sub(RT + 6, RX + 2, RX + 6);
                t.sub(RT + 7, RX + 3, RX + 7);
                let (t0r, t0i, t1r, t1i) = (RT, RT + 1, RT + 2, RT + 3);
                let (t2r, t2i, t3r, t3i) = (RT + 4, RT + 5, RT + 6, RT + 7);

                // u0 = t0 + t1 → position 0 (no twiddle).
                t.add(RY, t0r, t1r);
                t.add(RY + 1, t0i, t1i);
                t.st(RY, xr + idx(0));
                t.st(RY + 1, xi + idx(0));

                // Complex multiply helper: (ar,ai)·(wr,wi) → (RY+2, RY+3).
                let cmul_store = |t: &mut Program, ar: u8, ai: u8, w: u8, pos: u32| {
                    let (wr, wi) = (RW + 2 * w, RW + 2 * w + 1);
                    t.mul(RY + 2, ar, wr);
                    t.fnmac(RY + 2, ai, wi); // re = ar·wr − ai·wi
                    t.mul(RY + 3, ar, wi);
                    t.fmac(RY + 3, ai, wr); // im = ar·wi + ai·wr
                    t.st(RY + 2, xr + pos);
                    t.st(RY + 3, xi + pos);
                };

                // u1 = (t2 − i·t3)·W^j → position 1.
                t.add(RY, t2r, t3i);
                t.sub(RY + 1, t2i, t3r);
                cmul_store(&mut t, RY, RY + 1, 0, idx(1));
                // u2 = (t0 − t1)·W^2j → position 2.
                t.sub(RY, t0r, t1r);
                t.sub(RY + 1, t0i, t1i);
                cmul_store(&mut t, RY, RY + 1, 1, idx(2));
                // u3 = (t2 + i·t3)·W^3j → position 3.
                t.sub(RY, t2r, t3i);
                t.add(RY + 1, t2i, t3r);
                cmul_store(&mut t, RY, RY + 1, 2, idx(3));

                t.alu(); // butterfly index bookkeeping
                t.branch();
            }
            t.barrier(next_barrier);
            next_barrier += 1;
        }

        // Final pass: in-place base-4 digit-reversal (an involution —
        // each PE swaps its share of k < rev(k) pairs).
        let swap_pairs: Vec<usize> = (0..n).filter(|&k| digit_reverse(k, m) > k).collect();
        let total_swaps = lb * swap_pairs.len();
        for g in chunk_range(total_swaps, pe, npes) {
            let (f, si) = (g / swap_pairs.len(), g % swap_pairs.len());
            let k = swap_pairs[si];
            let r = digit_reverse(k, m);
            let (ka, ra) = ((f * n + k) as u32, (f * n + r) as u32);
            t.ld(RX, xr + ka);
            t.ld(RX + 1, xi + ka);
            t.ld(RX + 2, xr + ra);
            t.ld(RX + 3, xi + ra);
            t.st(RX, xr + ra);
            t.st(RX + 1, xi + ra);
            t.st(RX + 2, xr + ka);
            t.st(RX + 3, xi + ka);
            t.alu();
            t.branch();
        }
        t.barrier(next_barrier);
        t.halt();
        programs.push(t);
    }

    // Butterfly FLOP count: per butterfly 3 cmul (6 mul + 6 add/sub eqv →
    // using FMA: 34 f32 ops) — report the classic 8·N·log4(N) complex-op
    // convention scaled to real ops.
    let flops = (lb * m * bpf) as u64 * 34;

    let mut inputs = vec![
        (xr, input_re(p)[f0 * n..(f0 + lb) * n].to_vec()),
        (xi, input_im(p)[f0 * n..(f0 + lb) * n].to_vec()),
    ];
    if stage_tw {
        inputs.push((twr, tw_re));
        inputs.push((twi, tw_im));
    }
    let shape = format!("fft-{}x{}", p.batch, n);
    let name = match (parts, slices) {
        (1, 1) => shape,
        (_, 1) => format!("{shape}[{part}/{parts}]"),
        _ => format!("{shape}[{part}/{parts}]~{slice}/{slices}"),
    };
    let staged = Staged {
        name,
        programs,
        inputs,
        output_base: xr,
        output_len: lb * n, // re plane; im plane follows at xi
        flops,
        dma: None,
    };
    let band = FftBand {
        f0,
        frames: lb,
        re_base: xr,
        im_base: xi,
        tw_re_base: twr,
        tw_im_base: twi,
        tw_words: tw_copies * n,
    };
    (staged, band)
}

/// Word base of the imaginary output plane (planes are allocated
/// back-to-back when `batch·n` is a multiple of the bank count).
pub fn im_plane_offset(cfg: &ClusterConfig, p: &FftParams) -> u32 {
    let nb = cfg.num_banks() as u32;
    ((p.batch * p.n) as u32).div_ceil(nb) * nb
}

/// Host-side naive DFT reference (O(n²); for small test sizes).
pub fn reference(p: &FftParams) -> (Vec<f32>, Vec<f32>) {
    let xr = input_re(p);
    let xi = input_im(p);
    let mut or_ = vec![0.0f32; p.batch * p.n];
    let mut oi = vec![0.0f32; p.batch * p.n];
    for f in 0..p.batch {
        for k in 0..p.n {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for j in 0..p.n {
                let ang = -2.0 * std::f64::consts::PI * (k * j % p.n) as f64 / p.n as f64;
                let (c, s) = (ang.cos(), ang.sin());
                let (a, b) = (xr[f * p.n + j] as f64, xi[f * p.n + j] as f64);
                sr += a * c - b * s;
                si += a * s + b * c;
            }
            or_[f * p.n + k] = sr as f32;
            oi[f * p.n + k] = si as f32;
        }
    }
    (or_, oi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digit_reverse_involution() {
        for m in 1..=6 {
            let n = 1 << (2 * m);
            for k in 0..n {
                assert_eq!(digit_reverse(digit_reverse(k, m), m), k);
            }
        }
    }

    #[test]
    fn fft_matches_naive_dft_on_tiny_cluster() {
        let cfg = ClusterConfig::tiny();
        let p = FftParams { batch: 2, n: 64 };
        let (want_r, want_i) = reference(&p);
        let setup = build(&cfg, &p);
        let im_off = im_plane_offset(&cfg, &p);
        let (mut cl, io) = setup.into_cluster(cfg);
        cl.run(10_000_000);
        let got_r = io.read_output(&cl).unwrap();
        let got_i = cl.l1.read_slice(io.output_base + im_off, p.batch * p.n);
        for i in 0..p.batch * p.n {
            assert!(
                (got_r[i] - want_r[i]).abs() < 2e-2,
                "re[{i}] = {} want {}",
                got_r[i],
                want_r[i]
            );
            assert!(
                (got_i[i] - want_i[i]).abs() < 2e-2,
                "im[{i}] = {} want {}",
                got_i[i],
                want_i[i]
            );
        }
    }

    #[test]
    fn fft_frame_slices_match_the_host_reference_frames() {
        // Each frame slice of band 0 of 2 must transform exactly its
        // frames of the full batch — the per-slice functional check the
        // pipelined system engine relies on.
        let cfg = ClusterConfig::tiny();
        let p = FftParams { batch: 4, n: 64 };
        let (want_r, want_i) = reference(&p);
        for slice in 0..2 {
            let (staged, band) = build_band_slice(&cfg, &p, 0, 2, slice, 2, true);
            let (mut cl, io) = staged.into_cluster(cfg.clone());
            cl.run(10_000_000);
            let got_r = io.read_output(&cl).unwrap();
            let got_i = cl.l1.read_slice(band.im_base, band.frames * p.n);
            assert_eq!(got_r.len(), band.frames * p.n);
            for i in 0..band.frames * p.n {
                let gi = band.f0 * p.n + i;
                assert!(
                    (got_r[i] - want_r[gi]).abs() < 2e-2,
                    "slice {slice} re[{i}] = {} want {}",
                    got_r[i],
                    want_r[gi]
                );
                assert!(
                    (got_i[i] - want_i[gi]).abs() < 2e-2,
                    "slice {slice} im[{i}] = {} want {}",
                    got_i[i],
                    want_i[gi]
                );
            }
        }
    }

    #[test]
    fn fft_impulse_gives_flat_spectrum() {
        // Impulse at 0 → all-ones spectrum, robust end-to-end smoke.
        let cfg = ClusterConfig::tiny();
        let p = FftParams { batch: 1, n: 16 };
        let mut setup = build(&cfg, &p);
        // Override the inputs with the impulse.
        let mut re = vec![0.0f32; p.n];
        re[0] = 1.0;
        setup.inputs[0].1 = re;
        setup.inputs[1].1 = vec![0.0f32; p.n];
        let im_off = im_plane_offset(&cfg, &p);
        let (mut cl, io) = setup.into_cluster(cfg);
        cl.run(1_000_000);
        let got_r = io.read_output(&cl).unwrap();
        let got_i = cl.l1.read_slice(io.output_base + im_off, p.n);
        for k in 0..p.n {
            assert!((got_r[k] - 1.0).abs() < 1e-4, "re[{k}]={}", got_r[k]);
            assert!(got_i[k].abs() < 1e-4, "im[{k}]={}", got_i[k]);
        }
    }

    #[test]
    fn fft_stage_strides_reach_remote_levels() {
        let cfg = ClusterConfig::tiny();
        let p = FftParams { batch: 4, n: 256 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(50_000_000);
        // Early-stage strides cross Tiles; the kernel must exercise
        // non-local classes.
        let remote: u64 = stats.reqs_per_class[1] + stats.reqs_per_class[2]
            + stats.reqs_per_class[3];
        assert!(remote > 0, "FFT should generate non-local traffic");
    }
}
