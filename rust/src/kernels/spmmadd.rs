//! SpMMadd — the *irregular access* kernel (Sec. 7): element-wise addition
//! of two sparse matrices in CSR format, the GraphBLAS `C = A ⊕ B`
//! workload used to stress the interconnect with narrow, data-dependent,
//! branch-heavy accesses.
//!
//! Rows are distributed over PEs; each row performs a sorted two-way merge
//! of the A and B column lists. The *executed path* is fixed by the trace
//! builder (standard trace-driven simulation — it knows the matrices), but
//! every index/value still travels through the simulated L1, and the
//! compare feeding each branch is a register op dependent on the loaded
//! indices, so the RAW stalls the paper attributes to short dependence
//! chains + limited unrolling appear naturally, landing IPC near 0.53.

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, Scale};
use crate::isa::Program;
use crate::report::Verdict;
use crate::rng::Rng;

use super::{allclose_verdict, Alloc, Staged, StagedIo, Workload};

/// A host-side CSR matrix (indices stored as exactly-representable f32 in
/// L1 — all indices < 2^24).
#[derive(Debug, Clone)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Random sparse matrix with ~`nnz_per_row` entries per row.
    pub fn random(rows: usize, cols: usize, nnz_per_row: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut row_ptr = vec![0u32];
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for _ in 0..rows {
            let k = rng.gen_range(2 * nnz_per_row + 1);
            let mut cols_r: Vec<u32> =
                (0..k).map(|_| rng.gen_range(cols) as u32).collect();
            cols_r.sort_unstable();
            cols_r.dedup();
            for c in cols_r {
                col_idx.push(c);
                values.push(rng.range(-8, 8) as f32 * 0.25);
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    /// Densified form (for comparison against the `spmmadd` artifact).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                d[r * self.cols + self.col_idx[i] as usize] += self.values[i];
            }
        }
        d
    }

    /// Host-side merge: C = A + B.
    pub fn add(&self, other: &Csr) -> Csr {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        let mut row_ptr = vec![0u32];
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for r in 0..self.rows {
            let (mut ia, ea) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            let (mut ib, eb) = (other.row_ptr[r] as usize, other.row_ptr[r + 1] as usize);
            while ia < ea || ib < eb {
                let ca = if ia < ea { self.col_idx[ia] } else { u32::MAX };
                let cb = if ib < eb { other.col_idx[ib] } else { u32::MAX };
                if ca == cb {
                    col_idx.push(ca);
                    values.push(self.values[ia] + other.values[ib]);
                    ia += 1;
                    ib += 1;
                } else if ca < cb {
                    col_idx.push(ca);
                    values.push(self.values[ia]);
                    ia += 1;
                } else {
                    col_idx.push(cb);
                    values.push(other.values[ib]);
                    ib += 1;
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr { rows: self.rows, cols: self.cols, row_ptr, col_idx, values }
    }
}

/// Seed of the canonical golden workload (mirrored by
/// `python/compile/rng.py::SPMMADD_SEED`, which regenerates the same
/// matrices for `artifacts/spmmadd.golden.bin`).
pub const CANONICAL_SEED: u64 = 0x5EED;
/// How B's seed derives from A's (`SPMMADD_SEED_B_XOR` in the port).
pub const SEED_B_XOR: u64 = 0xFFFF_0000;
/// Non-zeros per row of the canonical workload.
pub const CANONICAL_NNZ_PER_ROW: usize = 8;

/// The canonical CSR pair (A, B) at the given shape — exactly the
/// matrices the Python port densifies for the spmmadd golden.
pub fn canonical_csr_pair(rows: usize, cols: usize) -> (Csr, Csr) {
    (
        Csr::random(rows, cols, CANONICAL_NNZ_PER_ROW, CANONICAL_SEED),
        Csr::random(rows, cols, CANONICAL_NNZ_PER_ROW, CANONICAL_SEED ^ SEED_B_XOR),
    )
}

/// Densified A + B of the canonical pair: the exact contents of
/// `artifacts/spmmadd.golden.bin` (quarters with ≤ 2 addends per cell —
/// no rounding, so comparisons against the golden are bit-exact).
pub fn canonical_dense_sum(rows: usize, cols: usize) -> Vec<f32> {
    let (a, b) = canonical_csr_pair(rows, cols);
    let mut sum = a.to_dense();
    for (s, x) in sum.iter_mut().zip(b.to_dense()) {
        *s += x;
    }
    sum
}

#[derive(Debug, Clone)]
pub struct SpmmaddParams {
    pub rows: usize,
    pub cols: usize,
    pub nnz_per_row: usize,
    pub seed: u64,
}

impl Default for SpmmaddParams {
    fn default() -> Self {
        SpmmaddParams { rows: 4096, cols: 4096, nnz_per_row: 16, seed: 0x5EED }
    }
}

/// CSR array layout in L1 (word bases), plus the host-side matrices —
/// computable without emitting any per-PE programs ([`layout_for`]), so
/// reference checks don't pay the trace-generation cost twice.
pub struct SpmmaddLayout {
    pub a: Csr,
    pub b: Csr,
    pub c_ref: Csr,
    pub a_col_base: u32,
    pub a_val_base: u32,
    pub b_col_base: u32,
    pub b_val_base: u32,
    pub c_col_base: u32,
    pub c_val_base: u32,
}

/// Deterministic matrices + L1 word bases for `p` — the staging layout
/// [`build_with_layout`] uses, without building the instruction traces.
pub fn layout_for(cfg: &ClusterConfig, p: &SpmmaddParams) -> SpmmaddLayout {
    let a = Csr::random(p.rows, p.cols, p.nnz_per_row, p.seed);
    let b = Csr::random(p.rows, p.cols, p.nnz_per_row, p.seed ^ SEED_B_XOR);
    let c = a.add(&b);
    let mut alloc = Alloc::new(cfg);
    let a_col_base = alloc.alloc(a.nnz() as u32);
    let a_val_base = alloc.alloc(a.nnz() as u32);
    let b_col_base = alloc.alloc(b.nnz() as u32);
    let b_val_base = alloc.alloc(b.nnz() as u32);
    let c_col_base = alloc.alloc(c.nnz() as u32);
    let c_val_base = alloc.alloc(c.nnz() as u32);
    SpmmaddLayout {
        a,
        b,
        c_ref: c,
        a_col_base,
        a_val_base,
        b_col_base,
        b_val_base,
        c_col_base,
        c_val_base,
    }
}

// Registers: r1 = A col, r2 = B col, r3 = cmp, r4 = A val, r5 = B val,
// r6 = out val.
const RA_COL: u8 = 1;
const RB_COL: u8 = 2;
const R_CMP: u8 = 3;
const RA_VAL: u8 = 4;
const RB_VAL: u8 = 5;
const R_OUT: u8 = 6;
// Burst-mode (cfg.burst) register windows, r8..r31: the four CSR
// streams are cached MAX_BURST_WORDS entries at a time (one burst load
// per refill instead of one word load per merge step), and outputs are
// buffered and drained with burst stores.
const RA_W: u8 = 8; // A col window
const RB_W: u8 = 12; // B col window
const RAV_W: u8 = 16; // A val window
const RBV_W: u8 = 20; // B val window
const RCV_O: u8 = 24; // C val output buffer
const RCC_O: u8 = 28; // C col output buffer

/// [`Workload`] registration: CSR SpMMadd with pinned or scale-resolved
/// shape (4096²/nnz 16 full, 2048² fast — the Fig. 14a sizes).
#[derive(Default)]
pub struct Spmmadd(pub Option<SpmmaddParams>);

impl Spmmadd {
    pub fn with(p: SpmmaddParams) -> Self {
        Spmmadd(Some(p))
    }
    fn resolve(&self, _cfg: &ClusterConfig, scale: Scale) -> SpmmaddParams {
        self.0.clone().unwrap_or(SpmmaddParams {
            rows: scale.pick(4096, 2048),
            cols: scale.pick(4096, 2048),
            nnz_per_row: 16,
            seed: CANONICAL_SEED,
        })
    }
}

impl Workload for Spmmadd {
    fn kind(&self) -> &'static str {
        "spmmadd"
    }
    fn describe(&self) -> &'static str {
        "CSR sparse matrix add C = A (+) B, irregular/branch-heavy (Fig. 14a)"
    }
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged {
        build(cfg, &self.resolve(cfg, scale))
    }
    fn check(
        &self,
        cfg: &ClusterConfig,
        scale: Scale,
        cl: &Cluster,
        io: &StagedIo,
    ) -> Verdict {
        // Regenerate the deterministic layout (same params → same
        // matrices → same bases) to locate C's value/column arrays —
        // matrices + bases only, no per-PE trace generation.
        let p = self.resolve(cfg, scale);
        let layout = layout_for(cfg, &p);
        let vals = match io.read_output(cl) {
            Ok(v) => v,
            Err(e) => return Verdict::Failed { reason: e.to_string() },
        };
        let cols = cl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
        let want_cols: Vec<f32> = layout.c_ref.col_idx.iter().map(|&c| c as f32).collect();
        match allclose_verdict(&vals, &layout.c_ref.values, 1e-5, "spmmadd C values vs host merge")
        {
            Verdict::Passed { .. } => allclose_verdict(
                &cols,
                &want_cols,
                0.0,
                "spmmadd C values+columns vs host merge",
            ),
            failed => failed,
        }
    }
}

pub fn build_with_layout(cfg: &ClusterConfig, p: &SpmmaddParams) -> (Staged, SpmmaddLayout) {
    let layout = layout_for(cfg, p);
    let (a, b, c) = (&layout.a, &layout.b, &layout.c_ref);
    let npes = cfg.num_pes();
    let (a_col, a_val) = (layout.a_col_base, layout.a_val_base);
    let (b_col, b_val) = (layout.b_col_base, layout.b_val_base);
    let (c_col, c_val) = (layout.c_col_base, layout.c_val_base);

    // Balance rows over PEs by merge work (nnz_a + nnz_b): greedy
    // longest-processing-time assignment. A naive contiguous split leaves
    // PEs with empty rows idling at the barrier (long-tail WFI).
    let mut order: Vec<usize> = (0..p.rows).collect();
    let work = |r: usize| {
        (a.row_ptr[r + 1] - a.row_ptr[r]) + (b.row_ptr[r + 1] - b.row_ptr[r])
    };
    order.sort_by_key(|&r| std::cmp::Reverse(work(r)));
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); npes];
    let mut load = vec![0u32; npes];
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u32, usize)>> =
        (0..npes).map(|i| std::cmp::Reverse((0u32, i))).collect();
    for r in order {
        let std::cmp::Reverse((l, pe)) = heap.pop().unwrap();
        assigned[pe].push(r);
        load[pe] = l + work(r) + 4;
        heap.push(std::cmp::Reverse((load[pe], pe)));
    }

    // TCDM burst mode (cfg.burst): instead of one single-word load per
    // merge step, each CSR stream is cached MAX_BURST_WORDS entries at a
    // time in a register window (one ld_burst per refill for cols, one
    // for vals), and outputs are buffered and drained with st_burst.
    // Windows persist across a PE's (LPT-shuffled, non-contiguous) rows:
    // a refill re-validates whenever the cursor leaves the cached range.
    let bw = crate::isa::MAX_BURST_WORDS;
    let burst = cfg.burst && bw > 1;
    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let mut t = Program::new();
        // Cached [lo, hi) index ranges of the A and B streams currently
        // resident in the col/val register windows (burst mode only).
        let (mut awin, mut bwin) = ((0usize, 0usize), (0usize, 0usize));
        for &r in &assigned[pe] {
            // Row-pointer fetches (values known to the builder; the loads
            // model the CSR bookkeeping traffic — distinct address per
            // row, as in a real row_ptr array).
            t.ld(R_CMP, a_col + a.row_ptr[r].min(a.nnz() as u32 - 1));
            t.ld(R_CMP, b_col + b.row_ptr[r].min(b.nnz() as u32 - 1));
            t.alu(); // end-pointer compare setup
            let (mut ia, ea) = (a.row_ptr[r] as usize, a.row_ptr[r + 1] as usize);
            let (mut ib, eb) = (b.row_ptr[r] as usize, b.row_ptr[r + 1] as usize);
            let mut ic = c.row_ptr[r] as usize;
            // Output burst buffer: C indices [ic0, ic0 + nbuf) are staged
            // in RCV_O/RCC_O and flushed when full or at row end (ic is
            // contiguous within a row, not across LPT-assigned rows).
            let (mut ic0, mut nbuf) = (ic, 0usize);
            while ia < ea || ib < eb {
                let ca = if ia < ea { a.col_idx[ia] } else { u32::MAX };
                let cb = if ib < eb { b.col_idx[ib] } else { u32::MAX };
                // Load the two candidate column indices (when available),
                // compare (dependent ALU), branch on the outcome.
                if ia < ea {
                    if burst {
                        if ia < awin.0 || ia >= awin.1 {
                            let n = bw.min(a.nnz() - ia);
                            t.ld_burst(RA_W, a_col + ia as u32, n as u8);
                            t.ld_burst(RAV_W, a_val + ia as u32, n as u8);
                            awin = (ia, ia + n);
                        }
                    } else {
                        t.ld(RA_COL, a_col + ia as u32);
                    }
                }
                if ib < eb {
                    if burst {
                        if ib < bwin.0 || ib >= bwin.1 {
                            let n = bw.min(b.nnz() - ib);
                            t.ld_burst(RB_W, b_col + ib as u32, n as u8);
                            t.ld_burst(RBV_W, b_val + ib as u32, n as u8);
                            bwin = (ib, ib + n);
                        }
                    } else {
                        t.ld(RB_COL, b_col + ib as u32);
                    }
                }
                if ia < ea && ib < eb {
                    // Waits on both (window) loads.
                    if burst {
                        t.sub(R_CMP, RA_W + (ia - awin.0) as u8, RB_W + (ib - bwin.0) as u8);
                    } else {
                        t.sub(R_CMP, RA_COL, RB_COL);
                    }
                } else {
                    t.alu();
                }
                t.branch();
                if burst {
                    let (ov, oc) = (RCV_O + nbuf as u8, RCC_O + nbuf as u8);
                    if ca == cb {
                        t.add(ov, RAV_W + (ia - awin.0) as u8, RBV_W + (ib - bwin.0) as u8);
                        t.ld_imm(oc, ca as f32);
                        ia += 1;
                        ib += 1;
                    } else if ca < cb {
                        t.mov(ov, RAV_W + (ia - awin.0) as u8);
                        t.ld_imm(oc, ca as f32);
                        ia += 1;
                    } else {
                        t.mov(ov, RBV_W + (ib - bwin.0) as u8);
                        t.ld_imm(oc, cb as f32);
                        ib += 1;
                    }
                    nbuf += 1;
                    if nbuf == bw {
                        t.st_burst(RCV_O, c_val + ic0 as u32, nbuf as u8);
                        t.st_burst(RCC_O, c_col + ic0 as u32, nbuf as u8);
                        ic0 += nbuf;
                        nbuf = 0;
                    }
                } else if ca == cb {
                    t.ld(RA_VAL, a_val + ia as u32);
                    t.ld(RB_VAL, b_val + ib as u32);
                    t.add(R_OUT, RA_VAL, RB_VAL);
                    t.st(R_OUT, c_val + ic as u32);
                    t.ld_imm(R_OUT, ca as f32);
                    t.st(R_OUT, c_col + ic as u32);
                    ia += 1;
                    ib += 1;
                } else if ca < cb {
                    t.ld(RA_VAL, a_val + ia as u32);
                    t.mov(R_OUT, RA_VAL);
                    t.st(R_OUT, c_val + ic as u32);
                    t.ld_imm(R_OUT, ca as f32);
                    t.st(R_OUT, c_col + ic as u32);
                    ia += 1;
                } else {
                    t.ld(RB_VAL, b_val + ib as u32);
                    t.mov(R_OUT, RB_VAL);
                    t.st(R_OUT, c_val + ic as u32);
                    t.ld_imm(R_OUT, cb as f32);
                    t.st(R_OUT, c_col + ic as u32);
                    ib += 1;
                }
                ic += 1;
            }
            if nbuf > 0 {
                // Row-end flush of the partial output buffer (a run may
                // straddle bank/Tile boundaries — the address map splits
                // it into legal consecutive-bank beats).
                t.st_burst(RCV_O, c_val + ic0 as u32, nbuf as u8);
                t.st_burst(RCC_O, c_col + ic0 as u32, nbuf as u8);
            }
            t.branch(); // row-loop backedge
        }
        t.barrier(0);
        t.halt();
        programs.push(t);
    }

    let as_f32 = |v: &[u32]| v.iter().map(|&x| x as f32).collect::<Vec<_>>();
    let setup = Staged {
        name: format!("spmmadd-{}x{}-nnz{}", p.rows, p.cols, a.nnz() + b.nnz()),
        programs,
        inputs: vec![
            (a_col, as_f32(&a.col_idx)),
            (a_val, a.values.clone()),
            (b_col, as_f32(&b.col_idx)),
            (b_val, b.values.clone()),
        ],
        output_base: c_val,
        output_len: c.nnz(),
        flops: c.nnz() as u64, // one add (or move) per output element
        dma: None,
    };
    (setup, layout)
}

pub fn build(cfg: &ClusterConfig, p: &SpmmaddParams) -> Staged {
    build_with_layout(cfg, p).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_csr_add_matches_dense() {
        let a = Csr::random(64, 64, 4, 1);
        let b = Csr::random(64, 64, 4, 2);
        let c = a.add(&b);
        let mut want = a.to_dense();
        for (w, x) in want.iter_mut().zip(b.to_dense()) {
            *w += x;
        }
        assert_eq!(c.to_dense(), want);
    }

    #[test]
    fn spmmadd_values_and_columns_correct_on_cluster() {
        let cfg = ClusterConfig::tiny();
        let p = SpmmaddParams { rows: 128, cols: 128, nnz_per_row: 4, seed: 7 };
        let (setup, layout) = build_with_layout(&cfg, &p);
        let (mut cl, io) = setup.into_cluster(cfg);
        cl.run(10_000_000);
        let vals = io.read_output(&cl).unwrap();
        let cols = cl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
        for (i, (&v, &want)) in vals.iter().zip(&layout.c_ref.values).enumerate() {
            assert!((v - want).abs() < 1e-5, "val[{i}] = {v}, want {want}");
        }
        for (i, (&cgot, &want)) in cols.iter().zip(&layout.c_ref.col_idx).enumerate() {
            assert_eq!(cgot, want as f32, "col[{i}]");
        }
    }

    #[test]
    fn spmmadd_burst_matches_single_word_results() {
        let p = SpmmaddParams { rows: 128, cols: 128, nnz_per_row: 4, seed: 7 };
        let cfg = ClusterConfig::tiny();
        let (setup, layout) = build_with_layout(&cfg, &p);
        let (mut cl, _) = setup.into_cluster(cfg.clone());
        let s = cl.run(10_000_000);

        let bcfg = cfg.with_burst(true);
        let (bsetup, _) = build_with_layout(&bcfg, &p);
        let (mut bl, bio) = bsetup.into_cluster(bcfg);
        let sb = bl.run(10_000_000);

        let vals = bio.read_output(&bl).unwrap();
        for (i, (&v, &want)) in vals.iter().zip(&layout.c_ref.values).enumerate() {
            assert!((v - want).abs() < 1e-5, "val[{i}] = {v}, want {want}");
        }
        let cols = bl.l1.read_slice(layout.c_col_base, layout.c_ref.nnz());
        for (i, (&cgot, &want)) in cols.iter().zip(&layout.c_ref.col_idx).enumerate() {
            assert_eq!(cgot, want as f32, "col[{i}]");
        }
        // Same arithmetic, fewer port grants: the windowed prefetch and
        // buffered stores replace per-step single-word traffic.
        assert_eq!(sb.flops, s.flops, "burst mode must not change FLOPs");
        assert!(sb.burst_reqs_per_class.iter().sum::<u64>() > 0);
        let (tot_b, tot_s) = (
            sb.reqs_per_class.iter().sum::<u64>(),
            s.reqs_per_class.iter().sum::<u64>(),
        );
        assert!(tot_b < tot_s, "bursts should cut requests: {tot_b} vs {tot_s}");
    }

    #[test]
    fn spmmadd_ipc_is_branchy_low() {
        let cfg = ClusterConfig::tiny();
        let p = SpmmaddParams { rows: 256, cols: 256, nnz_per_row: 6, seed: 3 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(50_000_000);
        // Branch bubbles + dependent loads: IPC clearly below the
        // streaming kernels but the kernel still makes progress.
        assert!(stats.ipc() < 0.8, "ipc = {}", stats.ipc());
        assert!(stats.ipc() > 0.3, "ipc = {}", stats.ipc());
        // Branch bubbles must be visible relative to issued work (the
        // makespan denominator also contains tail-idle cycles).
        assert!(
            stats.stall_ctrl as f64 / stats.instructions as f64 > 0.03,
            "ctrl {} / instr {}",
            stats.stall_ctrl,
            stats.instructions
        );
    }
}
