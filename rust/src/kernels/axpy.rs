//! AXPY — the paper's *local-access* kernel (Sec. 7): `z = α·x + y`.
//!
//! Data placement: x, y, z are bank-sweep-aligned in the interleaved
//! region, and PE `p` processes exactly the elements whose interleaved
//! word index falls in its own Tile's banks (`i mod num_banks ∈
//! [bf·p, bf·p+bf)` with banking factor bf = 4) — the chunk-of-4
//! assignment that makes every access single-cycle local, the property
//! the paper exploits to reach IPC 0.85.
//!
//! Inner loop (unrolled ×4, mirroring the paper's loop-unrolled Snitch
//! code): 8 non-blocking loads, 4 FMAs against the α register, 4 stores,
//! 2 address ALU ops, 1 branch.

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, Scale};
use crate::report::Verdict;

use super::{allclose_verdict, Alloc, Staged, StagedIo, Workload};
use crate::isa::Program;

/// α register.
const R_ALPHA: u8 = 1;
/// x operands r2..r5, y operands r6..r9.
const R_X: u8 = 2;
const R_Y: u8 = 6;

#[derive(Debug, Clone)]
pub struct AxpyParams {
    /// Elements; must be a multiple of `num_banks`.
    pub n: usize,
    pub alpha: f32,
}

impl Default for AxpyParams {
    fn default() -> Self {
        AxpyParams { n: 256 * 1024, alpha: 2.0 }
    }
}

/// Deterministic pseudo-input, reproduced bit-identically on the JAX side
/// by the harness staging the same vectors.
pub fn input_x(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 97) as f32) * 0.125 - 6.0).collect()
}
pub fn input_y(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 31) as f32) * 0.5 - 7.75).collect()
}

/// [`Workload`] registration: AXPY with pinned ([`Axpy::with`]) or
/// scale-resolved problem size (64/16 bank sweeps per array — the
/// Fig. 14a full/fast sizes on TeraPool).
#[derive(Default)]
pub struct Axpy(pub Option<AxpyParams>);

impl Axpy {
    pub fn with(p: AxpyParams) -> Self {
        Axpy(Some(p))
    }
    fn resolve(&self, cfg: &ClusterConfig, scale: Scale) -> AxpyParams {
        self.0.clone().unwrap_or(AxpyParams {
            n: cfg.num_banks() * scale.pick(64, 16),
            alpha: 2.0,
        })
    }
}

impl Workload for Axpy {
    fn kind(&self) -> &'static str {
        "axpy"
    }
    fn describe(&self) -> &'static str {
        "local-access BLAS-1 z = a*x + y (Fig. 14a, Table 6)"
    }
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged {
        build(cfg, &self.resolve(cfg, scale))
    }
    fn check(
        &self,
        cfg: &ClusterConfig,
        scale: Scale,
        cl: &Cluster,
        io: &StagedIo,
    ) -> Verdict {
        let p = self.resolve(cfg, scale);
        match io.read_output(cl) {
            Ok(got) => allclose_verdict(&got, &reference(&p), 1e-5, "axpy vs host reference"),
            Err(e) => Verdict::Failed { reason: e.to_string() },
        }
    }
}

pub fn build(cfg: &ClusterConfig, p: &AxpyParams) -> Staged {
    let nb = cfg.num_banks();
    let bf = cfg.banking_factor;
    let npes = cfg.num_pes();
    assert_eq!(p.n % nb, 0, "n must be a multiple of the bank count");

    let mut alloc = Alloc::new(cfg);
    let xb = alloc.alloc(p.n as u32);
    let yb = alloc.alloc(p.n as u32);
    let zb = alloc.alloc(p.n as u32);

    let sweeps = p.n / nb; // bank rows per array
    // TCDM burst mode (cfg.burst): each bf-element group is one burst
    // over the PE's bf consecutive local banks — one port grant and one
    // LSU entry instead of bf, the sequel paper's bandwidth lever.
    let burst = cfg.burst && bf > 1 && bf <= crate::isa::MAX_BURST_WORDS;
    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let mut t = Program::new();
        t.ld_imm(R_ALPHA, p.alpha);
        for k in 0..sweeps {
            // The bf(=4) elements of sweep k living in PE `pe`'s banks.
            let i0 = (k * nb + bf * pe) as u32;
            if burst {
                t.ld_burst(R_X, xb + i0, bf as u8);
                t.ld_burst(R_Y, yb + i0, bf as u8);
            } else {
                for j in 0..bf as u32 {
                    t.ld(R_X + j as u8, xb + i0 + j);
                }
                for j in 0..bf as u32 {
                    t.ld(R_Y + j as u8, yb + i0 + j);
                }
            }
            for j in 0..bf as u8 {
                // y_j += alpha * x_j
                t.fmac(R_Y + j, R_ALPHA, R_X + j);
            }
            if burst {
                t.st_burst(R_Y, zb + i0, bf as u8);
            } else {
                for j in 0..bf as u32 {
                    t.st(R_Y + j as u8, zb + i0 + j);
                }
            }
            t.alu(); // pointer bump
            t.alu(); // loop counter
            t.branch();
        }
        t.barrier(0);
        t.halt();
        programs.push(t);
    }

    Staged {
        name: format!("axpy-n{}", p.n),
        programs,
        inputs: vec![(xb, input_x(p.n)), (yb, input_y(p.n))],
        output_base: zb,
        output_len: p.n,
        flops: 2 * p.n as u64,
        dma: None,
    }
}

/// Host-side reference (must equal both the cluster result and the AOT
/// artifact's output).
pub fn reference(p: &AxpyParams) -> Vec<f32> {
    input_x(p.n)
        .iter()
        .zip(input_y(p.n))
        .map(|(&x, y)| p.alpha * x + y)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_computes_correctly_on_tiny_cluster() {
        let cfg = ClusterConfig::tiny();
        let p = AxpyParams { n: cfg.num_banks() * 8, alpha: 1.5 };
        let setup = build(&cfg, &p);
        let want = reference(&p);
        let (mut cl, io) = setup.into_cluster(cfg);
        let stats = cl.run(1_000_000);
        assert_eq!(io.read_output(&cl).unwrap(), want);
        assert_eq!(stats.flops, 2 * p.n as u64);
    }

    #[test]
    fn axpy_accesses_are_all_local() {
        let cfg = ClusterConfig::tiny();
        let p = AxpyParams { n: cfg.num_banks() * 4, alpha: 2.0 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(1_000_000);
        // Everything except the barrier atomics is Tile-local.
        assert_eq!(stats.reqs_per_class[1], 0);
        assert_eq!(stats.reqs_per_class[2], 0);
        assert_eq!(stats.reqs_per_class[3], 0);
    }

    #[test]
    fn axpy_ipc_is_high() {
        let cfg = ClusterConfig::tiny();
        let p = AxpyParams { n: cfg.num_banks() * 64, alpha: 2.0 };
        let (mut cl, _) = build(&cfg, &p).into_cluster(cfg);
        let stats = cl.run(1_000_000);
        assert!(stats.ipc() > 0.75, "ipc = {}", stats.ipc());
    }
}
