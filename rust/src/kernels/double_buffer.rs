//! Double-buffered kernels with HBM2E main memory — regenerates
//! **Fig. 14b** (timing breakdown of compute vs data transfer).
//!
//! Two L1 buffer sets: while the cluster computes round r out of buffer
//! r mod 2, the iDMA transfers round r+1 into the other set and drains
//! round r-1's results (Sec. 7). Memory-bound kernels (AXPY) cannot hide
//! the result/input transfers (compute ≈ 44 % of the timeline); DOTP's
//! output is a scalar so only inputs stream (≈ 82 %); compute-bound GEMM
//! hides HBM2E entirely.

use crate::config::{ClusterConfig, Scale};
use crate::dma::DmaDescriptor;
use crate::isa::{Op, Program};

use super::{Alloc, DmaPlan, Staged, Workload};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DbKernel {
    Axpy,
    Dotp,
    Gemm,
}

impl DbKernel {
    pub fn name(&self) -> &'static str {
        match self {
            DbKernel::Axpy => "axpy",
            DbKernel::Dotp => "dotp",
            DbKernel::Gemm => "gemm",
        }
    }
}

#[derive(Debug, Clone)]
pub struct DbParams {
    pub kernel: DbKernel,
    /// Words per input chunk (per operand); must be a bank-count multiple.
    pub chunk: usize,
    pub rounds: usize,
}

/// [`Workload`] registration: one entry per double-buffered kernel
/// (`db-axpy`/`db-dotp`/`db-gemm`), with pinned ([`Db::with`]) or
/// scale-resolved chunk/rounds (the Fig. 14b sizes: 32/16 bank sweeps
/// per chunk, 8/4 rounds). These workloads carry a [`DmaPlan`], so the
/// run path attaches the HBML and stages the main-memory image.
pub struct Db {
    kernel: DbKernel,
    size: Option<(usize, usize)>, // (chunk, rounds)
}

impl Db {
    pub fn new(kernel: DbKernel) -> Self {
        Db { kernel, size: None }
    }
    pub fn with(kernel: DbKernel, chunk: usize, rounds: usize) -> Self {
        Db { kernel, size: Some((chunk, rounds)) }
    }
    fn resolve(&self, cfg: &ClusterConfig, scale: Scale) -> DbParams {
        let (chunk, rounds) = self
            .size
            .unwrap_or((cfg.num_banks() * scale.pick(32, 16), scale.pick(8, 4)));
        DbParams { kernel: self.kernel, chunk, rounds }
    }
}

impl Workload for Db {
    fn kind(&self) -> &'static str {
        match self.kernel {
            DbKernel::Axpy => "db-axpy",
            DbKernel::Dotp => "db-dotp",
            DbKernel::Gemm => "db-gemm",
        }
    }
    fn describe(&self) -> &'static str {
        match self.kernel {
            DbKernel::Axpy => "double-buffered AXPY via HBM2E, memory-bound (Fig. 14b)",
            DbKernel::Dotp => "double-buffered DOTP via HBM2E, scalar writeback (Fig. 14b)",
            DbKernel::Gemm => "double-buffered GEMM proxy via HBM2E, compute-bound (Fig. 14b)",
        }
    }
    fn build(&self, cfg: &ClusterConfig, scale: Scale) -> Staged {
        stage(cfg, &self.resolve(cfg, scale))
    }
    // No host reference: the Fig. 14b quantity of interest is the timing
    // split, which RunStats carries — check stays NotChecked.
}

/// Result of a double-buffered run. `PartialEq` backs the
/// serial-vs-parallel differential suite: every field must match bit for
/// bit across engines and thread counts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DbResult {
    pub cycles: u64,
    /// Cycles PEs spent computing (issuing) rather than DMA-waiting.
    pub compute_fraction: f64,
    pub bytes_transferred: u64,
    pub ipc: f64,
}

/// Build and run a double-buffered kernel; returns the timing breakdown.
pub fn run(cfg: &ClusterConfig, p: &DbParams) -> DbResult {
    run_threads(cfg, p, 1)
}

/// [`run`] with the host engine choice threaded through: `threads > 1`
/// executes the cluster on the deterministic tile-parallel engine
/// (identical simulated results, less wall clock).
pub fn run_threads(cfg: &ClusterConfig, p: &DbParams, threads: usize) -> DbResult {
    let npes = cfg.num_pes();
    let (mut cl, _io) = stage(cfg, p).into_cluster(cfg.clone());
    let stats = cl.run_threads(200_000_000, threads);
    let total_pe_cycles = stats.cycles as f64 * npes as f64;
    // Compute fraction: cycles not stalled on synchronization (DMA wait +
    // barrier) — the Fig. 14b split.
    let compute = 1.0 - stats.stall_synch as f64 / total_pe_cycles;
    DbResult {
        cycles: stats.cycles,
        compute_fraction: compute,
        bytes_transferred: cl.dma.as_ref().unwrap().total_bytes(),
        ipc: stats.ipc(),
    }
}

/// Stage the double-buffered pipeline: per-PE traces over the two L1
/// buffer sets, plus the [`DmaPlan`] (3 descriptors per round: in-x,
/// in-y, out-z, and the input image regions). `Staged::into_cluster`
/// applies the plan on the running thread — the HBM image is
/// thread-local, which is what makes these workloads batch-safe.
pub fn stage(cfg: &ClusterConfig, p: &DbParams) -> Staged {
    let nb = cfg.num_banks();
    let bf = cfg.banking_factor;
    let npes = cfg.num_pes();
    assert_eq!(p.chunk % nb, 0);

    let mut alloc = Alloc::new(cfg);
    // Two buffer sets: x0,y0,z0 / x1,y1,z1.
    let bufs: Vec<[u32; 3]> = (0..2)
        .map(|_| {
            [
                alloc.alloc(p.chunk as u32),
                alloc.alloc(p.chunk as u32),
                alloc.alloc(p.chunk as u32),
            ]
        })
        .collect();

    // Descriptor ids: per round, in-x, in-y, out-z.
    // Main memory layout: round r input x at r*chunk*4, y after all x,
    // z after all y.
    let ch_b = (p.chunk * 4) as u64;
    let x_base = 0u64;
    let y_base = ch_b * p.rounds as u64;
    let z_base = 2 * ch_b * p.rounds as u64;

    let sweeps = p.chunk / nb;
    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let mut t = Program::new();
        let mut next_barrier = 0u16;
        if pe == 0 {
            t.push(Op::DmaStart { id: 0 }); // in-x round 0
            t.push(Op::DmaStart { id: 1 }); // in-y round 0
        }
        for r in 0..p.rounds {
            let din = (3 * r) as u16;
            // Wait for this round's inputs.
            t.push(Op::DmaWait { id: din });
            t.push(Op::DmaWait { id: din + 1 });
            // Kick next round's input transfers (overlap with compute).
            if pe == 0 && r + 1 < p.rounds {
                t.push(Op::DmaStart { id: din + 3 });
                t.push(Op::DmaStart { id: din + 4 });
            }
            // Before overwriting this buffer's z, its previous writeback
            // (round r-2, same buffer set) must have drained.
            if r >= 2 {
                t.push(Op::DmaWait { id: (3 * (r - 2)) as u16 + 2 });
            }
            let [xb, yb, zb] = bufs[r % 2];
            // Compute phase: chunk-of-4 local AXPY/DOTP body.
            t.ld_imm(1, 2.0); // alpha / dummy
            match p.kernel {
                DbKernel::Axpy | DbKernel::Dotp => {
                    if matches!(p.kernel, DbKernel::Dotp) {
                        for j in 0..bf as u8 {
                            t.ld_imm(10 + j, 0.0);
                        }
                    }
                    for k in 0..sweeps {
                        for j in 0..bf {
                            let i = (k * nb + bf * pe + j) as u32;
                            t.ld(2 + j as u8, xb + i);
                        }
                        for j in 0..bf {
                            let i = (k * nb + bf * pe + j) as u32;
                            t.ld(6 + j as u8, yb + i);
                        }
                        for j in 0..bf as u8 {
                            match p.kernel {
                                DbKernel::Axpy => t.fmac(6 + j, 1, 2 + j),
                                _ => t.fmac(10 + j, 2 + j, 6 + j),
                            }
                        }
                        if matches!(p.kernel, DbKernel::Axpy) {
                            for j in 0..bf {
                                let i = (k * nb + bf * pe + j) as u32;
                                t.st(6 + j as u8, zb + i);
                            }
                        }
                        t.alu();
                        t.branch();
                    }
                    if matches!(p.kernel, DbKernel::Dotp) {
                        t.add(14, 10, 11);
                        t.add(15, 12, 13);
                        t.add(14, 14, 15);
                        t.st(14, zb + pe as u32);
                    }
                }
                DbKernel::Gemm => {
                    // Compute-bound proxy: reuse the chunk K times — a
                    // resident-B panel GEMM does ~m FLOPs per loaded word.
                    let reuse = 24;
                    for _rep in 0..reuse {
                        for k in 0..sweeps {
                            for j in 0..bf {
                                let i = (k * nb + bf * pe + j) as u32;
                                t.ld(2 + j as u8, xb + i);
                            }
                            for j in 0..bf {
                                let i = (k * nb + bf * pe + j) as u32;
                                t.ld(6 + j as u8, yb + i);
                            }
                            for _ in 0..2 {
                                for j in 0..bf as u8 {
                                    t.fmac(10 + j, 2 + j, 6 + j);
                                }
                            }
                            t.alu();
                            t.branch();
                        }
                    }
                    for j in 0..bf as u8 {
                        t.st(10 + j, zb + (bf * pe) as u32 + j as u32);
                    }
                }
            }
            t.barrier(next_barrier);
            next_barrier += 1;
            // Kick this round's result writeback.
            if pe == 0 {
                t.push(Op::DmaStart { id: din + 2 });
            }
        }
        // Drain the final writebacks.
        if p.rounds >= 2 {
            t.push(Op::DmaWait { id: (3 * (p.rounds - 2)) as u16 + 2 });
        }
        t.push(Op::DmaWait { id: (3 * (p.rounds - 1)) as u16 + 2 });
        t.halt();
        programs.push(t);
    }

    // The DMA plan: descriptor ids are assigned in registration order, so
    // round r's (in-x, in-y, out-z) land on ids (3r, 3r+1, 3r+2) — the ids
    // the traces above wait on.
    let mut descriptors = Vec::with_capacity(3 * p.rounds);
    for r in 0..p.rounds {
        let [xb, yb, zb] = bufs[r % 2];
        descriptors.push(DmaDescriptor {
            l1_word: xb,
            mem_byte: x_base + r as u64 * ch_b,
            words: p.chunk as u32,
            to_l1: true,
        });
        descriptors.push(DmaDescriptor {
            l1_word: yb,
            mem_byte: y_base + r as u64 * ch_b,
            words: p.chunk as u32,
            to_l1: true,
        });
        // DOTP's result is a scalar per PE (per-round partials), so
        // only a single burst flows back; AXPY/GEMM write full/partial
        // result buffers.
        let out_words = match p.kernel {
            DbKernel::Axpy => p.chunk as u32,
            DbKernel::Dotp => crate::dma::BURST_WORDS,
            DbKernel::Gemm => (p.chunk as u32 / 8).max(crate::dma::BURST_WORDS),
        };
        descriptors.push(DmaDescriptor {
            l1_word: zb,
            mem_byte: z_base + r as u64 * ch_b,
            words: out_words,
            to_l1: false,
        });
    }
    let data: Vec<f32> = (0..p.chunk).map(|i| (i % 23) as f32 * 0.125).collect();
    let mut image = Vec::with_capacity(2 * p.rounds);
    for r in 0..p.rounds {
        image.push((x_base + r as u64 * ch_b, data.clone()));
        image.push((y_base + r as u64 * ch_b, data.clone()));
    }

    Staged {
        name: format!("db-{}-c{}-r{}", p.kernel.name(), p.chunk, p.rounds),
        programs,
        inputs: Vec::new(),
        // The results leave through the HBML, not the L1 image; expose
        // the last round's z buffer for ad-hoc inspection.
        output_base: bufs[(p.rounds + 1) % 2][2],
        output_len: 0,
        flops: 0,
        dma: Some(DmaPlan { descriptors, image }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dma::hbm_image_clear;

    fn tiny_params(kernel: DbKernel) -> DbParams {
        DbParams { kernel, chunk: 128 * 16, rounds: 4 }
    }

    #[test]
    fn axpy_db_runs_and_transfers() {
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let r = run(&cfg, &tiny_params(DbKernel::Axpy));
        assert!(r.cycles > 0);
        // 2 inputs + 1 full output buffer per round.
        assert_eq!(r.bytes_transferred, (3 * 4 * 128 * 16 * 4) as u64);
        assert!(r.compute_fraction > 0.05 && r.compute_fraction < 1.0);
    }

    #[test]
    fn gemm_db_hides_transfers_better_than_axpy() {
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let ax = run(&cfg, &tiny_params(DbKernel::Axpy));
        hbm_image_clear();
        let gm = run(&cfg, &tiny_params(DbKernel::Gemm));
        assert!(
            gm.compute_fraction > ax.compute_fraction,
            "gemm {} vs axpy {}",
            gm.compute_fraction,
            ax.compute_fraction
        );
    }

    #[test]
    fn dotp_db_between_axpy_and_gemm() {
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let ax = run(&cfg, &tiny_params(DbKernel::Axpy));
        hbm_image_clear();
        let dp = run(&cfg, &tiny_params(DbKernel::Dotp));
        // DOTP has no bulk result writeback → more of the timeline is
        // compute than AXPY.
        assert!(
            dp.compute_fraction >= ax.compute_fraction * 0.95,
            "dotp {} vs axpy {}",
            dp.compute_fraction,
            ax.compute_fraction
        );
    }
}
