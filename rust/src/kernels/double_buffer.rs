//! Double-buffered kernels with HBM2E main memory — regenerates
//! **Fig. 14b** (timing breakdown of compute vs data transfer).
//!
//! Two L1 buffer sets: while the cluster computes round r out of buffer
//! r mod 2, the iDMA transfers round r+1 into the other set and drains
//! round r-1's results (Sec. 7). Memory-bound kernels (AXPY) cannot hide
//! the result/input transfers (compute ≈ 44 % of the timeline); DOTP's
//! output is a scalar so only inputs stream (≈ 82 %); compute-bound GEMM
//! hides HBM2E entirely.

use crate::cluster::Cluster;
use crate::config::ClusterConfig;
use crate::dma::{hbm_image_stage, DmaDescriptor};
use crate::isa::{Op, Program};

use super::Alloc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DbKernel {
    Axpy,
    Dotp,
    Gemm,
}

impl DbKernel {
    pub fn name(&self) -> &'static str {
        match self {
            DbKernel::Axpy => "axpy",
            DbKernel::Dotp => "dotp",
            DbKernel::Gemm => "gemm",
        }
    }
}

pub struct DbParams {
    pub kernel: DbKernel,
    /// Words per input chunk (per operand); must be a bank-count multiple.
    pub chunk: usize,
    pub rounds: usize,
}

/// Result of a double-buffered run. `PartialEq` backs the
/// serial-vs-parallel differential suite: every field must match bit for
/// bit across engines and thread counts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DbResult {
    pub cycles: u64,
    /// Cycles PEs spent computing (issuing) rather than DMA-waiting.
    pub compute_fraction: f64,
    pub bytes_transferred: u64,
    pub ipc: f64,
}

/// Build and run a double-buffered kernel; returns the timing breakdown.
pub fn run(cfg: &ClusterConfig, p: &DbParams) -> DbResult {
    run_threads(cfg, p, 1)
}

/// [`run`] with the host engine choice threaded through: `threads > 1`
/// executes the cluster on the deterministic tile-parallel engine
/// (identical simulated results, less wall clock).
pub fn run_threads(cfg: &ClusterConfig, p: &DbParams, threads: usize) -> DbResult {
    let nb = cfg.num_banks();
    let bf = cfg.banking_factor;
    let npes = cfg.num_pes();
    assert_eq!(p.chunk % nb, 0);

    let mut alloc = Alloc::new(cfg);
    // Two buffer sets: x0,y0,z0 / x1,y1,z1.
    let bufs: Vec<[u32; 3]> = (0..2)
        .map(|_| {
            [
                alloc.alloc(p.chunk as u32),
                alloc.alloc(p.chunk as u32),
                alloc.alloc(p.chunk as u32),
            ]
        })
        .collect();

    // Descriptor ids: per round, in-x, in-y, out-z.
    // Main memory layout: round r input x at r*chunk*4, y after all x,
    // z after all y.
    let ch_b = (p.chunk * 4) as u64;
    let x_base = 0u64;
    let y_base = ch_b * p.rounds as u64;
    let z_base = 2 * ch_b * p.rounds as u64;

    let sweeps = p.chunk / nb;
    let mut programs = Vec::with_capacity(npes);
    for pe in 0..npes {
        let mut t = Program::new();
        let mut next_barrier = 0u16;
        if pe == 0 {
            t.push(Op::DmaStart { id: 0 }); // in-x round 0
            t.push(Op::DmaStart { id: 1 }); // in-y round 0
        }
        for r in 0..p.rounds {
            let din = (3 * r) as u16;
            // Wait for this round's inputs.
            t.push(Op::DmaWait { id: din });
            t.push(Op::DmaWait { id: din + 1 });
            // Kick next round's input transfers (overlap with compute).
            if pe == 0 && r + 1 < p.rounds {
                t.push(Op::DmaStart { id: din + 3 });
                t.push(Op::DmaStart { id: din + 4 });
            }
            // Before overwriting this buffer's z, its previous writeback
            // (round r-2, same buffer set) must have drained.
            if r >= 2 {
                t.push(Op::DmaWait { id: (3 * (r - 2)) as u16 + 2 });
            }
            let [xb, yb, zb] = bufs[r % 2];
            // Compute phase: chunk-of-4 local AXPY/DOTP body.
            t.ld_imm(1, 2.0); // alpha / dummy
            match p.kernel {
                DbKernel::Axpy | DbKernel::Dotp => {
                    if matches!(p.kernel, DbKernel::Dotp) {
                        for j in 0..bf as u8 {
                            t.ld_imm(10 + j, 0.0);
                        }
                    }
                    for k in 0..sweeps {
                        for j in 0..bf {
                            let i = (k * nb + bf * pe + j) as u32;
                            t.ld(2 + j as u8, xb + i);
                        }
                        for j in 0..bf {
                            let i = (k * nb + bf * pe + j) as u32;
                            t.ld(6 + j as u8, yb + i);
                        }
                        for j in 0..bf as u8 {
                            match p.kernel {
                                DbKernel::Axpy => t.fmac(6 + j, 1, 2 + j),
                                _ => t.fmac(10 + j, 2 + j, 6 + j),
                            }
                        }
                        if matches!(p.kernel, DbKernel::Axpy) {
                            for j in 0..bf {
                                let i = (k * nb + bf * pe + j) as u32;
                                t.st(6 + j as u8, zb + i);
                            }
                        }
                        t.alu();
                        t.branch();
                    }
                    if matches!(p.kernel, DbKernel::Dotp) {
                        t.add(14, 10, 11);
                        t.add(15, 12, 13);
                        t.add(14, 14, 15);
                        t.st(14, zb + pe as u32);
                    }
                }
                DbKernel::Gemm => {
                    // Compute-bound proxy: reuse the chunk K times — a
                    // resident-B panel GEMM does ~m FLOPs per loaded word.
                    let reuse = 24;
                    for _rep in 0..reuse {
                        for k in 0..sweeps {
                            for j in 0..bf {
                                let i = (k * nb + bf * pe + j) as u32;
                                t.ld(2 + j as u8, xb + i);
                            }
                            for j in 0..bf {
                                let i = (k * nb + bf * pe + j) as u32;
                                t.ld(6 + j as u8, yb + i);
                            }
                            for _ in 0..2 {
                                for j in 0..bf as u8 {
                                    t.fmac(10 + j, 2 + j, 6 + j);
                                }
                            }
                            t.alu();
                            t.branch();
                        }
                    }
                    for j in 0..bf as u8 {
                        t.st(10 + j, zb + (bf * pe) as u32 + j as u32);
                    }
                }
            }
            t.barrier(next_barrier);
            next_barrier += 1;
            // Kick this round's result writeback.
            if pe == 0 {
                t.push(Op::DmaStart { id: din + 2 });
            }
        }
        // Drain the final writebacks.
        if p.rounds >= 2 {
            t.push(Op::DmaWait { id: (3 * (p.rounds - 2)) as u16 + 2 });
        }
        t.push(Op::DmaWait { id: (3 * (p.rounds - 1)) as u16 + 2 });
        t.halt();
        programs.push(t);
    }

    let mut cl = Cluster::new(cfg.clone(), programs).with_dma();
    {
        let dma = cl.dma.as_mut().unwrap();
        for r in 0..p.rounds {
            let [xb, yb, zb] = bufs[r % 2];
            let id = dma.register(DmaDescriptor {
                l1_word: xb,
                mem_byte: x_base + r as u64 * ch_b,
                words: p.chunk as u32,
                to_l1: true,
            });
            assert_eq!(id as usize, 3 * r);
            dma.register(DmaDescriptor {
                l1_word: yb,
                mem_byte: y_base + r as u64 * ch_b,
                words: p.chunk as u32,
                to_l1: true,
            });
            // DOTP's result is a scalar per PE (per-round partials), so
            // only a single burst flows back; AXPY/GEMM write full/partial
            // result buffers.
            let out_words = match p.kernel {
                DbKernel::Axpy => p.chunk as u32,
                DbKernel::Dotp => crate::dma::BURST_WORDS,
                DbKernel::Gemm => (p.chunk as u32 / 8).max(crate::dma::BURST_WORDS),
            };
            dma.register(DmaDescriptor {
                l1_word: zb,
                mem_byte: z_base + r as u64 * ch_b,
                words: out_words,
                to_l1: false,
            });
        }
    }
    // Stage input images.
    let data: Vec<f32> = (0..p.chunk).map(|i| (i % 23) as f32 * 0.125).collect();
    for r in 0..p.rounds {
        hbm_image_stage(x_base + r as u64 * ch_b, &data);
        hbm_image_stage(y_base + r as u64 * ch_b, &data);
    }

    let stats = cl.run_threads(200_000_000, threads);
    let total_pe_cycles = stats.cycles as f64 * npes as f64;
    // Compute fraction: cycles not stalled on synchronization (DMA wait +
    // barrier) — the Fig. 14b split.
    let compute = 1.0 - stats.stall_synch as f64 / total_pe_cycles;
    DbResult {
        cycles: stats.cycles,
        compute_fraction: compute,
        bytes_transferred: cl.dma.as_ref().unwrap().total_bytes(),
        ipc: stats.ipc(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dma::hbm_image_clear;

    fn tiny_params(kernel: DbKernel) -> DbParams {
        DbParams { kernel, chunk: 128 * 16, rounds: 4 }
    }

    #[test]
    fn axpy_db_runs_and_transfers() {
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let r = run(&cfg, &tiny_params(DbKernel::Axpy));
        assert!(r.cycles > 0);
        // 2 inputs + 1 full output buffer per round.
        assert_eq!(r.bytes_transferred, (3 * 4 * 128 * 16 * 4) as u64);
        assert!(r.compute_fraction > 0.05 && r.compute_fraction < 1.0);
    }

    #[test]
    fn gemm_db_hides_transfers_better_than_axpy() {
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let ax = run(&cfg, &tiny_params(DbKernel::Axpy));
        hbm_image_clear();
        let gm = run(&cfg, &tiny_params(DbKernel::Gemm));
        assert!(
            gm.compute_fraction > ax.compute_fraction,
            "gemm {} vs axpy {}",
            gm.compute_fraction,
            ax.compute_fraction
        );
    }

    #[test]
    fn dotp_db_between_axpy_and_gemm() {
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let ax = run(&cfg, &tiny_params(DbKernel::Axpy));
        hbm_image_clear();
        let dp = run(&cfg, &tiny_params(DbKernel::Dotp));
        // DOTP has no bulk result writeback → more of the timeline is
        // compute than AXPY.
        assert!(
            dp.compute_fraction >= ax.compute_fraction * 0.95,
            "dotp {} vs axpy {}",
            dp.compute_fraction,
            ax.compute_fraction
        );
    }
}
