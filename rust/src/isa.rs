//! Trace ISA: the compact per-PE instruction stream executed by the
//! simulator.
//!
//! The paper's PEs are single-issue, single-stage Snitch cores
//! (RV32IMA + Xpulpimg + zfinx/zhinx, Sec. 4.1). We model the pipeline at
//! the granularity that determines the paper's results: issue rules,
//! register dependencies, the LSU transaction table, and memory requests.
//! Address arithmetic is pre-computed by the kernel trace builders (the
//! standard trace-driven approach), but **data flow is real**: loads fetch
//! actual f32 words from the simulated banks and compute ops produce
//! actual results, so the final memory image is checkable against the
//! AOT-compiled JAX golden outputs.

/// Number of architectural registers usable for f32 values. RV32 has 32
/// integer registers; zfinx executes FP from the integer file, and a few
/// (zero/ra/sp/addr temporaries) are spoken for — the kernel builders see
/// 32 and budget like the paper (a 4×4 GEMM block is "the maximum
/// supported by 32 ISA registers").
pub const NUM_REGS: usize = 32;

/// Maximum words a single burst request may move (matches the banking
/// factor of every shipped configuration: one beat per bank of the PE's
/// own bank group, the widest window one port grant can cover without
/// re-arbitrating). Also bounds the fixed arrays bursts travel in
/// ([`crate::interconnect::Request`] stays `Copy` for the sharded
/// engine's mailboxes).
pub const MAX_BURST_WORDS: usize = 4;

/// One trace instruction. Kept to 8 bytes — full-cluster GEMM traces reach
/// tens of millions of instructions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Load word: `rd <- L1[addr]`. Non-blocking; occupies a transaction
    /// table entry until the response returns.
    Ld { rd: u8, addr: u32 },
    /// Store word: `L1[addr] <- rs`. Tracked for retirement like loads.
    St { rs: u8, addr: u32 },
    /// Burst load: `rd+k <- L1[addr+k]` for `k in 0..n` (TCDM burst
    /// access, the sequel paper's bandwidth-ceiling breaker): one LSU
    /// transaction-table entry and one port grant move `n` words over
    /// consecutive banks. `1 <= n <= MAX_BURST_WORDS`.
    LdBurst { rd: u8, n: u8, addr: u32 },
    /// Burst store: `L1[addr+k] <- rs+k` for `k in 0..n`; one table
    /// entry, one grant, like [`Op::LdBurst`].
    StBurst { rs: u8, n: u8, addr: u32 },
    /// Atomic fetch-and-add to L1: `L1[addr] += rs` (the paper's join
    /// primitive). Serializes at the target bank.
    AtomAdd { rs: u8, addr: u32 },
    /// Load immediate: `rd <- imm` (lui/li or fp constant materialize).
    LdImm { rd: u8, imm: f32 },
    /// Fused multiply-accumulate (Xpulpimg MAC / fmadd): `rd += ra * rb`.
    Fmac { rd: u8, ra: u8, rb: u8 },
    /// Fused multiply-subtract: `rd -= ra * rb`.
    Fnmac { rd: u8, ra: u8, rb: u8 },
    /// `rd <- ra * rb`.
    Mul { rd: u8, ra: u8, rb: u8 },
    /// `rd <- ra + rb`.
    Add { rd: u8, ra: u8, rb: u8 },
    /// `rd <- ra - rb`.
    Sub { rd: u8, ra: u8, rb: u8 },
    /// `rd <- ra`.
    Mov { rd: u8, ra: u8 },
    /// Address/index/control arithmetic with no tracked data flow:
    /// occupies one issue slot.
    Alu,
    /// Taken branch/jump: one issue slot plus `CTRL_BUBBLE` refetch
    /// bubbles (single-stage core, L0 I$ refetch).
    Branch,
    /// Fork-join barrier arrival (atomic fetch&add on the Tile-local
    /// barrier counter) followed by WFI until global release.
    Barrier { id: u16 },
    /// Trigger the pre-registered DMA descriptor `id` (iDMA frontend
    /// CSR write; only one core should execute it).
    DmaStart { id: u16 },
    /// Block until DMA descriptor `id` has fully retired.
    DmaWait { id: u16 },
    /// Halt this PE (end of its program).
    Halt,
}

/// Refetch bubble cycles charged after a taken branch.
pub const CTRL_BUBBLE: u32 = 1;

/// Instruction class, for the Fig. 14a instruction-mix accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Load,
    Store,
    Atomic,
    Compute,
    Control,
    Sync,
}

impl Op {
    pub fn class(&self) -> OpClass {
        match self {
            Op::Ld { .. } | Op::LdBurst { .. } => OpClass::Load,
            Op::St { .. } | Op::StBurst { .. } => OpClass::Store,
            Op::AtomAdd { .. } => OpClass::Atomic,
            Op::LdImm { .. }
            | Op::Fmac { .. }
            | Op::Fnmac { .. }
            | Op::Mul { .. }
            | Op::Add { .. }
            | Op::Sub { .. }
            | Op::Mov { .. }
            | Op::Alu => OpClass::Compute,
            Op::Branch => OpClass::Control,
            Op::Barrier { .. } | Op::DmaStart { .. } | Op::DmaWait { .. } | Op::Halt => {
                OpClass::Sync
            }
        }
    }

    /// FLOP contributed by this instruction (FMA counts 2, as the paper
    /// counts one MAC as two operations — Table 5 footnote a).
    pub fn flops(&self) -> u64 {
        match self {
            Op::Fmac { .. } | Op::Fnmac { .. } => 2,
            Op::Mul { .. } | Op::Add { .. } | Op::Sub { .. } => 1,
            _ => 0,
        }
    }
}

/// A per-PE program: a flat instruction trace.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub ops: Vec<Op>,
}

impl Program {
    pub fn new() -> Self {
        Self { ops: Vec::new() }
    }
    pub fn push(&mut self, op: Op) {
        self.ops.push(op);
    }
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Small builder DSL used by the kernel trace generators.
impl Program {
    pub fn ld(&mut self, rd: u8, addr: u32) {
        self.push(Op::Ld { rd, addr });
    }
    pub fn st(&mut self, rs: u8, addr: u32) {
        self.push(Op::St { rs, addr });
    }
    /// Burst load of `n` words into `rd..rd+n` (see [`Op::LdBurst`]).
    pub fn ld_burst(&mut self, rd: u8, addr: u32, n: u8) {
        assert!(n >= 1 && n as usize <= MAX_BURST_WORDS, "burst length {n}");
        assert!(rd as usize + n as usize <= NUM_REGS, "burst regs out of range");
        self.push(Op::LdBurst { rd, n, addr });
    }
    /// Burst store of `n` words from `rs..rs+n` (see [`Op::StBurst`]).
    pub fn st_burst(&mut self, rs: u8, addr: u32, n: u8) {
        assert!(n >= 1 && n as usize <= MAX_BURST_WORDS, "burst length {n}");
        assert!(rs as usize + n as usize <= NUM_REGS, "burst regs out of range");
        self.push(Op::StBurst { rs, n, addr });
    }
    pub fn atom_add(&mut self, rs: u8, addr: u32) {
        self.push(Op::AtomAdd { rs, addr });
    }
    pub fn ld_imm(&mut self, rd: u8, imm: f32) {
        self.push(Op::LdImm { rd, imm });
    }
    pub fn fmac(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Op::Fmac { rd, ra, rb });
    }
    pub fn fnmac(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Op::Fnmac { rd, ra, rb });
    }
    pub fn mul(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Op::Mul { rd, ra, rb });
    }
    pub fn add(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Op::Add { rd, ra, rb });
    }
    pub fn sub(&mut self, rd: u8, ra: u8, rb: u8) {
        self.push(Op::Sub { rd, ra, rb });
    }
    pub fn mov(&mut self, rd: u8, ra: u8) {
        self.push(Op::Mov { rd, ra });
    }
    pub fn alu(&mut self) {
        self.push(Op::Alu);
    }
    pub fn branch(&mut self) {
        self.push(Op::Branch);
    }
    pub fn barrier(&mut self, id: u16) {
        self.push(Op::Barrier { id });
    }
    pub fn halt(&mut self) {
        self.push(Op::Halt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_is_compact() {
        // The whole-cluster GEMM trace is ~25M ops; keep them at 8 bytes.
        assert!(std::mem::size_of::<Op>() <= 8, "{}", std::mem::size_of::<Op>());
    }

    #[test]
    fn classes_and_flops() {
        assert_eq!(Op::Ld { rd: 0, addr: 0 }.class(), OpClass::Load);
        assert_eq!(Op::Fmac { rd: 0, ra: 1, rb: 2 }.flops(), 2);
        assert_eq!(Op::Add { rd: 0, ra: 1, rb: 2 }.flops(), 1);
        assert_eq!(Op::Ld { rd: 0, addr: 0 }.flops(), 0);
        assert_eq!(Op::Barrier { id: 0 }.class(), OpClass::Sync);
        assert_eq!(Op::LdBurst { rd: 1, n: 4, addr: 0 }.class(), OpClass::Load);
        assert_eq!(Op::StBurst { rs: 1, n: 4, addr: 0 }.class(), OpClass::Store);
        assert_eq!(Op::LdBurst { rd: 1, n: 4, addr: 0 }.flops(), 0);
    }

    #[test]
    fn burst_builder_checks_bounds() {
        let mut p = Program::new();
        p.ld_burst(2, 100, 4);
        p.st_burst(6, 200, 2);
        assert_eq!(p.ops[0], Op::LdBurst { rd: 2, n: 4, addr: 100 });
        assert_eq!(p.ops[1], Op::StBurst { rs: 6, n: 2, addr: 200 });
        let r = std::panic::catch_unwind(move || {
            let mut p = Program::new();
            p.ld_burst(30, 0, 4); // r30..r34 out of range
        });
        assert!(r.is_err());
    }

    #[test]
    fn builder_roundtrip() {
        let mut p = Program::new();
        p.ld(1, 100);
        p.fmac(2, 1, 1);
        p.st(2, 101);
        p.halt();
        assert_eq!(p.len(), 4);
        assert_eq!(p.ops[0], Op::Ld { rd: 1, addr: 100 });
    }
}
