//! Cycle-level model of TeraPool's hierarchical PE-to-L1 interconnect
//! (Sec. 3–4), sharded into **per-Tile memory domains**.
//!
//! Topology (Fig. 5/6): each Tile's PEs reach their 32 local banks through
//! a fully-combinational logarithmic crossbar (1-cycle round trip). Each
//! Tile additionally exposes **7 master ports**: one to the 8×8 crossbar
//! of its SubGroup, three to the 8×8 crossbars toward the other SubGroups
//! of its Group, and three to the 32×32 crossbars toward the three remote
//! Groups. Spill registers at hierarchy boundaries pipeline long paths,
//! yielding the NUMA round-trip profile 1-3-5-{7,9,11}.
//!
//! Model: every arbitration point (Tile master port per category, target
//! Tile slave port per category — which *is* the FC crossbar output — and
//! the bank port) grants **one request per cycle**; losers retry the next
//! cycle. Combinational stages traverse within a cycle; spill registers
//! add the fixed hop/response delays derived from the configured NUMA
//! latencies. The response path is modeled with complete arbitration
//! collapsed into its fixed delay (the paper's AMAT model, Sec. 3.1, also
//! attributes contention to the request path).
//!
//! ## Sharding (mirrors the physical hierarchy)
//!
//! The paper's key structural property — a request's destination Tile
//! fully determines the slave ports, bank queues and L1 banks it touches
//! — is reflected in the code: all mutable arbitration state lives in
//! [`TileDomain`], one per Tile, owning that Tile's master ports (source
//! side), slave ports + bank queues + L1 bank slice (destination side),
//! arrival/response time wheels and per-class statistics. Domains
//! exchange requests only through explicit [`XferEvent`] hand-offs
//! (master-port winners crossing a hierarchy boundary), merged once per
//! cycle in fixed Tile order. Because every domain consumes its inputs in
//! a canonical order (PE-ascending requests, Tile-ascending transfer
//! merges) and iterates its internal arbitration points in a
//! partition-independent order, stepping the domains serially on one
//! thread or spread across any number of worker threads produces
//! bit-identical results (`rust/tests/parallel_equiv.rs`).
//!
//! [`Interconnect`] is the thin router/facade over the domain array used
//! by the serial engine and the unit tests; the tile-parallel engine in
//! [`crate::cluster::Cluster::run_parallel`] drives the same domains
//! directly from its worker threads.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::config::{ClusterConfig, LatencyCfg};
use crate::isa::MAX_BURST_WORDS;
use crate::memory::{BankAddr, L1Memory, TileStore};

/// NUMA distance class of an access (Fig. 8b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumaClass {
    Local = 0,
    SubGroup = 1,
    Group = 2,
    RemoteGroup = 3,
}

pub const NUMA_CLASSES: [NumaClass; 4] = [
    NumaClass::Local,
    NumaClass::SubGroup,
    NumaClass::Group,
    NumaClass::RemoteGroup,
];

/// What the request does at the bank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReqKind {
    /// Load into register `rd` of the issuing core.
    Read { rd: u8 },
    /// Store `value`.
    Write,
    /// Atomic fetch-and-add of `value` (the join primitive).
    Amo,
}

/// An in-flight L1 request. Carried by value through the queues and
/// wheels (no slab/id indirection: a request lives in exactly one domain
/// structure at a time, or in a transfer event between domains).
///
/// A TCDM **burst run** is a request with `words > 1`: `words`
/// consecutive banks starting at `bank` at one row, arbitrated as a
/// single unit at the destination's bank ports and answered by one
/// response. `cluster::route_action` splits a burst instruction into
/// runs along Tile/row boundaries (`AddressMap::map_burst`); `last`
/// marks the run that retires the issuing PE's transaction-table entry.
/// The payload rides in the fixed `wdata` array so the type stays
/// `Copy` for the sharded engine's mailboxes.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub core: u32,
    pub kind: ReqKind,
    pub value: f32,
    pub bank: BankAddr,
    pub class: NumaClass,
    pub issue_cycle: u64,
    /// Cluster-defined tag (e.g. barrier id + 1); 0 = none.
    pub tag: u32,
    /// Beats in this request (1 = single word; > 1 = burst run).
    pub words: u8,
    /// True for single requests and for a burst's final run: completing
    /// it releases the PE's LSU transaction-table entry.
    pub last: bool,
    /// Burst payload: store data in (writes), loaded data out (reads),
    /// one slot per beat. Single-word requests keep using `value`.
    pub wdata: [f32; MAX_BURST_WORDS],
    slave_port: u8,
    hop_delay: u32,
    resp_delay: u32,
}

/// A completed request delivered back to the cluster. `words`, `last`
/// and `wdata` mirror the [`Request`] burst fields: a burst run answers
/// with one response carrying all its beats.
#[derive(Debug, Clone, Copy)]
pub struct Response {
    pub core: u32,
    pub kind: ReqKind,
    pub value: f32,
    pub latency: u64,
    pub class: NumaClass,
    pub tag: u32,
    pub words: u8,
    pub last: bool,
    pub wdata: [f32; MAX_BURST_WORDS],
}

impl Response {
    /// The barrier whose arrival atomic this response acknowledges, if
    /// any (barrier arrivals are tagged AMOs; see
    /// `cluster::route_action`). Shared by the serial engine's
    /// bookkeeping and the sharded engine's drain-time arrival counting
    /// so both classify responses identically.
    pub fn barrier_id(&self) -> Option<u16> {
        if matches!(self.kind, ReqKind::Amo) && self.tag != 0 {
            Some((self.tag - 1) as u16)
        } else {
            None
        }
    }
}

/// A master-port winner crossing a hierarchy boundary: generated by the
/// source Tile's domain, merged into the destination Tile's arrival wheel
/// at the top of the next cycle (the spill-register hop is ≥ 1 cycle, so
/// the hand-off never races the arrival).
#[derive(Debug, Clone, Copy)]
pub struct XferEvent {
    /// Cycle the request emerges from the spill registers.
    pub at: u64,
    pub dst_tile: u32,
    pub slave_port: u8,
    pub req: Request,
}

/// Fixed-size time wheel for delayed events (all delays ≤ 16 cycles).
struct Wheel<T> {
    slots: Vec<Vec<T>>,
}

const WHEEL: usize = 32;

impl<T> Wheel<T> {
    fn new() -> Self {
        Wheel { slots: (0..WHEEL).map(|_| Vec::new()).collect() }
    }
    fn push(&mut self, at: u64, item: T) {
        self.slots[(at as usize) % WHEEL].push(item);
    }
    /// Swap the due slot into `scratch` (capacity is recycled both ways —
    /// §Perf: `mem::take` here caused a realloc per cycle per wheel).
    fn drain_into(&mut self, now: u64, scratch: &mut Vec<T>) {
        scratch.clear();
        std::mem::swap(&mut self.slots[(now as usize) % WHEEL], scratch);
    }
}

/// Per-class latency/contention accounting (drives the measured-AMAT
/// validation of the analytical model, Sec. 7). `count` covers every
/// retired request; the `burst_*` fields split out the multi-word
/// subset (`burst_count` requests moving `burst_words` words total), so
/// `count - burst_count` is the single-word traffic and the legacy
/// totals are recoverable from a burst-off run unchanged.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassStats {
    pub count: u64,
    pub latency_sum: u64,
    pub latency_max: u64,
    pub contention_sum: u64,
    pub burst_count: u64,
    pub burst_words: u64,
}

impl ClassStats {
    pub fn amat(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.latency_sum as f64 / self.count as f64 }
    }
}

/// Interconnect statistics. Accumulated per Tile domain (integer sums, so
/// the merge over domains is order-insensitive and exact) and aggregated
/// by [`Interconnect::stats`].
#[derive(Debug, Clone, Default)]
pub struct IcnStats {
    pub per_class: [ClassStats; 4],
    /// Requests that lost a bank arbitration at least once.
    pub bank_conflicts: u64,
    pub issued: u64,
    pub completed: u64,
}

impl IcnStats {
    /// Average memory access time over all completed requests.
    pub fn amat(&self) -> f64 {
        let (mut n, mut s) = (0u64, 0u64);
        for c in &self.per_class {
            n += c.count;
            s += c.latency_sum;
        }
        if n == 0 { 0.0 } else { s as f64 / n as f64 }
    }
    /// Fraction of cycles lost to contention (beyond zero-load latency).
    pub fn contention_fraction(&self) -> f64 {
        let (mut s, mut c) = (0u64, 0u64);
        for cl in &self.per_class {
            s += cl.latency_sum;
            c += cl.contention_sum;
        }
        if s == 0 { 0.0 } else { c as f64 / s as f64 }
    }
    /// Fold another accumulator into this one (all integer sums/maxima,
    /// so the result is independent of merge order).
    pub fn merge(&mut self, other: &IcnStats) {
        for (a, b) in self.per_class.iter_mut().zip(&other.per_class) {
            a.count += b.count;
            a.latency_sum += b.latency_sum;
            a.latency_max = a.latency_max.max(b.latency_max);
            a.contention_sum += b.contention_sum;
            a.burst_count += b.burst_count;
            a.burst_words += b.burst_words;
        }
        self.bank_conflicts += other.bank_conflicts;
        self.issued += other.issued;
        self.completed += other.completed;
    }
}

const PORTS_PER_TILE: usize = 7;

/// Pure routing math resolved from the cluster configuration: NUMA
/// classification, master/slave port selection and the request/response
/// delay split. Read-only after construction, so the phase-1 workers of
/// the parallel engine classify and bucket requests without touching any
/// shared mutable state.
#[derive(Debug, Clone)]
pub struct Topology {
    tiles_per_subgroup: usize,
    tiles_per_group: usize,
    banks_per_tile: usize,
    latency: LatencyCfg,
}

impl Topology {
    pub fn new(cfg: &ClusterConfig) -> Self {
        Topology {
            tiles_per_subgroup: cfg.hierarchy.tiles_per_subgroup,
            tiles_per_group: cfg.hierarchy.tiles_per_group(),
            banks_per_tile: cfg.banks_per_tile(),
            latency: cfg.latency,
        }
    }

    /// NUMA class of (source tile → destination bank's tile).
    pub fn classify(&self, src_tile: usize, dst_tile: usize) -> NumaClass {
        if src_tile == dst_tile {
            return NumaClass::Local;
        }
        if src_tile / self.tiles_per_group != dst_tile / self.tiles_per_group {
            return NumaClass::RemoteGroup;
        }
        let s_sg = (src_tile % self.tiles_per_group) / self.tiles_per_subgroup;
        let d_sg = (dst_tile % self.tiles_per_group) / self.tiles_per_subgroup;
        if s_sg == d_sg { NumaClass::SubGroup } else { NumaClass::Group }
    }

    /// Tile owning a bank (the request's destination domain).
    pub fn dst_tile_of(&self, bank: BankAddr) -> usize {
        bank.bank as usize / self.banks_per_tile
    }

    /// Master-port index (0..7) at the source tile for a destination.
    fn master_port(&self, src_tile: usize, dst_tile: usize, class: NumaClass) -> usize {
        match class {
            NumaClass::Local => unreachable!("local requests bypass master ports"),
            NumaClass::SubGroup => 0,
            NumaClass::Group => {
                let s_sg = (src_tile % self.tiles_per_group) / self.tiles_per_subgroup;
                let d_sg = (dst_tile % self.tiles_per_group) / self.tiles_per_subgroup;
                1 + if d_sg < s_sg { d_sg } else { d_sg - 1 }
            }
            NumaClass::RemoteGroup => {
                let s_g = src_tile / self.tiles_per_group;
                let d_g = dst_tile / self.tiles_per_group;
                4 + if d_g < s_g { d_g } else { d_g - 1 }
            }
        }
    }

    /// Slave-port index at the destination tile (symmetric to master).
    fn slave_port(&self, src_tile: usize, dst_tile: usize, class: NumaClass) -> usize {
        self.master_port(dst_tile, src_tile, class)
    }

    /// Zero-load round-trip latency of a class.
    pub fn zero_load(&self, class: NumaClass) -> u32 {
        match class {
            NumaClass::Local => self.latency.local,
            NumaClass::SubGroup => self.latency.subgroup,
            NumaClass::Group => self.latency.group,
            NumaClass::RemoteGroup => self.latency.remote_group,
        }
    }

    fn delays(&self, class: NumaClass) -> (u32, u32) {
        // (request hop delay master→slave, response delay bank→core) such
        // that the zero-load round trip equals the configured latency.
        let split = |l: u32| {
            let hop = (l - 1) / 2;
            // The cross-domain hand-off merges master winners into their
            // destination wheel at the top of the *next* cycle, which is
            // only correct while the spill-register hop is ≥ 1 cycle
            // (a hop-0 event would land after its wheel slot drained and
            // silently arrive a full wheel revolution late). All non-local
            // NUMA latencies ≥ 3 satisfy this; enforce it for future
            // configs.
            debug_assert!(
                hop >= 1,
                "non-local latency {l} splits to a 0-cycle spill hop; \
                 the sharded hand-off needs hop >= 1 (latency >= 3)"
            );
            (hop, l - hop) // bank at issue+hop, data ready at issue+l
        };
        match class {
            NumaClass::Local => (0, self.latency.local),
            NumaClass::SubGroup => split(self.latency.subgroup),
            NumaClass::Group => split(self.latency.group),
            NumaClass::RemoteGroup => split(self.latency.remote_group),
        }
    }

    /// Build a request from `core` (in `src_tile`) to `bank`. Returns the
    /// request and its ingestion point at the *source* Tile's domain:
    /// `None` = same-Tile access straight to the bank queue, `Some(port)`
    /// = master-port queue toward the destination's hierarchy level. A
    /// pure function of the address map — phase-1 workers call this
    /// concurrently.
    #[allow(clippy::too_many_arguments)]
    pub fn make_request(
        &self,
        now: u64,
        core: u32,
        src_tile: usize,
        kind: ReqKind,
        value: f32,
        bank: BankAddr,
        tag: u32,
    ) -> (Request, Option<u8>) {
        let dst_tile = self.dst_tile_of(bank);
        let class = self.classify(src_tile, dst_tile);
        let (hop_delay, resp_delay) = self.delays(class);
        let (slave_port, master_port) = if class == NumaClass::Local {
            (0u8, None)
        } else {
            (
                self.slave_port(src_tile, dst_tile, class) as u8,
                Some(self.master_port(src_tile, dst_tile, class) as u8),
            )
        };
        // Beat 0's payload mirrors `value` so the bank access path reads
        // write data uniformly from `wdata` for singles and bursts alike.
        let mut wdata = [0.0; MAX_BURST_WORDS];
        wdata[0] = value;
        (
            Request {
                core,
                kind,
                value,
                bank,
                class,
                issue_cycle: now,
                tag,
                words: 1,
                last: true,
                wdata,
                slave_port,
                hop_delay,
                resp_delay,
            },
            master_port,
        )
    }
}

/// One Tile's memory domain: the Tile's 7 master ports (source side), its
/// 7 slave ports and bank queues (destination side), the arrival and
/// response time wheels, and the per-class statistics. All state a
/// request touches after bucketing lives in exactly one domain, so
/// domains step concurrently without locks on shared data.
///
/// Determinism: a domain is a state machine whose inputs arrive in a
/// canonical order (requests in PE-ascending order from its own Tile's
/// PEs, transfer events in Tile-ascending source order), and whose
/// internal arbitration sweeps are keyed to the domain alone — so its
/// evolution is independent of how domains are grouped onto host threads.
pub struct TileDomain {
    bank_base: u32,
    master_q: Vec<VecDeque<Request>>,
    slave_q: Vec<VecDeque<Request>>,
    bank_q: Vec<VecDeque<Request>>,
    active_masters: Vec<u8>,
    active_slaves: Vec<u8>,
    active_banks: Vec<u16>,
    arrivals: Wheel<(u8, Request)>,
    responses: Wheel<Response>,
    /// Requests resident in this domain (queues + wheels); 0 = idle.
    live: u32,
    pub stats: IcnStats,
    scratch_arr: Vec<(u8, Request)>,
    scratch_resp: Vec<Response>,
    scratch_nodes: Vec<u16>,
}

impl TileDomain {
    fn new(tile: usize, cfg: &ClusterConfig) -> Self {
        let bpt = cfg.banks_per_tile();
        TileDomain {
            bank_base: (tile * bpt) as u32,
            master_q: vec![VecDeque::new(); PORTS_PER_TILE],
            slave_q: vec![VecDeque::new(); PORTS_PER_TILE],
            bank_q: vec![VecDeque::new(); bpt],
            active_masters: Vec::new(),
            active_slaves: Vec::new(),
            active_banks: Vec::new(),
            arrivals: Wheel::new(),
            responses: Wheel::new(),
            live: 0,
            stats: IcnStats::default(),
            scratch_arr: Vec::new(),
            scratch_resp: Vec::new(),
            scratch_nodes: Vec::new(),
        }
    }

    /// Nothing queued or in flight inside this domain.
    pub fn is_idle(&self) -> bool {
        self.live == 0
    }

    /// Same-Tile request straight to its bank queue (issued by one of
    /// this Tile's own PEs this cycle, before arbitration).
    pub fn ingest_local(&mut self, req: Request) {
        self.live += 1;
        self.stats.issued += 1;
        let b = (req.bank.bank - self.bank_base) as usize;
        if self.bank_q[b].is_empty() {
            self.active_banks.push(b as u16);
        }
        self.bank_q[b].push_back(req);
    }

    /// Remote-bound request onto one of this (source) Tile's master
    /// ports.
    pub fn ingest_master(&mut self, port: u8, req: Request) {
        self.live += 1;
        self.stats.issued += 1;
        let q = &mut self.master_q[port as usize];
        if q.is_empty() {
            self.active_masters.push(port);
        }
        q.push_back(req);
    }

    /// A transfer event routed to this (destination) Tile: the request
    /// sits in the spill registers until `at`, then joins slave port
    /// `port`.
    pub fn ingest_arrival(&mut self, at: u64, port: u8, req: Request) {
        self.live += 1;
        self.arrivals.push(at, (port, req));
    }

    /// Schedule the response for a request whose bank access(es) just
    /// completed. One response per request — a burst run answers once
    /// for all its beats.
    fn push_response(&mut self, now: u64, req: Request) {
        let due = (now + req.resp_delay as u64).max(now + 1);
        self.responses.push(
            due,
            Response {
                core: req.core,
                kind: req.kind,
                value: req.value,
                latency: due - req.issue_cycle,
                class: req.class,
                tag: req.tag,
                words: req.words,
                last: req.last,
                wdata: req.wdata,
            },
        );
    }

    /// Advance this domain one cycle: deliver spill-register arrivals,
    /// arbitrate the master ports, slave ports/crossbar outputs and banks
    /// (one grant per node per cycle), perform the granted accesses on
    /// this Tile's L1 slice, and hand master winners (`xfer_out`) and
    /// responses falling due next cycle (`resp_out`) back to the caller.
    pub fn step(
        &mut self,
        now: u64,
        store: &mut TileStore,
        topo: &Topology,
        xfer_out: &mut Vec<XferEvent>,
        resp_out: &mut Vec<Response>,
    ) {
        // 1. Requests emerging from spill registers join their slave port.
        let mut arr = std::mem::take(&mut self.scratch_arr);
        self.arrivals.drain_into(now, &mut arr);
        for &(port, req) in arr.iter() {
            let q = &mut self.slave_q[port as usize];
            if q.is_empty() {
                self.active_slaves.push(port);
            }
            q.push_back(req);
        }
        self.scratch_arr = arr;

        // 2. Master-port arbitration: the winner crosses the hierarchy
        //    boundary and becomes a transfer event toward its destination
        //    Tile's domain (ingested there at the top of the next cycle).
        let mut nodes = std::mem::take(&mut self.scratch_nodes);
        nodes.clear();
        nodes.extend(self.active_masters.iter().map(|&p| p as u16));
        self.active_masters.clear();
        for &p in nodes.iter() {
            let q = &mut self.master_q[p as usize];
            if let Some(req) = q.pop_front() {
                self.live -= 1;
                xfer_out.push(XferEvent {
                    at: now + req.hop_delay as u64,
                    dst_tile: topo.dst_tile_of(req.bank) as u32,
                    slave_port: req.slave_port,
                    req,
                });
            }
            if !q.is_empty() {
                self.active_masters.push(p as u8);
            }
        }

        // 3. Slave-port arbitration (the FC crossbar output toward this
        //    tile): the winner proceeds to its bank the same cycle
        //    (combinational within the tile).
        nodes.clear();
        nodes.extend(self.active_slaves.iter().map(|&p| p as u16));
        self.active_slaves.clear();
        for &p in nodes.iter() {
            let q = &mut self.slave_q[p as usize];
            if let Some(req) = q.pop_front() {
                let b = (req.bank.bank - self.bank_base) as usize;
                if self.bank_q[b].is_empty() {
                    self.active_banks.push(b as u16);
                }
                self.bank_q[b].push_back(req);
            }
            if !q.is_empty() {
                self.active_slaves.push(p as u8);
            }
        }

        // 4. Bank ports: one access per bank per cycle, on this Tile's
        //    own L1 slice. Two deterministic passes over the same
        //    active-bank snapshot: burst runs first — a run queued at
        //    bank `b` claims the `words` consecutive bank ports
        //    b..b+words and performs all its beats under one grant (the
        //    TCDM burst wide grant) — then single-word heads at banks no
        //    run claimed. `covered` is a bank-port bitmask
        //    (banks_per_tile ≤ 32 in every shipped configuration), so
        //    the grant outcome depends only on this domain's
        //    insertion-ordered active list — partition-independent, as
        //    the deterministic-merge invariant requires.
        nodes.clear();
        nodes.extend_from_slice(&self.active_banks);
        self.active_banks.clear();
        debug_assert!(self.bank_q.len() <= 64, "covered bitmask needs widening");
        let mut covered: u64 = 0;
        for &b in nodes.iter() {
            let q = &mut self.bank_q[b as usize];
            let w = match q.front() {
                Some(r) if r.words > 1 => r.words as usize,
                _ => continue,
            };
            let mask = ((1u64 << w) - 1) << b;
            if covered & mask != 0 {
                continue; // overlaps a run already granted this cycle
            }
            covered |= mask;
            let mut req = q.pop_front().unwrap();
            let (lb, row) = (b as usize, req.bank.row as usize);
            debug_assert!(lb + w <= self.bank_q.len(), "burst run leaves the Tile");
            match req.kind {
                ReqKind::Read { .. } => {
                    for k in 0..w {
                        req.wdata[k] = store.read(lb + k, row);
                    }
                    req.value = req.wdata[0];
                }
                ReqKind::Write => {
                    for k in 0..w {
                        store.write(lb + k, row, req.wdata[k]);
                    }
                }
                ReqKind::Amo => unreachable!("AMOs never travel as bursts"),
            }
            self.push_response(now, req);
        }
        for &b in nodes.iter() {
            if covered & (1u64 << b) != 0 {
                continue; // port claimed by a burst run this cycle
            }
            let q = &mut self.bank_q[b as usize];
            if !matches!(q.front(), Some(r) if r.words <= 1) {
                continue; // empty, or a (stalled) burst head
            }
            let mut req = q.pop_front().unwrap();
            let (lb, row) = (b as usize, req.bank.row as usize);
            match req.kind {
                ReqKind::Read { .. } => {
                    req.value = store.read(lb, row);
                    req.wdata[0] = req.value;
                }
                ReqKind::Write => store.write(lb, row, req.wdata[0]),
                ReqKind::Amo => req.value = store.amo_add(lb, row, req.value),
            }
            self.push_response(now, req);
        }
        for &b in nodes.iter() {
            let q = &self.bank_q[b as usize];
            if !q.is_empty() {
                self.stats.bank_conflicts += q.len() as u64;
                self.active_banks.push(b);
            }
        }
        self.scratch_nodes = nodes;

        // 5. Responses falling due next cycle leave the domain now; the
        //    coordinator delivers them at the top of cycle `now + 1`,
        //    exactly when the serial reference engine would.
        let mut due = std::mem::take(&mut self.scratch_resp);
        self.responses.drain_into(now + 1, &mut due);
        for &r in due.iter() {
            let zero_load = topo.zero_load(r.class) as u64;
            let cs = &mut self.stats.per_class[r.class as usize];
            cs.count += 1;
            cs.latency_sum += r.latency;
            cs.latency_max = cs.latency_max.max(r.latency);
            cs.contention_sum += r.latency.saturating_sub(zero_load);
            if r.words > 1 {
                cs.burst_count += 1;
                cs.burst_words += r.words as u64;
            }
            self.stats.completed += 1;
            self.live -= 1;
            resp_out.push(r);
        }
        self.scratch_resp = due;
    }
}

/// The thin router over the per-Tile domain array: ingestion (serial
/// engine + unit tests), the cycle step in fixed Tile order, the
/// cross-domain transfer merge, and statistics aggregation.
///
/// Domains sit behind uncontended mutexes so the tile-parallel engine's
/// workers can own disjoint Tile ranges during their phase while the
/// coordinator owns them between phases; the serial paths below go
/// through `Mutex::get_mut` and never lock.
pub struct Interconnect {
    topo: Topology,
    domains: Vec<Mutex<TileDomain>>,
    /// Master winners awaiting the start-of-next-cycle merge (serial
    /// engine; the sharded engine routes these through per-(source,
    /// destination) worker mailboxes instead).
    xfer_buf: Vec<XferEvent>,
    xfer_scratch: Vec<XferEvent>,
    /// Responses drained from the domains, due for delivery at the next
    /// [`Interconnect::drain_responses`] call.
    pending_resp: Vec<Response>,
    inflight: u64,
}

impl Interconnect {
    pub fn new(cfg: &ClusterConfig) -> Self {
        Interconnect {
            topo: Topology::new(cfg),
            domains: (0..cfg.num_tiles())
                .map(|t| Mutex::new(TileDomain::new(t, cfg)))
                .collect(),
            xfer_buf: Vec::new(),
            xfer_scratch: Vec::new(),
            pending_resp: Vec::new(),
            inflight: 0,
        }
    }

    /// The routing math (shared read-only with the parallel workers).
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// A Tile's domain cell (the parallel engine's workers lock their
    /// owned range once per cycle; phases strictly alternate, so the
    /// locks are never contended).
    pub fn domain(&self, tile: usize) -> &Mutex<TileDomain> {
        &self.domains[tile]
    }

    pub fn num_domains(&self) -> usize {
        self.domains.len()
    }

    /// Requests alive anywhere in the memory system (queues, wheels,
    /// in-transit transfer events).
    pub fn inflight(&self) -> u64 {
        self.inflight
    }

    /// Events the serial step still has buffered between cycles:
    /// responses already drained from the wheels (no longer counted by
    /// [`Interconnect::inflight`]) but not yet delivered to their PEs,
    /// and master winners awaiting the next cycle's destination-wheel
    /// merge. The serial engine's idle-skip must see both empty — an
    /// `inflight() == 0` cluster with a pending response is one cycle
    /// away from waking a PE.
    pub fn has_pending(&self) -> bool {
        !self.pending_resp.is_empty() || !self.xfer_buf.is_empty()
    }

    /// Overwrite the in-flight count after a parallel run (the workers
    /// track births/deaths in their channels while they own the domains).
    pub(crate) fn set_inflight(&mut self, v: u64) {
        self.inflight = v;
    }

    /// NUMA class of (source tile → destination tile).
    pub fn classify(&self, src_tile: usize, dst_tile: usize) -> NumaClass {
        self.topo.classify(src_tile, dst_tile)
    }

    /// Ingest a prepared request at its source Tile's domain (see
    /// [`Topology::make_request`] for the `master_port` contract).
    pub fn ingest(&mut self, src_tile: usize, req: Request, master_port: Option<u8>) {
        self.inflight += 1;
        let d = self.domains[src_tile].get_mut().unwrap();
        match master_port {
            None => d.ingest_local(req),
            Some(p) => d.ingest_master(p, req),
        }
    }

    /// Issue a request from `core` (in `src_tile`) to `bank`. Serial
    /// engine + unit tests; the parallel workers use
    /// [`Topology::make_request`] and ingest into their own domains.
    #[allow(clippy::too_many_arguments)]
    pub fn push_request(
        &mut self,
        now: u64,
        core: u32,
        src_tile: usize,
        kind: ReqKind,
        value: f32,
        bank: BankAddr,
        tag: u32,
    ) {
        let (req, port) = self.topo.make_request(now, core, src_tile, kind, value, bank, tag);
        self.ingest(src_tile, req, port);
    }

    /// Hand the serial engine's carry-over buffers to a parallel run
    /// (mixed-engine stepping: responses already drained but not yet
    /// delivered, and transfer events awaiting the next-cycle merge).
    /// Appends into caller-owned scratch in stream order and leaves the
    /// internal queues empty *with their capacity intact* — the hot-path
    /// variant of `mem::take`, which would discard the allocations on
    /// every run (Table-6 scale: one pair per `try_run_threads` call).
    pub(crate) fn drain_pending(&mut self, resp: &mut Vec<Response>, xfers: &mut Vec<XferEvent>) {
        resp.append(&mut self.pending_resp);
        xfers.append(&mut self.xfer_buf);
    }

    /// Inverse hand-off: a parallel run that exited with undelivered
    /// events (only possible on a timeout) puts them back in the serial
    /// pending queues, so redelivery on continuation behaves as the
    /// serial engine's. Within each (source, destination) pair the
    /// stream order is preserved, which is the only order any observer
    /// depends on (per-PE response order; per-destination-wheel merge
    /// order).
    pub(crate) fn restore_pending(&mut self, mut resp: Vec<Response>, mut xfers: Vec<XferEvent>) {
        self.pending_resp.append(&mut resp);
        self.xfer_buf.append(&mut xfers);
    }

    /// Advance one cycle on the serial path: merge the previous cycle's
    /// master winners into their destination wheels (fixed Tile order),
    /// then step every domain in ascending Tile order against its own L1
    /// slice.
    pub fn step(&mut self, now: u64, l1: &mut L1Memory) {
        let Interconnect {
            topo,
            domains,
            xfer_buf,
            xfer_scratch,
            pending_resp,
            inflight,
        } = self;

        xfer_scratch.clear();
        std::mem::swap(xfer_buf, xfer_scratch);
        for ev in xfer_scratch.drain(..) {
            domains[ev.dst_tile as usize]
                .get_mut()
                .unwrap()
                .ingest_arrival(ev.at, ev.slave_port, ev.req);
        }

        let before = pending_resp.len();
        for (t, cell) in domains.iter_mut().enumerate() {
            let d = cell.get_mut().unwrap();
            if d.is_idle() {
                continue;
            }
            d.step(now, l1.tile_store_mut(t), topo, xfer_buf, pending_resp);
        }
        *inflight -= (pending_resp.len() - before) as u64;
    }

    /// Deliver all responses due this cycle (drained from the domain
    /// wheels at the end of the previous cycle's [`Interconnect::step`]),
    /// in fixed Tile order — identical to the order the parallel engine's
    /// coordinator merges per-worker response buffers.
    pub fn drain_responses(&mut self, _now: u64, mut sink: impl FnMut(Response)) {
        for r in self.pending_resp.drain(..) {
            sink(r);
        }
    }

    /// Aggregate statistics over all Tile domains (integer merges in
    /// fixed Tile order; exact regardless of engine or thread count).
    pub fn stats(&self) -> IcnStats {
        let mut agg = IcnStats::default();
        for cell in &self.domains {
            agg.merge(&cell.lock().unwrap().stats);
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::memory::L1Memory;

    fn setup() -> (ClusterConfig, L1Memory, Interconnect) {
        let cfg = ClusterConfig::terapool(9);
        let l1 = L1Memory::new(&cfg);
        let icn = Interconnect::new(&cfg);
        (cfg, l1, icn)
    }

    /// Run until a single response arrives; return (latency, value).
    fn run_one(icn: &mut Interconnect, l1: &mut L1Memory) -> (u64, f32) {
        let mut out = None;
        for now in 0..64 {
            icn.drain_responses(now, |r| out = Some((r.latency, r.value)));
            if let Some(o) = out {
                return o;
            }
            icn.step(now, l1);
        }
        panic!("no response after 64 cycles");
    }

    #[test]
    fn zero_load_latencies_match_numa_profile() {
        let (cfg, mut l1, _) = setup();
        // (dst_tile, expected RT) per class from tile 0.
        for (dst_tile, expect) in [(0usize, 1u64), (1, 3), (8, 5), (32, 9)] {
            let mut icn = Interconnect::new(&cfg);
            let bank = BankAddr { bank: (dst_tile * cfg.banks_per_tile()) as u32, row: 5 };
            l1.write_bank(bank, 42.5);
            icn.push_request(0, 0, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
            let (lat, val) = run_one(&mut icn, &mut l1);
            assert_eq!(lat, expect, "dst_tile={dst_tile}");
            assert_eq!(val, 42.5);
        }
    }

    #[test]
    fn zero_load_latencies_7_and_11() {
        for (rg, expect) in [(7u32, 7u64), (11, 11)] {
            let cfg = ClusterConfig::terapool(rg);
            let mut l1 = L1Memory::new(&cfg);
            let mut icn = Interconnect::new(&cfg);
            let bank = BankAddr { bank: (32 * cfg.banks_per_tile()) as u32, row: 0 };
            icn.push_request(0, 0, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
            let (lat, _) = run_one(&mut icn, &mut l1);
            assert_eq!(lat, expect);
        }
    }

    #[test]
    fn bank_conflict_serializes() {
        let (cfg, mut l1, mut icn) = setup();
        let bank = BankAddr { bank: 0, row: 0 };
        // 4 local cores of tile 0 hit the same bank.
        for core in 0..4 {
            icn.push_request(0, core, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
        }
        let mut lats = Vec::new();
        for now in 0..32 {
            icn.drain_responses(now, |r| lats.push(r.latency));
            icn.step(now, &mut l1);
        }
        lats.sort();
        assert_eq!(lats, vec![1, 2, 3, 4], "one grant per bank per cycle");
        assert_eq!(cfg.latency.local, 1);
    }

    #[test]
    fn master_port_contention_adds_cycles() {
        let (cfg, mut l1, mut icn) = setup();
        // 8 cores of tile 0 access 8 *different* banks of tile 1 (same
        // SubGroup): they serialize at tile 0's SubGroup master port.
        for core in 0..8u32 {
            let bank = BankAddr {
                bank: (cfg.banks_per_tile() + core as usize) as u32,
                row: 0,
            };
            icn.push_request(0, core, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
        }
        let mut lats = Vec::new();
        for now in 0..40 {
            icn.drain_responses(now, |r| lats.push(r.latency));
            icn.step(now, &mut l1);
        }
        lats.sort();
        assert_eq!(lats, vec![3, 4, 5, 6, 7, 8, 9, 10]);
    }

    #[test]
    fn writes_and_amos_apply() {
        let (cfg, mut l1, mut icn) = setup();
        let bank = BankAddr { bank: cfg.banks_per_tile() as u32, row: 3 };
        icn.push_request(0, 0, 0, ReqKind::Write, 7.0, bank, 0);
        run_one(&mut icn, &mut l1);
        assert_eq!(l1.read_bank(bank), 7.0);
        icn.push_request(0, 0, 0, ReqKind::Amo, 2.0, bank, 9);
        let (_, v) = run_one(&mut icn, &mut l1);
        assert_eq!(v, 9.0, "amo returns the new value");
        assert_eq!(l1.read_bank(bank), 9.0);
    }

    #[test]
    fn stats_accumulate_contention() {
        let (_, mut l1, mut icn) = setup();
        let bank = BankAddr { bank: 0, row: 0 };
        for core in 0..4 {
            icn.push_request(0, core, 0, ReqKind::Read { rd: 0 }, 0.0, bank, 0);
        }
        for now in 0..16 {
            icn.drain_responses(now, |_| ());
            icn.step(now, &mut l1);
        }
        let stats = icn.stats();
        let s = &stats.per_class[NumaClass::Local as usize];
        assert_eq!(s.count, 4);
        assert_eq!(s.latency_sum, 1 + 2 + 3 + 4);
        assert_eq!(s.contention_sum, 0 + 1 + 2 + 3);
        assert!((stats.amat() - 2.5).abs() < 1e-9);
    }

    /// Build a burst-run request the way `cluster::route_action` does:
    /// a normal single-word request widened to `n` beats.
    fn burst_req(
        icn: &Interconnect,
        core: u32,
        src_tile: usize,
        kind: ReqKind,
        bank: BankAddr,
        n: u8,
        wdata: [f32; MAX_BURST_WORDS],
    ) -> (Request, Option<u8>) {
        let (mut req, port) = icn.topo().make_request(0, core, src_tile, kind, wdata[0], bank, 0);
        req.words = n;
        req.wdata = wdata;
        (req, port)
    }

    #[test]
    fn burst_moves_n_words_in_one_grant() {
        let (cfg, mut l1, mut icn) = setup();
        for k in 0..4u32 {
            l1.write_bank(BankAddr { bank: k, row: 2 }, 10.0 + k as f32);
        }
        let (req, port) = burst_req(
            &icn,
            0,
            0,
            ReqKind::Read { rd: 4 },
            BankAddr { bank: 0, row: 2 },
            4,
            [0.0; MAX_BURST_WORDS],
        );
        icn.ingest(0, req, port);
        let mut got = None;
        for now in 0..8 {
            icn.drain_responses(now, |r| got = Some(r));
            if got.is_some() {
                break;
            }
            icn.step(now, &mut l1);
        }
        let r = got.expect("burst response");
        assert_eq!(r.latency, cfg.latency.local as u64, "one grant, local RT");
        assert_eq!(r.words, 4);
        assert!(r.last);
        assert_eq!(r.wdata, [10.0, 11.0, 12.0, 13.0]);
        let s = &icn.stats().per_class[NumaClass::Local as usize];
        assert_eq!((s.count, s.burst_count, s.burst_words), (1, 1, 4));
    }

    #[test]
    fn burst_store_writes_consecutive_banks() {
        let (_, mut l1, mut icn) = setup();
        let (req, port) = burst_req(
            &icn,
            0,
            0,
            ReqKind::Write,
            BankAddr { bank: 8, row: 1 },
            3,
            [5.0, 6.0, 7.0, 0.0],
        );
        icn.ingest(0, req, port);
        run_one(&mut icn, &mut l1);
        for k in 0..3u32 {
            assert_eq!(l1.read_bank(BankAddr { bank: 8 + k, row: 1 }), 5.0 + k as f32);
        }
        // The beat past the run's end is untouched.
        assert_eq!(l1.read_bank(BankAddr { bank: 11, row: 1 }), 0.0);
    }

    #[test]
    fn burst_claims_consecutive_ports_and_singles_stall() {
        let (_, mut l1, mut icn) = setup();
        // A 4-beat run over banks 0..4 plus singles at banks 2 (inside
        // the run's window — must lose this cycle's arbitration) and 5
        // (outside — unaffected), all issued at cycle 0.
        let (burst, bp) = burst_req(
            &icn,
            0,
            0,
            ReqKind::Read { rd: 4 },
            BankAddr { bank: 0, row: 0 },
            4,
            [0.0; MAX_BURST_WORDS],
        );
        icn.ingest(0, burst, bp);
        icn.push_request(0, 1, 0, ReqKind::Read { rd: 1 }, 0.0, BankAddr { bank: 2, row: 0 }, 0);
        icn.push_request(0, 2, 0, ReqKind::Read { rd: 1 }, 0.0, BankAddr { bank: 5, row: 0 }, 0);
        let mut lats = Vec::new();
        for now in 0..8 {
            icn.drain_responses(now, |r| lats.push((r.core, r.latency)));
            icn.step(now, &mut l1);
        }
        lats.sort();
        assert_eq!(lats, vec![(0, 1), (1, 2), (2, 1)]);
        assert_eq!(icn.stats().bank_conflicts, 1, "the covered single retried once");
    }

    #[test]
    fn stalled_burst_head_blocks_its_bank() {
        let (_, mut l1, mut icn) = setup();
        // Two overlapping runs: banks 0..4 and banks 2..6. The second is
        // ingested after the first, loses the covered-window check, and
        // retries a cycle later — singles behind it wait their turn.
        for (core, base) in [(0u32, 0u32), (1, 2)] {
            let (req, port) = burst_req(
                &icn,
                core,
                0,
                ReqKind::Read { rd: 4 },
                BankAddr { bank: base, row: 0 },
                4,
                [0.0; MAX_BURST_WORDS],
            );
            icn.ingest(0, req, port);
        }
        let mut lats = Vec::new();
        for now in 0..8 {
            icn.drain_responses(now, |r| lats.push((r.core, r.latency)));
            icn.step(now, &mut l1);
        }
        lats.sort();
        assert_eq!(lats, vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn remote_burst_keeps_numa_latency() {
        let (cfg, mut l1, mut icn) = setup();
        // Tile 0 → tile 1 (SubGroup): the run crosses the master/slave
        // ports like any request and still completes in one bank grant.
        let base = cfg.banks_per_tile() as u32;
        for k in 0..4u32 {
            l1.write_bank(BankAddr { bank: base + k, row: 0 }, 20.0 + k as f32);
        }
        let (req, port) = burst_req(
            &icn,
            0,
            0,
            ReqKind::Read { rd: 4 },
            BankAddr { bank: base, row: 0 },
            4,
            [0.0; MAX_BURST_WORDS],
        );
        icn.ingest(0, req, port);
        let (lat, _) = run_one(&mut icn, &mut l1);
        assert_eq!(lat, cfg.latency.subgroup as u64);
        let s = &icn.stats().per_class[NumaClass::SubGroup as usize];
        assert_eq!((s.burst_count, s.burst_words), (1, 4));
    }

    #[test]
    fn classify_covers_hierarchy() {
        let (_, _, icn) = setup();
        assert_eq!(icn.classify(0, 0), NumaClass::Local);
        assert_eq!(icn.classify(0, 7), NumaClass::SubGroup);
        assert_eq!(icn.classify(0, 31), NumaClass::Group);
        assert_eq!(icn.classify(0, 127), NumaClass::RemoteGroup);
        assert_eq!(icn.classify(127, 120), NumaClass::SubGroup);
    }

    #[test]
    fn distinct_ports_for_distinct_destinations() {
        let (_, _, icn) = setup();
        // From tile 0: the three other SubGroups map to ports 1..=3 and
        // the three remote groups to ports 4..=6.
        let p_sg: Vec<usize> = [8, 16, 24]
            .iter()
            .map(|&t| icn.topo().master_port(0, t, NumaClass::Group))
            .collect();
        assert_eq!(p_sg, vec![1, 2, 3]);
        let p_rg: Vec<usize> = [32, 64, 96]
            .iter()
            .map(|&t| icn.topo().master_port(0, t, NumaClass::RemoteGroup))
            .collect();
        assert_eq!(p_rg, vec![4, 5, 6]);
    }

    /// Stepping the domain array is independent of grouping: driving the
    /// domains through the facade must equal driving them through the
    /// same per-domain calls the parallel workers make.
    #[test]
    fn domain_stepping_matches_facade() {
        let (cfg, mut l1, mut icn) = setup();
        // A mixed local/remote burst from tiles 0 and 3.
        let reqs: Vec<(u32, usize, u32)> = vec![
            (0, 0, 0),    // local
            (1, 0, 40),   // tile 0 → tile 1 (subgroup)
            (24, 3, 96),  // tile 3 → tile 3 (local)
            (25, 3, 700), // tile 3 → tile 21 (group)
            (2, 0, 4000), // tile 0 → tile 125 (remote group)
        ];
        for &(core, tile, bank) in &reqs {
            icn.push_request(0, core, tile, ReqKind::Read { rd: 1 }, 0.0, BankAddr { bank, row: 0 }, 0);
        }
        let mut facade_lats = Vec::new();
        for now in 0..32 {
            icn.drain_responses(now, |r| facade_lats.push((r.core, r.latency)));
            icn.step(now, &mut l1);
        }

        // Same traffic, driven domain-by-domain like a worker would.
        let mut l1b = L1Memory::new(&cfg);
        let icn2 = Interconnect::new(&cfg);
        let topo = icn2.topo().clone();
        for &(core, tile, bank) in &reqs {
            let (req, port) = topo.make_request(0, core, tile, ReqKind::Read { rd: 1 }, 0.0, BankAddr { bank, row: 0 }, 0);
            let mut d = icn2.domain(tile).lock().unwrap();
            match port {
                None => d.ingest_local(req),
                Some(p) => d.ingest_master(p, req),
            }
        }
        let mut manual_lats = Vec::new();
        let mut xfers: Vec<XferEvent> = Vec::new();
        let mut resp: Vec<Response> = Vec::new();
        for now in 0..32 {
            for r in resp.drain(..) {
                manual_lats.push((r.core, r.latency));
            }
            for ev in xfers.drain(..).collect::<Vec<_>>() {
                icn2.domain(ev.dst_tile as usize)
                    .lock()
                    .unwrap()
                    .ingest_arrival(ev.at, ev.slave_port, ev.req);
            }
            for t in 0..icn2.num_domains() {
                let mut d = icn2.domain(t).lock().unwrap();
                if d.is_idle() {
                    continue;
                }
                let mut store = l1b.tile_store(t).lock().unwrap();
                d.step(now, &mut store, &topo, &mut xfers, &mut resp);
            }
        }
        assert_eq!(facade_lats, manual_lats, "facade and manual stepping diverge");
    }
}
