//! Cycle-level model of TeraPool's hierarchical PE-to-L1 interconnect
//! (Sec. 3–4).
//!
//! Topology (Fig. 5/6): each Tile's PEs reach their 32 local banks through
//! a fully-combinational logarithmic crossbar (1-cycle round trip). Each
//! Tile additionally exposes **7 master ports**: one to the 8×8 crossbar
//! of its SubGroup, three to the 8×8 crossbars toward the other SubGroups
//! of its Group, and three to the 32×32 crossbars toward the three remote
//! Groups. Spill registers at hierarchy boundaries pipeline long paths,
//! yielding the NUMA round-trip profile 1-3-5-{7,9,11}.
//!
//! Model: every arbitration point (Tile master port per category, target
//! Tile slave port per category — which *is* the FC crossbar output — and
//! the bank port) grants **one request per cycle**; losers retry the next
//! cycle. Combinational stages traverse within a cycle; spill registers
//! add the fixed hop/response delays derived from the configured NUMA
//! latencies. The response path is modeled with complete arbitration
//! collapsed into its fixed delay (the paper's AMAT model, Sec. 3.1, also
//! attributes contention to the request path).

use std::collections::VecDeque;

use crate::config::ClusterConfig;
use crate::memory::{BankAddr, L1Memory};

/// NUMA distance class of an access (Fig. 8b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumaClass {
    Local = 0,
    SubGroup = 1,
    Group = 2,
    RemoteGroup = 3,
}

pub const NUMA_CLASSES: [NumaClass; 4] = [
    NumaClass::Local,
    NumaClass::SubGroup,
    NumaClass::Group,
    NumaClass::RemoteGroup,
];

/// What the request does at the bank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReqKind {
    /// Load into register `rd` of the issuing core.
    Read { rd: u8 },
    /// Store `value`.
    Write,
    /// Atomic fetch-and-add of `value` (the join primitive).
    Amo,
}

/// An in-flight L1 request.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub core: u32,
    pub kind: ReqKind,
    pub value: f32,
    pub bank: BankAddr,
    pub class: NumaClass,
    pub issue_cycle: u64,
    /// Cluster-defined tag (e.g. barrier id + 1); 0 = none.
    pub tag: u32,
    slave_node: u32,
    hop_delay: u32,
    resp_delay: u32,
}

/// A completed request delivered back to the cluster.
#[derive(Debug, Clone, Copy)]
pub struct Response {
    pub core: u32,
    pub kind: ReqKind,
    pub value: f32,
    pub latency: u64,
    pub class: NumaClass,
    pub tag: u32,
}

/// Fixed-size time wheel for delayed events (all delays ≤ 16 cycles).
struct Wheel<T> {
    slots: Vec<Vec<T>>,
}

const WHEEL: usize = 32;

impl<T> Wheel<T> {
    fn new() -> Self {
        Wheel { slots: (0..WHEEL).map(|_| Vec::new()).collect() }
    }
    fn push(&mut self, at: u64, item: T) {
        self.slots[(at as usize) % WHEEL].push(item);
    }
    /// Swap the due slot into `scratch` (capacity is recycled both ways —
    /// §Perf: `mem::take` here caused a realloc per cycle per wheel).
    fn drain_into(&mut self, now: u64, scratch: &mut Vec<T>) {
        scratch.clear();
        std::mem::swap(&mut self.slots[(now as usize) % WHEEL], scratch);
    }
}

/// Per-class latency/contention accounting (drives the measured-AMAT
/// validation of the analytical model, Sec. 7).
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassStats {
    pub count: u64,
    pub latency_sum: u64,
    pub latency_max: u64,
    pub contention_sum: u64,
}

impl ClassStats {
    pub fn amat(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.latency_sum as f64 / self.count as f64 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct IcnStats {
    pub per_class: [ClassStats; 4],
    /// Requests that lost a bank arbitration at least once.
    pub bank_conflicts: u64,
    pub issued: u64,
    pub completed: u64,
}

impl IcnStats {
    /// Average memory access time over all completed requests.
    pub fn amat(&self) -> f64 {
        let (mut n, mut s) = (0u64, 0u64);
        for c in &self.per_class {
            n += c.count;
            s += c.latency_sum;
        }
        if n == 0 { 0.0 } else { s as f64 / n as f64 }
    }
    /// Fraction of cycles lost to contention (beyond zero-load latency).
    pub fn contention_fraction(&self) -> f64 {
        let (mut s, mut c) = (0u64, 0u64);
        for cl in &self.per_class {
            s += cl.latency_sum;
            c += cl.contention_sum;
        }
        if s == 0 { 0.0 } else { c as f64 / s as f64 }
    }
}

const NO_NODE: u32 = u32::MAX;
const PORTS_PER_TILE: usize = 7;

/// The interconnect simulation engine.
pub struct Interconnect {
    // topology
    tiles_per_subgroup: usize,
    tiles_per_group: usize,
    banks_per_tile: usize,
    latency: crate::config::LatencyCfg,

    // arbitration queues (FIFO; head granted each cycle)
    master_q: Vec<VecDeque<u32>>,
    slave_q: Vec<VecDeque<u32>>,
    bank_q: Vec<VecDeque<u32>>,
    active_masters: Vec<u32>,
    active_slaves: Vec<u32>,
    active_banks: Vec<u32>,

    arrivals: Wheel<(u32, u32)>, // (slave node, req)
    responses: Wheel<u32>,
    scratch_arrivals: Vec<(u32, u32)>,
    scratch_responses: Vec<u32>,
    scratch_nodes: Vec<u32>,

    reqs: Vec<Request>,
    free: Vec<u32>,
    pub stats: IcnStats,
    inflight: u64,
}

impl Interconnect {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let tiles = cfg.num_tiles();
        Interconnect {
            tiles_per_subgroup: cfg.hierarchy.tiles_per_subgroup,
            tiles_per_group: cfg.hierarchy.tiles_per_group(),
            banks_per_tile: cfg.banks_per_tile(),
            latency: cfg.latency,
            master_q: vec![VecDeque::new(); tiles * PORTS_PER_TILE],
            slave_q: vec![VecDeque::new(); tiles * PORTS_PER_TILE],
            bank_q: vec![VecDeque::new(); cfg.num_banks()],
            active_masters: Vec::new(),
            active_slaves: Vec::new(),
            active_banks: Vec::new(),
            arrivals: Wheel::new(),
            responses: Wheel::new(),
            scratch_arrivals: Vec::new(),
            scratch_responses: Vec::new(),
            scratch_nodes: Vec::new(),
            reqs: Vec::new(),
            free: Vec::new(),
            stats: IcnStats::default(),
            inflight: 0,
        }
    }

    pub fn inflight(&self) -> u64 {
        self.inflight
    }

    /// NUMA class of (source tile → destination bank's tile).
    pub fn classify(&self, src_tile: usize, dst_tile: usize) -> NumaClass {
        if src_tile == dst_tile {
            return NumaClass::Local;
        }
        if src_tile / self.tiles_per_group != dst_tile / self.tiles_per_group {
            return NumaClass::RemoteGroup;
        }
        let s_sg = (src_tile % self.tiles_per_group) / self.tiles_per_subgroup;
        let d_sg = (dst_tile % self.tiles_per_group) / self.tiles_per_subgroup;
        if s_sg == d_sg { NumaClass::SubGroup } else { NumaClass::Group }
    }

    /// Master-port index (0..7) at the source tile for a destination.
    fn master_port(&self, src_tile: usize, dst_tile: usize, class: NumaClass) -> usize {
        match class {
            NumaClass::Local => unreachable!("local requests bypass master ports"),
            NumaClass::SubGroup => 0,
            NumaClass::Group => {
                let s_sg = (src_tile % self.tiles_per_group) / self.tiles_per_subgroup;
                let d_sg = (dst_tile % self.tiles_per_group) / self.tiles_per_subgroup;
                1 + if d_sg < s_sg { d_sg } else { d_sg - 1 }
            }
            NumaClass::RemoteGroup => {
                let s_g = src_tile / self.tiles_per_group;
                let d_g = dst_tile / self.tiles_per_group;
                4 + if d_g < s_g { d_g } else { d_g - 1 }
            }
        }
    }

    /// Slave-port index at the destination tile (symmetric to master).
    fn slave_port(&self, src_tile: usize, dst_tile: usize, class: NumaClass) -> usize {
        self.master_port(dst_tile, src_tile, class)
    }

    fn delays(&self, class: NumaClass) -> (u32, u32) {
        // (request hop delay master→slave, response delay bank→core) such
        // that the zero-load round trip equals the configured latency.
        let split = |l: u32| {
            let hop = (l - 1) / 2;
            (hop, l - hop) // bank at issue+hop, data ready at issue+l
        };
        match class {
            NumaClass::Local => (0, self.latency.local),
            NumaClass::SubGroup => split(self.latency.subgroup),
            NumaClass::Group => split(self.latency.group),
            NumaClass::RemoteGroup => split(self.latency.remote_group),
        }
    }

    /// Issue a request from `core` (in `src_tile`) to `bank`. Returns the
    /// request id. Called by the cluster during the PE issue phase.
    pub fn push_request(
        &mut self,
        now: u64,
        core: u32,
        src_tile: usize,
        kind: ReqKind,
        value: f32,
        bank: BankAddr,
        tag: u32,
    ) {
        let dst_tile = bank.bank as usize / self.banks_per_tile;
        let class = self.classify(src_tile, dst_tile);
        let (hop_delay, resp_delay) = self.delays(class);
        let slave_node = if class == NumaClass::Local {
            NO_NODE
        } else {
            (dst_tile * PORTS_PER_TILE + self.slave_port(src_tile, dst_tile, class)) as u32
        };
        let req = Request {
            core,
            kind,
            value,
            bank,
            class,
            issue_cycle: now,
            tag,
            slave_node,
            hop_delay,
            resp_delay,
        };
        let id = match self.free.pop() {
            Some(i) => {
                self.reqs[i as usize] = req;
                i
            }
            None => {
                self.reqs.push(req);
                (self.reqs.len() - 1) as u32
            }
        };
        self.stats.issued += 1;
        self.inflight += 1;
        if class == NumaClass::Local {
            Self::enqueue(&mut self.bank_q, &mut self.active_banks, bank.bank, id);
        } else {
            let node = (src_tile * PORTS_PER_TILE
                + self.master_port(src_tile, dst_tile, class)) as u32;
            Self::enqueue(&mut self.master_q, &mut self.active_masters, node, id);
        }
    }

    fn enqueue(qs: &mut [VecDeque<u32>], active: &mut Vec<u32>, node: u32, id: u32) {
        let q = &mut qs[node as usize];
        if q.is_empty() {
            active.push(node);
        }
        q.push_back(id);
    }

    /// Advance one cycle: deliver spill-register arrivals, arbitrate the
    /// master ports, slave ports/crossbar outputs, and banks (one grant
    /// per node per cycle), perform the granted bank accesses on `l1`, and
    /// schedule responses.
    pub fn step(&mut self, now: u64, l1: &mut L1Memory) {
        // 1. Requests emerging from spill registers join their slave port.
        let mut arr = std::mem::take(&mut self.scratch_arrivals);
        self.arrivals.drain_into(now, &mut arr);
        for &(node, id) in &arr {
            Self::enqueue(&mut self.slave_q, &mut self.active_slaves, node, id);
        }
        self.scratch_arrivals = arr;

        // 2. Master-port arbitration: winner crosses the hierarchy
        //    boundary (spill register → arrives at slave port later).
        //    Active lists are swept through a recycled scratch vector
        //    (§Perf: take() dropped their capacity every cycle).
        let mut nodes = std::mem::take(&mut self.scratch_nodes);
        nodes.clear();
        nodes.extend_from_slice(&self.active_masters);
        self.active_masters.clear();
        for &node in &nodes {
            let q = &mut self.master_q[node as usize];
            if let Some(id) = q.pop_front() {
                let r = &self.reqs[id as usize];
                self.arrivals.push(now + r.hop_delay as u64, (r.slave_node, id));
            }
            if !q.is_empty() {
                self.active_masters.push(node);
            }
        }

        // 3. Slave-port arbitration (the FC crossbar output toward the
        //    target tile): winner proceeds to its bank the same cycle
        //    (combinational within the tile).
        nodes.clear();
        nodes.extend_from_slice(&self.active_slaves);
        self.active_slaves.clear();
        for &node in &nodes {
            let q = &mut self.slave_q[node as usize];
            if let Some(id) = q.pop_front() {
                let bank = self.reqs[id as usize].bank.bank;
                Self::enqueue(&mut self.bank_q, &mut self.active_banks, bank, id);
            }
            if !q.is_empty() {
                self.active_slaves.push(node);
            }
        }

        // 4. Bank ports: one access per bank per cycle.
        nodes.clear();
        nodes.extend_from_slice(&self.active_banks);
        self.active_banks.clear();
        let banks = &nodes;
        for &bank in banks {
            let q = &mut self.bank_q[bank as usize];
            if let Some(id) = q.pop_front() {
                let r = &mut self.reqs[id as usize];
                match r.kind {
                    ReqKind::Read { .. } => r.value = l1.read_bank(r.bank),
                    ReqKind::Write => l1.write_bank(r.bank, r.value),
                    ReqKind::Amo => {
                        r.value = l1.amo_add_bank(r.bank, r.value);
                    }
                }
                let resp_at = now + r.resp_delay as u64;
                self.responses.push(resp_at.max(now + 1), id);
            }
            if !q.is_empty() {
                self.stats.bank_conflicts += q.len() as u64;
                self.active_banks.push(bank);
            }
        }
        self.scratch_nodes = nodes;
    }

    /// Deliver all responses due at `now` into a caller-owned vector, in
    /// the same fixed order `drain_responses` uses — the collection form
    /// the two-phase parallel engine needs to bucket responses per Tile
    /// before handing them to the worker threads.
    pub fn drain_responses_into(&mut self, now: u64, out: &mut Vec<Response>) {
        self.drain_responses(now, |r| out.push(r));
    }

    /// Deliver all responses due at `now` (call at the top of each cycle).
    pub fn drain_responses(&mut self, now: u64, mut sink: impl FnMut(Response)) {
        let mut due = std::mem::take(&mut self.scratch_responses);
        self.responses.drain_into(now, &mut due);
        for &id in &due {
            let r = self.reqs[id as usize];
            let latency = now - r.issue_cycle;
            let zero_load = match r.class {
                NumaClass::Local => self.latency.local,
                NumaClass::SubGroup => self.latency.subgroup,
                NumaClass::Group => self.latency.group,
                NumaClass::RemoteGroup => self.latency.remote_group,
            } as u64;
            let cs = &mut self.stats.per_class[r.class as usize];
            cs.count += 1;
            cs.latency_sum += latency;
            cs.latency_max = cs.latency_max.max(latency);
            cs.contention_sum += latency.saturating_sub(zero_load);
            self.stats.completed += 1;
            self.inflight -= 1;
            self.free.push(id);
            sink(Response {
                core: r.core,
                kind: r.kind,
                value: r.value,
                latency,
                class: r.class,
                tag: r.tag,
            });
        }
        self.scratch_responses = due;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::memory::L1Memory;

    fn setup() -> (ClusterConfig, L1Memory, Interconnect) {
        let cfg = ClusterConfig::terapool(9);
        let l1 = L1Memory::new(&cfg);
        let icn = Interconnect::new(&cfg);
        (cfg, l1, icn)
    }

    /// Run until a single response arrives; return (latency, value).
    fn run_one(icn: &mut Interconnect, l1: &mut L1Memory) -> (u64, f32) {
        let mut out = None;
        for now in 0..64 {
            icn.drain_responses(now, |r| out = Some((r.latency, r.value)));
            if let Some(o) = out {
                return o;
            }
            icn.step(now, l1);
        }
        panic!("no response after 64 cycles");
    }

    #[test]
    fn zero_load_latencies_match_numa_profile() {
        let (cfg, mut l1, _) = setup();
        // (dst_tile, expected RT) per class from tile 0.
        for (dst_tile, expect) in [(0usize, 1u64), (1, 3), (8, 5), (32, 9)] {
            let mut icn = Interconnect::new(&cfg);
            let bank = BankAddr { bank: (dst_tile * cfg.banks_per_tile()) as u32, row: 5 };
            l1.write_bank(bank, 42.5);
            icn.push_request(0, 0, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
            let (lat, val) = run_one(&mut icn, &mut l1);
            assert_eq!(lat, expect, "dst_tile={dst_tile}");
            assert_eq!(val, 42.5);
        }
    }

    #[test]
    fn zero_load_latencies_7_and_11() {
        for (rg, expect) in [(7u32, 7u64), (11, 11)] {
            let cfg = ClusterConfig::terapool(rg);
            let mut l1 = L1Memory::new(&cfg);
            let mut icn = Interconnect::new(&cfg);
            let bank = BankAddr { bank: (32 * cfg.banks_per_tile()) as u32, row: 0 };
            icn.push_request(0, 0, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
            let (lat, _) = run_one(&mut icn, &mut l1);
            assert_eq!(lat, expect);
        }
    }

    #[test]
    fn bank_conflict_serializes() {
        let (cfg, mut l1, mut icn) = setup();
        let bank = BankAddr { bank: 0, row: 0 };
        // 4 local cores of tile 0 hit the same bank.
        for core in 0..4 {
            icn.push_request(0, core, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
        }
        let mut lats = Vec::new();
        for now in 0..32 {
            icn.drain_responses(now, |r| lats.push(r.latency));
            icn.step(now, &mut l1);
        }
        lats.sort();
        assert_eq!(lats, vec![1, 2, 3, 4], "one grant per bank per cycle");
        assert_eq!(cfg.latency.local, 1);
    }

    #[test]
    fn master_port_contention_adds_cycles() {
        let (cfg, mut l1, mut icn) = setup();
        // 8 cores of tile 0 access 8 *different* banks of tile 1 (same
        // SubGroup): they serialize at tile 0's SubGroup master port.
        for core in 0..8u32 {
            let bank = BankAddr {
                bank: (cfg.banks_per_tile() + core as usize) as u32,
                row: 0,
            };
            icn.push_request(0, core, 0, ReqKind::Read { rd: 1 }, 0.0, bank, 0);
        }
        let mut lats = Vec::new();
        for now in 0..40 {
            icn.drain_responses(now, |r| lats.push(r.latency));
            icn.step(now, &mut l1);
        }
        lats.sort();
        assert_eq!(lats, vec![3, 4, 5, 6, 7, 8, 9, 10]);
    }

    #[test]
    fn writes_and_amos_apply() {
        let (cfg, mut l1, mut icn) = setup();
        let bank = BankAddr { bank: cfg.banks_per_tile() as u32, row: 3 };
        icn.push_request(0, 0, 0, ReqKind::Write, 7.0, bank, 0);
        run_one(&mut icn, &mut l1);
        assert_eq!(l1.read_bank(bank), 7.0);
        icn.push_request(0, 0, 0, ReqKind::Amo, 2.0, bank, 9);
        let (_, v) = run_one(&mut icn, &mut l1);
        assert_eq!(v, 9.0, "amo returns the new value");
        assert_eq!(l1.read_bank(bank), 9.0);
    }

    #[test]
    fn stats_accumulate_contention() {
        let (_, mut l1, mut icn) = setup();
        let bank = BankAddr { bank: 0, row: 0 };
        for core in 0..4 {
            icn.push_request(0, core, 0, ReqKind::Read { rd: 0 }, 0.0, bank, 0);
        }
        for now in 0..16 {
            icn.drain_responses(now, |_| ());
            icn.step(now, &mut l1);
        }
        let s = &icn.stats.per_class[NumaClass::Local as usize];
        assert_eq!(s.count, 4);
        assert_eq!(s.latency_sum, 1 + 2 + 3 + 4);
        assert_eq!(s.contention_sum, 0 + 1 + 2 + 3);
        assert!((icn.stats.amat() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn classify_covers_hierarchy() {
        let (_, _, icn) = setup();
        assert_eq!(icn.classify(0, 0), NumaClass::Local);
        assert_eq!(icn.classify(0, 7), NumaClass::SubGroup);
        assert_eq!(icn.classify(0, 31), NumaClass::Group);
        assert_eq!(icn.classify(0, 127), NumaClass::RemoteGroup);
        assert_eq!(icn.classify(127, 120), NumaClass::SubGroup);
    }

    #[test]
    fn distinct_ports_for_distinct_destinations() {
        let (_, _, icn) = setup();
        // From tile 0: the three other SubGroups map to ports 1..=3 and
        // the three remote groups to ports 4..=6.
        let p_sg: Vec<usize> = [8, 16, 24]
            .iter()
            .map(|&t| icn.master_port(0, t, NumaClass::Group))
            .collect();
        assert_eq!(p_sg, vec![1, 2, 3]);
        let p_rg: Vec<usize> = [32, 64, 96]
            .iter()
            .map(|&t| icn.master_port(0, t, NumaClass::RemoteGroup))
            .collect();
        assert_eq!(p_rg, vec![4, 5, 6]);
    }
}
