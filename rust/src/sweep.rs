//! The estimate-guided design-space sweep service (ROADMAP item 4).
//!
//! A serving layer above [`crate::session`]: a [`SweepSpec`] declares a
//! config grid (preset × groups/banking overrides × burst × workloads at
//! one scale) in a small text format (`examples/*.sweep`, parsed like
//! `topology.rs` parses `.topo` files), and [`run_sweep`] explores it in
//! three deterministic phases:
//!
//! 1. **Explore** — every point runs through `Session::estimating` via
//!    `run_batch` fan-out: exact census, analytic timing, one fast-scale
//!    calibration run per point.
//! 2. **Refine** — the Pareto frontier over (estimated cycles, physical
//!    cost proxy) is computed, and *only* frontier points re-run through
//!    the cycle-accurate engine.
//! 3. **Verify** — each frontier point's estimate is held against its
//!    measurement with `tools/report_diff.py` semantics in-process
//!    ([`drift_verdict`]): census-backed fields exactly, timing fields
//!    to the spec's rtol.
//!
//! Serving-layer robustness rules:
//!
//! * **Per-point failure isolation** — a point that fails (unknown
//!   workload, `MaxCyclesExceeded`, `Unsupported`, ...) is recorded as a
//!   typed [`PointError`] and the sweep continues; sibling points are
//!   bit-identical to solo runs (jobs are independent by construction).
//! * **Resumable checkpoints** — [`run_sweep`] invokes a checkpoint
//!   callback with the partial [`SweepReport`] after every batch; an
//!   interrupted sweep resumes by passing the parsed checkpoint back as
//!   `prior`: completed points are reused verbatim (no re-estimation),
//!   guarded by the spec fingerprint.
//! * **Determinism** — point order is fixed by axis declaration order;
//!   the engine is bit-identical at any host-thread count; the frontier
//!   is a pure function of the estimates; JSON rendering is
//!   deterministic. A killed-and-resumed sweep therefore produces a
//!   byte-identical `SweepReport`.
//!
//! The cost proxy is silicon area (`physical::area`, gate equivalents):
//! it is defined for *every* config, which keeps the frontier axis
//! comparable across presets. Run energy (`physical::energy`) is
//! recorded as per-point provenance where the config sits on one of the
//! characterized operating points (remote-group latency 7/9/11), but
//! does not enter the frontier — mixing axes that only exist for some
//! points would make dominance depend on which points happen to be
//! characterized.

use std::collections::HashMap;
use std::path::Path;

use crate::config::{ClusterConfig, Scale};
use crate::errors::{Error, ErrorKind, Result};
use crate::kernels;
use crate::physical::{area, energy};
use crate::report::{Json, RunReport, Table};
use crate::session::{Job, Session};

/// Schema tag of the combined sweep document.
pub const SCHEMA: &str = "terapool-sweepreport-v1";

/// Default drift bound, matching `EstimateInfo::stated_rtol` and the CI
/// estimate-accuracy gate.
pub const DEFAULT_RTOL: f64 = 0.10;

fn bad(msg: impl Into<String>) -> Error {
    Error::with_kind(ErrorKind::BadTopology, format!("sweep: {}", msg.into()))
}

// ---------------------------------------------------------------------
// SweepSpec — the declarative config grid
// ---------------------------------------------------------------------

/// A declarative design-space grid. Points expand in fixed nesting
/// order — preset, then groups, then banking, then burst, then workload
/// — each axis in declaration order; that order is the checkpoint and
/// report identity, so it is part of the format's contract.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    /// Problem scale every point runs at (`fast` keeps the grid cheap;
    /// estimates are exact by construction at the calibration scale).
    pub scale: Scale,
    /// Drift bound for the frontier verify phase.
    pub rtol: f64,
    /// Cluster presets (the `topology.rs` preset namespace).
    pub presets: Vec<String>,
    /// `hierarchy.groups` overrides; `None` keeps the preset value.
    pub groups: Vec<Option<usize>>,
    /// `banking_factor` overrides; `None` keeps the preset value.
    pub banking: Vec<Option<usize>>,
    /// TCDM burst access on/off.
    pub burst: Vec<bool>,
    /// Clock frequency overrides (MHz); `None` keeps the preset value.
    /// Frequency feeds the physical model (runtime µs, GFLOP/s per W),
    /// not the cycle count — sweeping it explores operating points at
    /// identical simulated work.
    pub freq: Vec<Option<f64>>,
    /// Registered workload kinds.
    pub workloads: Vec<String>,
}

/// One fully-resolved grid point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Zero-based position in the expansion order.
    pub index: usize,
    /// Stable identity: `config-label/workload/scale-tag`.
    pub key: String,
    pub cfg: ClusterConfig,
    pub workload: String,
}

fn parse_scale(v: &str) -> Result<Scale> {
    match v {
        "fast" => Ok(Scale::Fast),
        "full" => Ok(Scale::Full),
        _ => Err(bad(format!("scale must be fast or full, got {v:?}"))),
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "1" | "true" | "on" => Ok(true),
        "0" | "false" | "off" => Ok(false),
        _ => Err(bad(format!("expected a boolean, got {v:?}"))),
    }
}

/// `default` keeps the preset value; anything else is a positive count.
fn parse_override(axis: &str, v: &str) -> Result<Option<usize>> {
    if v == "default" {
        return Ok(None);
    }
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(Some(n)),
        _ => Err(bad(format!("axis {axis} wants `default` or a positive integer, got {v:?}"))),
    }
}

/// `default` keeps the preset frequency; anything else is a positive
/// finite MHz value.
fn parse_freq(v: &str) -> Result<Option<f64>> {
    if v == "default" {
        return Ok(None);
    }
    match v.parse::<f64>() {
        Ok(f) if f.is_finite() && f > 0.0 => Ok(Some(f)),
        _ => Err(bad(format!(
            "axis freq_mhz wants `default` or a positive MHz value, got {v:?}"
        ))),
    }
}

fn no_dupes<T: PartialEq + std::fmt::Debug>(axis: &str, vals: &[T]) -> Result<()> {
    for (i, v) in vals.iter().enumerate() {
        if vals[..i].contains(v) {
            return Err(bad(format!("axis {axis} repeats value {v:?} (point keys must be unique)")));
        }
    }
    Ok(())
}

impl SweepSpec {
    /// Parse the text format. `name` is the fallback document name when
    /// no `sweep` line is present (the CLI passes the file stem).
    pub fn parse(text: &str, name: &str) -> Result<SweepSpec> {
        let mut spec = SweepSpec {
            name: name.to_string(),
            scale: Scale::Fast,
            rtol: DEFAULT_RTOL,
            presets: Vec::new(),
            groups: Vec::new(),
            banking: Vec::new(),
            burst: Vec::new(),
            freq: Vec::new(),
            workloads: Vec::new(),
        };
        let mut seen_axes: Vec<String> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let at = |e: Error| e.prefixed(&format!("line {}", lineno + 1));
            if let Some(rest) = line.strip_prefix("sweep ") {
                spec.name = rest.trim().to_string();
                continue;
            }
            if let Some(rest) = line.strip_prefix("axis ") {
                let (axis, vals) = rest
                    .split_once('=')
                    .ok_or_else(|| at(bad("axis wants `axis <name> = v1, v2, ...`")))?;
                let axis = axis.trim();
                if seen_axes.iter().any(|a| a == axis) {
                    return Err(at(bad(format!("axis {axis} declared twice"))));
                }
                seen_axes.push(axis.to_string());
                let vals: Vec<&str> = vals
                    .split(|c: char| c == ',' || c.is_whitespace())
                    .filter(|v| !v.is_empty())
                    .collect();
                if vals.is_empty() {
                    return Err(at(bad(format!("axis {axis} needs at least one value"))));
                }
                match axis {
                    "preset" => spec.presets = vals.iter().map(|v| v.to_string()).collect(),
                    "groups" => {
                        spec.groups = vals
                            .iter()
                            .map(|&v| parse_override("groups", v))
                            .collect::<Result<_>>()
                            .map_err(at)?;
                    }
                    "banking" => {
                        spec.banking = vals
                            .iter()
                            .map(|&v| parse_override("banking", v))
                            .collect::<Result<_>>()
                            .map_err(at)?;
                    }
                    "burst" => {
                        spec.burst =
                            vals.iter().map(|&v| parse_bool(v)).collect::<Result<_>>().map_err(at)?;
                    }
                    "freq_mhz" => {
                        spec.freq =
                            vals.iter().map(|&v| parse_freq(v)).collect::<Result<_>>().map_err(at)?;
                    }
                    "workload" => spec.workloads = vals.iter().map(|v| v.to_string()).collect(),
                    other => {
                        return Err(at(bad(format!(
                            "unknown axis {other:?} (known: preset, groups, banking, burst, \
                             freq_mhz, workload)"
                        ))))
                    }
                }
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| at(bad(format!("expected key=value or axis line, got {line:?}"))))?;
            match (k.trim(), v.trim()) {
                ("scale", v) => spec.scale = parse_scale(v).map_err(at)?,
                ("rtol", v) => {
                    spec.rtol = v
                        .parse::<f64>()
                        .map_err(|_| at(bad(format!("rtol wants a number, got {v:?}"))))?;
                }
                (other, _) => {
                    return Err(at(bad(format!(
                        "unknown directive {other:?} (known: sweep, scale, rtol, axis)"
                    ))))
                }
            }
        }
        // Optional axes default to a single no-override point.
        if spec.groups.is_empty() {
            spec.groups.push(None);
        }
        if spec.banking.is_empty() {
            spec.banking.push(None);
        }
        if spec.burst.is_empty() {
            spec.burst.push(false);
        }
        if spec.freq.is_empty() {
            spec.freq.push(None);
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load and parse a sweep file; the file stem is the fallback
    /// document name.
    pub fn load(path: &Path) -> Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| bad(format!("cannot read {}: {e}", path.display())))?;
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("sweep");
        Self::parse(&text, stem).map_err(|e| e.prefixed(&path.display().to_string()))
    }

    /// The invariant pass every constructor runs: axes non-empty and
    /// duplicate-free, presets/workloads resolvable, rtol sane. Workload
    /// rejections keep `kernels::lookup`'s typed `UnknownWorkload`.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "sweep: document needs a name");
        if !(self.rtol.is_finite() && self.rtol > 0.0 && self.rtol <= 1.0) {
            return Err(bad(format!("rtol must be in (0, 1], got {}", self.rtol)));
        }
        if self.presets.is_empty() {
            return Err(bad("needs an `axis preset = ...` with at least one preset"));
        }
        if self.workloads.is_empty() {
            return Err(bad("needs an `axis workload = ...` with at least one workload"));
        }
        for p in &self.presets {
            crate::topology::preset(p).map_err(|e| e.prefixed("sweep"))?;
        }
        for w in &self.workloads {
            kernels::lookup(w).map(|_| ()).map_err(|e| e.prefixed("sweep"))?;
        }
        for (axis, empty) in [
            ("groups", self.groups.is_empty()),
            ("banking", self.banking.is_empty()),
            ("burst", self.burst.is_empty()),
            ("freq_mhz", self.freq.is_empty()),
        ] {
            if empty {
                return Err(bad(format!("axis {axis} needs at least one value")));
            }
        }
        for f in self.freq.iter().flatten() {
            if !(f.is_finite() && *f > 0.0) {
                return Err(bad(format!("axis freq_mhz values must be positive MHz, got {f}")));
            }
        }
        no_dupes("preset", &self.presets)?;
        no_dupes("groups", &self.groups)?;
        no_dupes("banking", &self.banking)?;
        no_dupes("burst", &self.burst)?;
        no_dupes("freq_mhz", &self.freq)?;
        no_dupes("workload", &self.workloads)?;
        Ok(())
    }

    /// Expand the grid in the fixed nesting order. Config labels carry
    /// the overrides (`terapool9+bf2+burst`) so every point key — and
    /// every emitted `RunReport.config` — is unique within the sweep.
    pub fn points(&self) -> Result<Vec<SweepPoint>> {
        let mut pts = Vec::new();
        for preset in &self.presets {
            let base = crate::topology::preset(preset).map_err(|e| e.prefixed("sweep"))?;
            for &groups in &self.groups {
                for &banking in &self.banking {
                    for &burst in &self.burst {
                        for &freq in &self.freq {
                            let mut cfg = base.clone();
                            let mut label = preset.clone();
                            if let Some(g) = groups {
                                cfg.hierarchy.groups = g;
                                label.push_str(&format!("+g{g}"));
                            }
                            if let Some(bf) = banking {
                                cfg.banking_factor = bf;
                                label.push_str(&format!("+bf{bf}"));
                            }
                            cfg.burst = burst;
                            if burst {
                                label.push_str("+burst");
                            }
                            if let Some(f) = freq {
                                cfg.freq_mhz = f;
                                if f.fract() == 0.0 {
                                    label.push_str(&format!("+f{}", f as u64));
                                } else {
                                    label.push_str(&format!("+f{f}"));
                                }
                            }
                            cfg.name = label.clone();
                            for w in &self.workloads {
                                pts.push(SweepPoint {
                                    index: pts.len(),
                                    key: format!("{label}/{w}/{}", self.scale.tag()),
                                    cfg: cfg.clone(),
                                    workload: w.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
        Ok(pts)
    }

    /// FNV-1a over the debug rendering — the checkpoint guard: a resume
    /// against a different grid is refused instead of silently mixing
    /// incompatible points.
    pub fn fingerprint(&self) -> String {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x100_0000_01b3;
        let mut h = FNV_OFFSET;
        for b in format!("{self:?}").bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        format!("{h:016x}")
    }
}

// ---------------------------------------------------------------------
// Pareto frontier + physical cost proxy
// ---------------------------------------------------------------------

/// Non-domination flags over `(cycles, cost)` pairs, both minimized. A
/// point leaves the frontier only when some other point is no worse on
/// both axes and strictly better on at least one; exact ties stay on
/// the frontier together (deterministic, order-independent).
pub fn pareto_frontier(axes: &[(f64, f64)]) -> Vec<bool> {
    axes.iter()
        .map(|&(c, p)| {
            !axes.iter().any(|&(cj, pj)| cj <= c && pj <= p && (cj < c || pj < p))
        })
        .collect()
}

/// The frontier's cost axis: silicon area in gate equivalents — defined
/// for every config (unlike the energy model, which only characterizes
/// the TeraPool operating points).
pub fn cost_proxy(cfg: &ClusterConfig) -> f64 {
    area::breakdown(cfg).total()
}

/// Estimated run energy where the config matches a characterized
/// operating point (remote-group latency 7/9/11) — provenance only.
fn point_energy(cfg: &ClusterConfig, stats: &crate::cluster::RunStats) -> Option<f64> {
    matches!(cfg.latency.remote_group, 7 | 9 | 11)
        .then(|| energy::EnergyModel::for_cluster(cfg).run_energy_j(stats))
}

// ---------------------------------------------------------------------
// Drift verdict — report_diff.py semantics, in-process
// ---------------------------------------------------------------------

/// Estimated-vs-measured drift verdict for one point, mirroring
/// `tools/report_diff.py`: EXACT fields admit zero drift, TOLERANT
/// fields are held to `|est-meas| <= rtol · max(|est|, |meas|)`.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftVerdict {
    pub pass: bool,
    /// Largest relative drift over the tolerant fields.
    pub worst_rel: f64,
    pub worst_field: String,
    pub failures: Vec<String>,
}

struct DriftAcc {
    rtol: f64,
    worst_rel: f64,
    worst_field: String,
    failures: Vec<String>,
}

impl DriftAcc {
    fn exact_u64(&mut self, field: &str, meas: u64, est: u64) {
        if meas != est {
            self.failures.push(format!("{field}: exact field {meas} vs {est}"));
        }
    }
    fn tol(&mut self, field: &str, meas: f64, est: f64) {
        if meas == est || (meas.is_nan() && est.is_nan()) {
            return;
        }
        let denom = meas.abs().max(est.abs());
        let rel = if denom == 0.0 { 0.0 } else { (est - meas).abs() / denom };
        if rel > self.worst_rel {
            self.worst_rel = rel;
            self.worst_field = field.to_string();
        }
        let ok = (est - meas).abs() <= self.rtol * denom;
        if !ok {
            self.failures.push(format!("{field}: {meas} vs {est} (rel {rel:.4})"));
        }
    }
}

/// Hold an estimated report against its cycle-accurate measurement (the
/// reference side) at `rtol`.
pub fn drift_verdict(est: &RunReport, meas: &RunReport, rtol: f64) -> DriftVerdict {
    let mut a = DriftAcc { rtol, worst_rel: 0.0, worst_field: "-".into(), failures: Vec::new() };
    let (m, e) = (&meas.stats, &est.stats);

    if meas.fingerprint != est.fingerprint {
        a.failures
            .push(format!("fingerprint: {} vs {}", meas.fingerprint, est.fingerprint));
    }
    a.exact_u64("instructions", m.instructions, e.instructions);
    a.exact_u64("flops", m.flops, e.flops);
    a.exact_u64("num_pes", m.num_pes as u64, e.num_pes as u64);
    a.exact_u64("loads", m.loads, e.loads);
    a.exact_u64("stores", m.stores, e.stores);
    a.exact_u64("atomics", m.atomics, e.atomics);
    for c in 0..4 {
        a.exact_u64(&format!("reqs_per_class[{c}]"), m.reqs_per_class[c], e.reqs_per_class[c]);
        a.exact_u64(
            &format!("burst_reqs_per_class[{c}]"),
            m.burst_reqs_per_class[c],
            e.burst_reqs_per_class[c],
        );
        a.exact_u64(
            &format!("burst_words_per_class[{c}]"),
            m.burst_words_per_class[c],
            e.burst_words_per_class[c],
        );
    }

    a.tol("cycles", m.cycles as f64, e.cycles as f64);
    a.tol("stall_raw", m.stall_raw as f64, e.stall_raw as f64);
    a.tol("stall_lsu", m.stall_lsu as f64, e.stall_lsu as f64);
    a.tol("stall_ctrl", m.stall_ctrl as f64, e.stall_ctrl as f64);
    a.tol("stall_synch", m.stall_synch as f64, e.stall_synch as f64);
    a.tol("amat", m.amat, e.amat);
    for c in 0..4 {
        a.tol(&format!("amat_per_class[{c}]"), m.amat_per_class[c], e.amat_per_class[c]);
    }
    a.tol("ipc", m.ipc(), e.ipc());
    a.tol("gflops", m.gflops(), e.gflops());
    match (meas.dma_bytes, est.dma_bytes) {
        (None, None) => {}
        (Some(mb), Some(eb)) => a.tol("dma_bytes", mb as f64, eb as f64),
        (mb, eb) => a.failures.push(format!("dma_bytes: {mb:?} vs {eb:?}")),
    }

    DriftVerdict {
        pass: a.failures.is_empty(),
        worst_rel: a.worst_rel,
        worst_field: a.worst_field,
        failures: a.failures,
    }
}

// ---------------------------------------------------------------------
// SweepReport — the combined document (and its checkpoint form)
// ---------------------------------------------------------------------

/// A typed per-point failure (the isolation record, never fatal).
#[derive(Debug, Clone, PartialEq)]
pub struct PointError {
    /// Stable kind tag (`unknown-workload`, `max-cycles-exceeded`, ...).
    pub kind: String,
    pub message: String,
}

impl PointError {
    fn of(e: &Error) -> Self {
        let kind = match e.kind() {
            ErrorKind::Generic => "generic",
            ErrorKind::UnknownWorkload => "unknown-workload",
            ErrorKind::MaxCyclesExceeded => "max-cycles-exceeded",
            ErrorKind::BadTopology => "bad-topology",
            ErrorKind::Unsupported => "unsupported",
        };
        PointError { kind: kind.into(), message: e.to_string() }
    }
}

/// One grid point's full provenance: estimate, failure record, frontier
/// membership, measurement and drift verdict. `estimated`/`measured`
/// embed complete [`RunReport`]s (`EstimateInfo` included), so the
/// document is self-contained for downstream tooling.
#[derive(Debug, Clone, PartialEq)]
pub struct PointRecord {
    pub index: usize,
    pub key: String,
    pub config: String,
    pub workload: String,
    /// Area proxy (GE) — the frontier's cost axis.
    pub cost_proxy: f64,
    pub frontier: bool,
    /// Estimated run energy (J), where characterized.
    pub energy_j: Option<f64>,
    pub estimated: Option<RunReport>,
    pub measured: Option<RunReport>,
    pub error: Option<PointError>,
    pub drift: Option<DriftVerdict>,
}

/// The combined sweep document. The on-disk checkpoint is the same
/// schema written mid-flight; [`run_sweep`] recomputes every derived
/// field (frontier, energy, drift) from the embedded reports, so a
/// resumed sweep renders byte-identically to an uninterrupted one.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepReport {
    pub name: String,
    pub spec_fingerprint: String,
    /// `Scale::tag()` of every point.
    pub scale: String,
    pub rtol: f64,
    pub points: Vec<PointRecord>,
}

fn opt_num(v: Option<f64>) -> Json {
    v.map(Json::Num).unwrap_or(Json::Null)
}

/// `None` for absent *or* null fields (the writer emits explicit nulls).
fn opt_field(j: &Json, key: &str) -> Option<Json> {
    match j.get(key) {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.clone()),
    }
}

impl PointRecord {
    fn to_json(&self) -> Json {
        let rep = |o: &Option<RunReport>| o.as_ref().map(|r| r.to_json()).unwrap_or(Json::Null);
        Json::Obj(vec![
            ("index".into(), Json::Num(self.index as f64)),
            ("key".into(), Json::Str(self.key.clone())),
            ("config".into(), Json::Str(self.config.clone())),
            ("workload".into(), Json::Str(self.workload.clone())),
            ("cost_proxy_ge".into(), Json::Num(self.cost_proxy)),
            ("frontier".into(), Json::Bool(self.frontier)),
            ("energy_j".into(), opt_num(self.energy_j)),
            (
                "error".into(),
                match &self.error {
                    None => Json::Null,
                    Some(e) => Json::Obj(vec![
                        ("kind".into(), Json::Str(e.kind.clone())),
                        ("message".into(), Json::Str(e.message.clone())),
                    ]),
                },
            ),
            (
                "drift".into(),
                match &self.drift {
                    None => Json::Null,
                    Some(d) => Json::Obj(vec![
                        ("pass".into(), Json::Bool(d.pass)),
                        ("worst_rel".into(), Json::Num(d.worst_rel)),
                        ("worst_field".into(), Json::Str(d.worst_field.clone())),
                        (
                            "failures".into(),
                            Json::Arr(d.failures.iter().map(|f| Json::Str(f.clone())).collect()),
                        ),
                    ]),
                },
            ),
            ("estimated".into(), rep(&self.estimated)),
            ("measured".into(), rep(&self.measured)),
        ])
    }

    fn from_json(j: &Json) -> Result<PointRecord> {
        let rep = |key: &str| -> Result<Option<RunReport>> {
            opt_field(j, key).map(|v| RunReport::from_json(&v)).transpose()
        };
        let error = match opt_field(j, "error") {
            None => None,
            Some(e) => Some(PointError { kind: e.field_str("kind")?, message: e.field_str("message")? }),
        };
        let drift = match opt_field(j, "drift") {
            None => None,
            Some(d) => Some(DriftVerdict {
                pass: matches!(d.get("pass"), Some(Json::Bool(true))),
                worst_rel: d.field_f64("worst_rel")?,
                worst_field: d.field_str("worst_field")?,
                failures: d
                    .get("failures")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|f| f.as_str().map(str::to_string))
                    .collect(),
            }),
        };
        Ok(PointRecord {
            index: j.field_u64("index")? as usize,
            key: j.field_str("key")?,
            config: j.field_str("config")?,
            workload: j.field_str("workload")?,
            cost_proxy: j.field_f64("cost_proxy_ge")?,
            frontier: matches!(j.get("frontier"), Some(Json::Bool(true))),
            energy_j: opt_field(j, "energy_j").and_then(|v| v.as_f64()),
            estimated: rep("estimated")?,
            measured: rep("measured")?,
            error,
            drift,
        })
    }
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        let explored = self.points.iter().filter(|p| p.estimated.is_some()).count();
        let failed = self.points.iter().filter(|p| p.error.is_some()).count();
        let frontier = self.points.iter().filter(|p| p.frontier).count();
        Json::Obj(vec![
            ("schema".into(), Json::Str(SCHEMA.into())),
            ("name".into(), Json::Str(self.name.clone())),
            ("spec_fingerprint".into(), Json::Str(self.spec_fingerprint.clone())),
            ("scale".into(), Json::Str(self.scale.clone())),
            ("rtol".into(), Json::Num(self.rtol)),
            ("total_points".into(), Json::Num(self.points.len() as f64)),
            ("explored".into(), Json::Num(explored as f64)),
            ("failed".into(), Json::Num(failed as f64)),
            ("frontier_size".into(), Json::Num(frontier as f64)),
            ("points".into(), Json::Arr(self.points.iter().map(PointRecord::to_json).collect())),
        ])
    }

    /// Deterministic document rendering (the `--json` artifact and the
    /// checkpoint bytes).
    pub fn render(&self) -> String {
        let mut s = self.to_json().render();
        s.push('\n');
        s
    }

    pub fn from_json(j: &Json) -> Result<SweepReport> {
        let schema = j.field_str("schema")?;
        ensure!(schema == SCHEMA, "sweep: unsupported document schema {schema:?} (want {SCHEMA})");
        Ok(SweepReport {
            name: j.field_str("name")?,
            spec_fingerprint: j.field_str("spec_fingerprint")?,
            scale: j.field_str("scale")?,
            rtol: j.field_f64("rtol")?,
            points: j
                .get("points")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(PointRecord::from_json)
                .collect::<Result<_>>()?,
        })
    }

    pub fn parse(text: &str) -> Result<SweepReport> {
        Self::from_json(&Json::parse(text)?)
    }

    /// Frontier points whose drift verdict failed the rtol bound.
    pub fn frontier_drift_failures(&self) -> usize {
        self.points
            .iter()
            .filter(|p| p.frontier && p.drift.as_ref().is_some_and(|d| !d.pass))
            .count()
    }

    /// Human summary, one row per point.
    pub fn table(&self) -> Table {
        use crate::report::{f2, int};
        let mut t = Table::new(
            &format!("Sweep {} — {} points, scale {}", self.name, self.points.len(), self.scale),
            &["#", "Config", "Workload", "Est cycles", "Area MGE", "Frontier", "Meas cycles", "Drift"],
        );
        for p in &self.points {
            let est = match (&p.estimated, &p.error) {
                (Some(r), _) => int(r.stats.cycles),
                (None, Some(e)) => format!("FAILED ({})", e.kind),
                (None, None) => "-".into(),
            };
            let meas = p.measured.as_ref().map(|r| int(r.stats.cycles)).unwrap_or_else(|| "-".into());
            let drift = match &p.drift {
                Some(d) if d.pass => format!("ok (worst {:.4})", d.worst_rel),
                Some(d) => format!("FAIL ({})", d.worst_field),
                None => "-".into(),
            };
            t.row(vec![
                int(p.index as u64),
                p.config.clone(),
                p.workload.clone(),
                est,
                f2(p.cost_proxy / 1e6),
                if p.frontier { "*".into() } else { "".into() },
                meas,
                drift,
            ]);
        }
        t
    }
}

// ---------------------------------------------------------------------
// run_sweep — the three-phase service loop
// ---------------------------------------------------------------------

/// Recompute every derived field from the embedded reports: frontier
/// membership over the current estimates, provenance energy, drift
/// verdicts. Pure — calling it again on the same records is a no-op,
/// which is what makes checkpoints and final documents agree.
fn finalize(spec: &SweepSpec, points: &[SweepPoint], records: &mut [PointRecord]) {
    let est: Vec<(usize, f64, f64)> = records
        .iter()
        .enumerate()
        .filter_map(|(i, r)| {
            r.estimated.as_ref().map(|e| (i, e.stats.cycles as f64, r.cost_proxy))
        })
        .collect();
    let axes: Vec<(f64, f64)> = est.iter().map(|&(_, c, p)| (c, p)).collect();
    let on = pareto_frontier(&axes);
    for r in records.iter_mut() {
        r.frontier = false;
    }
    for (k, &(i, _, _)) in est.iter().enumerate() {
        records[i].frontier = on[k];
    }
    for (i, r) in records.iter_mut().enumerate() {
        r.energy_j = r.estimated.as_ref().and_then(|e| point_energy(&points[i].cfg, &e.stats));
        r.drift = match (&r.estimated, &r.measured) {
            (Some(e), Some(m)) => Some(drift_verdict(e, m, spec.rtol)),
            _ => None,
        };
    }
}

fn snapshot(spec: &SweepSpec, records: &[PointRecord]) -> SweepReport {
    SweepReport {
        name: spec.name.clone(),
        spec_fingerprint: spec.fingerprint(),
        scale: spec.scale.tag().into(),
        rtol: spec.rtol,
        points: records.to_vec(),
    }
}

/// Run the sweep service over `spec`.
///
/// * `threads` — host-thread budget; points fan out through
///   `Session::run_batch` in chunks of this size, with a checkpoint
///   after every chunk.
/// * `prior` — a parsed checkpoint (or finished report) to resume from:
///   completed points are reused verbatim, keyed by point identity and
///   guarded by the spec fingerprint.
/// * `on_checkpoint` — invoked with the partial document after every
///   batch; the CLI writes it to the `--resume` path. Checkpoint I/O
///   errors abort the sweep (a serving layer must not pretend to be
///   resumable when it is not).
pub fn run_sweep(
    spec: &SweepSpec,
    threads: usize,
    prior: Option<&SweepReport>,
    mut on_checkpoint: impl FnMut(&SweepReport) -> Result<()>,
) -> Result<SweepReport> {
    let fingerprint = spec.fingerprint();
    if let Some(p) = prior {
        ensure!(
            p.spec_fingerprint == fingerprint,
            "sweep: checkpoint belongs to a different spec (fingerprint {} vs {fingerprint})",
            p.spec_fingerprint
        );
    }
    let points = spec.points()?;
    ensure!(!points.is_empty(), "sweep: the grid is empty");

    // Skeleton records, then seed completed work from the prior
    // document — estimates, failures and measurements are reused
    // verbatim; everything derived is recomputed by `finalize`.
    let mut records: Vec<PointRecord> = points
        .iter()
        .map(|p| PointRecord {
            index: p.index,
            key: p.key.clone(),
            config: p.cfg.name.clone(),
            workload: p.workload.clone(),
            cost_proxy: cost_proxy(&p.cfg),
            frontier: false,
            energy_j: None,
            estimated: None,
            measured: None,
            error: None,
            drift: None,
        })
        .collect();
    if let Some(p) = prior {
        let by_key: HashMap<&str, &PointRecord> =
            p.points.iter().map(|r| (r.key.as_str(), r)).collect();
        for r in &mut records {
            if let Some(old) = by_key.get(r.key.as_str()) {
                r.estimated = old.estimated.clone();
                r.measured = old.measured.clone();
                r.error = old.error.clone();
            }
        }
    }

    let threads = threads.max(1);
    let base_cfg = points[0].cfg.clone();

    // ---- phase 1: explore every pending point with the estimator ----
    let est_session =
        Session::new(base_cfg.clone()).scale(spec.scale).threads(threads).estimating(true);
    let pending: Vec<usize> = records
        .iter()
        .enumerate()
        .filter(|(_, r)| r.estimated.is_none() && r.error.is_none())
        .map(|(i, _)| i)
        .collect();
    for chunk in pending.chunks(threads) {
        let mut idxs = Vec::with_capacity(chunk.len());
        let mut jobs = Vec::with_capacity(chunk.len());
        for &i in chunk {
            // A point that cannot even resolve its workload is recorded
            // and skipped — failure isolation starts at job build.
            match kernels::lookup(&records[i].workload) {
                Err(e) => records[i].error = Some(PointError::of(&e)),
                Ok(w) => {
                    idxs.push(i);
                    jobs.push(Job::new(points[i].cfg.clone(), w));
                }
            }
        }
        for (&i, res) in idxs.iter().zip(est_session.run_batch(&jobs)) {
            match res {
                Ok(rep) => records[i].estimated = Some(rep),
                Err(e) => records[i].error = Some(PointError::of(&e)),
            }
        }
        est_session.take_reports(); // the records own the reports
        finalize(spec, &points, &mut records);
        on_checkpoint(&snapshot(spec, &records))?;
    }
    finalize(spec, &points, &mut records);

    // ---- phase 2: re-run only the Pareto frontier cycle-accurately --
    let meas_session = Session::new(base_cfg).scale(spec.scale).threads(threads);
    let pending: Vec<usize> = records
        .iter()
        .enumerate()
        .filter(|(_, r)| r.frontier && r.measured.is_none() && r.error.is_none())
        .map(|(i, _)| i)
        .collect();
    for chunk in pending.chunks(threads) {
        let mut idxs = Vec::with_capacity(chunk.len());
        let mut jobs = Vec::with_capacity(chunk.len());
        for &i in chunk {
            match kernels::lookup(&records[i].workload) {
                Err(e) => records[i].error = Some(PointError::of(&e)),
                Ok(w) => {
                    idxs.push(i);
                    jobs.push(Job::new(points[i].cfg.clone(), w));
                }
            }
        }
        for (&i, res) in idxs.iter().zip(meas_session.run_batch(&jobs)) {
            match res {
                Ok(rep) => records[i].measured = Some(rep),
                // A frontier point failing its cycle-accurate re-run
                // (e.g. MaxCyclesExceeded at full scale) is recorded,
                // not fatal; it keeps its estimate and frontier flag.
                Err(e) => records[i].error = Some(PointError::of(&e)),
            }
        }
        meas_session.take_reports();
        finalize(spec, &points, &mut records);
        on_checkpoint(&snapshot(spec, &records))?;
    }

    // ---- phase 3: verify (drift verdicts land in finalize) ----------
    finalize(spec, &points, &mut records);
    Ok(snapshot(spec, &records))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    const EXAMPLE: &str = include_str!("../../examples/terapool.sweep");

    fn tiny_spec(workloads: &[&str]) -> SweepSpec {
        SweepSpec {
            name: "t".into(),
            scale: Scale::Fast,
            rtol: DEFAULT_RTOL,
            presets: vec!["tiny".into()],
            groups: vec![None],
            banking: vec![None],
            burst: vec![false],
            freq: vec![None],
            workloads: workloads.iter().map(|w| w.to_string()).collect(),
        }
    }

    #[test]
    fn example_spec_parses_and_expands() {
        let spec = SweepSpec::parse(EXAMPLE, "terapool").unwrap();
        let pts = spec.points().unwrap();
        assert!(pts.len() >= 24, "example grid must explore >= 24 points, got {}", pts.len());
        // Point keys are the checkpoint identity: all unique, in fixed
        // expansion order.
        let mut keys: Vec<&str> = pts.iter().map(|p| p.key.as_str()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), pts.len(), "point keys must be unique");
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(p.index, i);
        }
        assert_eq!(spec.fingerprint().len(), 16);
    }

    #[test]
    fn freq_axis_expands_and_labels_points() {
        let text = "axis preset = tiny\naxis freq_mhz = default, 600, 612.5\naxis workload = axpy\n";
        let spec = SweepSpec::parse(text, "f").unwrap();
        let pts = spec.points().unwrap();
        assert_eq!(pts.len(), 3);
        // `default` leaves the preset frequency and label untouched;
        // integral overrides render without a trailing ".0".
        assert!(pts[0].key.starts_with("tiny/"), "{}", pts[0].key);
        assert!(pts[1].key.starts_with("tiny+f600/"), "{}", pts[1].key);
        assert!(pts[2].key.starts_with("tiny+f612.5/"), "{}", pts[2].key);
        assert_eq!(pts[0].cfg.freq_mhz, crate::topology::preset("tiny").unwrap().freq_mhz);
        assert_eq!(pts[1].cfg.freq_mhz, 600.0);
    }

    #[test]
    fn malformed_specs_are_rejected_with_typed_errors() {
        let ok_tail = "axis preset = tiny\naxis workload = axpy\n";
        let cases: &[(&str, &str)] = &[
            ("axis preset = nope\naxis workload = axpy\n", "unknown cluster preset"),
            ("rtol = 5.0\naxis preset = tiny\naxis workload = axpy\n", "rtol must be in"),
            ("rtol = zero\naxis preset = tiny\naxis workload = axpy\n", "rtol wants a number"),
            ("scale = medium\naxis preset = tiny\naxis workload = axpy\n", "scale must be"),
            ("axis banking = 0\naxis preset = tiny\naxis workload = axpy\n", "banking wants"),
            ("axis burst = maybe\naxis preset = tiny\naxis workload = axpy\n", "expected a boolean"),
            ("axis flavor = a\naxis preset = tiny\naxis workload = axpy\n", "unknown axis"),
            ("frobnicate = 1\naxis preset = tiny\naxis workload = axpy\n", "unknown directive"),
            ("axis preset = tiny\naxis preset = tiny\naxis workload = axpy\n", "declared twice"),
            ("axis preset = tiny, tiny\naxis workload = axpy\n", "repeats value"),
            ("axis freq_mhz = 600, 600\naxis preset = tiny\naxis workload = axpy\n", "repeats value"),
            ("axis freq_mhz = 0\naxis preset = tiny\naxis workload = axpy\n", "positive MHz"),
            ("axis freq_mhz = fast\naxis preset = tiny\naxis workload = axpy\n", "positive MHz"),
            ("axis freq_mhz = -1\naxis preset = tiny\naxis workload = axpy\n", "positive MHz"),
            ("axis preset =\naxis workload = axpy\n", "at least one value"),
            ("axis workload = axpy\n", "axis preset"),
            ("axis preset = tiny\n", "axis workload"),
            ("just some words\n", "expected key=value"),
        ];
        for (text, needle) in cases {
            let e = SweepSpec::parse(text, "bad").unwrap_err();
            assert_eq!(e.kind(), ErrorKind::BadTopology, "{text:?}: {e}");
            assert!(e.to_string().contains(needle), "{text:?}: {e} (wanted {needle:?})");
        }
        // Unknown workloads keep the registry's typed error class.
        let e = SweepSpec::parse(&format!("{ok_tail}axis groups = 1\n"), "ok")
            .map(|mut s| {
                s.workloads = vec!["bogus".into()];
                s.validate().unwrap_err()
            })
            .unwrap();
        assert_eq!(e.kind(), ErrorKind::UnknownWorkload);
    }

    #[test]
    fn pareto_frontier_dominance_and_ties() {
        // (cycles, cost): a dominates b; c trades off; d ties with a.
        let axes = [(10.0, 5.0), (12.0, 6.0), (8.0, 9.0), (10.0, 5.0)];
        assert_eq!(pareto_frontier(&axes), vec![true, false, true, true]);
        assert_eq!(pareto_frontier(&[]), Vec::<bool>::new());
    }

    #[test]
    fn drift_verdict_mirrors_report_diff_semantics() {
        let cfg = ClusterConfig::tiny();
        let s = Session::new(cfg).scale(Scale::Fast);
        let r = s.run(&*kernels::lookup("axpy").unwrap()).unwrap();

        let v = drift_verdict(&r, &r, DEFAULT_RTOL);
        assert!(v.pass, "{:?}", v.failures);
        assert_eq!(v.worst_rel, 0.0);

        // Tolerant field within the bound: passes, drift recorded.
        let mut near = r.clone();
        near.stats.cycles = r.stats.cycles + r.stats.cycles / 20; // +5%
        let v = drift_verdict(&near, &r, DEFAULT_RTOL);
        assert!(v.pass, "{:?}", v.failures);
        // ipc/gflops are derived from cycles, so their relative drift
        // ties with the cycles field to within an ulp — any of the
        // three may win the worst-field slot.
        assert!(v.worst_rel > 0.0);
        assert!(["cycles", "ipc", "gflops"].contains(&v.worst_field.as_str()), "{}", v.worst_field);

        // Tolerant field beyond the bound: fails.
        let mut far = r.clone();
        far.stats.cycles = r.stats.cycles * 2;
        assert!(!drift_verdict(&far, &r, DEFAULT_RTOL).pass);

        // Exact fields admit zero drift regardless of rtol.
        let mut off = r.clone();
        off.stats.instructions += 1;
        let v = drift_verdict(&off, &r, 1.0);
        assert!(!v.pass && v.failures.iter().any(|f| f.contains("instructions")));
    }

    #[test]
    fn report_json_roundtrips_byte_identically() {
        let spec = tiny_spec(&["axpy", "dotp"]);
        let rep = run_sweep(&spec, 1, None, |_| Ok(())).unwrap();
        let text = rep.render();
        let back = SweepReport::parse(&text).unwrap();
        assert_eq!(back.render(), text, "render → parse → render must be the identity");
        assert_eq!(back.spec_fingerprint, rep.spec_fingerprint);
        assert_eq!(back.points.len(), rep.points.len());
    }

    #[test]
    fn frontier_points_are_measured_and_pass_drift_at_calibration_scale() {
        let spec = tiny_spec(&["axpy", "dotp"]);
        let rep = run_sweep(&spec, 2, None, |_| Ok(())).unwrap();
        assert_eq!(rep.points.len(), 2);
        let frontier: Vec<_> = rep.points.iter().filter(|p| p.frontier).collect();
        assert!(!frontier.is_empty(), "some point must be non-dominated");
        for p in &rep.points {
            assert!(p.estimated.is_some(), "{}: estimate missing", p.key);
            assert_eq!(p.measured.is_some(), p.frontier, "{}: only frontier points re-run", p.key);
        }
        // At the calibration scale the estimate is exact by
        // construction — drift verdicts must pass with zero drift.
        for p in frontier {
            let d = p.drift.as_ref().expect("frontier points carry a drift verdict");
            assert!(d.pass, "{}: {:?}", p.key, d.failures);
            let e = p.estimated.as_ref().unwrap();
            assert!(e.estimate.is_some(), "estimated reports carry EstimateInfo");
        }
        assert_eq!(rep.frontier_drift_failures(), 0);
    }

    #[test]
    fn checkpoint_from_other_spec_is_refused() {
        let spec = tiny_spec(&["axpy"]);
        let rep = run_sweep(&spec, 1, None, |_| Ok(())).unwrap();
        let other = tiny_spec(&["dotp"]);
        let e = run_sweep(&other, 1, Some(&rep), |_| Ok(())).unwrap_err();
        assert!(e.to_string().contains("different spec"), "{e}");
    }
}
