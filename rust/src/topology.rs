//! Declarative system topology: N TeraPool clusters, point-to-point or
//! 2-D-mesh inter-cluster links, and one off-chip main-memory node
//! fronting the shared HBM bus (the scale-out system of ROADMAP item 1,
//! in the style of the MemPool scale-out analysis and the Stream
//! `tpu_like_quad_core` topology configs).
//!
//! The text format is line-oriented (`#` starts a comment):
//!
//! ```text
//! system quad                      # optional document name
//! cluster c0 preset=terapool9 groups=1
//! cluster c1 preset=terapool9 groups=1
//! cluster c2 preset=terapool9 groups=1
//! cluster c3 preset=terapool9 groups=1
//! mesh 2x2 latency=32 width=8      # OR explicit `link A B ...` lines
//! memory hbm latency=64 width=16   # the off-chip node (optional line)
//! ```
//!
//! `link A B [latency=CYCLES] [width=WORDS]` declares one bidirectional
//! point-to-point link; `mesh CxR` generates the row-major 2-D grid over
//! the declared clusters instead. The two are mutually exclusive: once a
//! mesh is declared, extra `link` lines would add chords — cycles beyond
//! the grid — and the file is rejected rather than silently reshaped.
//! Every validation failure is a typed [`ErrorKind::BadTopology`]
//! (`errors::ErrorKind`), so callers and the rejection-table tests match
//! the class, not the message.
//!
//! A `Topology` is purely declarative: the stepping/traffic semantics
//! live in [`crate::system`].

use crate::config::{ClusterConfig, Hierarchy};
use crate::errors::{Error, Result};

/// Default inter-cluster link latency (cycles per hop): a die-to-die /
/// chiplet-crossing pipeline, an order of magnitude above the in-cluster
/// remote-Group latency.
pub const DEFAULT_LINK_LATENCY: u64 = 32;
/// Default inter-cluster link width (32-bit words per cycle per link).
pub const DEFAULT_LINK_WIDTH: usize = 8;
/// Default main-memory (shared HBM bus) access latency in cycles.
pub const DEFAULT_MEM_LATENCY: u64 = 64;
/// Default main-memory bus width (words per cycle, shared by all
/// clusters — the arbitration target).
pub const DEFAULT_MEM_WIDTH: usize = 16;

/// One named cluster instance of the system.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub cfg: ClusterConfig,
}

/// One bidirectional inter-cluster link (endpoints are cluster indices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkSpec {
    pub a: usize,
    pub b: usize,
    /// Pipeline latency per traversal (cycles).
    pub latency: u64,
    /// Transfer width (words per cycle).
    pub width: usize,
}

/// The single off-chip main-memory node fronting the shared HBM bus.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemorySpec {
    pub name: String,
    /// Access latency charged once per transfer (cycles).
    pub latency: u64,
    /// Bus width (words per cycle), shared by all clusters.
    pub width: usize,
}

impl Default for MemorySpec {
    fn default() -> Self {
        MemorySpec {
            name: "mem".to_string(),
            latency: DEFAULT_MEM_LATENCY,
            width: DEFAULT_MEM_WIDTH,
        }
    }
}

/// A validated system topology. Construction (parse / [`Topology::split`])
/// always runs the full validation pass, so holding a `Topology` implies
/// the invariants: non-empty unique cluster set, links between declared
/// distinct clusters, no duplicate links, mesh exactly covering the
/// cluster set, every cluster reachable from cluster 0.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub clusters: Vec<ClusterSpec>,
    pub links: Vec<LinkSpec>,
    /// `Some((cols, rows))` when the link set is a generated 2-D mesh.
    pub mesh: Option<(usize, usize)>,
    pub memory: MemorySpec,
}

fn bad(msg: impl Into<String>) -> Error {
    Error::bad_topology(msg)
}

/// Resolve a `preset=` value to a base cluster config. Shared with the
/// sweep-spec parser (`crate::sweep`), which sweeps the same preset
/// namespace.
pub(crate) fn preset(name: &str) -> Result<ClusterConfig> {
    Ok(match name {
        "tiny" => ClusterConfig::tiny(),
        "mempool" => ClusterConfig::mempool(),
        "occamy" => ClusterConfig::occamy(),
        "terapool" | "terapool9" => ClusterConfig::terapool(9),
        "terapool7" => ClusterConfig::terapool(7),
        "terapool11" => ClusterConfig::terapool(11),
        other => {
            return Err(bad(format!(
                "unknown cluster preset {other:?} \
                 (known: tiny, mempool, occamy, terapool7, terapool9, terapool11)"
            )))
        }
    })
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "1" | "true" | "on" => Ok(true),
        "0" | "false" | "off" => Ok(false),
        _ => Err(bad(format!("expected a boolean, got {v:?}"))),
    }
}

/// Split a `key=value` token.
fn keyval(tok: &str) -> Result<(&str, &str)> {
    tok.split_once('=')
        .ok_or_else(|| bad(format!("expected key=value, got {tok:?}")))
}

impl Topology {
    /// Parse the text format. `name` is the fallback document name when
    /// no `system` line is present (the CLI passes the file stem).
    pub fn parse(text: &str, name: &str) -> Result<Topology> {
        let mut doc_name: Option<String> = None;
        let mut clusters: Vec<ClusterSpec> = Vec::new();
        let mut raw_links: Vec<(String, String, u64, usize)> = Vec::new();
        let mut mesh: Option<(usize, usize, u64, usize)> = None;
        let mut memory: Option<MemorySpec> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let at = |e: Error| e.prefixed(&format!("line {}", lineno + 1));
            let mut toks = line.split_whitespace();
            match toks.next().unwrap() {
                "system" => {
                    let n = toks.next().ok_or_else(|| at(bad("system needs a name")))?;
                    doc_name = Some(n.to_string());
                }
                "cluster" => {
                    let cname = toks
                        .next()
                        .ok_or_else(|| at(bad("cluster needs a name")))?
                        .to_string();
                    let mut cfg: Option<ClusterConfig> = None;
                    let mut groups: Option<usize> = None;
                    let mut burst: Option<bool> = None;
                    for tok in toks {
                        let (k, v) = keyval(tok).map_err(at)?;
                        match k {
                            "preset" => cfg = Some(preset(v).map_err(at)?),
                            "groups" => {
                                groups =
                                    Some(v.parse().map_err(|_| {
                                        at(bad(format!("bad groups value {v:?}")))
                                    })?)
                            }
                            "burst" => burst = Some(parse_bool(v).map_err(at)?),
                            _ => return Err(at(bad(format!("unknown cluster option {k:?}")))),
                        }
                    }
                    let mut cfg = cfg
                        .ok_or_else(|| at(bad(format!("cluster {cname:?} needs preset=..."))))?;
                    if let Some(g) = groups {
                        if g == 0 {
                            return Err(at(bad("groups must be >= 1")));
                        }
                        cfg.hierarchy.groups = g;
                        cfg.name = format!("{}-g{}", cfg.name, g);
                    }
                    if let Some(b) = burst {
                        cfg.burst = b;
                    }
                    clusters.push(ClusterSpec { name: cname, cfg });
                }
                "link" => {
                    let a = toks
                        .next()
                        .ok_or_else(|| at(bad("link needs two endpoints")))?;
                    let b = toks
                        .next()
                        .ok_or_else(|| at(bad("link needs two endpoints")))?;
                    let (mut lat, mut width) = (DEFAULT_LINK_LATENCY, DEFAULT_LINK_WIDTH);
                    for tok in toks {
                        let (k, v) = keyval(tok).map_err(at)?;
                        match k {
                            "latency" => {
                                lat = v.parse().map_err(|_| {
                                    at(bad(format!("bad latency value {v:?}")))
                                })?
                            }
                            "width" => {
                                width = v.parse().map_err(|_| {
                                    at(bad(format!("bad width value {v:?}")))
                                })?
                            }
                            _ => return Err(at(bad(format!("unknown link option {k:?}")))),
                        }
                    }
                    raw_links.push((a.to_string(), b.to_string(), lat, width));
                }
                "mesh" => {
                    if mesh.is_some() {
                        return Err(at(bad("duplicate mesh declaration")));
                    }
                    let dims = toks.next().ok_or_else(|| at(bad("mesh needs CxR dims")))?;
                    let (c, r) = dims
                        .split_once('x')
                        .ok_or_else(|| at(bad(format!("mesh dims must be CxR, got {dims:?}"))))?;
                    let cols: usize = c
                        .parse()
                        .map_err(|_| at(bad(format!("bad mesh dims {dims:?}"))))?;
                    let rows: usize = r
                        .parse()
                        .map_err(|_| at(bad(format!("bad mesh dims {dims:?}"))))?;
                    let (mut lat, mut width) = (DEFAULT_LINK_LATENCY, DEFAULT_LINK_WIDTH);
                    for tok in toks {
                        let (k, v) = keyval(tok).map_err(at)?;
                        match k {
                            "latency" => {
                                lat = v.parse().map_err(|_| {
                                    at(bad(format!("bad latency value {v:?}")))
                                })?
                            }
                            "width" => {
                                width = v.parse().map_err(|_| {
                                    at(bad(format!("bad width value {v:?}")))
                                })?
                            }
                            _ => return Err(at(bad(format!("unknown mesh option {k:?}")))),
                        }
                    }
                    mesh = Some((cols, rows, lat, width));
                }
                "memory" => {
                    if memory.is_some() {
                        return Err(at(bad("duplicate memory node (exactly one is allowed)")));
                    }
                    let mname = toks
                        .next()
                        .ok_or_else(|| at(bad("memory needs a name")))?
                        .to_string();
                    let mut spec = MemorySpec {
                        name: mname,
                        ..MemorySpec::default()
                    };
                    for tok in toks {
                        let (k, v) = keyval(tok).map_err(at)?;
                        match k {
                            "latency" => {
                                spec.latency = v.parse().map_err(|_| {
                                    at(bad(format!("bad latency value {v:?}")))
                                })?
                            }
                            "width" => {
                                spec.width = v.parse().map_err(|_| {
                                    at(bad(format!("bad width value {v:?}")))
                                })?
                            }
                            _ => return Err(at(bad(format!("unknown memory option {k:?}")))),
                        }
                    }
                    memory = Some(spec);
                }
                other => return Err(at(bad(format!("unknown directive {other:?}")))),
            }
        }

        // Resolve link endpoints by cluster name.
        let index_of = |n: &str| -> Result<usize> {
            clusters
                .iter()
                .position(|c| c.name == n)
                .ok_or_else(|| bad(format!("link endpoint {n:?} names no declared cluster")))
        };
        let mut links: Vec<LinkSpec> = Vec::new();
        for (a, b, latency, width) in &raw_links {
            links.push(LinkSpec {
                a: index_of(a)?,
                b: index_of(b)?,
                latency: *latency,
                width: *width,
            });
        }
        let mut mesh_dims = None;
        if let Some((cols, rows, lat, width)) = mesh {
            if !raw_links.is_empty() {
                return Err(bad(
                    "mesh and explicit link lines are mutually exclusive: extra links \
                     would add chords (cycles) to the declared grid",
                ));
            }
            if cols * rows != clusters.len() {
                return Err(bad(format!(
                    "mesh {cols}x{rows} covers {} nodes but {} clusters are declared",
                    cols * rows,
                    clusters.len()
                )));
            }
            if cols == 0 || rows == 0 {
                return Err(bad("mesh dims must be >= 1"));
            }
            // Row-major grid links, ascending: right neighbor then down
            // neighbor of each node.
            for r in 0..rows {
                for c in 0..cols {
                    let id = r * cols + c;
                    if c + 1 < cols {
                        links.push(LinkSpec { a: id, b: id + 1, latency: lat, width });
                    }
                    if r + 1 < rows {
                        links.push(LinkSpec { a: id, b: id + cols, latency: lat, width });
                    }
                }
            }
            mesh_dims = Some((cols, rows));
        }

        let topo = Topology {
            name: doc_name.unwrap_or_else(|| name.to_string()),
            clusters,
            links,
            mesh: mesh_dims,
            memory: memory.unwrap_or_default(),
        };
        topo.validate()?;
        Ok(topo)
    }

    /// Load and parse a topology file; the file stem is the fallback
    /// document name.
    pub fn load(path: &std::path::Path) -> Result<Topology> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| bad(format!("cannot read {}: {e}", path.display())))?;
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("system");
        Self::parse(&text, stem).map_err(|e| e.prefixed(&path.display().to_string()))
    }

    /// Programmatic constructor for the scale-up-vs-scale-out experiment:
    /// split `base` into `parts` equal clusters at the same total PE
    /// count, wiring them point-to-point (2), as a 2-D mesh (perfect
    /// squares), or as a ring (otherwise). `parts` must divide the
    /// hierarchy along the Group → SubGroup → Tile levels.
    pub fn split(base: &ClusterConfig, parts: usize) -> Result<Topology> {
        if parts == 0 {
            return Err(bad("cannot split a cluster into 0 parts"));
        }
        let h = split_hierarchy(base.hierarchy, parts).ok_or_else(|| {
            bad(format!(
                "cannot split {} ({} PEs) into {parts} equal clusters along its hierarchy",
                base.name,
                base.num_pes()
            ))
        })?;
        let mut cfg = base.clone();
        cfg.hierarchy = h;
        if parts > 1 {
            cfg.name = format!("{}/{}way", base.name, parts);
        }
        let clusters: Vec<ClusterSpec> = (0..parts)
            .map(|i| ClusterSpec { name: format!("c{i}"), cfg: cfg.clone() })
            .collect();
        let mut links = Vec::new();
        let mut mesh = None;
        let side = (1..=parts).find(|s| s * s == parts);
        if parts == 2 {
            links.push(LinkSpec {
                a: 0,
                b: 1,
                latency: DEFAULT_LINK_LATENCY,
                width: DEFAULT_LINK_WIDTH,
            });
        } else if let Some(s) = side.filter(|_| parts > 1) {
            for r in 0..s {
                for c in 0..s {
                    let id = r * s + c;
                    if c + 1 < s {
                        links.push(LinkSpec {
                            a: id,
                            b: id + 1,
                            latency: DEFAULT_LINK_LATENCY,
                            width: DEFAULT_LINK_WIDTH,
                        });
                    }
                    if r + 1 < s {
                        links.push(LinkSpec {
                            a: id,
                            b: id + s,
                            latency: DEFAULT_LINK_LATENCY,
                            width: DEFAULT_LINK_WIDTH,
                        });
                    }
                }
            }
            mesh = Some((s, s));
        } else if parts > 2 {
            for i in 0..parts {
                links.push(LinkSpec {
                    a: i,
                    b: (i + 1) % parts,
                    latency: DEFAULT_LINK_LATENCY,
                    width: DEFAULT_LINK_WIDTH,
                });
            }
        }
        let topo = Topology {
            name: format!("{}-x{}", base.name, parts),
            clusters,
            links,
            mesh,
            memory: MemorySpec::default(),
        };
        topo.validate()?;
        Ok(topo)
    }

    /// The invariant pass behind every constructor.
    fn validate(&self) -> Result<()> {
        if self.clusters.is_empty() {
            return Err(bad("a system needs at least one cluster"));
        }
        for (i, c) in self.clusters.iter().enumerate() {
            if self.clusters[..i].iter().any(|o| o.name == c.name) {
                return Err(bad(format!("duplicate cluster name {:?}", c.name)));
            }
        }
        for (i, l) in self.links.iter().enumerate() {
            if l.a >= self.clusters.len() || l.b >= self.clusters.len() {
                return Err(bad(format!(
                    "link {i} endpoint out of range ({} clusters)",
                    self.clusters.len()
                )));
            }
            if l.a == l.b {
                return Err(bad(format!(
                    "link {i} connects cluster {:?} to itself",
                    self.clusters[l.a].name
                )));
            }
            if l.width == 0 {
                return Err(bad(format!("{}: zero-width link (no bandwidth)", self.link_name(i))));
            }
            if l.latency == 0 {
                return Err(bad(format!(
                    "{}: zero-latency link (a hop costs at least one cycle)",
                    self.link_name(i)
                )));
            }
            if self.links[..i]
                .iter()
                .any(|o| (o.a, o.b) == (l.a, l.b) || (o.b, o.a) == (l.a, l.b))
            {
                return Err(bad(format!("duplicate link {}", self.link_name(i))));
            }
        }
        if self.memory.width == 0 {
            return Err(bad("zero-width memory bus (no bandwidth)"));
        }
        // Reachability: the merge/broadcast schedule routes everything
        // through the link graph, so an unreachable cluster is a dead
        // declaration, not a degenerate schedule.
        if self.clusters.len() > 1 {
            let mut seen = vec![false; self.clusters.len()];
            let mut queue = vec![0usize];
            seen[0] = true;
            while let Some(c) = queue.pop() {
                for l in &self.links {
                    for (x, y) in [(l.a, l.b), (l.b, l.a)] {
                        if x == c && !seen[y] {
                            seen[y] = true;
                            queue.push(y);
                        }
                    }
                }
            }
            if let Some(i) = seen.iter().position(|s| !s) {
                return Err(bad(format!(
                    "cluster {:?} is unreachable from {:?} over the declared links",
                    self.clusters[i].name, self.clusters[0].name
                )));
            }
        }
        Ok(())
    }

    /// Total PE count across all clusters.
    pub fn total_pes(&self) -> usize {
        self.clusters.iter().map(|c| c.cfg.num_pes()).sum()
    }

    /// Display name of link `id`: `"c0<->c1"`.
    pub fn link_name(&self, id: usize) -> String {
        let l = &self.links[id];
        format!("{}<->{}", self.clusters[l.a].name, self.clusters[l.b].name)
    }

    /// Deterministic shortest route from cluster `src` to `dst` as a
    /// sequence of link ids. BFS with ascending link-id expansion, so
    /// equal-length routes tie-break on the lowest link ids — every
    /// engine asking for the same route gets the same answer, which the
    /// system layer's determinism proof leans on.
    pub fn route(&self, src: usize, dst: usize) -> Result<Vec<usize>> {
        if src == dst {
            return Ok(Vec::new());
        }
        let n = self.clusters.len();
        let mut prev: Vec<Option<(usize, usize)>> = vec![None; n]; // (node, link)
        let mut seen = vec![false; n];
        seen[src] = true;
        let mut frontier = vec![src];
        while !frontier.is_empty() && !seen[dst] {
            let mut next = Vec::new();
            for &c in &frontier {
                for (li, l) in self.links.iter().enumerate() {
                    for (x, y) in [(l.a, l.b), (l.b, l.a)] {
                        if x == c && !seen[y] {
                            seen[y] = true;
                            prev[y] = Some((c, li));
                            next.push(y);
                        }
                    }
                }
            }
            frontier = next;
        }
        if !seen[dst] {
            return Err(bad(format!(
                "no route from {:?} to {:?}",
                self.clusters[src].name, self.clusters[dst].name
            )));
        }
        let mut path = Vec::new();
        let mut cur = dst;
        while cur != src {
            let (p, li) = prev[cur].unwrap();
            path.push(li);
            cur = p;
        }
        path.reverse();
        Ok(path)
    }

    /// One-line human summary: `quad: 4x terapool-1-3-5-9-g1 (1024 PEs), 4 links (2x2 mesh), mem hbm`.
    pub fn describe(&self) -> String {
        let shape = match self.mesh {
            Some((c, r)) => format!("{} links ({c}x{r} mesh)", self.links.len()),
            None => format!("{} links", self.links.len()),
        };
        format!(
            "{}: {}x {} ({} PEs), {}, mem {} (lat {}, {} w/cy)",
            self.name,
            self.clusters.len(),
            self.clusters[0].cfg.name,
            self.total_pes(),
            shape,
            self.memory.name,
            self.memory.latency,
            self.memory.width
        )
    }

    /// Stable FNV-1a fingerprint over the canonical `Debug` rendering —
    /// same contract as [`ClusterConfig::fingerprint`]: equal
    /// fingerprints imply bit-identical system simulations.
    pub fn fingerprint(&self) -> String {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x100_0000_01b3;
        let mut h = FNV_OFFSET;
        for b in format!("{self:?}").bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        format!("{h:016x}")
    }
}

/// Divide the hierarchy by `parts` along Group → SubGroup → Tile levels
/// (greedy gcd at each level); `None` when `parts` does not divide the
/// shape exactly.
fn split_hierarchy(mut h: Hierarchy, parts: usize) -> Option<Hierarchy> {
    fn gcd(mut a: usize, mut b: usize) -> usize {
        while b != 0 {
            let t = a % b;
            a = b;
            b = t;
        }
        a
    }
    let mut rem = parts;
    for level in [
        &mut h.groups,
        &mut h.subgroups_per_group,
        &mut h.tiles_per_subgroup,
    ] {
        let g = gcd(*level, rem);
        *level /= g;
        rem /= g;
    }
    (rem == 1).then_some(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errors::ErrorKind;

    const QUAD: &str = "\
        system quad\n\
        cluster c0 preset=tiny\n\
        cluster c1 preset=tiny\n\
        cluster c2 preset=tiny\n\
        cluster c3 preset=tiny\n\
        mesh 2x2 latency=16 width=4\n\
        memory hbm latency=32 width=8\n";

    #[test]
    fn quad_mesh_parses_and_routes() {
        let t = Topology::parse(QUAD, "fallback").unwrap();
        assert_eq!(t.name, "quad");
        assert_eq!(t.clusters.len(), 4);
        assert_eq!(t.mesh, Some((2, 2)));
        // 2x2 mesh: 4 links — (0,1), (0,2), (1,3), (2,3).
        assert_eq!(t.links.len(), 4);
        assert_eq!(t.memory.width, 8);
        // Corner-to-corner route is two hops and deterministic: the
        // ascending tie-break picks 0->1->3 over 0->2->3.
        let path = t.route(0, 3).unwrap();
        assert_eq!(path.len(), 2);
        assert_eq!(
            (t.links[path[0]].a, t.links[path[0]].b),
            (0, 1),
            "tie-break must pick the lowest link ids"
        );
        assert_eq!(t.route(2, 2).unwrap(), Vec::<usize>::new());
        assert_eq!(t.total_pes(), 4 * ClusterConfig::tiny().num_pes());
    }

    #[test]
    fn defaults_fill_in_and_fingerprint_is_stable() {
        let text = "cluster a preset=tiny\ncluster b preset=tiny\nlink a b\n";
        let t = Topology::parse(text, "duo").unwrap();
        assert_eq!(t.name, "duo");
        assert_eq!(t.links[0].latency, DEFAULT_LINK_LATENCY);
        assert_eq!(t.links[0].width, DEFAULT_LINK_WIDTH);
        assert_eq!(t.memory.name, "mem");
        assert_eq!(t.fingerprint(), Topology::parse(text, "duo").unwrap().fingerprint());
        assert_ne!(t.fingerprint(), Topology::parse(QUAD, "x").unwrap().fingerprint());
    }

    /// The rejection table: every malformed document is a typed
    /// `BadTopology`, never a panic or a silently repaired system.
    #[test]
    fn malformed_topologies_are_rejected_with_typed_errors() {
        let cases: &[(&str, &str)] = &[
            // Bad link endpoints.
            ("cluster a preset=tiny\nlink a ghost\n", "names no declared cluster"),
            ("cluster a preset=tiny\nlink a a\n", "to itself"),
            // Cycles where a mesh is declared (chord links + mesh).
            (
                "cluster a preset=tiny\ncluster b preset=tiny\n\
                 cluster c preset=tiny\ncluster d preset=tiny\n\
                 mesh 2x2\nlink a d\n",
                "mutually exclusive",
            ),
            // Zero bandwidth.
            ("cluster a preset=tiny\ncluster b preset=tiny\nlink a b width=0\n", "zero-width"),
            ("cluster a preset=tiny\nmemory m width=0\n", "zero-width memory"),
            // Zero-latency hop.
            ("cluster a preset=tiny\ncluster b preset=tiny\nlink a b latency=0\n", "zero-latency"),
            // Mesh dims vs cluster count.
            ("cluster a preset=tiny\ncluster b preset=tiny\nmesh 2x2\n", "covers 4 nodes"),
            // Duplicates.
            ("cluster a preset=tiny\ncluster a preset=tiny\n", "duplicate cluster"),
            (
                "cluster a preset=tiny\ncluster b preset=tiny\nlink a b\nlink b a\n",
                "duplicate link",
            ),
            ("cluster a preset=tiny\nmemory m\nmemory n\n", "duplicate memory"),
            // Disconnected system.
            ("cluster a preset=tiny\ncluster b preset=tiny\n", "unreachable"),
            // Unknown syntax.
            ("flux a b\n", "unknown directive"),
            ("cluster a preset=warp9\n", "unknown cluster preset"),
            ("cluster a\n", "needs preset"),
            ("", "at least one cluster"),
        ];
        for (text, needle) in cases {
            let err = Topology::parse(text, "t").expect_err(text);
            assert_eq!(err.kind(), ErrorKind::BadTopology, "{text}");
            assert!(
                err.to_string().contains(needle),
                "{text:?}: {err} (wanted {needle:?})"
            );
        }
    }

    #[test]
    fn split_covers_p2p_mesh_and_ring() {
        let base = ClusterConfig::terapool(9);
        let one = Topology::split(&base, 1).unwrap();
        assert_eq!(one.clusters.len(), 1);
        assert!(one.links.is_empty());
        let two = Topology::split(&base, 2).unwrap();
        assert_eq!(two.links.len(), 1);
        assert_eq!(two.total_pes(), base.num_pes());
        assert_eq!(two.clusters[0].cfg.hierarchy.groups, 2);
        let four = Topology::split(&base, 4).unwrap();
        assert_eq!(four.mesh, Some((2, 2)));
        assert_eq!(four.total_pes(), base.num_pes());
        assert_eq!(four.clusters[0].cfg.hierarchy.groups, 1);
        // tiny is 4C-2T-2SG-2G: an 8-way split exists (2 groups × 2
        // subgroups × 2 tiles) and wires as a ring.
        let eight = Topology::split(&ClusterConfig::tiny(), 8).unwrap();
        assert_eq!(eight.links.len(), 8);
        assert_eq!(eight.total_pes(), ClusterConfig::tiny().num_pes());
        // A non-dividing split is a typed rejection.
        let err = Topology::split(&base, 3).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::BadTopology);
    }
}
