//! Minimal error plumbing, replacing the `anyhow` crate in this offline
//! build (same pattern as [`crate::rng`] replacing `rand`): a single
//! string-backed error type, `Result` alias, `bail!`/`ensure!`/`err!`
//! macros and a `Context` extension trait for `Result`/`Option`.

use std::fmt;

/// Machine-inspectable error classes. The simulation-facing API
/// (`Session`, the workload registry) promises *typed* failures — callers
/// match on [`Error::kind`] instead of scraping the message string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Anything without a dedicated class (I/O, parse, harness plumbing).
    Generic,
    /// A workload name not present in the registry
    /// (`kernels::lookup`) — a typed error, never a panic.
    UnknownWorkload,
    /// The cluster hit `max_cycles` before `done()`: the run did not
    /// finish, so its output image is garbage and must not be compared.
    MaxCyclesExceeded,
    /// A system topology description failed validation (unknown link
    /// endpoint, zero-width link, mesh/link contradictions, unreachable
    /// cluster, ...) — a typed error so the CLI and the rejection-table
    /// tests can match the class instead of the message.
    BadTopology,
    /// The requested combination is declaratively out of scope for the
    /// chosen run path (e.g. the analytic estimate census on a
    /// multi-cluster system run) — refused, never silently approximated.
    Unsupported,
}

/// A human-readable error with a machine-matchable [`ErrorKind`].
#[derive(Debug)]
pub struct Error {
    kind: ErrorKind,
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error { kind: ErrorKind::Generic, msg: msg.into() }
    }

    /// Construct with an explicit kind.
    pub fn with_kind(kind: ErrorKind, msg: impl Into<String>) -> Self {
        Error { kind, msg: msg.into() }
    }

    /// `UnknownWorkload` for `name`, listing what the registry offers.
    pub fn unknown_workload(name: &str, known: &[&str]) -> Self {
        Error::with_kind(
            ErrorKind::UnknownWorkload,
            format!("unknown workload {name:?} (registered: {})", known.join(", ")),
        )
    }

    /// `MaxCyclesExceeded` after simulating `max_cycles` of `what`.
    pub fn max_cycles(what: &str, max_cycles: u64) -> Self {
        Error::with_kind(
            ErrorKind::MaxCyclesExceeded,
            format!("{what}: did not finish within {max_cycles} cycles (possible deadlock)"),
        )
    }

    /// `BadTopology` with a description of the offending line/rule.
    pub fn bad_topology(msg: impl Into<String>) -> Self {
        Error::with_kind(ErrorKind::BadTopology, format!("topology: {}", msg.into()))
    }

    /// `Unsupported` for a refused run-path combination.
    pub fn unsupported(msg: impl Into<String>) -> Self {
        Error::with_kind(ErrorKind::Unsupported, msg.into())
    }

    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Prepend context to the message, keeping the kind (unlike the
    /// generic [`Context`] adapters, which can only produce `Generic`).
    pub fn prefixed(self, prefix: &str) -> Self {
        Error { kind: self.kind, msg: format!("{prefix}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

/// Crate-wide result alias (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (drop-in for `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::errors::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds (drop-in for
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Attach context to a fallible value (drop-in for `anyhow::Context`).
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 7);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }

    #[test]
    fn kinds_survive_prefixing() {
        let e = Error::max_cycles("gemm", 100).prefixed("session");
        assert_eq!(e.kind(), ErrorKind::MaxCyclesExceeded);
        assert!(e.to_string().starts_with("session: gemm:"));
        let e = Error::unknown_workload("nope", &["axpy", "gemm"]);
        assert_eq!(e.kind(), ErrorKind::UnknownWorkload);
        assert_eq!(fails().unwrap_err().kind(), ErrorKind::Generic);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }
}
