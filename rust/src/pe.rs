//! The processing element: a Snitch-like single-issue, single-stage
//! RV32IMA(+Xpulpimg, zfinx) core with a scoreboard and an LSU transaction
//! table (Sec. 4.1, Fig. 4).
//!
//! Modeled behaviour that determines the paper's results:
//!
//! * **single issue**: at most one instruction leaves the front end per
//!   cycle;
//! * **non-blocking loads**: loads/stores allocate a transaction-table
//!   entry and retire out of order; the scoreboard stalls any consumer of
//!   a register whose load is still in flight (RAW) and any reuse of a
//!   pending destination (WAW);
//! * **LSU stalls** when the transaction table (8 entries in TeraPool) is
//!   full;
//! * a taken **branch** costs one refetch bubble (single-stage core);
//! * **barrier/WFI**: arrival is an atomic fetch&add on the Tile-local
//!   counter, then the core sleeps until the cluster's wake-up broadcast.

use crate::interconnect::{ReqKind, Response};
use crate::isa::{Op, OpClass, Program, CTRL_BUBBLE, MAX_BURST_WORDS, NUM_REGS};

/// Why the PE could not issue this cycle (Fig. 14a stall taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallCause {
    /// Operand (or pending destination) still owned by an in-flight load.
    Raw,
    /// Transaction table full.
    Lsu,
    /// Refetch bubble after a taken branch.
    Ctrl,
    /// Barrier WFI / DMA wait.
    Synch,
}

/// What the cluster must do on behalf of the PE this cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// Nothing to route (issued a core-internal op, stalled, or halted).
    None,
    /// Route a load to L1.
    Load { rd: u8, addr: u32 },
    /// Route a store to L1.
    Store { value: f32, addr: u32 },
    /// Route a burst load of `n` words into `rd..rd+n` (one LSU
    /// transaction-table entry for the whole burst).
    LoadBurst { rd: u8, addr: u32, n: u8 },
    /// Route a burst store of `n` words; the data was read from
    /// `rs..rs+n` at issue, like [`Action::Store`] captures its value.
    StoreBurst { addr: u32, n: u8, values: [f32; MAX_BURST_WORDS] },
    /// Route an atomic fetch-and-add to L1.
    AmoAdd { value: f32, addr: u32 },
    /// Barrier arrival: the cluster issues the Tile-local atomic and
    /// parks the PE until the release broadcast.
    BarrierArrive { id: u16 },
    /// Trigger DMA descriptor `id` (iDMA frontend).
    DmaStart { id: u16 },
    /// Park the PE until DMA descriptor `id` retires.
    DmaWait { id: u16 },
}

/// Execution state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeState {
    Running,
    /// Arrival atomic in flight or waiting for the release broadcast.
    AtBarrier,
    WaitDma,
    Halted,
}

/// Per-PE performance counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct PeStats {
    pub issued: u64,
    pub loads: u64,
    pub stores: u64,
    pub atomics: u64,
    pub compute: u64,
    pub control: u64,
    pub sync_ops: u64,
    pub flops: u64,
    pub stall_raw: u64,
    pub stall_lsu: u64,
    pub stall_ctrl: u64,
    pub stall_synch: u64,
    /// Cycle at which this PE halted (set by the cluster).
    pub halt_cycle: u64,
}

impl PeStats {
    pub fn stalls_total(&self) -> u64 {
        self.stall_raw + self.stall_lsu + self.stall_ctrl + self.stall_synch
    }
}

/// A Snitch-like PE.
pub struct Pe {
    pub id: u32,
    pub tile: u32,
    program: Program,
    pc: usize,
    regs: [f32; NUM_REGS],
    /// Bitmask of registers owned by in-flight loads.
    pending: u32,
    tx_inflight: u32,
    tx_cap: u32,
    bubble: u32,
    pub state: PeState,
    pub stats: PeStats,
}

impl Pe {
    pub fn new(id: u32, tile: u32, tx_cap: u32, program: Program) -> Self {
        Pe {
            id,
            tile,
            program,
            pc: 0,
            regs: [0.0; NUM_REGS],
            pending: 0,
            tx_inflight: 0,
            tx_cap,
            bubble: 0,
            state: PeState::Running,
            stats: PeStats::default(),
        }
    }

    #[inline]
    fn is_pending(&self, r: u8) -> bool {
        self.pending & (1 << r) != 0
    }

    pub fn reg(&self, r: u8) -> f32 {
        self.regs[r as usize]
    }

    pub fn outstanding(&self) -> u32 {
        self.tx_inflight
    }

    /// All instructions retired and nothing in flight.
    pub fn done(&self) -> bool {
        self.state == PeState::Halted && self.tx_inflight == 0
    }

    fn stall(&mut self, cause: StallCause) -> Action {
        match cause {
            StallCause::Raw => self.stats.stall_raw += 1,
            StallCause::Lsu => self.stats.stall_lsu += 1,
            StallCause::Ctrl => self.stats.stall_ctrl += 1,
            StallCause::Synch => self.stats.stall_synch += 1,
        }
        Action::None
    }

    /// Credit `span` cycles of barrier/DMA wait in one update. The
    /// engines' idle-cycle fast-forward calls this instead of polling
    /// [`Pe::try_issue`] once per skipped cycle, which would charge the
    /// identical `StallCause::Synch` stall `span` times — the only
    /// per-cycle state a parked PE mutates.
    pub fn note_idle_span(&mut self, span: u64) {
        debug_assert!(
            matches!(self.state, PeState::AtBarrier | PeState::WaitDma),
            "idle-span credit on a non-parked PE"
        );
        self.stats.stall_synch += span;
    }

    fn count_issue(&mut self, op: &Op) {
        self.stats.issued += 1;
        self.stats.flops += op.flops();
        match op.class() {
            OpClass::Load => self.stats.loads += 1,
            OpClass::Store => self.stats.stores += 1,
            OpClass::Atomic => self.stats.atomics += 1,
            OpClass::Compute => self.stats.compute += 1,
            OpClass::Control => self.stats.control += 1,
            OpClass::Sync => self.stats.sync_ops += 1,
        }
    }

    /// Try to issue one instruction. The cluster routes the returned
    /// memory/synchronization actions.
    pub fn try_issue(&mut self) -> Action {
        match self.state {
            PeState::Halted => return Action::None,
            PeState::AtBarrier | PeState::WaitDma => {
                return self.stall(StallCause::Synch);
            }
            PeState::Running => {}
        }
        if self.bubble > 0 {
            self.bubble -= 1;
            return self.stall(StallCause::Ctrl);
        }
        let Some(&op) = self.program.ops.get(self.pc) else {
            // Fell off the end: treat as halt.
            self.state = PeState::Halted;
            return Action::None;
        };
        match op {
            Op::Ld { rd, addr } => {
                if self.is_pending(rd) {
                    return self.stall(StallCause::Raw); // WAW on in-flight load
                }
                if self.tx_inflight >= self.tx_cap {
                    return self.stall(StallCause::Lsu);
                }
                self.pending |= 1 << rd;
                self.tx_inflight += 1;
                self.count_issue(&op);
                self.pc += 1;
                Action::Load { rd, addr }
            }
            Op::St { rs, addr } => {
                if self.is_pending(rs) {
                    return self.stall(StallCause::Raw);
                }
                if self.tx_inflight >= self.tx_cap {
                    return self.stall(StallCause::Lsu);
                }
                self.tx_inflight += 1;
                self.count_issue(&op);
                self.pc += 1;
                Action::Store { value: self.regs[rs as usize], addr }
            }
            Op::LdBurst { rd, n, addr } => {
                // The whole destination window is one scoreboard unit:
                // any in-flight owner of rd..rd+n is a WAW hazard.
                let mask = ((1u32 << n) - 1) << rd;
                if self.pending & mask != 0 {
                    return self.stall(StallCause::Raw);
                }
                if self.tx_inflight >= self.tx_cap {
                    return self.stall(StallCause::Lsu);
                }
                self.pending |= mask;
                self.tx_inflight += 1;
                self.count_issue(&op);
                self.pc += 1;
                Action::LoadBurst { rd, addr, n }
            }
            Op::StBurst { rs, n, addr } => {
                let mask = ((1u32 << n) - 1) << rs;
                if self.pending & mask != 0 {
                    return self.stall(StallCause::Raw);
                }
                if self.tx_inflight >= self.tx_cap {
                    return self.stall(StallCause::Lsu);
                }
                self.tx_inflight += 1;
                self.count_issue(&op);
                self.pc += 1;
                let mut values = [0.0; MAX_BURST_WORDS];
                for k in 0..n as usize {
                    values[k] = self.regs[rs as usize + k];
                }
                Action::StoreBurst { addr, n, values }
            }
            Op::AtomAdd { rs, addr } => {
                if self.is_pending(rs) {
                    return self.stall(StallCause::Raw);
                }
                if self.tx_inflight >= self.tx_cap {
                    return self.stall(StallCause::Lsu);
                }
                self.tx_inflight += 1;
                self.count_issue(&op);
                self.pc += 1;
                Action::AmoAdd { value: self.regs[rs as usize], addr }
            }
            Op::LdImm { rd, imm } => {
                if self.is_pending(rd) {
                    return self.stall(StallCause::Raw);
                }
                self.regs[rd as usize] = imm;
                self.count_issue(&op);
                self.pc += 1;
                Action::None
            }
            Op::Fmac { rd, ra, rb } | Op::Fnmac { rd, ra, rb } => {
                if self.is_pending(ra) || self.is_pending(rb) || self.is_pending(rd) {
                    return self.stall(StallCause::Raw);
                }
                let prod = self.regs[ra as usize] * self.regs[rb as usize];
                if matches!(op, Op::Fmac { .. }) {
                    self.regs[rd as usize] += prod;
                } else {
                    self.regs[rd as usize] -= prod;
                }
                self.count_issue(&op);
                self.pc += 1;
                Action::None
            }
            Op::Mul { rd, ra, rb } | Op::Add { rd, ra, rb } | Op::Sub { rd, ra, rb } => {
                if self.is_pending(ra) || self.is_pending(rb) || self.is_pending(rd) {
                    return self.stall(StallCause::Raw);
                }
                let (a, b) = (self.regs[ra as usize], self.regs[rb as usize]);
                self.regs[rd as usize] = match op {
                    Op::Mul { .. } => a * b,
                    Op::Add { .. } => a + b,
                    _ => a - b,
                };
                self.count_issue(&op);
                self.pc += 1;
                Action::None
            }
            Op::Mov { rd, ra } => {
                if self.is_pending(ra) || self.is_pending(rd) {
                    return self.stall(StallCause::Raw);
                }
                self.regs[rd as usize] = self.regs[ra as usize];
                self.count_issue(&op);
                self.pc += 1;
                Action::None
            }
            Op::Alu => {
                self.count_issue(&op);
                self.pc += 1;
                Action::None
            }
            Op::Branch => {
                self.count_issue(&op);
                self.pc += 1;
                self.bubble = CTRL_BUBBLE;
                Action::None
            }
            Op::Barrier { id } => {
                if self.tx_inflight >= self.tx_cap {
                    return self.stall(StallCause::Lsu);
                }
                self.tx_inflight += 1;
                self.count_issue(&op);
                self.pc += 1;
                self.state = PeState::AtBarrier;
                Action::BarrierArrive { id }
            }
            Op::DmaStart { id } => {
                self.count_issue(&op);
                self.pc += 1;
                Action::DmaStart { id }
            }
            Op::DmaWait { id } => {
                self.count_issue(&op);
                self.pc += 1;
                self.state = PeState::WaitDma;
                Action::DmaWait { id }
            }
            Op::Halt => {
                self.state = PeState::Halted;
                Action::None
            }
        }
    }

    /// Load response: write back and release the register + table entry.
    pub fn complete_load(&mut self, rd: u8, value: f32) {
        debug_assert!(self.is_pending(rd));
        self.regs[rd as usize] = value;
        self.pending &= !(1 << rd);
        debug_assert!(self.tx_inflight > 0);
        self.tx_inflight -= 1;
    }

    /// Store/atomic acknowledgement: release the table entry.
    pub fn complete_ack(&mut self) {
        debug_assert!(self.tx_inflight > 0);
        self.tx_inflight -= 1;
    }

    /// Apply a completed L1 response: load write-back or store/atomic
    /// acknowledgement. Touches only this PE's private state, so both the
    /// serial and the tile-parallel engine route responses through here
    /// (barrier-counter bookkeeping stays with the cluster).
    ///
    /// A burst's runs each answer once: every run writes back its beats
    /// (reads) and frees their registers, but only the run flagged
    /// `last` releases the shared transaction-table entry.
    pub fn apply_response(&mut self, r: &Response) {
        match r.kind {
            ReqKind::Read { rd } => {
                for k in 0..r.words {
                    let reg = rd + k;
                    debug_assert!(self.is_pending(reg));
                    // Bank accesses mirror beat 0 into wdata[0], so this
                    // covers single-word responses too.
                    self.regs[reg as usize] = r.wdata[k as usize];
                    self.pending &= !(1 << reg);
                }
                if r.last {
                    debug_assert!(self.tx_inflight > 0);
                    self.tx_inflight -= 1;
                }
            }
            ReqKind::Write => {
                if r.last {
                    self.complete_ack();
                }
            }
            ReqKind::Amo => self.complete_ack(),
        }
    }

    /// Barrier release broadcast (or DMA completion) received.
    pub fn wake(&mut self) {
        debug_assert!(matches!(self.state, PeState::AtBarrier | PeState::WaitDma));
        self.state = PeState::Running;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Program;

    fn pe_with(ops: Vec<Op>) -> Pe {
        Pe::new(0, 0, 8, Program { ops })
    }

    #[test]
    fn compute_ops_execute_functionally() {
        let mut pe = pe_with(vec![
            Op::LdImm { rd: 1, imm: 3.0 },
            Op::LdImm { rd: 2, imm: 4.0 },
            Op::LdImm { rd: 3, imm: 10.0 },
            Op::Fmac { rd: 3, ra: 1, rb: 2 }, // 10 + 12 = 22
            Op::Sub { rd: 4, ra: 3, rb: 1 },  // 19
            Op::Halt,
        ]);
        for _ in 0..6 {
            pe.try_issue();
        }
        assert_eq!(pe.reg(3), 22.0);
        assert_eq!(pe.reg(4), 19.0);
        assert_eq!(pe.state, PeState::Halted);
        assert_eq!(pe.stats.issued, 5);
        assert_eq!(pe.stats.flops, 2 + 1);
    }

    #[test]
    fn raw_stall_until_load_returns() {
        let mut pe = pe_with(vec![
            Op::Ld { rd: 1, addr: 100 },
            Op::Add { rd: 2, ra: 1, rb: 1 },
            Op::Halt,
        ]);
        assert_eq!(pe.try_issue(), Action::Load { rd: 1, addr: 100 });
        // Consumer stalls while the load is outstanding.
        assert_eq!(pe.try_issue(), Action::None);
        assert_eq!(pe.try_issue(), Action::None);
        assert_eq!(pe.stats.stall_raw, 2);
        pe.complete_load(1, 21.0);
        pe.try_issue();
        assert_eq!(pe.reg(2), 42.0);
    }

    #[test]
    fn lsu_stall_when_tx_table_full() {
        let ops: Vec<Op> = (0..10).map(|i| Op::Ld { rd: i as u8 + 1, addr: i }).collect();
        let mut pe = pe_with(ops);
        for _ in 0..8 {
            assert!(matches!(pe.try_issue(), Action::Load { .. }));
        }
        // 9th load: table full (8 entries, Sec. 4.1).
        assert_eq!(pe.try_issue(), Action::None);
        assert_eq!(pe.stats.stall_lsu, 1);
        assert_eq!(pe.outstanding(), 8);
        pe.complete_load(1, 0.0);
        assert!(matches!(pe.try_issue(), Action::Load { .. }));
    }

    #[test]
    fn loads_retire_out_of_order() {
        let mut pe = pe_with(vec![
            Op::Ld { rd: 1, addr: 0 },
            Op::Ld { rd: 2, addr: 1 },
            Op::Add { rd: 3, ra: 2, rb: 2 }, // depends only on the 2nd load
            Op::Halt,
        ]);
        pe.try_issue();
        pe.try_issue();
        pe.complete_load(2, 5.0); // second load returns first
        pe.try_issue();
        assert_eq!(pe.reg(3), 10.0);
        assert_eq!(pe.outstanding(), 1);
    }

    #[test]
    fn branch_costs_a_bubble() {
        let mut pe = pe_with(vec![Op::Branch, Op::Alu, Op::Halt]);
        pe.try_issue(); // branch
        assert_eq!(pe.try_issue(), Action::None); // bubble
        assert_eq!(pe.stats.stall_ctrl, 1);
        pe.try_issue(); // alu
        assert_eq!(pe.stats.issued, 2);
    }

    #[test]
    fn store_carries_value_and_waw_protection() {
        let mut pe = pe_with(vec![
            Op::LdImm { rd: 1, imm: 2.5 },
            Op::St { rs: 1, addr: 7 },
            Op::Ld { rd: 1, addr: 9 }, // reuse r1: fine, store already read it
            Op::Ld { rd: 1, addr: 10 }, // WAW on pending r1 → raw stall
            Op::Halt,
        ]);
        pe.try_issue();
        assert_eq!(pe.try_issue(), Action::Store { value: 2.5, addr: 7 });
        assert!(matches!(pe.try_issue(), Action::Load { rd: 1, .. }));
        assert_eq!(pe.try_issue(), Action::None);
        assert_eq!(pe.stats.stall_raw, 1);
    }

    #[test]
    fn burst_load_holds_one_tx_entry_and_window_raw() {
        let mut pe = pe_with(vec![
            Op::LdBurst { rd: 4, n: 4, addr: 100 },
            Op::Add { rd: 1, ra: 6, rb: 6 }, // r6 inside the burst window
            Op::Halt,
        ]);
        assert_eq!(pe.try_issue(), Action::LoadBurst { rd: 4, addr: 100, n: 4 });
        assert_eq!(pe.outstanding(), 1, "whole burst = one table entry");
        assert_eq!(pe.try_issue(), Action::None);
        assert_eq!(pe.stats.stall_raw, 1, "window register still pending");
        assert_eq!(pe.stats.loads, 1);
    }

    #[test]
    fn burst_store_captures_window_values() {
        let mut pe = pe_with(vec![
            Op::LdImm { rd: 2, imm: 1.5 },
            Op::LdImm { rd: 3, imm: 2.5 },
            Op::StBurst { rs: 2, n: 2, addr: 40 },
            Op::Halt,
        ]);
        pe.try_issue();
        pe.try_issue();
        assert_eq!(
            pe.try_issue(),
            Action::StoreBurst { addr: 40, n: 2, values: [1.5, 2.5, 0.0, 0.0] }
        );
        assert_eq!(pe.outstanding(), 1);
    }

    #[test]
    fn split_burst_responses_retire_once() {
        // A 4-word burst load split by the interconnect into a 3-beat run
        // and a 1-beat run: the non-last run frees its registers but not
        // the table entry; the last run frees the entry.
        let mut pe = pe_with(vec![Op::LdBurst { rd: 4, n: 4, addr: 0 }, Op::Halt]);
        pe.try_issue();
        let run0 = Response {
            core: 0,
            kind: ReqKind::Read { rd: 4 },
            value: 1.0,
            latency: 1,
            class: crate::interconnect::NumaClass::Local,
            tag: 0,
            words: 3,
            last: false,
            wdata: [1.0, 2.0, 3.0, 0.0],
        };
        pe.apply_response(&run0);
        assert_eq!((pe.reg(4), pe.reg(5), pe.reg(6)), (1.0, 2.0, 3.0));
        assert_eq!(pe.outstanding(), 1, "non-last run keeps the entry");
        let run1 = Response { kind: ReqKind::Read { rd: 7 }, words: 1, last: true, wdata: [4.0; 4], ..run0 };
        pe.apply_response(&run1);
        assert_eq!(pe.reg(7), 4.0);
        assert_eq!(pe.outstanding(), 0, "last run releases the entry");
    }

    #[test]
    fn barrier_parks_until_wake() {
        let mut pe = pe_with(vec![Op::Barrier { id: 3 }, Op::Alu, Op::Halt]);
        assert_eq!(pe.try_issue(), Action::BarrierArrive { id: 3 });
        assert_eq!(pe.state, PeState::AtBarrier);
        assert_eq!(pe.try_issue(), Action::None);
        assert_eq!(pe.stats.stall_synch, 1);
        pe.complete_ack(); // arrival atomic acked
        pe.wake();
        assert!(matches!(pe.try_issue(), Action::None)); // Alu issues internally
        assert_eq!(pe.stats.issued, 2);
    }
}
