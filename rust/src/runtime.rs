//! PJRT runtime: loads the JAX/Pallas AOT artifacts (`artifacts/*.hlo.txt`)
//! and executes them on the XLA CPU client as **golden references** for
//! the cluster simulator's functional results.
//!
//! HLO *text* is the interchange format — jax ≥ 0.5 emits HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and DESIGN.md).
//!
//! Artifacts are compiled once per process and the executables reused;
//! Python never runs here.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Input descriptor from `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct ManifestInput {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<ManifestInput>,
}

/// Parse the line-oriented `manifest.txt` emitted by python/compile/aot.py:
///
/// ```text
/// artifact <name> <file> <sha256>
/// input <name> <dtype> <d0,d1,...|scalar>
/// ```
pub fn parse_manifest(text: &str) -> Result<HashMap<String, ManifestEntry>> {
    let mut out: HashMap<String, ManifestEntry> = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match it.next() {
            Some("artifact") => {
                let name = it.next().context("artifact: missing name")?;
                let file = it.next().context("artifact: missing file")?;
                let sha = it.next().context("artifact: missing sha256")?;
                out.insert(
                    name.to_string(),
                    ManifestEntry {
                        file: file.to_string(),
                        sha256: sha.to_string(),
                        inputs: Vec::new(),
                    },
                );
            }
            Some("input") => {
                let name = it.next().context("input: missing name")?;
                let dtype = it.next().context("input: missing dtype")?;
                let dims = it.next().context("input: missing dims")?;
                let shape: Vec<usize> = if dims == "scalar" {
                    vec![]
                } else {
                    dims.split(',')
                        .map(|d| d.parse().context("bad dim"))
                        .collect::<Result<_>>()?
                };
                out.get_mut(name)
                    .ok_or_else(|| anyhow!("input before artifact: {name}"))?
                    .inputs
                    .push(ManifestInput { shape, dtype: dtype.to_string() });
            }
            Some(tok) => {
                return Err(anyhow!("manifest line {}: unknown record {tok}", lineno + 1))
            }
            None => {}
        }
    }
    Ok(out)
}

/// The AOT artifact runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ManifestEntry>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Locate the artifacts directory: `$TERAPOOL_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (for tests run from rust/).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TERAPOOL_ARTIFACTS") {
        return PathBuf::from(d);
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.txt").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

impl Runtime {
    /// Create a CPU PJRT client over the given artifacts directory.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, executables: HashMap::new() })
    }

    pub fn with_default_dir() -> Result<Self> {
        Self::new(&artifacts_dir())
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.keys().map(|s| s.as_str()).collect()
    }

    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name}"))
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let entry = self.entry(name)?.clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on f32 input buffers (shapes validated against
    /// the manifest). Returns the flattened f32 outputs of the result
    /// tuple.
    pub fn execute_f32(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let entry = self.entry(name)?.clone();
        if entry.inputs.len() != inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (spec, data) in entry.inputs.iter().zip(inputs) {
            let expect: usize = spec.shape.iter().product();
            if expect != data.len() {
                return Err(anyhow!(
                    "{name}: input shape {:?} wants {expect} elements, got {}",
                    spec.shape,
                    data.len()
                ));
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = lit
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.executables.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        // Lowered with return_tuple=True: decompose the result tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("tuple decompose: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(out)
    }
}

/// Max |a-b| over two slices (golden-comparison helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Assert two f32 slices match within tolerance, reporting the worst
/// element on failure.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = (0usize, 0.0f32);
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs();
        if d > worst.1 {
            worst = (i, d);
        }
    }
    assert!(
        worst.1 <= atol,
        "{what}: max |Δ| = {} at index {} ({} vs {}), atol {atol}",
        worst.1,
        worst.0,
        a[worst.0],
        b[worst.0]
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(
            d.join("manifest.txt").exists(),
            "artifacts missing — run `make artifacts` first ({d:?})"
        );
    }

    #[test]
    fn manifest_parses_and_lists_all_kernels() {
        let rt = Runtime::with_default_dir().unwrap();
        for k in ["gemm", "axpy", "dotp", "fft", "spmmadd"] {
            assert!(rt.manifest.contains_key(k), "missing {k}");
        }
        let gemm = rt.entry("gemm").unwrap();
        assert_eq!(gemm.inputs.len(), 2);
        assert_eq!(gemm.inputs[0].shape, vec![256, 256]);
        assert!(!gemm.sha256.is_empty());
    }

    #[test]
    fn axpy_artifact_executes_correctly() {
        let mut rt = Runtime::with_default_dir().unwrap();
        let n = rt.entry("axpy").unwrap().inputs[1].shape[0];
        let alpha = vec![2.0f32];
        let x: Vec<f32> = (0..n).map(|i| (i % 5) as f32).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 3) as f32).collect();
        let out = rt.execute_f32("axpy", &[alpha.clone(), x.clone(), y.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        for i in (0..n).step_by(1771) {
            let want = 2.0 * x[i] + y[i];
            assert!((out[0][i] - want).abs() < 1e-5, "i={i}");
        }
    }

    #[test]
    fn spmmadd_artifact_is_elementwise_add() {
        let mut rt = Runtime::with_default_dir().unwrap();
        let shape = rt.entry("spmmadd").unwrap().inputs[0].shape.clone();
        let n: usize = shape.iter().product();
        let a: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| (i % 11) as f32 * 0.5).collect();
        let out = rt.execute_f32("spmmadd", &[a.clone(), b.clone()]).unwrap();
        for i in (0..n).step_by(997) {
            assert!((out[0][i] - (a[i] + b[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let mut rt = Runtime::with_default_dir().unwrap();
        let err = rt.execute_f32("axpy", &[vec![1.0], vec![1.0; 3], vec![1.0; 3]]);
        assert!(err.is_err());
    }
}
