//! Golden-artifact runtime: loads the manifest emitted by
//! `python/compile/aot.py` (`make artifacts`) and the **build-time
//! evaluated golden outputs** (`artifacts/<name>.golden.bin`) that the
//! integration tests compare the cluster simulator's memory image
//! against.
//!
//! Earlier revisions executed the AOT HLO artifacts through a PJRT/XLA
//! FFI at test time; that pulled the (offline-unavailable) `xla` crate
//! into every build. Golden *evaluation* now happens once at build time
//! on the Python side — aot.py runs each JAX entry on the same canonical
//! deterministic inputs the Rust trace builders stage
//! (`kernels::axpy::input_x` etc.) and dumps the outputs as raw
//! little-endian f32 — so this module is plain std Rust: a line-oriented
//! manifest parser plus a binary reader. The `.hlo.txt` artifacts are
//! still emitted and fingerprinted for provenance.
//!
//! Python never runs here; without `make artifacts` the golden layer is
//! simply reported unavailable and callers fall back to the pure-Rust
//! `reference()` oracles (see rust/tests/golden.rs).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::errors::{Context, Result};
use crate::err;

/// Input descriptor from `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct ManifestInput {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Golden-output descriptor (`golden <name> <file> <words>` record).
#[derive(Debug, Clone)]
pub struct GoldenRef {
    pub file: String,
    pub words: usize,
}

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<ManifestInput>,
    /// Build-time evaluated output, when aot.py could derive the entry's
    /// canonical inputs in closed form (all entries except spmmadd).
    pub golden: Option<GoldenRef>,
}

/// Parse the line-oriented `manifest.txt` emitted by python/compile/aot.py:
///
/// ```text
/// artifact <name> <file> <sha256>
/// input <name> <dtype> <d0,d1,...|scalar>
/// golden <name> <file> <words>
/// ```
pub fn parse_manifest(text: &str) -> Result<HashMap<String, ManifestEntry>> {
    let mut out: HashMap<String, ManifestEntry> = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        match it.next() {
            Some("artifact") => {
                let name = it.next().context("artifact: missing name")?;
                let file = it.next().context("artifact: missing file")?;
                let sha = it.next().context("artifact: missing sha256")?;
                out.insert(
                    name.to_string(),
                    ManifestEntry {
                        file: file.to_string(),
                        sha256: sha.to_string(),
                        inputs: Vec::new(),
                        golden: None,
                    },
                );
            }
            Some("input") => {
                let name = it.next().context("input: missing name")?;
                let dtype = it.next().context("input: missing dtype")?;
                let dims = it.next().context("input: missing dims")?;
                let shape: Vec<usize> = if dims == "scalar" {
                    vec![]
                } else {
                    dims.split(',')
                        .map(|d| d.parse().context("bad dim"))
                        .collect::<Result<_>>()?
                };
                out.get_mut(name)
                    .ok_or_else(|| err!("input before artifact: {name}"))?
                    .inputs
                    .push(ManifestInput { shape, dtype: dtype.to_string() });
            }
            Some("golden") => {
                let name = it.next().context("golden: missing name")?;
                let file = it.next().context("golden: missing file")?;
                let words: usize = it
                    .next()
                    .context("golden: missing word count")?
                    .parse()
                    .context("golden: bad word count")?;
                out.get_mut(name)
                    .ok_or_else(|| err!("golden before artifact: {name}"))?
                    .golden = Some(GoldenRef { file: file.to_string(), words });
            }
            Some(tok) => {
                return Err(err!("manifest line {}: unknown record {tok}", lineno + 1))
            }
            None => {}
        }
    }
    Ok(out)
}

/// The golden-artifact runtime.
pub struct Runtime {
    dir: PathBuf,
    manifest: HashMap<String, ManifestEntry>,
}

/// Locate the artifacts directory: `$TERAPOOL_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (for tests run from rust/).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TERAPOOL_ARTIFACTS") {
        return PathBuf::from(d);
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.txt").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

impl Runtime {
    /// Open the manifest in the given artifacts directory.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let manifest = parse_manifest(&text)?;
        Ok(Runtime { dir: dir.to_path_buf(), manifest })
    }

    pub fn with_default_dir() -> Result<Self> {
        Self::new(&artifacts_dir())
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.keys().map(|s| s.as_str()).collect()
    }

    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        self.manifest
            .get(name)
            .ok_or_else(|| err!("no artifact named {name}"))
    }

    /// Load the build-time evaluated golden output of an entry: the
    /// flattened f32 results of all its outputs, concatenated in output
    /// order (little-endian raw words on disk).
    pub fn golden_f32(&self, name: &str) -> Result<Vec<f32>> {
        let entry = self.entry(name)?;
        let golden = entry
            .golden
            .as_ref()
            .ok_or_else(|| err!("{name} has no golden record — rerun `make artifacts`"))?;
        let path = self.dir.join(&golden.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?} — rerun `make artifacts`"))?;
        if bytes.len() != golden.words * 4 {
            return Err(err!(
                "{name}: golden file {path:?} holds {} bytes, manifest says {} words",
                bytes.len(),
                golden.words
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Max |a-b| over two slices (golden-comparison helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Assert two f32 slices match within tolerance, reporting the worst
/// element on failure.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = (0usize, 0.0f32);
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs();
        if d > worst.1 {
            worst = (i, d);
        }
    }
    assert!(
        worst.1 <= atol,
        "{what}: max |Δ| = {} at index {} ({} vs {}), atol {atol}",
        worst.1,
        worst.0,
        a[worst.0],
        b[worst.0]
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# artifact <name> <file> <sha256> / input <name> <dtype> <dims>
artifact axpy axpy.hlo.txt abc123
input axpy float32 scalar
input axpy float32 262144
golden axpy axpy.golden.bin 262144
artifact gemm gemm.hlo.txt def456
input gemm float32 256,256
input gemm float32 256,256
";

    #[test]
    fn manifest_parses_entries_inputs_and_goldens() {
        let m = parse_manifest(SAMPLE).unwrap();
        let axpy = &m["axpy"];
        assert_eq!(axpy.file, "axpy.hlo.txt");
        assert_eq!(axpy.inputs.len(), 2);
        assert_eq!(axpy.inputs[0].shape, Vec::<usize>::new());
        assert_eq!(axpy.inputs[1].shape, vec![262144]);
        let g = axpy.golden.as_ref().unwrap();
        assert_eq!(g.file, "axpy.golden.bin");
        assert_eq!(g.words, 262144);
        let gemm = &m["gemm"];
        assert_eq!(gemm.inputs[0].shape, vec![256, 256]);
        assert!(gemm.golden.is_none());
    }

    #[test]
    fn manifest_rejects_orphan_and_unknown_records() {
        assert!(parse_manifest("input axpy float32 scalar").is_err());
        assert!(parse_manifest("golden axpy f.bin 4").is_err());
        assert!(parse_manifest("frobnicate axpy").is_err());
    }

    #[test]
    fn golden_roundtrip_through_tempdir() {
        let dir = std::env::temp_dir().join(format!(
            "terapool-golden-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = vec![1.5, -2.25, 0.0, 1e-3];
        let mut bytes = Vec::new();
        for v in &data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("axpy.golden.bin"), &bytes).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact axpy axpy.hlo.txt abc\ngolden axpy axpy.golden.bin 4\n",
        )
        .unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.golden_f32("axpy").unwrap(), data);
        assert!(rt.golden_f32("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifacts_dir_is_a_clean_error() {
        let e = Runtime::new(Path::new("/nonexistent-terapool-artifacts")).unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
    }

    #[test]
    fn allclose_reports_worst_element() {
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0, 2.0], &[1.0, 2.5], 0.1, "demo");
        });
        assert!(r.is_err());
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
    }
}
