//! Minimal deterministic PRNG (SplitMix64 core), replacing the `rand`
//! crate in this offline build. Quality is ample for workload generation
//! and the AMAT burst simulations (equidistributed 64-bit outputs).

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; n > 0).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.gen_range((hi - lo) as usize) as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::seed_from_u64(7);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.gen_range(8);
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit: {seen:?}");
    }

    #[test]
    fn f64_uniformish() {
        let mut r = Rng::seed_from_u64(1);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
