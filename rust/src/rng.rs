//! Minimal deterministic PRNG (SplitMix64 core), replacing the `rand`
//! crate in this offline build. Quality is ample for workload generation
//! and the AMAT burst simulations (equidistributed 64-bit outputs).
//!
//! The generator is ported bit-for-bit to `python/compile/rng.py` so the
//! build layer can regenerate SpMMadd's canonical CSR inputs for the JAX
//! golden (`artifacts/spmmadd.golden.bin`). Both sides pin the first 64
//! draws of seed `0x5EED` to the same constants (see
//! `first_64_draws_pinned_cross_language` below and
//! python/tests/test_rng.py) — drift on either side fails both suites.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; n > 0).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.gen_range((hi - lo) as usize) as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::seed_from_u64(7);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.gen_range(8);
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit: {seen:?}");
    }

    /// Cross-language pin: the same 64 draws are asserted by
    /// python/tests/test_rng.py against python/compile/rng.py, which
    /// regenerates SpMMadd's CSR golden inputs. Seed 0x5EED is the
    /// canonical SpMMadd workload seed.
    #[test]
    fn first_64_draws_pinned_cross_language() {
        const EXPECTED: [u64; 64] = [
            0x09F1FD9D03F0A9B4, 0x553274161BBF8475, 0x5D5BCA4696B343B3, 0x70D29B6C7D22528D,
            0x0BF2B716F9915475, 0x5EB7F92B95387CCA, 0x296CD0F2C21D7F90, 0x1289A69805C125B1,
            0xDAA27FB8DACB9E73, 0x3ED08D59CB3F4727, 0x58A5F17B6C15C659, 0x651AC042FA7B481A,
            0x22AF6AEAA88E8DCC, 0x2D2BAE64640ABFB9, 0xAD0E83A710231B07, 0x9D30FF2169D91F12,
            0xF5FF07C9523504DD, 0x1273C823BA66EEC0, 0x47E1DBE249CB520B, 0xBBEA42BD69484ADC,
            0xC33E61BC6EF9E4C4, 0x752CD583231B5114, 0xE53DC6E1988622E5, 0x928EB721ED361BA3,
            0x10BF7972F379031E, 0x974041D15AD75C38, 0xFF9B273F42286387, 0x2601349FEF087EB0,
            0x5753F8EF429A4A7E, 0x2663E5E9DCBCBABA, 0xA8BB872E52C6235C, 0xE1774D56B0DC91AC,
            0x8634930F702B6452, 0x1674658F30892DDD, 0x2F957488E4FD469E, 0x656ED1CB9A126362,
            0x5325662609163089, 0x3BA278A39643A1BC, 0x0EFA3DDA544646D9, 0x4CC8C74C1FB520CC,
            0x626C1EF331F85C18, 0x01457B862CC7B3C9, 0x3825403DF6F9AD71, 0x272C78C413C9D42D,
            0x4DDE6838B289C9CE, 0x1467A1289E64EB89, 0x00EB8B8A36B5B98D, 0xF2443B542BF81344,
            0x278641CAD03AD4BE, 0x5A71CD3D503FAEEE, 0x2C58DAA06446969A, 0x79559FF0F9D26976,
            0x4A127FE7AAC0FFFD, 0xBCA4883827803ECC, 0xB60627C1559D3728, 0x0D1D73CE3F48B12D,
            0x78E74B9EB7B50E87, 0xEB26C664BA822E65, 0xEF794A8DCA9DCB0A, 0x89119CBF1EE9784B,
            0x180B37DFF135DE45, 0xBE1B67D3E6055F33, 0x6FBE6FBA62CE02C8, 0x1FBF7B87B4F36BC8,
        ];
        let mut r = Rng::seed_from_u64(0x5EED);
        for (i, &want) in EXPECTED.iter().enumerate() {
            assert_eq!(r.next_u64(), want, "draw {i}");
        }
    }

    #[test]
    fn f64_uniformish() {
        let mut r = Rng::seed_from_u64(1);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
