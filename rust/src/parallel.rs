//! Host-side parallel execution plumbing for the deterministic
//! three-phase cluster engine (`Cluster::run_parallel`), replacing the
//! `rayon` crate in this offline build with `std::thread::scope` plus a
//! spin barrier.
//!
//! ## Determinism contract (see DESIGN.md §Three-phase sharded engine)
//!
//! Each simulated cycle is split into:
//!
//! * **serial pre-phase (coordinator)** — deliver the previous cycle's
//!   drained responses and wake-ups, barrier bookkeeping/release, DMA
//!   control + progress, and the cross-shard transfer merge, all in fixed
//!   global orders (worker order = Tile order = the serial engine's
//!   order).
//! * **phase 1 (parallel)** — each worker applies its PEs' responses and
//!   wake-ups, then issues each PE in index order, bucketing every memory
//!   action *directly into the issuing Tile's memory domain* (a pure
//!   function of the address map; a Tile's requests can only come from
//!   its own PEs, so no cross-worker hand-off exists here). DMA control
//!   ops go to the coordinator's outbox instead.
//! * **phase 2 (parallel)** — each worker steps its owned Tile domains in
//!   ascending Tile order: master/slave/bank arbitration and the bank
//!   reads/writes/AMOs against the Tiles' own L1 slices, then drains the
//!   responses falling due next cycle into its channel.
//!
//! Workers own disjoint, *contiguous* ranges of Tiles (and exactly those
//! Tiles' PEs), in Tile → SubGroup → Group order — the paper's physical
//! hierarchy. Every per-domain input stream is consumed in a canonical
//! order and every cross-domain hand-off is merged in ascending Tile
//! order, so results, cycle counts and all statistics are bit-identical
//! to the serial engine for any thread count — `rust/tests/
//! parallel_equiv.rs` enforces this differentially.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::cluster::{route_action, RoutedAction};
use crate::interconnect::{Interconnect, Response, TileDomain, XferEvent};
use crate::memory::L1Memory;
use crate::pe::{Action, Pe};

/// Default worker-thread count for harness code (tests, benches,
/// examples): the host's cores, capped at 16. Phase 2 (bank arbitration)
/// is sharded by destination Tile, so the old 8-thread knee — "the serial
/// phase 2 dominates anyway" — is gone; what bounds scaling now is the
/// per-cycle coordinator merge plus two barrier crossings, whose cost
/// grows with the worker count while each worker's share of the domain
/// work shrinks. Past ~16 workers the crossings outweigh the shrinking
/// shares on every realistic simulated cycle length.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Sense-reversing spin barrier: far cheaper per crossing than
/// `std::sync::Barrier` (no mutex/condvar), which matters because the
/// engine crosses it twice per simulated cycle.
pub struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
        }
    }

    /// Block (spinning) until all `n` participants have arrived.
    pub fn wait(&self) {
        let round = self.generation.load(Ordering::SeqCst);
        if self.count.fetch_add(1, Ordering::SeqCst) + 1 == self.n {
            // Last arriver: reset the counter *before* releasing the
            // generation, so early re-entrants of the next round never
            // race the reset.
            self.count.store(0, Ordering::SeqCst);
            self.generation.fetch_add(1, Ordering::SeqCst);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::SeqCst) == round {
                spins += 1;
                if spins < 4096 {
                    std::hint::spin_loop();
                } else {
                    // Long serial pre-phase (e.g. heavy DMA traffic):
                    // stop burning the core.
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Coordinator-side drop guard: sets `stop` and performs the final
/// barrier crossing exactly once — on normal completion *or* while the
/// coordinator unwinds from a panic (e.g. a routing assert in the
/// pre-phase). Without it, workers parked at the cycle-top rendezvous
/// would spin forever and `std::thread::scope` would never finish
/// joining, turning a clean panic into a hang. Every coordinator panic
/// site has the workers parked at that rendezvous (they only run strictly
/// between the two barrier crossings), so the single release here is
/// always paired.
pub struct PoolShutdown<'a> {
    stop: &'a AtomicBool,
    barrier: &'a SpinBarrier,
}

impl<'a> PoolShutdown<'a> {
    pub fn new(stop: &'a AtomicBool, barrier: &'a SpinBarrier) -> Self {
        PoolShutdown { stop, barrier }
    }
}

impl Drop for PoolShutdown<'_> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.barrier.wait();
    }
}

/// Coordinator → worker hand-off for one cycle.
#[derive(Default)]
pub struct Inbox {
    /// L1 responses due this cycle for PEs owned by the worker, in the
    /// global (Tile-ascending) drained order.
    pub responses: Vec<Response>,
    /// PEs (global indices) to wake before issuing: barrier releases and
    /// DMA completions.
    pub wakes: Vec<u32>,
}

/// Per-worker mailbox. Phases strictly alternate (enforced by the
/// barrier), so every lock below is uncontended; the Mutex exists to give
/// the alternation a safe Rust expression, not for arbitration.
pub struct WorkerChannel {
    /// Global index of the first PE owned by this worker.
    pub pe_base: u32,
    pub inbox: Mutex<Inbox>,
    /// DMA control ops issued in phase 1, `(global pe, action)` in PE
    /// order — the only actions the coordinator still routes itself.
    pub outbox: Mutex<Vec<(u32, Action)>>,
    /// Transfer events routed *to* this worker's Tiles, already in the
    /// global merge order (the coordinator buckets a Tile-ascending
    /// stream, which bucketing preserves per destination).
    pub xfer_in: Mutex<Vec<XferEvent>>,
    /// Master-port winners of this worker's source Tiles, Tile-ascending.
    pub xfer_out: Mutex<Vec<XferEvent>>,
    /// Responses drained from this worker's domains, Tile-ascending.
    pub resp_out: Mutex<Vec<Response>>,
    /// Net requests born minus retired in this worker's domains. The sum
    /// over all channels is the cluster-wide in-flight count (a request
    /// born in one worker's source Tile may retire in another's
    /// destination Tile, so individual counters can go negative).
    pub inflight: AtomicI64,
    /// Whether any owned PE is still live after this worker's last phase.
    pub busy: AtomicBool,
}

impl WorkerChannel {
    pub fn new(pe_base: u32) -> Self {
        WorkerChannel {
            pe_base,
            inbox: Mutex::new(Inbox::default()),
            outbox: Mutex::new(Vec::new()),
            xfer_in: Mutex::new(Vec::new()),
            xfer_out: Mutex::new(Vec::new()),
            resp_out: Mutex::new(Vec::new()),
            inflight: AtomicI64::new(0),
            busy: AtomicBool::new(false),
        }
    }
}

/// Everything a worker needs besides its PE slice: its channel, the
/// shared (read-only-routed) views of the memory system, its owned Tile
/// range, and the coordinator-published cycle counter.
pub struct WorkerCtx<'a> {
    pub ch: &'a WorkerChannel,
    pub icn: &'a Interconnect,
    pub l1: &'a L1Memory,
    pub tile_lo: usize,
    pub tile_hi: usize,
    pub pes_per_tile: usize,
    pub now: &'a AtomicU64,
}

/// Worker body: one iteration per simulated cycle until `stop` is raised.
///
/// `pes` is the worker's contiguous PE slice (exactly the PEs of Tiles
/// `[tile_lo, tile_hi)`); `ctx.ch.pe_base` is the global index of
/// `pes[0]`. A panic inside the phase work (e.g. a debug assertion)
/// raises `failed` and keeps the barrier protocol alive, so the
/// coordinator can shut the pool down and re-raise instead of spinning
/// forever.
pub fn worker_loop(
    pes: &mut [Pe],
    ctx: WorkerCtx<'_>,
    barrier: &SpinBarrier,
    stop: &AtomicBool,
    failed: &AtomicBool,
) {
    let ch = ctx.ch;
    let base = ch.pe_base as usize;
    let mut responses: Vec<Response> = Vec::new();
    let mut wakes: Vec<u32> = Vec::new();
    let mut actions: Vec<(u32, Action)> = Vec::new();
    let mut xfer_out: Vec<XferEvent> = Vec::new();
    let mut resp_out: Vec<Response> = Vec::new();
    loop {
        barrier.wait();
        if stop.load(Ordering::SeqCst) {
            break;
        }

        let work = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let now = ctx.now.load(Ordering::SeqCst);

            // Take this cycle's events (capacity is recycled both ways).
            {
                let mut inbox = ch.inbox.lock().unwrap();
                std::mem::swap(&mut inbox.responses, &mut responses);
                std::mem::swap(&mut inbox.wakes, &mut wakes);
            }

            // Response write-backs first, wake-ups second — the same
            // order the serial engine uses within a cycle.
            for r in &responses {
                pes[r.core as usize - base].apply_response(r);
            }
            responses.clear();
            for &pe in &wakes {
                pes[pe as usize - base].wake();
            }
            wakes.clear();

            // Own this worker's Tile domains for the whole phase (one
            // uncontended lock per Tile per cycle).
            let mut domains: Vec<MutexGuard<'_, TileDomain>> = (ctx.tile_lo..ctx.tile_hi)
                .map(|t| ctx.icn.domain(t).lock().unwrap())
                .collect();

            // Cross-shard arrivals routed by the coordinator, already in
            // the global (Tile-ascending) merge order.
            {
                let mut xin = ch.xfer_in.lock().unwrap();
                for ev in xin.drain(..) {
                    domains[ev.dst_tile as usize - ctx.tile_lo]
                        .ingest_arrival(ev.at, ev.slave_port, ev.req);
                }
            }

            // Phase 1: issue every owned PE in index order, bucketing
            // memory actions straight into the issuing Tile's domain.
            let mut busy = false;
            let mut births: i64 = 0;
            for (i, pe) in pes.iter_mut().enumerate() {
                let action = pe.try_issue();
                if action != Action::None {
                    let gpe = (base + i) as u32;
                    let tile = (base + i) / ctx.pes_per_tile;
                    match route_action(now, gpe, tile, action, &ctx.l1.map, ctx.icn.topo()) {
                        RoutedAction::None => {}
                        RoutedAction::Mem { req, master_port } => {
                            births += 1;
                            let d = &mut domains[tile - ctx.tile_lo];
                            match master_port {
                                None => d.ingest_local(req),
                                Some(p) => d.ingest_master(p, req),
                            }
                        }
                        RoutedAction::Dma(op) => actions.push((gpe, op)),
                    }
                }
                busy |= !pe.done();
            }

            // Phase 2: per-shard arbitration + bank accesses, ascending
            // Tile order; responses due next cycle leave the domains.
            for (k, t) in (ctx.tile_lo..ctx.tile_hi).enumerate() {
                let d = &mut *domains[k];
                if d.is_idle() {
                    continue;
                }
                let mut store = ctx.l1.tile_store(t).lock().unwrap();
                d.step(now, &mut store, ctx.icn.topo(), &mut xfer_out, &mut resp_out);
            }
            let deaths = resp_out.len() as i64;
            ch.inflight.fetch_add(births - deaths, Ordering::SeqCst);
            drop(domains);

            // Publish this cycle's outputs for the coordinator.
            {
                let mut out = ch.xfer_out.lock().unwrap();
                out.append(&mut xfer_out);
            }
            {
                let mut out = ch.resp_out.lock().unwrap();
                out.append(&mut resp_out);
            }
            {
                let mut outbox = ch.outbox.lock().unwrap();
                std::mem::swap(&mut *outbox, &mut actions);
            }
            debug_assert!(actions.is_empty());
            ch.busy.store(busy, Ordering::SeqCst);
        }));
        if work.is_err() {
            failed.store(true, Ordering::SeqCst);
        }

        barrier.wait();
    }
}

/// Job-level fan-out for the `Session` batch path: run `n` independent
/// jobs on up to `threads` host workers and return the results **in job
/// order** regardless of which worker ran what. Scheduling is dynamic
/// (an atomic work cursor), but because every job is independent and the
/// result lands in its own indexed slot, the output is deterministic —
/// batched runs are bit-identical to a sequential loop. A panicking job
/// propagates out of the scope (same contract as running it inline).
pub fn scatter<R: Send>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let workers = threads.min(n);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    return;
                }
                let r = f(i);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("scatter: job slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn spin_barrier_rendezvous_many_rounds() {
        const THREADS: usize = 4;
        const ROUNDS: u64 = 200;
        let barrier = SpinBarrier::new(THREADS);
        let counter = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for round in 0..ROUNDS {
                        counter.fetch_add(1, Ordering::SeqCst);
                        barrier.wait();
                        // After the barrier, all THREADS increments of
                        // this round must be visible.
                        let c = counter.load(Ordering::SeqCst);
                        assert!(c >= (round + 1) * THREADS as u64, "round {round}: {c}");
                        barrier.wait();
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), ROUNDS * THREADS as u64);
    }

    #[test]
    fn single_participant_barrier_never_blocks() {
        let b = SpinBarrier::new(1);
        for _ in 0..10 {
            b.wait();
        }
    }

    #[test]
    fn scatter_preserves_job_order_at_any_width() {
        let jobs = 23usize;
        let want: Vec<usize> = (0..jobs).map(|i| i * i).collect();
        for threads in [1usize, 2, 4, 8, 64] {
            let got = scatter(jobs, threads, |i| i * i);
            assert_eq!(got, want, "{threads} threads");
        }
        assert_eq!(scatter(0, 4, |i| i), Vec::<usize>::new());
    }
}
