//! Host-side parallel execution plumbing for the deterministic two-phase
//! cluster engine (`Cluster::run_parallel`), replacing the `rayon` crate
//! in this offline build with `std::thread::scope` plus a spin barrier.
//!
//! ## Determinism contract (see DESIGN.md §Two-phase engine)
//!
//! Each simulated cycle is split into:
//!
//! * **phase 1 (parallel)** — per-Tile work with no shared state: apply
//!   the cycle's L1 responses and wake-ups to the Tile's PEs, then issue
//!   each PE in index order, queuing the resulting memory/sync actions
//!   into a per-worker buffer. Workers own disjoint, *contiguous* ranges
//!   of Tiles (Tile → SubGroup → Group order, the paper's physical
//!   hierarchy), so concatenating the per-worker buffers in worker order
//!   reproduces the exact PE-ascending order of the serial engine.
//! * **phase 2 (serial)** — the coordinator drains the per-worker action
//!   buffers in worker order and performs bank arbitration, barrier
//!   bookkeeping and DMA progress in a fixed total order, bit-identically
//!   to [`crate::cluster::Cluster::step`].
//!
//! Because PE state is only ever mutated in phase 1 by the worker that
//! owns it, and all shared structures (interconnect queues, L1 banks,
//! barrier counters, the DMA engine) are only mutated in phase 2 in a
//! fixed order, results, cycle counts and every statistic are identical
//! to the serial engine for any thread count — `rust/tests/
//! parallel_equiv.rs` enforces this differentially.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::interconnect::Response;
use crate::pe::{Action, Pe};

/// Default worker-thread count for harness code (tests, benches,
/// examples): the host's cores, capped at 8 — beyond the Tile-sharding
/// sweet spot the serial phase 2 dominates anyway (EXPERIMENTS.md §Perf).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Sense-reversing spin barrier: far cheaper per crossing than
/// `std::sync::Barrier` (no mutex/condvar), which matters because the
/// engine crosses it twice per simulated cycle.
pub struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
        }
    }

    /// Block (spinning) until all `n` participants have arrived.
    pub fn wait(&self) {
        let round = self.generation.load(Ordering::SeqCst);
        if self.count.fetch_add(1, Ordering::SeqCst) + 1 == self.n {
            // Last arriver: reset the counter *before* releasing the
            // generation, so early re-entrants of the next round never
            // race the reset.
            self.count.store(0, Ordering::SeqCst);
            self.generation.fetch_add(1, Ordering::SeqCst);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::SeqCst) == round {
                spins += 1;
                if spins < 4096 {
                    std::hint::spin_loop();
                } else {
                    // Long serial phase (e.g. heavy bank arbitration):
                    // stop burning the core.
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Coordinator-side drop guard: sets `stop` and performs the final
/// barrier crossing exactly once — on normal completion *or* while the
/// coordinator unwinds from a panic (e.g. a routing assert in phase 2).
/// Without it, workers parked at the cycle-top rendezvous would spin
/// forever and `std::thread::scope` would never finish joining, turning
/// a clean panic into a hang. Every coordinator panic site has the
/// workers parked at that rendezvous (they only run strictly between
/// the two phase-1 barrier crossings), so the single release here is
/// always paired.
pub struct PoolShutdown<'a> {
    stop: &'a AtomicBool,
    barrier: &'a SpinBarrier,
}

impl<'a> PoolShutdown<'a> {
    pub fn new(stop: &'a AtomicBool, barrier: &'a SpinBarrier) -> Self {
        PoolShutdown { stop, barrier }
    }
}

impl Drop for PoolShutdown<'_> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.barrier.wait();
    }
}

/// Coordinator → worker hand-off for one cycle.
#[derive(Default)]
pub struct Inbox {
    /// L1 responses due this cycle for PEs owned by the worker, in the
    /// global drained order.
    pub responses: Vec<Response>,
    /// PEs (global indices) to wake before issuing: barrier releases and
    /// DMA completions.
    pub wakes: Vec<u32>,
}

/// Per-worker mailbox. Phases strictly alternate (enforced by the
/// barrier), so every lock below is uncontended; the Mutex exists to give
/// the alternation a safe Rust expression, not for arbitration.
pub struct WorkerChannel {
    /// Global index of the first PE owned by this worker.
    pub pe_base: u32,
    pub inbox: Mutex<Inbox>,
    /// Actions issued in phase 1, `(global pe index, action)` in PE order.
    pub outbox: Mutex<Vec<(u32, Action)>>,
    /// Whether any owned PE is still live after this worker's last phase.
    pub busy: AtomicBool,
}

impl WorkerChannel {
    pub fn new(pe_base: u32) -> Self {
        WorkerChannel {
            pe_base,
            inbox: Mutex::new(Inbox::default()),
            outbox: Mutex::new(Vec::new()),
            busy: AtomicBool::new(false),
        }
    }
}

/// Worker body: one iteration per simulated cycle until `stop` is raised.
///
/// `pes` is the worker's contiguous PE slice (whole Tiles); `ch.pe_base`
/// is the global index of `pes[0]`. A panic inside the phase work (e.g.
/// a debug assertion) raises `failed` and keeps the barrier protocol
/// alive, so the coordinator can shut the pool down and re-raise instead
/// of spinning forever.
pub fn worker_loop(
    pes: &mut [Pe],
    ch: &WorkerChannel,
    barrier: &SpinBarrier,
    stop: &AtomicBool,
    failed: &AtomicBool,
) {
    let base = ch.pe_base as usize;
    let mut responses: Vec<Response> = Vec::new();
    let mut wakes: Vec<u32> = Vec::new();
    let mut actions: Vec<(u32, Action)> = Vec::new();
    loop {
        barrier.wait();
        if stop.load(Ordering::SeqCst) {
            break;
        }

        let work = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // Take this cycle's events (capacity is recycled both ways).
            {
                let mut inbox = ch.inbox.lock().unwrap();
                std::mem::swap(&mut inbox.responses, &mut responses);
                std::mem::swap(&mut inbox.wakes, &mut wakes);
            }

            // Response write-backs first, wake-ups second — the same
            // order the serial engine uses within a cycle.
            for r in &responses {
                pes[r.core as usize - base].apply_response(r);
            }
            responses.clear();
            for &pe in &wakes {
                pes[pe as usize - base].wake();
            }
            wakes.clear();

            // Issue every owned PE in index order.
            let mut busy = false;
            for (i, pe) in pes.iter_mut().enumerate() {
                let action = pe.try_issue();
                if action != Action::None {
                    actions.push(((base + i) as u32, action));
                }
                busy |= !pe.done();
            }
            ch.busy.store(busy, Ordering::SeqCst);
            {
                // Publish the actions; the coordinator swapped in an
                // empty vector (recycled capacity) at the end of last
                // cycle.
                let mut outbox = ch.outbox.lock().unwrap();
                std::mem::swap(&mut *outbox, &mut actions);
            }
            debug_assert!(actions.is_empty());
        }));
        if work.is_err() {
            failed.store(true, Ordering::SeqCst);
        }

        barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn spin_barrier_rendezvous_many_rounds() {
        const THREADS: usize = 4;
        const ROUNDS: u64 = 200;
        let barrier = SpinBarrier::new(THREADS);
        let counter = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for round in 0..ROUNDS {
                        counter.fetch_add(1, Ordering::SeqCst);
                        barrier.wait();
                        // After the barrier, all THREADS increments of
                        // this round must be visible.
                        let c = counter.load(Ordering::SeqCst);
                        assert!(c >= (round + 1) * THREADS as u64, "round {round}: {c}");
                        barrier.wait();
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), ROUNDS * THREADS as u64);
    }

    #[test]
    fn single_participant_barrier_never_blocks() {
        let b = SpinBarrier::new(1);
        for _ in 0..10 {
            b.wait();
        }
    }
}
