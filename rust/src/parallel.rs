//! Host-side parallel execution plumbing for the deterministic **fully
//! sharded** cluster engine (`Cluster::run_parallel`), replacing the
//! `rayon` crate in this offline build with `std::thread::scope`, a spin
//! barrier, per-worker mailbox matrices and a binary reduction tree.
//!
//! ## Sharded cycle contract (see DESIGN.md §Fully sharded engine)
//!
//! Each simulated cycle runs almost entirely inside the workers; the
//! coordinator's per-cycle work is O(threads) plus the genuinely serial
//! DMA channel-arbitration decisions:
//!
//! * **cycle top (parallel, owner-computes)** — each worker drains the
//!   response/transfer mailboxes addressed to it (in ascending source
//!   order, which restores the serial engine's global Tile-ascending
//!   order), applies responses and wake-ups to its own PEs, ingests
//!   transfer arrivals into its own Tile domains, and applies the
//!   sub-runs of this cycle's inbound DMA bursts that land in its own
//!   L1 slices.
//! * **phase 1 (parallel)** — each worker issues its PEs in index order,
//!   bucketing memory actions into the issuing Tile's domain. `DmaWait`
//!   is resolved locally against the worker's descriptor done-mirror;
//!   only `DmaStart` crosses to the coordinator (via the summary tree).
//! * **phase 2 (parallel)** — each worker steps its Tile domains in
//!   ascending order, then buckets the drained responses and master-port
//!   winners straight into the destination workers' mailboxes. Barrier
//!   arrivals are counted here, at drain time, into the worker's
//!   [`CycleSummary`].
//! * **summary reduction (parallel)** — the per-worker summaries (busy
//!   flag, unconsumed-event count, barrier-arrival tallies, `DmaStart`
//!   stream) merge pairwise up a binary worker tree; child `c = w + 2^l`
//!   folds into parent `w` in ascending level order, so concatenated
//!   streams stay in ascending worker (= PE = Tile) order and the
//!   coordinator reads a single root.
//! * **serial pre-phase (coordinator, O(threads))** — decide
//!   termination, consume the root summary (global barrier counters,
//!   release scheduling, `DmaStart` programming), run the DMA *timing*
//!   step ([`crate::dma::DmaEvent`]) — moving outbound burst words
//!   inline at the exact serial point (the main-memory image is
//!   single-owner state) — and publish the per-cycle [`ControlBlock`]
//!   (releases, retired descriptors, inbound data-movement jobs).
//!
//! Workers own disjoint, *contiguous* ranges of Tiles (and exactly those
//! Tiles' PEs), in Tile → SubGroup → Group order — the paper's physical
//! hierarchy. Every per-domain input stream is consumed in a canonical
//! order and every cross-domain hand-off lands in a per-(source,
//! destination) mailbox whose drain order restores the global merge, so
//! results, cycle counts and all statistics are bit-identical to the
//! serial engine for any thread count — `rust/tests/parallel_equiv.rs`
//! enforces this differentially at 1–16 threads.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, RwLock};

use crate::cluster::{route_action, RoutedAction};
use crate::interconnect::{Interconnect, Response, TileDomain, XferEvent};
use crate::memory::L1Memory;
use crate::pe::{Action, Pe, PeState};
use crate::stats::IdCounts;

/// Default worker-thread count for harness code (tests, benches,
/// examples): the host's cores, capped at 16. With the pre-phase sharded
/// (owner-computes delivery, distributed barriers/DMA, mailbox transfer
/// scatter) the coordinator's per-cycle work is O(threads); what bounds
/// scaling now is the cycle-top barrier crossing plus the summary-tree
/// depth (cycle *completion* is observed through the root summary stamp,
/// not a second crossing), whose cost grows with the worker count while
/// each worker's share of the domain work shrinks. Past ~16 workers the
/// synchronization outweighs the shrinking shares on every realistic
/// simulated cycle length.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Sense-reversing spin barrier: far cheaper per crossing than
/// `std::sync::Barrier` (no mutex/condvar), which matters because the
/// engine crosses it once per simulated cycle — the cycle-top rendezvous
/// that releases the workers into the cycle. (Cycle *completion* needs no
/// second crossing: the coordinator observes it through the summary
/// tree's root ready-stamp, see [`await_summary`].)
pub struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
        }
    }

    /// Block (spinning) until all `n` participants have arrived.
    pub fn wait(&self) {
        let round = self.generation.load(Ordering::SeqCst);
        if self.count.fetch_add(1, Ordering::SeqCst) + 1 == self.n {
            // Last arriver: reset the counter *before* releasing the
            // generation, so early re-entrants of the next round never
            // race the reset.
            self.count.store(0, Ordering::SeqCst);
            self.generation.fetch_add(1, Ordering::SeqCst);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::SeqCst) == round {
                spins += 1;
                if spins < 4096 {
                    std::hint::spin_loop();
                } else {
                    // Long serial pre-phase (e.g. heavy DMA traffic):
                    // stop burning the core.
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Coordinator-side drop guard: sets `stop` and performs the final
/// barrier crossing exactly once — on normal completion *or* while the
/// coordinator unwinds from a panic (e.g. a routing assert in the
/// pre-phase). Without it, workers parked at the cycle-top rendezvous
/// would spin forever and `std::thread::scope` would never finish
/// joining, turning a clean panic into a hang. At every coordinator panic
/// site the workers are either parked at that rendezvous or finishing the
/// cycle body on their way back to it (nothing in the body can block
/// indefinitely: the only inter-worker wait, the summary-tree fold,
/// escapes via `failed`), so the single release here is always paired
/// with each worker's next cycle-top arrival. `parallel::tests::
/// pool_shutdown_releases_workers_on_coordinator_panic` pins the
/// invariant.
pub struct PoolShutdown<'a> {
    stop: &'a AtomicBool,
    barrier: &'a SpinBarrier,
}

impl<'a> PoolShutdown<'a> {
    pub fn new(stop: &'a AtomicBool, barrier: &'a SpinBarrier) -> Self {
        PoolShutdown { stop, barrier }
    }
}

impl Drop for PoolShutdown<'_> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.barrier.wait();
    }
}

/// Single-producer, single-consumer event box between one (source,
/// destination) worker pair, double-buffered by cycle parity: the writer
/// fills parity `now & 1` during its phase, the reader drains parity
/// `(now & 1) ^ 1` at the next cycle top, so the two sides never touch
/// the same buffer in the same phase. The flag spares the reader a lock
/// on the (common) empty case; the Mutex is uncontended by construction
/// and exists to give the phase alternation a safe Rust expression.
pub struct Mailbox<T> {
    flag: AtomicBool,
    q: Mutex<Vec<T>>,
}

impl<T: Copy> Mailbox<T> {
    fn new() -> Self {
        Mailbox {
            flag: AtomicBool::new(false),
            q: Mutex::new(Vec::new()),
        }
    }

    /// Move `items` into the box (no-op when empty), preserving order.
    pub fn publish(&self, items: &mut Vec<T>) {
        if items.is_empty() {
            return;
        }
        self.q.lock().unwrap().append(items);
        self.flag.store(true, Ordering::Release);
    }

    /// Drain the box in publish order through `f`.
    pub fn consume(&self, mut f: impl FnMut(T)) {
        if self.flag.swap(false, Ordering::Acquire) {
            for item in self.q.lock().unwrap().drain(..) {
                f(item);
            }
        }
    }
}

/// One worker's per-cycle output summary, combined pairwise up the binary
/// worker tree so the coordinator consumes a single root instead of
/// O(cluster) event streams. All fields merge associatively; the
/// `dma_ops` stream concatenates child-after-parent, which (children
/// being higher worker indices) keeps it in global PE order.
#[derive(Default)]
pub struct CycleSummary {
    /// Any PE in the merged range still live.
    pub busy: bool,
    /// Any PE in the merged range in `PeState::Running` *after* this
    /// cycle's phase 1 — the consensus signal for the coordinator's
    /// idle-cycle fast-forward. Distinct from `busy`, which stays true
    /// for parked (barrier/DMA-waiting) PEs: a cluster can be busy yet
    /// have nothing to do until a scheduled event.
    pub runnable: bool,
    /// Responses + transfer events published to mailboxes this cycle
    /// (unconsumed until the next cycle top).
    pub events: u64,
    /// Barrier arrivals observed at drain time, tallied per barrier id.
    pub arrivals: IdCounts,
    /// `DmaStart` control ops in global PE order — the only PE actions
    /// the coordinator still routes itself.
    pub dma_ops: Vec<(u32, Action)>,
}

impl CycleSummary {
    fn reset(&mut self) {
        self.busy = false;
        self.runnable = false;
        self.events = 0;
        self.arrivals.clear();
        self.dma_ops.clear();
    }

    /// Fold `other` (a higher-indexed worker's subtree) into this one.
    pub fn absorb(&mut self, other: &mut CycleSummary) {
        self.busy |= other.busy;
        self.runnable |= other.runnable;
        self.events += other.events;
        self.arrivals.absorb(&other.arrivals);
        self.dma_ops.append(&mut other.dma_ops);
        other.busy = false;
        other.runnable = false;
        other.events = 0;
        other.arrivals.clear();
    }
}

/// One **inbound** DMA burst's functional data movement, published by
/// the coordinator and executed by every worker on the sub-runs that
/// land in its own Tiles. (Outbound bursts never become jobs: their L1
/// reads and image writes happen inline on the coordinator at the exact
/// serial point — the image is single-owner state, so there is nothing
/// to shard.)
pub struct DmaJob {
    pub l1_word: u32,
    /// The burst's words, staged from the main-memory image.
    pub data: Vec<f32>,
}

/// Per-cycle coordinator → workers broadcast, published under the write
/// lock strictly between the barrier crossings (workers read-lock it
/// concurrently during their phase). The `seed_*` fields are one-time
/// carry-over from earlier serial stepping on the same cluster,
/// consumed/cleared after the first parallel cycle.
#[derive(Default)]
pub struct ControlBlock {
    /// Idle cycles fast-forwarded over since the workers' last cycle:
    /// the coordinator found the cluster quiescent and jumped the cycle
    /// counter by this span. Each worker credits its own parked PEs
    /// with the span's synch stalls at its cycle top — the only
    /// per-cycle state a quiescent span would have mutated.
    pub skip: u64,
    /// Barrier ids whose release broadcast fires this cycle; each worker
    /// wakes its own waiters.
    pub releases: Vec<u16>,
    /// Descriptors that retired this cycle (first cycle: all descriptors
    /// already done) — workers update their done-mirrors and wake their
    /// own `DmaWait`-parked PEs.
    pub dma_done: Vec<u16>,
    /// Functional data movement of this cycle's issued bursts.
    pub dma_jobs: Vec<DmaJob>,
    /// Seed: responses drained but undelivered when the engine started,
    /// pre-bucketed per destination worker.
    pub seed_resp: Vec<Mutex<Vec<Response>>>,
    /// Seed: transfer events awaiting their next-cycle merge, per
    /// destination worker.
    pub seed_xfer: Vec<Mutex<Vec<XferEvent>>>,
    /// Seed: (barrier id, PE) pairs parked at a barrier.
    pub seed_waiting: Vec<(u16, u32)>,
    /// Seed: (PE, descriptor) pairs parked on `DmaWait`.
    pub seed_dma_waiters: Vec<(u32, u16)>,
}

/// Parked-PE bookkeeping a worker hands back at shutdown so the cluster
/// can continue (mixed-engine stepping) with consistent state.
#[derive(Default)]
pub struct ParkedState {
    /// (barrier id, PE) pairs still waiting for a release.
    pub barrier_waiting: Vec<(u16, u32)>,
    /// (PE, descriptor) pairs still waiting for a retirement.
    pub dma_waiters: Vec<(u32, u16)>,
}

/// Per-worker communication endpoints. Phases strictly alternate
/// (enforced by the barrier) and mailboxes are parity-double-buffered,
/// so every lock below is uncontended; the Mutexes express the
/// alternation safely, they never arbitrate.
pub struct WorkerChannel {
    /// Global index of the first PE owned by this worker.
    pub pe_base: u32,
    /// Outgoing response mailboxes: `resp[parity][destination worker]`.
    resp: [Vec<Mailbox<Response>>; 2],
    /// Outgoing transfer-event mailboxes, same layout.
    xfer: [Vec<Mailbox<XferEvent>>; 2],
    /// This worker's (partially tree-merged) cycle summary.
    pub summary: Mutex<CycleSummary>,
    /// Cycle number for which `summary` covers the worker's whole
    /// subtree; `u64::MAX` = never published.
    pub summary_ready: AtomicU64,
    /// Net requests born minus retired in this worker's domains. The sum
    /// over all channels is the cluster-wide in-flight count (a request
    /// born in one worker's source Tile may retire in another's
    /// destination Tile, so individual counters can go negative).
    pub inflight: AtomicI64,
    /// Parked state dumped when the pool shuts down.
    pub parked: Mutex<ParkedState>,
}

impl WorkerChannel {
    pub fn new(pe_base: u32, workers: usize) -> Self {
        let boxes = |n: usize| -> Vec<Mailbox<Response>> { (0..n).map(|_| Mailbox::new()).collect() };
        let xboxes = |n: usize| -> Vec<Mailbox<XferEvent>> { (0..n).map(|_| Mailbox::new()).collect() };
        WorkerChannel {
            pe_base,
            resp: [boxes(workers), boxes(workers)],
            xfer: [xboxes(workers), xboxes(workers)],
            summary: Mutex::new(CycleSummary::default()),
            summary_ready: AtomicU64::new(u64::MAX),
            inflight: AtomicI64::new(0),
            parked: Mutex::new(ParkedState::default()),
        }
    }

    pub fn resp_to(&self, parity: usize, dst: usize) -> &Mailbox<Response> {
        &self.resp[parity][dst]
    }

    pub fn xfer_to(&self, parity: usize, dst: usize) -> &Mailbox<XferEvent> {
        &self.xfer[parity][dst]
    }
}

/// Everything a worker needs besides its PE slice: the full channel
/// array (mailbox reads cross workers), the control block, the shared
/// (read-only-routed) views of the memory system, its owned Tile range
/// and the coordinator-published cycle counter.
pub struct WorkerCtx<'a> {
    pub idx: usize,
    pub channels: &'a [WorkerChannel],
    pub ctrl: &'a RwLock<ControlBlock>,
    pub icn: &'a Interconnect,
    pub l1: &'a L1Memory,
    pub tile_lo: usize,
    pub tile_hi: usize,
    pub pes_per_tile: usize,
    pub tiles_per_worker: usize,
    pub pes_per_worker: usize,
    pub has_dma: bool,
    pub now: &'a AtomicU64,
}

/// Apply one response to its (owned) PE and register barrier waiters —
/// the per-PE half of what the serial engine's step 1 does; the arrival
/// *counting* half happened at drain time in the destination domain's
/// worker.
fn apply_response_owned(
    pes: &mut [Pe],
    base: usize,
    r: &Response,
    waiting: &mut HashMap<u16, Vec<u32>>,
) {
    pes[r.core as usize - base].apply_response(r);
    if let Some(id) = r.barrier_id() {
        waiting.entry(id).or_default().push(r.core);
    }
}

/// Spin until `ready` publishes `cycle`, with an escape hatch when a
/// sibling worker failed (its summary will never arrive).
///
/// Workers use it to fold child subtrees; the coordinator uses it on the
/// *root* stamp (`channels[0].summary_ready`) as the cycle-completion
/// wait, replacing what used to be a second full barrier crossing. The
/// Acquire load pairs with each worker's Release store, and because every
/// worker's stamp is transitively awaited along the root's subtree chain,
/// observing the root stamp orders *all* workers' cycle work (mailbox
/// publishes, `inflight` updates, ctrl read-guard drops) before whatever
/// the caller does next.
pub fn await_summary(ready: &AtomicU64, cycle: u64, failed: &AtomicBool) {
    let mut spins = 0u32;
    while ready.load(Ordering::Acquire) != cycle {
        if failed.load(Ordering::Relaxed) {
            return;
        }
        spins += 1;
        if spins < 4096 {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
}

/// Worker body: one iteration per simulated cycle until `stop` is raised.
///
/// `pes` is the worker's contiguous PE slice (exactly the PEs of Tiles
/// `[tile_lo, tile_hi)`); `ctx.channels[ctx.idx].pe_base` is the global
/// index of `pes[0]`. A panic inside the phase work (e.g. a debug
/// assertion) raises `failed`, still publishes the summary-ready stamp so
/// tree parents never spin forever, and keeps the barrier protocol alive
/// so the coordinator can shut the pool down and re-raise instead of
/// hanging.
pub fn worker_loop(
    pes: &mut [Pe],
    ctx: WorkerCtx<'_>,
    barrier: &SpinBarrier,
    stop: &AtomicBool,
    failed: &AtomicBool,
) {
    let w = ctx.idx;
    let workers = ctx.channels.len();
    let ch = &ctx.channels[w];
    let base = ch.pe_base as usize;

    // Worker-local sharded state: this worker's parked PEs and its
    // mirror of the retired-descriptor set.
    let mut waiting: HashMap<u16, Vec<u32>> = HashMap::new();
    let mut dma_waiters: Vec<(u32, u16)> = Vec::new();
    let mut dma_done: Vec<bool> = Vec::new();

    // Recycled buffers.
    let mut summary = CycleSummary::default();
    let mut resp_out: Vec<Vec<Response>> = (0..workers).map(|_| Vec::new()).collect();
    let mut xfer_out: Vec<Vec<XferEvent>> = (0..workers).map(|_| Vec::new()).collect();
    let mut flat_resp: Vec<Response> = Vec::new();
    let mut flat_xfer: Vec<XferEvent> = Vec::new();

    loop {
        barrier.wait();
        if stop.load(Ordering::SeqCst) {
            // Hand the parked state back so the cluster stays consistent
            // for mixed-engine continuation.
            let mut parked = ch.parked.lock().unwrap();
            for (id, list) in waiting.drain() {
                for pe in list {
                    parked.barrier_waiting.push((id, pe));
                }
            }
            parked.dma_waiters.append(&mut dma_waiters);
            break;
        }

        let now = ctx.now.load(Ordering::SeqCst);
        let work = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let cur = (now & 1) as usize;
            let prev = cur ^ 1;
            summary.reset();

            // ---- cycle top: owner-computes delivery -------------------
            let cb = ctx.ctrl.read().unwrap();

            // Idle-cycle fast-forward: the coordinator jumped the clock
            // over `cb.skip` fully quiescent cycles. Credit each of my
            // parked PEs with the stall_synch ticks it would have
            // accumulated polling through them one by one — nothing else
            // in a quiescent cycle touches worker state.
            if cb.skip > 0 {
                for pe in pes.iter_mut() {
                    if matches!(pe.state, PeState::AtBarrier | PeState::WaitDma) {
                        pe.note_idle_span(cb.skip);
                    }
                }
            }

            // Seeds (non-empty only on the first cycle after a
            // mixed-engine hand-off): carried-over undelivered responses,
            // parked PEs, parked DMA waiters.
            for r in cb.seed_resp[w].lock().unwrap().drain(..) {
                apply_response_owned(pes, base, &r, &mut waiting);
            }
            for &(id, pe) in &cb.seed_waiting {
                if pe as usize / ctx.pes_per_worker == w {
                    waiting.entry(id).or_default().push(pe);
                }
            }
            for &(pe, id) in &cb.seed_dma_waiters {
                if pe as usize / ctx.pes_per_worker == w {
                    dma_waiters.push((pe, id));
                }
            }

            // (1) Responses for my PEs, drained in ascending source-worker
            // order — which restores the serial engine's global
            // Tile-ascending delivery order restricted to my PEs.
            for src in ctx.channels {
                src.resp_to(prev, w)
                    .consume(|r| apply_response_owned(pes, base, &r, &mut waiting));
            }

            // (2) Barrier release broadcasts: wake my own waiters.
            for &id in &cb.releases {
                if let Some(list) = waiting.remove(&id) {
                    for pe in list {
                        pes[pe as usize - base].wake();
                    }
                }
            }

            // (3) DMA retirements: update the done-mirror, wake my own
            // parked waiters (same cycle the serial engine wakes them).
            for &d in &cb.dma_done {
                let d = d as usize;
                if dma_done.len() <= d {
                    dma_done.resize(d + 1, false);
                }
                dma_done[d] = true;
            }
            if !cb.dma_done.is_empty() && !dma_waiters.is_empty() {
                dma_waiters.retain(|&(pe, id)| {
                    if dma_done.get(id as usize).copied().unwrap_or(false) {
                        pes[pe as usize - base].wake();
                        false
                    } else {
                        true
                    }
                });
            }

            // (4) Inbound DMA movement: the sub-runs of this cycle's
            // bursts that land in my Tiles go straight into my slices —
            // visible to this cycle's bank accesses, exactly as the
            // serial engine's step-3 movement is. (Outbound bursts moved
            // inline on the coordinator during the pre-phase.)
            for job in cb.dma_jobs.iter() {
                ctx.l1
                    .write_run_range(job.l1_word, &job.data, ctx.tile_lo, ctx.tile_hi);
            }

            // ---- own the Tile domains for the rest of the cycle -------
            let mut domains: Vec<MutexGuard<'_, TileDomain>> = (ctx.tile_lo..ctx.tile_hi)
                .map(|t| ctx.icn.domain(t).lock().unwrap())
                .collect();

            // (5) Cross-shard arrivals: seeds first (strictly older),
            // then the mailboxes in ascending source order — the global
            // Tile-ascending merge, restricted to my destination Tiles.
            for ev in cb.seed_xfer[w].lock().unwrap().drain(..) {
                domains[ev.dst_tile as usize - ctx.tile_lo]
                    .ingest_arrival(ev.at, ev.slave_port, ev.req);
            }
            for src in ctx.channels {
                src.xfer_to(prev, w).consume(|ev| {
                    domains[ev.dst_tile as usize - ctx.tile_lo]
                        .ingest_arrival(ev.at, ev.slave_port, ev.req);
                });
            }
            drop(cb);

            // (6) Phase 1: issue every owned PE in index order, bucketing
            // memory actions straight into the issuing Tile's domain.
            let mut busy = false;
            let mut runnable = false;
            let mut births: i64 = 0;
            for (i, pe) in pes.iter_mut().enumerate() {
                let action = pe.try_issue();
                if action != Action::None {
                    let gpe = (base + i) as u32;
                    let tile = (base + i) / ctx.pes_per_tile;
                    match route_action(now, gpe, tile, action, &ctx.l1.map, ctx.icn.topo()) {
                        RoutedAction::None => {}
                        RoutedAction::Mem { reqs } => {
                            let d = &mut domains[tile - ctx.tile_lo];
                            for (req, master_port) in reqs.into_iter().flatten() {
                                births += 1;
                                match master_port {
                                    None => d.ingest_local(req),
                                    Some(p) => d.ingest_master(p, req),
                                }
                            }
                        }
                        RoutedAction::Dma(op) => match op {
                            Action::DmaStart { .. } => summary.dma_ops.push((gpe, op)),
                            Action::DmaWait { id } => {
                                // Resolved locally against the done-mirror,
                                // whose state equals the serial engine's
                                // `is_done` at this exact point of the
                                // cycle (post DMA-progress, in-issue).
                                let done = !ctx.has_dma
                                    || dma_done.get(id as usize).copied().unwrap_or(false);
                                if done {
                                    pe.wake();
                                } else {
                                    dma_waiters.push((gpe, id));
                                }
                            }
                            _ => unreachable!("only DMA control ops are RoutedAction::Dma"),
                        },
                    }
                }
                busy |= !pe.done();
                runnable |= pe.state == PeState::Running;
            }

            // (7) Phase 2: per-shard arbitration + bank accesses in
            // ascending Tile order; drains land in flat buffers, then get
            // bucketed per destination worker (stable, so each bucket
            // preserves my Tile-ascending order).
            for (k, t) in (ctx.tile_lo..ctx.tile_hi).enumerate() {
                let d = &mut *domains[k];
                if d.is_idle() {
                    continue;
                }
                let mut store = ctx.l1.tile_store(t).lock().unwrap();
                d.step(now, &mut store, ctx.icn.topo(), &mut flat_xfer, &mut flat_resp);
            }
            drop(domains);

            let deaths = flat_resp.len() as i64;
            let mut events = 0u64;
            for r in flat_resp.drain(..) {
                // Barrier arrivals are counted where they are drained, so
                // the coordinator sees them at the same pre-phase the
                // serial engine's bookkeeping would.
                if let Some(id) = r.barrier_id() {
                    summary.arrivals.add(id, 1);
                }
                resp_out[r.core as usize / ctx.pes_per_worker].push(r);
                events += 1;
            }
            for ev in flat_xfer.drain(..) {
                xfer_out[ev.dst_tile as usize / ctx.tiles_per_worker].push(ev);
                events += 1;
            }
            for (dst, buf) in resp_out.iter_mut().enumerate() {
                ch.resp_to(cur, dst).publish(buf);
            }
            for (dst, buf) in xfer_out.iter_mut().enumerate() {
                ch.xfer_to(cur, dst).publish(buf);
            }
            ch.inflight.fetch_add(births - deaths, Ordering::SeqCst);
            summary.busy = busy;
            summary.runnable = runnable;
            summary.events = events;

            // (8) Summary reduction: fold every child subtree (ascending
            // levels keep streams in ascending worker order), then
            // publish for my parent / the coordinator.
            let mut level = 0usize;
            loop {
                let stride = 1usize << level;
                if w & stride != 0 {
                    break; // I'm a right child at this level.
                }
                let child = w + stride;
                if child >= workers {
                    break;
                }
                await_summary(&ctx.channels[child].summary_ready, now, failed);
                let mut cs = ctx.channels[child]
                    .summary
                    .lock()
                    .unwrap_or_else(|p| p.into_inner());
                summary.absorb(&mut cs);
                drop(cs);
                level += 1;
            }
            {
                let mut slot = ch.summary.lock().unwrap_or_else(|p| p.into_inner());
                std::mem::swap(&mut *slot, &mut summary);
            }
            ch.summary_ready.store(now, Ordering::Release);
        }));
        if work.is_err() {
            failed.store(true, Ordering::SeqCst);
            // Keep the tree protocol alive: parents escape their spin via
            // `failed`, but publish the stamp anyway so nothing depends on
            // the race.
            ch.summary_ready.store(now, Ordering::SeqCst);
        }
        // No bottom crossing: the coordinator observes cycle completion
        // through the root summary stamp and cannot release the next
        // cycle-top rendezvous before every worker has stamped, so
        // looping straight back to `barrier.wait()` is race-free.
    }
}

/// Job-level fan-out for the `Session` batch path: run `n` independent
/// jobs on up to `threads` host workers and return the results **in job
/// order** regardless of which worker ran what. Scheduling is dynamic
/// (an atomic work cursor), but because every job is independent and the
/// result lands in its own indexed slot, the output is deterministic —
/// batched runs are bit-identical to a sequential loop. A panicking job
/// propagates out of the scope (same contract as running it inline).
pub fn scatter<R: Send>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let workers = threads.min(n);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    return;
                }
                let r = f(i);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("scatter: job slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn spin_barrier_rendezvous_many_rounds() {
        const THREADS: usize = 4;
        const ROUNDS: u64 = 200;
        let barrier = SpinBarrier::new(THREADS);
        let counter = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for round in 0..ROUNDS {
                        counter.fetch_add(1, Ordering::SeqCst);
                        barrier.wait();
                        // After the barrier, all THREADS increments of
                        // this round must be visible.
                        let c = counter.load(Ordering::SeqCst);
                        assert!(c >= (round + 1) * THREADS as u64, "round {round}: {c}");
                        barrier.wait();
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), ROUNDS * THREADS as u64);
    }

    #[test]
    fn single_participant_barrier_never_blocks() {
        let b = SpinBarrier::new(1);
        for _ in 0..10 {
            b.wait();
        }
    }

    /// The PoolShutdown single-release invariant under the distributed
    /// barrier: a coordinator panic mid-pre-phase must release the parked
    /// workers exactly once (no hang, no unbalanced crossing) and every
    /// worker must exit its loop.
    #[test]
    fn pool_shutdown_releases_workers_on_coordinator_panic() {
        const W: usize = 3;
        let barrier = SpinBarrier::new(W + 1);
        let stop = AtomicBool::new(false);
        let exited = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..W {
                s.spawn(|| {
                    loop {
                        // Same single-crossing protocol as worker_loop:
                        // one cycle-top rendezvous, then the cycle body
                        // (empty here), then straight back to the top.
                        barrier.wait();
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                    exited.fetch_add(1, Ordering::SeqCst);
                });
            }
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _shutdown = PoolShutdown::new(&stop, &barrier);
                // One healthy cycle release, then a pre-phase panic.
                barrier.wait();
                panic!("coordinator pre-phase failure");
            }));
            assert!(result.is_err(), "the panic must propagate");
        });
        assert_eq!(exited.load(Ordering::SeqCst), W, "all workers must exit");
        assert!(stop.load(Ordering::SeqCst));
    }

    /// Mailboxes preserve publish order across parity flips and report
    /// emptiness cheaply.
    #[test]
    fn mailbox_roundtrip_preserves_order() {
        let mb: Mailbox<u32> = Mailbox::new();
        let mut batch1 = vec![1, 2, 3];
        let mut batch2 = vec![4, 5];
        mb.publish(&mut batch1);
        mb.publish(&mut batch2);
        assert!(batch1.is_empty() && batch2.is_empty());
        let mut got = Vec::new();
        mb.consume(|v| got.push(v));
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
        // Drained: a second consume sees nothing.
        mb.consume(|_| panic!("mailbox must be empty"));
    }

    /// The summary tree's merge is associative and keeps the DmaStart
    /// stream in ascending-worker order when children fold in ascending
    /// level order.
    #[test]
    fn cycle_summary_absorb_concatenates_in_worker_order() {
        let op = |pe: u32| (pe, Action::DmaStart { id: pe as u16 });
        let mut w0 = CycleSummary {
            busy: false,
            runnable: false,
            events: 1,
            arrivals: IdCounts::default(),
            dma_ops: vec![op(0)],
        };
        let mut w1 = CycleSummary {
            busy: true,
            runnable: true,
            events: 2,
            arrivals: IdCounts::default(),
            dma_ops: vec![op(8)],
        };
        let mut w2 = CycleSummary {
            busy: false,
            runnable: false,
            events: 0,
            arrivals: IdCounts::default(),
            dma_ops: vec![op(16)],
        };
        let mut w3 = CycleSummary {
            busy: false,
            runnable: false,
            events: 4,
            arrivals: IdCounts::default(),
            dma_ops: vec![op(24)],
        };
        w0.arrivals.add(0, 3);
        w2.arrivals.add(0, 2);
        w2.arrivals.add(5, 1);
        // Level 0: 0←1, 2←3. Level 1: 0←2.
        w0.absorb(&mut w1);
        w2.absorb(&mut w3);
        w0.absorb(&mut w2);
        assert!(w0.busy);
        assert!(w0.runnable, "runnable merges like busy");
        assert!(!w1.runnable, "absorb drains the child");
        assert_eq!(w0.events, 7);
        let pes: Vec<u32> = w0.dma_ops.iter().map(|&(pe, _)| pe).collect();
        assert_eq!(pes, vec![0, 8, 16, 24], "global PE order");
        assert_eq!(w0.arrivals.iter().collect::<Vec<_>>(), vec![(0, 5), (5, 1)]);
    }

    #[test]
    fn scatter_preserves_job_order_at_any_width() {
        let jobs = 23usize;
        let want: Vec<usize> = (0..jobs).map(|i| i * i).collect();
        for threads in [1usize, 2, 4, 8, 64] {
            let got = scatter(jobs, threads, |i| i * i);
            assert_eq!(got, want, "{threads} threads");
        }
        assert_eq!(scatter(0, 4, |i| i), Vec::<usize>::new());
    }
}
