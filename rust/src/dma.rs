//! Modular iDMA engine (Sec. 5.2): frontend / midend / backend.
//!
//! * **frontend** — accepts transfer descriptors (src, dst, size) from the
//!   cores (a CSR write takes `CONFIG_CYCLES`) and forwards them;
//! * **midend** — splits a transfer into sub-tasks along the SubGroup
//!   boundaries of the interleaved L1 map: the maximum contiguous run in
//!   one SubGroup is 256 words = one 1 KiB AXI4 burst (Sec. 5.4), so no
//!   further splitting is ever needed;
//! * **backends** — one per SubGroup (16 total), each owning a 512-bit
//!   AXI4 master ([`AxiPort`]) toward the memory controller. Backends
//!   bridge the system AXI and the L1 SPM: on an inbound burst completion
//!   they deposit the words into the SubGroup's banks, on outbound they
//!   source them.
//!
//! The L2 main-memory side interleaves 256 words per HBM2E channel, which
//! together with one-backend-per-SubGroup gives the conflict-free
//! backend↔channel pairing the paper engineers in Sec. 5.4.

use std::collections::VecDeque;

use crate::axi::{AxiPort, AxiTreeLatency};
use crate::config::ClusterConfig;
use crate::hbm::{Hbm, HbmConfig};
use crate::memory::L1Memory;

/// Cycles for a core to program the frontend (CSR writes: src, dst, len,
/// trigger — Fig. 9's "DMA frontend configuration cycles").
pub const CONFIG_CYCLES: u64 = 16;

/// Words per AXI burst: one SubGroup-contiguous run (256 × 32 bit = 1 KiB).
pub const BURST_WORDS: u32 = 256;

/// A software-visible transfer descriptor.
#[derive(Debug, Clone, Copy)]
pub struct DmaDescriptor {
    /// L1 start word (must lie in the interleaved region).
    pub l1_word: u32,
    /// Main-memory byte address.
    pub mem_byte: u64,
    /// Transfer length in words.
    pub words: u32,
    /// `true`: main memory → L1 (inbound); `false`: L1 → main memory.
    pub to_l1: bool,
}

#[derive(Debug, Clone, Copy)]
struct Burst {
    desc: u16,
    l1_word: u32,
    mem_byte: u64,
    words: u32,
    to_l1: bool,
    backend: u16,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum DescState {
    Registered,
    /// Frontend accepted; bursts enqueued; counting completions.
    Running { remaining: u32, ready_at: u64 },
    Done { at: u64 },
}

/// A timing decision of one DMA cycle, reported by
/// [`DmaSubsystem::step_events`] for the engine to act on. Splitting the
/// *decisions* (channel arbitration, burst issue, completions — serial
/// by nature) from the *functional word movement* (embarrassingly
/// parallel per destination Tile) is what lets the sharded engine keep
/// only the former on its coordinator.
#[derive(Debug, Clone, Copy)]
pub enum DmaEvent {
    /// A burst left its backend this cycle. The functional word movement
    /// is the caller's job: the serial engine moves the words inline
    /// ([`DmaSubsystem::step`]), the sharded engine partitions the run
    /// across its workers by destination Tile.
    Issue { l1_word: u32, words: u32, mem_byte: u64, to_l1: bool },
    /// A descriptor's last burst completed: `DmaWait`-parked PEs may
    /// wake from this cycle on.
    Retired { id: u16 },
}

struct Backend {
    port: AxiPort,
    queue: VecDeque<Burst>,
}

/// What the DMA subsystem is waiting on — the engines' idle-skip wake
/// query ([`DmaSubsystem::next_wake`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaWake {
    /// A burst sits in a backend queue: issue arbitration (and the AXI
    /// port's occupancy/stall accounting) runs every cycle, so the span
    /// is not skippable.
    Busy,
    /// Backends are drained; quiet until the earliest in-flight HBM
    /// burst completes at this cycle.
    At(u64),
    /// Nothing queued or in flight.
    Idle,
}

/// The DMA subsystem: descriptors + midend split + 16 backends + HBM.
pub struct DmaSubsystem {
    pub hbm: Hbm,
    lat: AxiTreeLatency,
    backends: Vec<Backend>,
    descs: Vec<(DmaDescriptor, DescState)>,
    inflight: Vec<Burst>,
    free_inflight: Vec<u32>,
    frontend_free: u64,
    /// Recycled burst staging buffer for the functional data movement.
    word_buf: Vec<f32>,
    /// Recycled completion-id scratch for [`DmaSubsystem::step_events`]
    /// (one retirement sweep per simulated cycle — keep it off the
    /// allocator).
    completed_scratch: Vec<u64>,
    // geometry
    interleaved_base: u32,
    num_banks: usize,
    banks_per_subgroup: usize,
    pub started: u64,
    pub completed_bursts: u64,
}

impl DmaSubsystem {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let subgroups = cfg.hierarchy.num_subgroups();
        DmaSubsystem {
            hbm: Hbm::new(HbmConfig::new(cfg.ddr, cfg.freq_mhz)),
            lat: AxiTreeLatency::default(),
            backends: (0..subgroups)
                .map(|_| Backend { port: AxiPort::new(64, 8), queue: VecDeque::new() })
                .collect(),
            descs: Vec::new(),
            inflight: Vec::new(),
            free_inflight: Vec::new(),
            frontend_free: 0,
            word_buf: Vec::new(),
            completed_scratch: Vec::new(),
            interleaved_base: cfg.seq_words_total() as u32,
            num_banks: cfg.num_banks(),
            banks_per_subgroup: cfg.banks_per_subgroup(),
            started: 0,
            completed_bursts: 0,
        }
    }

    /// Register a descriptor ahead of the run; returns its id, referenced
    /// by `Op::DmaStart`/`Op::DmaWait` in kernel traces.
    pub fn register(&mut self, d: DmaDescriptor) -> u16 {
        assert!(
            d.l1_word >= self.interleaved_base,
            "DMA targets must lie in the interleaved region"
        );
        assert_eq!(
            (d.l1_word - self.interleaved_base) % BURST_WORDS,
            0,
            "L1 start must be 256-word aligned (SubGroup run boundary)"
        );
        self.descs.push((d, DescState::Registered));
        (self.descs.len() - 1) as u16
    }

    /// SubGroup owning an interleaved word (≡ its backend index).
    fn subgroup_of(&self, word: u32) -> usize {
        ((word - self.interleaved_base) as usize % self.num_banks) / self.banks_per_subgroup
    }

    /// Frontend trigger: split via the midend and enqueue on backends.
    pub fn start(&mut self, id: u16, now: u64) {
        let (d, state) = self.descs[id as usize];
        assert!(
            matches!(state, DescState::Registered),
            "descriptor {id} started twice"
        );
        let ready_at = self.frontend_free.max(now) + CONFIG_CYCLES;
        self.frontend_free = ready_at;

        // Midend: split on 256-word SubGroup runs.
        let mut remaining = 0u32;
        let mut off = 0u32;
        while off < d.words {
            let words = BURST_WORDS.min(d.words - off);
            let l1_word = d.l1_word + off;
            let backend = self.subgroup_of(l1_word) as u16;
            self.backends[backend as usize].queue.push_back(Burst {
                desc: id,
                l1_word,
                mem_byte: d.mem_byte + off as u64 * 4,
                words,
                to_l1: d.to_l1,
                backend,
            });
            remaining += 1;
            off += words;
        }
        self.descs[id as usize].1 = DescState::Running { remaining, ready_at };
        self.started += 1;
    }

    pub fn is_done(&self, id: u16) -> bool {
        matches!(self.descs[id as usize].1, DescState::Done { .. })
    }

    pub fn done_at(&self, id: u16) -> Option<u64> {
        match self.descs[id as usize].1 {
            DescState::Done { at } => Some(at),
            _ => None,
        }
    }

    pub fn idle(&self) -> bool {
        self.descs
            .iter()
            .all(|(_, s)| matches!(s, DescState::Registered | DescState::Done { .. }))
    }

    /// When does the DMA subsystem next need a cycle? See [`DmaWake`].
    /// Conservative on purpose: any queued burst reports `Busy` even if
    /// its descriptor's `ready_at` lies in the future, because once a
    /// queue head is ready the per-cycle arbitration (including
    /// `AxiPort::note_stall` accounting on blocked cycles) must run
    /// every cycle to stay bit-identical with the stepped engine.
    pub fn next_wake(&self) -> DmaWake {
        if self.backends.iter().any(|b| !b.queue.is_empty()) {
            return DmaWake::Busy;
        }
        match self.hbm.next_completion_at() {
            Some(at) => DmaWake::At(at),
            None => DmaWake::Idle,
        }
    }

    /// Advance the timing model one cycle: retire HBM completions and
    /// issue new bursts from the backend queues, reporting every decision
    /// through `sink` ([`DmaEvent`]). This is the **serial core** of a DMA
    /// cycle — frontend state, backend arbitration, AXI occupancy and the
    /// HBM channel model; the functional word movement of issued bursts is
    /// delegated to the caller, at the exact point in the cycle the serial
    /// engine has always moved data.
    pub fn step_events(&mut self, now: u64, mut sink: impl FnMut(DmaEvent)) {
        // 1. Completions coming back from the memory controller.
        let mut done_ids = std::mem::take(&mut self.completed_scratch);
        done_ids.clear();
        self.hbm.take_completed(now, |bid| done_ids.push(bid));
        for &bid in &done_ids {
            let b = self.inflight[bid as usize];
            self.free_inflight.push(bid as u32);
            self.backends[b.backend as usize].port.retire();
            self.completed_bursts += 1;
            if let DescState::Running { remaining, .. } = &mut self.descs[b.desc as usize].1 {
                *remaining -= 1;
                if *remaining == 0 {
                    self.descs[b.desc as usize].1 = DescState::Done { at: now };
                    sink(DmaEvent::Retired { id: b.desc });
                }
            }
        }
        self.completed_scratch = done_ids;

        // 2. Issue from backend queues (≤1 burst per backend per cycle,
        //    bounded by the 512-bit port's beat rate and outstanding cap).
        for be_idx in 0..self.backends.len() {
            let ready = match self.backends[be_idx].queue.front() {
                Some(b) => match self.descs[b.desc as usize].1 {
                    DescState::Running { ready_at, .. } => ready_at <= now,
                    _ => false,
                },
                None => false,
            };
            if !ready {
                continue;
            }
            if !self.backends[be_idx].port.can_issue(now) {
                self.backends[be_idx].port.note_stall();
                continue;
            }
            let b = self.backends[be_idx].queue.pop_front().unwrap();
            let bytes = b.words as u64 * 4;
            self.backends[be_idx].port.issue(now, bytes);
            sink(DmaEvent::Issue {
                l1_word: b.l1_word,
                words: b.words,
                mem_byte: b.mem_byte,
                to_l1: b.to_l1,
            });
            let bid = match self.free_inflight.pop() {
                Some(i) => {
                    self.inflight[i as usize] = b;
                    i as u64
                }
                None => {
                    self.inflight.push(b);
                    (self.inflight.len() - 1) as u64
                }
            };
            self.hbm
                .submit(now + self.lat.backend_to_mc() as u64, b.mem_byte, bytes, bid);
        }
    }

    /// Advance one cycle with the functional data movement inline — the
    /// serial reference engine's DMA step (and the DMA-only harnesses').
    ///
    /// Takes `&L1Memory` (word access through the per-Tile slice locks),
    /// and `&mut L1Memory` call sites coerce. Data moves at burst issue
    /// (both directions) in one shot — the timing of visibility is
    /// guarded by DmaWait in the traces. Whole-burst staging through
    /// `word_buf` lets the L1 side use run-grouped Tile locking instead
    /// of per-word locks.
    pub fn step(&mut self, now: u64, l1: &L1Memory) {
        let mut words = std::mem::take(&mut self.word_buf);
        self.step_events(now, |ev| {
            if let DmaEvent::Issue { l1_word, words: n, mem_byte, to_l1 } = ev {
                if to_l1 {
                    words.clear();
                    words.extend((0..n).map(|w| hbm_image_read(mem_byte + w as u64 * 4)));
                    l1.write_run_shared(l1_word, &words);
                } else {
                    l1.read_run_shared(l1_word, n as usize, &mut words);
                    for (w, &v) in words.iter().enumerate() {
                        hbm_image_write(mem_byte + w as u64 * 4, v);
                    }
                }
            }
        });
        self.word_buf = words;
    }

    /// Ids of descriptors that already retired — seeds the sharded
    /// engine's per-worker done-mirrors when a run starts on a cluster
    /// that was stepped before (mixed-engine stepping).
    pub fn done_ids(&self) -> Vec<u16> {
        self.descs
            .iter()
            .enumerate()
            .filter(|(_, (_, s))| matches!(s, DescState::Done { .. }))
            .map(|(i, _)| i as u16)
            .collect()
    }

    /// Bytes moved so far (both directions).
    pub fn total_bytes(&self) -> u64 {
        self.backends.iter().map(|b| b.port.bytes).sum()
    }
}

// ---------------------------------------------------------------------
// Main-memory functional image. The timing model (Hbm) and the contents
// live separately: the image is a process-global sparse store so DMA
// harnesses and the cluster can stage inputs / read back outputs.
// ---------------------------------------------------------------------

use std::cell::RefCell;
use std::collections::HashMap;

thread_local! {
    static HBM_IMAGE: RefCell<HashMap<u64, f32>> = RefCell::new(HashMap::new());
}

/// Write a word into the functional main-memory image.
pub fn hbm_image_write(byte_addr: u64, v: f32) {
    HBM_IMAGE.with(|m| {
        m.borrow_mut().insert(byte_addr, v);
    });
}

/// Read a word from the functional main-memory image (0.0 if untouched).
pub fn hbm_image_read(byte_addr: u64) -> f32 {
    HBM_IMAGE.with(|m| m.borrow().get(&byte_addr).copied().unwrap_or(0.0))
}

/// Clear the image (between experiments).
pub fn hbm_image_clear() {
    HBM_IMAGE.with(|m| m.borrow_mut().clear());
}

/// Stage a slice into the image at `byte_addr`.
pub fn hbm_image_stage(byte_addr: u64, data: &[f32]) {
    HBM_IMAGE.with(|m| {
        let mut m = m.borrow_mut();
        for (i, &v) in data.iter().enumerate() {
            m.insert(byte_addr + i as u64 * 4, v);
        }
    });
}

/// Read a slice back from the image.
pub fn hbm_image_fetch(byte_addr: u64, words: usize) -> Vec<f32> {
    HBM_IMAGE.with(|m| {
        let m = m.borrow();
        (0..words)
            .map(|i| m.get(&(byte_addr + i as u64 * 4)).copied().unwrap_or(0.0))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn run_until_idle(dma: &mut DmaSubsystem, l1: &mut L1Memory, max: u64) -> u64 {
        for now in 0..max {
            dma.step(now, l1);
            if dma.idle() && dma.hbm.pending() == 0 {
                return now;
            }
        }
        panic!("DMA did not finish in {max} cycles");
    }

    #[test]
    fn inbound_transfer_lands_in_l1() {
        hbm_image_clear();
        let cfg = ClusterConfig::terapool(9);
        let mut l1 = L1Memory::new(&cfg);
        let mut dma = DmaSubsystem::new(&cfg);
        let base = l1.map.interleaved_base();
        let data: Vec<f32> = (0..1024).map(|i| i as f32).collect();
        hbm_image_stage(0x1000, &data);
        let id = dma.register(DmaDescriptor {
            l1_word: base,
            mem_byte: 0x1000,
            words: 1024,
            to_l1: true,
        });
        dma.start(id, 0);
        run_until_idle(&mut dma, &mut l1, 10_000);
        assert!(dma.is_done(id));
        assert_eq!(l1.read_slice(base, 1024), data);
    }

    #[test]
    fn outbound_transfer_reaches_image() {
        hbm_image_clear();
        let cfg = ClusterConfig::terapool(9);
        let mut l1 = L1Memory::new(&cfg);
        let mut dma = DmaSubsystem::new(&cfg);
        let base = l1.map.interleaved_base();
        let data: Vec<f32> = (0..512).map(|i| (i * 3) as f32).collect();
        l1.write_slice(base, &data);
        let id = dma.register(DmaDescriptor {
            l1_word: base,
            mem_byte: 0x8000,
            words: 512,
            to_l1: false,
        });
        dma.start(id, 0);
        run_until_idle(&mut dma, &mut l1, 10_000);
        assert_eq!(hbm_image_fetch(0x8000, 512), data);
    }

    #[test]
    fn midend_splits_on_subgroup_runs() {
        hbm_image_clear();
        let cfg = ClusterConfig::terapool(9);
        let mut l1 = L1Memory::new(&cfg);
        let mut dma = DmaSubsystem::new(&cfg);
        let base = l1.map.interleaved_base();
        // 4096 words = 16 bursts, one per SubGroup backend.
        let id = dma.register(DmaDescriptor {
            l1_word: base,
            mem_byte: 0,
            words: 4096,
            to_l1: true,
        });
        dma.start(id, 0);
        let queued: usize = dma.backends.iter().map(|b| b.queue.len()).sum();
        assert_eq!(queued, 16);
        for b in &dma.backends {
            assert_eq!(b.queue.len(), 1, "one run per SubGroup");
        }
        run_until_idle(&mut dma, &mut l1, 10_000);
        assert_eq!(dma.completed_bursts, 16);
    }

    #[test]
    fn config_cycles_delay_start() {
        hbm_image_clear();
        let cfg = ClusterConfig::terapool(9);
        let mut l1 = L1Memory::new(&cfg);
        let mut dma = DmaSubsystem::new(&cfg);
        let base = l1.map.interleaved_base();
        let id = dma.register(DmaDescriptor { l1_word: base, mem_byte: 0, words: 256, to_l1: true });
        dma.start(id, 0);
        let end = run_until_idle(&mut dma, &mut l1, 10_000);
        assert!(end >= CONFIG_CYCLES, "transfer can't beat frontend config");
    }

    #[test]
    fn full_l1_transfer_bandwidth_near_peak_at_900mhz() {
        hbm_image_clear();
        let cfg = ClusterConfig::terapool(11); // 910 MHz — paper rounds to 900
        let mut l1 = L1Memory::new(&cfg);
        let mut dma = DmaSubsystem::new(&cfg);
        let base = l1.map.interleaved_base();
        let words = (cfg.l1_words() as u32 - base).min(3 * 1024 * 1024 / 4);
        let id = dma.register(DmaDescriptor { l1_word: base, mem_byte: 0, words, to_l1: true });
        dma.start(id, 0);
        let end = run_until_idle(&mut dma, &mut l1, 1_000_000);
        let gbps = dma.hbm.achieved_gbps(end);
        let peak = cfg.ddr.peak_gbps_total();
        assert!(
            gbps > 0.85 * peak,
            "achieved {gbps:.0} GB/s vs peak {peak:.0} GB/s"
        );
    }
}
