//! Reporting: plain-text table rendering shared by the CLI and the
//! benches, plus the structured [`RunReport`] every `Session` run returns
//! — one object carrying the config fingerprint, `RunStats`, per-class
//! interconnect numbers and the validation [`Verdict`], serialized
//! through the hand-rolled [`Json`] writer/parser (the offline build has
//! no serde). `main.rs --json`, the benches, goldens and CI all consume
//! this object instead of re-deriving tables.

use crate::cluster::RunStats;
use crate::errors::Result;
use crate::{bail, ensure, err};

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

// ---------------------------------------------------------------------
// Minimal JSON value — writer and parser. Just enough for RunReport and
// the bench trend files: null/bool/finite numbers/strings (with escape
// handling)/arrays/objects. Non-finite floats serialize as null and
// parse back as NaN, keeping emit → parse total.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s, 0);
        s
    }

    fn render_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) if !x.is_finite() => out.push_str("null"),
            // Rust's shortest-roundtrip float Display: parse() recovers
            // the exact bits, which the report round-trip test relies on.
            Json::Num(x) => out.push_str(&format!("{x}")),
            Json::Str(s) => render_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    it.render_into(out, indent);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in pairs.iter().enumerate() {
                    out.push_str(&pad);
                    render_str(k, out);
                    out.push_str(": ");
                    v.render_into(out, indent + 1);
                    out.push_str(if i + 1 < pairs.len() { ",\n" } else { "\n" });
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }

    pub fn parse(text: &str) -> Result<Json> {
        // Zero-copy cursor over the input bytes: every structural
        // character in JSON is ASCII, so byte positions at delimiters
        // are always char boundaries and string content can be sliced
        // straight out of `text` (no per-char Vec of the whole doc).
        let mut p = Parser { s: text, pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.s.len(), "trailing junk at byte {}", p.pos);
        Ok(v)
    }

    /// Object field access (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Null => Some(f64::NAN), // non-finite round-trip
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed field lookups with path-bearing errors (for from_json).
    pub fn field_str(&self, key: &str) -> Result<String> {
        self.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| err!("missing/ill-typed string field {key:?}"))
    }
    pub fn field_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| err!("missing/ill-typed integer field {key:?}"))
    }
    pub fn field_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| err!("missing/ill-typed number field {key:?}"))
    }
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Byte cursor over the source text. `pos` is a byte index that only
/// ever stops on ASCII structural characters (or the start of a UTF-8
/// sequence inside a string, which is copied out as a whole `&str`
/// slice), so all slicing below stays on char boundaries.
struct Parser<'a> {
    s: &'a str,
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.s.as_bytes().get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        let b = self.s.as_bytes();
        while self.pos < b.len() && b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        let Some(c) = self.peek() else { bail!("unexpected end of JSON") };
        match c {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => {
                self.pos += 1;
                let mut items = Vec::new();
                loop {
                    self.skip_ws();
                    if self.peek() == Some(b']') {
                        self.pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    if !items.is_empty() {
                        ensure!(
                            self.peek() == Some(b','),
                            "expected ',' in array at byte {}",
                            self.pos
                        );
                        self.pos += 1;
                    }
                    items.push(self.value()?);
                }
            }
            b'{' => {
                self.pos += 1;
                let mut pairs = Vec::new();
                loop {
                    self.skip_ws();
                    if self.peek() == Some(b'}') {
                        self.pos += 1;
                        return Ok(Json::Obj(pairs));
                    }
                    if !pairs.is_empty() {
                        ensure!(
                            self.peek() == Some(b','),
                            "expected ',' in object at byte {}",
                            self.pos
                        );
                        self.pos += 1;
                        self.skip_ws();
                    }
                    let k = self.string()?;
                    self.skip_ws();
                    ensure!(self.peek() == Some(b':'), "expected ':' after key {k:?}");
                    self.pos += 1;
                    pairs.push((k, self.value()?));
                }
            }
            _ => {
                let start = self.pos;
                let b = self.s.as_bytes();
                while self.pos < b.len() && b"+-.eE0123456789".contains(&b[self.pos]) {
                    self.pos += 1;
                }
                let tok = &self.s[start..self.pos];
                tok.parse::<f64>()
                    .map(Json::Num)
                    .map_err(|_| err!("invalid JSON number {tok:?} at byte {start}"))
            }
        }
    }

    fn lit(&mut self, lit: &str, v: Json) -> Result<Json> {
        let end = self.pos + lit.len();
        ensure!(
            end <= self.s.len() && &self.s.as_bytes()[self.pos..end] == lit.as_bytes(),
            "invalid JSON literal at byte {}",
            self.pos
        );
        self.pos = end;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        ensure!(self.peek() == Some(b'"'), "expected string at byte {}", self.pos);
        self.pos += 1;
        let b = self.s.as_bytes();
        let mut s = String::new();
        let mut seg = self.pos; // start of the current unescaped run
        while self.pos < b.len() {
            match b[self.pos] {
                b'"' => {
                    s.push_str(&self.s[seg..self.pos]);
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    s.push_str(&self.s[seg..self.pos]);
                    self.pos += 1;
                    let Some(e) = self.peek() else { bail!("dangling escape") };
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'u' => {
                            // str::get also rejects a slice that would
                            // split a multi-byte char (bad escape body).
                            let hex = self
                                .s
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| err!("truncated \\u escape"))?;
                            self.pos += 4;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| err!("bad \\u escape {hex:?}"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("unsupported escape \\{}", other as char),
                    }
                    seg = self.pos;
                }
                // Multi-byte UTF-8 and plain ASCII both ride along in
                // the current run; advance to the next char start.
                _ => {
                    self.pos += 1;
                    while self.pos < b.len() && b[self.pos] & 0xC0 == 0x80 {
                        self.pos += 1;
                    }
                }
            }
        }
        bail!("unterminated string")
    }
}

// ---------------------------------------------------------------------
// Verdict + RunReport: the structured result of one Session run.
// ---------------------------------------------------------------------

/// Functional-validation outcome of a run, produced by
/// `Workload::check` against the kernel's host reference.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// Output matched the host reference; `detail` records what/how.
    Passed { detail: String },
    /// Output diverged (or could not be read); the run is wrong.
    Failed { reason: String },
    /// No check ran (checking disabled, or no reference at this size).
    NotChecked,
}

impl Verdict {
    pub fn is_failure(&self) -> bool {
        matches!(self, Verdict::Failed { .. })
    }

    pub fn status(&self) -> &'static str {
        match self {
            Verdict::Passed { .. } => "passed",
            Verdict::Failed { .. } => "failed",
            Verdict::NotChecked => "not_checked",
        }
    }

    pub fn detail(&self) -> &str {
        match self {
            Verdict::Passed { detail } => detail,
            Verdict::Failed { reason } => reason,
            Verdict::NotChecked => "",
        }
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("status".into(), Json::Str(self.status().into())),
            ("detail".into(), Json::Str(self.detail().into())),
        ])
    }

    fn from_json(j: &Json) -> Result<Verdict> {
        let detail = j.field_str("detail")?;
        Ok(match j.field_str("status")?.as_str() {
            "passed" => Verdict::Passed { detail },
            "failed" => Verdict::Failed { reason: detail },
            "not_checked" => Verdict::NotChecked,
            other => bail!("unknown verdict status {other:?}"),
        })
    }
}

/// Provenance of an analytic estimate (`Session::estimating`): which
/// scale anchored the calibration, how long the anchor run took, how far
/// the uncalibrated model sat from that anchor, and the error bound the
/// estimate is stated to (what `tools/report_diff.py --rtol` should be
/// asked to hold it to against a cycle-accurate sweep).
#[derive(Debug, Clone, PartialEq)]
pub struct EstimateInfo {
    /// Scale tag of the calibration run, e.g. `fast`.
    pub calibration_scale: String,
    /// Measured cycles of the calibration run.
    pub calibration_cycles: u64,
    /// |model − measured| / measured cycles at the calibration scale —
    /// the residual the ratio calibration cancelled.
    pub model_residual: f64,
    /// Relative tolerance the estimate is stated to (EXPERIMENTS.md).
    pub stated_rtol: f64,
}

impl EstimateInfo {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("calibration_scale".into(), Json::Str(self.calibration_scale.clone())),
            ("calibration_cycles".into(), Json::Num(self.calibration_cycles as f64)),
            ("model_residual".into(), Json::Num(self.model_residual)),
            ("stated_rtol".into(), Json::Num(self.stated_rtol)),
        ])
    }

    fn from_json(j: &Json) -> Result<EstimateInfo> {
        Ok(EstimateInfo {
            calibration_scale: j.field_str("calibration_scale")?,
            calibration_cycles: j.field_u64("calibration_cycles")?,
            model_residual: j.field_f64("model_residual")?,
            stated_rtol: j.field_f64("stated_rtol")?,
        })
    }
}

/// One cluster's slice of a multi-cluster system run.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemClusterInfo {
    pub name: String,
    pub num_pes: usize,
    /// Compute cycles of this cluster's chunk (its own clock; the sum
    /// over its slices when the run is pipelined).
    pub cycles: u64,
    pub instructions: u64,
    pub flops: u64,
    /// Per-slice compute windows `[start, end)` on the *system*
    /// timeline, in slice order. One window per slice (a single window
    /// for a phase-serial run).
    pub slice_windows: Vec<(u64, u64)>,
}

/// One inter-cluster link's traffic during a system run.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemLinkInfo {
    /// Display name, e.g. `c0<->c1`.
    pub name: String,
    /// Words moved across the link (both directions).
    pub words: u64,
    /// Cycles the link spent transmitting.
    pub busy_cycles: u64,
}

/// The system-level section of a multi-cluster run report: topology
/// identity, per-cluster and per-link breakdowns, shared-bus traffic and
/// the stage/compute/merge timeline split — what `fig-scaleout` plots.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemInfo {
    /// Topology name (document name or `Topology::split` tag).
    pub topology: String,
    pub clusters: Vec<SystemClusterInfo>,
    pub links: Vec<SystemLinkInfo>,
    /// Words moved over the shared main-memory bus (staging + merge).
    pub bus_words: u64,
    /// Cycles the shared bus spent granting words.
    pub bus_busy_cycles: u64,
    /// System cycles until every cluster could start compute (staging +
    /// halo broadcasts + the start barrier).
    pub stage_cycles: u64,
    /// System cycles from compute start to the last cluster finishing.
    pub compute_cycles: u64,
    /// System cycles from the last compute finish to the last merge
    /// word landing in the memory node.
    pub merge_cycles: u64,
    /// Total words moved over inter-cluster links.
    pub link_words: u64,
    /// Band slices per cluster (1 = phase-serial timeline).
    pub slices: u64,
    /// Bus grant cycles spent while **no** cluster slice was computing —
    /// the data movement the timeline actually pays for.
    pub exposed_bus_cycles: u64,
    /// Bus grant cycles overlapped with at least one compute window.
    /// `exposed + hidden == bus_busy_cycles` always.
    pub hidden_bus_cycles: u64,
}

/// Optional integer field: `default` when the key is absent (older
/// document revisions), a typed error when present but ill-typed.
fn opt_u64(j: &Json, key: &str, default: u64) -> Result<u64> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| err!("ill-typed integer field {key:?}")),
    }
}

impl SystemInfo {
    fn to_json(&self) -> Json {
        let clusters = self
            .clusters
            .iter()
            .map(|c| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(c.name.clone())),
                    ("num_pes".into(), Json::Num(c.num_pes as f64)),
                    ("cycles".into(), Json::Num(c.cycles as f64)),
                    ("instructions".into(), Json::Num(c.instructions as f64)),
                    ("flops".into(), Json::Num(c.flops as f64)),
                    (
                        "slice_windows".into(),
                        Json::Arr(
                            c.slice_windows
                                .iter()
                                .map(|&(s, e)| {
                                    Json::Arr(vec![Json::Num(s as f64), Json::Num(e as f64)])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let links = self
            .links
            .iter()
            .map(|l| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(l.name.clone())),
                    ("words".into(), Json::Num(l.words as f64)),
                    ("busy_cycles".into(), Json::Num(l.busy_cycles as f64)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("topology".into(), Json::Str(self.topology.clone())),
            ("clusters".into(), Json::Arr(clusters)),
            ("links".into(), Json::Arr(links)),
            ("bus_words".into(), Json::Num(self.bus_words as f64)),
            ("bus_busy_cycles".into(), Json::Num(self.bus_busy_cycles as f64)),
            ("stage_cycles".into(), Json::Num(self.stage_cycles as f64)),
            ("compute_cycles".into(), Json::Num(self.compute_cycles as f64)),
            ("merge_cycles".into(), Json::Num(self.merge_cycles as f64)),
            ("link_words".into(), Json::Num(self.link_words as f64)),
            ("slices".into(), Json::Num(self.slices as f64)),
            ("exposed_bus_cycles".into(), Json::Num(self.exposed_bus_cycles as f64)),
            ("hidden_bus_cycles".into(), Json::Num(self.hidden_bus_cycles as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<SystemInfo> {
        let clusters = j
            .get("clusters")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("missing system.clusters array"))?
            .iter()
            .map(|c| {
                // `slice_windows` is absent in pre-pipeline documents —
                // default to no recorded windows.
                let slice_windows = match c.get("slice_windows").and_then(Json::as_arr) {
                    None => Vec::new(),
                    Some(ws) => ws
                        .iter()
                        .map(|w| {
                            let pair = w
                                .as_arr()
                                .filter(|p| p.len() == 2)
                                .ok_or_else(|| err!("ill-formed slice_windows entry"))?;
                            let s = pair[0]
                                .as_u64()
                                .ok_or_else(|| err!("ill-typed slice window start"))?;
                            let e = pair[1]
                                .as_u64()
                                .ok_or_else(|| err!("ill-typed slice window end"))?;
                            Ok((s, e))
                        })
                        .collect::<Result<Vec<_>>>()?,
                };
                Ok(SystemClusterInfo {
                    name: c.field_str("name")?,
                    num_pes: c.field_u64("num_pes")? as usize,
                    cycles: c.field_u64("cycles")?,
                    instructions: c.field_u64("instructions")?,
                    flops: c.field_u64("flops")?,
                    slice_windows,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let links = j
            .get("links")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("missing system.links array"))?
            .iter()
            .map(|l| {
                Ok(SystemLinkInfo {
                    name: l.field_str("name")?,
                    words: l.field_u64("words")?,
                    busy_cycles: l.field_u64("busy_cycles")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(SystemInfo {
            topology: j.field_str("topology")?,
            clusters,
            links,
            bus_words: j.field_u64("bus_words")?,
            bus_busy_cycles: j.field_u64("bus_busy_cycles")?,
            stage_cycles: j.field_u64("stage_cycles")?,
            compute_cycles: j.field_u64("compute_cycles")?,
            merge_cycles: j.field_u64("merge_cycles")?,
            link_words: j.field_u64("link_words")?,
            // Overlap counters are absent in pre-pipeline documents:
            // those runs were phase-serial single-slice timelines.
            slices: opt_u64(j, "slices", 1)?,
            exposed_bus_cycles: opt_u64(j, "exposed_bus_cycles", 0)?,
            hidden_bus_cycles: opt_u64(j, "hidden_bus_cycles", 0)?,
        })
    }
}

/// Everything one `Session` run produces: identity (workload instance +
/// registry kind + config name + config fingerprint + scale), engine
/// choice, the full [`RunStats`] (including per-class AMAT / request
/// histograms), HBML traffic, and the validation verdict. `PartialEq`
/// backs the batch-vs-sequential bit-identity tests.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    /// Staged instance name, e.g. `axpy-n262144`.
    pub workload: String,
    /// Registry kind, e.g. `axpy`.
    pub kind: String,
    /// Cluster config name, e.g. `terapool-1-3-5-9`.
    pub config: String,
    /// `ClusterConfig::fingerprint()` of the exact config simulated.
    pub fingerprint: String,
    /// `full` or `fast`.
    pub scale: String,
    /// Engine threads the cluster sim ran with (1 = serial reference).
    pub engine_threads: usize,
    pub max_cycles: u64,
    pub stats: RunStats,
    /// HBML bytes moved (None when the run had no DMA subsystem).
    pub dma_bytes: Option<u64>,
    pub verdict: Verdict,
    /// Calibration provenance when the stats came from the analytic
    /// fast path rather than a cycle-accurate run.
    pub estimate: Option<EstimateInfo>,
    /// Per-cluster/per-link breakdown when the run was a multi-cluster
    /// system run (`Session::system`); `None` for single-cluster runs.
    /// Absent in pre-scale-out documents, which still parse.
    pub system: Option<SystemInfo>,
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        let s = &self.stats;
        let stats = Json::Obj(vec![
            ("cycles".into(), Json::Num(s.cycles as f64)),
            ("instructions".into(), Json::Num(s.instructions as f64)),
            ("flops".into(), Json::Num(s.flops as f64)),
            ("num_pes".into(), Json::Num(s.num_pes as f64)),
            ("freq_mhz".into(), Json::Num(s.freq_mhz)),
            ("stall_raw".into(), Json::Num(s.stall_raw as f64)),
            ("stall_lsu".into(), Json::Num(s.stall_lsu as f64)),
            ("stall_ctrl".into(), Json::Num(s.stall_ctrl as f64)),
            ("stall_synch".into(), Json::Num(s.stall_synch as f64)),
            ("loads".into(), Json::Num(s.loads as f64)),
            ("stores".into(), Json::Num(s.stores as f64)),
            ("atomics".into(), Json::Num(s.atomics as f64)),
            ("amat".into(), Json::Num(s.amat)),
            (
                "amat_per_class".into(),
                Json::Arr(s.amat_per_class.iter().map(|&x| Json::Num(x)).collect()),
            ),
            (
                "reqs_per_class".into(),
                Json::Arr(s.reqs_per_class.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
            (
                "burst_reqs_per_class".into(),
                Json::Arr(
                    s.burst_reqs_per_class.iter().map(|&x| Json::Num(x as f64)).collect(),
                ),
            ),
            (
                "burst_words_per_class".into(),
                Json::Arr(
                    s.burst_words_per_class.iter().map(|&x| Json::Num(x as f64)).collect(),
                ),
            ),
            ("ipc".into(), Json::Num(s.ipc())),
            ("gflops".into(), Json::Num(s.gflops())),
        ]);
        Json::Obj(vec![
            ("workload".into(), Json::Str(self.workload.clone())),
            ("kind".into(), Json::Str(self.kind.clone())),
            ("config".into(), Json::Str(self.config.clone())),
            ("fingerprint".into(), Json::Str(self.fingerprint.clone())),
            ("scale".into(), Json::Str(self.scale.clone())),
            ("engine_threads".into(), Json::Num(self.engine_threads as f64)),
            ("max_cycles".into(), Json::Num(self.max_cycles as f64)),
            ("stats".into(), stats),
            (
                "dma_bytes".into(),
                match self.dma_bytes {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            ("verdict".into(), self.verdict.to_json()),
            (
                "estimate".into(),
                match &self.estimate {
                    Some(e) => e.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "system".into(),
                match &self.system {
                    Some(s) => s.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunReport> {
        let sj = j.get("stats").ok_or_else(|| err!("missing stats object"))?;
        let arr4 = |key: &str| -> Result<[f64; 4]> {
            let a = sj
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| err!("missing/ill-typed array field {key:?}"))?;
            ensure!(a.len() == 4, "{key} must have 4 entries, got {}", a.len());
            let mut out = [0.0; 4];
            for (o, v) in out.iter_mut().zip(a) {
                *o = v.as_f64().ok_or_else(|| err!("non-number in {key}"))?;
            }
            Ok(out)
        };
        // u64 counter arrays; absent fields (pre-burst documents) read
        // as zeros so old reports stay parseable.
        let arr4u = |key: &str| -> Result<[u64; 4]> {
            if sj.get(key).is_none() {
                return Ok([0; 4]);
            }
            let a = arr4(key)?;
            Ok([a[0] as u64, a[1] as u64, a[2] as u64, a[3] as u64])
        };
        let amat_per_class = arr4("amat_per_class")?;
        let rq = arr4("reqs_per_class")?;
        let burst_reqs_per_class = arr4u("burst_reqs_per_class")?;
        let burst_words_per_class = arr4u("burst_words_per_class")?;
        let stats = RunStats {
            cycles: sj.field_u64("cycles")?,
            instructions: sj.field_u64("instructions")?,
            flops: sj.field_u64("flops")?,
            num_pes: sj.field_u64("num_pes")? as usize,
            freq_mhz: sj.field_f64("freq_mhz")?,
            stall_raw: sj.field_u64("stall_raw")?,
            stall_lsu: sj.field_u64("stall_lsu")?,
            stall_ctrl: sj.field_u64("stall_ctrl")?,
            stall_synch: sj.field_u64("stall_synch")?,
            loads: sj.field_u64("loads")?,
            stores: sj.field_u64("stores")?,
            atomics: sj.field_u64("atomics")?,
            amat: sj.field_f64("amat")?,
            amat_per_class,
            reqs_per_class: [rq[0] as u64, rq[1] as u64, rq[2] as u64, rq[3] as u64],
            burst_reqs_per_class,
            burst_words_per_class,
        };
        Ok(RunReport {
            workload: j.field_str("workload")?,
            kind: j.field_str("kind")?,
            config: j.field_str("config")?,
            fingerprint: j.field_str("fingerprint")?,
            scale: j.field_str("scale")?,
            engine_threads: j.field_u64("engine_threads")? as usize,
            max_cycles: j.field_u64("max_cycles")?,
            stats,
            dma_bytes: match j.get("dma_bytes") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_u64().ok_or_else(|| err!("ill-typed dma_bytes"))?),
            },
            verdict: Verdict::from_json(
                j.get("verdict").ok_or_else(|| err!("missing verdict"))?,
            )?,
            estimate: match j.get("estimate") {
                Some(Json::Null) | None => None,
                Some(v) => Some(EstimateInfo::from_json(v)?),
            },
            // Absent in pre-scale-out documents: parses as None.
            system: match j.get("system") {
                Some(Json::Null) | None => None,
                Some(v) => Some(SystemInfo::from_json(v)?),
            },
        })
    }
}

/// Serialize a report batch as the `terapool-runreport-v1` document the
/// CLI's `--json` flag writes and CI uploads.
pub fn reports_to_json(reports: &[RunReport]) -> String {
    let doc = Json::Obj(vec![
        ("schema".into(), Json::Str("terapool-runreport-v1".into())),
        ("reports".into(), Json::Arr(reports.iter().map(RunReport::to_json).collect())),
    ]);
    let mut s = doc.render();
    s.push('\n');
    s
}

/// Parse a `terapool-runreport-v1` document back into reports.
pub fn reports_from_json(text: &str) -> Result<Vec<RunReport>> {
    let doc = Json::parse(text)?;
    ensure!(
        doc.get("schema").and_then(Json::as_str) == Some("terapool-runreport-v1"),
        "not a terapool-runreport-v1 document"
    );
    doc.get("reports")
        .and_then(Json::as_arr)
        .ok_or_else(|| err!("missing reports array"))?
        .iter()
        .map(RunReport::from_json)
        .collect()
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}
pub fn int(x: u64) -> String {
    format!("{x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "metric"]);
        t.row(vec!["x".into(), f2(1.5)]);
        t.row(vec!["longer".into(), pct(0.123)]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("12.3%"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn json_value_round_trips() {
        let v = Json::Obj(vec![
            ("s".into(), Json::Str("he \"llo\"\nworld \\".into())),
            ("n".into(), Json::Num(1.25e-3)),
            ("big".into(), Json::Num(2_000_000_000.0)),
            ("nan".into(), Json::Num(f64::NAN)),
            ("b".into(), Json::Bool(true)),
            ("a".into(), Json::Arr(vec![Json::Null, Json::Num(-7.0)])),
        ]);
        let r = Json::parse(&v.render()).unwrap();
        assert_eq!(r.field_str("s").unwrap(), "he \"llo\"\nworld \\");
        assert_eq!(r.field_f64("n").unwrap(), 1.25e-3);
        assert_eq!(r.field_u64("big").unwrap(), 2_000_000_000);
        assert!(r.field_f64("nan").unwrap().is_nan()); // null ↔ NaN
        assert_eq!(r.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn json_parser_rejects_junk() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err()); // trailing comma → value error
        assert!(Json::parse("{\"a\": 1} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn json_parses_multibyte_strings() {
        let v = Json::Obj(vec![("s".into(), Json::Str("héllo → wörld ✓".into()))]);
        let r = Json::parse(&v.render()).unwrap();
        assert_eq!(r.field_str("s").unwrap(), "héllo → wörld ✓");
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        assert!(Json::parse("\"abc\\u12\"").is_err()); // truncated escape
    }

    #[test]
    fn run_report_burst_fields_round_trip_and_default() {
        let rep = RunReport {
            workload: "axpy-n1024".into(),
            kind: "axpy".into(),
            config: "tiny".into(),
            fingerprint: "abcd".into(),
            scale: "fast".into(),
            engine_threads: 1,
            max_cycles: 1000,
            stats: RunStats {
                cycles: 10,
                instructions: 20,
                flops: 30,
                num_pes: 4,
                freq_mhz: 500.0,
                stall_raw: 1,
                stall_lsu: 2,
                stall_ctrl: 3,
                stall_synch: 4,
                loads: 5,
                stores: 6,
                atomics: 7,
                amat: 1.5,
                amat_per_class: [1.0, 2.0, 3.0, 4.0],
                reqs_per_class: [8, 0, 0, 1],
                burst_reqs_per_class: [2, 0, 0, 0],
                burst_words_per_class: [8, 0, 0, 0],
            },
            dma_bytes: None,
            verdict: Verdict::NotChecked,
            estimate: None,
            system: None,
        };
        assert_eq!(RunReport::from_json(&rep.to_json()).unwrap(), rep);
        // Pre-burst documents (no burst arrays) parse with zeroed
        // counters instead of failing.
        let Json::Obj(mut pairs) = rep.to_json() else { unreachable!() };
        for (k, v) in pairs.iter_mut() {
            if k == "stats" {
                if let Json::Obj(sp) = v {
                    sp.retain(|(sk, _)| !sk.starts_with("burst_"));
                }
            }
        }
        // Pre-scale-out documents also lack the `system` field.
        pairs.retain(|(k, _)| k != "system");
        let old = RunReport::from_json(&Json::Obj(pairs)).unwrap();
        assert_eq!(old.stats.burst_reqs_per_class, [0; 4]);
        assert_eq!(old.stats.burst_words_per_class, [0; 4]);
        assert_eq!(old.system, None);
    }

    #[test]
    fn system_info_round_trips() {
        let rep = SystemInfo {
            topology: "quad".into(),
            clusters: vec![SystemClusterInfo {
                name: "c0".into(),
                num_pes: 256,
                cycles: 1000,
                instructions: 2000,
                flops: 3000,
                slice_windows: vec![(300, 800), (850, 1350)],
            }],
            links: vec![SystemLinkInfo { name: "c0<->c1".into(), words: 64, busy_cycles: 8 }],
            bus_words: 4096,
            bus_busy_cycles: 256,
            stage_cycles: 300,
            compute_cycles: 900,
            merge_cycles: 120,
            link_words: 64,
            slices: 2,
            exposed_bus_cycles: 100,
            hidden_bus_cycles: 156,
        };
        assert_eq!(SystemInfo::from_json(&rep.to_json()).unwrap(), rep);
    }

    #[test]
    fn system_info_overlap_fields_default_when_absent() {
        // Pre-pipeline documents carry no slices/exposed/hidden counters
        // and no per-slice windows: parse them as a single-slice
        // phase-serial record instead of erroring.
        let rep = SystemInfo {
            topology: "dual".into(),
            clusters: vec![SystemClusterInfo {
                name: "c0".into(),
                num_pes: 16,
                cycles: 10,
                instructions: 20,
                flops: 30,
                slice_windows: Vec::new(),
            }],
            links: vec![],
            bus_words: 1,
            bus_busy_cycles: 1,
            stage_cycles: 1,
            compute_cycles: 10,
            merge_cycles: 1,
            link_words: 0,
            slices: 1,
            exposed_bus_cycles: 0,
            hidden_bus_cycles: 0,
        };
        let Json::Obj(mut pairs) = rep.to_json() else { panic!("system info is an object") };
        pairs.retain(|(k, _)| {
            k != "slices" && k != "exposed_bus_cycles" && k != "hidden_bus_cycles"
        });
        for (k, v) in pairs.iter_mut() {
            if k == "clusters" {
                let Json::Arr(cs) = v else { panic!("clusters is an array") };
                for c in cs {
                    let Json::Obj(cp) = c else { panic!("cluster is an object") };
                    cp.retain(|(ck, _)| ck != "slice_windows");
                }
            }
        }
        let old = SystemInfo::from_json(&Json::Obj(pairs)).unwrap();
        assert_eq!(old, rep);
    }

    #[test]
    fn verdict_json_round_trips() {
        for v in [
            Verdict::Passed { detail: "256 elems, tol 1e-5".into() },
            Verdict::Failed { reason: "max |d| 0.3".into() },
            Verdict::NotChecked,
        ] {
            assert_eq!(Verdict::from_json(&v.to_json()).unwrap(), v);
        }
    }
}
