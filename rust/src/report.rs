//! Plain-text table/series rendering shared by the CLI (`terapool <exp>`)
//! and the criterion benches, so every paper table/figure regenerates with
//! identical formatting in both paths.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}
pub fn int(x: u64) -> String {
    format!("{x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "metric"]);
        t.row(vec!["x".into(), f2(1.5)]);
        t.row(vec!["longer".into(), pct(0.123)]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("12.3%"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
